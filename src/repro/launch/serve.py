"""Production serving launcher (batched decode; see runtime/server.py).

    PYTHONPATH=src python -m repro.launch.serve --arch zamba2-7b --reduced

Fault-tolerance knobs (PR 7): ``--index-policy`` hardens prompt/offset
streams, ``--ttft-slo``/``--capacity-rps`` turn on SLO-aware shedding,
``--wave-deadline`` arms the wave watchdog, and ``--chaos-site``/
``--chaos-at`` inject a seeded fault schedule (see runtime/faults.py) to
exercise the recovery path from the command line.

Disaggregated embedding tier (PR 8): ``--disagg`` moves the stacked
tables into ``--replicas`` embedding-service processes
(runtime/embedding_service.py) reached over the fault-tolerant RPC tier —
``--rpc-timeout-s`` bounds every call, ``--degrade-policy`` decides what
a step does while every replica is dark (hot-slab lookups always serve
locally).
"""
from __future__ import annotations

import argparse
import collections

import jax
import numpy as np

from ..configs import get_config, get_reduced
from ..models import LM
from ..runtime.faults import FaultInjector, FaultSpec
from ..runtime.server import DecodeServer, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prefill-chunk", type=int, default=8)
    ap.add_argument("--pipeline", action="store_true",
                    help="cross-program pipelining: feed each wave's "
                         "access streams through the PipelineGroup")
    ap.add_argument("--index-policy", default="strict",
                    choices=("strict", "clamp", "drop"),
                    help="offset-stream hardening: strict fails the "
                         "request typed, clamp/drop repair and count")
    ap.add_argument("--ttft-slo", type=float, default=None, metavar="S",
                    help="server-wide TTFT budget (seconds); lapsed "
                         "requests expire, hopeless ones shed")
    ap.add_argument("--capacity-rps", default=None,
                    type=lambda s: s if s == "auto" else float(s),
                    help="calibrated service capacity (requests/s) for "
                         "submit-time predicted-wait shedding, or 'auto' "
                         "to self-calibrate from the measured wave-time "
                         "EWMA after a warmup wave count (live estimate "
                         "surfaced as serve_stats.capacity_rps_live)")
    ap.add_argument("--wave-deadline", type=float, default=None,
                    metavar="S", help="wave watchdog deadline (seconds)")
    ap.add_argument("--wave-retries", type=int, default=1)
    ap.add_argument("--disagg", action="store_true",
                    help="serve the embedding programs from a pool of "
                         "embedding-service replica processes (the "
                         "disaggregated tier) instead of in-process")
    ap.add_argument("--replicas", type=int, default=2,
                    help="embedding-service replicas behind --disagg")
    ap.add_argument("--rpc-timeout-s", type=float, default=30.0,
                    help="per-call RPC deadline of the service client")
    ap.add_argument("--artifact-dir", default=None,
                    help="AOT serving artifact directory "
                         "(core/artifact.py): boot loads the compiled "
                         "program + serialized executables from here "
                         "instead of compiling (fingerprint-gated, falls "
                         "back to a fresh compile); a fresh compile is "
                         "saved back after the first wave")
    ap.add_argument("--degrade-policy", default="fail",
                    choices=("fail", "stale"),
                    help="cold-lookup resolution while every replica is "
                         "dark: fail typed, or serve the local (possibly "
                         "stale) table copy")
    ap.add_argument("--chaos-site", default=None,
                    choices=("marshal", "transfer", "dispatch", "result",
                             "wave", "rpc_send", "rpc_recv", "heartbeat",
                             "service_crash"),
                    help="inject an InjectedFailure at this site")
    ap.add_argument("--chaos-at", type=int, nargs="*", default=[1],
                    help="1-based call ordinals of the site to fire at")
    ap.add_argument("--chaos-seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    faults = None
    if args.chaos_site is not None:
        faults = FaultInjector(
            [FaultSpec(args.chaos_site, at=tuple(args.chaos_at))],
            seed=args.chaos_seed)
    pool = None
    if args.disagg:
        from ..runtime.embedding_service import ServicePool
        pool = ServicePool(args.replicas, rpc_timeout_s=args.rpc_timeout_s,
                           heartbeat_interval_s=0.5, faults=faults)
    try:
        srv = DecodeServer(lm, params, batch_slots=args.slots,
                           max_len=args.max_len,
                           prefill_chunk=args.prefill_chunk,
                           pipeline=args.pipeline,
                           index_policy=args.index_policy,
                           capacity_rps=args.capacity_rps,
                           ttft_slo_s=args.ttft_slo,
                           wave_deadline_s=args.wave_deadline,
                           wave_retries=args.wave_retries,
                           faults=faults,
                           service="disagg" if args.disagg else "inproc",
                           service_pool=pool,
                           degrade_policy=args.degrade_policy,
                           artifact_dir=args.artifact_dir)
        _drive(srv, lm, cfg, args, faults, pool)
    finally:
        if pool is not None:
            pool.close()


def _drive(srv, lm, cfg, args, faults, pool):
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size, 8).astype(
        np.int32), max_new_tokens=16) for _ in range(args.requests)]
    for r in reqs:
        srv.submit(r)
    steps = srv.run_until_drained()
    statuses = collections.Counter(r.status for r in reqs)
    print(f"served {len(reqs)} requests in {steps} serving iterations; "
          f"all done={all(r.done for r in reqs)}; "
          f"statuses={dict(statuses)}")
    print("serve_stats:", srv.serve_stats)
    if args.artifact_dir and srv.compile_stats is not None:
        print("artifact:", srv.compile_stats.get("artifact", {}))
    if pool is not None:
        print("service_pool:", pool.stats())
    if faults is not None:
        print("chaos:", faults.stats())
    if srv.pipeline_group is not None:
        print("pipeline_group:",
              srv.compile_stats.get("pipeline_group", {}))


if __name__ == "__main__":
    main()
