"""Production serving launcher (batched decode; see runtime/server.py).

    PYTHONPATH=src python -m repro.launch.serve --arch zamba2-7b --reduced
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from ..configs import get_config, get_reduced
from ..models import LM
from ..runtime.server import DecodeServer, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prefill-chunk", type=int, default=8)
    ap.add_argument("--pipeline", action="store_true",
                    help="cross-program pipelining: feed each wave's "
                         "access streams through the PipelineGroup")
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    srv = DecodeServer(lm, params, batch_slots=args.slots,
                       max_len=args.max_len,
                       prefill_chunk=args.prefill_chunk,
                       pipeline=args.pipeline)
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size, 8).astype(
        np.int32), max_new_tokens=16) for _ in range(args.requests)]
    for r in reqs:
        srv.submit(r)
    steps = srv.run_until_drained()
    print(f"served {len(reqs)} requests in {steps} serving iterations; "
          f"all done={all(r.done for r in reqs)}")
    print("serve_stats:", srv.serve_stats)
    if srv.pipeline_group is not None:
        print("pipeline_group:",
              srv.compile_stats.get("pipeline_group", {}))


if __name__ == "__main__":
    main()
