import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run (deliverable e).

For every (architecture × input shape × mesh) cell: build the step bundle,
``jit(...).lower(...)``, ``.compile()``, and record
``memory_analysis`` / ``cost_analysis`` / collective-bytes (parsed from the
HLO) into ``experiments/dryrun/<arch>__<shape>__<mesh>.json``.

The single-pod 16×16 mesh feeds the roofline table; the 2×16×16 multi-pod
mesh proves the ``pod`` axis shards.  Any failure here (sharding mismatch,
compile-time OOM, unsupported collective) is a bug in the framework.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-4b \
        --shape train_4k --mesh single [--compile-only]
    PYTHONPATH=src python -m repro.launch.dryrun --all
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax

from ..configs import get_config, list_archs
from ..roofline.analysis import collective_bytes_from_hlo, roofline_terms
from .mesh import make_production_mesh, mesh_context
from .steps import SHAPES, build_bundle, shape_applicable

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def run_cell(arch: str, shape: str, mesh_kind: str, remat: str = "dots",
             skip_existing: bool = True, do_cost: bool = True,
             variant: str = "", overrides: dict = None) -> dict:
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    suffix = f"__{variant}" if variant else ""
    out_path = OUT_DIR / f"{arch}__{shape}__{mesh_kind}{suffix}.json"
    if skip_existing and out_path.exists():
        prev = json.loads(out_path.read_text())
        # re-run when a cost pass is requested but missing from the record
        if not (do_cost and mesh_kind == "single"
                and prev.get("status") == "ok"
                and "roofline" not in prev):
            return prev

    import dataclasses as _dc
    cfg = get_config(arch)
    if overrides:
        cfg = _dc.replace(cfg, **overrides)
    rec = {"arch": arch, "shape": shape, "mesh": mesh_kind, "remat": remat,
           "variant": variant, "overrides": overrides or {}}
    skip = shape_applicable(cfg, shape)
    if skip:
        rec["status"] = "skipped"
        rec["reason"] = skip
        out_path.write_text(json.dumps(rec, indent=2))
        return rec

    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
        with mesh_context(mesh):
            bundle = build_bundle(cfg, mesh, shape, remat=remat)
            jitted = jax.jit(bundle.fn, in_shardings=bundle.in_shardings)
            lowered = jitted.lower(*bundle.args)
            rec["lower_s"] = round(time.time() - t0, 1)
            hlo = lowered.as_text()
            rec["collective_bytes"] = collective_bytes_from_hlo(hlo)
            t1 = time.time()
            compiled = lowered.compile()
            rec["compile_s"] = round(time.time() - t1, 1)
            mem = compiled.memory_analysis()
            rec["memory"] = {
                k: int(getattr(mem, k, 0) or 0)
                for k in ("argument_size_in_bytes", "output_size_in_bytes",
                          "temp_size_in_bytes", "generated_code_size_in_bytes",
                          "alias_size_in_bytes")}
            cost = compiled.cost_analysis()
            if isinstance(cost, (list, tuple)):
                cost = cost[0] if cost else {}
            rec["flops_scanned"] = float((cost or {}).get("flops", 0.0))
            rec["bytes_scanned"] = float(
                (cost or {}).get("bytes accessed", 0.0))

            # --- cost pass (single-pod only): scan-free/unrolled variant.
            # XLA cost analysis counts while-loop bodies ONCE, so the
            # scanned program undercounts; the unrolled cost-mode COMPILED
            # module gives trip-correct, fusion-real, post-SPMD PER-DEVICE
            # flops / bytes / collective traffic (roofline methodology in
            # EXPERIMENTS.md).
            if mesh_kind == "single" and do_cost:
                t2 = time.time()
                cost_bundle = build_bundle(cfg, mesh, shape, remat="none",
                                           cost_mode=True)
                cost_lowered = jax.jit(
                    cost_bundle.fn,
                    in_shardings=cost_bundle.in_shardings).lower(
                        *cost_bundle.args)
                ccost_lo = cost_lowered.cost_analysis() or {}
                # global (pre-SPMD) flops — fallback + cross-check
                rec["flops_global_lowered"] = float(
                    ccost_lo.get("flops", 0.0))
                n = mesh.devices.size
                try:
                    cost_compiled = cost_lowered.compile()
                    ccost = cost_compiled.cost_analysis()
                    if isinstance(ccost, (list, tuple)):
                        ccost = ccost[0] if ccost else {}
                    rec["flops_per_device"] = float(ccost.get("flops", 0.0))
                    rec["bytes_per_device"] = float(
                        ccost.get("bytes accessed", 0.0))
                    rec["coll_bytes_per_device"] = collective_bytes_from_hlo(
                        cost_compiled.as_text())
                    rec["cost_compiled"] = True
                except Exception as ce:  # noqa: BLE001 — degrade gracefully
                    rec["cost_compiled"] = False
                    rec["cost_compile_error"] = f"{type(ce).__name__}: {ce}"
                    rec["flops_per_device"] = \
                        rec["flops_global_lowered"] / n
                    f = (rec["flops_global_lowered"] /
                         (rec["flops_scanned"] * n)
                         if rec["flops_scanned"] else 1.0)
                    rec["bytes_per_device"] = rec["bytes_scanned"] * max(f, 1)
                    rec["coll_bytes_per_device"] = \
                        collective_bytes_from_hlo(hlo)
                rec["cost_pass_s"] = round(time.time() - t2, 1)
                rec["flops"] = rec["flops_per_device"] * n
                rec["roofline"] = roofline_terms(
                    flops=rec["flops_per_device"],
                    bytes_accessed=rec["bytes_per_device"],
                    collective_bytes=rec["coll_bytes_per_device"],
                    n_chips=1)  # all quantities are per-device already
            rec["status"] = "ok"
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-3000:]
    rec["total_s"] = round(time.time() - t0, 1)
    out_path.write_text(json.dumps(rec, indent=2))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multipod"])
    ap.add_argument("--remat", default="dots",
                    choices=["none", "dots", "full"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--no-cost", action="store_true",
                    help="skip the (expensive) unrolled cost pass")
    args = ap.parse_args()

    cells = []
    archs = list_archs() if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) \
        else [args.shape]
    meshes = ["single", "multipod"] if args.all else [args.mesh]
    for a in archs:
        for s in shapes:
            for m in meshes:
                cells.append((a, s, m))

    for arch, shape, mesh_kind in cells:
        rec = run_cell(arch, shape, mesh_kind, remat=args.remat,
                       skip_existing=not args.force,
                       do_cost=not args.no_cost)
        status = rec["status"]
        extra = (f"flops={rec.get('flops', 0):.3e} "
                 f"coll={rec.get('collective_bytes', 0):.3e}B "
                 f"t={rec.get('total_s', '?')}s"
                 if status == "ok" else rec.get("reason",
                                                rec.get("error", ""))[:90])
        print(f"[{status:7s}] {arch:24s} {shape:12s} {mesh_kind:8s} {extra}",
              flush=True)


if __name__ == "__main__":
    main()
