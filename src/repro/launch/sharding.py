"""Sharding rules: params, optimizer state (ZeRO-1), KV caches, batches.

Conventions (DESIGN.md §5):
  * embedding / unembedding tables: vocab → ``model``
  * attention projections: heads (fused head·dim columns) → ``model``
  * MLP: hidden → ``model`` (column then row parallel)
  * MoE: experts → ``model`` (EP == TP axis)
  * SSM/xLSTM inner dims → ``model``
  * batch dims → (``pod``, ``data``)
  * optimizer moments: params' spec, plus ZeRO-1 sharding of replicated
    leaves over ``data``
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from jax.tree_util import DictKey, SequenceKey

COL = {"wq", "wk", "wv", "wi_gate", "wi_up", "w_in", "w_gate", "w_if"}
ROW = {"wo", "w_out"}
REPL = {"router", "A_log", "D", "dt_bias", "b_i", "b_f", "b", "conv_w",
        "norm1", "norm2", "norm_x", "norm_z", "final_norm", "enc_norm",
        "frontend_proj", "w_kr", "r"}


def _path_names(path):
    out = []
    for k in path:
        if isinstance(k, DictKey):
            out.append(str(k.key))
        elif isinstance(k, SequenceKey):
            out.append(f"[{k.idx}]")
        else:
            out.append(str(k))
    return out


def param_spec(path, leaf) -> P:
    names = _path_names(path)
    name = names[-1]
    stacked = names[0] in ("scan", "enc_scan")
    in_moe = "moe" in names and "shared" not in names

    if name == "embed":
        base = ("model", None)
    elif name in REPL or leaf.ndim <= 1:
        base = (None,) * (leaf.ndim - (1 if stacked else 0))
    elif in_moe and name in ("wi_gate", "wi_up", "wo"):
        base = ("model", None, None)          # experts → model (EP)
    elif name in ("w_dkv", "w_uk", "w_uv"):
        base = (None, "model")
    elif name in COL:
        base = (None, "model")
    elif name in ROW:
        base = ("model", None)
    else:
        base = (None,) * (leaf.ndim - (1 if stacked else 0))
    if stacked:
        base = (None,) + tuple(base)
    assert len(base) == leaf.ndim, (names, leaf.ndim, base)
    return P(*base)


def param_specs(params):
    return jax.tree_util.tree_map_with_path(param_spec, params)


def zero1_specs(params, specs, data_axes: tuple, mesh):
    """ZeRO-1: optimizer moments of *replicated* leaves shard their leading
    dim over the data axes when divisible (param itself stays replicated)."""
    dsize = int(np.prod([mesh.shape[a] for a in data_axes])) if data_axes \
        else 1

    def one(leaf, spec):
        if dsize <= 1 or leaf.ndim == 0:
            return spec
        if all(s is None for s in spec) and leaf.shape[0] % dsize == 0 \
                and leaf.shape[0] >= dsize:
            return P(tuple(data_axes), *((None,) * (leaf.ndim - 1)))
        return spec

    return jax.tree.map(one, params, specs)


# ---------------------------------------------------------------------------
# Caches & batches
# ---------------------------------------------------------------------------

def cache_spec(path, leaf, batch_axes, msize: int = 1) -> P:
    names = _path_names(path)
    name = names[-1]
    stacked = names[0] == "scan"
    nd = leaf.ndim - (1 if stacked else 0)
    ba = batch_axes if batch_axes else None

    if name == "len" or nd == 0:
        base = (None,) * nd
    elif name in ("k", "v"):            # (B, S, Hkv, hd)
        hkv = leaf.shape[-2]
        # few-KV-head archs (gemma3 kv=4 < model=16): shard head_dim instead
        base = (ba, None, "model", None) if hkv % msize == 0 \
            else (ba, None, None, "model")
    elif name == "c" and nd == 3:       # mla latent (B, S, r)
        base = (ba, None, "model")
    elif name == "kr":                  # (B, S, rd)
        base = (ba, None, None)
    elif name == "state":               # mamba (B, H, P, N)
        base = (ba, "model", None, None)
    elif name == "conv":                # (B, 3, d_inner)
        base = (ba, None, "model")
    elif name == "C":                   # mlstm (B, H, hd, hd)
        base = (ba, None, "model", None)
    elif name == "n" and nd == 4:       # mlstm normalizer (B, H, 1, hd)
        base = (ba, None, None, None)
    elif nd == 2:                       # slstm scalars (B, d)
        base = (ba, "model")
    else:
        base = (ba,) + (None,) * (nd - 1)
    if stacked:
        base = (None,) + tuple(base)
    base = tuple(base)[:leaf.ndim]
    base = base + (None,) * (leaf.ndim - len(base))
    return P(*base)


def cache_specs(caches, batch: int, mesh, data_axes: tuple):
    dsize = int(np.prod([mesh.shape[a] for a in data_axes])) if data_axes \
        else 1
    ba = tuple(data_axes) if batch % max(dsize, 1) == 0 and batch >= dsize \
        else ()
    msize = mesh.shape["model"]
    specs = jax.tree_util.tree_map_with_path(
        lambda p, l: cache_spec(p, l, ba, msize), caches)
    return sanitize_specs(specs, caches, mesh)


def batch_specs(batch_struct: dict, batch: int, mesh, data_axes: tuple):
    dsize = int(np.prod([mesh.shape[a] for a in data_axes])) if data_axes \
        else 1
    ba = tuple(data_axes) if batch % max(dsize, 1) == 0 and batch >= dsize \
        else None

    def one(leaf):
        return P(ba, *((None,) * (leaf.ndim - 1)))
    return jax.tree.map(one, batch_struct)


def sanitize_specs(specs, tree, mesh):
    """Drop any per-dim axis assignment that does not divide the dim —
    e.g. 4 KV heads cannot shard over model=16, so the spec falls back to
    the head_dim (caller's alternate) or replication for that dim."""
    def one(spec, leaf):
        dims = []
        for i in range(leaf.ndim):
            ax = spec[i] if i < len(spec) else None
            if ax is None:
                dims.append(None)
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            size = int(np.prod([mesh.shape[a] for a in axes]))
            dims.append(ax if leaf.shape[i] % size == 0 and
                        leaf.shape[i] >= size else None)
        return P(*dims)
    return jax.tree.map(one, specs, tree,
                        is_leaf=lambda x: isinstance(x, P))


def to_shardings(mesh, specs):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# Executor table shardings (vocab-partitioned stacked tables)
# ---------------------------------------------------------------------------

def table_row_sharding(mesh, axis: str = "model") -> NamedSharding:
    """Row (vocab) sharding of a stacked embedding table — the placement the
    sharded :class:`~repro.core.executor.ProgramExecutor` gives its fused
    stacked buffers and routed ``(S, …)`` offset-stream buckets (leading dim
    = shard)."""
    return leading_axis_sharding(mesh, axis, 2)


def leading_axis_sharding(mesh, axis: str = "model",
                          ndim: int = 2) -> NamedSharding:
    """Shard only the leading dim over ``axis`` — stacked tables and routed
    2-D buckets (``ndim=2``), and the collective exchange's ``(S_src, …)``
    send buffers (``ndim`` 3/4: dim 0 = source shard)."""
    return NamedSharding(mesh, P(axis, *((None,) * (ndim - 1))))


def replicated_sharding(mesh, ndim: int = 1) -> NamedSharding:
    """Fully-replicated placement (the executor's ``roff`` streams and
    pooled outputs)."""
    return NamedSharding(mesh, P(*(None,) * ndim))
