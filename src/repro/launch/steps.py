"""train_step / serve_step factories + ShapeDtypeStruct input specs.

``input_specs(cfg, shape_name)`` returns weak-type-correct stand-ins for
every model input — no device allocation — which is what both the multi-pod
dry-run and the roofline analysis lower against.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from ..models import LM, ModelConfig, ShardCtx
from ..optim import adamw, apply_updates
from . import sharding as shd
from .mesh import data_axes_of

SHAPES = {
    # name: (seq_len, global_batch, kind)
    "train_4k": (4096, 256, "train"),
    "prefill_32k": (32768, 32, "prefill"),
    "decode_32k": (32768, 128, "decode"),
    "long_500k": (524288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape_name: str) -> Optional[str]:
    """None if the (arch, shape) cell runs; else the documented skip reason."""
    if shape_name == "long_500k" and not cfg.long_context_ok:
        return ("full-attention arch: 500k decode needs sub-quadratic "
                "attention state (DESIGN.md §4)")
    return None


def make_batch_struct(cfg: ModelConfig, seq: int, batch: int,
                      kind: str) -> dict:
    i32 = jnp.int32
    d = cfg.jdtype
    out: dict = {}
    if kind == "train":
        out["tokens"] = jax.ShapeDtypeStruct((batch, seq), i32)
        out["labels"] = jax.ShapeDtypeStruct((batch, seq), i32)
    elif kind == "prefill":
        out["tokens"] = jax.ShapeDtypeStruct((batch, seq), i32)
    else:  # decode: one new token against a cache of `seq`
        out["tokens"] = jax.ShapeDtypeStruct((batch, 1), i32)
    if cfg.modality == "audio-stub" and kind != "decode":
        out["enc_embeds"] = jax.ShapeDtypeStruct((batch, seq, cfg.d_model), d)
    if cfg.modality == "vision-stub" and kind != "decode":
        from ..configs.llava_next_34b import VISION_TOKENS
        n = min(VISION_TOKENS, seq)
        out["frontend_embeds"] = jax.ShapeDtypeStruct(
            (batch, n, cfg.d_model), d)
    return out


@dataclasses.dataclass
class StepBundle:
    """Everything needed to lower one (arch × shape × mesh) cell."""
    fn: object               # jit-able step function
    args: tuple              # ShapeDtypeStructs (abstract) in order
    in_shardings: tuple
    kind: str


def make_lm(cfg: ModelConfig, mesh, remat: str = "dots",
            cost_mode: bool = False) -> LM:
    shard = ShardCtx(mesh=mesh, data_axes=data_axes_of(mesh),
                     model_axis="model", remat=remat, cost_mode=cost_mode)
    return LM(cfg, shard)


def build_train_bundle(cfg: ModelConfig, mesh, seq: int, batch: int,
                       remat: str = "dots",
                       cost_mode: bool = False) -> StepBundle:
    lm = make_lm(cfg, mesh, remat, cost_mode=cost_mode)
    opt = adamw(lr=3e-4)
    params_s = jax.eval_shape(lm.init, jax.random.PRNGKey(0))
    opt_s = jax.eval_shape(opt.init, params_s)
    batch_struct = make_batch_struct(cfg, seq, batch, "train")

    p_specs = shd.sanitize_specs(shd.param_specs(params_s), params_s, mesh)
    mu_specs = shd.zero1_specs(params_s, p_specs, data_axes_of(mesh), mesh)
    from ..optim.adamw import AdamWState
    opt_specs = AdamWState(jax.sharding.PartitionSpec(), mu_specs, mu_specs)
    b_specs = shd.batch_specs(batch_struct, batch, mesh, data_axes_of(mesh))

    def train_step(params, opt_state, batch_):
        loss, grads = jax.value_and_grad(lm.loss)(params, batch_)
        updates, opt_state = opt.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        return params, opt_state, loss

    in_sh = (shd.to_shardings(mesh, p_specs),
             shd.to_shardings(mesh, opt_specs),
             shd.to_shardings(mesh, b_specs))
    return StepBundle(train_step, (params_s, opt_s, batch_struct), in_sh,
                      "train")


def build_prefill_bundle(cfg: ModelConfig, mesh, seq: int,
                         batch: int, cost_mode: bool = False) -> StepBundle:
    lm = make_lm(cfg, mesh, remat="none", cost_mode=cost_mode)
    params_s = jax.eval_shape(lm.init, jax.random.PRNGKey(0))
    batch_struct = make_batch_struct(cfg, seq, batch, "prefill")
    p_specs = shd.sanitize_specs(shd.param_specs(params_s), params_s, mesh)
    b_specs = shd.batch_specs(batch_struct, batch, mesh, data_axes_of(mesh))

    def prefill_step(params, batch_):
        return lm.prefill(params, batch_, None)

    in_sh = (shd.to_shardings(mesh, p_specs),
             shd.to_shardings(mesh, b_specs))
    return StepBundle(prefill_step, (params_s, batch_struct), in_sh,
                      "prefill")


def build_decode_bundle(cfg: ModelConfig, mesh, cache_len: int,
                        batch: int, cost_mode: bool = False) -> StepBundle:
    lm = make_lm(cfg, mesh, remat="none", cost_mode=cost_mode)
    params_s = jax.eval_shape(lm.init, jax.random.PRNGKey(0))
    caches_s = jax.eval_shape(
        lambda: lm.init_caches(batch, cache_len), )
    batch_struct = make_batch_struct(cfg, cache_len, batch, "decode")
    da = data_axes_of(mesh)
    p_specs = shd.sanitize_specs(shd.param_specs(params_s), params_s, mesh)
    c_specs = shd.cache_specs(caches_s, batch, mesh, da)
    b_specs = shd.batch_specs(batch_struct, batch, mesh, da)

    extra = {}
    if cfg.enc_layers:  # whisper cross-attention context
        extra["enc_out"] = jax.ShapeDtypeStruct(
            (batch, min(cfg.enc_seq, cache_len), cfg.d_model), cfg.jdtype)
    e_specs = shd.batch_specs(extra, batch, mesh, da) if extra else {}

    def serve_step(params, tokens, caches, extra_):
        logits, caches = lm.decode_step(params, tokens, caches,
                                        batch_ctx=extra_ or None)
        return logits, caches

    in_sh = (shd.to_shardings(mesh, p_specs),
             shd.to_shardings(mesh, b_specs)["tokens"],
             shd.to_shardings(mesh, c_specs),
             shd.to_shardings(mesh, e_specs))
    return StepBundle(serve_step,
                      (params_s, batch_struct["tokens"], caches_s, extra),
                      in_sh, "decode")


def build_bundle(cfg: ModelConfig, mesh, shape_name: str,
                 remat: str = "dots",
                 cost_mode: bool = False) -> StepBundle:
    seq, batch, kind = SHAPES[shape_name]
    if kind == "train":
        return build_train_bundle(cfg, mesh, seq, batch, remat, cost_mode)
    if kind == "prefill":
        return build_prefill_bundle(cfg, mesh, seq, batch, cost_mode)
    return build_decode_bundle(cfg, mesh, seq, batch, cost_mode)
