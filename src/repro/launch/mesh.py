"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — the dry-run sets
``--xla_force_host_platform_device_count=512`` before any jax import, and
smoke tests must keep seeing 1 device.
"""
from __future__ import annotations

import jax


def axis_types_kw(n_axes: int) -> dict:
    """``axis_types=`` kwarg for ``jax.make_mesh`` across jax versions:
    ``jax.sharding.AxisType`` only exists from jax 0.5 on; older versions
    already default to the Auto semantics we want, so omit the kwarg."""
    at = getattr(jax.sharding, "AxisType", None)
    return {} if at is None else {"axis_types": (at.Auto,) * n_axes}


def mesh_context(mesh):
    """Context manager activating ``mesh``: ``jax.set_mesh`` where it exists
    (jax ≥ 0.6); the Mesh object itself is the context manager before."""
    set_mesh = getattr(jax, "set_mesh", None)
    return set_mesh(mesh) if set_mesh is not None else mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2 pods = 512 chips for the multi-pod run.

    Axes: ``data`` (in-pod DP), ``model`` (TP/EP/vocab/head sharding),
    ``pod`` (cross-pod pure-DP; its gradient all-reduce crosses DCN).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **axis_types_kw(len(axes)))


def data_axes_of(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_shard_count(mesh, axis: str = "model") -> int:
    """Vocab-shard count the steady-state executor will use on ``mesh`` —
    the launch-layer alias of :func:`repro.core.shard_plan.shard_count`
    (one definition; imported lazily so this module stays importable before
    the kernel stack)."""
    from ..core.shard_plan import shard_count
    return shard_count(mesh, axis)


def make_host_mesh(model_parallel: int = 1):
    """Whatever this host actually has — used by examples and tests."""
    n = len(jax.devices())
    dp = n // model_parallel
    return jax.make_mesh((dp, model_parallel), ("data", "model"),
                         **axis_types_kw(2))
