"""Production training launcher.

On a real TPU pod slice this runs under `jax.distributed.initialize()` with
one process per host; here it drives the same code path on the local
device set.  Fault tolerance comes from the supervised restart loop
(`repro.runtime.trainer`); elastic rescale from the offset-based
checkpoints.

    PYTHONPATH=src python -m repro.launch.train --arch gemma3-4b \
        --reduced --steps 50 --model-parallel 2
"""
from __future__ import annotations

import argparse

import jax

from ..configs import get_config, get_reduced
from ..data.pipeline import DataConfig, SyntheticTokens
from ..models import LM, ShardCtx
from ..runtime.trainer import Trainer, TrainerConfig, run_supervised
from .mesh import data_axes_of, make_host_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--compress", action="store_true")
    ap.add_argument("--deadline-s", type=float, default=None)
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    mesh = make_host_mesh(args.model_parallel) \
        if args.model_parallel > 1 else None
    shard = ShardCtx(mesh=mesh, data_axes=data_axes_of(mesh)) if mesh \
        else ShardCtx()
    data = SyntheticTokens(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        global_batch=args.global_batch, modality=cfg.modality,
        d_model=cfg.d_model, enc_seq=args.seq))
    tcfg = TrainerConfig(total_steps=args.steps,
                         ckpt_every=max(args.steps // 4, 1),
                         ckpt_dir=args.ckpt_dir,
                         grad_compression=args.compress,
                         step_deadline_s=args.deadline_s)

    out = run_supervised(lambda: Trainer(LM(cfg, shard), data, tcfg),
                         jax.random.PRNGKey(0))
    print(f"done: step={out['final_step']} restarts={out['restarts']} "
          f"loss {out['losses'][0]:.3f} -> {out['losses'][-1]:.3f}")


if __name__ == "__main__":
    main()
