"""Reuse-distance / locality characterization (paper §2.2, Table 1).

Temporal locality is characterized by the *reuse distance* of each access —
the number of other distinct vectors touched since the last access to the
same vector.  The CDF of reuse distances proxies the hit probability of a
cache holding x vectors: ``CDF(x) ≈ hit rate``.  These tools generate the
paper's L0/L1/L2 locality classes and feed both the characterization
benchmark and the DAE cost model.
"""
from __future__ import annotations

import numpy as np


def reuse_distances(trace: np.ndarray) -> np.ndarray:
    """Exact reuse distances (−1 for first accesses) via an LRU stack
    maintained with an order-statistics-free O(N·U) fallback or an O(N log N)
    Fenwick tree over last-access times."""
    trace = np.asarray(trace)
    n = len(trace)
    last_seen: dict = {}
    # Fenwick tree over positions: 1 if that position is the *latest* access
    # of its vector, else 0.  Reuse distance = # of set bits strictly between
    # last_seen[v] and now.
    tree = np.zeros(n + 1, np.int64)

    def add(i, v):
        i += 1
        while i <= n:
            tree[i] += v
            i += i & (-i)

    def prefix(i):
        i += 1
        s = 0
        while i > 0:
            s += tree[i]
            i -= i & (-i)
        return s

    out = np.empty(n, np.int64)
    for t, v in enumerate(trace):
        if v in last_seen:
            lp = last_seen[v]
            out[t] = prefix(t - 1) - prefix(lp)
            add(lp, -1)
        else:
            out[t] = -1
        add(t, 1)
        last_seen[v] = t
    return out


def reuse_cdf(trace: np.ndarray, xs: np.ndarray = None):
    """(xs, CDF(xs)) — fraction of accesses with reuse distance ≤ x.

    First accesses count as misses at every cache size (distance ∞)."""
    d = reuse_distances(trace)
    n = len(d)
    if xs is None:
        xs = np.unique(np.concatenate(
            [[1, 2, 4], np.logspace(1, 7, 25).astype(np.int64)]))
    reused = d[d >= 0]
    cdf = np.array([(reused <= x).sum() / n for x in xs])
    return xs, cdf


def hit_rate(trace: np.ndarray, cache_vectors: int) -> float:
    d = reuse_distances(trace)
    return float((d[d >= 0] <= cache_vectors).sum() / len(d))


def row_reuse_scores(trace: np.ndarray, num_rows: int) -> np.ndarray:
    """Per-row replication-benefit score: the number of accesses to each row
    with a *finite* reuse distance (i.e. its re-accesses).

    This is exactly the traffic a replicated copy of the row would absorb —
    a row touched once contributes nothing (its single access pays the
    exchange either way), while the Zipf head re-accessed thousands of times
    is where a hot slab removes exchange volume.  First accesses (distance
    -1 in :func:`reuse_distances`) are excluded by construction."""
    trace = np.asarray(trace, np.int64)
    d = reuse_distances(trace)
    scores = np.zeros(num_rows, np.int64)
    reused = trace[d >= 0]
    if len(reused):
        np.add.at(scores, reused, 1)
    return scores


def classify_hot(trace: np.ndarray, num_rows: int, max_hot: int) -> np.ndarray:
    """The Zipf head of one vocab: up to ``max_hot`` row ids worth
    replicating, ranked by :func:`row_reuse_scores` (ties broken by row id
    for determinism), returned sorted ascending.  Rows with zero reuse are
    never classified hot — an all-distinct trace yields an empty head."""
    if max_hot <= 0 or len(trace) == 0:
        return np.zeros(0, np.int64)
    scores = row_reuse_scores(trace, num_rows)
    candidates = np.flatnonzero(scores > 0)
    if len(candidates) == 0:
        return np.zeros(0, np.int64)
    order = np.lexsort((candidates, -scores[candidates]))
    return np.sort(candidates[order[:int(max_hot)]])


def make_trace(num_vectors: int, num_accesses: int, locality: str = "L1",
               seed: int = 0) -> np.ndarray:
    """Synthetic DLRM-style traces with low/medium/high locality
    (paper §8.1, following the Meta synthetic-input methodology [18])."""
    rng = np.random.default_rng(seed)
    alpha = {"L0": 0.0, "L1": 0.8, "L2": 1.4}[locality]
    if alpha == 0.0:
        return rng.integers(0, num_vectors, num_accesses).astype(np.int64)
    ranks = np.arange(1, num_vectors + 1, dtype=np.float64)
    p = ranks ** (-alpha)
    p /= p.sum()
    perm = rng.permutation(num_vectors)
    return perm[rng.choice(num_vectors, size=num_accesses, p=p)]
