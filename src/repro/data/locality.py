"""Reuse-distance / locality characterization (paper §2.2, Table 1).

Temporal locality is characterized by the *reuse distance* of each access —
the number of other distinct vectors touched since the last access to the
same vector.  The CDF of reuse distances proxies the hit probability of a
cache holding x vectors: ``CDF(x) ≈ hit rate``.  These tools generate the
paper's L0/L1/L2 locality classes and feed both the characterization
benchmark and the DAE cost model.
"""
from __future__ import annotations

import dataclasses

import numpy as np


def reuse_distances(trace: np.ndarray) -> np.ndarray:
    """Exact reuse distances (−1 for first accesses) via an LRU stack
    maintained with an order-statistics-free O(N·U) fallback or an O(N log N)
    Fenwick tree over last-access times."""
    trace = np.asarray(trace)
    n = len(trace)
    last_seen: dict = {}
    # Fenwick tree over positions: 1 if that position is the *latest* access
    # of its vector, else 0.  Reuse distance = # of set bits strictly between
    # last_seen[v] and now.
    tree = np.zeros(n + 1, np.int64)

    def add(i, v):
        i += 1
        while i <= n:
            tree[i] += v
            i += i & (-i)

    def prefix(i):
        i += 1
        s = 0
        while i > 0:
            s += tree[i]
            i -= i & (-i)
        return s

    out = np.empty(n, np.int64)
    for t, v in enumerate(trace):
        if v in last_seen:
            lp = last_seen[v]
            out[t] = prefix(t - 1) - prefix(lp)
            add(lp, -1)
        else:
            out[t] = -1
        add(t, 1)
        last_seen[v] = t
    return out


def reuse_cdf(trace: np.ndarray, xs: np.ndarray = None):
    """(xs, CDF(xs)) — fraction of accesses with reuse distance ≤ x.

    First accesses count as misses at every cache size (distance ∞)."""
    d = reuse_distances(trace)
    n = len(d)
    if xs is None:
        xs = np.unique(np.concatenate(
            [[1, 2, 4], np.logspace(1, 7, 25).astype(np.int64)]))
    reused = d[d >= 0]
    cdf = np.array([(reused <= x).sum() / n for x in xs])
    return xs, cdf


def hit_rate(trace: np.ndarray, cache_vectors: int) -> float:
    d = reuse_distances(trace)
    return float((d[d >= 0] <= cache_vectors).sum() / len(d))


def row_reuse_scores(trace: np.ndarray, num_rows: int) -> np.ndarray:
    """Per-row replication-benefit score: the number of accesses to each row
    with a *finite* reuse distance (i.e. its re-accesses).

    This is exactly the traffic a replicated copy of the row would absorb —
    a row touched once contributes nothing (its single access pays the
    exchange either way), while the Zipf head re-accessed thousands of times
    is where a hot slab removes exchange volume.  First accesses (distance
    -1 in :func:`reuse_distances`) are excluded by construction."""
    trace = np.asarray(trace, np.int64)
    d = reuse_distances(trace)
    scores = np.zeros(num_rows, np.int64)
    reused = trace[d >= 0]
    if len(reused):
        np.add.at(scores, reused, 1)
    return scores


def classify_hot(trace: np.ndarray, num_rows: int, max_hot: int) -> np.ndarray:
    """The Zipf head of one vocab: up to ``max_hot`` row ids worth
    replicating, ranked by :func:`row_reuse_scores` (ties broken by row id
    for determinism), returned sorted ascending.  Rows with zero reuse are
    never classified hot — an all-distinct trace yields an empty head."""
    if max_hot <= 0 or len(trace) == 0:
        return np.zeros(0, np.int64)
    scores = row_reuse_scores(trace, num_rows)
    candidates = np.flatnonzero(scores > 0)
    if len(candidates) == 0:
        return np.zeros(0, np.int64)
    order = np.lexsort((candidates, -scores[candidates]))
    return np.sort(candidates[order[:int(max_hot)]])


@dataclasses.dataclass(frozen=True)
class AdaptiveHotConfig:
    """Knobs for the executor's sliding-window hot-slab re-classifier.

    Frozen (hashable) so it can participate in executor cache keys.

    * ``window_steps`` — span of the sliding window, in executor steps.
    * ``num_windows`` — ring granularity: the window is a ring of this many
      count sketches, each covering ``window_steps / num_windows`` steps;
      rotating drops the oldest stripe so counts age out instead of
      accumulating for the process lifetime.
    * ``drift_threshold`` — swap trigger: re-classify when the windowed hot
      hit-rate falls below ``drift_threshold ×`` the reference hit-rate
      captured over the first full window after the last (re)classification.
    * ``min_swap_interval`` — steps that must elapse between swaps, bounding
      respecialization churn under oscillating traffic.
    * ``spill_fraction`` — cap on the fraction of an overloaded source
      shard's hot lookups that may spill to the least-loaded peer.
    * ``spill_overload`` — a source shard's lattice diagonal counts as
      overloaded when it exceeds this multiple of the mean diagonal load.
    * ``refine_passes`` — settling re-ranks after a drift-triggered swap.
      The reactive swap classifies on a window still partially filled with
      pre-drift counts; the swap flushes the window, and each refine pass
      re-ranks once the window has refilled with purely post-swap traffic,
      evicting rows the contaminated ranking kept.
    """
    window_steps: int = 64
    num_windows: int = 4
    drift_threshold: float = 0.6
    min_swap_interval: int = 32
    spill_fraction: float = 0.25
    spill_overload: float = 1.5
    refine_passes: int = 1

    def __post_init__(self):
        if self.window_steps < self.num_windows or self.num_windows < 1:
            raise ValueError("window_steps must be >= num_windows >= 1")
        if not (0.0 < self.drift_threshold <= 1.0):
            raise ValueError("drift_threshold must be in (0, 1]")
        if not (0.0 <= self.spill_fraction <= 1.0):
            raise ValueError("spill_fraction must be in [0, 1]")
        if self.refine_passes < 0:
            raise ValueError("refine_passes must be >= 0")


class WindowedCounts:
    """Per-row access counts over a sliding window of the last W steps.

    A ring of ``num_windows`` count stripes; each stripe accumulates
    ``window_steps // num_windows`` steps, then the ring advances and the
    oldest stripe is cleared.  ``totals()`` sums the ring — a bounded-age
    sketch of the recent head, unlike a lifetime-cumulative counter that
    drowns drift under history."""

    def __init__(self, num_rows: int, window_steps: int = 64,
                 num_windows: int = 4):
        if window_steps < num_windows or num_windows < 1:
            raise ValueError("window_steps must be >= num_windows >= 1")
        self.num_rows = int(num_rows)
        self.window_steps = int(window_steps)
        self.num_windows = int(num_windows)
        self.stride = max(1, self.window_steps // self.num_windows)
        self._ring = np.zeros((self.num_windows, self.num_rows), np.int64)
        self._slot = 0
        self._steps = 0          # lifetime steps observed
        self._wrapped = False    # True once every stripe has been filled

    @property
    def full(self) -> bool:
        """True once the ring spans a complete window."""
        return self._wrapped

    @property
    def steps(self) -> int:
        return self._steps

    def add(self, rows: np.ndarray) -> None:
        """Record one step's accessed row ids (any shape, any multiplicity).
        Out-of-range ids are ignored (hardening repairs run downstream)."""
        rows = np.asarray(rows, np.int64).ravel()
        if len(rows):
            rows = rows[(rows >= 0) & (rows < self.num_rows)]
            np.add.at(self._ring[self._slot], rows, 1)
        self._steps += 1
        if self._steps % self.stride == 0:
            self._slot = (self._slot + 1) % self.num_windows
            if self._slot == 0:
                self._wrapped = True
            self._ring[self._slot] = 0

    def totals(self) -> np.ndarray:
        """Summed per-row counts across the ring (the windowed sketch)."""
        return self._ring.sum(axis=0)

    def reset(self) -> None:
        self._ring[:] = 0
        self._slot = 0
        self._steps = 0
        self._wrapped = False


def classify_hot_from_counts(counts: np.ndarray, max_hot: int,
                             prev_hot: np.ndarray = None) -> np.ndarray:
    """Re-rank the hot set from windowed per-row counts.

    Same contract as :func:`classify_hot` — top ``max_hot`` rows by count,
    ties broken by row id, returned sorted ascending — but from live counts
    instead of a calibration trace.  Because a swapped slab must keep every
    slot's table shape constant (the lattice/executables are specialized on
    sizes, not membership), the result is padded with ``prev_hot`` ids (in
    their ranked order of recency-of-use, i.e. count-desc) so the returned
    set has *exactly* ``len(prev_hot)`` rows whenever ``prev_hot`` is
    given."""
    counts = np.asarray(counts, np.int64)
    if max_hot <= 0:
        return np.zeros(0, np.int64)
    candidates = np.flatnonzero(counts > 0)
    order = np.lexsort((candidates, -counts[candidates]))
    hot = candidates[order[:int(max_hot)]]
    if prev_hot is not None:
        prev_hot = np.asarray(prev_hot, np.int64)
        want = len(prev_hot)
        if len(hot) < want:
            # keep previously-hot rows (highest windowed count first) to
            # hold the set size — shape stability beats eviction here
            fill = prev_hot[~np.isin(prev_hot, hot)]
            fill = fill[np.argsort(-counts[fill], kind="stable")]
            hot = np.concatenate([hot, fill[:want - len(hot)]])
        else:
            hot = hot[:want]
    return np.sort(hot)


def make_trace(num_vectors: int, num_accesses: int, locality: str = "L1",
               seed: int = 0) -> np.ndarray:
    """Synthetic DLRM-style traces with low/medium/high locality
    (paper §8.1, following the Meta synthetic-input methodology [18])."""
    rng = np.random.default_rng(seed)
    alpha = {"L0": 0.0, "L1": 0.8, "L2": 1.4}[locality]
    if alpha == 0.0:
        return rng.integers(0, num_vectors, num_accesses).astype(np.int64)
    ranks = np.arange(1, num_vectors + 1, dtype=np.float64)
    p = ranks ** (-alpha)
    p /= p.sum()
    perm = rng.permutation(num_vectors)
    return perm[rng.choice(num_vectors, size=num_accesses, p=p)]
