"""Training data pipeline.

Deterministic, restartable synthetic token stream: the batch at step ``k``
is a pure function of (seed, k), so a restarted/elastically-rescaled job
resumes mid-epoch with zero state beyond the step counter (the checkpoint
stores only ``step``).  Sharded hosts draw disjoint slices of the global
batch by host index — the standard per-host data-loading contract.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    modality: str = "text"
    d_model: int = 0           # for stub frontends
    enc_seq: int = 0


class SyntheticTokens:
    """Markov-ish synthetic text: zipf unigram with local repetition, so the
    loss actually decreases during the e2e example runs."""

    def __init__(self, cfg: DataConfig, host_index: int = 0,
                 host_count: int = 1):
        assert cfg.global_batch % host_count == 0
        self.cfg = cfg
        self.host_index = host_index
        self.host_count = host_count
        self.local_batch = cfg.global_batch // host_count
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        p = ranks ** -1.1
        self._p = p / p.sum()

    def batch_at(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed, step, self.host_index))
        b, s = self.local_batch, cfg.seq_len
        base = rng.choice(cfg.vocab_size, size=(b, s + 1), p=self._p)
        # local repetition: with p=0.3 copy the previous token (learnable)
        rep = rng.random((b, s + 1)) < 0.3
        for t in range(1, s + 1):
            base[:, t] = np.where(rep[:, t], base[:, t - 1], base[:, t])
        out = {"tokens": base[:, :-1].astype(np.int32),
               "labels": base[:, 1:].astype(np.int32)}
        if cfg.modality == "audio-stub":
            out["enc_embeds"] = rng.standard_normal(
                (b, cfg.enc_seq or s, cfg.d_model)).astype(np.float32)
        elif cfg.modality == "vision-stub":
            out["frontend_embeds"] = rng.standard_normal(
                (b, min(576, s), cfg.d_model)).astype(np.float32)
        return out

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
