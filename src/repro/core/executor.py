"""ProgramExecutor — the steady-state runtime of a compiled embedding program.

The compile cache (PR 1) made per-step *pass* overhead free; this module
removes the per-step *data-movement* overhead and runs the program the way
the DAE machine is meant to run — the access stream ahead of execute:

    compile cache                 marshaling cache              step loop
    ─────────────                 ────────────────              ─────────
    (signature, O?, vlen)   ──▶   device-resident stacked   ──▶ double-
    ProgramCompileResult          tables + roff streams +       buffered
    (executor_for, LRU)           bucketed scratch buffers      submit/result

Three mechanisms, mirroring the DAE queue at program scope:

* **Marshaling cache** — everything per-*signature* is built once and kept
  device-resident: the fused units' row-stacked tables (device-side concat,
  donated in place on :meth:`ProgramExecutor.update_tables`), the per-segment
  ``roff`` table-offset streams, and per-batch-shape scratch buffers for the
  CSR operands.  A steady-state step does **zero host table stacking**.
* **Capacity buckets** — ``idxs``/``vals`` nnz and the ``max_lookups`` grid
  extent are padded to the capacity-bucket lattice carried by each unit's
  compiled :class:`~repro.core.access_plan.AccessPlan`
  (:mod:`repro.core.capacity`), so a ragged batch sequence reuses one
  kernel trace per bucket instead of re-specializing every step.
* **Cross-step access/execute overlap** — :meth:`ProgramExecutor.submit`
  marshals step N+1's access-side operands (host index packing + device
  transfer, dispatched asynchronously) while step N's execute phase is still
  in flight; ``jax.block_until_ready`` happens only at the consume point
  (:meth:`StepHandle.result`), with a bounded in-flight depth for
  backpressure.  Host scratch is double-buffered per bucket so packing
  step N+1 never races step N's transfer.

``executor_for`` memoizes executors on the program signature (bounded LRU)
alongside the compile cache, which is what the runtimes
(:mod:`repro.runtime.server`, :mod:`repro.runtime.trainer`) hold on to.

**Sharded programs** — pass ``mesh`` (and optionally ``shard_axis``) and the
fused units' stacked tables are vocab-partitioned over that mesh axis per
each unit's compiled :class:`~repro.core.access_plan.AccessPlan`: each
device holds a 1/S slice of every slot's cold tail plus the replicated hot
slab (the classified Zipf head — pass ``hot_rows`` to enable), the per-step
CSR streams are routed to their owning shards by the host interpreting the
plan (the access unit doing the offset-stream exchange, padded to the same
pow-2/quarter-octave capacity buckets so the exchange is retrace-free; hot
lookups stay local and pay no exchange), and the batched SLS kernel runs
under ``shard_map`` (:mod:`repro.core.shard_plan` owns the device bodies).
With ``exchange="collective"`` (the ≥2-shard default) the routed buckets
become the *send lattice* of a ``jax.lax.all_to_all`` executed inside the
shard_map body — one resident send buffer per step instead of per-shard
host scatters — and pooled outputs are **reduce-scattered** over the mesh
(each shard owns a contiguous segment slice; ``replicate_outputs=True`` is
the escape hatch back to the fully-replicated ``psum``/``pmax`` combine,
which is also the ``exchange="host"`` default).  A mesh of size 1 (or
``mesh=None``) takes exactly the single-device path.
"""
from __future__ import annotations

import dataclasses
import functools
import weakref
from collections import deque
from pathlib import Path
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ops as kops
from . import access_plan as ap
from . import backend_jax as bj
from . import backend_pallas as bp
from . import cost_model
from . import shard_plan as sp
from .cost_model import FusionBudget
from .ops import EmbeddingProgram
from .passes.fuse import FusedGroup
from .pipeline import (BoundedLru, ProgramCompileResult, compile_program,
                       entries_by_shards)


@dataclasses.dataclass(eq=False)  # identity semantics: outputs hold arrays
class StepHandle:
    """One in-flight program step.  ``outputs`` are lazy device arrays;
    :meth:`result` is the consume point (the only place that blocks)."""

    outputs: dict                 # op name -> device array (async)
    index: int                    # step number within the executor
    done: bool = False
    faults: object = None         # chaos injector (site "result"), if any
    # disaggregated steps: a zero-arg resolver for outputs still on the
    # wire — the RPC left at submit, the reply is consumed here, so the
    # submit/result overlap hides the extra hop exactly like it hides the
    # device round trip
    pending: object = None

    def result(self) -> dict:
        if self.faults is not None:
            self.faults.fire("result", step=self.index)
        if self.pending is not None:
            fn, self.pending = self.pending, None
            self.outputs.update(fn())
        jax.block_until_ready(self.outputs)
        self.done = True
        return self.outputs


class _TxnRef:
    """Placeholder for one host array riding a :class:`TransferBatch`."""
    __slots__ = ("i",)

    def __init__(self, i: int):
        self.i = i


class TransferBatch:
    """One serving wave's coalesced host→device transfer.

    :meth:`PipelineGroup.submit_wave` hands every member executor the same
    batch: gather-kind jax units stage their per-step streams into it
    instead of issuing individual transfers, and defer their dispatch as a
    pure ``run(dev_inputs) -> {op name: output}`` function.  :meth:`flush`
    ships every collected array in one batched ``jax.device_put`` and runs
    the deferred dispatches; the pipeline group goes further and traces
    all of them into a single jitted wave executable
    (:meth:`PipelineGroup.submit_wave`).  Per-wave transfer and dispatch
    overhead is paid once per *wave* instead of once per *array/op* — the
    structural edge of the pipelined serving path over stepping the
    programs sequentially (benchmarks/bench_serving.py's ablation)."""

    def __init__(self):
        self._host: list = []
        # (handle outputs dict, run fn, staged inputs with _TxnRefs)
        self.fills: list = []
        self.n_arrays = 0

    def put(self, arr: np.ndarray) -> _TxnRef:
        self._host.append(arr)
        self.n_arrays += 1
        return _TxnRef(len(self._host) - 1)

    def defer(self, outs: dict, run, staged: dict) -> None:
        self.fills.append((outs, run, staged))

    def flush(self) -> None:
        """One batched device_put, then the deferred unit dispatches
        (eagerly — the group's jitted wave path is in submit_wave)."""
        devs = jax.device_put(self._host) if self._host else []
        fills, self.fills, self._host = self.fills, [], []
        for outs, run, staged in fills:
            outs.update(run({k: devs[v.i] if isinstance(v, _TxnRef) else v
                             for k, v in staged.items()}))


class BufferPool:
    """Rotating host staging buffers behind the per-step marshaling.

    Each *entry* is a small ring of identically-shaped buffer sets; every
    slot remembers the :class:`StepHandle` that last packed it (recorded by
    :meth:`ProgramExecutor.submit`), so a slot is never rewritten while its
    transfer may still be in flight.  Acquisition scans the ring for a free
    slot; when every slot is busy the ring **grows** (up to ``max_slots``)
    instead of stalling — with a *shared* pool a forced drain would block
    program A's marshal on program B's execute, exactly the serialization
    the pipeline group exists to avoid.  Only a full ring at ``max_slots``
    pays a ``forced_drains`` stall.

    ``shared=False`` (each executor's private default) keys entries by
    ``(executor, unit, capacity bucket)`` — the legacy double-buffer
    layout.  ``shared=True`` (:func:`pipeline_group`) keys by the canonical
    *buffer spec signature* alone, so same-shaped staging of different
    compiled programs draws from one ring: the device-buffer pool that lets
    two programs pipeline against each other.  Sharing is safe because
    every marshal path fully overwrites what its kernel reads (CSR tails
    are padded in-bounds per step).
    """

    def __init__(self, n_slots: int = 2, max_slots: Optional[int] = None,
                 shared: bool = False):
        self.n_slots = max(2, n_slots)
        self.max_slots = max(self.n_slots, max_slots or self.n_slots * 4)
        self.shared = shared
        self._entries: dict = {}
        self.stats = {"entries": 0, "hits": 0, "misses": 0, "grown": 0,
                      "forced_drains": 0, "bytes": 0}

    @staticmethod
    def spec_sig(spec: dict) -> tuple:
        return tuple(sorted((k, tuple(shape), np.dtype(dt).str)
                            for k, (shape, dt) in spec.items()))

    def key_for(self, owner_tag, bucket, spec: dict):
        if self.shared:
            return self.spec_sig(spec)
        return (owner_tag, bucket)

    @staticmethod
    def _alloc(spec: dict) -> dict:
        return {k: np.zeros(shape, dt) for k, (shape, dt) in spec.items()}

    def _count_bytes(self, spec: dict, n: int) -> None:
        self.stats["bytes"] += n * sum(
            int(np.prod(shape)) * np.dtype(dt).itemsize
            for shape, dt in spec.values())

    def acquire(self, key, spec: dict):
        """Returns ``(entry, turn, created)``; the caller packs
        ``entry["slots"][turn]`` and records the owning handle at submit."""
        entry = self._entries.get(key)
        created = entry is None
        if created:
            entry = {"slots": [self._alloc(spec)
                               for _ in range(self.n_slots)],
                     "owners": [None] * self.n_slots, "turn": 0, "uses": 0}
            self._entries[key] = entry
            self.stats["misses"] += 1
            self.stats["entries"] = len(self._entries)
            self._count_bytes(spec, self.n_slots)
        else:
            self.stats["hits"] += 1
        entry["uses"] += 1
        n = len(entry["slots"])
        turn = None
        for k in range(1, n + 1):
            t = (entry["turn"] + k) % n
            owner = entry["owners"][t]
            if owner is None or owner.done:
                turn = t
                break
        if turn is None:
            if n < self.max_slots:    # every slot in flight: grow the ring
                entry["slots"].append(self._alloc(spec))
                entry["owners"].append(None)
                turn = n
                self.stats["grown"] += 1
                self._count_bytes(spec, 1)
            else:                     # full ring: drain the oldest owner
                turn = (entry["turn"] + 1) % n
                entry["owners"][turn].result()
                self.stats["forced_drains"] += 1
        entry["turn"] = turn
        entry["owners"][turn] = None
        return entry, turn, created

    def release_all(self) -> None:
        """Forget every slot's owning handle (fault recovery: the owners
        were marked done and abandoned, so their transfers will never be
        consumed — the slots must become reusable, not leak busy)."""
        for entry in self._entries.values():
            entry["owners"] = [None] * len(entry["slots"])
        self.stats["releases"] = self.stats.get("releases", 0) + 1


@dataclasses.dataclass
class _UnitState:
    """Device-resident state of one compiled unit (the marshaling cache).

    ``plan`` is the unit's compiled :class:`~repro.core.access_plan.AccessPlan`
    — ALL host marshaling of this unit (stream merge, capacity buckets,
    shard routing, hot/cold addressing) is interpretation of it."""

    unit: object                  # CompiledUnit
    plan: Optional[ap.AccessPlan] = None
    table: Optional[jax.Array] = None
    roff: Optional[jax.Array] = None       # fused units only (device)
    # weakrefs to the bound source table arrays: identity comparison that
    # cannot be fooled by CPython id reuse (a collected source reads as
    # "changed" and triggers a rebind) and does not pin caller memory
    src_refs: tuple = ()
    owns_table: bool = False      # stacked buffer built by us (donatable)

    def sources_unchanged(self, srcs: list) -> bool:
        return (len(self.src_refs) == len(srcs) and
                all(r() is a for r, a in zip(self.src_refs, srcs)))

    @property
    def group(self) -> Optional[FusedGroup]:
        return self.unit.group

    @property
    def res(self):
        return self.unit.result


@functools.partial(jax.jit, donate_argnums=(0,))
def _restack(old: jax.Array, parts: tuple) -> jax.Array:
    """Device-side table restack: writes the member tables into the donated
    previous stacked buffer — an in-place update (steady-state training
    refresh), never a host round trip."""
    off = 0
    for p in parts:
        old = jax.lax.dynamic_update_slice(old, p.astype(old.dtype), (off, 0))
        off += p.shape[0]
    return old


class ProgramExecutor:
    """Steady-state executor over one :class:`ProgramCompileResult`.

    Per-step input contract matches :func:`run_program_interpreted`:
    ``inputs`` maps op name -> that op's concrete inputs.  Tables bind on
    the first step and are reused while the caller keeps passing the *same
    array objects* (the steady-state fast path: params are long-lived);
    handing different table objects — fresh arrays, another model's params
    sharing this signature, per-step ``fusedmm`` features — is detected by
    identity and triggers a rebind, never a silently stale lookup.
    :meth:`update_tables` refreshes in place when the same objects mutate
    on device.  Per-step index data flows through bucketed, double-buffered
    scratch.

    ``backend`` selects the execute unit: ``"pallas"`` (the DAE kernels —
    the TPU target, interpreter-validated on CPU) or ``"jax"`` (the stock
    XLA gather/segment-sum path of :mod:`repro.core.backend_jax` — the
    production path on hosts without the kernels).  The marshaling cache
    and overlap machinery are identical; only per-step operand placement
    differs (the jax backend's reference kernels take host CSR streams).
    """

    def __init__(self, compiled: ProgramCompileResult,
                 interpret: Optional[bool] = None, depth: int = 2,
                 backend: str = "pallas", mesh=None,
                 shard_axis: str = "model", hot_rows=None,
                 exchange: Optional[str] = None,
                 replicate_outputs: Optional[bool] = None,
                 pool: Optional[BufferPool] = None,
                 index_policy: str = "strict",
                 faults=None, service: str = "inproc",
                 service_pool=None, degrade_policy: str = "fail",
                 adaptive=None):
        assert depth >= 1, depth
        assert backend in ("pallas", "jax"), backend
        assert index_policy in ap.INDEX_POLICIES, index_policy
        assert service in ("inproc", "disagg"), service
        assert degrade_policy in ("fail", "stale"), degrade_policy
        if service == "disagg":
            assert service_pool is not None, \
                "service='disagg' requires a service_pool"
        self.compiled = compiled
        self.interpret = (kops.default_interpret() if interpret is None
                          else interpret)
        self.depth = depth
        self.backend = backend
        self.shards = sp.shard_count(mesh, shard_axis)
        # a 1-wide mesh IS the single-device executor (bit-identical path)
        self.mesh = mesh if self.shards > 1 else None
        self.shard_axis = shard_axis
        # exchange mode of the sharded offset streams: "collective" (the
        # default on >=2 shards) ships ONE resident send buffer per step and
        # runs the index exchange as jax.lax.all_to_all inside the shard_map
        # body; "host" is the PR-3/4 single-controller routed scatter.
        assert exchange in (None, "host", "collective"), exchange
        self.exchange = ("host" if self.shards == 1
                         else (exchange or "collective"))
        # pooled outputs: reduce-scattered over the mesh (each shard owns
        # its contiguous segment slice — the default with the collective
        # exchange) or fully replicated via psum/pmax (the escape hatch,
        # and the host-exchange default for PR-4 compatibility).
        if replicate_outputs is None:
            replicate_outputs = self.exchange == "host"
        self.replicate_outputs = bool(replicate_outputs) \
            if self.shards > 1 else True
        # disaggregated embedding tier: steps route to a replica pool
        # (runtime.embedding_service.ServicePool-shaped, duck-typed so
        # core never imports runtime) instead of executing here; the
        # degrade policy decides what a step does while every replica is
        # dark (ServiceUnavailable): hot-slab steps always serve locally,
        # cold steps serve from the local tables under "stale" or fail
        # typed under "fail"
        self.service = service
        self.service_pool = service_pool
        self.degrade_policy = degrade_policy
        assert not (service == "disagg" and sp.shard_count(
            mesh, shard_axis) > 1), \
            "disaggregated service is a single-shard client path"
        # the replicated Zipf head: the slab a dark-shard step can serve
        # locally (independent of the sharded hot/cold machinery below)
        self._svc_hot = (
            {n: np.unique(np.asarray(list(ids), dtype=np.int64))
             for n, ids in dict(hot_rows).items()}
            if (service == "disagg" and hot_rows) else {})
        self._svc_srcs: Optional[tuple] = None  # tables last shipped
        # hot/cold vocab classification ({op name: replicated row ids});
        # only meaningful on sharded executors — see core/access_plan.py
        self.hot_rows = dict(hot_rows) if (hot_rows and self.shards > 1) \
            else {}
        self._hot_spec = ap.canonical_hot(self.hot_rows)
        # adaptive hot-slab re-classification (data.locality.AdaptiveHotConfig
        # or None): a sliding window of per-row access counts drives live
        # slab swaps (swap_hot_slab) and hot-aware spill routing.  The
        # windowed hot/cold counters below are ALWAYS maintained — they are
        # the drift observable window_stats() exposes to operators even on
        # static executors.
        from ..data.locality import AdaptiveHotConfig, WindowedCounts
        if adaptive is not None and not isinstance(adaptive,
                                                   AdaptiveHotConfig):
            raise TypeError("adaptive must be an AdaptiveHotConfig or None")
        self.adaptive = adaptive
        _w = adaptive or AdaptiveHotConfig()
        self._win_stride = max(1, _w.window_steps // _w.num_windows)
        self._win_ring = np.zeros((_w.num_windows, 2), np.int64)  # hot, cold
        self._win_slot = 0
        self._win_steps = 0
        self._win_full = False
        self.slab_epoch = 0
        self._adapt_counts = {}           # op name -> WindowedCounts
        self._adapt_ref: Optional[float] = None  # post-swap reference rate
        self._adapt_last_swap = 0
        self._adapt_refine = 0            # settling passes still owed
        if adaptive is not None:
            for name, op in compiled.program.ops:
                if (self.shards > 1 and name in self.hot_rows) or \
                        (service == "disagg" and name in self._svc_hot):
                    self._adapt_counts[name] = WindowedCounts(
                        op.num_embeddings, adaptive.window_steps,
                        adaptive.num_windows)
        self._shard_fns: dict = {}        # (unit_idx, bucket) -> jitted call
        self._units = [_UnitState(u) for u in compiled.units]
        for u in self._units:
            u.plan = self._plan_for(u)
        # host staging: private ring pool by default, or a shared pool
        # handed in by pipeline_group (same entries serve every member)
        self.pool = pool or BufferPool(n_slots=max(2, depth + 1))
        self._pool_tag = object()         # private-pool key namespace
        self._slots_packed: list = []     # slots the current dispatch used
        self._txn: Optional[TransferBatch] = None   # wave-coalesced puts
        self._inflight: deque = deque()
        self._steps = 0
        # input hardening of the per-step offset streams (every marshaling
        # path interprets the hardened dict): "strict" raises a typed
        # MalformedAccessError, "clamp"/"drop" degrade per-lookup and count
        self.index_policy = index_policy
        # chaos injector (runtime.faults.FaultInjector-shaped, duck-typed
        # so core never imports runtime); None in production
        self.faults = faults
        self.stats = {"steps": 0, "table_stacks": 0, "table_restacks": 0,
                      "table_rebinds": 0, "marshal_hits": 0,
                      "marshal_misses": 0, "max_inflight": 0,
                      "exchange_index_bytes": 0, "exchange_row_bytes": 0,
                      "hot_lookups": 0, "cold_lookups": 0,
                      "host_syncs": 0, "oob_lookups": 0,
                      "dropped_lookups": 0, "resets": 0,
                      "rpc_steps": 0, "hot_local_steps": 0,
                      "stale_steps": 0, "degraded_failed_steps": 0,
                      "hot_swaps": 0, "hot_swaps_rejected": 0,
                      "spilled_lookups": 0}
        # serving artifact (core/artifact.py): attach_artifact() arms the
        # AOT executable cache; executors built without an artifact_dir
        # keep aot=None — the plain jit C++ fastpath, zero new overhead
        self.aot = None
        self.compile_source = "fresh"     # fresh | artifact
        self._artifact_dir: Optional[Path] = None
        self._artifact_meta: Optional[dict] = None

    def _fire(self, site: str) -> None:
        if self.faults is not None:
            self.faults.fire(site, program=self.compiled.program.name)

    # ------------------------------------------------------------------
    # Serving artifact (core/artifact.py)
    # ------------------------------------------------------------------

    def attach_artifact(self, artifact_dir, meta: dict,
                        payloads: Optional[dict] = None,
                        source: str = "fresh") -> None:
        """Arm the AOT executable cache against a serving artifact: eager
        kernel dispatches now run AOT-compiled executables, hydrated from
        ``payloads`` (deserialized lazily per call key) or lowered once."""
        from . import artifact as art
        self._artifact_dir = Path(artifact_dir)
        self._artifact_meta = dict(meta)
        self.aot = art.AotCache(payloads)
        self.compile_source = source

    def save_artifact(self) -> Optional[Path]:
        """Persist the compile result + every AOT executable captured so
        far (atomic re-publish; idempotent).  Call again after the first
        step so the artifact carries the executables of the shapes this
        deployment actually serves — that is what lets the next boot reach
        its first token without a single trace."""
        if self._artifact_dir is None or self._artifact_meta is None:
            return None
        from . import artifact as art
        if self.aot is None:
            self.aot = art.AotCache()
        return art.save_artifact(self._artifact_dir, self.compiled,
                                 meta=self._artifact_meta,
                                 aot_payloads=self.aot.payloads())

    def _plan_for(self, u: _UnitState) -> ap.AccessPlan:
        """The unit's AccessPlan: the compiled artifact when it matches this
        executor's shard count + hot classification, else respecialized
        (a caller that compiled without shard info — direct
        ``ProgramExecutor(compile_program(...), mesh=...)`` construction —
        still interprets exactly one plan)."""
        plan = u.unit.result.access_plan
        shards = self.shards if u.group is not None else 1
        hot = self.hot_rows if u.group is not None else None
        hot_spec = self._hot_spec if u.group is not None else ()
        if plan is None or plan.shards != shards or \
                plan.hot_spec != hot_spec:
            plan = ap.build_plan(u.res.op, u.group, shards=shards,
                                 hot_rows=hot, epoch=self.slab_epoch)
        elif self.adaptive is not None:
            # adaptive executors mutate plan.spill / plan.rr_start as
            # per-step feedback — never on the shared compiled artifact
            plan = dataclasses.replace(plan, spill={}, rr_start=0,
                                       epoch=self.slab_epoch)
        return plan

    @property
    def signature(self) -> tuple:
        return (self.compiled.program.signature(), self.compiled.opt_level,
                self.compiled.vlen)

    # ------------------------------------------------------------------
    # Marshaling cache: device-resident tables + roff
    # ------------------------------------------------------------------

    def _table_key(self, u: _UnitState) -> str:
        return "x" if u.res.op.kind == "fusedmm" else "table"

    def _src_tables(self, u: _UnitState, inputs: dict) -> list:
        """The unit's source table arrays, one per stacked slot (the plan's
        slot order — shared slots read once)."""
        if u.group is None:
            return [inputs[u.unit.names[0]][self._table_key(u)]]
        return [inputs[name]["table"]
                for name in u.plan.slot_first_member]

    def _bind_unit(self, u: _UnitState, inputs: dict) -> None:
        srcs = self._src_tables(u, inputs)
        u.src_refs = tuple(weakref.ref(a) for a in srcs)
        if u.group is not None and self.shards > 1:
            # vocab-sharded stacked table: every device materializes only
            # its own 1/S slice of each cold slice + the replicated hot
            # slabs (the AccessPlan layout).  Routed indices arrive fully
            # rebased, so the kernel's seg_base stream is all-zero.
            if u.roff is None:
                u.roff = sp.put_replicated(
                    np.zeros(u.plan.num_segments, np.int32), self.mesh)
            u.table = sp.shard_stack_tables(
                [jnp.asarray(a) for a in srcs], u.plan, self.mesh,
                self.shard_axis)
            u.owns_table = True
            return
        if u.group is None:
            u.table = jnp.asarray(srcs[0])
            u.owns_table = False
        else:
            parts = tuple(jnp.asarray(a) for a in srcs)
            # a single-slot stack may alias the caller's array — only a
            # buffer WE built (concat) may later be donated by _restack
            u.owns_table = len(parts) > 1
            u.table = (parts[0] if len(parts) == 1
                       else jnp.concatenate(parts, axis=0))
            if u.roff is None:
                u.roff = jnp.asarray(u.plan.roff)

    def bind_tables(self, inputs: dict) -> None:
        """Build the device-resident stacked tables (once per signature)."""
        for u in self._units:
            self._bind_unit(u, inputs)
            self.stats["table_stacks"] += 1

    def update_tables(self, inputs: dict) -> None:
        """Refresh the stacked tables after the member tables changed (e.g.
        a train step updated the embeddings).  Device-side concat with the
        old stacked buffer donated where we own it — an in-place update,
        never a host round trip.

        ``inputs`` may be *partial*: units with any member absent are left
        untouched (the trainer feeds only the param-backed tables each
        optimizer step; per-step operand tables such as the MoE capacity
        buffer stay bound to their last step).  Units already bound to these
        exact arrays are also skipped, so a steady-state caller can feed
        every step for free.  An owned multi-slot stack is refreshed by the
        donated device restack (``table_restacks``); an aliased single
        table just rebinds the reference (``table_rebinds``) — the
        train-serve handoff path, which never re-stacks."""
        todo = []
        for u in self._units:
            if not all(n in inputs for n in u.unit.names):
                continue
            if u.table is not None and \
                    u.sources_unchanged(self._src_tables(u, inputs)):
                continue
            todo.append(u)
        if not todo:
            return
        self.drain()   # a donated buffer must not be read by in-flight steps
        for u in todo:
            if u.table is None:
                self._bind_unit(u, inputs)
                self.stats["table_stacks"] += 1
                continue
            srcs = self._src_tables(u, inputs)
            u.src_refs = tuple(weakref.ref(a) for a in srcs)
            if u.group is not None and self.shards > 1:
                u.table = sp.shard_stack_tables(
                    [jnp.asarray(a) for a in srcs], u.plan, self.mesh,
                    self.shard_axis)
                self.stats["table_restacks"] += 1
            elif u.group is not None and u.owns_table:
                u.table = _restack(u.table,
                                   tuple(jnp.asarray(a) for a in srcs))
                self.stats["table_restacks"] += 1
            else:   # bound buffer aliases caller data: never donate it
                u.table = jnp.asarray(srcs[0])
                self.stats["table_rebinds"] += 1

    # ------------------------------------------------------------------
    # Per-step access-stream marshaling (bucketed, double-buffered)
    # ------------------------------------------------------------------

    def _scratch_for(self, unit_idx: int, bucket: tuple, spec: dict):
        """Rotating host scratch per (unit, shape bucket), drawn from the
        executor's :class:`BufferPool` (``depth + 1`` slots min 2 keep the
        steady-state private pipeline from ever stalling on a busy slot; a
        shared pool grows its ring instead — see :class:`BufferPool`).
        Slot-owner accounting (recorded by :meth:`submit`) guarantees
        packing step N+k never races an in-flight transfer, regardless of
        how ``submit`` and ``step`` calls interleave across the programs
        sharing the pool."""
        self._fire("marshal")
        key = self.pool.key_for((self._pool_tag, unit_idx), bucket, spec)
        entry, turn, created = self.pool.acquire(key, spec)
        self.stats["marshal_misses" if created else "marshal_hits"] += 1
        self._slots_packed.append((entry, turn))
        return entry["slots"][turn]

    def _marshal_csr(self, idx: int, u: _UnitState, inputs: dict):
        """Fused CSR unit: interpret the AccessPlan — per-member CSR shapes,
        capacity buckets and the offset-merged pack all come from the plan;
        this method only manages the rotating scratch and device transfer.
        The pallas backend gets device-put capacity buffers; the jax backend
        gets exact-length host views (its reference kernels derive segment
        ids from ``ptrs`` on the host anyway)."""
        plan = u.plan
        op = plan.op
        parts, nnz, max_seg = plan.csr_parts(inputs)
        cap = plan.lattice.lookup_capacity(nnz)
        ml = plan.lattice.grid_capacity(max_seg)
        need_vals = plan.need_vals
        spec = {"ptrs": ((op.num_segments + 1,), np.int32),
                "idxs": ((cap,), np.int32)}
        if need_vals:
            spec["vals"] = ((cap,), np.dtype(op.dtype))
        buf = self._scratch_for(idx, (cap, ml), spec)
        plan.pack_csr(buf, parts, inputs)
        if self.backend == "jax":
            ins = {"table": u.table, "roff": plan.roff,
                   "ptrs": buf["ptrs"], "idxs": buf["idxs"][:nnz]}
            if need_vals:
                ins["vals"] = buf["vals"][:nnz]
            return ins, ml
        buf["idxs"][nnz:cap] = 0          # pad rows must stay in bounds
        dev = {"table": u.table, "roff": u.roff,
               "ptrs": self._put(buf["ptrs"]),
               "idxs": self._put(buf["idxs"])}
        if need_vals:
            dev["vals"] = self._put(buf["vals"])
        return dev, ml

    def _put(self, arr) -> jax.Array:
        """Host→device transfer of one per-step operand, counted in
        ``host_syncs`` (the executor's per-step transfer-issue stat)."""
        self._fire("transfer")
        self.stats["host_syncs"] += 1
        return jax.device_put(arr)

    def _marshal_gather(self, idx: int, u: _UnitState, inputs: dict):
        plan = u.plan
        n = plan.num_segments
        buf = self._scratch_for(idx, (), {"idxs": ((n,), np.int32)})
        plan.pack_gather(buf, inputs)
        if self.backend == "jax":
            return {"table": u.table, "roff": plan.roff,
                    "idxs": buf["idxs"]}, None
        return {"table": u.table, "roff": u.roff,
                "idxs": self._put(buf["idxs"])}, None

    # ------------------------------------------------------------------
    # Sharded fused units: host-routed offset-stream exchange + shard_map
    # ------------------------------------------------------------------

    def _put_sharded(self, arr) -> jax.Array:
        """Leading-dim-sharded placement of one per-step operand buffer,
        counted as a host sync (a host→device transfer the device pipeline
        must wait on — the collective exchange's whole point is issuing
        fewer of these per step)."""
        self._fire("transfer")
        self.stats["host_syncs"] += 1
        return sp.put_sharded(arr, self.mesh, self.shard_axis)

    def _shard_fn(self, idx: int, u: _UnitState, bucket: tuple):
        """Memoized jit(shard_map) callable per (unit, capacity bucket) —
        the sharded analogue of the per-bucket kernel trace.  The exchange
        mode and output placement are executor-level constants, so they
        need no key component."""
        key = (idx, bucket)
        fn = self._shard_fns.get(key)
        if fn is not None:
            return fn
        op = u.group.op
        plan = u.plan
        collective = self.exchange == "collective"
        repl = self.replicate_outputs
        axis = self.shard_axis
        kw = dict(axis=axis, backend=self.backend, replicate=repl,
                  shards=self.shards, seg_cap=plan.seg_cap)
        if op.kind == "gather":
            make = (sp.make_gather_collective_body if collective
                    else sp.make_gather_body)
            body = make(op, interpret=self.interpret, **kw)
            fn = sp.sharded_call(
                body, self.mesh, axis,
                sp.gather_in_specs(axis, collective=collective),
                sp.pooled_out_specs(axis, 3, replicate=repl))
        else:
            kind, cap, ml, need_vals = bucket
            kplan = bp.make_plan(u.res)
            col_tile = kplan.col_tile if kplan.whole_row_dma else 128
            make = (sp.make_csr_collective_body if collective
                    else sp.make_csr_body)
            body = make(op, max_lookups=ml, need_vals=need_vals,
                        interpret=self.interpret, col_tile=col_tile, **kw)
            fn = sp.sharded_call(
                body, self.mesh, axis,
                sp.csr_in_specs(axis, collective=collective,
                                need_vals=need_vals),
                sp.pooled_out_specs(axis, 2, replicate=repl))
        self._shard_fns[key] = fn
        return fn

    def _count_row_bytes(self, op, blk: int, plan) -> None:
        """Pooled-rows-back volume of one sharded step: the replicated
        psum/pmax ships every shard's partials everywhere ((S-1)·B·E·4);
        the reduce-scatter leaves each shard only its own segment slice —
        1/S of that, plus the padding rows of the scatter grid."""
        s = self.shards
        width = blk * op.emb_len * 4
        if self.replicate_outputs:
            self.stats["exchange_row_bytes"] += \
                op.num_segments * width * (s - 1)
        else:
            self.stats["exchange_row_bytes"] += \
                plan.padded_segments * width * (s - 1) // s

    def _run_csr_sharded(self, idx: int, u: _UnitState, inputs: dict):
        """Fused CSR unit over S vocab shards, host exchange: the
        AccessPlan merges the member streams and routes every lookup to its
        owning shard (indices out — hot rows resolve to the replicated slab
        and pay no exchange), then the batched kernel runs per shard under
        shard_map and the partial pools combine (pooled rows back)."""
        if self.exchange == "collective":
            return self._run_csr_collective(idx, u, inputs)
        plan = u.plan
        op = plan.op
        need_vals = plan.need_vals
        routed = plan.route_csr(inputs)
        s, cap, ml = self.shards, routed["cap"], routed["max_lookups"]
        spec = {"ptrs": ((s, op.num_segments + 1), np.int32),
                "idxs": ((s, cap), np.int32)}
        if need_vals:
            spec["vals"] = ((s, cap), np.dtype(op.dtype))
        buf = self._scratch_for(idx, (cap, ml), spec)
        buf["ptrs"][:] = routed["ptrs"]
        bounds = routed["bounds"]
        for o in range(s):
            n = bounds[o + 1] - bounds[o]
            buf["idxs"][o, :n] = routed["idxs"][bounds[o]:bounds[o + 1]]
            buf["idxs"][o, n:] = 0        # pad rows must stay in bounds
            if need_vals:
                buf["vals"][o, :n] = routed["vals"][bounds[o]:bounds[o + 1]]
                buf["vals"][o, n:] = 0
        # only the cold tail is exchanged; hot lookups were absorbed by the
        # replicated slab (local lookup on a round-robin shard)
        self.stats["exchange_index_bytes"] += \
            routed["cold_nnz"] * (8 if need_vals else 4)
        self._note_hot_cold(routed["hot_nnz"], routed["cold_nnz"])
        # next step's round-robin hot assignment starts at the shard whose
        # routed bucket was lightest this step
        if self.adaptive is not None:
            plan.rr_start = int(np.argmin(routed["nnz"]))
        self._count_row_bytes(op, 1, plan)
        args = [u.table, u.roff, self._put_sharded(buf["ptrs"]),
                self._put_sharded(buf["idxs"])]
        if need_vals:
            args.append(self._put_sharded(buf["vals"]))
        fn = self._shard_fn(idx, u, ("csr", cap, ml, need_vals))
        return fn(*args)

    def _run_csr_collective(self, idx: int, u: _UnitState, inputs: dict):
        """Fused CSR unit over S vocab shards, collective exchange: the
        AccessPlan packs the step into the (src, dst) send lattice — ONE
        resident send buffer (plus its vals twin when weighted) is
        device_put per step — and the index exchange itself runs as
        ``jax.lax.all_to_all`` inside the shard_map body (hot lookups sit
        on the diagonal: zero wire traffic)."""
        plan = u.plan
        op = plan.op
        need_vals = plan.need_vals
        routed = plan.route_csr_collective(inputs)
        s, cap, ml = self.shards, routed["cap"], routed["max_lookups"]
        spec = {"ints": ((s, s, 2, cap), np.int32)}
        if need_vals:
            spec["vals"] = ((s, s, cap), np.dtype(op.dtype))
        buf = self._scratch_for(idx, ("coll", cap, ml), spec)
        plan.fill_lattice(routed, buf["ints"],
                          buf["vals"] if need_vals else None)
        # wire volume: only off-diagonal (src != owner) lookups actually
        # cross a link in the all_to_all; hot lookups are always diagonal.
        # Each wire lookup carries its segment id + local index (+ val):
        # 8 (12 weighted) bytes — matching the gather path's seg+idx count
        self.stats["exchange_index_bytes"] += \
            routed["wire_nnz"] * (12 if need_vals else 8)
        self._note_hot_cold(routed["hot_nnz"], routed["cold_nnz"])
        self.stats["spilled_lookups"] += routed.get("spilled_nnz", 0)
        # feedback for the NEXT step: when one source's diagonal bucket is
        # overloaded, spill a bounded fraction of its hot lookups to the
        # least-loaded peer (the slab is replicated — owner choice is free)
        if self.adaptive is not None:
            plan.spill = sp.compute_spill(routed["pair_counts"],
                                          self.adaptive.spill_fraction,
                                          self.adaptive.spill_overload)
        self._count_row_bytes(op, 1, plan)
        args = [u.table, u.roff, self._put_sharded(buf["ints"])]
        if need_vals:
            args.append(self._put_sharded(buf["vals"]))
        fn = self._shard_fn(idx, u, ("csr", cap, ml, need_vals))
        return fn(*args)

    def _run_gather_sharded(self, idx: int, u: _UnitState, inputs: dict):
        plan = u.plan
        n = plan.num_segments
        blk = plan.op.block_rows
        s = self.shards
        if self.exchange == "collective":
            routed = plan.route_gather_collective(inputs)
            cap = routed["cap"]
            spec = {"ints": ((s, s, 2, cap), np.int32)}
            buf = self._scratch_for(idx, ("gather-coll", cap), spec)
            plan.fill_lattice(routed, buf["ints"])
            self.stats["exchange_index_bytes"] += \
                routed["wire_segments"] * 8   # seg + idx word
            args = [u.table, u.roff, self._put_sharded(buf["ints"])]
            bucket = ("gather-coll", cap)
        else:
            routed = plan.route_gather(inputs)
            spec = {"idxs": ((s, n), np.int32),
                    "mask": ((s, n), np.float32)}
            buf = self._scratch_for(idx, ("gather",), spec)
            buf["idxs"][:] = routed["idxs"]
            buf["mask"][:] = routed["mask"]
            self.stats["exchange_index_bytes"] += \
                routed["cold_segments"] * 8   # idx + mask word
            args = [u.table, u.roff, self._put_sharded(buf["idxs"]),
                    self._put_sharded(buf["mask"])]
            bucket = ("gather",)
        self._note_hot_cold(routed["hot_segments"], routed["cold_segments"])
        self._count_row_bytes(plan.op, blk, plan)
        fn = self._shard_fn(idx, u, bucket)
        return fn(*args)

    def _marshal_single(self, idx: int, u: _UnitState, inputs: dict):
        """Singleton unit: device-transfer the per-step operands, bucketing
        the ragged CSR streams to the plan's capacity lattice."""
        op = u.res.op
        name = u.unit.names[0]
        ins = inputs[name]
        if op.kind == "gather":
            return {"table": u.table,
                    "idxs": self._put(np.asarray(ins["idxs"]))}, None
        if op.kind == "kg":
            return {"table": u.table,
                    "idxs": self._put(np.asarray(ins["idxs"])),
                    "vals": self._put(np.asarray(ins["vals"]))}, 1
        if op.index_format == "lengths" and "ptrs" not in ins:
            ptrs = np.zeros(op.num_segments + 1, np.int64)
            np.cumsum(ins["lens"], out=ptrs[1:])
        else:
            ptrs = np.asarray(ins["ptrs"], np.int64)
        nnz = int(ptrs[-1])
        cap = u.plan.lattice.lookup_capacity(nnz)
        ml = u.plan.lattice.grid_capacity(int(np.diff(ptrs).max(initial=0)))
        key = "x" if op.kind == "fusedmm" else "table"
        need_vals = u.plan.need_vals and "vals" in ins
        spec = {"ptrs": ((op.num_segments + 1,), np.int32),
                "idxs": ((cap,), np.int32)}
        if need_vals:
            spec["vals"] = ((cap,), np.dtype(op.dtype))
        buf = self._scratch_for(idx, (cap, ml), spec)
        buf["ptrs"][:] = ptrs
        buf["idxs"][:nnz] = ins["idxs"]
        buf["idxs"][nnz:cap] = 0
        dev = {key: u.table, "ptrs": self._put(buf["ptrs"]),
               "idxs": self._put(buf["idxs"])}
        if need_vals:
            buf["vals"][:nnz] = ins["vals"]
            dev["vals"] = self._put(buf["vals"])
        return dev, ml

    # ------------------------------------------------------------------
    # Step loop
    # ------------------------------------------------------------------

    def _execute(self, u: _UnitState, ins: dict, ml, aot=None):
        """``aot`` is only ever passed at *eager* call sites: run-closures
        traced into the wave executable (:meth:`_unit_run`) and shard_map
        bodies cannot invoke an AOT-compiled callable mid-trace, so they
        keep the plain jit path (trace-on-load fallback, see
        :mod:`repro.core.artifact`)."""
        if self.backend == "jax":
            return bj.execute(u.res.op, ins, aot=aot)
        return bp.execute(u.res, ins, interpret=self.interpret,
                          max_lookups=ml, aot=aot)

    def _txn_defer(self, outs: dict, dev: dict, run) -> None:
        """Stage a gather-kind unit's per-step host arrays on the wave's
        :class:`TransferBatch` and defer its dispatch to the batched flush
        (device-resident values ride through untouched).  ``run`` must be a
        *stable* (cached per unit) pure function of the device inputs — the
        pipeline group traces the wave's runs into one jitted executable
        and reuses it across waves keyed on those function identities."""
        txn = self._txn
        staged = {k: txn.put(v) if isinstance(v, np.ndarray) else v
                  for k, v in dev.items()}
        txn.defer(outs, run, staged)

    def _unit_run(self, u: _UnitState):
        """The unit's deferred-dispatch function (memoized on the unit so
        jitted wave executables can be cached on its identity)."""
        run = getattr(u, "txn_run", None)
        if run is not None:
            return run
        if u.group is None:
            name, op = u.unit.names[0], u.res.op

            def run(d):
                return {name: bj.execute(op, d)}
        else:
            members = tuple(zip(u.group.members, u.group.member_ops,
                                u.group.seg_offsets))

            def run(d, u=u, members=members):
                fused = self._execute(u, d, None)
                return {name: fused[off:off + mop.num_segments]
                        for name, mop, off in members}
        u.txn_run = run
        return run

    def _harden_unit(self, u: _UnitState, inputs: dict) -> dict:
        """Validate the unit's offset streams against its AccessPlan under
        this executor's ``index_policy`` before ANY marshaling path reads
        them.  Returns the (possibly repaired) inputs dict — the same
        object on clean streams, so the hardened steady state is
        bit-identical to an unhardened executor."""
        fallback = u.unit.names[0] if u.group is None else None
        hardened, oob, dropped = u.plan.harden_step(
            inputs, self.index_policy, fallback_name=fallback)
        self.stats["oob_lookups"] += oob
        self.stats["dropped_lookups"] += dropped
        return hardened

    def _dispatch(self, inputs: dict) -> dict:
        outs: dict = {}
        for idx, u in enumerate(self._units):
            uin = self._harden_unit(u, inputs)
            if u.table is None:
                self._bind_unit(u, uin)
                self.stats["table_stacks"] += 1
            elif not u.sources_unchanged(self._src_tables(u, uin)):
                # the caller handed different table objects (fresh arrays,
                # another model's params, per-step fusedmm features):
                # rebind rather than silently serve stale tables.  Identity
                # is the steady-state fast path — stable params never pay.
                self._bind_unit(u, uin)
                self.stats["table_rebinds"] += 1
            if u.group is None:
                if self.backend == "jax":
                    name = u.unit.names[0]
                    key = "x" if u.res.op.kind == "fusedmm" else "table"
                    ins = {**uin[name], key: u.table}
                    if self._txn is not None and \
                            u.res.op.kind in ("gather", "kg"):
                        # CSR-kind jax units derive segment ids on the host
                        # from these streams — only pure-device gathers ride
                        # the batched transfer
                        norm = {k: v if isinstance(v, jax.Array)
                                else np.asarray(v) for k, v in ins.items()}
                        self._txn_defer(outs, norm, self._unit_run(u))
                        continue
                    outs[name] = bj.execute(u.res.op, ins, aot=self.aot)
                    continue
                dev, ml = self._marshal_single(idx, u, uin)
                outs[u.unit.names[0]] = self._execute(u, dev, ml,
                                                      aot=self.aot)
                continue
            if self.shards > 1:
                # epoch-checked marshaling: the plan interpreted here must
                # be the one the device tables were stacked under — a
                # mismatch means a half-applied slab swap
                if u.plan.epoch != self.slab_epoch:
                    raise RuntimeError(
                        f"stale access plan (epoch {u.plan.epoch} != slab "
                        f"epoch {self.slab_epoch}) — swap_hot_slab left a "
                        f"unit behind")
                fused = (self._run_gather_sharded(idx, u, uin)
                         if u.group.op.kind == "gather"
                         else self._run_csr_sharded(idx, u, uin))
            elif u.group.op.kind == "gather":
                dev, ml = self._marshal_gather(idx, u, uin)
                if self._txn is not None and self.backend == "jax":
                    self._txn_defer(outs, dev, self._unit_run(u))
                    continue
                fused = self._execute(u, dev, ml, aot=self.aot)
            else:
                dev, ml = self._marshal_csr(idx, u, uin)
                fused = self._execute(u, dev, ml, aot=self.aot)
            for name, mop, off in zip(u.group.members, u.group.member_ops,
                                      u.group.seg_offsets):
                outs[name] = fused[off:off + mop.num_segments]
        return outs

    def submit(self, inputs: dict, txn: Optional[TransferBatch] = None
               ) -> StepHandle:
        """Dispatch one step asynchronously: marshal + launch now, block
        never.  At ``depth`` steps in flight the oldest is drained first
        (backpressure), so step N+1's access stream is prepared while step
        N's execute phase runs — the cross-step DAE overlap.

        With ``txn`` (:meth:`PipelineGroup.submit_wave`), gather-kind units
        stage their streams on the shared :class:`TransferBatch` and their
        dispatch is deferred to its flush; the handle's outputs materialize
        then.  Sharded executors route their own exchange and ignore it."""
        self._fire("dispatch")
        while len(self._inflight) >= self.depth:
            self._inflight.popleft().result()
        self._slots_packed = []
        if self._adapt_counts:
            self._adapt_observe(inputs)
            if self.service == "disagg":
                self._note_svc_traffic(inputs)
        if self.service == "disagg":
            outs, pending = self._submit_disagg(inputs)
        else:
            pending = None
            self._txn = txn if self.shards == 1 else None
            try:
                outs = self._dispatch(inputs)
            finally:
                self._txn = None
        h = StepHandle(outs, self._steps, faults=self.faults,
                       pending=pending)
        for entry, turn in self._slots_packed:
            entry["owners"][turn] = h     # slot busy until h resolves
        self._steps += 1
        self.stats["steps"] += 1
        self._inflight.append(h)
        self.stats["max_inflight"] = max(self.stats["max_inflight"],
                                         len(self._inflight))
        self._win_tick()
        if self.adaptive is not None:
            self._adapt_tick()
        return h

    def step(self, inputs: dict) -> dict:
        """Synchronous convenience: submit + block on this step's result."""
        h = self.submit(inputs)
        if h in self._inflight:     # an end-of-submit slab swap drains the
            self._inflight.remove(h)    # queue before we get here
        return h.result()

    # ------------------------------------------------------------------
    # Disaggregated service path (service="disagg")
    # ------------------------------------------------------------------

    def _svc_tables(self, inputs: dict) -> dict:
        return {name: inputs[name]["x" if op.kind == "fusedmm" else "table"]
                for name, op in self.compiled.program.ops}

    def _svc_sync(self, inputs: dict) -> None:
        """Ship tables to the service pool on first step / object change —
        the same identity discipline as :meth:`_bind_unit`: stable params
        never re-ship, fresh arrays trigger an update (with the in-flight
        remote steps drained first, so they land on the tables they were
        submitted against)."""
        tables = self._svc_tables(inputs)
        srcs = tuple(tables.values())
        if self._svc_srcs is not None and \
                len(self._svc_srcs) == len(srcs) and \
                all(a is b for a, b in zip(self._svc_srcs, srcs)):
            return
        host = {n: np.asarray(a) for n, a in tables.items()}
        if self._svc_srcs is None:
            self.service_pool.bind(
                self.compiled.program, host,
                opt_level=self.compiled.opt_level, vlen=self.compiled.vlen,
                backend=self.backend, index_policy=self.index_policy,
                interpret=self.interpret,
                hot_spec={n: tuple(int(i) for i in v)
                          for n, v in self._svc_hot.items()} or None)
            self.stats["table_stacks"] += 1
        else:
            self.drain()
            self.service_pool.update_tables(host)
            self.stats["table_rebinds"] += 1
        self._svc_srcs = srcs

    def _submit_disagg(self, inputs: dict):
        """Send the step's offset streams to the service; the reply is
        consumed at :meth:`StepHandle.result` via the handle's ``pending``
        resolver.  :class:`~repro.core.access_plan.ServiceUnavailable`
        (pool exhausted its bounded retry, every replica dark) resolves
        per the degrade policy; every other fault propagates typed."""
        self._svc_sync(inputs)
        streams: dict = {}
        for name, op in self.compiled.program.ops:
            tkey = "x" if op.kind == "fusedmm" else "table"
            for k, v in inputs[name].items():
                if k != tkey:
                    streams[f"{name}/{k}"] = np.asarray(v)
        self.stats["rpc_steps"] += 1
        try:
            fut = self.service_pool.submit_step(streams)
        except ap.ServiceUnavailable as e:
            return self._degrade_outputs(inputs, e), None

        def pending() -> dict:
            try:
                return fut.wait()
            except ap.ServiceUnavailable as e:
                return self._degrade_outputs(inputs, e)

        return {}, pending

    def _step_all_hot(self, inputs: dict) -> bool:
        """True when every lookup of this step stays inside the replicated
        Zipf head (the hot slab every client keeps locally)."""
        if not self._svc_hot:
            return False
        for name, op in self.compiled.program.ops:
            hot = self._svc_hot.get(name)
            if hot is None:
                return False
            idxs = np.asarray(inputs[name].get("idxs", ()))
            if idxs.size and not np.isin(idxs, hot).all():
                return False
        return True

    def _degrade_outputs(self, inputs: dict, cause) -> dict:
        """Resolve a step while the service tier is dark.  Hot-slab steps
        always serve locally (the head is replicated client-side and kept
        fresh); cold steps serve from the local table copy under
        ``degrade_policy="stale"`` or re-raise typed under ``"fail"`` —
        each path counted."""
        if self._step_all_hot(inputs):
            self.stats["hot_local_steps"] += 1
        elif self.degrade_policy == "stale":
            self.stats["stale_steps"] += 1
        else:
            self.stats["degraded_failed_steps"] += 1
            raise cause
        # local fallback execution: binds the local tables lazily on the
        # first dark step (tables stack once, then it's the normal path)
        outs = self._dispatch(inputs)
        self._slots_packed = []
        return outs

    def run_steps(self, steps) -> list:
        """Run a sequence of step inputs through the double-buffered loop;
        returns each step's materialized outputs, in order."""
        out: list = []
        for ins in steps:
            out.append(self.submit(ins))
        return [h.result() for h in out]

    def drain(self) -> None:
        while self._inflight:
            self._inflight.popleft().result()

    def reset(self) -> None:
        """Fault recovery: abandon every in-flight step and free its
        staging slots.  The abandoned handles are marked ``done`` (their
        outputs may be garbage — a faulted marshal can leave a partially
        packed buffer — and must not be consumed), the pool's owner
        accounting is cleared so slots don't leak busy, and the next
        :meth:`submit` starts from a clean pipeline.  Device-resident
        tables and jitted kernels survive — recovery costs no recompile."""
        for h in self._inflight:
            h.done = True
        self._inflight.clear()
        self._slots_packed = []
        self._txn = None
        self.pool.release_all()
        self.stats["resets"] += 1

    def use_pool(self, pool: BufferPool) -> None:
        """Re-home host staging onto ``pool`` (the pipeline-group join).
        Slots of the old pool still owned by in-flight handles stay alive
        through those handles; new marshals draw from the shared rings."""
        self.pool = pool

    # ------------------------------------------------------------------
    # Adaptive locality: windowed counters, drift detection, slab swap
    # ------------------------------------------------------------------

    def _note_hot_cold(self, hot: int, cold: int) -> None:
        """Count one routing's hot/cold split: cumulative (back-compat
        stats) AND into the sliding-window ring drift detection reads."""
        self.stats["hot_lookups"] += hot
        self.stats["cold_lookups"] += cold
        self._win_ring[self._win_slot, 0] += hot
        self._win_ring[self._win_slot, 1] += cold

    def _win_tick(self) -> None:
        """Advance the hot/cold window ring by one step (rotating out the
        oldest stripe each ``window_steps / num_windows`` steps)."""
        self._win_steps += 1
        if self._win_steps % self._win_stride == 0:
            self._win_slot = (self._win_slot + 1) % len(self._win_ring)
            if self._win_slot == 0:
                self._win_full = True
            self._win_ring[self._win_slot] = 0

    def window_stats(self) -> dict:
        """Hot/cold traffic over the last window — the drift observable.

        Unlike the lifetime-cumulative ``stats["hot_lookups"]`` /
        ``hot_traffic_fraction`` (kept for back-compat), these age out:
        a head rotation shows up within one window instead of being
        averaged into history.  The re-classifier and operators read the
        same snapshot."""
        hot = int(self._win_ring[:, 0].sum())
        cold = int(self._win_ring[:, 1].sum())
        total = hot + cold
        span = self._win_stride * len(self._win_ring)
        return {
            "window_steps": span,
            "steps_in_window": min(self._win_steps, span),
            "window_full": self._win_full,
            "hot_lookups": hot,
            "cold_lookups": cold,
            "hot_traffic_fraction": round(hot / total, 4) if total else 0.0,
            "adaptive": self.adaptive is not None,
            "slab_epoch": self.slab_epoch,
            "hot_swaps": self.stats["hot_swaps"],
            "hot_swaps_rejected": self.stats["hot_swaps_rejected"],
            "spilled_lookups": self.stats["spilled_lookups"],
            "reference_hot_fraction": self._adapt_ref,
        }

    def _note_svc_traffic(self, inputs: dict) -> None:
        """Disagg steps never route shard-side, so an adaptive client feeds
        the hot/cold window itself: each index stream is split against the
        replicated head it keeps locally (``_svc_hot``)."""
        for name, hot in self._svc_hot.items():
            ins = inputs.get(name)
            if ins is None or "idxs" not in ins:
                continue
            idxs = np.asarray(ins["idxs"]).ravel()
            if idxs.size:
                nh = int(np.isin(idxs, hot).sum())
                self._note_hot_cold(nh, idxs.size - nh)

    def _adapt_observe(self, inputs: dict) -> None:
        """Feed the step's index streams into the per-op windowed row
        counters (the re-classifier's ranking signal)."""
        for name, wc in self._adapt_counts.items():
            ins = inputs.get(name)
            if ins is not None and "idxs" in ins:
                wc.add(np.asarray(ins["idxs"]))

    def _adapt_tick(self) -> None:
        """Drift detection, once per step: compare the windowed hot
        hit-rate against the reference captured over the first full window
        after the last (re)classification; a collapse below
        ``drift_threshold × reference`` re-ranks and swaps the slab."""
        cfg = self.adaptive
        if cfg is None or not self._adapt_counts or not self._win_full:
            return
        span = self._win_stride * len(self._win_ring)
        if self._adapt_refine > 0:
            # settling pass: the window has refilled since the reactive
            # swap flushed it, so the ranking now sees purely post-swap
            # traffic — re-rank to evict rows the contaminated (partially
            # pre-drift) reactive ranking kept.  Drift detection stays
            # paused while the slab is settling.
            if self._win_steps % span == 0:
                self._adapt_refine -= 1
                self._reclassify()
            return
        hot = int(self._win_ring[:, 0].sum())
        cold = int(self._win_ring[:, 1].sum())
        if not hot + cold:
            return
        frac = hot / (hot + cold)
        if self._adapt_ref is None:
            self._adapt_ref = float(frac)
            return
        if frac >= cfg.drift_threshold * self._adapt_ref:
            # healthy window: let a better-than-reference rate raise the bar
            self._adapt_ref = max(self._adapt_ref, float(frac))
            return
        if self._steps - self._adapt_last_swap < cfg.min_swap_interval:
            return
        self._adapt_last_swap = self._steps
        if self._reclassify():
            # the reactive ranking saw pre-drift history: flush the window
            # and counters so the settling passes rank on clean data
            self._reset_windows()
            self._adapt_refine = cfg.refine_passes

    def _reset_windows(self) -> None:
        """Flush the hot/cold ring and every per-op count sketch — called
        after a reactive swap so settling passes rank on post-swap traffic
        only."""
        self._win_ring[:] = 0
        self._win_slot = 0
        self._win_steps = 0
        self._win_full = False
        for wc in self._adapt_counts.values():
            wc.reset()

    def _reclassify(self) -> bool:
        """Re-rank each tracked op's hot set from its windowed counts and
        swap the slab (size-preserving — see ``classify_hot_from_counts``).
        Returns True when a swap actually happened."""
        from ..data.locality import classify_hot_from_counts
        prev = self.hot_rows if self.shards > 1 else self._svc_hot
        new: dict = {}
        for name, wc in self._adapt_counts.items():
            prev_ids = np.asarray(sorted(int(i) for i in prev.get(name, ())),
                                  np.int64)
            if not len(prev_ids):
                continue
            ids = classify_hot_from_counts(wc.totals(), len(prev_ids),
                                           prev_hot=prev_ids)
            new[name] = tuple(int(i) for i in ids)
        return bool(new) and self.swap_hot_slab(new)

    def swap_hot_slab(self, hot_rows) -> bool:
        """Swap the replicated hot slab in place: same shapes, new
        membership.  The slab is *data* — per-slot hot counts (and so the
        local table shape, the capacity lattice, and every memoized
        ``_shard_fn``/scratch bucket) are unchanged, so the swap re-ranks
        the plan and re-stacks the device tables through the
        ``update_tables`` respecialization path without a single retrace.
        A candidate set that WOULD change a slot's geometry (shared-table
        slot unions can) is rejected and counted, never half-applied.
        Returns True when a swap happened."""
        new_hot = {n: tuple(int(i) for i in ids)
                   for n, ids in dict(hot_rows).items()}
        new_spec = ap.canonical_hot(new_hot)
        if self.service == "disagg":
            cur = ap.canonical_hot({n: tuple(int(i) for i in v)
                                    for n, v in self._svc_hot.items()})
            if new_spec == cur:
                return False
            self.drain()
            self._svc_hot = {n: np.unique(np.asarray(list(ids), np.int64))
                             for n, ids in new_hot.items()}
            self.slab_epoch += 1
            self.stats["hot_swaps"] += 1
            self._adapt_ref = None
            self._adapt_last_swap = self._steps
            # propagate through the artifact-republish path so a respawned
            # replica re-warms with the CURRENT slab (and live replicas
            # learn the new spec without a table re-ship)
            publish = getattr(self.service_pool, "publish_hot_spec", None)
            if publish is not None:
                publish(new_hot)
            return True
        if self.shards == 1 or new_spec == self._hot_spec:
            return False
        epoch = self.slab_epoch + 1
        rebuilt: list = []
        for u in self._units:
            if u.group is None:
                continue
            plan = ap.build_plan(u.res.op, u.group, shards=self.shards,
                                 hot_rows=new_hot, epoch=epoch)
            old = u.plan
            if plan.local_rows != old.local_rows or any(
                    a.hot_rows != b.hot_rows or a.cap != b.cap
                    for a, b in zip(plan.slots, old.slots)):
                self.stats["hot_swaps_rejected"] += 1
                return False
            plan.rr_start, plan.spill = old.rr_start, dict(old.spill)
            rebuilt.append((u, plan))
        self.drain()    # restacked buffers must not be read by old steps
        self.hot_rows, self._hot_spec = new_hot, new_spec
        for u, plan in rebuilt:
            u.plan = plan
            if u.table is None:
                continue
            srcs = [r() for r in (u.src_refs or ())]
            if not srcs or any(s is None for s in srcs):
                u.table = None          # sources gone: rebind next step
                continue
            u.table = sp.shard_stack_tables(
                [jnp.asarray(a) for a in srcs], plan, self.mesh,
                self.shard_axis)
            self.stats["table_restacks"] += 1
        self.slab_epoch = epoch
        self.stats["hot_swaps"] += 1
        self._adapt_ref = None
        self._adapt_last_swap = self._steps
        return True

    def access_plan_stats(self) -> dict:
        """The compiled access side, observable: per-plan hot/cold layout,
        cost-model exchange estimate vs. the measured counters, and the
        plan-build time the ``plan-access`` pass recorded."""
        fused = [u for u in self._units if u.group is not None]
        steps = self.stats["steps"]
        est = [cost_model.exchange_bytes(
                   u.group.member_ops, self.shards,
                   replicate_outputs=self.replicate_outputs,
                   collective=self.exchange == "collective")
               for u in fused]
        est_idx = sum(e["index_bytes"] for e in est) * steps
        hot = self.stats["hot_lookups"]
        cold = self.stats["cold_lookups"]
        total = hot + cold
        return {
            "shards": self.shards,
            "exchange": self.exchange,
            "replicate_outputs": self.replicate_outputs,
            "host_syncs": self.stats["host_syncs"],
            "host_syncs_per_step": round(
                self.stats["host_syncs"] / steps, 2) if steps else 0.0,
            "exchange_row_bytes": self.stats["exchange_row_bytes"],
            "exchange_row_bytes_est": sum(e["row_bytes"]
                                          for e in est) * steps,
            "units": len(self._units),
            "fused_units": len(fused),
            "hot_rows": sum(u.plan.hot_rows_total for u in fused),
            "hot_slab_bytes": sum(u.plan.hot_slab_bytes for u in fused),
            "hot_lookups": hot,
            "cold_lookups": cold,
            "hot_traffic_fraction": round(hot / total, 4) if total else 0.0,
            "exchange_index_bytes": self.stats["exchange_index_bytes"],
            # the interleaved (no hot slab) cost-model estimate — actual
            # below it means the hot slab absorbed that much routed volume
            "exchange_index_bytes_est": est_idx,
            "exchange_savings_bytes": max(
                0, est_idx - self.stats["exchange_index_bytes"]),
            "hot_swaps": self.stats["hot_swaps"],
            "spilled_lookups": self.stats["spilled_lookups"],
            "window": self.window_stats(),
            "plan_build_s": round(sum(
                r.duration_s for r in self.compiled.pass_records()
                if r.name == "plan-access" and r.ran), 6),
        }


# ---------------------------------------------------------------------------
# Pipeline group: two (or more) compiled programs overlapped through one
# shared staging pool — cross-PROGRAM access/execute overlap.
# ---------------------------------------------------------------------------

class PipelineGroup:
    """Cross-program pipelining over a shared :class:`BufferPool`.

    :meth:`ProgramExecutor.submit` already overlaps step N+1's access-side
    marshal with step N's execute *within* one program.  A serving wave is
    two programs back to back — the decode embed of wave W+1 and the MoE
    un-dispatch of wave W — and running them through separate executors
    serializes at each program's own backpressure.  The group re-homes every
    member onto one shared pool (entries keyed by buffer-spec signature, so
    same-shaped staging is one ring) and accounts in-flight steps per
    program, so program A's marshal proceeds while program B executes.

    ``depth`` is the group-level backpressure bound (default: the sum of
    the members' depths — members throttle themselves first; pass a smaller
    value to cap total in-flight work across programs)."""

    def __init__(self, executors, names=None, depth: Optional[int] = None,
                 n_slots: Optional[int] = None,
                 max_slots: Optional[int] = None):
        assert executors, "pipeline_group needs at least one executor"
        self.executors = list(executors)
        self.names = list(names) if names is not None else [
            ex.compiled.program.name for ex in self.executors]
        assert len(set(self.names)) == len(self.names), \
            f"ambiguous program names: {self.names}"
        self._by_name = dict(zip(self.names, self.executors))
        slots = n_slots or max(max(2, ex.depth + 1)
                               for ex in self.executors)
        self.pool = BufferPool(n_slots=slots, max_slots=max_slots,
                               shared=True)
        for ex in self.executors:
            ex.drain()                  # old-pool slots settle before rehome
            ex.use_pool(self.pool)
        self.depth = depth or sum(ex.depth for ex in self.executors)
        self._inflight: deque = deque()   # (name, StepHandle)
        self._wave_fns: dict = {}         # wave signature -> jitted fn
        # group-level chaos injector (sites: dispatch at submit_wave,
        # transfer at the wave flush, result on the wave's handles); set by
        # the server so cached member executors stay untouched
        self.faults = None
        self.stats = {
            "submitted": {n: 0 for n in self.names},
            "in_flight": {n: 0 for n in self.names},
            "max_in_flight": {n: 0 for n in self.names},
            "group_drains": 0,
            "waves": 0,
            "batched_arrays": 0,
            "resets": 0,
        }

    def _fire(self, site: str) -> None:
        if self.faults is not None:
            self.faults.fire(site, group=tuple(self.names))

    def executor(self, name: str) -> ProgramExecutor:
        return self._by_name[name]

    def _gc(self) -> None:
        """Drop handles resolved elsewhere (member backpressure, caller
        ``result()``) from the group ledger."""
        live: deque = deque()
        for n, h in self._inflight:
            if h.done:
                self.stats["in_flight"][n] -= 1
            else:
                live.append((n, h))
        self._inflight = live

    def submit(self, name: str, inputs: dict) -> StepHandle:
        """Dispatch one step of member ``name`` asynchronously, under both
        the member's own depth bound and the group bound."""
        self._gc()
        while len(self._inflight) >= self.depth:
            n0, h0 = self._inflight.popleft()
            h0.result()
            self.stats["in_flight"][n0] -= 1
            self.stats["group_drains"] += 1
        h = self._by_name[name].submit(inputs)
        self._inflight.append((name, h))
        st = self.stats
        st["submitted"][name] += 1
        st["in_flight"][name] += 1
        st["max_in_flight"][name] = max(st["max_in_flight"][name],
                                        st["in_flight"][name])
        return h

    def step(self, name: str, inputs: dict) -> dict:
        """Synchronous convenience: group submit + block on the result."""
        return self.submit(name, inputs).result()

    def submit_wave(self, wave: dict) -> dict:
        """Submit one serving wave — ``{program name: inputs}`` — across
        members as ONE co-scheduled dispatch: every member marshals its
        access streams onto a shared :class:`TransferBatch`, one batched
        ``jax.device_put`` ships them all, and the members' deferred unit
        dispatches are traced into a single jitted wave executable (cached
        on the wave's unit/shape signature, so steady-state waves never
        retrace).  Returns ``{name: StepHandle}``."""
        self._fire("dispatch")
        self._gc()
        while len(self._inflight) > max(0, self.depth - len(wave)):
            n0, h0 = self._inflight.popleft()
            h0.result()
            self.stats["in_flight"][n0] -= 1
            self.stats["group_drains"] += 1
        txn = TransferBatch()
        handles = {}
        for name, inputs in wave.items():
            handles[name] = self._by_name[name].submit(inputs, txn=txn)
        self._flush_wave(txn)
        if self.faults is not None:
            for h in handles.values():
                h.faults = self.faults
        st = self.stats
        st["waves"] += 1
        st["batched_arrays"] += txn.n_arrays
        for name, h in handles.items():
            self._inflight.append((name, h))
            st["submitted"][name] += 1
            st["in_flight"][name] += 1
            st["max_in_flight"][name] = max(st["max_in_flight"][name],
                                            st["in_flight"][name])
        return handles

    def _flush_wave(self, txn: TransferBatch) -> None:
        """Flush the wave's deferred dispatches through one jitted wave
        executable.  The trace closes over nothing: device-resident
        constants (stacked tables, fused row offsets) and the batched
        per-wave streams are both arguments, so a table rebind is just a
        different argument and the cache key only carries unit identities
        and array shapes."""
        self._fire("transfer")
        if not txn.fills:
            txn.flush()                   # nothing deferred: transfers only
            return
        host, txn._host = txn._host, []
        fills, txn.fills = txn.fills, []
        consts: list = []
        plan: list = []
        for _, _, staged in fills:
            spec = []
            for k, v in staged.items():
                if isinstance(v, _TxnRef):
                    spec.append((k, True, v.i))
                else:
                    spec.append((k, False, len(consts)))
                    consts.append(v)
            plan.append(tuple(spec))
        key = (tuple(run for _, run, _ in fills), tuple(plan),
               tuple((a.shape, a.dtype.str) for a in host),
               tuple((tuple(c.shape), str(c.dtype)) for c in consts))
        fn = self._wave_fns.get(key)
        if fn is None:
            runs = [run for _, run, _ in fills]
            splan = tuple(plan)

            def wave_fn(consts, devs):
                return [run({k: devs[i] if is_dev else consts[i]
                             for k, is_dev, i in spec})
                        for run, spec in zip(runs, splan)]
            fn = jax.jit(wave_fn)
            self._wave_fns[key] = fn
        devs = jax.device_put(host) if host else []
        for (outs, _, _), res in zip(fills, fn(consts, devs)):
            outs.update(res)

    def drain(self) -> None:
        for ex in self.executors:
            ex.drain()
        for n, h in self._inflight:
            h.result()
        self._gc()

    def reset(self) -> None:
        """Fault recovery across the whole group: abandon every member's
        in-flight steps (a faulted wave may have left partially staged
        transfers), clear the group ledger, and release the shared pool's
        slot owners.  The next :meth:`submit_wave` starts clean — jitted
        wave executables and bound tables survive."""
        for n, h in self._inflight:
            h.done = True
        self._inflight.clear()
        for n in self.names:
            self.stats["in_flight"][n] = 0
        for ex in self.executors:
            ex.reset()
        self.stats["resets"] += 1

    def group_stats(self) -> dict:
        """Per-program in-flight accounting + the shared pool's counters
        (what benchmarks/run.py surfaces)."""
        self._gc()
        return {
            "programs": list(self.names),
            "depth": self.depth,
            "submitted": dict(self.stats["submitted"]),
            "in_flight": dict(self.stats["in_flight"]),
            "max_in_flight": dict(self.stats["max_in_flight"]),
            "group_drains": self.stats["group_drains"],
            "waves": self.stats["waves"],
            "batched_arrays": self.stats["batched_arrays"],
            "resets": self.stats["resets"],
            "pool": dict(self.pool.stats),
        }


def pipeline_group(executors, names=None, depth: Optional[int] = None,
                   n_slots: Optional[int] = None,
                   max_slots: Optional[int] = None) -> PipelineGroup:
    """Join ``executors`` into a :class:`PipelineGroup` sharing one staging
    pool: ``group.submit("decode-embed", ...)`` marshals wave W+1's embed
    stream while ``"moe-undispatch"``'s wave-W execute is still in flight.
    ``names`` defaults to each executor's program name."""
    return PipelineGroup(executors, names=names, depth=depth,
                         n_slots=n_slots, max_slots=max_slots)


# ---------------------------------------------------------------------------
# Executor cache: one steady-state executor per program signature, kept
# alongside the compile artifact (bounded LRU like the compile cache).
# ---------------------------------------------------------------------------

_EXECUTOR_CACHE = BoundedLru(16)


def executor_for(program: EmbeddingProgram, opt_level: str = "O3",
                 vlen: int = 128, interpret: Optional[bool] = None,
                 budget: Optional[FusionBudget] = None,
                 depth: int = 2, backend: str = "pallas",
                 mesh=None, shard_axis: str = "model",
                 hot_rows=None, exchange: Optional[str] = None,
                 replicate_outputs: Optional[bool] = None,
                 index_policy: str = "strict", service: str = "inproc",
                 service_pool=None,
                 degrade_policy: str = "fail",
                 adaptive=None, artifact_dir=None) -> ProgramExecutor:
    """The steady-state entry point: compile (compile-cache backed) and
    return the memoized executor whose marshaling cache is already warm for
    this signature.

    The key is the program's *structural* signature: a hit can hand back an
    executor whose tables were bound by another caller, which is exactly
    what the per-step table identity check in :meth:`ProgramExecutor.step`
    resolves (same arrays → warm fast path; different model's arrays →
    automatic rebind).

    ``mesh``/``shard_axis`` select vocab-sharded execution: the fused
    stacked tables partition over ``mesh.shape[shard_axis]`` shards and the
    ``budget`` is rewritten to budget per-shard VMEM (``FusionBudget.shards``
    — part of the compile-cache key, so replicated and sharded plans never
    collide).  A 1-wide axis (or ``mesh=None``) is the single-device path.

    ``hot_rows`` (``{op name: replicated row ids}``, e.g. from
    :func:`repro.core.access_plan.hot_rows_from_traces`) selects
    locality-aware hot/cold sharding: the classified Zipf head of each
    vocab is replicated on every shard (local lookups, zero exchange) while
    the tail stays interleave-sharded.  Ignored on the single-device path;
    part of both cache keys.

    ``exchange`` selects how the routed offset streams move on a ≥2-shard
    mesh: ``"collective"`` (the default) marshals one resident send buffer
    per step and runs the index exchange as ``jax.lax.all_to_all`` inside
    the shard_map body; ``"host"`` is the PR-3/4 single-controller routed
    scatter.  ``replicate_outputs`` picks the pooled-output placement:
    reduce-scattered segment slices (collective default) or fully
    replicated via psum/pmax (host default, and the escape hatch).

    ``adaptive`` (a :class:`repro.data.locality.AdaptiveHotConfig`) turns
    the hot slab into a live cache: windowed per-row counters re-rank the
    head when the windowed hot hit-rate collapses and swap the slab in
    place (no recompile — see :meth:`ProgramExecutor.swap_hot_slab`), plus
    hot-aware spill routing off overloaded lattice diagonals.  Hashable,
    so it keys the executor cache like every other knob.

    ``artifact_dir`` points at a serving artifact (:mod:`repro.core
    .artifact`): on an executor-cache miss the compile payload + AOT
    executables hydrate from disk *before* any compilation (fingerprint/
    identity mismatches fall back to a fresh compile, counted), and a
    fresh compile is saved back so the next boot loads.  Deliberately NOT
    part of the executor-cache key — the artifact changes where a compile
    comes from, never what it computes."""
    # canonicalize defaults so explicit-default calls hit the same entry
    interpret = kops.default_interpret() if interpret is None else interpret
    shards = sp.shard_count(mesh, shard_axis)
    # disaggregated clients keep their hot-slab spec even on one shard
    # (it's the local-serving slab, not the sharded hot/cold plan) — and
    # the pool's identity keys the cache so two pools never share a
    # client executor
    service_hot = None
    if service == "disagg":
        assert service_pool is not None, \
            "service='disagg' requires a service_pool"
        assert shards == 1, \
            "disaggregated service is a single-shard client path"
        service_hot = hot_rows
    if shards == 1:
        mesh = None
        hot_rows = None
        exchange = "host"
        replicate_outputs = True
    else:
        exchange = exchange or "collective"
        if replicate_outputs is None:
            replicate_outputs = exchange == "host"
    budget = budget or FusionBudget()
    if budget.shards != shards:
        budget = dataclasses.replace(budget, shards=shards)
    hot_spec = ap.canonical_hot(hot_rows)
    key = (program.signature(), opt_level, vlen, interpret, budget, depth,
           backend, mesh, shard_axis if mesh is not None else None,
           hot_spec, exchange, bool(replicate_outputs), index_policy,
           service, degrade_policy if service == "disagg" else None,
           service_pool.pool_id if service_pool is not None else None,
           ap.canonical_hot(service_hot), adaptive)
    ex = _EXECUTOR_CACHE.get(key)
    if ex is not None:
        return ex
    compiled = None
    payloads = None
    source = "fresh"
    ameta = None
    if artifact_dir is not None:
        from . import artifact as art
        ameta = art.artifact_meta(program, opt_level=opt_level, vlen=vlen,
                                  budget=budget, hot_rows=hot_rows,
                                  backend=backend, interpret=interpret)
        loaded = art.load_artifact(artifact_dir, ameta)
        if loaded is not None:
            compiled, payloads = loaded
            source = "artifact"
            # hydrate the compile cache: later compile_program calls with
            # this identity (other executors, direct callers) hit too
            from .pipeline import seed_compile_cache
            seed_compile_cache(
                art.compile_key_of(program, ameta, budget=budget,
                                   hot_rows=hot_rows), compiled)
        else:
            art.note_fresh_compile()
    if compiled is None:
        compiled = compile_program(program, opt_level, vlen=vlen,
                                   budget=budget, hot_rows=hot_rows)
    ex = ProgramExecutor(compiled, interpret=interpret, depth=depth,
                         backend=backend, mesh=mesh, shard_axis=shard_axis,
                         hot_rows=hot_rows if shards > 1 else service_hot,
                         exchange=exchange,
                         replicate_outputs=replicate_outputs,
                         index_policy=index_policy, service=service,
                         service_pool=service_pool,
                         degrade_policy=degrade_policy, adaptive=adaptive)
    if artifact_dir is not None:
        ex.attach_artifact(artifact_dir, ameta, payloads, source)
        if source == "fresh":
            # save on first compile, so the NEXT boot loads; callers that
            # step the executor re-save (save_artifact is idempotent) to
            # capture the AOT executables of the shapes actually served
            ex.save_artifact()
    _EXECUTOR_CACHE.put(key, ex)
    return ex


def executor_cache_stats() -> dict:
    s = _EXECUTOR_CACHE.stats()
    s["entries_by_shards"] = entries_by_shards(_EXECUTOR_CACHE)
    return s


def set_executor_cache_limit(limit: int) -> int:
    return _EXECUTOR_CACHE.set_limit(limit)


def clear_executor_cache() -> None:
    _EXECUTOR_CACHE.clear()
