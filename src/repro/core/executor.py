"""ProgramExecutor — the steady-state runtime of a compiled embedding program.

The compile cache (PR 1) made per-step *pass* overhead free; this module
removes the per-step *data-movement* overhead and runs the program the way
the DAE machine is meant to run — the access stream ahead of execute:

    compile cache                 marshaling cache              step loop
    ─────────────                 ────────────────              ─────────
    (signature, O?, vlen)   ──▶   device-resident stacked   ──▶ double-
    ProgramCompileResult          tables + roff streams +       buffered
    (executor_for, LRU)           bucketed scratch buffers      submit/result

Three mechanisms, mirroring the DAE queue at program scope:

* **Marshaling cache** — everything per-*signature* is built once and kept
  device-resident: the fused units' row-stacked tables (device-side concat,
  donated in place on :meth:`ProgramExecutor.update_tables`), the per-segment
  ``roff`` table-offset streams, and per-batch-shape scratch buffers for the
  CSR operands.  A steady-state step does **zero host table stacking**.
* **Capacity buckets** — ``idxs``/``vals`` nnz and the ``max_lookups`` grid
  extent are padded to power-of-two buckets
  (:func:`repro.kernels.sls.lookup_capacity`), so a ragged batch sequence
  reuses one kernel trace per bucket instead of re-specializing every step.
* **Cross-step access/execute overlap** — :meth:`ProgramExecutor.submit`
  marshals step N+1's access-side operands (host index packing + device
  transfer, dispatched asynchronously) while step N's execute phase is still
  in flight; ``jax.block_until_ready`` happens only at the consume point
  (:meth:`StepHandle.result`), with a bounded in-flight depth for
  backpressure.  Host scratch is double-buffered per bucket so packing
  step N+1 never races step N's transfer.

``executor_for`` memoizes executors on the program signature (bounded LRU)
alongside the compile cache, which is what the runtimes
(:mod:`repro.runtime.server`, :mod:`repro.runtime.trainer`) hold on to.
"""
from __future__ import annotations

import dataclasses
import functools
import weakref
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ops as kops
from . import backend_jax as bj
from . import backend_pallas as bp
from .cost_model import FusionBudget
from .ops import EmbeddingProgram
from .passes.fuse import FusedGroup, group_roff
from .pipeline import BoundedLru, ProgramCompileResult, compile_program


@dataclasses.dataclass(eq=False)  # identity semantics: outputs hold arrays
class StepHandle:
    """One in-flight program step.  ``outputs`` are lazy device arrays;
    :meth:`result` is the consume point (the only place that blocks)."""

    outputs: dict                 # op name -> device array (async)
    index: int                    # step number within the executor
    done: bool = False

    def result(self) -> dict:
        jax.block_until_ready(self.outputs)
        self.done = True
        return self.outputs


@dataclasses.dataclass
class _UnitState:
    """Device-resident state of one compiled unit (the marshaling cache)."""

    unit: object                  # CompiledUnit
    table: Optional[jax.Array] = None
    roff: Optional[jax.Array] = None       # fused units only (device)
    roff_np: Optional[np.ndarray] = None   # fused units only (host mirror)
    kg_ptrs: dict = dataclasses.field(default_factory=dict)
    # weakrefs to the bound source table arrays: identity comparison that
    # cannot be fooled by CPython id reuse (a collected source reads as
    # "changed" and triggers a rebind) and does not pin caller memory
    src_refs: tuple = ()
    owns_table: bool = False      # stacked buffer built by us (donatable)

    def sources_unchanged(self, srcs: list) -> bool:
        return (len(self.src_refs) == len(srcs) and
                all(r() is a for r, a in zip(self.src_refs, srcs)))

    @property
    def group(self) -> Optional[FusedGroup]:
        return self.unit.group

    @property
    def res(self):
        return self.unit.result


@functools.partial(jax.jit, donate_argnums=(0,))
def _restack(old: jax.Array, parts: tuple) -> jax.Array:
    """Device-side table restack: writes the member tables into the donated
    previous stacked buffer — an in-place update (steady-state training
    refresh), never a host round trip."""
    off = 0
    for p in parts:
        old = jax.lax.dynamic_update_slice(old, p.astype(old.dtype), (off, 0))
        off += p.shape[0]
    return old


class ProgramExecutor:
    """Steady-state executor over one :class:`ProgramCompileResult`.

    Per-step input contract matches :func:`run_program_interpreted`:
    ``inputs`` maps op name -> that op's concrete inputs.  Tables bind on
    the first step and are reused while the caller keeps passing the *same
    array objects* (the steady-state fast path: params are long-lived);
    handing different table objects — fresh arrays, another model's params
    sharing this signature, per-step ``fusedmm`` features — is detected by
    identity and triggers a rebind, never a silently stale lookup.
    :meth:`update_tables` refreshes in place when the same objects mutate
    on device.  Per-step index data flows through bucketed, double-buffered
    scratch.

    ``backend`` selects the execute unit: ``"pallas"`` (the DAE kernels —
    the TPU target, interpreter-validated on CPU) or ``"jax"`` (the stock
    XLA gather/segment-sum path of :mod:`repro.core.backend_jax` — the
    production path on hosts without the kernels).  The marshaling cache
    and overlap machinery are identical; only per-step operand placement
    differs (the jax backend's reference kernels take host CSR streams).
    """

    def __init__(self, compiled: ProgramCompileResult,
                 interpret: Optional[bool] = None, depth: int = 2,
                 backend: str = "pallas"):
        assert depth >= 1, depth
        assert backend in ("pallas", "jax"), backend
        self.compiled = compiled
        self.interpret = (kops.default_interpret() if interpret is None
                          else interpret)
        self.depth = depth
        self.backend = backend
        self._units = [_UnitState(u) for u in compiled.units]
        self._scratch: dict = {}          # (unit_idx, bucket) -> slot entry
        self._slots_packed: list = []     # slots the current dispatch used
        self._inflight: deque = deque()
        self._steps = 0
        self.stats = {"steps": 0, "table_stacks": 0, "table_restacks": 0,
                      "table_rebinds": 0, "marshal_hits": 0,
                      "marshal_misses": 0, "max_inflight": 0}

    @property
    def signature(self) -> tuple:
        return (self.compiled.program.signature(), self.compiled.opt_level,
                self.compiled.vlen)

    # ------------------------------------------------------------------
    # Marshaling cache: device-resident tables + roff
    # ------------------------------------------------------------------

    def _table_key(self, u: _UnitState) -> str:
        return "x" if u.res.op.kind == "fusedmm" else "table"

    def _src_tables(self, u: _UnitState, inputs: dict) -> list:
        """The unit's source table arrays, one per stacked slot."""
        if u.group is None:
            return [inputs[u.unit.names[0]][self._table_key(u)]]
        g = u.group
        parts, placed = [], set()
        for name, base in zip(g.members, g.row_offsets):
            if base not in placed:        # shared slots are stacked once
                placed.add(base)
                parts.append(inputs[name]["table"])
        return parts

    def _bind_unit(self, u: _UnitState, inputs: dict) -> None:
        srcs = self._src_tables(u, inputs)
        u.src_refs = tuple(weakref.ref(a) for a in srcs)
        if u.group is None:
            u.table = jnp.asarray(srcs[0])
            u.owns_table = False
        else:
            parts = tuple(jnp.asarray(a) for a in srcs)
            # a single-slot stack may alias the caller's array — only a
            # buffer WE built (concat) may later be donated by _restack
            u.owns_table = len(parts) > 1
            u.table = (parts[0] if len(parts) == 1
                       else jnp.concatenate(parts, axis=0))
            if u.roff is None:
                u.roff_np = group_roff(u.group)
                u.roff = jnp.asarray(u.roff_np)

    def bind_tables(self, inputs: dict) -> None:
        """Build the device-resident stacked tables (once per signature)."""
        for u in self._units:
            self._bind_unit(u, inputs)
            self.stats["table_stacks"] += 1

    def update_tables(self, inputs: dict) -> None:
        """Refresh the stacked tables after the member tables changed (e.g.
        a train step updated the embeddings).  Device-side concat with the
        old stacked buffer donated where we own it — an in-place update,
        never a host round trip."""
        if any(u.table is None for u in self._units):
            return self.bind_tables(inputs)
        self.drain()   # a donated buffer must not be read by in-flight steps
        for u in self._units:
            srcs = self._src_tables(u, inputs)
            u.src_refs = tuple(weakref.ref(a) for a in srcs)
            if u.group is None:
                u.table = jnp.asarray(srcs[0])
            elif u.owns_table:
                u.table = _restack(u.table,
                                   tuple(jnp.asarray(a) for a in srcs))
            else:   # bound buffer aliases caller data: never donate it
                u.table = jnp.asarray(srcs[0])
            self.stats["table_restacks"] += 1

    # ------------------------------------------------------------------
    # Per-step access-stream marshaling (bucketed, double-buffered)
    # ------------------------------------------------------------------

    def _scratch_for(self, unit_idx: int, bucket: tuple, spec: dict):
        """Rotating host scratch slots per (unit, shape bucket).

        Each slot remembers the :class:`StepHandle` that last packed it
        (recorded by :meth:`submit`); before a slot is reused, that owner is
        drained if still unresolved — packing step N+k never races an
        in-flight transfer, regardless of how ``submit`` and ``step`` calls
        interleave.  ``depth`` slots (min 2) keep the steady-state pipeline
        from ever hitting that drain.
        """
        key = (unit_idx, bucket)
        entry = self._scratch.get(key)
        if entry is None:
            n_slots = max(2, self.depth)
            entry = {"slots": [
                {k: np.zeros(shape, dt) for k, (shape, dt) in spec.items()}
                for _ in range(n_slots)],
                "owners": [None] * n_slots, "turn": 0, "uses": 0}
            self._scratch[key] = entry
            self.stats["marshal_misses"] += 1
        else:
            self.stats["marshal_hits"] += 1
        entry["uses"] += 1
        turn = (entry["turn"] + 1) % len(entry["slots"])
        entry["turn"] = turn
        owner = entry["owners"][turn]
        if owner is not None and not owner.done:
            owner.result()            # slot still in flight: drain it first
        entry["owners"][turn] = None
        self._slots_packed.append((entry, turn))
        return entry["slots"][turn]

    def _marshal_csr(self, idx: int, u: _UnitState, inputs: dict):
        """Fused CSR unit: pack the offset-merged ptrs + concatenated
        idxs/vals into bucketed scratch; returns (exec inputs, max_lookups).
        The pallas backend gets device-put capacity buffers; the jax backend
        gets exact-length host views (its reference kernels derive segment
        ids from ``ptrs`` on the host anyway)."""
        g = u.group
        op = g.op
        nnz = 0
        max_seg = 0
        members = []
        for name, mop, seg_off in zip(g.members, g.member_ops, g.seg_offsets):
            ins = inputs[name]
            if mop.kind == "kg":
                p = u.kg_ptrs.get(name)
                if p is None:
                    p = u.kg_ptrs[name] = np.arange(
                        mop.num_segments + 1, dtype=np.int64)
            else:
                p = np.asarray(ins["ptrs"], np.int64)
            m_nnz = int(p[-1])
            max_seg = max(max_seg, int(np.diff(p).max(initial=0)))
            members.append((name, mop, seg_off, p, m_nnz))
            nnz += m_nnz
        cap = kops.lookup_capacity(nnz)
        ml = kops.grid_capacity(max_seg)
        need_vals = op.weighted or op.kind == "spmm"
        spec = {"ptrs": ((op.num_segments + 1,), np.int32),
                "idxs": ((cap,), np.int32)}
        if need_vals:
            spec["vals"] = ((cap,), np.dtype(op.dtype))
        buf = self._scratch_for(idx, (cap, ml), spec)
        unit_w = g.unit_weight
        pos = 0
        for name, mop, seg_off, p, m_nnz in members:
            buf["ptrs"][seg_off:seg_off + mop.num_segments] = p[:-1] + pos
            buf["idxs"][pos:pos + m_nnz] = inputs[name]["idxs"]
            if need_vals:
                v = inputs[name].get("vals")
                if v is None:             # unit-weight upcast member
                    buf["vals"][pos:pos + m_nnz] = unit_w
                else:
                    buf["vals"][pos:pos + m_nnz] = v
            pos += m_nnz
        buf["ptrs"][op.num_segments] = nnz
        if self.backend == "jax":
            ins = {"table": u.table, "roff": u.roff_np,
                   "ptrs": buf["ptrs"], "idxs": buf["idxs"][:nnz]}
            if need_vals:
                ins["vals"] = buf["vals"][:nnz]
            return ins, ml
        buf["idxs"][nnz:cap] = 0          # pad rows must stay in bounds
        dev = {"table": u.table, "roff": u.roff,
               "ptrs": jax.device_put(buf["ptrs"]),
               "idxs": jax.device_put(buf["idxs"])}
        if need_vals:
            dev["vals"] = jax.device_put(buf["vals"])
        return dev, ml

    def _marshal_gather(self, idx: int, u: _UnitState, inputs: dict):
        g = u.group
        n = g.op.num_segments
        buf = self._scratch_for(idx, (), {"idxs": ((n,), np.int32)})
        for name, mop, seg_off in zip(g.members, g.member_ops, g.seg_offsets):
            buf["idxs"][seg_off:seg_off + mop.num_segments] = \
                inputs[name]["idxs"]
        if self.backend == "jax":
            return {"table": u.table, "roff": u.roff_np,
                    "idxs": buf["idxs"]}, None
        return {"table": u.table, "roff": u.roff,
                "idxs": jax.device_put(buf["idxs"])}, None

    def _marshal_single(self, idx: int, u: _UnitState, inputs: dict):
        """Singleton unit: device-transfer the per-step operands, bucketing
        the ragged CSR streams."""
        op = u.res.op
        name = u.unit.names[0]
        ins = inputs[name]
        if op.kind == "gather":
            return {"table": u.table,
                    "idxs": jax.device_put(np.asarray(ins["idxs"]))}, None
        if op.kind == "kg":
            return {"table": u.table,
                    "idxs": jax.device_put(np.asarray(ins["idxs"])),
                    "vals": jax.device_put(np.asarray(ins["vals"]))}, 1
        if op.index_format == "lengths" and "ptrs" not in ins:
            ptrs = np.zeros(op.num_segments + 1, np.int64)
            np.cumsum(ins["lens"], out=ptrs[1:])
        else:
            ptrs = np.asarray(ins["ptrs"], np.int64)
        nnz = int(ptrs[-1])
        cap = kops.lookup_capacity(nnz)
        ml = kops.grid_capacity(int(np.diff(ptrs).max(initial=0)))
        key = "x" if op.kind == "fusedmm" else "table"
        need_vals = (op.weighted or op.kind == "spmm") and "vals" in ins
        spec = {"ptrs": ((op.num_segments + 1,), np.int32),
                "idxs": ((cap,), np.int32)}
        if need_vals:
            spec["vals"] = ((cap,), np.dtype(op.dtype))
        buf = self._scratch_for(idx, (cap, ml), spec)
        buf["ptrs"][:] = ptrs
        buf["idxs"][:nnz] = ins["idxs"]
        buf["idxs"][nnz:cap] = 0
        dev = {key: u.table, "ptrs": jax.device_put(buf["ptrs"]),
               "idxs": jax.device_put(buf["idxs"])}
        if need_vals:
            buf["vals"][:nnz] = ins["vals"]
            dev["vals"] = jax.device_put(buf["vals"])
        return dev, ml

    # ------------------------------------------------------------------
    # Step loop
    # ------------------------------------------------------------------

    def _execute(self, u: _UnitState, ins: dict, ml):
        if self.backend == "jax":
            return bj.execute(u.res.op, ins)
        return bp.execute(u.res, ins, interpret=self.interpret,
                          max_lookups=ml)

    def _dispatch(self, inputs: dict) -> dict:
        outs: dict = {}
        for idx, u in enumerate(self._units):
            if u.table is None:
                self._bind_unit(u, inputs)
                self.stats["table_stacks"] += 1
            elif not u.sources_unchanged(self._src_tables(u, inputs)):
                # the caller handed different table objects (fresh arrays,
                # another model's params, per-step fusedmm features):
                # rebind rather than silently serve stale tables.  Identity
                # is the steady-state fast path — stable params never pay.
                self._bind_unit(u, inputs)
                self.stats["table_rebinds"] += 1
            if u.group is None:
                if self.backend == "jax":
                    name = u.unit.names[0]
                    key = "x" if u.res.op.kind == "fusedmm" else "table"
                    ins = {**inputs[name], key: u.table}
                    outs[name] = bj.execute(u.res.op, ins)
                    continue
                dev, ml = self._marshal_single(idx, u, inputs)
                outs[u.unit.names[0]] = self._execute(u, dev, ml)
                continue
            if u.group.op.kind == "gather":
                dev, ml = self._marshal_gather(idx, u, inputs)
            else:
                dev, ml = self._marshal_csr(idx, u, inputs)
            fused = self._execute(u, dev, ml)
            for name, mop, off in zip(u.group.members, u.group.member_ops,
                                      u.group.seg_offsets):
                outs[name] = fused[off:off + mop.num_segments]
        return outs

    def submit(self, inputs: dict) -> StepHandle:
        """Dispatch one step asynchronously: marshal + launch now, block
        never.  At ``depth`` steps in flight the oldest is drained first
        (backpressure), so step N+1's access stream is prepared while step
        N's execute phase runs — the cross-step DAE overlap."""
        while len(self._inflight) >= self.depth:
            self._inflight.popleft().result()
        self._slots_packed = []
        h = StepHandle(self._dispatch(inputs), self._steps)
        for entry, turn in self._slots_packed:
            entry["owners"][turn] = h     # slot busy until h resolves
        self._steps += 1
        self.stats["steps"] += 1
        self._inflight.append(h)
        self.stats["max_inflight"] = max(self.stats["max_inflight"],
                                         len(self._inflight))
        return h

    def step(self, inputs: dict) -> dict:
        """Synchronous convenience: submit + block on this step's result."""
        h = self.submit(inputs)
        self._inflight.remove(h)
        return h.result()

    def run_steps(self, steps) -> list:
        """Run a sequence of step inputs through the double-buffered loop;
        returns each step's materialized outputs, in order."""
        out: list = []
        for ins in steps:
            out.append(self.submit(ins))
        return [h.result() for h in out]

    def drain(self) -> None:
        while self._inflight:
            self._inflight.popleft().result()


# ---------------------------------------------------------------------------
# Executor cache: one steady-state executor per program signature, kept
# alongside the compile artifact (bounded LRU like the compile cache).
# ---------------------------------------------------------------------------

_EXECUTOR_CACHE = BoundedLru(16)


def executor_for(program: EmbeddingProgram, opt_level: str = "O3",
                 vlen: int = 128, interpret: Optional[bool] = None,
                 budget: Optional[FusionBudget] = None,
                 depth: int = 2, backend: str = "pallas") -> ProgramExecutor:
    """The steady-state entry point: compile (compile-cache backed) and
    return the memoized executor whose marshaling cache is already warm for
    this signature.

    The key is the program's *structural* signature: a hit can hand back an
    executor whose tables were bound by another caller, which is exactly
    what the per-step table identity check in :meth:`ProgramExecutor.step`
    resolves (same arrays → warm fast path; different model's arrays →
    automatic rebind)."""
    # canonicalize defaults so explicit-default calls hit the same entry
    interpret = kops.default_interpret() if interpret is None else interpret
    budget = budget or FusionBudget()
    key = (program.signature(), opt_level, vlen, interpret, budget, depth,
           backend)
    ex = _EXECUTOR_CACHE.get(key)
    if ex is not None:
        return ex
    compiled = compile_program(program, opt_level, vlen=vlen, budget=budget)
    ex = ProgramExecutor(compiled, interpret=interpret, depth=depth,
                         backend=backend)
    _EXECUTOR_CACHE.put(key, ex)
    return ex


def executor_cache_stats() -> dict:
    return _EXECUTOR_CACHE.stats()


def set_executor_cache_limit(limit: int) -> int:
    return _EXECUTOR_CACHE.set_limit(limit)


def clear_executor_cache() -> None:
    _EXECUTOR_CACHE.clear()
