"""DLC → pure-JAX executor — the "traditional core" baseline (paper §3).

This backend executes the embedding operation with stock XLA ops
(gather + segment reduction), i.e. what a non-DAE machine runs.  It doubles
as the at-scale oracle for the Pallas backend and as the sharding-friendly
path used inside pjit'd models when no kernel is applicable.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from ..kernels import ref
from .ops import EmbeddingOp


def _run(aot, name, fn, static: dict, *args, **kw):
    """Dispatch one kernel call: the plain jit path, or — when the caller
    holds an :class:`~repro.core.artifact.AotCache` — the AOT-compiled
    executable (deserialized from the serving artifact or lowered once)."""
    if aot is None:
        return fn(*args, **kw, **static)
    return aot.call(name, fn, static, *args, **kw)


def execute(op: EmbeddingOp, inputs: dict, aot=None) -> jnp.ndarray:
    if op.kind == "gather":
        idxs = jnp.asarray(inputs["idxs"])
        if "roff" in inputs:   # fused multi-table: per-segment table base
            idxs = idxs + jnp.asarray(inputs["roff"], jnp.int32)
        return _run(aot, "ref.block_gather", ref.block_gather,
                    {"block_rows": op.block_rows},
                    jnp.asarray(inputs["table"]), idxs)
    if op.kind == "kg":
        seg = np.arange(op.num_segments, dtype=np.int32)
        return _run(aot, "ref.sls", ref.sls,
                    {"num_segments": op.num_segments,
                     "add_op": op.semiring.add, "mul_op": op.semiring.mul},
                    jnp.asarray(inputs["table"]),
                    jnp.asarray(inputs["idxs"]), jnp.asarray(seg),
                    jnp.asarray(inputs["vals"]))
    seg = ref.csr_to_lookups(_ptrs_of(op, inputs))
    if op.kind == "fusedmm":
        return _run(aot, "ref.fusedmm", ref.fusedmm,
                    {"num_segments": op.num_segments},
                    jnp.asarray(inputs["x"]),
                    jnp.asarray(inputs["idxs"]), jnp.asarray(seg))
    w = inputs.get("vals")
    idxs = np.asarray(inputs["idxs"])
    if "roff" in inputs:       # fused multi-table: rebase per lookup
        idxs = idxs + np.asarray(inputs["roff"], np.int64)[seg]
    return _run(aot, "ref.sls", ref.sls,
                {"num_segments": op.num_segments,
                 "add_op": op.semiring.add, "mul_op": op.semiring.mul},
                jnp.asarray(inputs["table"]), jnp.asarray(idxs),
                jnp.asarray(seg), None if w is None else jnp.asarray(w))


def _ptrs_of(op: EmbeddingOp, inputs: dict) -> np.ndarray:
    """CSR offsets from either index format (lengths → cumulative sum)."""
    if op.index_format == "lengths" and "ptrs" not in inputs:
        ptrs = np.zeros(op.num_segments + 1, np.int32)
        np.cumsum(inputs["lens"], out=ptrs[1:])
        return ptrs
    return np.asarray(inputs["ptrs"])
