"""PassManager — the compiler's pass registry, scheduler and diagnostics.

The hard-coded pass sequence that used to live in :mod:`repro.core.pipeline`
is now data: every stage of emberc is a registered :class:`Pass` with a
declared input IR stage (``op``/``scf``/``slc``/``slcv``/``dlc``), a minimum
opt level, and the compile options it consumes.  The manager

* runs the passes in registration order, skipping those gated off by the opt
  level or whose input stage does not match the current IR stage;
* records per-pass wall time and notes (:class:`PassRecord`) — the
  diagnostics the compile cache and the benchmarks introspect;
* runs an **IR verifier between passes** (``slc.verify`` on SLC/SLCV
  functions, structural checks on SCF and DLC), so a pass that produces a
  malformed function is caught at its own boundary rather than three passes
  later.

Custom passes register with :meth:`PassManager.register` (optionally
positioned ``after=`` an existing pass), which is also how tests inject
deliberately-broken passes to exercise the verifier.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

from . import scf as scf_ir
from . import slc as slc_ir
from .access_plan import AccessPlan, plan_access_pass
from .decouple import decouple
from .dlc import DlcProgram, lower_to_dlc
from .ops import EmbeddingOp
from .passes import apply_store_streams, bufferize, queue_align, vectorize
from .scf import ScfFunc, build_scf
from .slc import SlcFunc, SlcVerifyError

#: IR stages a pass may declare.  ``op`` is the frontend EmbeddingOp /
#: EmbeddingProgram level; ``slcv`` is SLC after vectorization (slcv.for
#: loops present); ``program`` marks program-level passes (fusion) that the
#: driver in :mod:`repro.core.pipeline` runs before per-op compilation;
#: ``access`` is the host-side companion of the DLC artifact — the
#: :class:`~repro.core.access_plan.AccessPlan` emitted by ``plan-access``.
STAGES = ("program", "op", "scf", "slc", "slcv", "dlc", "access")


class PassManagerError(Exception):
    pass


@dataclasses.dataclass(frozen=True)
class Pass:
    """A registered compiler pass.

    ``stage``     IR stage(s) the pass consumes (str or tuple of str);
    ``produces``  stage of its output (defaults to its input stage);
    ``min_level`` smallest numeric opt level at which the pass runs;
    ``options``   names of compile options forwarded as keyword args.
    """

    name: str
    stage: tuple
    fn: Callable
    produces: Optional[str] = None
    min_level: int = 0
    options: tuple = ()

    def __post_init__(self):
        stage = self.stage if isinstance(self.stage, tuple) else (self.stage,)
        object.__setattr__(self, "stage", stage)
        for s in stage + ((self.produces,) if self.produces else ()):
            assert s in STAGES, f"unknown IR stage {s!r}"


@dataclasses.dataclass
class PassRecord:
    """Per-pass diagnostic entry (the timing/diagnostics surface)."""

    name: str
    stage: str               # input stage the pass saw (or would have seen)
    ran: bool
    duration_s: float = 0.0
    note: str = ""


def _slcv_of(fn: SlcFunc, vlen: int = 128, **_):
    return vectorize(fn, vlen=vlen)


def default_passes() -> list:
    """The emberc pipeline (paper §5–§7) as a pass list."""
    return [
        Pass("build-scf", "op", lambda op, **_: build_scf(op),
             produces="scf"),
        Pass("decouple", "scf", lambda fn, **_: decouple(fn),
             produces="slc"),
        Pass("vectorize", "slc", _slcv_of, produces="slcv",
             min_level=1, options=("vlen",)),
        Pass("bufferize", ("slc", "slcv"), lambda fn, **_: bufferize(fn),
             min_level=2),
        Pass("store-streams", ("slc", "slcv"),
             lambda fn, **_: apply_store_streams(fn), min_level=3),
        Pass("queue-align", ("slc", "slcv"), lambda fn, **_: queue_align(fn),
             min_level=3),
        Pass("lower-dlc", ("slc", "slcv"), lambda fn, **_: lower_to_dlc(fn),
             produces="dlc"),
        # the host-side access artifact: stream layout, capacity-bucket
        # lattice, shard routing table and hot/cold classification as data —
        # what every host marshaling path interprets (repro.core.access_plan)
        Pass("plan-access", "dlc", plan_access_pass, produces="access",
             options=("frontend_op", "group", "shards", "hot_rows")),
    ]


def verify_ir(stage: str, unit) -> None:
    """Inter-pass verifier: structural invariants per IR stage."""
    if stage in ("slc", "slcv"):
        if not isinstance(unit, SlcFunc):
            raise SlcVerifyError(f"stage {stage} holds {type(unit).__name__}")
        slc_ir.verify(unit)
        if stage == "slcv" and not any(
                l.vlen for l, _ in slc_ir.loops(unit.body)):
            raise SlcVerifyError("slcv function has no vectorized loop")
    elif stage == "scf":
        if not isinstance(unit, ScfFunc):
            raise SlcVerifyError(f"stage scf holds {type(unit).__name__}")
        if "out" not in unit.memrefs or not unit.body:
            raise SlcVerifyError("scf function missing out memref or body")
    elif stage == "dlc":
        if not isinstance(unit, DlcProgram):
            raise SlcVerifyError(f"stage dlc holds {type(unit).__name__}")
        tokens = [c.token for c in unit.cases]
        if len(tokens) != len(set(tokens)):
            raise SlcVerifyError(f"duplicate DLC case tokens: {tokens}")
    elif stage == "access":
        if not isinstance(unit, AccessPlan):
            raise SlcVerifyError(
                f"stage access holds {type(unit).__name__}")
        if unit.local_rows <= 0 or len(unit.roff) != unit.num_segments:
            raise SlcVerifyError("access plan has inconsistent geometry")


class PassManager:
    """Runs registered passes over one compilation unit with verification.

    ``PassManager.total_executed`` counts every pass body actually executed
    by *any* manager — the observable the compile-cache tests use to prove a
    cache hit re-ran nothing.
    """

    total_executed = 0

    def __init__(self, passes: Optional[list] = None, verify: bool = True):
        self.passes = list(default_passes() if passes is None else passes)
        self.verify = verify

    def register(self, p: Pass, after: Optional[str] = None) -> None:
        """Insert a pass (at the end, or right after the named pass)."""
        if after is None:
            self.passes.append(p)
            return
        for i, q in enumerate(self.passes):
            if q.name == after:
                self.passes.insert(i + 1, p)
                return
        raise PassManagerError(f"no pass named {after!r} to insert after")

    def run(self, op: EmbeddingOp, opt_level: int, **options):
        """Compile one EmbeddingOp through the registered pipeline.

        Returns ``(artifacts, records)`` where ``artifacts`` maps every
        produced stage name to its IR (``scf``, ``slc`` — the final
        SLC/SLCV function — and ``dlc``).
        """
        unit, stage = op, "op"
        # the frontend op is always available to passes that declare it
        # (plan-access rebuilds the host stream layout from it)
        options.setdefault("frontend_op", op)
        artifacts: dict = {}
        records: list = []
        for p in self.passes:
            if opt_level < p.min_level or stage not in p.stage:
                records.append(PassRecord(p.name, stage, ran=False,
                                          note="opt-gated"
                                          if opt_level < p.min_level
                                          else f"stage {stage} not in "
                                               f"{p.stage}"))
                continue
            kw = {k: options[k] for k in p.options if k in options}
            t0 = time.perf_counter()
            unit = p.fn(unit, **kw)
            dt = time.perf_counter() - t0
            PassManager.total_executed += 1
            stage = p.produces or stage
            if self.verify:
                verify_ir(stage, unit)
            records.append(PassRecord(p.name, stage, ran=True, duration_s=dt))
            if stage in ("slc", "slcv"):
                artifacts["slc"] = unit
            else:
                artifacts[stage] = unit
        if "dlc" not in artifacts:
            raise PassManagerError(
                "pipeline did not reach the DLC stage; passes: "
                f"{[p.name for p in self.passes]}")
        return artifacts, records
