"""Decoupled Lookup-Compute (DLC) IR — the paper's contribution #3 (§4).

The DLC IR is the low-level DAE abstraction: a *lookup program* (streaming
dataflow code for the access unit: traversal operators, memory streams, ALU
streams, queue pushes) and a *compute program* (imperative code for the
execute unit: a while-loop popping control tokens and dispatching to
per-token cases).  Data and control flow between the two **only** through
the queues — which is exactly what makes post-decoupling global optimization
hard, and why the optimizing passes run on SLC before lowering here.

Positional semantics stand in for the paper's ``(tu_id, event)`` pairs: a
node placed before a child loop fires on the parent's iteration event
(``ite``); a node placed after a child loop fires on that child's ``end``
event.  The queue-faithful interpreter lives in :mod:`repro.core.interp`;
the Pallas backend erases the queues into a DMA schedule
(:mod:`repro.core.backend_pallas`).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Union

from . import scf
from .ops import EmbeddingOp
from .slc import (AccStr, AluStr, BufStr, Callback, DotBuf, MemStr, PushBuf,
                  SBin, SlcFor, SlcFunc, StoreBuf, StreamRef, ToVal,
                  callback_streams)

DONE = "done"

Src = tuple  # ('const', v) | ('param', name) | ('stream', sid)


# ---- lookup (access-unit) program ----------------------------------------

@dataclasses.dataclass
class DLoop:
    tu: str
    lb: Src
    ub: Src
    body: list
    vlen: Optional[int] = None


@dataclasses.dataclass
class DMem:
    sid: str
    memref: str
    indices: tuple  # of Src


@dataclasses.dataclass
class DAlu:
    sid: str
    op: str
    a: Src
    b: Src


@dataclasses.dataclass
class DAcc:
    """Accumulation stream (§7.4): exclusive running sum on the access unit."""
    sid: str
    src: Src
    init: int = 0


@dataclasses.dataclass
class DPushData:
    src: Src


@dataclasses.dataclass
class DPushTok:
    token: str


@dataclasses.dataclass
class DStore:
    """Store stream (§7.4): access unit writes a row directly to memory."""
    memref: str
    row: tuple  # of Src
    src: Src


# ---- compute (execute-unit) program ---------------------------------------

@dataclasses.dataclass
class CPop:
    """Pop `count` chunks into `var` (count>1 → concatenated vector).
    When `also` is set, chunks for the two vars are interleaved in dataQ."""
    var: str
    count: Union[int, object] = 1
    also: Optional[str] = None


@dataclasses.dataclass
class CDot:
    var: str
    a: str
    b: str
    fn: str = "identity"


@dataclasses.dataclass
class CStoreRow:
    memref: str
    row: tuple  # of scf exprs over compute locals
    var: str
    accumulate: Optional[str]
    scale: Optional[object] = None


@dataclasses.dataclass
class DCase:
    token: str
    body: list  # CPop/CDot/CStoreRow/scf stmts


@dataclasses.dataclass
class DlcProgram:
    name: str
    op: EmbeddingOp
    params: dict
    lookup: list            # access-unit dataflow tree
    cases: list             # compute-unit token cases
    locals_init: dict       # execute-side persistent locals (counters, …)
    opt: dict


# ---------------------------------------------------------------------------
# SLC → DLC lowering (paper §6.3)
# ---------------------------------------------------------------------------

class _Lower:
    def __init__(self, fn: SlcFunc):
        self.fn = fn
        self.cases: list = []
        self.locals_init: dict = {}
        self.ntok = 0
        self.alu_n = 0
        self.bufs: set = set()
        self.buf_chunks: dict = {}   # buf -> chunk count (int)
        self.extra_access: list = []

    def tok(self, hint) -> str:
        self.ntok += 1
        return f"t{self.ntok}_{hint}"

    def sidx_to_src(self, e, access_nodes) -> Src:
        if isinstance(e, scf.Const):
            return ("const", e.value)
        if isinstance(e, scf.Param):
            return ("param", e.name)
        if isinstance(e, StreamRef):
            return ("stream", e.name)
        if isinstance(e, SBin):
            # materialize compound index arithmetic as an ALU stream
            a = self.sidx_to_src(e.a, access_nodes)
            b = self.sidx_to_src(e.b, access_nodes)
            self.alu_n += 1
            sid = f"alu{self.alu_n}"
            access_nodes.append(DAlu(sid, e.op, a, b))
            return ("stream", sid)
        raise TypeError(e)

    # -- compute-side expression rewrite: ToVal(s) -> VarRef(q_s) ----------
    def rewrite_cb_expr(self, e):
        if isinstance(e, ToVal):
            return scf.VarRef(f"q_{e.stream}")
        if isinstance(e, scf.Bin):
            return scf.Bin(e.op, self.rewrite_cb_expr(e.a),
                           self.rewrite_cb_expr(e.b))
        if isinstance(e, scf.Apply):
            return scf.Apply(e.fn, self.rewrite_cb_expr(e.a))
        if isinstance(e, scf.Load):
            return scf.Load(e.memref,
                            tuple(self.rewrite_cb_expr(i) for i in e.indices))
        return e

    def rewrite_cb_stmt(self, s):
        if isinstance(s, scf.Let):
            return scf.Let(s.var, self.rewrite_cb_expr(s.value))
        if isinstance(s, scf.SetVar):
            return scf.SetVar(s.var, self.rewrite_cb_expr(s.value))
        if isinstance(s, scf.Store):
            return scf.Store(s.memref,
                             tuple(self.rewrite_cb_expr(i) for i in s.indices),
                             self.rewrite_cb_expr(s.value), s.accumulate)
        if isinstance(s, scf.For):
            return scf.For(s.var, self.rewrite_cb_expr(s.lb),
                           self.rewrite_cb_expr(s.ub),
                           [self.rewrite_cb_stmt(b) for b in s.body])
        raise TypeError(s)

    def lower_body(self, body) -> list:
        nodes: list = []
        for node in body:
            if isinstance(node, SlcFor):
                for var, init in node.carry.items():
                    self.locals_init[var] = init
                lb = self.sidx_to_src(node.lb, nodes)
                ub = self.sidx_to_src(node.ub, nodes)
                nodes.append(DLoop(node.tu if hasattr(node, "tu") else node.stream,
                                   lb, ub, self.lower_body(node.body),
                                   vlen=node.vlen))
            elif isinstance(node, MemStr):
                idx = tuple(self.sidx_to_src(i, nodes) for i in node.indices)
                nodes.append(DMem(node.stream, node.memref, idx))
            elif isinstance(node, AluStr):
                nodes.append(DAlu(node.stream, node.op,
                                  self.sidx_to_src(node.a, nodes),
                                  self.sidx_to_src(node.b, nodes)))
            elif isinstance(node, AccStr):
                nodes.append(DAcc(node.stream,
                                  self.sidx_to_src(node.src, nodes),
                                  node.init))
            elif isinstance(node, BufStr):
                self.bufs.add(node.stream)
                self.buf_chunks[node.stream] = 0
            elif isinstance(node, PushBuf):
                # buffered data: pushed chunk-wise with NO per-chunk token
                nodes.append(DPushData(("stream", node.src)))
                self.buf_chunks[node.buf] += 1  # chunks per inner iteration
            elif isinstance(node, Callback):
                nodes.extend(self.lower_callback(node))
            elif isinstance(node, StoreBuf):
                nodes.extend(self.lower_storebuf(node))
            else:
                raise TypeError(node)
        return nodes

    def lower_callback(self, cb: Callback) -> list:
        streams = sorted(callback_streams(cb))
        token = self.tok("cb")
        access = [DPushData(("stream", s)) for s in streams]
        access.append(DPushTok(token))
        body = [CPop(f"q_{s}") for s in streams]
        body += [self.rewrite_cb_stmt(s) for s in cb.body]
        self.cases.append(DCase(token, body))
        return access

    def lower_storebuf(self, sb: StoreBuf) -> list:
        emb_len = self.fn.params["emb_len"]
        vlen = self.fn.opt.get("vlen") or 1
        n_chunks = -(-emb_len // vlen)

        if sb.as_store_stream:
            # §7.4: no queue traffic at all — access unit stores directly.
            # NOTE: the buffer's PushBuf ops were already emitted as
            # DPushData; the caller strips them (see lower_to_dlc) since the
            # buffered value goes straight to memory here.
            row = tuple(self.sidx_to_src(_cb_expr_to_sidx(i), self.extra_access)
                        for i in sb.row_indices)
            return [DStore(sb.memref, row, ("buf", sb.buf))]

        access: list = []
        body: list = []
        # Queue discipline: the buffer chunks were pushed by the inner loop
        # (they sit in dataQ *first*); scalar operands (row ids, scales) are
        # marshaled after the inner traversal, at this StoreBuf's position.
        # Pops must mirror that order exactly.
        if isinstance(sb.scale, DotBuf):
            body.append(CPop(f"q_{sb.scale.buf_a}", count=n_chunks,
                             also=f"q_{sb.scale.buf_b}"))
            buf_var = f"q_{sb.scale.buf_b}" if sb.buf == sb.scale.buf_b \
                else f"q_{sb.buf}"
        else:
            body.append(CPop(f"q_{sb.buf}", count=n_chunks))
            buf_var = f"q_{sb.buf}"

        # scalar row operands (those still marshaled through the queue)
        row_exprs = []
        for i in sb.row_indices:
            if isinstance(i, ToVal):
                access.append(DPushData(("stream", i.stream)))
                body.append(CPop(f"q_{i.stream}"))
                row_exprs.append(scf.VarRef(f"q_{i.stream}"))
            else:
                row_exprs.append(self.rewrite_cb_expr(i))

        scale_expr = None
        if isinstance(sb.scale, DotBuf):
            body.append(CDot("q_dot", f"q_{sb.scale.buf_a}",
                             f"q_{sb.scale.buf_b}", sb.scale.fn))
            scale_expr = scf.VarRef("q_dot")
        elif sb.scale is not None:
            if isinstance(sb.scale, ToVal):
                access.append(DPushData(("stream", sb.scale.stream)))
                body.append(CPop(f"q_{sb.scale.stream}"))
                scale_expr = scf.VarRef(f"q_{sb.scale.stream}")
            else:
                scale_expr = self.rewrite_cb_expr(sb.scale)

        body.append(CStoreRow(sb.memref, tuple(row_exprs), buf_var,
                              sb.accumulate, scale=scale_expr))
        token = self.tok("row")
        access.append(DPushTok(token))
        self.cases.append(DCase(token, body))
        return access


def _cb_expr_to_sidx(e):
    if isinstance(e, ToVal):
        return StreamRef(e.stream)
    if isinstance(e, scf.Const) or isinstance(e, scf.Param):
        return e
    if isinstance(e, scf.Bin):
        return SBin(e.op, _cb_expr_to_sidx(e.a), _cb_expr_to_sidx(e.b))
    raise TypeError(f"store-stream row must be access-side computable: {e}")


def lower_to_dlc(fn: SlcFunc) -> DlcProgram:
    lo = _Lower(fn)
    lookup = lo.lower_body(fn.body)
    if fn.opt.get("store_streams"):
        lookup = _fuse_store_streams(lookup, lo)
    return DlcProgram(fn.name, fn.op, dict(fn.params), lookup,
                      lo.cases, lo.locals_init, dict(fn.opt))


def _fuse_store_streams(lookup: list, lo: _Lower) -> list:
    """For store-stream outputs the buffered PushBuf chunks must not hit the
    queue: rewrite  [loop{..., push v}, store(buf)]  into a direct store of
    the value stream inside the loop, addressed by loop position."""
    def rec(body):
        out = []
        i = 0
        while i < len(body):
            node = body[i]
            if isinstance(node, DLoop):
                node = DLoop(node.tu, node.lb, node.ub, rec(node.body),
                             node.vlen)
                # pattern: DLoop whose body pushes data, followed by DStore
                if (i + 1 < len(body) and isinstance(body[i + 1], DStore)
                        and body[i + 1].src[0] == "buf"):
                    st: DStore = body[i + 1]
                    inner = []
                    for n in node.body:
                        if isinstance(n, DPushData):
                            # the pushed chunk becomes a direct store,
                            # column-addressed by the inner traversal
                            inner.append(DStore(st.memref,
                                                st.row + (("stream", node.tu),),
                                                n.src))
                        else:
                            inner.append(n)
                    out.append(DLoop(node.tu, node.lb, node.ub, inner,
                                     node.vlen))
                    i += 2
                    continue
                out.append(node)
            else:
                out.append(node)
            i += 1
        return out
    return rec(lookup)


# ---------------------------------------------------------------------------
# Pretty printer (paper Fig 10c/10e surface syntax)
# ---------------------------------------------------------------------------

def pretty(prog: DlcProgram) -> str:
    lines = [f"// DLC lookup program (access unit) — {prog.name}"]

    def src(s):
        k, v = s
        return {"const": str(v), "param": v,
                "stream": v, "buf": f"buf({v})"}[k]

    def rec(body, ind):
        pad = "  " * ind
        for n in body:
            if isinstance(n, DLoop):
                v = f"<{n.vlen}>" if n.vlen else ""
                lines.append(f"{pad}{n.tu} = loop_tr{v}({src(n.lb)}, {src(n.ub)}) {{")
                rec(n.body, ind + 1)
                lines.append(f"{pad}}}")
            elif isinstance(n, DMem):
                lines.append(f"{pad}{n.sid} = mem_str({n.memref}"
                             f"[{','.join(src(i) for i in n.indices)}])")
            elif isinstance(n, DAlu):
                lines.append(f"{pad}{n.sid} = alu_str({src(n.a)} {n.op} {src(n.b)})")
            elif isinstance(n, DAcc):
                lines.append(f"{pad}{n.sid} = acc_str(+= {src(n.src)}, init={n.init})")
            elif isinstance(n, DPushData):
                lines.append(f"{pad}push_op(dataQ, {src(n.src)})")
            elif isinstance(n, DPushTok):
                lines.append(f"{pad}callback(ctrlQ, {n.token})")
            elif isinstance(n, DStore):
                lines.append(f"{pad}store_str({n.memref}"
                             f"[{','.join(src(i) for i in n.row)}] <- {src(n.src)})")
    rec(prog.lookup, 0)

    lines.append("")
    lines.append("// DLC compute program (execute unit)")
    lines.append("while((tkn = ctrlQ.pop()) != done) {")
    for case in prog.cases:
        lines.append(f"  if (tkn == {case.token}) {{")
        for s in case.body:
            if isinstance(s, CPop):
                extra = f" interleaved_with {s.also}" if s.also else ""
                lines.append(f"    {s.var} = dataQ.pop<{s.count} chunks>(){extra}")
            elif isinstance(s, CDot):
                lines.append(f"    {s.var} = {s.fn}(dot({s.a}, {s.b}))")
            elif isinstance(s, CStoreRow):
                sc = f"{_pp_expr(s.scale)} * " if s.scale is not None else ""
                op = {"add": "+=", None: "="}.get(s.accumulate, f"{s.accumulate}=")
                row = ",".join(_pp_expr(r) for r in s.row)
                lines.append(f"    {s.memref}[{row},:] {op} {sc}{s.var}")
            else:
                lines.append(f"    {_pp_stmt(s)}")
        lines.append("  }")
    lines.append("}")
    if prog.locals_init:
        lines.insert(len(lines) - len(prog.cases) * 3 - 2,
                     f"// execute-unit locals: {prog.locals_init}")
    return "\n".join(lines)


def _pp_expr(e):
    if isinstance(e, scf.Const):
        return str(e.value)
    if isinstance(e, scf.Param):
        return e.name
    if isinstance(e, scf.VarRef):
        return e.name
    if isinstance(e, scf.Load):
        return f"{e.memref}[{','.join(_pp_expr(i) for i in e.indices)}]"
    if isinstance(e, scf.Bin):
        return f"({_pp_expr(e.a)}{e.op}{_pp_expr(e.b)})"
    if isinstance(e, scf.Apply):
        return f"{e.fn}({_pp_expr(e.a)})"
    return repr(e)


def _pp_stmt(s):
    if isinstance(s, (scf.Let, scf.SetVar)):
        return f"{s.var} = {_pp_expr(s.value)}"
    if isinstance(s, scf.Store):
        op = {"add": "+=", None: "="}.get(s.accumulate, f"{s.accumulate}=")
        return f"{s.memref}[{','.join(_pp_expr(i) for i in s.indices)}] {op} {_pp_expr(s.value)}"
    if isinstance(s, scf.For):
        inner = "; ".join(_pp_stmt(b) for b in s.body)
        return f"for({s.var} in {_pp_expr(s.lb)}..{_pp_expr(s.ub)}) {{ {inner} }}"
    return repr(s)


# ---------------------------------------------------------------------------
# Queue traffic accounting (feeds the cost model / Fig 14 demonstrations)
# ---------------------------------------------------------------------------

def queue_profile(prog: DlcProgram) -> dict:
    """Static per-inner-element queue traffic of the program (Fig 14):
    how many data items and tokens are marshaled per looked-up element."""
    # count pushes at each loop depth; normalize to the innermost trip
    depth_items = {}

    def rec(body, depth):
        for n in body:
            if isinstance(n, DLoop):
                rec(n.body, depth + 1)
            elif isinstance(n, (DPushData, DPushTok)):
                key = (depth, isinstance(n, DPushTok))
                depth_items[key] = depth_items.get(key, 0) + 1
    rec(prog.lookup, 0)
    max_d = max((d for d, _ in depth_items), default=0)
    data_inner = sum(v for (d, tok), v in depth_items.items()
                     if d == max_d and not tok)
    tok_inner = sum(v for (d, tok), v in depth_items.items()
                    if d == max_d and tok)
    return {"inner_depth": max_d,
            "data_pushes_at_inner": data_inner,
            "token_pushes_at_inner": tok_inner,
            "by_depth": depth_items}
