from .vectorize import vectorize
from .bufferize import bufferize
from .queue_align import queue_align
from .model_specific import apply_store_streams

__all__ = ["vectorize", "bufferize", "queue_align", "apply_store_streams"]
