from .vectorize import vectorize
from .bufferize import bufferize
from .queue_align import queue_align
from .model_specific import apply_store_streams
from .fuse import (FusedGroup, fuse_program, fuse_inputs, fuse_index_inputs,
                   group_roff, partition_members, split_outputs, stack_tables,
                   fusion_key)

__all__ = ["vectorize", "bufferize", "queue_align", "apply_store_streams",
           "FusedGroup", "fuse_program", "fuse_inputs", "fuse_index_inputs",
           "group_roff", "partition_members", "split_outputs", "stack_tables",
           "fusion_key"]
