from .vectorize import vectorize
from .bufferize import bufferize
from .queue_align import queue_align
from .model_specific import apply_store_streams
from .fuse import (FusedGroup, fuse_program, fuse_inputs, split_outputs,
                   fusion_key)

__all__ = ["vectorize", "bufferize", "queue_align", "apply_store_streams",
           "FusedGroup", "fuse_program", "fuse_inputs", "split_outputs",
           "fusion_key"]
