"""Queue-alignment pass (paper §7.3).

Scalar operands (segment IDs) interleaved between embedding vectors in the
data queue break vector-load alignment.  When the output-row index a
callback pops is just the induction variable of an outer loop, Ember keeps a
*core-side counter* instead: the access unit stops marshaling the scalar,
and the execute unit increments its local counter on a segment-end control
token (Fig 14d / 15d).

On the TPU backend this corresponds to (a) deriving output addresses from
the grid position / scalar-prefetched ``ptrs`` instead of streaming them,
and (b) padding ``emb_len`` to a multiple of the 128-lane vector so each
marshaled vector is tile-aligned in VMEM — both recorded in ``fn.opt`` for
the kernel-plan generator.
"""
from __future__ import annotations

import copy

from .. import scf
from ..slc import Callback, SlcFor, SlcFunc, StoreBuf, ToVal, verify


def queue_align(fn: SlcFunc) -> SlcFunc:
    fn = copy.deepcopy(fn)
    aligned = _align_body(fn.body, loop_stack=[])
    if fn.opt.get("vlen"):
        v = fn.opt["vlen"]
        fn.opt["padded_emb"] = -(-fn.params["emb_len"] // v) * v
    fn.opt["queue_aligned"] = bool(aligned)
    verify(fn)
    return fn


def _align_body(body, loop_stack) -> bool:
    changed = False
    for node in body:
        if isinstance(node, SlcFor):
            changed |= _align_body(node.body, loop_stack + [node])
        elif isinstance(node, StoreBuf) and not node.as_store_stream:
            # store-stream rows are access-side addresses already (§7.4);
            # there is no queue traffic left to align for them
            changed |= _align_storebuf(node, body, loop_stack)
    return changed


def _align_storebuf(sb: StoreBuf, body, loop_stack) -> bool:
    """Replace row indices that are outer-loop induction streams with
    execute-side counters incremented on segment-end tokens."""
    if not loop_stack:
        return False
    by_stream = {l.stream: l for l in loop_stack}
    new_rows = []
    changed = False
    for idx in sb.row_indices:
        # Only the *outermost* loop's induction can be kept as a core-side
        # counter: counters of nested loops would need per-ancestor-iteration
        # resets, which the token stream does not expose (the paper pads
        # those scalars to vectors instead, §7.3 — we keep popping them).
        if (isinstance(idx, ToVal) and idx.stream in by_stream
                and by_stream[idx.stream] is loop_stack[0]):
            loop = by_stream[idx.stream]
            ctr = f"ctr_{idx.stream}"
            if ctr not in loop.carry:
                loop.carry[ctr] = 0
                # increment at the end of each `loop` iteration: the last
                # position of its body ≙ the child's end event in DLC
                loop.body.append(Callback([
                    scf.SetVar(ctr, scf.Bin("+", scf.VarRef(ctr), scf.Const(1)))
                ]))
            new_rows.append(scf.VarRef(ctr))
            changed = True
        else:
            new_rows.append(idx)
    sb.row_indices = tuple(new_rows)
    return changed
