"""Model-specific optimizations (paper §7.4).

Block-sparse attention gathers (SpAttn) have *no* compute: the callback just
copies the marshaled vector into the output.  Ember adds **store streams** so
the access unit writes results directly to memory without passing through
the core at all — the whole operation is offloaded (the 17× case in Fig 7).

The paper also adds cache-level / temporal-hint selection on load streams
(load reused index blocks from L2, stream embedding data non-temporally).
TPUs have no hardware-managed cache between HBM and VMEM, so those hints
have no direct analogue (DESIGN.md §2); we record the *intent* as plan hints
(``resident_blocks``) which the Pallas block-gather kernel realizes by
keeping hot blocks pinned in VMEM across grid steps, and which the cost
model uses to discount re-fetch traffic.
"""
from __future__ import annotations

import copy

from ..slc import SlcFunc, StoreBuf, verify


def apply_store_streams(fn: SlcFunc) -> SlcFunc:
    """Convert compute-free whole-row stores into access-unit store streams."""
    if fn.op.has_compute:
        return fn  # only legal when the execute unit contributes nothing
    fn = copy.deepcopy(fn)
    n = 0

    def rec(body):
        nonlocal n
        for node in body:
            if isinstance(node, StoreBuf) and node.accumulate is None \
                    and node.scale is None:
                node.as_store_stream = True
                n += 1
            elif hasattr(node, "body"):
                rec(node.body)
    rec(fn.body)
    if n:
        fn.opt["store_streams"] = True
        fn.opt["resident_blocks"] = True   # L2-residency intent (see above)
    verify(fn)
    return fn
