"""Bufferization pass (paper §7.2).

Marshals and computes embedding vectors as *compound* values: the access
unit pushes all ``emb_len`` elements of an embedding vector per control
token, and the execute unit processes them with a tight chunked loop.  This
amortizes token overhead over whole vectors — the dominant win for long
embedding vectors (RM2/RM3 in Fig 16).

Structurally (Fig 15b → 15c): a buffer stream is declared before the inner
loop; the inner loop pushes loaded elements into it; the element-wise
callback moves *after* the inner loop and becomes a whole-row store
(:class:`~repro.core.slc.StoreBuf`).

Two shapes are recognized:

* reduction ops (sls/spmm/kg/gather): the inner callback is a single
  (possibly scaled) accumulate of the table-element stream — it becomes
  ``out[row, :] ⊕= scale ⊗ vec(buf)``;
* fusedmm: the SDDMM accumulator + SpMM workspace loop pair becomes two
  buffer streams and ``out[i, :] += f(dot(buf_xi, buf_xj)) * vec(buf_xj)``
  — the workspace loop's memory traffic disappears into the buffer reuse
  (this is what the paper's hand-written MP code does).
"""
from __future__ import annotations

import copy

from .. import scf
from ..slc import (BufStr, Callback, DotBuf, MemStr, PushBuf, SlcFor, SlcFunc,
                   StoreBuf, ToVal, verify)


class BufferizeError(Exception):
    pass


def bufferize(fn: SlcFunc) -> SlcFunc:
    fn = copy.deepcopy(fn)
    if not _bufferize_body(fn, fn.body, parent=None):
        raise BufferizeError("no bufferizable inner loop found")
    fn.opt["bufferized"] = True
    verify(fn)
    return fn


def _bufferize_body(fn, body, parent) -> bool:
    for pos, node in enumerate(body):
        if not isinstance(node, SlcFor):
            continue
        if any(isinstance(c, SlcFor) for c in node.body):
            if _bufferize_body(fn, node.body, parent=node):
                return True
            continue
        # `node` is an innermost loop — try both recognized shapes
        if _try_reduction_shape(fn, body, pos, node):
            return True
        if _try_fusedmm_shape(fn, body, pos, node):
            return True
    return False


def _try_reduction_shape(fn, parent_body, pos, inner: SlcFor) -> bool:
    """sls/spmm/kg/gather: inner = [MemStr(s_val), Callback([Store])]."""
    mems = [n for n in inner.body if isinstance(n, MemStr)]
    cbs = [n for n in inner.body if isinstance(n, Callback)]
    if len(mems) != 1 or len(cbs) != 1 or len(cbs[0].body) != 1:
        return False
    st = cbs[0].body[0]
    if not isinstance(st, scf.Store):
        return False
    s_val = mems[0].stream
    # store value: ToVal(s_val) or Bin(op, scale, ToVal(s_val))
    scale = None
    v = st.value
    if isinstance(v, scf.Bin) and isinstance(v.b, ToVal) and v.b.stream == s_val:
        scale = v.a
    elif not (isinstance(v, ToVal) and v.stream == s_val):
        return False
    # store indices: leading row indices + trailing inner-loop index
    if not (isinstance(st.indices[-1], ToVal)
            and st.indices[-1].stream == inner.stream):
        return False
    row = tuple(st.indices[:-1])

    buf = f"buf_{s_val}"
    inner.body = [mems[0], PushBuf(buf, s_val)]
    parent_body[pos:pos + 1] = [
        BufStr(buf),
        inner,
        StoreBuf(st.memref, row, buf, st.accumulate, scale=scale),
    ]
    return True


def _try_fusedmm_shape(fn, parent_body, pos, inner: SlcFor) -> bool:
    """fusedmm: [MemStr xi, MemStr xj, Callback[s += xi*xj]] + trailing
    workspace callback ``for e2: out[i,e2] += s * x[j,e2]``."""
    mems = [n for n in inner.body if isinstance(n, MemStr)]
    cbs = [n for n in inner.body if isinstance(n, Callback)]
    if len(mems) != 2 or len(cbs) != 1:
        return False
    red = cbs[0].body[-1]
    if not (isinstance(red, scf.SetVar) and isinstance(red.value, scf.Bin)):
        return False
    acc_var = red.var
    # locate: preceding init callback (s = 0) and trailing workspace callback
    init_cb = ws_cb = None
    for n in parent_body[:pos]:
        if isinstance(n, Callback) and any(
                isinstance(s, scf.Let) and s.var == acc_var for s in n.body):
            init_cb = n
    for n in parent_body[pos + 1:]:
        if isinstance(n, Callback) and any(
                isinstance(s, scf.For) for s in n.body):
            ws_cb = n
            break
    if init_cb is None or ws_cb is None:
        return False
    ws_for = next(s for s in ws_cb.body if isinstance(s, scf.For))
    ws_store = next(s for s in ws_for.body if isinstance(s, scf.Store))
    row = tuple(i for i in ws_store.indices
                if not (isinstance(i, scf.VarRef) and i.name == ws_for.var))
    fnname = "identity"
    for s in ws_cb.body:
        if isinstance(s, scf.SetVar) and isinstance(s.value, scf.Apply):
            fnname = s.value.fn

    s_xi, s_xj = mems[0].stream, mems[1].stream
    bxi, bxj = f"buf_{s_xi}", f"buf_{s_xj}"
    inner.body = [mems[0], mems[1], PushBuf(bxi, s_xi), PushBuf(bxj, s_xj)]
    new_nodes = [
        BufStr(bxi), BufStr(bxj), inner,
        StoreBuf(ws_store.memref, row, bxj, ws_store.accumulate,
                 scale=DotBuf(bxi, bxj, fnname)),
    ]
    out = []
    for n in parent_body:
        if n is init_cb or n is ws_cb:
            continue
        if n is inner:
            out.extend(new_nodes)
        else:
            out.append(n)
    parent_body[:] = out
    return True
