"""Vectorization pass (paper §7.1).

The paper vectorizes the *inner* loop only — prior work showed inner-loop
vectorization is the efficient scheme for sparse-dense contractions when the
dense operand is row-major with rows ≥ vlen, which embedding operations
satisfy.  A loop may be vectorized iff all of its callbacks can be; the one
non-trivially-vectorizable pattern in embedding ops is the scalar reduction
accumulator (fusedmm's SDDMM dot product), which we vectorize as
vector-FMA + horizontal sum (``Apply('hsum', ·)``), exactly how SVE/TPU-VPU
reductions lower.

On the TPU target ``vlen`` is a multiple of the 128-wide lane dimension.
"""
from __future__ import annotations

import copy

from .. import scf
from ..slc import Callback, SlcFor, SlcFunc, ToVal, verify


class VectorizeError(Exception):
    pass


def _vectorizable_stmt(s) -> bool:
    if isinstance(s, (scf.Let, scf.SetVar, scf.Store)):
        return True
    if isinstance(s, scf.For):
        return all(_vectorizable_stmt(b) for b in s.body)
    return False


def _innermost(body):
    loop = None
    for node in body:
        if isinstance(node, SlcFor):
            loop = node
    if loop is None:
        return None
    inner = _innermost(loop.body)
    return inner if inner is not None else loop


def vectorize(fn: SlcFunc, vlen: int = 128) -> SlcFunc:
    """Return a new SlcFunc with the innermost loop vectorized (slcv dual)."""
    fn = copy.deepcopy(fn)
    inner = _innermost(fn.body)
    if inner is None:
        raise VectorizeError("no loop to vectorize")
    # legality: every callback of the loop must vectorize
    for node in inner.body:
        if isinstance(node, Callback):
            if not all(_vectorizable_stmt(s) for s in node.body):
                raise VectorizeError(f"callback not vectorizable: {node}")
    inner.vlen = vlen
    # rewrite scalar reduction accumulators: s = s + <vec>  →
    # s = s + hsum(<vec>)   (vector FMA + horizontal reduction)
    inner_streams = {inner.stream}
    for node in inner.body:
        if isinstance(node, Callback):
            node.body = [_rewrite_reduction(s, inner_streams, fn)
                         for s in node.body]
    fn.opt["vectorized"] = True
    fn.opt["vlen"] = vlen
    verify(fn)
    return fn


def _uses_vector(e, fn: SlcFunc) -> bool:
    """Does this expression reference any stream (vector-valued post-pass)?"""
    if isinstance(e, ToVal):
        return True
    if isinstance(e, scf.Bin):
        return _uses_vector(e.a, fn) or _uses_vector(e.b, fn)
    if isinstance(e, scf.Apply):
        return _uses_vector(e.a, fn)
    if isinstance(e, scf.Load):
        return any(_uses_vector(i, fn) for i in e.indices)
    return False


def _rewrite_reduction(s, inner_streams, fn):
    if (isinstance(s, scf.SetVar) and isinstance(s.value, scf.Bin)
            and s.value.op == "+"
            and isinstance(s.value.a, scf.VarRef)
            and s.value.a.name == s.var
            and _uses_vector(s.value.b, fn)):
        return scf.SetVar(s.var, scf.Bin("+", s.value.a,
                                         scf.Apply("hsum", s.value.b)))
    return s
