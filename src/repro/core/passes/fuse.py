"""Multi-table fusion pass (program stage).

A model step's lookups over *distinct* tables (the multi-table DLRM shape
from the paper's Table 1; RecNMP/MicroRec show co-scheduling lookups across
tables is where the large wins are) compile today into N independent DAE
schedules — N access streams, N dispatches, N compile artifacts.  This pass
merges compatible SLS/SpMM/gather/KG ops into ONE batched loop nest over the
row-stacked table:

* one access stream walks the concatenated segments (``ptrs`` offset-merged,
  ``idxs`` unchanged);
* a per-segment **table-offset stream** ``roff`` rebases indices onto the
  stacked table on the access unit (MemStr + AluStr — never marshaled);
* the execute unit sees one interleaved queue, so every downstream
  optimization (vectorize/bufferize/align/store-streams) applies once to
  the whole group.

Ops naming a shared table (``EmbeddingProgram.shared_tables``) stack that
table once and point their ``roff`` entries at the same base.

Compatibility: same CSR class — ``kg`` fuses with ``sls`` as a *degenerate
CSR* (one lookup per segment, ``ptrs = arange``) — plus equal emb_len,
dtype, semiring, block_rows and the ``offsets`` index format.  Mixed
weighted/unweighted members fuse via a **unit-weight upcast**: unweighted
members marshal a constant ⊗-identity ``vals`` stream (1 for mul, 0 for
add).  Incompatible ops compile as singleton units, unchanged.

**Cost-model partitioning**: compatibility only proposes candidates.  Each
candidate group is checked against :class:`repro.core.cost_model.FusionBudget`
— the estimated on-chip working set of the batched KernelPlan (row-tile
buffers + scalar-prefetched access-stream operands) must fit — and a group
that does not fit is split into the fewest sub-units that do, balanced on
access-unit cycles (LPT) so no sub-unit's traversal stream becomes the
straggler.
"""
from __future__ import annotations

import dataclasses
import weakref
from typing import Optional

import numpy as np

from .. import cost_model
from ..ops import EmbeddingOp, EmbeddingProgram

FUSABLE_KINDS = ("sls", "spmm", "gather", "kg")

#: kinds that share the batched CSR loop nest ('kg' = degenerate CSR)
_CSR_CLASS = {"sls": "sls", "kg": "sls", "spmm": "spmm"}


@dataclasses.dataclass(frozen=True)
class FusedGroup:
    """A set of member ops compiled as one batched multi-table op."""

    members: tuple           # op names, program order
    member_ops: tuple        # the original EmbeddingOps
    op: EmbeddingOp          # the fused op (num_tables = #stacked tables)
    seg_offsets: tuple       # per-member first output row in the fused out
    row_offsets: tuple       # per-member base row in the stacked table
                             # (block units for 'gather')

    @property
    def num_tables(self) -> int:
        return self.op.num_tables

    @property
    def unit_weight(self) -> float:
        """⊗-identity marshaled for unweighted members in an upcast group."""
        return 1.0 if self.op.semiring.mul == "mul" else 0.0


def fusion_key(prog: EmbeddingProgram, name: str):
    """Ops with equal keys may fuse; None means never fused."""
    op = prog.op(name)
    if (op.kind not in FUSABLE_KINDS or op.index_format != "offsets"
            or op.num_tables != 1):
        return None
    # 'weighted' is deliberately absent: mixed groups unit-weight upcast
    return (_CSR_CLASS.get(op.kind, op.kind), op.emb_len, op.dtype,
            op.semiring, op.block_rows)


def fuse_program(prog: EmbeddingProgram, vlen: int = 128,
                 budget: Optional[cost_model.FusionBudget] = None):
    """Group compatible ops under the resource budget.  Returns
    ``(units, note)`` where each unit is either ``(name, op)`` for a
    singleton or a :class:`FusedGroup`."""
    budget = budget or cost_model.FusionBudget()
    groups: dict = {}
    order: list = []
    for name, _ in prog.ops:
        key = fusion_key(prog, name)
        groups.setdefault(key, []).append(name)
        order.append((key, name))

    units: list = []
    emitted: set = set()
    n_split = 0
    for key, name in order:
        if name in emitted:
            continue
        members = groups[key] if key is not None else [name]
        if key is None or len(members) < 2:
            units.append((name, prog.op(name)))
            emitted.add(name)
            continue
        parts = partition_members(prog, tuple(members), vlen, budget)
        n_split += len(parts) > 1
        for part in parts:
            if len(part) < 2:
                units.append((part[0], prog.op(part[0])))
            else:
                units.append(_build_group(prog, part))
        emitted.update(members)
    n_fused = sum(1 for u in units if isinstance(u, FusedGroup))
    note = (f"{len(prog.ops)} ops -> {len(units)} units "
            f"({n_fused} fused group{'s' if n_fused != 1 else ''}")
    note += f", {n_split} split by budget)" if n_split else ")"
    return units, note


def partition_members(prog: EmbeddingProgram, members: tuple, vlen: int,
                      budget: cost_model.FusionBudget) -> list:
    """Split one compatibility group into sub-groups that fit ``budget``,
    balanced on access-unit cycles.

    Greedy LPT bin packing: members sorted by descending access weight go to
    the least-loaded sub-unit with operand headroom; a member that fits
    nowhere opens a new sub-unit.  The result preserves program order within
    each part and never exceeds the budget (a lone member that alone exceeds
    it stays a singleton — there is nothing left to split).
    """
    ops = {n: prog.op(n) for n in members}
    if cost_model.fits_budget(ops.values(), vlen, budget):
        return [tuple(members)]       # the whole group fits: fuse it all
    tile = max(cost_model.plan_tile_bytes(op, vlen, budget.num_buffers)
               for op in ops.values())
    cap = budget.vmem_bytes - tile
    # conservative: parts inherit the whole group's upcast (a part keeping
    # any weighted/kg member marshals vals for all of its members).  The
    # footprint is per shard — vocab sharding divides the index streams, so
    # a sharded executor's budget admits much larger groups.
    upcast = cost_model.group_needs_vals(ops.values())
    foot = {n: cost_model.operand_bytes(op, force_vals=upcast,
                                        shards=budget.shards)
            for n, op in ops.items()}

    index = {n: i for i, n in enumerate(members)}
    weight = {n: cost_model.access_weight(op) for n, op in ops.items()}
    # LPT over access cycles; ties broken by program order for determinism
    ranked = sorted(members, key=lambda n: (-weight[n], index[n]))
    bins: list = []                   # each: [load, operand_bytes, names]
    for n in ranked:
        best = None
        for b in bins:
            if b[1] + foot[n] <= cap and (best is None or b[0] < best[0]):
                best = b
        if best is None:
            bins.append([weight[n], foot[n], [n]])
        else:
            best[0] += weight[n]
            best[1] += foot[n]
            best[2].append(n)
    for b in bins:
        b[2].sort(key=index.__getitem__)
    bins.sort(key=lambda b: index[b[2][0]])
    return [tuple(b[2]) for b in bins]


def _effective_avg_lookups(op: EmbeddingOp) -> int:
    return 1 if op.kind == "kg" else op.avg_lookups


def _build_group(prog: EmbeddingProgram, members: tuple) -> FusedGroup:
    ops = tuple(prog.op(n) for n in members)
    proto = ops[0]
    kind = _CSR_CLASS.get(proto.kind, proto.kind)
    # unit-weight upcast: a group with any weighted/kg member marshals a
    # vals stream for every member (⊗-identity for the unweighted ones)
    weighted = (kind == "sls" and
                any(op.weighted or op.kind == "kg" for op in ops))
    # stack each distinct table once; shared tables share a base offset
    slot_base: dict = {}
    row_offsets: list = []
    next_row = 0
    for name, op in zip(members, ops):
        slot = prog.table_slot(name)
        if slot not in slot_base:
            slot_base[slot] = next_row
            next_row += op.num_embeddings
        row_offsets.append(slot_base[slot])
    seg_offsets = tuple(int(x) for x in
                        np.cumsum([0] + [op.num_segments for op in ops[:-1]]))
    fused = EmbeddingOp(
        kind=kind,
        num_segments=sum(op.num_segments for op in ops),
        num_embeddings=next_row,
        emb_len=proto.emb_len,
        avg_lookups=max(_effective_avg_lookups(op) for op in ops),
        block_rows=proto.block_rows,
        weighted=weighted,
        semiring=proto.semiring,
        dtype=proto.dtype,
        index_format="offsets",
        # even an all-shared-table group keeps the roff nest (all-zero
        # offsets): num_tables > 1 is what selects the fused loop shape
        num_tables=max(len(slot_base), 2),
    )
    return FusedGroup(tuple(members), ops, fused, seg_offsets,
                      tuple(row_offsets))


# ---------------------------------------------------------------------------
# Runtime marshaling: per-op inputs <-> fused inputs/outputs.
#
# The layout logic lives in repro.core.access_plan — these helpers build the
# group's (single-device) AccessPlan and interpret it, so the one-shot path
# can never diverge from what the executor and the shard planner marshal.
# ---------------------------------------------------------------------------

#: group -> its single-device AccessPlan.  Weak-keyed: the one-shot helpers
#: below run once per program execution and the plan build is O(vocab), so
#: rebuilding per call would dominate small interpreted steps; weak keys
#: keep dropped groups (and their numpy remap arrays) collectable.
_PLAN_CACHE = weakref.WeakKeyDictionary()


def _plan_of(group: FusedGroup):
    from ..access_plan import plan_for_group
    plan = _PLAN_CACHE.get(group)
    if plan is None:
        plan = _PLAN_CACHE[group] = plan_for_group(group)
    return plan


def stack_tables(group: FusedGroup, inputs: dict) -> np.ndarray:
    """Row-stack the member tables per the compiled AccessPlan layout.

    Placement follows the plan's slots (which honor the program's
    shared-table annotation): each declared table slot is written once into
    the stacked buffer, so the runtime marshaling can never diverge from the
    compiled fused op — regardless of whether shared tables arrive as one
    array object or equal-valued copies.
    """
    plan = _plan_of(group)
    parts = []
    for slot, name in zip(plan.slots, plan.slot_first_member):
        tbl = np.asarray(inputs[name]["table"])
        expect = slot.rows * plan.blk
        assert tbl.shape[0] == expect, \
            f"{name}: table has {tbl.shape[0]} rows, op declares {expect}"
        parts.append(tbl)
    return plan.stack_np(parts)


def group_roff(group: FusedGroup) -> np.ndarray:
    """The per-segment table-offset stream (static per signature)."""
    return _plan_of(group).roff


def fuse_index_inputs(group: FusedGroup, inputs: dict) -> dict:
    """The *per-step* half of the marshaling: offset-merged ``ptrs``,
    concatenated ``idxs``/``vals`` and the ``roff`` stream — everything
    except the stacked table (see :func:`stack_tables`).  Unweighted members
    of an upcast group emit a constant ⊗-identity ``vals`` run; kg members
    emit their degenerate one-per-segment CSR."""
    return _plan_of(group).fused_index_inputs(inputs)


def fuse_inputs(group: FusedGroup, inputs: dict) -> dict:
    """Build the fused op's concrete inputs from per-op input dicts (the
    one-shot path: stacked table + per-step index streams)."""
    fused_in = fuse_index_inputs(group, inputs)
    fused_in["table"] = stack_tables(group, inputs)
    return fused_in


def split_outputs(group: FusedGroup, fused_out) -> dict:
    """Slice the fused output back into per-op outputs, keyed by name."""
    out: dict = {}
    for name, op, off in zip(group.members, group.member_ops,
                             group.seg_offsets):
        out[name] = fused_out[off:off + op.num_segments]
    return out
