"""Multi-table fusion pass (program stage).

A model step's lookups over *distinct* tables (the multi-table DLRM shape
from the paper's Table 1; RecNMP/MicroRec show co-scheduling lookups across
tables is where the large wins are) compile today into N independent DAE
schedules — N access streams, N dispatches, N compile artifacts.  This pass
merges compatible SLS/SpMM/gather ops into ONE batched loop nest over the
row-stacked table:

* one access stream walks the concatenated segments (``ptrs`` offset-merged,
  ``idxs`` unchanged);
* a per-segment **table-offset stream** ``roff`` rebases indices onto the
  stacked table on the access unit (MemStr + AluStr — never marshaled);
* the execute unit sees one interleaved queue, so every downstream
  optimization (vectorize/bufferize/align/store-streams) applies once to
  the whole group.

Ops naming a shared table (``EmbeddingProgram.shared_tables``) stack that
table once and point their ``roff`` entries at the same base.

Compatibility: same kind ∈ {sls, spmm, gather}, emb_len, dtype, semiring,
weighted flag, block_rows, and the ``offsets`` index format.  Incompatible
ops compile as singleton units, unchanged.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..ops import EmbeddingOp, EmbeddingProgram

FUSABLE_KINDS = ("sls", "spmm", "gather")


@dataclasses.dataclass(frozen=True)
class FusedGroup:
    """A set of member ops compiled as one batched multi-table op."""

    members: tuple           # op names, program order
    member_ops: tuple        # the original EmbeddingOps
    op: EmbeddingOp          # the fused op (num_tables = #stacked tables)
    seg_offsets: tuple       # per-member first output row in the fused out
    row_offsets: tuple       # per-member base row in the stacked table
                             # (block units for 'gather')

    @property
    def num_tables(self) -> int:
        return self.op.num_tables


def fusion_key(prog: EmbeddingProgram, name: str):
    """Ops with equal keys may fuse; None means never fused."""
    op = prog.op(name)
    if (op.kind not in FUSABLE_KINDS or op.index_format != "offsets"
            or op.num_tables != 1):
        return None
    return (op.kind, op.emb_len, op.dtype, op.weighted, op.semiring,
            op.block_rows)


def fuse_program(prog: EmbeddingProgram):
    """Group compatible ops.  Returns ``(units, note)`` where each unit is
    either ``(name, op)`` for a singleton or a :class:`FusedGroup`."""
    groups: dict = {}
    order: list = []
    for name, _ in prog.ops:
        key = fusion_key(prog, name)
        groups.setdefault(key, []).append(name)
        order.append((key, name))

    units: list = []
    emitted: set = set()
    for key, name in order:
        if name in emitted:
            continue
        members = groups[key] if key is not None else [name]
        if key is None or len(members) < 2:
            units.append((name, prog.op(name)))
            emitted.add(name)
            continue
        units.append(_build_group(prog, tuple(members)))
        emitted.update(members)
    n_fused = sum(1 for u in units if isinstance(u, FusedGroup))
    note = (f"{len(prog.ops)} ops -> {len(units)} units "
            f"({n_fused} fused group{'s' if n_fused != 1 else ''})")
    return units, note


def _build_group(prog: EmbeddingProgram, members: tuple) -> FusedGroup:
    ops = tuple(prog.op(n) for n in members)
    proto = ops[0]
    # stack each distinct table once; shared tables share a base offset
    slot_base: dict = {}
    row_offsets: list = []
    next_row = 0
    for name, op in zip(members, ops):
        slot = prog.table_slot(name)
        if slot not in slot_base:
            slot_base[slot] = next_row
            next_row += op.num_embeddings
        row_offsets.append(slot_base[slot])
    seg_offsets = tuple(int(x) for x in
                        np.cumsum([0] + [op.num_segments for op in ops[:-1]]))
    fused = EmbeddingOp(
        kind=proto.kind,
        num_segments=sum(op.num_segments for op in ops),
        num_embeddings=next_row,
        emb_len=proto.emb_len,
        avg_lookups=max(op.avg_lookups for op in ops),
        block_rows=proto.block_rows,
        weighted=proto.weighted,
        semiring=proto.semiring,
        dtype=proto.dtype,
        index_format="offsets",
        # even an all-shared-table group keeps the roff nest (all-zero
        # offsets): num_tables > 1 is what selects the fused loop shape
        num_tables=max(len(slot_base), 2),
    )
    return FusedGroup(tuple(members), ops, fused, seg_offsets,
                      tuple(row_offsets))


# ---------------------------------------------------------------------------
# Runtime marshaling: per-op inputs <-> fused inputs/outputs
# ---------------------------------------------------------------------------

def fuse_inputs(group: FusedGroup, inputs: dict) -> dict:
    """Build the fused op's concrete inputs from per-op input dicts.

    Placement follows the *compile-time* layout (``group.row_offsets``, which
    honors the program's shared-table annotation): each declared table slot
    is written once into the stacked buffer, so the runtime marshaling can
    never diverge from the compiled fused op — regardless of whether shared
    tables arrive as one array object or equal-valued copies.  Also
    offset-merges ``ptrs``, concatenates ``idxs``/``vals``, and emits the
    per-segment ``roff`` table-offset array.
    """
    op0 = group.member_ops[0]
    blk = op0.block_rows if op0.kind == "gather" else 1
    total_rows = group.op.num_embeddings * blk
    table = np.empty((total_rows, op0.emb_len), np.dtype(op0.dtype))
    placed: set = set()
    roff_parts: list = []
    for name, op, base in zip(group.members, group.member_ops,
                              group.row_offsets):
        tbl = np.asarray(inputs[name]["table"])
        row_base = base * blk
        expect = op.num_embeddings * blk
        assert tbl.shape[0] == expect, \
            f"{name}: table has {tbl.shape[0]} rows, op declares {expect}"
        if base not in placed:      # shared slots are stacked once
            placed.add(base)
            table[row_base:row_base + tbl.shape[0]] = tbl
        roff_parts.append(np.full(op.num_segments, base, np.int32))

    fused_in: dict = {"table": table, "roff": np.concatenate(roff_parts)}
    op0 = group.member_ops[0]
    if op0.kind == "gather":
        fused_in["idxs"] = np.concatenate(
            [np.asarray(inputs[n]["idxs"]) for n in group.members])
        return fused_in

    ptrs_parts: list = []
    nnz = 0
    for name in group.members:
        p = np.asarray(inputs[name]["ptrs"], np.int64)
        ptrs_parts.append(p[:-1] + nnz if ptrs_parts else p[:-1])
        nnz += int(p[-1])
    fused_in["ptrs"] = np.concatenate(
        ptrs_parts + [np.asarray([nnz])]).astype(np.int32)
    fused_in["idxs"] = np.concatenate(
        [np.asarray(inputs[n]["idxs"]) for n in group.members])
    if op0.weighted or op0.kind == "spmm":
        fused_in["vals"] = np.concatenate(
            [np.asarray(inputs[n]["vals"]) for n in group.members])
    return fused_in


def split_outputs(group: FusedGroup, fused_out) -> dict:
    """Slice the fused output back into per-op outputs, keyed by name."""
    out: dict = {}
    for name, op, off in zip(group.members, group.member_ops,
                             group.seg_offsets):
        out[name] = fused_out[off:off + op.num_segments]
    return out
