"""DLC → Pallas code generation (the paper's `tmu` dialect stage, for TPU).

The optimized DLC program is erased into a :class:`KernelPlan` — the queue
machinery becomes a DMA schedule (DESIGN.md §2) — and the plan parameterizes
the generic DAE kernel templates in :mod:`repro.kernels`:

=====================  =====================================================
DLC/opt property        KernelPlan effect
=====================  =====================================================
vectorized (vlen)       column tile = round_up(vlen, 128) lanes
bufferized              whole-row DMA per lookup (one block per table row);
                        without it the kernel walks column tiles (more grid
                        steps → more DMA descriptors ≙ queue traffic)
queue_aligned           rows padded to the lane tile; output addressed from
                        scalar-prefetched ptrs, no row-id marshaling
store_streams           pure-copy kernel (block_gather) — VPU bypassed
=====================  =====================================================

Un-vectorized (O0) programs have no sensible TPU realization — a 1-lane VPU
op does not exist — so O0/O1 differences below the lane width are modeled by
the cost model, and the Pallas backend refuses plans narrower than a lane.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np

from ..kernels import ops as kops
from .cost_model import lane_tile
from .ops import EmbeddingOp
from .pipeline import (CompileResult, ProgramCompileResult, opt_level_index)
from .passes import fuse_inputs, split_outputs


@dataclasses.dataclass(frozen=True)
class KernelPlan:
    kind: str
    col_tile: int           # lane-tile of each DMA (queue "chunk")
    whole_row_dma: bool     # bufferization: one DMA per embedding row
    aligned: bool           # queue alignment: padded rows, no id marshaling
    store_stream: bool      # §7.4 pure-copy path
    num_buffers: int = 2    # DMA pipeline depth (the queue depth)
    num_tables: int = 1     # >1: batched multi-table plan (stacked table +
                            # scalar-prefetched per-segment base stream)

    @property
    def vmem_bytes_per_buffer(self) -> int:
        return self.col_tile * 4 * self.num_buffers

    @property
    def batched(self) -> bool:
        return self.num_tables > 1


def make_plan(res: CompileResult) -> KernelPlan:
    opt = res.opt
    vlen = opt.get("vlen") or 0
    if vlen and vlen < 128:
        vlen = 128  # TPU lane width floor (see module docstring)
    col_tile = lane_tile(res.op.emb_len, vlen)
    return KernelPlan(
        kind=res.op.kind,
        col_tile=col_tile,
        whole_row_dma=bool(opt.get("bufferized")),
        aligned=bool(opt.get("queue_aligned")),
        store_stream=bool(opt.get("store_streams")),
        num_tables=res.op.num_tables,
    )


def _run(aot, name, fn, static: dict, *args, **kw):
    """Dispatch one kernel launch: the plain jit wrapper, or — when the
    caller holds an :class:`~repro.core.artifact.AotCache` — the
    AOT-compiled executable (deserialized from the serving artifact or
    lowered once).  ``fn`` must be the underlying jit object (the public
    :mod:`repro.kernels.ops` wrappers are plain functions, no ``lower``)."""
    if aot is None:
        return fn(*args, **kw, **static)
    return aot.call(name, fn, static, *args, **kw)


def execute(res: CompileResult, inputs: dict, interpret: bool = True,
            max_lookups: Optional[int] = None, aot=None):
    """Run the compiled op through the Pallas DAE kernels.

    ``max_lookups`` (the kernel's static lookup-slot grid extent) is derived
    from ``ptrs`` when absent — a host read of the offsets.  Steady-state
    callers (:mod:`repro.core.executor`) pass a precomputed *bucketed* value
    so device-resident ``ptrs`` are never synced back to the host and ragged
    batches reuse one jit specialization per bucket.
    """
    op = res.op
    plan = make_plan(res)
    interp = kops.default_interpret() if interpret is None else bool(interpret)
    if op.kind == "gather":
        assert plan.store_stream or opt_level_index(res.opt_level) < 3
        idxs = jnp.asarray(inputs["idxs"])
        if plan.batched and "roff" in inputs:
            # table-offset stream: rebase is scalar index math ahead of DMA
            idxs = idxs + jnp.asarray(inputs["roff"], jnp.int32)
        return _run(aot, "block_gather_pallas", kops.block_gather_pallas,
                    {"block_rows": op.block_rows, "interpret": interp},
                    jnp.asarray(inputs["table"]), idxs)
    if op.kind == "fusedmm":
        ptrs = _ptrs_of(op, inputs)
        if max_lookups is None:
            max_lookups = kops.max_lookups_of(np.asarray(ptrs))
        return _run(aot, "fusedmm_pallas", kops.fusedmm_pallas,
                    {"num_segments": op.num_segments,
                     "max_lookups": max_lookups, "interpret": interp},
                    jnp.asarray(inputs["x"]), jnp.asarray(ptrs),
                    jnp.asarray(inputs["idxs"]))
    if op.kind == "kg":
        ptrs = np.arange(op.num_segments + 1, dtype=np.int32)
        w = inputs["vals"]
        max_lookups = 1
    else:
        ptrs = _ptrs_of(op, inputs)
        w = inputs.get("vals")
    if max_lookups is None:
        max_lookups = kops.max_lookups_of(np.asarray(ptrs))
    col_tile = plan.col_tile if plan.whole_row_dma else 128
    seg_base = None
    if plan.batched and "roff" in inputs:
        seg_base = jnp.asarray(inputs["roff"], jnp.int32)
    return _run(aot, "sls_pallas", kops.sls_pallas,
                {"num_segments": op.num_segments,
                 "max_lookups": max_lookups,
                 "add_op": op.semiring.add, "mul_op": op.semiring.mul,
                 "col_tile": col_tile, "interpret": interp},
                jnp.asarray(inputs["table"]), jnp.asarray(ptrs),
                jnp.asarray(inputs["idxs"]),
                None if w is None else jnp.asarray(w),
                seg_base=seg_base)


def execute_program(pres: ProgramCompileResult, inputs: dict,
                    interpret: bool = True) -> dict:
    """Run a compiled program on the Pallas backend.

    ``inputs`` maps op name -> concrete inputs.  Fused units execute ONE
    batched kernel launch over the stacked table (one scalar-prefetch access
    stream instead of per-table dispatches) and split the output rows back
    per member op.
    """
    outs: dict = {}
    for unit in pres.units:
        if unit.group is None:
            outs[unit.names[0]] = execute(unit.result,
                                          inputs[unit.names[0]],
                                          interpret=interpret)
        else:
            fused = execute(unit.result, fuse_inputs(unit.group, inputs),
                            interpret=interpret)
            outs.update(split_outputs(unit.group, fused))
    return outs


def _ptrs_of(op: EmbeddingOp, inputs: dict):
    """CSR offsets from either index format (lengths → cumulative sum).
    Already-device arrays pass through untouched (no host round trip)."""
    if op.index_format == "lengths" and "ptrs" not in inputs:
        ptrs = np.zeros(op.num_segments + 1, np.int32)
        np.cumsum(inputs["lens"], out=ptrs[1:])
        return ptrs
    ptrs = inputs["ptrs"]
    return ptrs if isinstance(ptrs, jnp.ndarray) else np.asarray(ptrs)
