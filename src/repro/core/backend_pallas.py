"""DLC → Pallas code generation (the paper's `tmu` dialect stage, for TPU).

The optimized DLC program is erased into a :class:`KernelPlan` — the queue
machinery becomes a DMA schedule (DESIGN.md §2) — and the plan parameterizes
the generic DAE kernel templates in :mod:`repro.kernels`:

=====================  =====================================================
DLC/opt property        KernelPlan effect
=====================  =====================================================
vectorized (vlen)       column tile = round_up(vlen, 128) lanes
bufferized              whole-row DMA per lookup (one block per table row);
                        without it the kernel walks column tiles (more grid
                        steps → more DMA descriptors ≙ queue traffic)
queue_aligned           rows padded to the lane tile; output addressed from
                        scalar-prefetched ptrs, no row-id marshaling
store_streams           pure-copy kernel (block_gather) — VPU bypassed
=====================  =====================================================

Un-vectorized (O0) programs have no sensible TPU realization — a 1-lane VPU
op does not exist — so O0/O1 differences below the lane width are modeled by
the cost model, and the Pallas backend refuses plans narrower than a lane.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from ..kernels import ops as kops
from .ops import EmbeddingOp
from .pipeline import (CompileResult, ProgramCompileResult, opt_level_index)
from .passes import fuse_inputs, split_outputs


@dataclasses.dataclass(frozen=True)
class KernelPlan:
    kind: str
    col_tile: int           # lane-tile of each DMA (queue "chunk")
    whole_row_dma: bool     # bufferization: one DMA per embedding row
    aligned: bool           # queue alignment: padded rows, no id marshaling
    store_stream: bool      # §7.4 pure-copy path
    num_buffers: int = 2    # DMA pipeline depth (the queue depth)
    num_tables: int = 1     # >1: batched multi-table plan (stacked table +
                            # scalar-prefetched per-segment base stream)

    @property
    def vmem_bytes_per_buffer(self) -> int:
        return self.col_tile * 4 * self.num_buffers

    @property
    def batched(self) -> bool:
        return self.num_tables > 1


def make_plan(res: CompileResult) -> KernelPlan:
    opt = res.opt
    vlen = opt.get("vlen") or 0
    if vlen and vlen < 128:
        vlen = 128  # TPU lane width floor (see module docstring)
    emb = res.op.emb_len
    col_tile = min(_round_up(max(vlen, 128), 128), _round_up(emb, 128))
    return KernelPlan(
        kind=res.op.kind,
        col_tile=col_tile,
        whole_row_dma=bool(opt.get("bufferized")),
        aligned=bool(opt.get("queue_aligned")),
        store_stream=bool(opt.get("store_streams")),
        num_tables=res.op.num_tables,
    )


def execute(res: CompileResult, inputs: dict, interpret: bool = True):
    """Run the compiled op through the Pallas DAE kernels."""
    op = res.op
    plan = make_plan(res)
    if op.kind == "gather":
        assert plan.store_stream or opt_level_index(res.opt_level) < 3
        idxs = jnp.asarray(inputs["idxs"])
        if plan.batched and "roff" in inputs:
            # table-offset stream: rebase is scalar index math ahead of DMA
            idxs = idxs + jnp.asarray(inputs["roff"], jnp.int32)
        return kops.block_gather(jnp.asarray(inputs["table"]), idxs,
                                 block_rows=op.block_rows,
                                 interpret=interpret)
    if op.kind == "fusedmm":
        ptrs = _ptrs_of(op, inputs)
        return kops.fusedmm(jnp.asarray(inputs["x"]), jnp.asarray(ptrs),
                            jnp.asarray(inputs["idxs"]),
                            num_segments=op.num_segments,
                            max_lookups=kops.max_lookups_of(ptrs),
                            interpret=interpret)
    if op.kind == "kg":
        ptrs = np.arange(op.num_segments + 1, dtype=np.int32)
        w = inputs["vals"]
    else:
        ptrs = _ptrs_of(op, inputs)
        w = inputs.get("vals")
    col_tile = plan.col_tile if plan.whole_row_dma else 128
    seg_base = None
    if plan.batched and "roff" in inputs:
        seg_base = jnp.asarray(inputs["roff"], jnp.int32)
    return kops.sls(jnp.asarray(inputs["table"]), jnp.asarray(ptrs),
                    jnp.asarray(inputs["idxs"]),
                    None if w is None else jnp.asarray(w),
                    num_segments=op.num_segments,
                    max_lookups=kops.max_lookups_of(ptrs),
                    add_op=op.semiring.add, mul_op=op.semiring.mul,
                    col_tile=col_tile, interpret=interpret,
                    seg_base=seg_base)


def execute_program(pres: ProgramCompileResult, inputs: dict,
                    interpret: bool = True) -> dict:
    """Run a compiled program on the Pallas backend.

    ``inputs`` maps op name -> concrete inputs.  Fused units execute ONE
    batched kernel launch over the stacked table (one scalar-prefetch access
    stream instead of per-table dispatches) and split the output rows back
    per member op.
    """
    outs: dict = {}
    for unit in pres.units:
        if unit.group is None:
            outs[unit.names[0]] = execute(unit.result,
                                          inputs[unit.names[0]],
                                          interpret=interpret)
        else:
            fused = execute(unit.result, fuse_inputs(unit.group, inputs),
                            interpret=interpret)
            outs.update(split_outputs(unit.group, fused))
    return outs


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def _ptrs_of(op: EmbeddingOp, inputs: dict) -> np.ndarray:
    """CSR offsets from either index format (lengths → cumulative sum)."""
    if op.index_format == "lengths" and "ptrs" not in inputs:
        ptrs = np.zeros(op.num_segments + 1, np.int32)
        np.cumsum(inputs["lens"], out=ptrs[1:])
        return ptrs
    return np.asarray(inputs["ptrs"])
