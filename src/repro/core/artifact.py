"""AOT serving artifact: boot by loading, not compiling (ROADMAP item 5).

Ember's premise is that the expensive analysis happens once at compile
time — but a fresh *process* still re-pays the whole PassManager + trace +
XLA compile before its first request.  This module makes the compiled
program a durable on-disk artifact so a restarted server (or a respawned
disaggregated replica) reaches its first token by **loading**:

    <artifact_dir>/current/
        meta.json        # format + runtime fingerprint + compile identity
        compile.pkl      # pickled ProgramCompileResult (IR + AccessPlans)
        aot.pkl          # {kernel-call key -> serialized XLA executable}
    <artifact_dir>/current.COMMITTED   # ckpt commit-marker protocol

Publication reuses :func:`repro.checkpoint.ckpt.publish_dir` — the same
retire-marker → rename → fsync sequence checkpoints use, so a crash
mid-save leaves either the previous committed artifact or a torn state
that :func:`load_artifact` detects and rejects (never a half-read).

Loading is fingerprint-gated: the artifact is accepted only when the
jax/jaxlib versions, backend platform, device fingerprint and format
version all match the running process AND the compile identity (program
signature hash, opt_level, vlen, fusion budget, hot spec) matches what
the caller is about to compile.  Any mismatch increments a reject
counter (:func:`artifact_stats`) and falls back to a fresh compile —
a stale artifact can cost time, never numerics.

The lowered executables ride along as ``jax.experimental
.serialize_executable`` payloads inside :class:`AotCache`: per kernel
call-site key, the cache deserializes the stored executable (~ms)
instead of tracing + XLA-compiling (~100s of ms); a payload that fails
to deserialize (version skew the fingerprint could not see) falls back
to a live ``fn.lower(...).compile()`` for that key alone.  Call sites
inside a live jax trace (the serving wave executable, shard_map bodies)
cannot host an AOT-compiled callable and keep the plain jit path — for
them the artifact still saves the PassManager re-run via the hydrated
compile cache, and the docs call the residual trace-on-load out.
"""
from __future__ import annotations

import hashlib
import json
import os
import pickle
import shutil
from pathlib import Path
from typing import Optional

import numpy as np

from .access_plan import canonical_hot
from .cost_model import FusionBudget
from .pipeline import ProgramCompileResult, compile_cache_key

__all__ = ["AotCache", "artifact_meta", "artifact_stats",
           "aot_supported", "load_artifact", "reset_artifact_stats",
           "runtime_fingerprint", "save_artifact"]

#: bump on any incompatible change to the on-disk layout
FORMAT_VERSION = 1

_STATS = {"saves": 0, "loads": 0, "fresh_compiles": 0, "rejects": {},
          "aot_deserialized": 0, "aot_compiled": 0, "aot_fallbacks": 0}


def artifact_stats() -> dict:
    """Process-wide load/save/reject counters (reject keyed by reason —
    the runbook's fresh-compile-fallback observability)."""
    s = dict(_STATS)
    s["rejects"] = dict(_STATS["rejects"])
    return s


def reset_artifact_stats() -> None:
    _STATS.update({"saves": 0, "loads": 0, "fresh_compiles": 0,
                   "rejects": {}, "aot_deserialized": 0, "aot_compiled": 0,
                   "aot_fallbacks": 0})


def _reject(reason: str) -> None:
    _STATS["rejects"][reason] = _STATS["rejects"].get(reason, 0) + 1


def note_fresh_compile() -> None:
    """An artifact_dir caller that ended up compiling (missing/rejected
    artifact) — the counter the version-skew runbook row watches."""
    _STATS["fresh_compiles"] += 1


def runtime_fingerprint() -> dict:
    """What must match for a serialized executable to be trustworthy on
    this process: jax/jaxlib versions (tracing + XLA serialization
    compatibility) and the device topology it was lowered for."""
    import jax
    import jaxlib
    devs = jax.devices()
    return {"jax": jax.__version__, "jaxlib": jaxlib.__version__,
            "backend": jax.default_backend(),
            "device_kinds": sorted({d.device_kind for d in devs}),
            "device_count": len(devs)}


def aot_supported() -> bool:
    """Whether the installed jax can (de)serialize compiled executables.
    When False the artifact still carries the compile payload — boot saves
    the PassManager, not the XLA compile (graceful trace-on-load)."""
    try:
        from jax.experimental import serialize_executable  # noqa: F401
        return True
    except ImportError:
        return False


# ---------------------------------------------------------------------------
# AotCache: per-kernel-call memo of lowered executables
# ---------------------------------------------------------------------------

class AotCache:
    """Memoizes ``fn.lower(*args, **static).compile()`` per call-site key
    and hydrates lazily from serialized payloads loaded off an artifact.

    A key is (kernel name, sorted static kwargs, abstract signature of
    the array arguments) — exactly what jit specializes on — so the cache
    holds one executable per kernel specialization, the same population a
    warm in-process jit cache would.  ``payloads()`` exports every held
    executable back to serialized form for :func:`save_artifact`.
    """

    def __init__(self, payloads: Optional[dict] = None):
        self._compiled: dict = {}
        self._blobs: dict = dict(payloads or {})
        self.stats = {"hits": 0, "loads": 0, "compiles": 0, "fallbacks": 0}

    @staticmethod
    def _sig(args: tuple, kwargs: dict) -> tuple:
        import jax
        leaves, treedef = jax.tree_util.tree_flatten((args, kwargs))
        return (str(treedef),
                tuple((tuple(np.shape(a)),
                       np.dtype(getattr(a, "dtype",
                                        np.asarray(a).dtype)).str)
                      for a in leaves))

    def call(self, name: str, fn, static: dict, *args, **kwargs):
        """Run ``fn`` (a jit object) AOT: deserialize or lower+compile the
        executable for this specialization once, then invoke it directly —
        static kwargs are baked into the executable, only arrays cross."""
        key = (name, tuple(sorted(static.items())),
               self._sig(args, kwargs))
        exe = self._compiled.get(key)
        if exe is None:
            exe = self._hydrate(key)
        if exe is None:
            exe = fn.lower(*args, **kwargs, **static).compile()
            self._compiled[key] = exe
            self.stats["compiles"] += 1
            _STATS["aot_compiled"] += 1
        else:
            self.stats["hits"] += 1
        return exe(*args, **kwargs)

    def _hydrate(self, key):
        blob = self._blobs.get(key)
        if blob is None:
            return None
        try:
            from jax.experimental import serialize_executable as se
            exe = se.deserialize_and_load(*pickle.loads(blob))
        except Exception:   # noqa: BLE001 — any skew → live compile
            self.stats["fallbacks"] += 1
            _STATS["aot_fallbacks"] += 1
            del self._blobs[key]
            return None
        self._compiled[key] = exe
        self.stats["loads"] += 1
        _STATS["aot_deserialized"] += 1
        return exe

    def payloads(self) -> dict:
        """Serialize every resident executable (plus still-cold loaded
        blobs) for :func:`save_artifact`.  Unserializable executables are
        skipped — the artifact stays loadable, those keys re-trace."""
        out = dict(self._blobs)
        if not aot_supported():
            return out
        from jax.experimental import serialize_executable as se
        for key, exe in self._compiled.items():
            if key in out:
                continue
            try:
                out[key] = pickle.dumps(se.serialize(exe))
            except Exception:   # noqa: BLE001
                pass
        return out


# ---------------------------------------------------------------------------
# Save / load
# ---------------------------------------------------------------------------

def artifact_meta(program, *, opt_level: str, vlen: int = 128,
                  budget: Optional[FusionBudget] = None, hot_rows=None,
                  backend: str = "pallas", interpret=None) -> dict:
    """The identity an artifact is saved under and validated against at
    load: the compile-cache key rendered JSON-stable.  ``backend`` and
    ``interpret`` are informational — the compile payload is
    backend-agnostic IR; AOT blobs self-select by their call keys."""
    budget = budget or FusionBudget()
    sig = hashlib.sha256(repr(program.signature()).encode()).hexdigest()
    return {"identity": {"signature_sha": sig,
                         "opt_level": opt_level,
                         "vlen": vlen,
                         "budget": repr(budget),
                         "hot_spec": _jsonable(canonical_hot(hot_rows))},
            "backend": backend,
            "interpret": None if interpret is None else bool(interpret),
            "program": program.name}


def _jsonable(x):
    return json.loads(json.dumps(x))


def compile_key_of(program, meta: dict, *,
                   budget: Optional[FusionBudget] = None,
                   hot_rows=None) -> tuple:
    """The compile-cache key matching an artifact's identity (used to
    seed :mod:`repro.core.pipeline`'s cache after a successful load)."""
    ident = meta["identity"]
    return compile_cache_key(program, ident["opt_level"],
                             vlen=ident["vlen"], budget=budget,
                             hot_rows=hot_rows)


def save_artifact(artifact_dir, compiled: ProgramCompileResult, *,
                  meta: dict, aot_payloads: Optional[dict] = None) -> Path:
    """Atomically publish ``<artifact_dir>/current`` (ckpt commit-marker
    protocol).  Re-saving overwrites — last writer wins, and a loader
    racing the publish window sees a torn state and compiles fresh."""
    import dataclasses

    from ..checkpoint.ckpt import publish_dir
    artifact_dir = Path(artifact_dir)
    artifact_dir.mkdir(parents=True, exist_ok=True)
    tmp = artifact_dir / f".tmp_current_{os.getpid()}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()
    full = {"format": FORMAT_VERSION, "fingerprint": runtime_fingerprint(),
            **meta}
    # a cache-hit flag inside the payload would lie on the next process
    payload = dataclasses.replace(compiled, cache_hit=False)
    _write_fsync(tmp / "meta.json", json.dumps(full, indent=1).encode())
    _write_fsync(tmp / "compile.pkl", pickle.dumps(payload))
    _write_fsync(tmp / "aot.pkl", pickle.dumps(dict(aot_payloads or {})))
    publish_dir(artifact_dir, tmp, artifact_dir / "current",
                artifact_dir / "current.COMMITTED")
    _STATS["saves"] += 1
    return artifact_dir / "current"


def _write_fsync(path: Path, data: bytes) -> None:
    with open(path, "wb") as f:
        f.write(data)
        f.flush()
        os.fsync(f.fileno())


def load_artifact(artifact_dir, meta: dict) -> Optional[tuple]:
    """``(ProgramCompileResult, aot_payloads)`` when a committed artifact
    matches ``meta`` (from :func:`artifact_meta`) on this runtime, else
    None with the reject reason counted in :func:`artifact_stats`:

    * ``fingerprint`` — jax/jaxlib/platform/device skew (rolling upgrade)
    * ``identity``    — different program/opt_level/vlen/budget/hot spec
    * ``format``      — on-disk layout generation changed
    * ``torn``        — crash mid-publish (or a racing saver); the commit
      marker and directory disagree
    * ``unpickle``    — compile payload does not deserialize here
    """
    d = Path(artifact_dir) / "current"
    marker = Path(artifact_dir) / "current.COMMITTED"
    if not marker.exists():
        return None                       # no artifact yet: not a reject
    try:
        raw = json.loads((d / "meta.json").read_text())
    except (OSError, json.JSONDecodeError):
        _reject("torn")
        return None
    if raw.get("format") != FORMAT_VERSION:
        _reject("format")
        return None
    if raw.get("fingerprint") != runtime_fingerprint():
        _reject("fingerprint")
        return None
    if raw.get("identity") != _jsonable(meta["identity"]):
        _reject("identity")
        return None
    try:
        compiled = pickle.loads((d / "compile.pkl").read_bytes())
        payloads = pickle.loads((d / "aot.pkl").read_bytes())
    except OSError:
        _reject("torn")
        return None
    except Exception:   # noqa: BLE001 — version-skewed pickle, bad bytes
        _reject("unpickle")
        return None
    if not isinstance(compiled, ProgramCompileResult):
        _reject("unpickle")
        return None
    _STATS["loads"] += 1
    return compiled, dict(payloads)
