"""DAE machine-balance cost model (paper §3, §8.1 — Figs 6, 7, 16, 17).

We cannot run gem5+McPAT here, so the paper's *hardware* results are
reproduced with a first-principles queue-balance model of the abstract DAE
machine (Fig 9): the achieved throughput of an embedding operation is the
minimum of

  * the **execute-unit** rate at which tokens/operands can be popped and
    computed,
  * the **access-unit** rate at which the traversal engine can generate
    addresses and marshal operands into the queues, and
  * the **memory** rate allowed by outstanding-request capacity
    (Little's law: requests/s = outstanding / effective latency, with the
    effective latency set by the reuse-distance hit probability).

Cycle-level constants are derived from the paper's structure and calibrated
once against its published ratios (Fig 16: emb-opt3/emb-opt0 = 6.6× / 12.1×
/ 21× for RM1/RM2/RM3; vectorization ≈ 5.13× with 17% deviation; Fig 6:
TMU ≈ 5.7× requests/s of a core; Fig 7 geomean 5.8×).  The *model shape* is
what matters: per-element token+pop costs at O0, per-chunk at O1,
per-lookup at O2/O3, with the access-side per-lookup traversal overhead
(index load + token push) as the O3 floor.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from .ops import EmbeddingOp

# SVE-512 f32 vector length used throughout the paper's evaluation.
VLEN = 16


@dataclasses.dataclass(frozen=True)
class Machine:
    """Cycle/structure constants of the DAE processor under study (§3.1)."""
    freq_ghz: float = 2.0
    # execute unit (superscalar SIMD core), cycles amortized per unit of work
    c_elem_scalar: float = 1.0    # O0: pipelined pop+pop+pop+fma per element
    c_chunk_vector: float = 3.12  # O1: token + 2 scalar pops + vpop + vfma
    c_row_token: float = 1.6      # O2: per-row token pop + row-id pop
    c_chunk_buffered: float = 0.7 # O2/O3: fused vpop+vfma per chunk (dual issue)
    # access unit (TMU dataflow traversal engine)
    h_lookup: float = 4.4         # per-lookup: idxs mem_str + loop_tr + token
    a_elem_scalar: float = 0.5    # O0 marshaling per element (3 pushes, pipelined)
    a_chunk: float = 0.21         # vectorized marshal per chunk
    # memory subsystem
    outstanding_tmu: int = 96     # TMU tracks ~8-10× the core's requests (§3.2)
    outstanding_core: int = 10
    lat_hbm_cycles: float = 180.0
    lat_cache_cycles: float = 16.0
    line_bytes: int = 64
    hbm_gbps: float = 450.0       # one HBM2 stack


DEFAULT = Machine()


def _chunks(emb_len: int) -> int:
    return -(-emb_len // VLEN)


def effective_latency(m: Machine, hit_rate: float) -> float:
    return hit_rate * m.lat_cache_cycles + (1 - hit_rate) * m.lat_hbm_cycles


def mem_cycles_per_lookup(op: EmbeddingOp, m: Machine, hit_rate: float,
                          outstanding: int) -> float:
    """Little's-law bound: cycles between completed row fetches per slot."""
    lines = max(1.0, op.emb_len * 4 / m.line_bytes)
    lam = effective_latency(m, hit_rate)
    return lines * lam / outstanding


def compute_cycles_per_lookup(op: EmbeddingOp, m: Machine, lvl: int) -> float:
    e = op.emb_len
    c = _chunks(e)
    flop_scale = max(1.0, op.compute_per_lookup)  # MP does 4 flops/element
    if not op.has_compute and lvl >= 3:
        return 0.0  # store streams: fully offloaded (§7.4)
    if lvl == 0:
        return e * m.c_elem_scalar * flop_scale
    if lvl == 1:
        return c * m.c_chunk_vector * flop_scale
    if lvl == 2:
        return m.c_row_token + c * m.c_chunk_buffered * flop_scale
    return 0.25 * m.c_row_token + c * m.c_chunk_buffered * flop_scale


def access_cycles_per_lookup(op: EmbeddingOp, m: Machine, lvl: int) -> float:
    e = op.emb_len
    c = _chunks(e)
    if lvl == 0:
        return m.h_lookup + e * m.a_elem_scalar
    if lvl == 1:
        return m.h_lookup + c * (m.a_chunk + 2 * m.a_elem_scalar / VLEN)
    # O2 still marshals the row id scalar; O3 drops it (queue alignment)
    extra = m.a_elem_scalar if lvl == 2 else 0.0
    return m.h_lookup + extra + c * m.a_chunk


def lookup_cycles(op: EmbeddingOp, lvl: int, hit_rate: float = 0.0,
                  m: Machine = DEFAULT, decoupled: bool = True) -> dict:
    """All three balance terms (cycles/lookup) + the binding bottleneck."""
    outstanding = m.outstanding_tmu if decoupled else m.outstanding_core
    comp = compute_cycles_per_lookup(op, m, lvl)
    acc = access_cycles_per_lookup(op, m, lvl)
    mem = mem_cycles_per_lookup(op, m, hit_rate, outstanding)
    if not decoupled:
        # traditional core: access + compute share one pipeline, and the
        # loop cannot run ahead — costs add instead of overlapping
        coupled = comp + acc
        total = max(coupled, mem)
        which = "core" if coupled >= mem else "memory"
        return {"compute": comp, "access": acc, "memory": mem,
                "total": total, "bottleneck": which}
    total = max(comp, acc, mem)
    which = ("compute" if total == comp else
             "access" if total == acc else "memory")
    return {"compute": comp, "access": acc, "memory": mem,
            "total": total, "bottleneck": which}


def throughput_eps(op: EmbeddingOp, lvl: int, hit_rate: float = 0.0,
                   m: Machine = DEFAULT, decoupled: bool = True) -> float:
    """Elements marshaled+computed per second."""
    t = lookup_cycles(op, lvl, hit_rate, m, decoupled)["total"]
    if t == 0.0:
        # fully offloaded store-stream path: memory-rate bound
        t = mem_cycles_per_lookup(op, m, hit_rate, m.outstanding_tmu)
    return op.emb_len * m.freq_ghz * 1e9 / t


def speedup_over_opt0(op: EmbeddingOp, lvl: int, hit_rate: float = 0.0,
                      m: Machine = DEFAULT) -> float:
    """Fig 16: emb-optN over emb-opt0."""
    return (throughput_eps(op, lvl, hit_rate, m) /
            throughput_eps(op, 0, hit_rate, m))


def dae_speedup_over_core(op: EmbeddingOp, hit_rate: float = 0.0,
                          m: Machine = DEFAULT) -> float:
    """Fig 7: optimized DAE code vs an optimized traditional core.

    The traditional-core baseline is the *fused, vectorized* loop (it has no
    queues to pay for), but it is limited by the core's outstanding-request
    capacity and cannot decouple traversal from compute.
    """
    core = throughput_eps(op, 1, hit_rate, m, decoupled=False)
    dae = throughput_eps(op, 3, hit_rate, m, decoupled=True)
    return dae / core


def requests_per_second(m: Machine = DEFAULT, decoupled: bool = True,
                        hit_rate: float = 0.0) -> float:
    """Fig 6a: sustainable memory requests/s of TMU vs core."""
    outstanding = m.outstanding_tmu if decoupled else m.outstanding_core
    lam = effective_latency(m, hit_rate)
    return outstanding / lam * m.freq_ghz * 1e9


# ---------------------------------------------------------------------------
# Batched-plan resource model (fusion partitioning, PR 2)
#
# A fused multi-table unit compiles to ONE batched KernelPlan whose on-chip
# working set grows with the group: the double-buffered row tiles and the
# output tile are fixed, but the scalar-prefetched access-stream operands
# (ptrs, idxs, roff, vals) are resident for the whole launch.  The fusion
# partitioner uses these estimates to fuse only groups that fit the budget
# and to split giant groups into sub-units balanced on *access* cycles (the
# serial resource of the DAE machine — the execute unit drains whatever the
# access stream feeds it, so skewed sub-units idle the narrow side).
# ---------------------------------------------------------------------------

#: Default on-chip budget for one batched plan's working set (row-tile
#: double buffers + output tile + scalar-prefetch operand arrays).  TPU
#: cores have ~16 MiB of VMEM; one fused unit may claim at most a quarter so
#: the rest of the step (attention, MLP tiles) still fits.
VMEM_BUDGET_BYTES = 4 * 2 ** 20


@dataclasses.dataclass(frozen=True)
class FusionBudget:
    """Resource envelope the fusion partitioner must respect."""

    vmem_bytes: int = VMEM_BUDGET_BYTES
    num_buffers: int = 2          # DMA pipeline depth (KernelPlan default)
    #: target ceiling on access/execute cycle skew of one fused plan; groups
    #: above it are still legal (skew is reported, not enforced) — balance
    #: is what the partitioner optimizes when it has to split anyway.
    balance_target: float = 8.0
    #: vocab-shard count of the executor that will run the plan.  The budget
    #: is PER SHARD: sharding divides the per-device index/vals streams (and
    #: the stacked-table footprint) by S, so the partitioner splits far
    #: fewer groups.  Part of the compile-cache key via this dataclass.
    shards: int = 1
    #: per-table byte budget for the replicated hot slab of locality-aware
    #: hot/cold sharding (see :mod:`repro.core.access_plan`): the classified
    #: Zipf head of each vocab may replicate up to this many bytes per shard
    #: — lookups it absorbs pay zero exchange.  0 disables classification
    #: (:func:`~repro.core.access_plan.hot_rows_from_traces` sizes heads
    #: against it).  Part of the compile-cache key via this dataclass.
    hot_slab_bytes: int = 0


def lane_tile(emb_len: int, vlen: int) -> int:
    """THE column-tile choice of a KernelPlan (backend_pallas.make_plan
    calls this too — one definition, so the partitioner's VMEM audit can
    never drift from what the backend actually tiles)."""
    def up(x, m):
        return -(-x // m) * m
    return min(up(max(vlen, 128), 128), up(emb_len, 128))


def plan_tile_bytes(op: EmbeddingOp, vlen: int = 128,
                    num_buffers: int = 2) -> int:
    """Fixed VMEM of one batched plan: in-flight row tiles + output tile."""
    itemsize = 4  # f32 tiles (lower precision still DMA-pads to lanes)
    tile = lane_tile(op.emb_len, vlen)
    rows = op.block_rows if op.kind == "gather" else 1
    return (num_buffers + 1) * rows * tile * itemsize


def operand_bytes(op: EmbeddingOp, force_vals: bool = False,
                  shards: int = 1) -> int:
    """Scalar-prefetch (access stream) footprint of one member op: the CSR
    ``ptrs``, the expected ``idxs``/``vals`` nnz, and its ``roff`` slot.

    ``force_vals``: a mixed weighted/unweighted group unit-weight-upcasts,
    so EVERY member marshals a vals word per lookup — the group-level
    estimators pass ``group_needs_vals`` here so the audit counts what the
    fused plan actually prefetches.

    ``shards``: vocab-sharded execution re-emits the CSR per shard, so each
    shard still prefetches the full ``ptrs``/``roff`` control streams but
    only its ~1/S slice of the index/vals streams.
    """
    lookups = -(-expected_lookups(op) // max(shards, 1))
    words = op.num_segments + 1          # ptrs (kg: the degenerate arange)
    words += lookups                     # idxs
    words += op.num_segments             # roff entry per segment
    if force_vals or op.weighted or op.kind in ("spmm", "kg"):
        words += lookups                 # vals
    return words * 4


def group_needs_vals(ops) -> bool:
    """Does a fused group of ``ops`` marshal a vals stream (and hence
    unit-weight-upcast its unweighted members)?  Mirrors _build_group."""
    return any(op.weighted or op.kind in ("spmm", "kg") for op in ops)


def expected_lookups(op: EmbeddingOp) -> int:
    """Expected access-stream length (kg is one lookup per segment)."""
    if op.kind == "kg":
        return op.num_segments
    if op.kind == "gather":
        return op.num_segments
    return op.num_segments * max(op.avg_lookups, 1)


def access_weight(op: EmbeddingOp, lvl: int = 3, m: Machine = DEFAULT) -> float:
    """Total access-unit cycles this op contributes to a fused plan's
    (serial) traversal stream — the partitioner's balance weight."""
    return expected_lookups(op) * access_cycles_per_lookup(op, m, lvl)


def execute_weight(op: EmbeddingOp, lvl: int = 3, m: Machine = DEFAULT) -> float:
    return expected_lookups(op) * compute_cycles_per_lookup(op, m, lvl)


def table_bytes(op: EmbeddingOp, shards: int = 1) -> int:
    """Stacked-table rows this member contributes per shard (ceil-split of
    its vocab over ``shards`` — the layout of :mod:`repro.core.shard_plan`).
    Shared-table dedup happens at stack time; this is the audit's upper
    bound, consistent with :func:`operand_bytes`."""
    rows = -(-op.num_embeddings // max(shards, 1))
    blk = op.block_rows if op.kind == "gather" else 1
    return rows * blk * op.emb_len * np.dtype(op.dtype).itemsize


def exchange_bytes(ops, shards: int = 1,
                   hot_traffic_fraction: float = 0.0,
                   replicate_outputs: bool = True,
                   collective: bool = False) -> dict:
    """Per-step exchange-volume estimate of running ``ops`` as one fused
    unit vocab-sharded over ``shards``: indices out (each lookup's index —
    and its vals word in an upcast group — lands on its owning shard;
    (S-1)/S of them are remote, the collective link model: diagonal traffic
    of the all_to_all send lattice never crosses a link) and pooled rows
    back.  With ``replicate_outputs`` the (B, E) partial pools all-reduce
    (each shard ships its partials S-1 hops); reduce-scattered outputs
    (``replicate_outputs=False``) ship only (S-1)/S of the
    segment-padded pools — the replicated volume ÷ S, plus the padding
    rows of the scatter grid.

    ``hot_traffic_fraction`` is the share of lookups the replicated hot
    slab absorbs (hot rows are local on every shard — zero index exchange);
    ``index_savings_bytes`` reports what the classification saved vs. the
    all-interleaved layout.  ``collective`` adds the fused-segment-id word
    every lookup of the all_to_all send lattice carries (the receiver
    rebuilds its sub-CSR from it), matching the executor's wire counter."""
    ops = list(ops)
    if shards <= 1:
        return {"index_bytes": 0, "row_bytes": 0, "total_bytes": 0,
                "index_savings_bytes": 0}
    h = min(max(float(hot_traffic_fraction), 0.0), 1.0)
    lookups = sum(expected_lookups(op) for op in ops)
    words = 2 if group_needs_vals(ops) else 1
    if collective:
        words += 1                       # the per-lookup segment id
    idx_all = int(lookups * words * 4 * (shards - 1) / shards)
    idx = int(idx_all * (1.0 - h))

    def out_width(op):                   # bytes per output segment row
        blk = op.block_rows if op.kind == "gather" else 1
        return blk * op.emb_len * 4

    if replicate_outputs:
        rows = sum(op.num_segments * out_width(op) for op in ops) \
            * (shards - 1)
    else:
        segs = sum(op.num_segments for op in ops)
        pad = -(-segs // shards) * shards - segs
        # per-op widths summed like the replicate branch (a fused group is
        # width-homogeneous, but the helper is public); pad rows take the
        # group width of ops[0]
        rows = (sum(op.num_segments * out_width(op) for op in ops)
                + pad * out_width(ops[0])) * (shards - 1) // shards
    return {"index_bytes": idx, "row_bytes": rows,
            "total_bytes": idx + rows,
            "index_savings_bytes": idx_all - idx}


def fused_plan_resources(ops, vlen: int = 128, lvl: int = 3,
                         num_buffers: int = 2,
                         m: Machine = DEFAULT, shards: int = 1,
                         hot_rows_total: int = 0,
                         hot_traffic_fraction: float = 0.0,
                         replicate_outputs: bool = True,
                         collective: bool = False) -> dict:
    """Resource estimate of compiling ``ops`` as ONE batched KernelPlan.

    Returns vmem_bytes (tiles + scalar operands — PER SHARD when
    ``shards`` > 1, which is what the partitioner budgets), the split of
    that total, the stacked-table footprint (total and per shard — the
    per-shard figure includes the replicated hot slab of
    ``hot_rows_total`` classified rows — an int COUNT, not the
    ``{name: ids}`` mapping the compile entry points take), the per-step
    exchange volume of the sharded path
    with the savings the hot slab buys (``hot_traffic_fraction`` of the
    index stream stays local), total access/execute cycles of the batched
    stream, and their skew (``queue_balance`` ≥ 1; 1.0 = perfectly
    balanced DAE queues).
    """
    ops = list(ops)
    assert ops, "empty fusion candidate"
    tiles = max(plan_tile_bytes(op, vlen, num_buffers) for op in ops)
    upcast = group_needs_vals(ops)
    operands = sum(operand_bytes(op, force_vals=upcast, shards=shards)
                   for op in ops)
    acc = sum(access_weight(op, lvl, m) for op in ops)
    exe = sum(execute_weight(op, lvl, m) for op in ops)
    hi, lo = max(acc, exe), min(acc, exe)
    # the replicated hot slab every shard carries (0 rows when disabled);
    # compatibility already guarantees a homogeneous (emb_len, blk, dtype)
    op0 = ops[0]
    blk = op0.block_rows if op0.kind == "gather" else 1
    hot_slab = (int(hot_rows_total) * blk * op0.emb_len
                * np.dtype(op0.dtype).itemsize if shards > 1 else 0)
    exch = exchange_bytes(ops, shards, hot_traffic_fraction,
                          replicate_outputs=replicate_outputs,
                          collective=collective)
    return {
        "vmem_bytes": tiles + operands,
        "tile_bytes": tiles,
        "operand_bytes": operands,
        "table_bytes": sum(table_bytes(op) for op in ops),
        "table_bytes_per_shard":
            sum(table_bytes(op, shards) for op in ops) + hot_slab,
        "hot_slab_bytes": hot_slab,
        "exchange_bytes": exch["total_bytes"],
        "exchange_savings_bytes": exch["index_savings_bytes"],
        "shards": shards,
        "access_cycles": acc,
        "execute_cycles": exe,
        "queue_balance": (hi / lo) if lo > 0 else math.inf,
    }


def fits_budget(ops, vlen: int = 128,
                budget: FusionBudget = FusionBudget()) -> bool:
    """May ``ops`` legally compile as one fused unit under ``budget``?"""
    res = fused_plan_resources(ops, vlen, num_buffers=budget.num_buffers,
                               shards=budget.shards)
    return res["vmem_bytes"] <= budget.vmem_bytes


def queue_plane_point(op: EmbeddingOp, lvl: int, hit_rate: float = 0.0,
                      m: Machine = DEFAULT) -> tuple:
    """Fig 17: (access-unit queue-write rate, execute-unit queue-read rate),
    normalized to emb-opt0, for the ablation plane plot."""
    def rates(level):
        acc = access_cycles_per_lookup(op, m, level)
        acc = max(acc, mem_cycles_per_lookup(op, m, hit_rate,
                                             m.outstanding_tmu))
        comp = compute_cycles_per_lookup(op, m, level)
        return (op.emb_len / acc if acc else math.inf,
                op.emb_len / comp if comp else math.inf)
    a0, c0 = rates(0)
    a, c = rates(lvl)
    return a / a0, c / c0
