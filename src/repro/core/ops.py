"""Frontend embedding-operation specifications.

These are the operations the Ember paper characterizes (Table 1) and
compiles: the PyTorch ``nn.EmbeddingBag`` / Caffe2 SLS family, knowledge-graph
semiring lookups, block-sparse-attention gathers, GNN SpMM aggregation, and
message-passing FusedMM (SDDMM+SpMM).  An :class:`EmbeddingOp` is what a
framework frontend (torch-mlir / MPACT in the paper; our model zoo here)
hands to the compiler pipeline in :mod:`repro.core.pipeline`.

Every op kind carries a pure-numpy reference semantics
(:func:`reference`) that all IR interpreters and backends are tested
against.
"""
from __future__ import annotations

import dataclasses
from typing import Literal, Optional

import numpy as np

OpKind = Literal["sls", "kg", "gather", "spmm", "fusedmm"]

# Semiring (⊕, ⊗) pairs used by KG models (paper §4): ⊕ reduces embedding
# vectors, ⊗ combines a vector element with the edge/relation value.
ADD_OPS = {"add", "max", "min"}
MUL_OPS = {"mul", "add"}

ADD_IDENTITY = {"add": 0.0, "max": -np.inf, "min": np.inf}


@dataclasses.dataclass(frozen=True)
class Semiring:
    add: str = "add"
    mul: str = "mul"

    def __post_init__(self):
        assert self.add in ADD_OPS, self.add
        assert self.mul in MUL_OPS, self.mul

    @property
    def identity(self) -> float:
        return ADD_IDENTITY[self.add]

    def np_add(self, a, b):
        return {"add": np.add, "max": np.maximum, "min": np.minimum}[self.add](a, b)

    def np_mul(self, a, b):
        return {"mul": np.multiply, "add": np.add}[self.mul](a, b)


@dataclasses.dataclass(frozen=True)
class EmbeddingOp:
    """A characterized embedding operation (paper Table 1).

    kind == 'sls':     out[b, e] ⊕= vals[p] ⊗ table[idxs[p], e]
                       for p in ptrs[b] .. ptrs[b+1]          (CSR segments)
    kind == 'kg':      out[b, e] ⊕= vals[b] ⊗ table[idxs[b], e]
                       (one nonzero per row: no ptrs)
    kind == 'gather':  out[g, r, e] = table[idxs[g] * block_rows + r, e]
                       (block-sparse attention gather: replication, no compute)
    kind == 'spmm':    identical loop nest to 'sls' (A in CSR, B dense row-major)
    kind == 'fusedmm': SDDMM fused with SpMM (message passing):
                       s = f(Σ_e x[i,e] * x[idxs[p],e]);  out[i,e] += s * x[idxs[p],e]
    """

    kind: OpKind
    num_segments: int          # batch rows (b) / output rows (i) / query slots (g)
    num_embeddings: int        # embedding-table rows (before blocking for 'gather')
    emb_len: int               # elements per embedding vector
    avg_lookups: int = 8       # average nnz per segment (CSR kinds)
    block_rows: int = 1        # rows per block ('gather' only)
    weighted: bool = False     # per-lookup scaling values (GNN edge weights)
    semiring: Semiring = Semiring()
    dtype: str = "float32"
    # CSR variants: "offsets" (ptrs array) or "lengths" (per-segment counts;
    # lowered with an access-unit accumulation stream, paper §7.4)
    index_format: str = "offsets"
    # >1 marks a *fused* multi-table op (produced by the program-level fusion
    # pass): the table memref is the row-stacked concatenation of the member
    # tables and an extra read-only per-segment base array ``roff`` carries
    # the table-offset stream (row units; block units for 'gather').
    num_tables: int = 1

    # ---- structural properties used by characterization + cost model ----
    @property
    def has_compute(self) -> bool:
        return self.kind != "gather"

    @property
    def compute_per_lookup(self) -> float:
        """FLOPs of execute-unit work per looked-up element (Table 1 col 3)."""
        if self.kind == "gather":
            return 0.0
        if self.kind == "fusedmm":
            return 4.0  # sddmm mul+add then spmm mul+add
        if self.weighted:
            return 2.0
        return 1.0

    @property
    def uses_csr(self) -> bool:
        return self.kind in ("sls", "spmm", "fusedmm")

    def footprint_bytes(self) -> int:
        itemsize = np.dtype(self.dtype).itemsize
        rows = self.num_embeddings * (self.block_rows if self.kind == "gather" else 1)
        return rows * self.emb_len * itemsize


# ---------------------------------------------------------------------------
# Random instance generation (inputs for interpreters/tests/benchmarks)
# ---------------------------------------------------------------------------

def make_inputs(op: EmbeddingOp, seed: int = 0, alpha: Optional[float] = None) -> dict:
    """Generate a concrete input set for ``op``.

    ``alpha`` controls temporal locality: indices are drawn from a Zipf-like
    power-law over table rows (alpha=None → uniform).  This mirrors the
    paper's L0/L1/L2 locality sweeps (§8.1) and the Criteo CDFs (Table 1).
    """
    rng = np.random.default_rng(seed)
    dt = np.dtype(op.dtype)

    def draw(n):
        if not n:
            return np.zeros((0,), np.int32)
        if alpha is None:
            return rng.integers(0, op.num_embeddings, size=n).astype(np.int32)
        # power-law rank distribution over a random permutation of rows
        ranks = np.arange(1, op.num_embeddings + 1, dtype=np.float64)
        p = ranks ** (-alpha)
        p /= p.sum()
        perm = rng.permutation(op.num_embeddings)
        return perm[rng.choice(op.num_embeddings, size=n, p=p)].astype(np.int32)

    inputs: dict = {}
    if op.kind == "gather":
        table = rng.standard_normal(
            (op.num_embeddings * op.block_rows, op.emb_len)).astype(dt)
        inputs["table"] = table
        inputs["idxs"] = draw(op.num_segments)
        return inputs

    table_name = "x" if op.kind == "fusedmm" else "table"
    n_rows = op.num_segments if op.kind == "fusedmm" else op.num_embeddings
    if op.kind == "fusedmm":
        # x is both the dense operand and the output's source: square-ish graph
        n_rows = max(op.num_embeddings, op.num_segments)
    inputs[table_name] = rng.standard_normal((n_rows, op.emb_len)).astype(dt)

    if op.uses_csr:
        lens = rng.poisson(op.avg_lookups, size=op.num_segments).clip(0, None)
        ptrs = np.zeros(op.num_segments + 1, np.int32)
        np.cumsum(lens, out=ptrs[1:])
        nnz = int(ptrs[-1])
        if op.index_format == "lengths":
            inputs["lens"] = lens.astype(np.int32)
        else:
            inputs["ptrs"] = ptrs
        inputs["idxs"] = np.minimum(draw(nnz), n_rows - 1)
        if op.weighted or op.kind == "spmm":
            inputs["vals"] = rng.standard_normal((nnz,)).astype(dt)
    else:  # kg
        inputs["idxs"] = draw(op.num_segments)
        inputs["vals"] = rng.standard_normal((op.num_segments,)).astype(dt)
    return inputs


def out_shape(op: EmbeddingOp) -> tuple:
    if op.kind == "gather":
        return (op.num_segments, op.block_rows, op.emb_len)
    return (op.num_segments, op.emb_len)


# ---------------------------------------------------------------------------
# Pure numpy reference semantics (the ground-truth oracle)
# ---------------------------------------------------------------------------

def reference(op: EmbeddingOp, inputs: dict) -> np.ndarray:
    sr = op.semiring
    dt = np.dtype(op.dtype)

    if op.kind == "gather":
        idxs = inputs["idxs"]
        if "roff" in inputs:          # fused multi-table: per-segment base
            idxs = idxs + inputs["roff"]
        table = inputs["table"]
        rows = (idxs[:, None] * op.block_rows + np.arange(op.block_rows)[None, :])
        return table[rows]  # (g, r, e)

    if op.kind == "kg":
        table, idxs, vals = inputs["table"], inputs["idxs"], inputs["vals"]
        out = np.full((op.num_segments, op.emb_len), sr.identity, dt)
        contrib = sr.np_mul(table[idxs], vals[:, None])
        return sr.np_add(out, contrib).astype(dt)

    if op.index_format == "lengths" and "ptrs" not in inputs:
        ptrs = np.zeros(op.num_segments + 1, np.int64)
        np.cumsum(inputs["lens"], out=ptrs[1:])
    else:
        ptrs = inputs["ptrs"]
    idxs = inputs["idxs"]
    if op.kind == "fusedmm":
        x = inputs["x"]
        out = np.zeros((op.num_segments, op.emb_len), dt)
        for i in range(op.num_segments):
            for p in range(ptrs[i], ptrs[i + 1]):
                j = idxs[p]
                s = np.dot(x[i], x[j])          # SDDMM (execute-unit workspace)
                out[i] += s * x[j]              # SpMM accumulate
        return out

    table = inputs["table"]
    vals = inputs.get("vals")
    roff = inputs.get("roff")
    out = np.full((op.num_segments, op.emb_len), sr.identity, dt)
    for b in range(op.num_segments):
        base = int(roff[b]) if roff is not None else 0
        for p in range(ptrs[b], ptrs[b + 1]):
            v = table[idxs[p] + base]
            if vals is not None:
                v = sr.np_mul(v, vals[p])
            out[b] = sr.np_add(out[b], v)
    # empty segments produce the additive identity; SLS convention is 0
    if sr.add != "add":
        seg_lens = np.diff(ptrs)
        out[seg_lens == 0] = 0.0
    return out.astype(dt)


# ---------------------------------------------------------------------------
# Program-level frontend: an ordered set of named embedding operations
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class EmbeddingProgram:
    """All irregular lookups of one model step, compiled as a unit.

    A model step is never a single :class:`EmbeddingOp` — a DLRM step does
    one SLS per embedding table, an LM step does token embedding + the label
    gather of the vocab-parallel cross entropy + (for MoE) expert dispatch.
    Compiling them together lets the pass manager fuse compatible lookups
    into one DAE schedule (one access stream over stacked tables) and lets
    the runtime reuse the compiled artifact across steps via the compile
    cache (keyed on :meth:`signature`).

    ``ops``            ordered tuple of ``(name, EmbeddingOp)``;
    ``shared_tables``  tuples of op names whose table memref is the *same*
                       array (e.g. token embedding and the unembedding label
                       gather both read the embed table) — the fusion pass
                       stacks a shared table once.
    """

    name: str
    ops: tuple                       # of (name, EmbeddingOp)
    shared_tables: tuple = ()        # of tuple[str, ...]

    def __post_init__(self):
        names = [n for n, _ in self.ops]
        assert len(names) == len(set(names)), f"duplicate op names: {names}"
        known = set(names)
        for group in self.shared_tables:
            for n in group:
                assert n in known, f"shared_tables references unknown op {n!r}"

    def __len__(self) -> int:
        return len(self.ops)

    @property
    def names(self) -> tuple:
        return tuple(n for n, _ in self.ops)

    def op(self, name: str) -> EmbeddingOp:
        return dict(self.ops)[name]

    def signature(self) -> tuple:
        """Hashable structural identity — the compile-cache key component.

        Deliberately excludes ``name``: two programs with identical op
        structure compile to identical artifacts and must share a cache
        entry (e.g. every decode step of every server replica).
        """
        return (tuple(self.ops),
                tuple(tuple(g) for g in self.shared_tables))

    def table_slot(self, name: str):
        """Canonical table identity for ``name`` (shared group or self)."""
        for group in self.shared_tables:
            if name in group:
                return ("shared",) + tuple(group)
        return ("own", name)


def single_op_program(op: EmbeddingOp, name: str = "op") -> EmbeddingProgram:
    return EmbeddingProgram(name, ((name, op),))


def make_program_inputs(prog: EmbeddingProgram, seed: int = 0,
                        alpha: Optional[float] = None) -> dict:
    """Per-op concrete inputs; ops in a shared-table group get the *same*
    table array (shape-checked), mirroring a real model's aliased tables."""
    inputs: dict = {}
    shared_cache: dict = {}
    for i, (name, op) in enumerate(prog.ops):
        ins = make_inputs(op, seed=seed + i, alpha=alpha)
        slot = prog.table_slot(name)
        tbl_key = "x" if op.kind == "fusedmm" else "table"
        if slot[0] == "shared":
            if slot in shared_cache:
                prev = shared_cache[slot]
                assert prev.shape == ins[tbl_key].shape, \
                    f"shared tables of {slot} disagree in shape"
                ins[tbl_key] = prev
            else:
                shared_cache[slot] = ins[tbl_key]
        inputs[name] = ins
    return inputs


def program_reference(prog: EmbeddingProgram, inputs: dict) -> dict:
    """Composed numpy oracle: per-op reference outputs, keyed by op name."""
    return {name: reference(op, inputs[name]) for name, op in prog.ops}
