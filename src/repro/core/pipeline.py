"""emberc — the end-to-end Ember compiler driver (paper §5, Fig 11).

Program-level flow (one invocation compiles ALL of a model step's lookups):

    EmbeddingProgram {name_i: EmbeddingOp_i}
        ──[fuse]──▶ units = fused multi-table ops + singletons   (program)
    then per unit, under the PassManager (stage, ✓ = verifier between passes):
        EmbeddingOp ──build-scf──▶ SCF ✓ ──decouple──▶ SLC ✓
            ──[vectorize]──▶ SLCV ✓ ──[bufferize]──▶ ✓
            ──[store-streams]──▶ ✓ ──[queue-align]──▶ ✓
            ──lower-dlc──▶ DLC ✓
        ──codegen──▶ {queue-faithful interpreter | jnp baseline | Pallas plan}

    compile cache: (program.signature(), opt_level, vlen) ──▶ ProgramCompileResult
        (a hit returns the cached artifact; NO pass re-runs — observable via
         PassManager.total_executed and the per-pass PassRecord diagnostics)

Opt levels mirror the paper's ablation (Table 4) and are ordered
numerically (``O<n>``; OPT_LEVELS is the source of truth):

    O0  emb-opt0   unoptimized decoupled code
    O1  emb-opt1   + vectorization           (§7.1)
    O2  emb-opt2   + bufferization           (§7.2)
    O3  emb-opt3   + queue alignment and model-specific store
                     streams where applicable (§7.3, §7.4)

Single-op entry points (``compile_op``/``run_interpreted``) remain as thin
wrappers over a one-op program.
"""
from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict
from typing import Optional, Union

from .access_plan import AccessPlan, canonical_hot
from .cost_model import FusionBudget
from .dlc import DlcProgram
from .ops import EmbeddingOp, EmbeddingProgram, single_op_program
from .pass_manager import PassManager, PassRecord
from .passes import FusedGroup, fuse_inputs, fuse_program, split_outputs
from .scf import ScfFunc
from .slc import SlcFunc

OPT_LEVELS = ("O0", "O1", "O2", "O3")


def opt_level_index(opt_level: Union[str, int]) -> int:
    """Parse ``"O<n>"`` to its numeric level (the only sanctioned way to
    compare opt levels — lexical comparison breaks past O9)."""
    if isinstance(opt_level, int):
        assert 0 <= opt_level < len(OPT_LEVELS), opt_level
        return opt_level
    assert opt_level in OPT_LEVELS, opt_level
    return OPT_LEVELS.index(opt_level)


@dataclasses.dataclass
class CompileResult:
    op: EmbeddingOp
    opt_level: str
    scf: ScfFunc
    slc: SlcFunc
    dlc: DlcProgram
    records: list = dataclasses.field(default_factory=list)  # PassRecords
    #: the host-side access artifact of this unit (plan-access pass): stream
    #: layout, capacity-bucket lattice, shard routing + hot/cold split
    access_plan: Optional[AccessPlan] = None

    @property
    def opt(self) -> dict:
        return self.slc.opt

    @property
    def opt_level_idx(self) -> int:
        return opt_level_index(self.opt_level)


@dataclasses.dataclass
class CompiledUnit:
    """One compiled unit of a program: a singleton op or a fused group."""

    names: tuple                     # member op names (len 1 if unfused)
    result: CompileResult
    group: Optional[FusedGroup] = None

    @property
    def fused(self) -> bool:
        return self.group is not None


@dataclasses.dataclass
class ProgramCompileResult:
    program: EmbeddingProgram
    opt_level: str
    vlen: int
    units: list                      # of CompiledUnit
    records: list                    # program-level PassRecords
    cache_hit: bool = False

    @property
    def fused_units(self) -> list:
        return [u for u in self.units if u.fused]

    def unit_of(self, name: str) -> CompiledUnit:
        for u in self.units:
            if name in u.names:
                return u
        raise KeyError(name)

    def pass_records(self) -> list:
        """All diagnostics: program-level + every unit's pass records."""
        out = list(self.records)
        for u in self.units:
            out.extend(u.result.records)
        return out


# ---------------------------------------------------------------------------
# Compilation
# ---------------------------------------------------------------------------

_DEFAULT_PM = PassManager()

class BoundedLru:
    """OrderedDict-backed LRU with hit/miss/eviction counters — the shape of
    every steady-state cache here (compile artifacts, executors): long-lived
    servers see a new key per signature they ever compile; without a bound,
    a shape-diverse workload grows the cache (and what it pins) forever."""

    def __init__(self, limit: int):
        assert limit >= 1, limit
        self._entries: "OrderedDict" = OrderedDict()
        self.limit = limit
        self.hits = self.misses = self.evictions = 0

    def get(self, key):
        v = self._entries.get(key)
        if v is None:
            self.misses += 1
            return None
        self.hits += 1
        self._entries.move_to_end(key)
        return v

    def put(self, key, value) -> None:
        self._entries[key] = value
        self._entries.move_to_end(key)   # re-insert refreshes recency
        self._trim()

    def set_limit(self, limit: int) -> int:
        """Set capacity (entries); returns the previous limit.  Shrinking
        evicts least-recently-used entries immediately."""
        assert limit >= 1, limit
        prev, self.limit = self.limit, limit
        self._trim()
        return prev

    def _trim(self) -> None:
        while len(self._entries) > self.limit:
            self._entries.popitem(last=False)
            self.evictions += 1

    def clear(self) -> None:
        self._entries.clear()
        self.hits = self.misses = self.evictions = 0

    def keys(self) -> list:
        return list(self._entries.keys())

    def stats(self) -> dict:
        return {"hits": self.hits, "misses": self.misses,
                "evictions": self.evictions, "entries": len(self._entries),
                "capacity": self.limit}

    def __len__(self) -> int:
        return len(self._entries)


def entries_by_shards(cache: "BoundedLru") -> dict:
    """Resident-entry histogram keyed by vocab-shard count.

    Both steady-state caches key on a :class:`~repro.core.cost_model.FusionBudget`
    (which carries ``shards``), so a shard-count change that silently forks
    cache entries — the classic sharded cache-key regression — shows up here
    (and in ``benchmarks/run.py``'s stats printout)."""
    by: dict = {}
    for key in cache.keys():
        shards = 1
        for part in (key if isinstance(key, tuple) else (key,)):
            s = getattr(part, "shards", None)
            if isinstance(s, int):
                shards = s
                break
        by[shards] = by.get(shards, 0) + 1
    return by


# compile cache: (program signature, opt_level, vlen, …) -> ProgramCompileResult
DEFAULT_COMPILE_CACHE_LIMIT = 64

_COMPILE_CACHE = BoundedLru(DEFAULT_COMPILE_CACHE_LIMIT)


def set_compile_cache_limit(limit: int) -> int:
    return _COMPILE_CACHE.set_limit(limit)


def compile_cache_stats() -> dict:
    s = _COMPILE_CACHE.stats()
    total = s["hits"] + s["misses"]
    s["hit_rate"] = s["hits"] / total if total else 0.0
    s["entries_by_shards"] = entries_by_shards(_COMPILE_CACHE)
    return s


def clear_compile_cache() -> None:
    _COMPILE_CACHE.clear()


def compile_cache_key(program: EmbeddingProgram, opt_level: str,
                      vlen: int = 128, fuse: bool = True,
                      budget: Optional[FusionBudget] = None,
                      hot_rows=None) -> tuple:
    """The memoization key of :func:`compile_program` — also the compile
    half of the serving artifact's identity (:mod:`repro.core.artifact`)."""
    budget = budget or FusionBudget()
    return (program.signature(), opt_level, vlen, fuse, budget,
            canonical_hot(hot_rows))


def seed_compile_cache(key: tuple, result: ProgramCompileResult) -> None:
    """Hydrate the compile cache from a deserialized artifact: the next
    :func:`compile_program` with this key is a cache hit, not a re-run of
    the PassManager pipeline."""
    _COMPILE_CACHE.put(key, result)


def _compile_one(op: EmbeddingOp, opt_level: str, vlen: int,
                 pm: PassManager, group=None, shards: int = 1,
                 hot_rows=None) -> CompileResult:
    arts, records = pm.run(op, opt_level_index(opt_level), vlen=vlen,
                           group=group, shards=shards, hot_rows=hot_rows)
    return CompileResult(op, opt_level, arts["scf"], arts["slc"],
                         arts["dlc"], records,
                         access_plan=arts.get("access"))


def compile_program(program: EmbeddingProgram, opt_level: str = "O3",
                    vlen: int = 128, pm: Optional[PassManager] = None,
                    fuse: bool = True, use_cache: bool = True,
                    budget: Optional[FusionBudget] = None,
                    hot_rows=None) -> ProgramCompileResult:
    """Compile every lookup of a model step as one unit.

    The fusion pass first merges compatible multi-table lookups — under the
    ``budget`` resource envelope: a compatibility group whose batched plan
    would overflow the estimated VMEM working set is split into balanced
    sub-units (see ``passes/fuse.py``).  Each resulting unit then runs the
    full PassManager pipeline, whose final ``plan-access`` pass emits the
    unit's :class:`~repro.core.access_plan.AccessPlan` for
    ``budget.shards`` vocab shards and the ``hot_rows`` hot/cold
    classification (``{op name: replicated row ids}``, e.g. from
    :func:`~repro.core.access_plan.hot_rows_from_traces`).  Results are
    memoized (bounded LRU) on ``(program.signature(), opt_level, vlen,
    fuse, budget, hot_rows)`` so steady-state callers (decode servers,
    train steps) pay compilation once.
    """
    assert opt_level in OPT_LEVELS, opt_level
    budget = budget or FusionBudget()  # canonical: None = the default budget
    key = compile_cache_key(program, opt_level, vlen, fuse, budget, hot_rows)
    if use_cache and pm is None:
        cached = _COMPILE_CACHE.get(key)
        if cached is not None:
            return dataclasses.replace(cached, cache_hit=True)

    pm_ = pm or _DEFAULT_PM
    records: list = []
    if fuse:
        t0 = time.perf_counter()
        units_spec, note = fuse_program(program, vlen=vlen, budget=budget)
        records.append(PassRecord("fuse", "program", ran=True,
                                  duration_s=time.perf_counter() - t0,
                                  note=note))
    else:
        units_spec = [(n, op) for n, op in program.ops]
        records.append(PassRecord("fuse", "program", ran=False,
                                  note="disabled"))

    units: list = []
    for spec in units_spec:
        if isinstance(spec, FusedGroup):
            res = _compile_one(spec.op, opt_level, vlen, pm_, group=spec,
                               shards=budget.shards, hot_rows=hot_rows)
            units.append(CompiledUnit(spec.members, res, group=spec))
        else:
            name, op = spec
            # singleton units always execute unsharded (only fused stacked
            # tables vocab-partition), so their plan is the 1-shard plan
            res = _compile_one(op, opt_level, vlen, pm_, shards=1)
            units.append(CompiledUnit((name,), res))

    out = ProgramCompileResult(program, opt_level, vlen, units, records)
    if use_cache and pm is None:
        _COMPILE_CACHE.put(key, out)
    return out


def compile_op(op: EmbeddingOp, opt_level: str = "O3", vlen: int = 128,
               pm: Optional[PassManager] = None) -> CompileResult:
    """Compile a single embedding operation through the full IR stack."""
    assert opt_level in OPT_LEVELS, opt_level
    return _compile_one(op, opt_level, vlen, pm or _DEFAULT_PM)


# ---------------------------------------------------------------------------
# Reference execution
# ---------------------------------------------------------------------------

def run_interpreted(res: CompileResult, inputs: dict, stage: str = "dlc",
                    return_queues: bool = False):
    """Execute a compile result on the CPU reference interpreters."""
    from . import interp
    if stage == "scf":
        from .scf import interp_scf
        return interp_scf(res.scf, inputs)
    if stage == "slc":
        return interp.interp_slc(res.slc, inputs)
    if stage == "dlc":
        return interp.interp_dlc(res.dlc, inputs, return_queues=return_queues)
    raise ValueError(stage)


def run_program_interpreted(pres: ProgramCompileResult, inputs: dict,
                            stage: str = "dlc",
                            return_queues: bool = False):
    """Execute a compiled program; returns per-op outputs keyed by name.

    ``inputs`` maps op name -> that op's concrete inputs (see
    :func:`repro.core.ops.make_program_inputs`).  Fused units marshal their
    members' inputs into the stacked form, run once, and split the result.
    With ``return_queues`` also returns aggregated queue statistics (only
    meaningful for the queue-faithful DLC stage).
    """
    assert not return_queues or stage == "dlc", \
        "queue statistics only exist at the dlc stage"
    outs: dict = {}
    stats = {"data_pushed": 0, "tokens": 0, "data_left": 0, "ctrl_left": 0}

    def _run(res, ins):
        if return_queues and stage == "dlc":
            out, st = run_interpreted(res, ins, stage, return_queues=True)
            for k in stats:
                stats[k] += st[k]
            return out
        return run_interpreted(res, ins, stage)

    for unit in pres.units:
        if unit.group is None:
            outs[unit.names[0]] = _run(unit.result, inputs[unit.names[0]])
        else:
            fused_out = _run(unit.result, fuse_inputs(unit.group, inputs))
            outs.update(split_outputs(unit.group, fused_out))
    if return_queues:
        return outs, stats
    return outs
