"""emberc — the end-to-end Ember compiler driver (paper §5, Fig 11).

    EmbeddingOp ──build_scf──▶ SCF ──decouple──▶ SLC
        ──[vectorize]──▶ SLCV ──[bufferize]──▶ ──[store-streams]──▶
        ──[queue-align]──▶ optimized SLC ──lower──▶ DLC
        ──codegen──▶ {queue-faithful interpreter | jnp baseline | Pallas plan}

Opt levels mirror the paper's ablation (Table 4):

    O0  emb-opt0   unoptimized decoupled code
    O1  emb-opt1   + vectorization           (§7.1)
    O2  emb-opt2   + bufferization           (§7.2)
    O3  emb-opt3   + queue alignment and model-specific store
                     streams where applicable (§7.3, §7.4)
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from .ops import EmbeddingOp
from .scf import ScfFunc, build_scf
from .decouple import decouple
from .dlc import DlcProgram, lower_to_dlc
from .passes import apply_store_streams, bufferize, queue_align, vectorize
from .slc import SlcFunc

OPT_LEVELS = ("O0", "O1", "O2", "O3")


@dataclasses.dataclass
class CompileResult:
    op: EmbeddingOp
    opt_level: str
    scf: ScfFunc
    slc: SlcFunc
    dlc: DlcProgram

    @property
    def opt(self) -> dict:
        return self.slc.opt


def compile_op(op: EmbeddingOp, opt_level: str = "O3",
               vlen: int = 128) -> CompileResult:
    """Compile an embedding operation through the full IR stack."""
    assert opt_level in OPT_LEVELS, opt_level
    scf_fn = build_scf(op)
    slc_fn = decouple(scf_fn)
    if opt_level >= "O1":
        slc_fn = vectorize(slc_fn, vlen=vlen)
    if opt_level >= "O2":
        slc_fn = bufferize(slc_fn)
    if opt_level >= "O3":
        slc_fn = apply_store_streams(slc_fn)
        slc_fn = queue_align(slc_fn)
    dlc_prog = lower_to_dlc(slc_fn)
    return CompileResult(op, opt_level, scf_fn, slc_fn, dlc_prog)


def run_interpreted(res: CompileResult, inputs: dict, stage: str = "dlc",
                    return_queues: bool = False):
    """Execute a compile result on the CPU reference interpreters."""
    from . import interp
    if stage == "scf":
        from .scf import interp_scf
        return interp_scf(res.scf, inputs)
    if stage == "slc":
        return interp.interp_slc(res.slc, inputs)
    if stage == "dlc":
        return interp.interp_dlc(res.dlc, inputs, return_queues=return_queues)
    raise ValueError(stage)
