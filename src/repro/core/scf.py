"""Structured Control Flow (SCF) IR.

This is Ember's *input* IR (paper Fig 13a): the loop-nest form of an
embedding operation as it comes out of torch-mlir / MPACT.  We model it as a
small tree of dataclasses with executable semantics (:func:`interp_scf`),
which the SCF→SLC decoupling algorithm (:mod:`repro.core.decouple`) consumes.

Expressions are side-effect free; statements mutate scalar variables
(``Let``/``SetVar``) or memrefs (``Store``).  Loop bounds may be expressions
over parent-loop loads (e.g. ``ptrs[b]``) — exactly the pattern whose
offloadability the paper's decoupling legality rules reason about.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Union

import numpy as np

from .ops import EmbeddingOp, Semiring

# ----------------------------------------------------------------------------
# Expressions
# ----------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Const:
    value: Union[int, float]


@dataclasses.dataclass(frozen=True)
class Param:
    """A compile-time-known scalar (e.g. emb_len, num_segments)."""
    name: str


@dataclasses.dataclass(frozen=True)
class VarRef:
    name: str


@dataclasses.dataclass(frozen=True)
class Load:
    memref: str
    indices: tuple


@dataclasses.dataclass(frozen=True)
class Bin:
    op: str  # + - * / min max
    a: "Expr"
    b: "Expr"


@dataclasses.dataclass(frozen=True)
class Apply:
    """Unary scalar function (fusedmm's f(s)); kept abstract by name."""
    fn: str  # 'relu' | 'identity'
    a: "Expr"


Expr = Union[Const, Param, VarRef, Load, Bin, Apply]

# ----------------------------------------------------------------------------
# Statements
# ----------------------------------------------------------------------------


@dataclasses.dataclass
class Let:
    var: str
    value: Expr


@dataclasses.dataclass
class SetVar:
    var: str
    value: Expr


@dataclasses.dataclass
class Store:
    memref: str
    indices: tuple
    value: Expr
    accumulate: Optional[str] = None  # None = overwrite; else ⊕ op name


@dataclasses.dataclass
class For:
    var: str
    lb: Expr
    ub: Expr
    body: list


Stmt = Union[Let, SetVar, Store, For]


@dataclasses.dataclass
class MemRefDecl:
    name: str
    rank: int
    dtype: str
    read_only: bool


@dataclasses.dataclass
class ScfFunc:
    name: str
    memrefs: dict          # name -> MemRefDecl
    params: dict           # name -> int
    body: list             # list[Stmt]
    op: EmbeddingOp        # provenance


# ----------------------------------------------------------------------------
# Builders: EmbeddingOp -> SCF loop nest (paper Fig 10b / Table 1 col 2)
# ----------------------------------------------------------------------------

def build_scf(op: EmbeddingOp) -> ScfFunc:
    sr = op.semiring
    P = Param

    def decl(name, rank, ro=True, dtype=None):
        return MemRefDecl(name, rank, dtype or op.dtype, ro)

    fused = op.num_tables > 1
    if op.kind == "gather":
        memrefs = {
            "idxs": decl("idxs", 1, dtype="int32"),
            "table": decl("table", 2),
            "out": decl("out", 3, ro=False),
        }
        if fused:
            memrefs["roff"] = decl("roff", 1, dtype="int32")
        head = [Let("i0", Load("idxs", (VarRef("g"),))),
                Let("base", Load("roff", (VarRef("g"),))),
                Let("i", Bin("+", VarRef("i0"), VarRef("base")))] if fused \
            else [Let("i", Load("idxs", (VarRef("g"),)))]
        body = [
            For("g", Const(0), P("num_segments"), head + [
                For("r", Const(0), P("block_rows"), [
                    Let("row", Bin("+", Bin("*", VarRef("i"), P("block_rows")),
                                   VarRef("r"))),
                    For("e", Const(0), P("emb_len"), [
                        Store("out", (VarRef("g"), VarRef("r"), VarRef("e")),
                              Load("table", (VarRef("row"), VarRef("e")))),
                    ]),
                ]),
            ]),
        ]
        params = {"num_segments": op.num_segments, "block_rows": op.block_rows,
                  "emb_len": op.emb_len}
        return ScfFunc("gather", memrefs, params, body, op)

    if op.kind == "kg":
        memrefs = {
            "idxs": decl("idxs", 1, dtype="int32"),
            "vals": decl("vals", 1),
            "table": decl("table", 2),
            "out": decl("out", 2, ro=False),
        }
        body = [
            For("b", Const(0), P("num_segments"), [
                Let("i", Load("idxs", (VarRef("b"),))),
                Let("w", Load("vals", (VarRef("b"),))),
                For("e", Const(0), P("emb_len"), [
                    Store("out", (VarRef("b"), VarRef("e")),
                          Bin(_mul_binop(sr), VarRef("w"),
                              Load("table", (VarRef("i"), VarRef("e")))),
                          accumulate=sr.add),
                ]),
            ]),
        ]
        params = {"num_segments": op.num_segments, "emb_len": op.emb_len}
        return ScfFunc("kg", memrefs, params, body, op)

    if op.kind == "fusedmm":
        memrefs = {
            "ptrs": decl("ptrs", 1, dtype="int32"),
            "idxs": decl("idxs", 1, dtype="int32"),
            "x": decl("x", 2),
            "out": decl("out", 2, ro=False),
        }
        body = [
            For("i", Const(0), P("num_segments"), [
                Let("beg", Load("ptrs", (VarRef("i"),))),
                Let("end", Load("ptrs", (Bin("+", VarRef("i"), Const(1)),))),
                For("p", VarRef("beg"), VarRef("end"), [
                    Let("j", Load("idxs", (VarRef("p"),))),
                    Let("s", Const(0.0)),
                    # SDDMM loop: reads x[i,:] (fresh: j-indexed x rows) —
                    # offloadable; the accumulation into s is execute-side.
                    For("e", Const(0), P("emb_len"), [
                        SetVar("s", Bin("+", VarRef("s"),
                                        Bin("*",
                                            Load("x", (VarRef("i"), VarRef("e"))),
                                            Load("x", (VarRef("j"), VarRef("e")))))),
                    ]),
                    # workspace loop (paper §6.2): re-reads x[j,:] — already
                    # read by a sibling at the same level ⇒ NOT an offload
                    # candidate; it stays on the execute unit.
                    For("e2", Const(0), P("emb_len"), [
                        Store("out", (VarRef("i"), VarRef("e2")),
                              Bin("*", VarRef("s"),
                                  Load("x", (VarRef("j"), VarRef("e2")))),
                              accumulate="add"),
                    ]),
                ]),
            ]),
        ]
        params = {"num_segments": op.num_segments, "emb_len": op.emb_len}
        return ScfFunc("fusedmm", memrefs, params, body, op)

    # sls / spmm share one nest (paper §4: SLS ≡ SpMM(ikj, CSR))
    lengths = op.index_format == "lengths"
    assert not (fused and lengths), \
        "multi-table fusion requires the offsets index format"
    memrefs = {
        ("lens" if lengths else "ptrs"):
            decl("lens" if lengths else "ptrs", 1, dtype="int32"),
        "idxs": decl("idxs", 1, dtype="int32"),
        "table": decl("table", 2),
        "out": decl("out", 2, ro=False),
    }
    if fused:
        memrefs["roff"] = decl("roff", 1, dtype="int32")
    weighted = op.weighted or op.kind == "spmm"
    if weighted:
        memrefs["vals"] = decl("vals", 1)
    inner_val: Expr = Load("table", (VarRef("i"), VarRef("e")))
    if weighted:
        inner_val = Bin(_mul_binop(sr), VarRef("w"), inner_val)
    if fused:
        # the table-offset stream: idxs rebase onto the stacked table is
        # access-unit index arithmetic (MemStr roff[b] + AluStr add)
        seg_body = [Let("i0", Load("idxs", (VarRef("p"),))),
                    Let("i", Bin("+", VarRef("i0"), VarRef("base")))]
    else:
        seg_body = [Let("i", Load("idxs", (VarRef("p"),)))]
    if weighted:
        seg_body.append(Let("w", Load("vals", (VarRef("p"),))))
    seg_body.append(
        For("e", Const(0), Param("emb_len"), [
            Store("out", (VarRef("b"), VarRef("e")), inner_val,
                  accumulate=sr.add),
        ]))
    if lengths:
        # segment boundaries tracked by ACCUMULATING lengths (paper §7.4's
        # accumulation streams) instead of loading offsets
        body = [
            Let("acc", Const(0)),
            For("b", Const(0), Param("num_segments"), [
                Let("n", Load("lens", (VarRef("b"),))),
                Let("beg", VarRef("acc")),
                Let("end", Bin("+", VarRef("acc"), VarRef("n"))),
                For("p", VarRef("beg"), VarRef("end"), seg_body),
                SetVar("acc", VarRef("end")),
            ]),
        ]
    else:
        seg_head = [
            Let("beg", Load("ptrs", (VarRef("b"),))),
            Let("end", Load("ptrs", (Bin("+", VarRef("b"), Const(1)),))),
        ]
        if fused:
            seg_head.append(Let("base", Load("roff", (VarRef("b"),))))
        body = [
            For("b", Const(0), Param("num_segments"),
                seg_head + [For("p", VarRef("beg"), VarRef("end"), seg_body)]),
        ]
    params = {"num_segments": op.num_segments, "emb_len": op.emb_len}
    return ScfFunc(op.kind, memrefs, params, body, op)


def _mul_binop(sr: Semiring) -> str:
    return {"mul": "*", "add": "+"}[sr.mul]


# ----------------------------------------------------------------------------
# Interpreter
# ----------------------------------------------------------------------------

_BINOPS = {
    "+": lambda a, b: a + b,
    "-": lambda a, b: a - b,
    "*": lambda a, b: a * b,
    "/": lambda a, b: a / b,
    "min": min,
    "max": max,
}

_ACC = {
    "add": lambda a, b: a + b,
    "max": lambda a, b: max(a, b),
    "min": lambda a, b: min(a, b),
}

_FNS = {"identity": lambda x: x, "relu": lambda x: max(x, 0.0)}


def eval_expr(e: Expr, env: dict, mem: dict, params: dict):
    if isinstance(e, Const):
        return e.value
    if isinstance(e, Param):
        return params[e.name]
    if isinstance(e, VarRef):
        return env[e.name]
    if isinstance(e, Load):
        idx = tuple(int(eval_expr(i, env, mem, params)) for i in e.indices)
        return mem[e.memref][idx]
    if isinstance(e, Bin):
        return _BINOPS[e.op](eval_expr(e.a, env, mem, params),
                             eval_expr(e.b, env, mem, params))
    if isinstance(e, Apply):
        return _FNS[e.fn](eval_expr(e.a, env, mem, params))
    raise TypeError(e)


def _run_stmts(stmts: list, env: dict, mem: dict, params: dict):
    for s in stmts:
        if isinstance(s, Let) or isinstance(s, SetVar):
            env[s.var] = eval_expr(s.value, env, mem, params)
        elif isinstance(s, Store):
            idx = tuple(int(eval_expr(i, env, mem, params)) for i in s.indices)
            v = eval_expr(s.value, env, mem, params)
            if s.accumulate is None:
                mem[s.memref][idx] = v
            else:
                mem[s.memref][idx] = _ACC[s.accumulate](mem[s.memref][idx], v)
        elif isinstance(s, For):
            lb = int(eval_expr(s.lb, env, mem, params))
            ub = int(eval_expr(s.ub, env, mem, params))
            for i in range(lb, ub):
                env[s.var] = i
                _run_stmts(s.body, env, mem, params)
        else:
            raise TypeError(s)


def interp_scf(fn: ScfFunc, inputs: dict) -> np.ndarray:
    """Execute the SCF loop nest; returns ``out``."""
    from .ops import out_shape
    op = fn.op
    mem = dict(inputs)
    init = op.semiring.identity if op.has_compute else 0.0
    mem["out"] = np.full(out_shape(op), init, np.dtype(op.dtype))
    _run_stmts(fn.body, {}, mem, fn.params)
    out = mem["out"]
    if op.has_compute and op.semiring.add != "add" and op.uses_csr:
        lens = np.diff(inputs["ptrs"])
        out[lens == 0] = 0.0
    return out.astype(np.dtype(op.dtype))
