"""SCF → SLC decoupling (paper §6.2).

Implements the paper's offloading legality rules verbatim:

An SCF loop is an *offloading candidate* iff

  (1) its iteration bounds are static (Const/Param) or computed by another
      offloading candidate (i.e. expressions over already-streamed values) —
      access units cannot read data back from the execute unit; and
  (2) it loads from at least one read-only memory location that has not
      already been read (by a parent loop or an earlier sibling subtree, at
      embedding-vector granularity).

Loops failing (2) are *workspace loops* (they only revisit partial results /
already-marshaled data) and stay on the execute unit, inside callbacks.
At most one offloading candidate is selected per nesting level (embedding
operations, being sparse-dense contractions, never need more — §6.2).

Offloaded read-only loads and index arithmetic become ``MemStr``/``AluStr``
streams hoisted before their callback; remaining compute is wrapped into
``Callback`` nodes whose expressions read streams through ``ToVal``.
"""
from __future__ import annotations

from . import scf
from .slc import (AccStr, AluStr, Callback, MemStr, SBin, SlcFor, SlcFunc,
                  StreamRef, ToVal, verify)


class _Ctx:
    def __init__(self, fn: scf.ScfFunc):
        self.fn = fn
        self.stream_of: dict = {}      # scf var -> stream name
        self.read_rows: set = set()    # (memref, row-key) freshness record
        self.used: set = set()
        self.pending_acc: dict = {}
        self.counter = 0

    def fresh(self, hint: str) -> str:
        name = f"s_{hint}"
        if name in self.used:
            self.counter += 1
            name = f"s_{hint}{self.counter}"
        self.used.add(name)
        return name


def _row_key(ctx: _Ctx, load: scf.Load):
    """Vector-granularity location key: drop the innermost index."""
    return (load.memref, tuple(_sym(ctx, i) for i in load.indices[:-1]))


def _sym(ctx: _Ctx, e) -> object:
    if isinstance(e, scf.Const):
        return ("c", e.value)
    if isinstance(e, scf.Param):
        return ("p", e.name)
    if isinstance(e, scf.VarRef):
        return ("v", ctx.stream_of.get(e.name, e.name))
    if isinstance(e, scf.Bin):
        return (e.op, _sym(ctx, e.a), _sym(ctx, e.b))
    if isinstance(e, scf.Load):
        return ("ld", e.memref, tuple(_sym(ctx, i) for i in e.indices))
    return ("?",)


def _streamable_idx(ctx: _Ctx, e) -> bool:
    """Can this index expression be evaluated on the access unit?"""
    if isinstance(e, (scf.Const, scf.Param)):
        return True
    if isinstance(e, scf.VarRef):
        return e.name in ctx.stream_of
    if isinstance(e, scf.Bin):
        return _streamable_idx(ctx, e.a) and _streamable_idx(ctx, e.b)
    return False


def _to_sidx(ctx: _Ctx, e):
    if isinstance(e, (scf.Const, scf.Param)):
        return e
    if isinstance(e, scf.VarRef):
        return StreamRef(ctx.stream_of[e.name])
    if isinstance(e, scf.Bin):
        return SBin(e.op, _to_sidx(ctx, e.a), _to_sidx(ctx, e.b))
    raise TypeError(e)


def _loads_in(stmt) -> list:
    out = []

    def expr(e):
        if isinstance(e, scf.Load):
            out.append(e)
            for i in e.indices:
                expr(i)
        elif isinstance(e, scf.Bin):
            expr(e.a)
            expr(e.b)
        elif isinstance(e, scf.Apply):
            expr(e.a)

    def rec(s):
        if isinstance(s, (scf.Let, scf.SetVar)):
            expr(s.value)
        elif isinstance(s, scf.Store):
            expr(s.value)
            for i in s.indices:
                expr(i)
        elif isinstance(s, scf.For):
            expr(s.lb)
            expr(s.ub)
            for b in s.body:
                rec(b)
    rec(stmt)
    return out


def _has_fresh_load(ctx: _Ctx, loop: scf.For) -> bool:
    ro = {n for n, d in ctx.fn.memrefs.items() if d.read_only}
    for ld in _loads_in(loop):
        if ld.memref in ro and _row_key(ctx, ld) not in ctx.read_rows:
            return True
    return False


def _bounds_ok(ctx: _Ctx, loop: scf.For) -> bool:
    return _streamable_idx(ctx, loop.lb) and _streamable_idx(ctx, loop.ub)


def _is_candidate(ctx: _Ctx, loop: scf.For) -> bool:
    return _bounds_ok(ctx, loop) and _has_fresh_load(ctx, loop)


def decouple(fn: scf.ScfFunc) -> SlcFunc:
    ctx = _Ctx(fn)
    body = _lower_level(ctx, fn.body, allow_candidate=True)
    out = SlcFunc(fn.name, fn.memrefs, dict(fn.params), body, fn.op)
    verify(out)
    return out


def _lower_level(ctx: _Ctx, stmts: list, allow_candidate: bool) -> list:
    """Lower one SCF nesting level to SLC nodes."""
    stmts = _recognize_accumulators(ctx, stmts)
    nodes: list = []
    pending: list = []   # callback stmts accumulated at this level
    picked_candidate = False

    def flush():
        if pending:
            nodes.append(Callback(list(pending)))
            pending.clear()

    for s in stmts:
        if isinstance(s, scf.Let) and _offloadable_let(ctx, s):
            flush()
            nodes.append(_stream_for_let(ctx, s))
        elif isinstance(s, scf.For):
            if allow_candidate and not picked_candidate and _is_candidate(ctx, s):
                picked_candidate = True
                flush()
                nodes.append(_lower_candidate_loop(ctx, s))
            else:
                # workspace loop: stays on the execute unit
                pending.append(_rewrite_stmt(ctx, s, extract=None))
        elif isinstance(s, (scf.Let, scf.SetVar, scf.Store)):
            extracted: list = []
            pending.append(_rewrite_stmt(ctx, s, extract=extracted))
            # hoist extracted streams *before* the callback
            if extracted:
                flush_at = len(nodes)
                flush()
                for m in extracted:
                    nodes.insert(flush_at, m)
                    flush_at += 1
        else:
            raise TypeError(s)
    flush()
    return nodes


def _offloadable_let(ctx: _Ctx, s: scf.Let) -> bool:
    v = s.value
    if isinstance(v, _AccRef):
        return True
    if isinstance(v, scf.Load):
        d = ctx.fn.memrefs.get(v.memref)
        return (d is not None and d.read_only and
                all(_streamable_idx(ctx, i) for i in v.indices))
    # pure index arithmetic over streams
    if isinstance(v, scf.Bin):
        return _streamable_idx(ctx, v)
    return False


def _stream_for_let(ctx: _Ctx, s: scf.Let):
    v = s.value
    name = ctx.fresh(s.var)
    if isinstance(v, _AccRef):
        # §7.4 accumulation stream: exclusive running sum of the length
        # stream (already decoupled — body order guarantees it exists)
        src = StreamRef(ctx.stream_of[v.src_var])
        node = AccStr(name, src, init=v.init)
        ctx.stream_of[s.var] = name
        return node
    if isinstance(v, scf.Load):
        node = MemStr(name, v.memref, tuple(_to_sidx(ctx, i) for i in v.indices))
        ctx.read_rows.add(_row_key(ctx, v))
    else:
        node = AluStr(name, v.op, _to_sidx(ctx, v.a), _to_sidx(ctx, v.b))
    ctx.stream_of[s.var] = name
    return node


def _lower_candidate_loop(ctx: _Ctx, loop: scf.For) -> SlcFor:
    sname = ctx.fresh(loop.var)
    ctx.stream_of[loop.var] = sname
    body = _lower_level(ctx, loop.body, allow_candidate=True)
    return SlcFor(sname, _to_sidx(ctx, loop.lb), _to_sidx(ctx, loop.ub), body)


def _rewrite_stmt(ctx: _Ctx, s, extract):
    """Rewrite an execute-side statement: VarRef→ToVal for streamed vars;
    when ``extract`` is a list, hoist offloadable Loads into MemStr streams
    (paper §6.2: loads moved before their corresponding callback)."""

    def expr(e):
        if isinstance(e, scf.VarRef):
            if e.name in ctx.stream_of:
                return ToVal(ctx.stream_of[e.name])
            return e
        if isinstance(e, scf.Load):
            d = ctx.fn.memrefs.get(e.memref)
            offl = (extract is not None and d is not None and d.read_only and
                    all(_streamable_idx(ctx, i) for i in e.indices))
            if offl:
                name = ctx.fresh(f"{e.memref}v")
                extract.append(
                    MemStr(name, e.memref,
                           tuple(_to_sidx(ctx, i) for i in e.indices)))
                ctx.read_rows.add(_row_key(ctx, e))
                return ToVal(name)
            return scf.Load(e.memref, tuple(expr(i) for i in e.indices))
        if isinstance(e, scf.Bin):
            return scf.Bin(e.op, expr(e.a), expr(e.b))
        if isinstance(e, scf.Apply):
            return scf.Apply(e.fn, expr(e.a))
        return e

    if isinstance(s, scf.Let):
        return scf.Let(s.var, expr(s.value))
    if isinstance(s, scf.SetVar):
        return scf.SetVar(s.var, expr(s.value))
    if isinstance(s, scf.Store):
        return scf.Store(s.memref, tuple(expr(i) for i in s.indices),
                         expr(s.value), s.accumulate)
    if isinstance(s, scf.For):
        # workspace loop body: locals keep their names; streams become ToVal
        return scf.For(s.var, expr(s.lb) if not isinstance(s.lb, (scf.Const, scf.Param)) else s.lb,
                       s.ub if isinstance(s.ub, (scf.Const, scf.Param)) else expr(s.ub),
                       [_rewrite_stmt(ctx, b, extract=None) for b in s.body])
    raise TypeError(s)


def _recognize_accumulators(ctx: _Ctx, stmts: list) -> list:
    """Paper §7.4 accumulation streams: the pattern

        acc = C;  for b { n = lens[b]; beg = acc; end = acc+n; ...;
                          acc = end }

    becomes an access-unit ``acc_str`` (exclusive running sum of the length
    stream), making the scalar accumulator offloadable — without this the
    inner-loop bounds depend on an execute-side variable and the loop could
    not be decoupled at all."""
    out = []
    i = 0
    while i < len(stmts):
        s0 = stmts[i]
        nxt = stmts[i + 1] if i + 1 < len(stmts) else None
        if (isinstance(s0, scf.Let) and isinstance(s0.value, scf.Const)
                and isinstance(nxt, scf.For)
                and _accumulates(nxt.body, s0.var)):
            ctx.pending_acc[s0.var] = int(s0.value.value)
            out.append(nxt)   # drop the init; the loop body is rewritten
            i += 2
            continue
        out.append(s0)
        i += 1
    # inside a loop whose parent registered an accumulator: rewrite
    return [_rewrite_acc_loop(ctx, s) if isinstance(s, scf.For) else s
            for s in out]


def _accumulates(body, var) -> bool:
    has_beg = any(isinstance(b, scf.Let) and isinstance(b.value, scf.VarRef)
                  and b.value.name == var for b in body)
    has_upd = any(isinstance(b, scf.SetVar) and b.var == var for b in body)
    return has_beg and has_upd


def _rewrite_acc_loop(ctx: _Ctx, loop: scf.For) -> scf.For:
    accs = {v for v in ctx.pending_acc
            if _accumulates(loop.body, v)}
    if not accs:
        return loop
    var = accs.pop()
    init = ctx.pending_acc.pop(var)
    # locate: end = acc + n; SetVar(acc, end)  →  the increment var is n
    end_var = None
    for b in loop.body:
        if (isinstance(b, scf.SetVar) and b.var == var
                and isinstance(b.value, scf.VarRef)):
            end_var = b.value.name
    src_var = None
    for b in loop.body:
        if (isinstance(b, scf.Let) and b.var == end_var
                and isinstance(b.value, scf.Bin) and b.value.op == "+"):
            for o in (b.value.a, b.value.b):
                if isinstance(o, scf.VarRef) and o.name != var:
                    src_var = o.name
    if src_var is None:
        return loop  # pattern mismatch: leave untouched (execute-side)
    beg_var = next(b.var for b in loop.body
                   if isinstance(b, scf.Let)
                   and isinstance(b.value, scf.VarRef)
                   and b.value.name == var)
    new_body = []
    for b in loop.body:
        if (isinstance(b, scf.Let) and isinstance(b.value, scf.VarRef)
                and b.value.name == var):
            # beg = acc  →  synthetic node resolved into an AccStr
            new_body.append(scf.Let(b.var, _AccRef(var, init, src_var)))
        elif isinstance(b, scf.Let) and b.var == end_var:
            # end = acc + n  →  end = beg + n (beg is now a stream)
            new_body.append(scf.Let(b.var, scf.Bin(
                "+", scf.VarRef(beg_var), scf.VarRef(src_var))))
        elif isinstance(b, scf.SetVar) and b.var == var:
            continue  # the accumulation lives in the stream now
        else:
            new_body.append(b)
    return scf.For(loop.var, loop.lb, loop.ub, new_body)


@__import__("dataclasses").dataclass(frozen=True)
class _AccRef:
    var: str
    init: int
    src_var: str
