"""AccessPlan — the compiled host-side access artifact (DLC stage).

Ember's thesis is that the *access side* of an embedding operation deserves
its own compiled representation: the paper lowers lookups through dedicated
IRs (SCF -> SLC -> SLCV -> DLC) so access-stream generation is optimized
code, not ad-hoc host glue.  Before this module the program-scope access
work had drifted back into glue: CSR merging + ``roff`` synthesis lived in
``passes/fuse.py``, capacity bucketing was re-derived by the executor, and
the shard-routing layout was a private implementation inside
``core/shard_plan.py`` — three host paths each re-deriving the same stream
layout.

The ``plan-access`` pass (registered after ``lower-dlc`` in the
PassManager pipeline) now emits ONE :class:`AccessPlan` per compiled unit,
capturing as *data*:

* the stacked-slot geometry (slot bases, per-segment ``roff`` table-offset
  stream) of the fused unit;
* the capacity-bucket lattice (:mod:`repro.core.capacity`) every ragged
  extent is padded to;
* the vocab-shard routing table — per-slot ownership divisors, local bases,
  and the per-lookup owner/local-address computation of the offset-stream
  exchange;
* the **hot/cold row classification**: the Zipf head of each vocab slot
  (scored by :func:`repro.data.locality.classify_hot` reuse counts) is
  replicated on every shard as a *hot slab*, so hot lookups are local on
  whichever shard is least loaded (round-robin) and pay **zero exchange**;
  only the interleave-sharded cold tail routes indices across the mesh.

All host marshaling — the executor's per-step packing, the shard planner's
routed exchange, the one-shot ``fuse_inputs`` path — is *interpretation of
one AccessPlan*; none of those layers derives layout on its own anymore.

Sharded local-table layout (one fused unit, S shards)::

    shard s = [ slot0 cold slice s | slot1 cold slice s | ...
                | slot0 hot slab | slot1 hot slab | ... ]

    cold slice s of slot t = rows with cold-rank in [s*C_t, (s+1)*C_t),
    C_t = ceil(#cold_t / S); the hot slabs are identical on every shard.

Every shard's local table has the same shape and the same local bases
(SPMD), and the routed per-lookup indices are emitted *fully rebased* to
the local layout (the access-unit ALU resolving the complete address), so
the kernel-side ``seg_base`` stream degenerates to zeros on the sharded
path.  With an empty hot classification the layout and routing reduce
exactly to the PR-3 interleaved ceil-split (regression-tested).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from .capacity import DEFAULT_LATTICE, CapacityLattice
from .ops import EmbeddingOp


class EmberFault(RuntimeError):
    """Base of the typed fault vocabulary.

    Lives here (the lowest layer that raises one) so :mod:`core` never
    imports :mod:`repro.runtime`; :mod:`repro.runtime.faults` is the
    user-facing home that re-exports it alongside the runtime faults
    (``InjectedFailure``, ``WaveTimeout``, ...)."""


class MalformedAccessError(EmberFault, ValueError):
    """An offset stream failed validation against its compiled
    :class:`AccessPlan` — out-of-bounds indices under ``strict`` policy,
    or structural damage (non-monotone ``ptrs``, stream-length mismatches,
    extents past the capacity lattice's int32 address space) under *any*
    policy.  Carries the op name and a machine-checkable ``reason``."""

    def __init__(self, op_name, reason: str, detail: str = ""):
        self.op_name = op_name
        self.reason = reason
        super().__init__(
            f"malformed access stream for op {op_name!r}: {reason}"
            + (f" ({detail})" if detail else ""))


class RpcError(EmberFault):
    """Disaggregated-tier transport failure (framing, closed socket).

    Defined here with :class:`EmberFault` (not in :mod:`repro.runtime`)
    because the executor's disaggregated submit path must classify it —
    transport faults fail over / degrade, application faults propagate —
    and core never imports runtime."""


class RpcTimeout(RpcError):
    """A per-call RPC deadline lapsed (``rpc_timeout_s``)."""


class ServiceUnavailable(RpcError):
    """Every embedding-service replica is dark after bounded retry; the
    executor's ``degrade_policy`` decides whether the step serves locally
    (hot slab / stale tables) or fails typed."""


#: index-validation policies of the marshaling path (``strict`` raises a
#: typed error; ``clamp``/``drop`` degrade per-lookup and count it)
INDEX_POLICIES = ("strict", "clamp", "drop")

#: the kernels address streams in int32 — a padded capacity bucket past
#: this is un-marshalable regardless of policy (structural, always raises)
_INT32_MAX = 2 ** 31 - 1


def canonical_hot(hot_rows) -> tuple:
    """Hashable canonical form of a ``{op name: hot row ids}`` mapping —
    the compile-cache / executor-cache key component."""
    if not hot_rows:
        return ()
    return tuple(sorted(
        (str(name), tuple(int(i) for i in sorted(set(ids))))
        for name, ids in dict(hot_rows).items() if len(ids)))


@dataclasses.dataclass(frozen=True)
class MemberPlan:
    """One member op's slice of the fused access stream.  Whether a vals
    stream is marshaled is a *unit*-level property (``AccessPlan.need_vals``
    — a mixed group unit-weight-upcasts every member), so it is not
    duplicated here."""

    name: Optional[str]      # op name (None for a singleton unit)
    kind: str                # sls | kg | gather | spmm | fusedmm
    num_segments: int
    seg_offset: int          # first fused output row of this member
    slot: int                # stacked-slot index (shared tables share one)


@dataclasses.dataclass
class SlotPlan:
    """One stacked table slot's layout: single-device base + shard split +
    hot/cold classification.  ``remap``/``is_hot`` are only materialized on
    sharded plans (they are the per-row address-translation tables of the
    routed exchange)."""

    rows: int                     # index-unit rows of the slot
    base: int                     # single-device stacked base (index units)
    hot_ids: np.ndarray           # sorted global unit-row ids, replicated
    cold_ids: np.ndarray          # ascending ids of the interleaved tail
    cap: int                      # per-shard cold capacity ceil(#cold / S)
    cold_base: int                # local base of this slot's cold slice
    hot_base: int                 # local base of this slot's hot slab
    remap: Optional[np.ndarray]   # row -> cold rank | hot slab position
    is_hot: Optional[np.ndarray]  # row -> replicated?

    @property
    def hot_rows(self) -> int:
        return len(self.hot_ids)

    @property
    def cold_rows(self) -> int:
        return len(self.cold_ids)


@dataclasses.dataclass
class AccessPlan:
    """The per-unit access artifact: stream layout + routing as data.

    Built once per compiled unit by the ``plan-access`` pass (part of the
    compile-cache artifact) and interpreted by every host marshaling path.
    All methods are pure; a plan may be shared by concurrent executors.
    """

    op: EmbeddingOp               # the unit's (fused) op
    group: Optional[object]       # the FusedGroup (None for singletons)
    kind: str                     # csr | gather (the fused loop class)
    shards: int
    blk: int                      # physical rows per index unit
    num_segments: int
    members: tuple                # of MemberPlan
    slots: tuple                  # of SlotPlan
    roff: np.ndarray              # per-segment single-device stacked base
    lattice: CapacityLattice
    need_vals: bool
    unit_weight: float            # ⊗-identity for unit-weight upcast
    hot_spec: tuple = ()          # canonical_hot() the plan was built with
    #: monotone slab generation: bumped on every adaptive hot-slab swap so
    #: marshaling can assert it interprets the plan the tables were stacked
    #: under (an epoch mismatch means a stale plan — a correctness bug)
    epoch: int = 0
    #: hot-spill table ``{src_shard: (dst_shard, fraction)}`` — when a
    #: source shard's lattice diagonal is overloaded, route this bounded
    #: fraction of its hot lookups to the named (least-loaded) peer.  The
    #: slab is replicated, so reassigning a hot lookup's owner is always
    #: legal; it merely moves the lookup off the diagonal onto the wire.
    #: Mutable feedback state: the executor refreshes it from the previous
    #: step's ``pair_counts`` (never shared across executors).
    spill: dict = dataclasses.field(default_factory=dict, repr=False)
    #: starting shard of the host-path round-robin hot owner assignment;
    #: the executor points it at the shard with the lightest routed bucket
    #: observed on the previous step.
    rr_start: int = 0
    _kg_ptrs: dict = dataclasses.field(default_factory=dict, repr=False)

    # ------------------------------------------------------------------
    # Geometry
    # ------------------------------------------------------------------

    @property
    def fused(self) -> bool:
        return self.group is not None

    @property
    def local_rows(self) -> int:
        """Index-unit rows of ONE shard's local table (cold slices + hot
        slabs); equals the full stacked rows on a 1-shard plan."""
        if self.shards == 1:
            return sum(s.rows for s in self.slots)
        return sum(s.cap for s in self.slots) + self.hot_rows_total

    @property
    def hot_rows_total(self) -> int:
        return sum(s.hot_rows for s in self.slots)

    @property
    def seg_cap(self) -> int:
        """Contiguous segment-slice size of the collective exchange layout:
        shard ``s`` *originates* fused segments ``[s·seg_cap, (s+1)·seg_cap)``
        (its slice of the batch — the multi-host arrival model) and, with
        reduce-scattered outputs, *owns* their pooled rows."""
        return -(-self.num_segments // self.shards)

    @property
    def padded_segments(self) -> int:
        """Fused output rows after padding to the reduce-scatter grid
        (``seg_cap · shards``); rows ``>= num_segments`` are never read."""
        return self.seg_cap * self.shards

    @property
    def hot_slab_bytes(self) -> int:
        """Bytes of replicated hot rows each shard carries (0 when cold-only)."""
        item = np.dtype(self.op.dtype).itemsize
        return self.hot_rows_total * self.blk * self.op.emb_len * item

    @property
    def table_bytes_per_shard(self) -> int:
        item = np.dtype(self.op.dtype).itemsize
        return self.local_rows * self.blk * self.op.emb_len * item

    @property
    def slot_first_member(self) -> tuple:
        """Per slot, the first member name bound to it — the executor reads
        each slot's source table array through this member's inputs."""
        first: dict = {}
        for m in self.members:
            first.setdefault(m.slot, m.name)
        return tuple(first[t] for t in range(len(self.slots)))

    def stats(self) -> dict:
        return {"shards": self.shards, "slots": len(self.slots),
                "members": len(self.members),
                "hot_rows": self.hot_rows_total,
                "hot_slab_bytes": self.hot_slab_bytes,
                "local_rows": self.local_rows}

    # ------------------------------------------------------------------
    # Input hardening
    # ------------------------------------------------------------------

    def harden_step(self, inputs: dict, policy: str,
                    fallback_name: Optional[str] = None) -> tuple:
        """Validate (and, under ``clamp``/``drop``, repair) one step's
        member streams against this plan before any marshaling path
        interprets them.  Returns ``(inputs, oob, dropped)``.

        * *Structural* damage — wrong stream lengths, non-monotone or
          non-zero-based ``ptrs``, negative ``lens``, non-integer index
          dtypes, an nnz whose padded capacity bucket leaves int32 — has
          no graceful reading and raises :class:`MalformedAccessError`
          under **every** policy.
        * *Value* damage — indices outside the member's vocab bound
          (``slots[m.slot].rows``) — raises under ``strict``; ``clamp``
          clips to the valid range (counted in ``oob``); ``drop`` removes
          the offending CSR entries (counted in ``dropped``; for
          one-lookup-per-segment streams — gather/kg members — a segment
          cannot be empty, so drop degrades to clamp and counts ``oob``).

        The returned dict is the *same object* when every stream is clean
        (the zero-copy fast path — marshaling is then bit-identical to an
        unhardened executor); repaired members get shallow-copied entries.
        """
        assert policy in INDEX_POLICIES, (policy, INDEX_POLICIES)
        out, oob, dropped = inputs, 0, 0
        for m in self.members:
            name = m.name if m.name is not None else fallback_name
            ins = inputs[name]
            new, o, d = self._harden_member(m, ins, policy, name)
            oob += o
            dropped += d
            if new is not ins:
                if out is inputs:
                    out = dict(inputs)
                out[name] = new
        return out, oob, dropped

    def _member_idxs(self, m: MemberPlan, ins: dict, name) -> np.ndarray:
        idxs = np.asarray(ins["idxs"])
        if idxs.ndim != 1:
            raise MalformedAccessError(
                name, "idxs must be 1-D", f"got shape {idxs.shape}")
        if not np.issubdtype(idxs.dtype, np.integer):
            raise MalformedAccessError(
                name, "idxs must be an integer array",
                f"got dtype {idxs.dtype}")
        return idxs

    def _harden_member(self, m: MemberPlan, ins: dict, policy: str,
                       name) -> tuple:
        rows = self.slots[m.slot].rows
        idxs = self._member_idxs(m, ins, name)
        if m.kind in ("gather", "kg"):
            # one lookup per segment: the stream IS the segment axis
            if len(idxs) != m.num_segments:
                raise MalformedAccessError(
                    name, "idxs length != num_segments",
                    f"{len(idxs)} != {m.num_segments}")
            vals = ins.get("vals")
            if m.kind == "kg" and vals is not None \
                    and len(np.asarray(vals)) != m.num_segments:
                raise MalformedAccessError(
                    name, "vals length != num_segments",
                    f"{len(np.asarray(vals))} != {m.num_segments}")
            bad = (idxs < 0) | (idxs >= rows)
            nbad = int(bad.sum())
            if nbad == 0:
                return ins, 0, 0
            if policy == "strict":
                off = idxs[bad]
                raise MalformedAccessError(
                    name, f"{nbad} index(es) outside [0, {rows})",
                    f"e.g. {int(off[0])}")
            # drop == clamp here: a gather segment cannot be empty
            return {**ins, "idxs": np.clip(idxs, 0, rows - 1)}, nbad, 0
        # CSR stream (sls | spmm | fusedmm): ptrs (or lens) + idxs + vals
        ptrs, from_lens = self._harden_ptrs(m, ins, name)
        nnz = int(ptrs[-1])
        if nnz != len(idxs):
            raise MalformedAccessError(
                name, "ptrs[-1] != len(idxs)", f"{nnz} != {len(idxs)}")
        vals = ins.get("vals")
        if vals is not None and len(np.asarray(vals)) != nnz:
            raise MalformedAccessError(
                name, "vals length != nnz",
                f"{len(np.asarray(vals))} != {nnz}")
        if self.lattice.lookup_capacity(nnz) > _INT32_MAX:
            raise MalformedAccessError(
                name, "padded lookup capacity exceeds int32 address space",
                f"nnz={nnz}")
        bad = (idxs < 0) | (idxs >= rows)
        nbad = int(bad.sum())
        if nbad == 0:
            return ins, 0, 0
        if policy == "strict":
            off = idxs[bad]
            raise MalformedAccessError(
                name, f"{nbad} index(es) outside [0, {rows})",
                f"e.g. {int(off[0])}")
        if policy == "clamp":
            return {**ins, "idxs": np.clip(idxs, 0, rows - 1)}, nbad, 0
        # drop: excise the bad entries and rebuild the CSR offsets
        keep = ~bad
        seg = np.repeat(np.arange(m.num_segments), np.diff(ptrs))
        kept_per_seg = np.bincount(seg[keep], minlength=m.num_segments)
        new_ptrs = np.zeros(m.num_segments + 1, ptrs.dtype)
        np.cumsum(kept_per_seg, out=new_ptrs[1:])
        new = {**ins, "ptrs": new_ptrs, "idxs": idxs[keep]}
        new.pop("lens", None)         # superseded by the rebuilt ptrs
        if vals is not None:
            new["vals"] = np.asarray(vals)[keep]
        return new, 0, nbad

    def _harden_ptrs(self, m: MemberPlan, ins: dict, name) -> tuple:
        """Validate the CSR offset run (or derive it from ``lens``):
        zero-based, monotone non-decreasing, one entry past the segments."""
        if "ptrs" not in ins:
            if "lens" not in ins:
                raise MalformedAccessError(name, "missing ptrs/lens stream")
            lens = np.asarray(ins["lens"])
            if len(lens) != m.num_segments:
                raise MalformedAccessError(
                    name, "lens length != num_segments",
                    f"{len(lens)} != {m.num_segments}")
            if len(lens) and int(lens.min()) < 0:
                raise MalformedAccessError(
                    name, "negative segment length",
                    f"min={int(lens.min())}")
            ptrs = np.zeros(m.num_segments + 1, np.int64)
            np.cumsum(lens, out=ptrs[1:])
            return ptrs, True
        ptrs = np.asarray(ins["ptrs"], np.int64)
        if ptrs.shape != (m.num_segments + 1,):
            raise MalformedAccessError(
                name, "ptrs length != num_segments + 1",
                f"{ptrs.shape} != ({m.num_segments + 1},)")
        if int(ptrs[0]) != 0:
            raise MalformedAccessError(
                name, "ptrs must be zero-based", f"ptrs[0]={int(ptrs[0])}")
        if len(ptrs) > 1 and int(np.diff(ptrs).min()) < 0:
            raise MalformedAccessError(name, "ptrs must be non-decreasing")
        return ptrs, False

    # ------------------------------------------------------------------
    # Per-step stream interpretation (single-device path)
    # ------------------------------------------------------------------

    def member_ptrs(self, m: MemberPlan, ins: dict) -> np.ndarray:
        """CSR offsets of one member; kg members get their static degenerate
        one-per-segment CSR (cached — it never changes per signature)."""
        if m.kind == "kg":
            p = self._kg_ptrs.get(m.seg_offset)
            if p is None:
                p = self._kg_ptrs[m.seg_offset] = np.arange(
                    m.num_segments + 1, dtype=np.int64)
            return p
        return np.asarray(ins["ptrs"], np.int64)

    def csr_parts(self, inputs: dict) -> tuple:
        """Per-member CSR shape of one step: ``(parts, nnz, max_seg)`` with
        ``parts`` a list of ``(member, ptrs, member_nnz)`` — everything the
        capacity bucketing and the packing need."""
        parts: list = []
        nnz = 0
        max_seg = 0
        for m in self.members:
            p = self.member_ptrs(m, inputs[m.name])
            n = int(p[-1])
            max_seg = max(max_seg, int(np.diff(p).max(initial=0)))
            parts.append((m, p, n))
            nnz += n
        return parts, nnz, max_seg

    def pack_csr(self, buf: dict, parts: list, inputs: dict) -> int:
        """Write the offset-merged fused CSR into ``buf`` (the executor's
        bucketed scratch or a fresh exact-size dict): member ``ptrs`` run
        rebased by the running nnz, ``idxs`` concatenated, unweighted
        members of an upcast group emitting the constant ⊗-identity run."""
        pos = 0
        for m, p, n in parts:
            buf["ptrs"][m.seg_offset:m.seg_offset + m.num_segments] = \
                p[:-1] + pos
            buf["idxs"][pos:pos + n] = inputs[m.name]["idxs"]
            if "vals" in buf:
                v = inputs[m.name].get("vals")
                if v is None:             # unit-weight upcast member
                    buf["vals"][pos:pos + n] = self.unit_weight
                else:
                    buf["vals"][pos:pos + n] = v
            pos += n
        buf["ptrs"][self.num_segments] = pos
        return pos

    def pack_gather(self, buf: dict, inputs: dict) -> None:
        for m in self.members:
            buf["idxs"][m.seg_offset:m.seg_offset + m.num_segments] = \
                inputs[m.name]["idxs"]

    def fused_index_inputs(self, inputs: dict) -> dict:
        """The one-shot per-step marshaling (exact-size fresh arrays):
        offset-merged ``ptrs``, concatenated ``idxs``/``vals`` and the
        ``roff`` stream — everything except the stacked table."""
        out: dict = {"roff": self.roff}
        if self.kind == "gather":
            out["idxs"] = np.concatenate(
                [np.asarray(inputs[m.name]["idxs"]) for m in self.members])
            return out
        parts, nnz, _ = self.csr_parts(inputs)
        buf = {"ptrs": np.zeros(self.num_segments + 1, np.int32),
               "idxs": np.zeros(nnz, np.int32)}
        if self.need_vals:
            buf["vals"] = np.zeros(nnz, np.dtype(self.op.dtype))
        self.pack_csr(buf, parts, inputs)
        out.update(buf)
        return out

    # ------------------------------------------------------------------
    # Table stacking (layout interpretation)
    # ------------------------------------------------------------------

    def phys_rows(self, ids: np.ndarray) -> np.ndarray:
        """Index-unit row ids -> physical table rows (gather blocks)."""
        ids = np.asarray(ids, np.int64)
        if self.blk == 1:
            return ids
        return (ids[:, None] * self.blk +
                np.arange(self.blk, dtype=np.int64)[None, :]).reshape(-1)

    def stack_np(self, parts: list) -> np.ndarray:
        """Numpy oracle of the stacked table this plan lays out: the
        single-device row-stack on 1 shard, or the ``(S*L*blk, E)`` global
        array whose row block ``s`` is shard ``s``'s local table (cold
        slices + replicated hot slabs)."""
        emb = parts[0].shape[1]
        dt = parts[0].dtype
        if self.shards == 1:
            out = np.empty((self.local_rows * self.blk, emb), dt)
            for slot, p in zip(self.slots, parts):
                p = np.asarray(p)
                assert p.shape[0] == slot.rows * self.blk, \
                    (p.shape, slot.rows, self.blk)
                out[slot.base * self.blk:
                    slot.base * self.blk + p.shape[0]] = p
            return out
        s, blk, L = self.shards, self.blk, self.local_rows
        out = np.zeros((s * L * blk, emb), dt)
        for slot, p in zip(self.slots, parts):
            p = np.asarray(p)
            cold = p[self.phys_rows(slot.cold_ids)]
            hot = p[self.phys_rows(slot.hot_ids)]
            for sh in range(s):
                lo = sh * slot.cap
                hi = min((sh + 1) * slot.cap, slot.cold_rows)
                if lo < hi:
                    dst = (sh * L + slot.cold_base) * blk
                    out[dst:dst + (hi - lo) * blk] = \
                        cold[lo * blk:hi * blk]
                if slot.hot_rows:
                    dst = (sh * L + slot.hot_base) * blk
                    out[dst:dst + slot.hot_rows * blk] = hot
        return out

    # ------------------------------------------------------------------
    # Sharded routing (the offset-stream exchange, step 1)
    # ------------------------------------------------------------------

    def _resolve(self, idxs: np.ndarray, slot: SlotPlan, rr: int,
                 hot_owner: Optional[np.ndarray] = None) -> tuple:
        """Per-lookup (owner shard, fully-rebased local index, #hot) of one
        member's index stream.  Hot rows are local everywhere, so their
        owner is a load-balancing choice — round-robin in stream order
        (``rr`` threads the counter across members), or, on the collective
        path, the per-lookup ``hot_owner`` (the *source* shard: a hot
        lookup is then served where it arrives and never hits the wire) —
        and they contribute no exchange; cold rows route to
        ``cold_rank // C_t``."""
        idxs = np.asarray(idxs, np.int64)
        if slot.remap is None or not slot.hot_rows:
            owner = idxs // slot.cap
            return owner, slot.cold_base + idxs - owner * slot.cap, 0, rr
        r = slot.remap[idxs].astype(np.int64)
        hot = slot.is_hot[idxs]
        nh = int(hot.sum())
        owner = np.empty(len(idxs), np.int64)
        cold = ~hot
        owner[cold] = r[cold] // slot.cap
        if hot_owner is not None:
            owner[hot] = np.asarray(hot_owner, np.int64)[hot]
        else:
            owner[hot] = (rr + np.arange(nh, dtype=np.int64)) % self.shards
            rr += nh
        local = np.where(hot, slot.hot_base + r,
                         slot.cold_base + r - owner * slot.cap)
        return owner, local, nh, rr

    def route_csr(self, inputs: dict) -> dict:
        """Bucket one step's fused CSR stream by owning shard: merge the
        member streams, resolve every lookup's (owner, local address),
        stable-sort by owner (the source stream is segment-ordered, so each
        shard's re-emitted CSR is already valid) and pad to the joint
        exchange capacity bucket.  ``cold_nnz`` is the routed (exchanged)
        volume; ``hot_nnz`` lookups were absorbed by the replicated slab."""
        s = self.shards
        parts, nnz, _ = self.csr_parts(inputs)
        segs, owners, locals_, vals = [], [], [], []
        hot_nnz, rr = 0, int(self.rr_start) % s
        for m, p, n in parts:
            ins = inputs[m.name]
            segs.append(np.repeat(
                np.arange(m.num_segments, dtype=np.int64) + m.seg_offset,
                np.diff(p)))
            owner, local, nh, rr = self._resolve(
                ins["idxs"], self.slots[m.slot], rr)
            owners.append(owner)
            locals_.append(local)
            hot_nnz += nh
            if self.need_vals:
                v = ins.get("vals")
                vals.append(np.full(n, self.unit_weight,
                                    np.dtype(self.op.dtype))
                            if v is None else np.asarray(v))
        seg = np.concatenate(segs) if segs else np.zeros(0, np.int64)
        owner = np.concatenate(owners) if owners else np.zeros(0, np.int64)
        local = np.concatenate(locals_) if locals_ else np.zeros(0, np.int64)
        counts = np.zeros((s, self.num_segments), np.int64)
        if len(seg):
            np.add.at(counts, (owner, seg), 1)
        per_nnz = counts.sum(axis=1)
        ptrs = np.zeros((s, self.num_segments + 1), np.int32)
        np.cumsum(counts, axis=1, out=ptrs[:, 1:])
        perm = np.argsort(owner, kind="stable")
        bounds = np.zeros(s + 1, np.int64)
        np.cumsum(per_nnz, out=bounds[1:])
        cap, ml = self.lattice.exchange_capacity(
            per_nnz, counts.max(axis=1, initial=0))
        return {
            "ptrs": ptrs,
            "nnz": per_nnz,
            "idxs": local[perm].astype(np.int32),
            "vals": (np.concatenate(vals)[perm]
                     if self.need_vals else None),
            "bounds": bounds,
            "cap": cap,
            "max_lookups": ml,
            "hot_nnz": hot_nnz,
            "cold_nnz": nnz - hot_nnz,
        }

    def route_gather(self, inputs: dict) -> dict:
        """Bucket a fused gather's one-index-per-segment stream: every shard
        gets the full (B,) local-index vector with non-owned slots masked
        out (a gather's 'pool' is the row itself, so the mask IS the partial
        pool).  Hot segments are served round-robin — no exchange."""
        s, B = self.shards, self.num_segments
        idxs_out = np.zeros((s, B), np.int32)
        mask = np.zeros((s, B), np.float32)
        shard_ids = np.arange(s)[:, None]
        hot_segments, rr = 0, int(self.rr_start) % s
        for m in self.members:
            owner, local, nh, rr = self._resolve(
                inputs[m.name]["idxs"], self.slots[m.slot], rr)
            hot_segments += nh
            sl = slice(m.seg_offset, m.seg_offset + m.num_segments)
            owned = owner[None, :] == shard_ids
            idxs_out[:, sl] = np.where(owned, local[None, :], 0)
            mask[:, sl] = owned
        return {"idxs": idxs_out, "mask": mask,
                "hot_segments": hot_segments,
                "cold_segments": B - hot_segments}

    # ------------------------------------------------------------------
    # Collective routing (the offset-stream exchange as all_to_all send
    # buffers — see docs/executor.md §Collective exchange)
    # ------------------------------------------------------------------

    def fill_lattice(self, routed: dict, ints: np.ndarray,
                     vals: Optional[np.ndarray] = None) -> None:
        """Scatter a collective routing's per-lookup streams into the
        ``(S_src, S_dst, 2, cap)`` send lattice IN PLACE (the executor's
        rotating scratch — the steady-state path allocates nothing per
        step).  Pad slots get the segment sentinel; packing is stable
        within each pair, so per-pair runs stay segment-ordered."""
        s = self.shards
        cap = ints.shape[-1]
        ints[:, :, 0, :] = self.num_segments      # pad sentinel
        ints[:, :, 1, :] = 0                      # pad rows stay in bounds
        if vals is not None:
            vals[:] = 0
        seg = routed["seg"]
        n = len(seg)
        if not n:
            return
        flat = routed["src"] * s + routed["owner"]
        perm = np.argsort(flat, kind="stable")
        sflat = flat[perm]
        bounds = np.zeros(s * s + 1, np.int64)
        np.cumsum(np.bincount(sflat, minlength=s * s), out=bounds[1:])
        within = np.arange(n, dtype=np.int64) - bounds[sflat]
        i3 = ints.reshape(s * s, 2, cap)
        i3[sflat, 0, within] = seg[perm].astype(np.int32)
        i3[sflat, 1, within] = routed["local"][perm].astype(np.int32)
        if vals is not None:
            vals.reshape(s * s, cap)[sflat, within] = routed["val"][perm]

    def packed_lattice(self, routed: dict) -> dict:
        """Fresh-array packing of a collective routing (tests and one-shot
        callers; the executor fills its scratch via :meth:`fill_lattice`)."""
        s, cap = self.shards, routed["cap"]
        ints = np.empty((s, s, 2, cap), np.int32)
        vals = (np.empty((s, s, cap), np.dtype(self.op.dtype))
                if routed.get("val") is not None else None)
        self.fill_lattice(routed, ints, vals)
        return {"ints": ints, "vals": vals}

    def route_csr_collective(self, inputs: dict) -> dict:
        """Bucket one step's fused CSR stream into the ``(src, dst)`` send
        lattice of the device-collective exchange (``jax.lax.all_to_all``
        inside the shard_map body — see :mod:`repro.core.shard_plan`).

        The *source* shard of a lookup is the contiguous segment slice its
        fused segment falls in (``seg // seg_cap``) — the shard that, in a
        multi-host deployment, generates that slice of the batch and (with
        reduce-scattered outputs) owns its pooled rows.  Hot lookups are
        served **at the source** (the slab is local on every shard), so
        they occupy the diagonal of the send lattice and never hit the
        wire; cold lookups route to ``cold_rank // C_t`` as always.  Every
        pair bucket pads to ONE capacity (the lattice bucket of the max
        pair count) so the ``all_to_all`` is retrace-free across ragged
        steps; pad slots carry the segment sentinel ``num_segments``
        (masked on device), and the per-lookup *segment id* travels with
        the index, so the receiving shard can rebuild a canonical CSR
        without any cross-pair host merge.  ``wire_nnz`` counts the
        off-diagonal (actually exchanged) lookups.

        Returns the resolved streams + capacities; pack them into a send
        buffer with :meth:`fill_lattice` (in-place, the executor's scratch)
        or :meth:`packed_lattice` (fresh arrays)."""
        s = self.shards
        sc = self.seg_cap
        parts, nnz, _ = self.csr_parts(inputs)
        segs_l, srcs_l, owners_l, locals_l, hots_l, vals_l = \
            [], [], [], [], [], []
        hot_nnz = 0
        for m, p, n in parts:
            ins = inputs[m.name]
            seg = np.repeat(
                np.arange(m.num_segments, dtype=np.int64) + m.seg_offset,
                np.diff(p))
            src = np.minimum(seg // sc, s - 1)
            slot = self.slots[m.slot]
            owner, local, nh, _ = self._resolve(
                ins["idxs"], slot, 0, hot_owner=src)
            hot_nnz += nh
            segs_l.append(seg)
            srcs_l.append(src)
            owners_l.append(owner)
            locals_l.append(local)
            if self.spill:
                hots_l.append(
                    np.zeros(n, bool)
                    if slot.remap is None or not slot.hot_rows
                    else slot.is_hot[np.asarray(ins["idxs"], np.int64)])
            if self.need_vals:
                v = ins.get("vals")
                vals_l.append(np.full(n, self.unit_weight,
                                      np.dtype(self.op.dtype))
                              if v is None else np.asarray(v))
        cat = (lambda xs, dt: np.concatenate(xs)
               if xs else np.zeros(0, dt))
        seg = cat(segs_l, np.int64)
        src = cat(srcs_l, np.int64)
        owner = cat(owners_l, np.int64)
        local = cat(locals_l, np.int64)
        # Hot-aware source spill: a hot lookup's owner is a free choice
        # (the slab is replicated), so shed a bounded, deterministic
        # prefix (stream order) of an overloaded source's hot lookups to
        # its least-loaded peer — trading a little wire volume for
        # diagonal balance.
        spilled = 0
        if self.spill and len(seg):
            hot = cat(hots_l, bool)
            for s0, (dst, frac) in self.spill.items():
                s0, dst = int(s0) % s, int(dst) % s
                if dst == s0:
                    continue
                sel = np.flatnonzero(hot & (src == s0))
                k = int(len(sel) * min(max(float(frac), 0.0), 1.0))
                if k:
                    owner[sel[:k]] = dst
                    spilled += k
        pair = np.zeros((s, s), np.int64)
        dst_seg = np.zeros((s, self.num_segments), np.int64)
        if len(seg):
            np.add.at(pair, (src, owner), 1)
            np.add.at(dst_seg, (owner, seg), 1)
        cap, ml = self.lattice.collective_exchange_capacity(
            pair, dst_seg.max(axis=1, initial=0))
        return {
            "seg": seg,
            "src": src,
            "owner": owner,
            "local": local,
            "val": (cat(vals_l, np.dtype(self.op.dtype))
                    if self.need_vals else None),
            "cap": cap,
            "max_lookups": ml,
            "pair_counts": pair,
            "nnz": pair.sum(axis=0),
            "hot_nnz": hot_nnz,
            "cold_nnz": nnz - hot_nnz,
            "spilled_nnz": spilled,
            "wire_nnz": int(pair.sum() - np.trace(pair)),
        }

    def route_gather_collective(self, inputs: dict) -> dict:
        """Collective routing of a fused gather's one-index-per-segment
        stream: same ``(src, dst)`` send lattice as the CSR path (segment
        id + local block index per lookup); the receiving shard gathers its
        owned blocks and scatters them to their segments — exactly one
        shard owns each segment, so the cross-shard combine is a plain sum
        (or its reduce-scatter).  Pack via :meth:`fill_lattice` /
        :meth:`packed_lattice`, like the CSR routing."""
        s, sc, B = self.shards, self.seg_cap, self.num_segments
        segs_l, srcs_l, owners_l, locals_l = [], [], [], []
        hot_segments = 0
        for m in self.members:
            seg = np.arange(m.num_segments, dtype=np.int64) + m.seg_offset
            src = np.minimum(seg // sc, s - 1)
            owner, local, nh, _ = self._resolve(
                inputs[m.name]["idxs"], self.slots[m.slot], 0,
                hot_owner=src)
            hot_segments += nh
            segs_l.append(seg)
            srcs_l.append(src)
            owners_l.append(owner)
            locals_l.append(local)
        seg = np.concatenate(segs_l)
        src = np.concatenate(srcs_l)
        owner = np.concatenate(owners_l)
        local = np.concatenate(locals_l)
        pair = np.zeros((s, s), np.int64)
        np.add.at(pair, (src, owner), 1)
        cap, _ = self.lattice.collective_exchange_capacity(pair, [0])
        return {"seg": seg, "src": src, "owner": owner, "local": local,
                "val": None, "cap": cap,
                "pair_counts": pair,
                "hot_segments": hot_segments,
                "cold_segments": B - hot_segments,
                "wire_segments": int(pair.sum() - np.trace(pair))}


# ---------------------------------------------------------------------------
# Builders
# ---------------------------------------------------------------------------

def _build_slots(rows_per_slot: list, bases: list, shards: int,
                 hot_per_slot: list) -> tuple:
    """Lay out the slots: single-device bases are given; the sharded layout
    packs cold slices first (cumulative ceil-split caps), then the
    replicated hot slabs."""
    hots = [np.asarray(sorted(set(int(i) for i in h if 0 <= int(i) < r)),
                       np.int64)
            for r, h in zip(rows_per_slot, hot_per_slot)]
    caps = [max(1, -(-(r - len(h)) // shards))
            for r, h in zip(rows_per_slot, hots)]
    total_cold = sum(caps)
    slots: list = []
    cold_base = 0
    hot_base = total_cold
    for rows, base, hot, cap in zip(rows_per_slot, bases, hots, caps):
        cold = np.setdiff1d(np.arange(rows, dtype=np.int64), hot)
        remap = is_hot = None
        if shards > 1:
            remap = np.zeros(rows, np.int32)
            is_hot = np.zeros(rows, bool)
            remap[cold] = np.arange(len(cold), dtype=np.int32)
            if len(hot):
                remap[hot] = np.arange(len(hot), dtype=np.int32)
                is_hot[hot] = True
        slots.append(SlotPlan(rows=rows, base=base, hot_ids=hot,
                              cold_ids=cold, cap=cap, cold_base=cold_base,
                              hot_base=hot_base, remap=remap,
                              is_hot=is_hot))
        cold_base += cap
        hot_base += len(hot)
    return tuple(slots)


def build_plan(op: EmbeddingOp, group=None, shards: int = 1,
               hot_rows=None, lattice: CapacityLattice = DEFAULT_LATTICE,
               epoch: int = 0) -> AccessPlan:
    """Build the AccessPlan of one compiled unit.

    ``group`` is the fusion pass's FusedGroup (duck-typed: ``members``,
    ``member_ops``, ``row_offsets``, ``seg_offsets``, ``op``,
    ``unit_weight``); ``None`` builds the trivial singleton plan.
    ``hot_rows`` maps member op names to replicated row ids — only
    meaningful on sharded plans (a 1-shard plan has no exchange to save,
    so the classification is dropped and the layout is exactly the
    single-device stack)."""
    shards = max(int(shards), 1)
    hot_rows = dict(hot_rows) if (hot_rows and shards > 1) else {}
    if group is None:
        member = MemberPlan(None, op.kind, op.num_segments, 0, 0)
        slots = _build_slots([op.num_embeddings], [0], shards, [()])
        return AccessPlan(
            op=op, group=None,
            kind="gather" if op.kind == "gather" else "csr",
            shards=shards, blk=op.block_rows if op.kind == "gather" else 1,
            num_segments=op.num_segments, members=(member,), slots=slots,
            roff=np.zeros(op.num_segments, np.int32), lattice=lattice,
            # kg included: a standalone kg op always consumes a vals stream
            # (fused groups instead fold kg into op.weighted via the upcast)
            need_vals=op.weighted or op.kind in ("spmm", "kg"),
            unit_weight=1.0 if op.semiring.mul == "mul" else 0.0,
            epoch=epoch)

    fop = group.op
    blk = fop.block_rows if fop.kind == "gather" else 1
    slot_of_base: dict = {}
    rows_per_slot: list = []
    bases: list = []
    members: list = []
    hot_per_slot: list = []
    for name, mop, base, seg_off in zip(group.members, group.member_ops,
                                        group.row_offsets,
                                        group.seg_offsets):
        if base not in slot_of_base:
            slot_of_base[base] = len(rows_per_slot)
            rows_per_slot.append(mop.num_embeddings)
            bases.append(base)
            hot_per_slot.append(set())
        t = slot_of_base[base]
        hot_per_slot[t].update(hot_rows.get(name, ()))
        members.append(MemberPlan(name, mop.kind, mop.num_segments,
                                  seg_off, t))
    slots = _build_slots(rows_per_slot, bases, shards,
                         [sorted(h) for h in hot_per_slot])
    roff = np.concatenate(
        [np.full(m.num_segments, slots[m.slot].base, np.int32)
         for m in members])
    return AccessPlan(
        op=fop, group=group,
        kind="gather" if fop.kind == "gather" else "csr",
        shards=shards, blk=blk, num_segments=fop.num_segments,
        members=tuple(members), slots=slots, roff=roff, lattice=lattice,
        need_vals=fop.weighted or fop.kind == "spmm",
        unit_weight=group.unit_weight,
        hot_spec=canonical_hot(hot_rows), epoch=epoch)


def plan_for_group(group, shards: int = 1, hot_rows=None) -> AccessPlan:
    """Convenience: the AccessPlan of a FusedGroup outside the pass
    pipeline (the one-shot ``fuse_inputs`` path and tests)."""
    return build_plan(group.op, group, shards=shards, hot_rows=hot_rows)


def plan_access_pass(dlc, frontend_op=None, group=None, shards: int = 1,
                     hot_rows=None, **_) -> AccessPlan:
    """The ``plan-access`` PassManager pass: consumes the DLC program (the
    plan is the host-side companion of the device DLC artifact) and emits
    the unit's AccessPlan from the compile options the driver forwards."""
    assert frontend_op is not None, "plan-access needs the frontend op"
    return build_plan(frontend_op, group, shards=shards, hot_rows=hot_rows)


def hot_rows_from_traces(program, traces: dict, budget) -> dict:
    """Classify each op's Zipf head from calibration index traces, sized to
    ``budget.hot_slab_bytes`` per table (0 disables).  Returns the
    ``{op name: tuple(row ids)}`` mapping ``executor_for`` /
    ``compile_program`` accept as ``hot_rows``."""
    from ..data.locality import classify_hot
    out: dict = {}
    if getattr(budget, "hot_slab_bytes", 0) <= 0:
        return out
    for name, op in program.ops:
        tr = traces.get(name)
        if tr is None or len(tr) == 0:
            continue
        blk = op.block_rows if op.kind == "gather" else 1
        row_bytes = blk * op.emb_len * np.dtype(op.dtype).itemsize
        max_hot = budget.hot_slab_bytes // max(row_bytes, 1)
        ids = classify_hot(np.asarray(tr), op.num_embeddings, max_hot)
        if len(ids):
            out[name] = tuple(int(i) for i in ids)
    return out
