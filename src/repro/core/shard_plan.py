"""Vocab-sharded fused programs — the device half of the sharded executor.

At serving scale one device cannot hold the fused stacked tables, so the
steady-state executor shards them along the vocab (row) dimension over the
``model`` axis of the production mesh, FlexEMR-style: the *indices* move to
the data, the data never moves to the compute.

All layout and routing decisions — the interleaved cold split, the
replicated hot slabs, per-lookup owner/local-address resolution, the
capacity buckets of the exchange — live in the compiled
:class:`~repro.core.access_plan.AccessPlan` (the ``plan-access`` pass).
This module only *realizes* a plan on a mesh:

* :func:`shard_stack_tables` materializes the plan's per-shard local tables
  (cold slices + replicated hot slabs) as one row-sharded global array;
* :func:`put_sharded` / :func:`put_replicated` place the per-step operand
  buffers: the host-exchange ``(S_dst, …)`` routed buckets, or the
  collective path's ``(S_src, …)`` resident send lattice;
* ``make_csr_body`` / ``make_gather_body`` (host exchange) and
  ``make_csr_collective_body`` / ``make_gather_collective_body``
  (device-collective exchange) + :func:`sharded_call` build the
  ``jit(shard_map(...))`` execute bodies: optional on-device
  ``all_to_all`` index exchange, local pool, then pooled-rows-back combine
  — fully replicated (``psum``/``pmax``/``pmin``) or **reduce-scattered**
  so each shard keeps only its contiguous segment slice — with
  ⊕-identity-exact empty-segment handling throughout.

Exchange protocol (per step, the access side doing the all-to-all on the
offset stream):

    1. **indices out** — the host interprets the AccessPlan: every lookup
       resolves to ``(owner shard, fully-rebased local address)``; hot rows
       are replicated so their lookups are *local* (round-robin on the host
       exchange; served at the *source* shard — zero wire traffic — on the
       collective), cold rows route to ``cold_rank // C_t``.  Buckets are
       padded to the plan's capacity lattice, so the exchange is
       retrace-free across ragged steps.  ``exchange="host"`` realizes the
       move as a single-controller sharded ``device_put`` of per-owner
       buckets; ``exchange="collective"`` device_puts ONE ``(S_src, S_dst,
       …)`` send lattice and runs ``jax.lax.all_to_all`` *inside* the
       shard_map body (each lookup travels with its fused segment id, so
       the receiver rebuilds a canonical sub-CSR without host help).
    2. **local pool** — each shard runs the batched SLS kernel (or the XLA
       reference body) over its local sub-CSR; since routed indices arrive
       fully rebased, the kernel's ``seg_base`` stream is all-zero here.
    3. **pooled rows back** — the partial pools combine across shards with
       ``psum`` (⊕=add) / ``pmax`` / ``pmin`` when replicated, or
       reduce-scatter (``psum_scatter``; the all_to_all transpose for
       max/min) when each shard owns a segment slice; locally-empty
       segments contribute the ⊕-identity, and globally-empty segments are
       fixed to 0 afterwards (the SLS convention), so a shard receiving
       zero indices for a step is a no-op, not a hazard.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ..kernels import ops as kops
from ..launch.sharding import (leading_axis_sharding, replicated_sharding,
                               table_row_sharding)
from .access_plan import AccessPlan
from .jax_compat import shard_map

_ADD_IDENT = {"add": 0.0, "max": -np.inf, "min": np.inf}


def shard_count(mesh, axis: str = "model") -> int:
    """Size of ``axis`` in ``mesh`` (1 when mesh is None / axis absent) —
    the executor's single switch between the replicated and sharded paths."""
    if mesh is None:
        return 1
    shape = dict(mesh.shape)
    return int(shape.get(axis, 1))


# ---------------------------------------------------------------------------
# Layout realization: the plan's per-shard tables on a mesh
# ---------------------------------------------------------------------------

def shard_stack_tables(parts: list, plan: AccessPlan, mesh,
                       axis: str) -> jax.Array:
    """Device-side sharded stacking of one fused unit per its AccessPlan:
    each slot's cold tail is striped over the shards (ceil-split, padded),
    its hot slab is replicated into every shard's local table, and the
    ``(S·L·blk, E)`` result is placed row-sharded over ``axis`` — each
    device materializes only its own ``(L·blk, E)`` slice."""
    s, blk = plan.shards, plan.blk
    cold_stripes, hot_stripes = [], []
    for slot, p in zip(plan.slots, parts):
        p = jnp.asarray(p)
        emb = p.shape[1]
        if slot.hot_rows:
            cold = jnp.take(p, plan.phys_rows(slot.cold_ids), axis=0)
            hot = jnp.take(p, plan.phys_rows(slot.hot_ids), axis=0)
        else:
            cold, hot = p, None
        pad = s * slot.cap * blk - cold.shape[0]
        if pad:
            cold = jnp.pad(cold, ((0, pad), (0, 0)))
        cold_stripes.append(cold.reshape(s, slot.cap * blk, emb))
        if hot is not None:
            hot_stripes.append(jnp.broadcast_to(hot[None], (s,) + hot.shape))
    glob = jnp.concatenate(cold_stripes + hot_stripes, axis=1).reshape(
        s * plan.local_rows * blk, cold_stripes[0].shape[-1])
    return jax.device_put(glob, table_row_sharding(mesh, axis))


def compute_spill(pair_counts: np.ndarray, max_fraction: float,
                  overload_ratio: float) -> dict:
    """Hot-spill table from one step's ``(S_src, S_dst)`` pair counts.

    The lattice diagonal is the hot (source-served) traffic; when a source
    shard's diagonal exceeds ``overload_ratio ×`` the mean diagonal load,
    a bounded ``max_fraction`` of its hot lookups should spill to its
    least-loaded peer (by total routed column load).  Returns the
    ``{src: (dst, fraction)}`` mapping
    :meth:`~repro.core.access_plan.AccessPlan.route_csr_collective`
    applies on the *next* step — the feedback edge of the executor's
    spill-aware lattice fill."""
    pair = np.asarray(pair_counts, np.int64)
    s = pair.shape[0]
    if s < 2 or max_fraction <= 0.0:
        return {}
    diag = np.diag(pair).astype(np.float64)
    mean = diag.mean()
    if mean <= 0:
        return {}
    load = pair.sum(axis=0).astype(np.float64)   # per-dst routed work
    spill: dict = {}
    for src in np.flatnonzero(diag > overload_ratio * mean):
        peers = np.array([d for d in range(s) if d != src])
        dst = int(peers[np.argmin(load[peers])])
        spill[int(src)] = (dst, float(max_fraction))
    return spill


def put_sharded(arr: np.ndarray, mesh, axis: str) -> jax.Array:
    """Place a host ``(S, …)`` bucket array so shard ``s`` holds block ``s``
    of the leading dim: the host-exchange scatter (dim 0 = *destination*
    shard) and the collective path's resident send buffer (dim 0 = *source*
    shard — the ``all_to_all`` moves the indices from there)."""
    assert arr.ndim >= 2, arr.shape
    return jax.device_put(arr, leading_axis_sharding(mesh, axis, arr.ndim))


def put_replicated(arr, mesh) -> jax.Array:
    a = jnp.asarray(arr)
    return jax.device_put(a, replicated_sharding(mesh, a.ndim))


# ---------------------------------------------------------------------------
# Device-side execute bodies (steps 2+3: local pool + pooled rows back)
# ---------------------------------------------------------------------------

def _combine(out, axis: str, add_op: str):
    if add_op == "add":
        return jax.lax.psum(out, axis)
    return (jax.lax.pmax if add_op == "max" else jax.lax.pmin)(out, axis)


def _reduce_scatter(x, axis: str, add_op: str, shards: int, seg_cap: int):
    """⊕-reduce-scatter of per-shard partial pools along dim 0: pad the
    segment dim to the ``shards·seg_cap`` grid and leave each shard holding
    the combined rows of its own contiguous segment slice (rows past the
    true segment count are padding and never read).  ``psum_scatter`` is
    the ⊕=add primitive; max/min reduce-scatter via the all_to_all
    transpose (each shard collects every peer's partials for its slice)."""
    pad = shards * seg_cap - x.shape[0]
    if pad:
        x = jnp.pad(x, ((0, pad),) + ((0, 0),) * (x.ndim - 1),
                    constant_values=_ADD_IDENT[add_op])
    if add_op == "add":
        return jax.lax.psum_scatter(x, axis, scatter_dimension=0,
                                    tiled=True)
    r = jax.lax.all_to_all(x.reshape((shards, seg_cap) + x.shape[1:]),
                           axis, 0, 0)
    return (jnp.max if add_op == "max" else jnp.min)(r, axis=0)


def _finish_csr(out, counts, *, axis: str, add_op: str, replicate: bool,
                shards: int, seg_cap: int):
    """Cross-shard combine + SLS zero-fix of one CSR unit's partial pools.
    ``counts`` are the shard's per-segment lookup counts (locally-empty
    segments hold the ⊕-identity in ``out``); globally-empty segments are
    fixed to 0 after the merge — the SLS convention — using the summed
    counts, reduce-scattered alongside the rows when outputs are owned."""
    if replicate:
        merged = _combine(out, axis, add_op)
        if add_op == "add":
            return merged
        total = jax.lax.psum(counts, axis)
        return jnp.where((total > 0)[:, None], merged, 0.0)
    merged = _reduce_scatter(out, axis, add_op, shards, seg_cap)
    if add_op == "add":
        return merged
    pad = shards * seg_cap - counts.shape[0]
    if pad:
        counts = jnp.pad(counts, (0, pad))
    total = jax.lax.psum_scatter(counts, axis, scatter_dimension=0,
                                 tiled=True)
    return jnp.where((total > 0)[:, None], merged, 0.0)


def jnp_sls_local(table, ptrs, idxs, vals, roff, *, num_segments: int,
                  add_op: str, mul_op: str):
    """Traceable XLA reference of the local-shard SLS pool (the ``jax``
    backend's execute unit under shard_map).  Locally-empty segments yield
    the ⊕-identity (NOT the SLS zero) so cross-shard merging stays exact;
    the caller zero-fixes globally-empty segments after the combine."""
    cap = idxs.shape[0]
    pos = jnp.arange(cap, dtype=jnp.int32)
    p32 = ptrs.astype(jnp.int32)
    seg = jnp.searchsorted(p32[1:], pos, side="right")
    valid = pos < p32[-1]
    segc = jnp.minimum(seg, num_segments - 1)
    rows = jnp.take(table, idxs + jnp.take(roff, segc), axis=0)
    if vals is not None:
        w = vals[:, None].astype(rows.dtype)
        rows = rows * w if mul_op == "mul" else rows + w
    ident = jnp.asarray(_ADD_IDENT[add_op], rows.dtype)
    rows = jnp.where(valid[:, None], rows, ident)
    reduce = {"add": jax.ops.segment_sum, "max": jax.ops.segment_max,
              "min": jax.ops.segment_min}[add_op]
    out = reduce(rows, segc, num_segments=num_segments)
    if add_op != "add":
        counts = p32[1:] - p32[:-1]
        out = jnp.where((counts > 0)[:, None], out, ident)
    return out


def _local_pool_csr(table, roff, ptrs, idxs, vals, *, backend: str,
                    add_op: str, mul_op: str, nseg: int, max_lookups: int,
                    col_tile: int, interpret: bool):
    """One shard's partial pool over a local sub-CSR, with locally-empty
    segments holding the ⊕-identity (merge-ready).  Returns
    ``(out, counts)`` — counts feed the globally-empty zero-fix."""
    counts = ptrs[1:] - ptrs[:-1]
    if backend == "pallas":
        out = kops.sls(table, ptrs, idxs, vals, num_segments=nseg,
                       max_lookups=max_lookups, add_op=add_op,
                       mul_op=mul_op, col_tile=col_tile,
                       interpret=interpret, seg_base=roff)
        if add_op != "add":
            # the kernel zeroed locally-empty segments (SLS convention);
            # restore the ⊕-identity before merging across shards
            out = jnp.where((counts > 0)[:, None], out,
                            jnp.asarray(_ADD_IDENT[add_op], out.dtype))
    else:
        out = jnp_sls_local(table, ptrs, idxs, vals, roff,
                            num_segments=nseg, add_op=add_op,
                            mul_op=mul_op)
    return out, counts


def make_csr_body(op, *, axis: str, backend: str, max_lookups: int,
                  need_vals: bool, interpret: bool, col_tile: int,
                  replicate: bool = True, shards: int = 1,
                  seg_cap: int = 0):
    """shard_map body of one fused CSR unit under the *host* exchange: the
    bucketed operands arrive pre-routed with a leading length-1 shard dim
    (in_specs P(axis, …)); the table arrives as the local (L·blk, E) slice;
    ``roff`` replicated (all-zero — routed indices arrive fully rebased).
    Local pool, then pooled rows back — replicated (``psum``/``pmax``) or
    reduce-scattered to each shard's segment slice."""
    add_op, mul_op = op.semiring.add, op.semiring.mul
    nseg = op.num_segments

    def body(table, roff, ptrs, idxs, *maybe_vals):
        out, counts = _local_pool_csr(
            table, roff, ptrs[0], idxs[0],
            maybe_vals[0][0] if need_vals else None,
            backend=backend, add_op=add_op, mul_op=mul_op, nseg=nseg,
            max_lookups=max_lookups, col_tile=col_tile,
            interpret=interpret)
        return _finish_csr(out, counts, axis=axis, add_op=add_op,
                           replicate=replicate, shards=shards,
                           seg_cap=seg_cap)

    return body


def make_csr_collective_body(op, *, axis: str, backend: str,
                             max_lookups: int, need_vals: bool,
                             interpret: bool, col_tile: int,
                             replicate: bool, shards: int, seg_cap: int):
    """shard_map body of one fused CSR unit under the *collective* exchange.

    The operands arrive as the resident send buffer — per shard a
    ``(S, 2, cap)`` lattice of (segment id, local index) pairs keyed by
    destination (plus a ``(S, cap)`` vals lattice) — and the index exchange
    itself runs on device: ``all_to_all`` transposes the lattice so dim 0
    becomes *received-from*.  Pad slots carry the segment sentinel
    ``num_segments``.  The received streams rebuild a canonical local
    sub-CSR (pallas: stable sort by segment + ``searchsorted`` offsets; the
    kernel then runs exactly as on the host-exchange path) or feed the
    segment-reduce directly (jax backend), and the pooled rows combine
    replicated or reduce-scattered."""
    add_op, mul_op = op.semiring.add, op.semiring.mul
    nseg = op.num_segments

    def body(table, roff, ints, *maybe_vals):
        recv = jax.lax.all_to_all(ints[0], axis, 0, 0)   # dim 0: src shard
        segs = recv[:, 0, :].reshape(-1)
        idxs = recv[:, 1, :].reshape(-1)
        vals = (jax.lax.all_to_all(maybe_vals[0][0], axis, 0, 0).reshape(-1)
                if need_vals else None)
        valid = segs < nseg
        if backend == "pallas":
            order = jnp.argsort(segs)          # stable; sentinels sort last
            ptrs = jnp.searchsorted(
                jnp.take(segs, order),
                jnp.arange(nseg + 1, dtype=segs.dtype)).astype(jnp.int32)
            out, counts = _local_pool_csr(
                table, roff, ptrs, jnp.take(idxs, order),
                jnp.take(vals, order) if need_vals else None,
                backend=backend, add_op=add_op, mul_op=mul_op, nseg=nseg,
                max_lookups=max_lookups, col_tile=col_tile,
                interpret=interpret)
        else:
            segc = jnp.minimum(segs, nseg - 1).astype(jnp.int32)
            rows = jnp.take(table, idxs, axis=0)
            if need_vals:
                w = vals[:, None].astype(rows.dtype)
                rows = rows * w if mul_op == "mul" else rows + w
            ident = jnp.asarray(_ADD_IDENT[add_op], rows.dtype)
            rows = jnp.where(valid[:, None], rows, ident)
            reduce = {"add": jax.ops.segment_sum,
                      "max": jax.ops.segment_max,
                      "min": jax.ops.segment_min}[add_op]
            out = reduce(rows, segc, num_segments=nseg)
            counts = jax.ops.segment_sum(valid.astype(jnp.int32), segc,
                                         num_segments=nseg)
            if add_op != "add":
                out = jnp.where((counts > 0)[:, None], out, ident)
        return _finish_csr(out, counts, axis=axis, add_op=add_op,
                           replicate=replicate, shards=shards,
                           seg_cap=seg_cap)

    return body


def make_gather_body(op, *, axis: str, backend: str, interpret: bool,
                     replicate: bool = True, shards: int = 1,
                     seg_cap: int = 0):
    """shard_map body of one fused gather unit under the host exchange:
    masked local block-gather; partial rows back via psum (exactly one
    shard owns each segment) or reduce-scattered to the owner slices."""
    blk = op.block_rows

    def body(table, roff, idxs, mask):
        i = idxs[0] + roff
        rows = _local_block_gather(table, i, blk, backend, interpret)
        rows = rows * mask[0][:, None, None].astype(rows.dtype)
        if replicate:
            return jax.lax.psum(rows, axis)
        return _reduce_scatter(rows, axis, "add", shards, seg_cap)

    return body


def _local_block_gather(table, i, blk: int, backend: str, interpret: bool):
    if backend == "pallas":
        return kops.block_gather(table, i, block_rows=blk,
                                 interpret=interpret)
    r = i[:, None] * blk + jnp.arange(blk, dtype=i.dtype)[None, :]
    return jnp.take(table, r.reshape(-1), axis=0).reshape(
        i.shape[0], blk, table.shape[-1])


def make_gather_collective_body(op, *, axis: str, backend: str,
                                interpret: bool, replicate: bool,
                                shards: int, seg_cap: int):
    """Collective-exchange gather body: all_to_all the (segment, block id)
    send lattice, block-gather the received local blocks, scatter them to
    their segments (each segment globally owned by exactly one lookup), and
    sum-combine — replicated or reduce-scattered."""
    blk = op.block_rows
    nseg = op.num_segments

    def body(table, roff, ints):
        recv = jax.lax.all_to_all(ints[0], axis, 0, 0)
        segs = recv[:, 0, :].reshape(-1)
        idxs = recv[:, 1, :].reshape(-1)
        valid = segs < nseg
        rows = _local_block_gather(table, idxs, blk, backend, interpret)
        rows = rows * valid[:, None, None].astype(rows.dtype)
        segc = jnp.minimum(segs, nseg - 1).astype(jnp.int32)
        out = jax.ops.segment_sum(rows, segc, num_segments=nseg)
        if replicate:
            return jax.lax.psum(out, axis)
        return _reduce_scatter(out, axis, "add", shards, seg_cap)

    return body


def sharded_call(body, mesh, axis: str, in_specs, out_specs):
    """jit(shard_map(body)) with the caller's explicit operand/output
    PartitionSpecs (the table is always ``P(axis, None)``, ``roff``
    replicated, buckets/send buffers leading-dim sharded; outputs
    replicated or — reduce-scattered — leading-dim sharded).  jit makes the
    per-capacity-bucket trace the retrace unit, mirroring the single-device
    executor."""
    return jax.jit(shard_map(body, mesh=mesh, in_specs=tuple(in_specs),
                             out_specs=out_specs, check_vma=False))


def csr_in_specs(axis: str, *, collective: bool, need_vals: bool) -> tuple:
    """(table, roff, …operands) specs of a CSR unit's shard_map call."""
    if collective:
        ops_ = (P(axis, None, None, None),)          # ints (S, S, 2, cap)
        if need_vals:
            ops_ += (P(axis, None, None),)           # vals (S, S, cap)
    else:
        ops_ = (P(axis, None), P(axis, None))        # ptrs, idxs
        if need_vals:
            ops_ += (P(axis, None),)
    return (P(axis, None), P(None)) + ops_


def gather_in_specs(axis: str, *, collective: bool) -> tuple:
    if collective:
        return (P(axis, None), P(None), P(axis, None, None, None))
    return (P(axis, None), P(None), P(axis, None), P(axis, None))


def pooled_out_specs(axis: str, ndim: int, *, replicate: bool):
    """Replicated pooled output, or the reduce-scattered layout where each
    shard holds its contiguous segment slice (leading dim sharded)."""
    if replicate:
        return P(*(None,) * ndim)
    return P(axis, *(None,) * (ndim - 1))
