"""Vocab-sharded fused programs — the distributed half of the executor.

At serving scale one device cannot hold the fused stacked tables, so the
steady-state executor shards them along the vocab (row) dimension over the
``model`` axis of the production mesh, FlexEMR-style: the *indices* move to
the data, the data never moves to the compute.

Layout (one fused unit, S shards)::

    stacked slots:   [ slot0 rows | slot1 rows | ... ]        (replicated PR2)
    sharded:  shard s holds rows [s·C_t, (s+1)·C_t) of EVERY slot t,
              C_t = ceil(rows_t / S), stacked in slot order:

        global array (S·L, E), L = Σ_t C_t, NamedSharding P(axis, None)
        shard s = [ slot0[s·C0:(s+1)·C0] | slot1[s·C1:(s+1)·C1] | ... ]

    so every shard's *local* stacked table has the same shape (SPMD) and the
    same local slot bases — one replicated ``roff`` stream serves all shards.

Exchange protocol (per step, the access side doing the all-to-all on the
offset stream):

    1. **indices out** — the host (the access unit of the program-scope DAE
       machine) buckets the fused CSR stream by owning shard
       (``owner = idx // C_t``), rebases each index to the owner's local rows
       (``idx - owner·C_t``) and re-emits one valid CSR per shard over ALL
       fused segments.  The buckets are padded to the pow-2 nnz /
       quarter-octave ``max_lookups`` capacities of :mod:`repro.kernels.sls`,
       so the exchange is retrace-free across ragged steps.  A single
       sharded ``device_put`` of the ``(S, …)`` buckets realizes the
       scatter; on a multi-host mesh the identical buckets feed
       ``jax.lax.all_to_all`` (see docs/executor.md).
    2. **local pool** — each shard runs the batched SLS kernel (or the XLA
       reference body) over its local sub-CSR with ``seg_base`` rebased to
       the local slot bases: partial pooled rows for every segment.
    3. **pooled rows back** — the partial pools combine across shards with
       ``psum`` (⊕=add) / ``pmax`` / ``pmin``; locally-empty segments
       contribute the ⊕-identity, and globally-empty segments are fixed to 0
       afterwards (the SLS convention), so a shard receiving zero indices
       for a step is a no-op, not a hazard.

Everything here is pure layout/routing/trace machinery; the executor
(:mod:`repro.core.executor`) owns the caches and the step loop.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ..kernels import ops as kops
from ..launch.sharding import replicated_sharding, table_row_sharding
from .jax_compat import shard_map
from .passes.fuse import FusedGroup

_ADD_IDENT = {"add": 0.0, "max": -np.inf, "min": np.inf}


def shard_count(mesh, axis: str = "model") -> int:
    """Size of ``axis`` in ``mesh`` (1 when mesh is None / axis absent) —
    the executor's single switch between the replicated and sharded paths."""
    if mesh is None:
        return 1
    shape = dict(mesh.shape)
    return int(shape.get(axis, 1))


# ---------------------------------------------------------------------------
# Layout
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShardLayout:
    """Vocab partition of one fused unit's stacked table over S shards."""

    shards: int
    blk: int                 # physical rows per index unit (gather blocks)
    slot_rows: tuple         # index-unit rows of each stacked slot
    slot_caps: tuple         # per-slot per-shard capacity C_t = ceil(rows/S)
    slot_local_base: tuple   # local base of each slot (index units)
    member_slot: tuple       # member i -> slot index

    @property
    def local_rows(self) -> int:
        """Index-unit rows of ONE shard's local stacked table (L)."""
        return sum(self.slot_caps)

    @property
    def table_bytes_per_shard(self) -> int:
        return self.local_rows * self.blk * 4  # per f32 column; ×E outside

    def member_cap(self, i: int) -> int:
        """Ownership divisor of member ``i``'s indices."""
        return self.slot_caps[self.member_slot[i]]

    def member_local_base(self, i: int) -> int:
        return self.slot_local_base[self.member_slot[i]]


def build_layout(group: FusedGroup, shards: int) -> ShardLayout:
    """Partition the group's stacked slots over ``shards`` (ceil-split, so
    ``owner = idx // C_t`` is one integer divide on the access side)."""
    assert shards >= 1, shards
    op0 = group.member_ops[0]
    blk = op0.block_rows if op0.kind == "gather" else 1
    slot_of_base: dict = {}
    slot_rows: list = []
    member_slot: list = []
    for op, base in zip(group.member_ops, group.row_offsets):
        if base not in slot_of_base:
            slot_of_base[base] = len(slot_rows)
            slot_rows.append(op.num_embeddings)
        member_slot.append(slot_of_base[base])
    caps = tuple(-(-r // shards) for r in slot_rows)
    local_base = tuple(int(x) for x in np.cumsum((0,) + caps[:-1]))
    return ShardLayout(shards, blk, tuple(slot_rows), caps, local_base,
                       tuple(member_slot))


def interleave_parts_np(parts: list, layout: ShardLayout) -> np.ndarray:
    """Numpy oracle of the sharded stacking: ``(S·L·blk, E)`` where row block
    ``s`` is shard ``s``'s local stacked table (slot slices, zero-padded)."""
    s, blk = layout.shards, layout.blk
    emb = parts[0].shape[1]
    out = np.zeros((s * layout.local_rows * blk, emb), parts[0].dtype)
    for p, rows, cap, base in zip(parts, layout.slot_rows, layout.slot_caps,
                                  layout.slot_local_base):
        p = np.asarray(p)
        assert p.shape[0] == rows * blk, (p.shape, rows, blk)
        for sh in range(s):
            lo, hi = sh * cap, min((sh + 1) * cap, rows)
            if lo >= hi:
                continue
            dst = (sh * layout.local_rows + base) * blk
            out[dst:dst + (hi - lo) * blk] = p[lo * blk:hi * blk]
    return out


def shard_stack_tables(parts: list, layout: ShardLayout, mesh,
                       axis: str) -> jax.Array:
    """Device-side sharded stacking: pad each slot to ``S·C_t`` rows, stripe
    by shard, concatenate the stripes per shard, and place the ``(S·L·blk, E)``
    result row-sharded over ``axis`` — each device materializes only its own
    ``(L·blk, E)`` slice."""
    s, blk = layout.shards, layout.blk
    stripes = []
    for p, rows, cap in zip(parts, layout.slot_rows, layout.slot_caps):
        p = jnp.asarray(p)
        pad = s * cap * blk - p.shape[0]
        if pad:
            p = jnp.pad(p, ((0, pad), (0, 0)))
        stripes.append(p.reshape(s, cap * blk, p.shape[1]))
    glob = jnp.concatenate(stripes, axis=1).reshape(
        s * layout.local_rows * blk, stripes[0].shape[-1])
    return jax.device_put(glob, table_row_sharding(mesh, axis))


def local_roff(group: FusedGroup, layout: ShardLayout) -> np.ndarray:
    """Per-segment table-offset stream rebased to the LOCAL slot bases —
    identical on every shard (the layout gives all shards the same local
    geometry), so one replicated array serves the whole mesh."""
    return np.concatenate(
        [np.full(op.num_segments, layout.member_local_base(i), np.int32)
         for i, op in enumerate(group.member_ops)])


# ---------------------------------------------------------------------------
# Host-side offset-stream routing (step 1 of the exchange)
# ---------------------------------------------------------------------------

def route_csr(layout: ShardLayout, num_segments: int, seg: np.ndarray,
              idxs: np.ndarray, caps: np.ndarray,
              vals: Optional[np.ndarray] = None) -> dict:
    """Bucket one fused CSR stream by owning shard.

    ``seg``/``idxs``/``caps`` are per-lookup streams (fused segment id,
    global member-table row, ownership divisor of that member).  Returns the
    per-shard re-emitted CSR: ``ptrs (S, B+1)``, per-shard nnz, the
    owner-sorted local indices/values, and the capacity buckets the caller
    should pad to (pow-2 nnz, quarter-octave max_lookups — the same buckets
    the single-device kernel retraces on, so the exchange reuses them)."""
    s = layout.shards
    owner = idxs // caps
    local = (idxs - owner * caps).astype(np.int32)
    counts = np.zeros((s, num_segments), np.int64)
    if len(seg):
        np.add.at(counts, (owner, seg), 1)
    nnz = counts.sum(axis=1)
    ptrs = np.zeros((s, num_segments + 1), np.int32)
    np.cumsum(counts, axis=1, out=ptrs[:, 1:])
    # stable owner sort keeps each shard's stream segment-ordered (the
    # source stream is), so the re-emitted per-shard CSR is already valid
    perm = np.argsort(owner, kind="stable")
    bounds = np.zeros(s + 1, np.int64)
    np.cumsum(nnz, out=bounds[1:])
    cap, ml = kops.exchange_capacity(nnz, counts.max(axis=1, initial=0))
    return {
        "ptrs": ptrs,
        "nnz": nnz,
        "idxs": local[perm],
        "vals": None if vals is None else np.asarray(vals)[perm],
        "bounds": bounds,
        "cap": cap,
        "max_lookups": ml,
    }


def segment_caps(group: FusedGroup, layout: ShardLayout) -> np.ndarray:
    """Per-segment ownership divisor (each segment's member's slot cap) —
    static per signature, computed once at bind time."""
    return np.concatenate(
        [np.full(op.num_segments, layout.member_cap(i), np.int64)
         for i, op in enumerate(group.member_ops)])


def route_gather(layout: ShardLayout, caps: np.ndarray,
                 idxs: np.ndarray) -> dict:
    """Bucket a fused gather's one-index-per-segment stream: every shard
    gets the full (B,) index vector with non-owned slots masked out (a
    gather's 'pool' is the row itself, so the mask IS the partial pool)."""
    owner = idxs // caps
    local = (idxs - owner * caps).astype(np.int32)
    s = layout.shards
    shard_ids = np.arange(s)[:, None]
    mask = (owner[None, :] == shard_ids)
    return {"idxs": np.where(mask, local[None, :], 0).astype(np.int32),
            "mask": mask.astype(np.float32)}


def put_sharded(arr: np.ndarray, mesh, axis: str) -> jax.Array:
    """Scatter a host ``(S, …)`` bucket array so shard ``s`` holds row ``s``
    — the single-controller realization of the indices-out all-to-all."""
    assert arr.ndim == 2, arr.shape   # all exchange buckets are (S, width)
    return jax.device_put(arr, table_row_sharding(mesh, axis))


def put_replicated(arr, mesh) -> jax.Array:
    a = jnp.asarray(arr)
    return jax.device_put(a, replicated_sharding(mesh, a.ndim))


# ---------------------------------------------------------------------------
# Device-side execute bodies (steps 2+3: local pool + pooled rows back)
# ---------------------------------------------------------------------------

def _combine(out, axis: str, add_op: str):
    if add_op == "add":
        return jax.lax.psum(out, axis)
    return (jax.lax.pmax if add_op == "max" else jax.lax.pmin)(out, axis)


def jnp_sls_local(table, ptrs, idxs, vals, roff, *, num_segments: int,
                  add_op: str, mul_op: str):
    """Traceable XLA reference of the local-shard SLS pool (the ``jax``
    backend's execute unit under shard_map).  Locally-empty segments yield
    the ⊕-identity (NOT the SLS zero) so cross-shard merging stays exact;
    the caller zero-fixes globally-empty segments after the combine."""
    cap = idxs.shape[0]
    pos = jnp.arange(cap, dtype=jnp.int32)
    p32 = ptrs.astype(jnp.int32)
    seg = jnp.searchsorted(p32[1:], pos, side="right")
    valid = pos < p32[-1]
    segc = jnp.minimum(seg, num_segments - 1)
    rows = jnp.take(table, idxs + jnp.take(roff, segc), axis=0)
    if vals is not None:
        w = vals[:, None].astype(rows.dtype)
        rows = rows * w if mul_op == "mul" else rows + w
    ident = jnp.asarray(_ADD_IDENT[add_op], rows.dtype)
    rows = jnp.where(valid[:, None], rows, ident)
    reduce = {"add": jax.ops.segment_sum, "max": jax.ops.segment_max,
              "min": jax.ops.segment_min}[add_op]
    out = reduce(rows, segc, num_segments=num_segments)
    if add_op != "add":
        counts = p32[1:] - p32[:-1]
        out = jnp.where((counts > 0)[:, None], out, ident)
    return out


def make_csr_body(op, *, axis: str, backend: str, max_lookups: int,
                  need_vals: bool, interpret: bool, col_tile: int):
    """shard_map body of one fused CSR unit: local pool + pooled-rows-back
    combine.  The bucketed operands arrive with a leading length-1 shard dim
    (in_specs P(axis, …)); the table arrives as the local (L·blk, E) slice;
    ``roff`` replicated."""
    add_op, mul_op = op.semiring.add, op.semiring.mul
    nseg = op.num_segments

    def body(table, roff, ptrs, idxs, *maybe_vals):
        ptrs1, idxs1 = ptrs[0], idxs[0]
        vals1 = maybe_vals[0][0] if need_vals else None
        if backend == "pallas":
            out = kops.sls(table, ptrs1, idxs1, vals1, num_segments=nseg,
                           max_lookups=max_lookups, add_op=add_op,
                           mul_op=mul_op, col_tile=col_tile,
                           interpret=interpret, seg_base=roff)
            if add_op != "add":
                # the kernel zeroed locally-empty segments (SLS convention);
                # restore the ⊕-identity before merging across shards
                counts = ptrs1[1:] - ptrs1[:-1]
                out = jnp.where((counts > 0)[:, None],
                                out, jnp.asarray(_ADD_IDENT[add_op],
                                                 out.dtype))
        else:
            out = jnp_sls_local(table, ptrs1, idxs1, vals1, roff,
                                num_segments=nseg, add_op=add_op,
                                mul_op=mul_op)
        merged = _combine(out, axis, add_op)
        if add_op == "add":
            return merged
        total = jax.lax.psum(ptrs1[1:] - ptrs1[:-1], axis)
        return jnp.where((total > 0)[:, None], merged, 0.0)

    return body


def make_gather_body(op, *, axis: str, backend: str, interpret: bool):
    """shard_map body of one fused gather unit: masked local block-gather,
    partial rows back via psum (exactly one shard owns each segment)."""
    blk = op.block_rows

    def body(table, roff, idxs, mask):
        i = idxs[0] + roff
        if backend == "pallas":
            rows = kops.block_gather(table, i, block_rows=blk,
                                     interpret=interpret)
        else:
            r = i[:, None] * blk + jnp.arange(blk, dtype=i.dtype)[None, :]
            rows = jnp.take(table, r.reshape(-1), axis=0).reshape(
                i.shape[0], blk, table.shape[-1])
        rows = rows * mask[0][:, None, None].astype(rows.dtype)
        return jax.lax.psum(rows, axis)

    return body


def sharded_call(body, mesh, axis: str, n_bucketed: int, out_ndim: int):
    """jit(shard_map(body)): table row-sharded, ``roff`` replicated,
    ``n_bucketed`` per-shard operand buckets, replicated pooled output.
    jit makes the per-capacity-bucket trace the retrace unit, mirroring the
    single-device executor."""
    in_specs = (P(axis, None), P(None)) + \
        tuple(P(axis, *(None,) * 1) for _ in range(n_bucketed))
    out_specs = P(*(None,) * out_ndim)
    return jax.jit(shard_map(body, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False))
