"""Vocab-sharded fused programs — the device half of the sharded executor.

At serving scale one device cannot hold the fused stacked tables, so the
steady-state executor shards them along the vocab (row) dimension over the
``model`` axis of the production mesh, FlexEMR-style: the *indices* move to
the data, the data never moves to the compute.

All layout and routing decisions — the interleaved cold split, the
replicated hot slabs, per-lookup owner/local-address resolution, the
capacity buckets of the exchange — live in the compiled
:class:`~repro.core.access_plan.AccessPlan` (the ``plan-access`` pass).
This module only *realizes* a plan on a mesh:

* :func:`shard_stack_tables` materializes the plan's per-shard local tables
  (cold slices + replicated hot slabs) as one row-sharded global array;
* :func:`put_sharded` / :func:`put_replicated` place the routed ``(S, …)``
  exchange buckets (the single-controller stand-in for the indices-out
  ``all_to_all``);
* ``make_csr_body`` / ``make_gather_body`` / :func:`sharded_call` build the
  ``jit(shard_map(...))`` execute bodies: local pool + pooled-rows-back
  combine (``psum``/``pmax``/``pmin`` with ⊕-identity-exact empty-segment
  handling).

Exchange protocol (per step, the access side doing the all-to-all on the
offset stream):

    1. **indices out** — the host interprets the AccessPlan: every lookup
       resolves to ``(owner shard, fully-rebased local address)``; hot rows
       are replicated so their lookups are *local* on a round-robin shard
       (zero exchange), cold rows route to ``cold_rank // C_t``.  Buckets
       are padded to the plan's capacity lattice, so the exchange is
       retrace-free across ragged steps.
    2. **local pool** — each shard runs the batched SLS kernel (or the XLA
       reference body) over its local sub-CSR; since routed indices arrive
       fully rebased, the kernel's ``seg_base`` stream is all-zero here.
    3. **pooled rows back** — the partial pools combine across shards with
       ``psum`` (⊕=add) / ``pmax`` / ``pmin``; locally-empty segments
       contribute the ⊕-identity, and globally-empty segments are fixed to
       0 afterwards (the SLS convention), so a shard receiving zero indices
       for a step is a no-op, not a hazard.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ..kernels import ops as kops
from ..launch.sharding import replicated_sharding, table_row_sharding
from .access_plan import AccessPlan
from .jax_compat import shard_map

_ADD_IDENT = {"add": 0.0, "max": -np.inf, "min": np.inf}


def shard_count(mesh, axis: str = "model") -> int:
    """Size of ``axis`` in ``mesh`` (1 when mesh is None / axis absent) —
    the executor's single switch between the replicated and sharded paths."""
    if mesh is None:
        return 1
    shape = dict(mesh.shape)
    return int(shape.get(axis, 1))


# ---------------------------------------------------------------------------
# Layout realization: the plan's per-shard tables on a mesh
# ---------------------------------------------------------------------------

def shard_stack_tables(parts: list, plan: AccessPlan, mesh,
                       axis: str) -> jax.Array:
    """Device-side sharded stacking of one fused unit per its AccessPlan:
    each slot's cold tail is striped over the shards (ceil-split, padded),
    its hot slab is replicated into every shard's local table, and the
    ``(S·L·blk, E)`` result is placed row-sharded over ``axis`` — each
    device materializes only its own ``(L·blk, E)`` slice."""
    s, blk = plan.shards, plan.blk
    cold_stripes, hot_stripes = [], []
    for slot, p in zip(plan.slots, parts):
        p = jnp.asarray(p)
        emb = p.shape[1]
        if slot.hot_rows:
            cold = jnp.take(p, plan.phys_rows(slot.cold_ids), axis=0)
            hot = jnp.take(p, plan.phys_rows(slot.hot_ids), axis=0)
        else:
            cold, hot = p, None
        pad = s * slot.cap * blk - cold.shape[0]
        if pad:
            cold = jnp.pad(cold, ((0, pad), (0, 0)))
        cold_stripes.append(cold.reshape(s, slot.cap * blk, emb))
        if hot is not None:
            hot_stripes.append(jnp.broadcast_to(hot[None], (s,) + hot.shape))
    glob = jnp.concatenate(cold_stripes + hot_stripes, axis=1).reshape(
        s * plan.local_rows * blk, cold_stripes[0].shape[-1])
    return jax.device_put(glob, table_row_sharding(mesh, axis))


def put_sharded(arr: np.ndarray, mesh, axis: str) -> jax.Array:
    """Scatter a host ``(S, …)`` bucket array so shard ``s`` holds row ``s``
    — the single-controller realization of the indices-out all-to-all."""
    assert arr.ndim == 2, arr.shape   # all exchange buckets are (S, width)
    return jax.device_put(arr, table_row_sharding(mesh, axis))


def put_replicated(arr, mesh) -> jax.Array:
    a = jnp.asarray(arr)
    return jax.device_put(a, replicated_sharding(mesh, a.ndim))


# ---------------------------------------------------------------------------
# Device-side execute bodies (steps 2+3: local pool + pooled rows back)
# ---------------------------------------------------------------------------

def _combine(out, axis: str, add_op: str):
    if add_op == "add":
        return jax.lax.psum(out, axis)
    return (jax.lax.pmax if add_op == "max" else jax.lax.pmin)(out, axis)


def jnp_sls_local(table, ptrs, idxs, vals, roff, *, num_segments: int,
                  add_op: str, mul_op: str):
    """Traceable XLA reference of the local-shard SLS pool (the ``jax``
    backend's execute unit under shard_map).  Locally-empty segments yield
    the ⊕-identity (NOT the SLS zero) so cross-shard merging stays exact;
    the caller zero-fixes globally-empty segments after the combine."""
    cap = idxs.shape[0]
    pos = jnp.arange(cap, dtype=jnp.int32)
    p32 = ptrs.astype(jnp.int32)
    seg = jnp.searchsorted(p32[1:], pos, side="right")
    valid = pos < p32[-1]
    segc = jnp.minimum(seg, num_segments - 1)
    rows = jnp.take(table, idxs + jnp.take(roff, segc), axis=0)
    if vals is not None:
        w = vals[:, None].astype(rows.dtype)
        rows = rows * w if mul_op == "mul" else rows + w
    ident = jnp.asarray(_ADD_IDENT[add_op], rows.dtype)
    rows = jnp.where(valid[:, None], rows, ident)
    reduce = {"add": jax.ops.segment_sum, "max": jax.ops.segment_max,
              "min": jax.ops.segment_min}[add_op]
    out = reduce(rows, segc, num_segments=num_segments)
    if add_op != "add":
        counts = p32[1:] - p32[:-1]
        out = jnp.where((counts > 0)[:, None], out, ident)
    return out


def make_csr_body(op, *, axis: str, backend: str, max_lookups: int,
                  need_vals: bool, interpret: bool, col_tile: int):
    """shard_map body of one fused CSR unit: local pool + pooled-rows-back
    combine.  The bucketed operands arrive with a leading length-1 shard dim
    (in_specs P(axis, …)); the table arrives as the local (L·blk, E) slice;
    ``roff`` replicated (all-zero — routed indices arrive fully rebased)."""
    add_op, mul_op = op.semiring.add, op.semiring.mul
    nseg = op.num_segments

    def body(table, roff, ptrs, idxs, *maybe_vals):
        ptrs1, idxs1 = ptrs[0], idxs[0]
        vals1 = maybe_vals[0][0] if need_vals else None
        if backend == "pallas":
            out = kops.sls(table, ptrs1, idxs1, vals1, num_segments=nseg,
                           max_lookups=max_lookups, add_op=add_op,
                           mul_op=mul_op, col_tile=col_tile,
                           interpret=interpret, seg_base=roff)
            if add_op != "add":
                # the kernel zeroed locally-empty segments (SLS convention);
                # restore the ⊕-identity before merging across shards
                counts = ptrs1[1:] - ptrs1[:-1]
                out = jnp.where((counts > 0)[:, None],
                                out, jnp.asarray(_ADD_IDENT[add_op],
                                                 out.dtype))
        else:
            out = jnp_sls_local(table, ptrs1, idxs1, vals1, roff,
                                num_segments=nseg, add_op=add_op,
                                mul_op=mul_op)
        merged = _combine(out, axis, add_op)
        if add_op == "add":
            return merged
        total = jax.lax.psum(ptrs1[1:] - ptrs1[:-1], axis)
        return jnp.where((total > 0)[:, None], merged, 0.0)

    return body


def make_gather_body(op, *, axis: str, backend: str, interpret: bool):
    """shard_map body of one fused gather unit: masked local block-gather,
    partial rows back via psum (exactly one shard owns each segment)."""
    blk = op.block_rows

    def body(table, roff, idxs, mask):
        i = idxs[0] + roff
        if backend == "pallas":
            rows = kops.block_gather(table, i, block_rows=blk,
                                     interpret=interpret)
        else:
            r = i[:, None] * blk + jnp.arange(blk, dtype=i.dtype)[None, :]
            rows = jnp.take(table, r.reshape(-1), axis=0).reshape(
                i.shape[0], blk, table.shape[-1])
        rows = rows * mask[0][:, None, None].astype(rows.dtype)
        return jax.lax.psum(rows, axis)

    return body


def sharded_call(body, mesh, axis: str, n_bucketed: int, out_ndim: int):
    """jit(shard_map(body)): table row-sharded, ``roff`` replicated,
    ``n_bucketed`` per-shard operand buckets, replicated pooled output.
    jit makes the per-capacity-bucket trace the retrace unit, mirroring the
    single-device executor."""
    in_specs = (P(axis, None), P(None)) + \
        tuple(P(axis, *(None,) * 1) for _ in range(n_bucketed))
    out_specs = P(*(None,) * out_ndim)
    return jax.jit(shard_map(body, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False))
