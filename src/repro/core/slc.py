"""Structured Lookup-Compute (SLC) IR — the paper's contribution #5 (§6).

The SLC IR extends structured control flow with *streams* (lookup-side
values produced by the access unit) and *callbacks* (execute-side compute
wrapped inside the loops that trigger it).  Crucially — and this is the whole
point of the IR — callbacks read stream values through explicit
``to_val`` conversions (:class:`ToVal`), so the data flow between access and
execute code is *not* (de)serialized through queues yet.  That keeps global
analyses (vectorization, bufferization, code motion across the
access/execute boundary) straightforward; the queue machinery only appears
after lowering to DLC (:mod:`repro.core.dlc`).

Node inventory (paper Fig 12 grammar, adapted):

=================  =========================================================
``SlcFor``         ``slc.for`` / ``slcv.for`` (when ``vlen`` is set); may own
                   loop-carried execute-side counters (``carry``, §7.3)
``MemStr``         ``slc.mem_str`` — load stream
``AluStr``         ``slc.alu_str`` — integer ALU stream
``BufStr``         ``slcv.buf_str`` — buffer stream (§7.2), reset per
                   enclosing iteration
``PushBuf``        ``slc.push`` into a buffer stream
``Callback``       ``slc.callback`` — imperative compute (SCF stmts + ToVal)
``StoreBuf``       whole-vector store of a buffer into a memref row; the
                   bufferized dual of the element-wise accumulate callback.
                   ``as_store_stream=True`` marks it for access-unit direct
                   store (model-specific opt, §7.4)
=================  =========================================================
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Union

from .ops import EmbeddingOp
from . import scf

# ---------------------------------------------------------------------------
# Stream-index expressions (what MemStr/AluStr indices may contain)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class StreamRef:
    name: str


SIdx = Union[scf.Const, scf.Param, StreamRef, "SBin"]


@dataclasses.dataclass(frozen=True)
class SBin:
    op: str
    a: SIdx
    b: SIdx


# ---------------------------------------------------------------------------
# Callback-body expression extensions (usable inside scf exprs)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ToVal:
    """slc.to_val — materialize the current stream value on the core."""
    stream: str


@dataclasses.dataclass(frozen=True)
class DotBuf:
    """Dot product of two buffer streams (fusedmm's SDDMM reduction)."""
    buf_a: str
    buf_b: str
    fn: str = "identity"   # post-reduction scalar function


# ---------------------------------------------------------------------------
# SLC statements
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class MemStr:
    stream: str
    memref: str
    indices: tuple  # of SIdx


@dataclasses.dataclass
class AluStr:
    stream: str
    op: str
    a: SIdx
    b: SIdx


@dataclasses.dataclass
class AccStr:
    """Accumulation stream (paper §7.4): the access unit tracks segment
    boundaries by accumulating lengths instead of loading offsets.  Value is
    the *exclusive* running sum (the total BEFORE this iteration's add)."""
    stream: str
    src: object        # SIdx added per enclosing-loop iteration
    init: int = 0


@dataclasses.dataclass
class BufStr:
    stream: str


@dataclasses.dataclass
class PushBuf:
    buf: str
    src: str  # source stream


@dataclasses.dataclass
class Callback:
    body: list  # scf stmts, exprs may contain ToVal / DotBuf


@dataclasses.dataclass
class StoreBuf:
    memref: str
    row_indices: tuple          # of callback exprs (ToVal / VarRef / Const)
    buf: str
    accumulate: Optional[str]   # None overwrite, else semiring-add name
    scale: Optional[object] = None   # optional callback expr multiplied in
    as_store_stream: bool = False    # §7.4: bypass the core entirely


@dataclasses.dataclass
class SlcFor:
    stream: str
    lb: SIdx
    ub: SIdx
    body: list
    vlen: Optional[int] = None      # set by the vectorize pass (slcv.for)
    carry: dict = dataclasses.field(default_factory=dict)  # var -> init


SlcNode = Union[MemStr, AluStr, AccStr, BufStr, PushBuf, Callback,
                StoreBuf, SlcFor]


@dataclasses.dataclass
class SlcFunc:
    name: str
    memrefs: dict
    params: dict
    body: list
    op: EmbeddingOp
    # optimization record: which passes ran (drives DLC lowering + backends)
    opt: dict = dataclasses.field(default_factory=lambda: {
        "vectorized": False, "vlen": None, "bufferized": False,
        "queue_aligned": False, "store_streams": False,
    })


# ---------------------------------------------------------------------------
# Structural helpers / verifier
# ---------------------------------------------------------------------------

def walk(body, fn, depth=0):
    for node in body:
        fn(node, depth)
        if isinstance(node, SlcFor):
            walk(node.body, fn, depth + 1)
        elif isinstance(node, Callback):
            pass


def loops(body):
    out = []
    walk(body, lambda n, d: out.append((n, d)) if isinstance(n, SlcFor) else None)
    return out


def innermost_loop(fn: SlcFunc) -> Optional[SlcFor]:
    ls = loops(fn.body)
    if not ls:
        return None
    return max(ls, key=lambda t: t[1])[0]


def streams_defined(body) -> set:
    out = set()

    def f(n, d):
        if isinstance(n, (MemStr, AluStr, AccStr, BufStr)):
            out.add(n.stream)
        elif isinstance(n, SlcFor):
            out.add(n.stream)
    walk(body, f)
    return out


def _expr_streams(e, acc):
    if isinstance(e, ToVal):
        acc.add(e.stream)
    elif isinstance(e, DotBuf):
        acc.add(e.buf_a)
        acc.add(e.buf_b)
    elif isinstance(e, scf.Bin):
        _expr_streams(e.a, acc)
        _expr_streams(e.b, acc)
    elif isinstance(e, scf.Apply):
        _expr_streams(e.a, acc)
    elif isinstance(e, scf.Load):
        for i in e.indices:
            _expr_streams(i, acc)


def callback_streams(node) -> set:
    """Streams a callback/StoreBuf converts to values (its queue operands)."""
    acc: set = set()
    if isinstance(node, StoreBuf):
        for i in node.row_indices:
            _expr_streams(i, acc)
        acc.add(node.buf)
        if node.scale is not None:
            _expr_streams(node.scale, acc)
        return acc

    def stmts(body):
        for s in body:
            if isinstance(s, (scf.Let, scf.SetVar)):
                _expr_streams(s.value, acc)
            elif isinstance(s, scf.Store):
                for i in s.indices:
                    _expr_streams(i, acc)
                _expr_streams(s.value, acc)
            elif isinstance(s, scf.For):
                _expr_streams(s.lb, acc)
                _expr_streams(s.ub, acc)
                stmts(s.body)
    stmts(node.body)
    return acc


class SlcVerifyError(Exception):
    pass


def verify(fn: SlcFunc):
    """Structural invariants every SLC function must satisfy."""
    defined: set = set(fn.params)

    def check_sidx(e, scope):
        if isinstance(e, StreamRef):
            if e.name not in scope:
                raise SlcVerifyError(f"use of undefined stream {e.name!r}")
        elif isinstance(e, SBin):
            check_sidx(e.a, scope)
            check_sidx(e.b, scope)

    def rec(body, scope):
        scope = set(scope)
        for node in body:
            if isinstance(node, SlcFor):
                check_sidx(node.lb, scope)
                check_sidx(node.ub, scope)
                rec(node.body, scope | {node.stream})
                scope.add(node.stream)
            elif isinstance(node, MemStr):
                if node.memref not in fn.memrefs:
                    raise SlcVerifyError(f"unknown memref {node.memref!r}")
                if not fn.memrefs[node.memref].read_only:
                    raise SlcVerifyError(
                        f"mem_str over writable memref {node.memref!r}: the "
                        "access unit may only read read-only data (§6.2)")
                for i in node.indices:
                    check_sidx(i, scope)
                scope.add(node.stream)
            elif isinstance(node, AluStr):
                check_sidx(node.a, scope)
                check_sidx(node.b, scope)
                scope.add(node.stream)
            elif isinstance(node, AccStr):
                check_sidx(node.src, scope)
                scope.add(node.stream)
            elif isinstance(node, BufStr):
                scope.add(node.stream)
            elif isinstance(node, PushBuf):
                if node.buf not in scope or node.src not in scope:
                    raise SlcVerifyError("push into/from undefined stream")
            elif isinstance(node, (Callback, StoreBuf)):
                for s in callback_streams(node):
                    if s not in scope:
                        raise SlcVerifyError(
                            f"callback reads undefined stream {s!r}")
            else:
                raise SlcVerifyError(f"unknown node {node!r}")
    rec(fn.body, set())
    return True


def pretty(fn: SlcFunc) -> str:
    """Render SLC in the paper's surface syntax (Fig 15) for inspection."""
    lines = [f"void {fn.name}(...)  // opt={ {k: v for k, v in fn.opt.items() if v} }"]

    def sidx(e):
        if isinstance(e, scf.Const):
            return str(e.value)
        if isinstance(e, scf.Param):
            return e.name
        if isinstance(e, StreamRef):
            return e.name
        if isinstance(e, SBin):
            return f"({sidx(e.a)}{e.op}{sidx(e.b)})"
        return repr(e)

    def expr(e):
        if isinstance(e, ToVal):
            return f"slc.to_val({e.stream})"
        if isinstance(e, DotBuf):
            d = f"dot({e.buf_a},{e.buf_b})"
            return d if e.fn == "identity" else f"{e.fn}({d})"
        if isinstance(e, scf.Const):
            return str(e.value)
        if isinstance(e, scf.Param):
            return e.name
        if isinstance(e, scf.VarRef):
            return e.name
        if isinstance(e, scf.Load):
            return f"{e.memref}[{','.join(expr(i) for i in e.indices)}]"
        if isinstance(e, scf.Bin):
            return f"({expr(e.a)}{e.op}{expr(e.b)})"
        if isinstance(e, scf.Apply):
            return f"{e.fn}({expr(e.a)})"
        return repr(e)

    def stmt(s, ind):
        pad = "  " * ind
        if isinstance(s, scf.Let):
            lines.append(f"{pad}{s.var} = {expr(s.value)};")
        elif isinstance(s, scf.SetVar):
            lines.append(f"{pad}{s.var} = {expr(s.value)};")
        elif isinstance(s, scf.Store):
            tgt = f"{s.memref}[{','.join(expr(i) for i in s.indices)}]"
            op = {"add": "+=", None: "="}.get(s.accumulate, f"{s.accumulate}=")
            lines.append(f"{pad}{tgt} {op} {expr(s.value)};")
        elif isinstance(s, scf.For):
            lines.append(f"{pad}for({s.var}={expr(s.lb)}; {s.var}<{expr(s.ub)}; {s.var}++){{")
            for b in s.body:
                stmt(b, ind + 1)
            lines.append(f"{pad}}}")

    def rec(body, ind):
        pad = "  " * ind
        for node in body:
            if isinstance(node, SlcFor):
                v = f"<{node.vlen}>" if node.vlen else ""
                carry = f" carry{node.carry}" if node.carry else ""
                lines.append(
                    f"{pad}slc{'v' if node.vlen else ''}.for{v}(stream {node.stream}"
                    f" from {sidx(node.lb)} to {sidx(node.ub)}){carry}{{")
                rec(node.body, ind + 1)
                lines.append(f"{pad}}}")
            elif isinstance(node, MemStr):
                idx = ",".join(sidx(i) for i in node.indices)
                lines.append(f"{pad}stream {node.stream} = slc.mem_str({node.memref}[{idx}]);")
            elif isinstance(node, AluStr):
                lines.append(f"{pad}stream {node.stream} = slc.alu_str({sidx(node.a)}{node.op}{sidx(node.b)});")
            elif isinstance(node, AccStr):
                lines.append(f"{pad}stream {node.stream} = slc.acc_str(+= {sidx(node.src)}, init={node.init});")
            elif isinstance(node, BufStr):
                lines.append(f"{pad}stream {node.stream} = slcv.buf_str();")
            elif isinstance(node, PushBuf):
                lines.append(f"{pad}slc.push({node.buf}, {node.src});")
            elif isinstance(node, StoreBuf):
                row = ",".join(expr(i) for i in node.row_indices)
                sc = f"{expr(node.scale)} * " if node.scale is not None else ""
                op = {"add": "+=", None: "="}.get(node.accumulate, f"{node.accumulate}=")
                ss = "  // store-stream (access-unit direct)" if node.as_store_stream else ""
                lines.append(f"{pad}{node.memref}[{row},:] {op} {sc}vec({node.buf});{ss}")
            elif isinstance(node, Callback):
                lines.append(f"{pad}slc.callback{{")
                for s in node.body:
                    stmt(s, ind + 1)
                lines.append(f"{pad}}}")
    rec(fn.body, 1)
    return "\n".join(lines)
