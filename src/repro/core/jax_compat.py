"""Version-compat shims for the narrow jax API surface we depend on.

The repo targets current jax but must run on the 0.4.x line too (this
container ships 0.4.37): ``jax.shard_map`` graduated from
``jax.experimental.shard_map`` in 0.5/0.6 and renamed its replication-check
kwarg (``check_rep`` → ``check_vma``).  Callers use :func:`shard_map` below
with the *new* spelling; the shim rewrites for old versions.

Mesh-related shims (``axis_types_kw``, ``mesh_context``) live in
:mod:`repro.launch.mesh` next to the mesh constructors.
"""
from __future__ import annotations

import jax


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False):
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as sm_old
    return sm_old(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=check_vma)
