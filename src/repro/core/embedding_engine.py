"""Sharded embedding engine — Ember's technique as a first-class framework
feature.

Every assigned architecture funnels its irregular-lookup work through this
module: token embedding (vocab-sharded tables = the paper's embedding
tables), the unembedding/logits projection, and the vocab-parallel cross
entropy that never materializes unsharded logits.

Strategy selection mirrors emberc's job (pick the best lookup schedule for
the target):

``take``          plain ``jnp.take`` — small/replicated tables;
``one_hot``       MXU-friendly one-hot matmul — tiny vocabularies only;
``masked_psum``   shard_map: mask ids to the local vocab shard, local take,
                  ``psum`` over the vocab axis — the production path for
                  model-sharded tables (the DAE decomposition at cluster
                  scale: local gather = access, psum = combine);
``masked_psum_scatter``  same but reduce-scatters the result over the
                  sequence axis (sequence parallelism) — halves the
                  collective bytes when the consumer is seq-sharded;
``pallas``        the emberc-compiled DAE gather kernel (single-device TPU
                  runtime path) — compiled through the *program-level*
                  pipeline, so repeated lookups of the same shape are
                  compile-cache hits.

The engine also builds the :class:`~repro.core.ops.EmbeddingProgram` that
describes ALL of a model step's irregular lookups (token embedding + the
vocab-parallel label gather + optional MoE dispatch), which the runtimes
compile once and reuse across steps (:func:`model_embedding_program`).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from .jax_compat import shard_map
from .ops import EmbeddingOp, EmbeddingProgram, single_op_program


def choose_strategy(vocab_size: int, sharded: bool) -> str:
    if not sharded:
        return "take"
    if vocab_size <= 1024:
        return "one_hot"
    return "masked_psum"


# ---------------------------------------------------------------------------
# Lookup
# ---------------------------------------------------------------------------

def lookup(table: jax.Array, ids: jax.Array, *, mesh=None,
           vocab_axis: Optional[str] = None, strategy: str = "take",
           data_axes: tuple = (), seq_scatter: bool = False) -> jax.Array:
    """Embed ``ids (..., S)`` from ``table (V, D)`` → ``(..., S, D)``.

    ``data_axes`` are the mesh axes the leading (batch) dim of ``ids`` is
    sharded over.  With ``seq_scatter`` the result comes back sharded over
    the vocab axis along S (sequence parallelism via reduce-scatter).
    """
    if strategy == "take":
        return jnp.take(table, ids, axis=0)
    if strategy == "one_hot":
        oh = jax.nn.one_hot(ids, table.shape[0], dtype=table.dtype)
        return oh @ table
    if strategy in ("masked_psum", "masked_psum_scatter"):
        assert mesh is not None and vocab_axis is not None
        return _masked_lookup(table, ids, mesh, vocab_axis, data_axes,
                              seq_scatter or strategy.endswith("scatter"))
    if strategy == "pallas":
        return _pallas_lookup(table, ids)
    raise ValueError(strategy)


def _pallas_lookup(table, ids):
    """Single-device DAE path: compile (cached) + run the gather kernel."""
    from . import backend_pallas as bp
    from .pipeline import compile_program
    from ..kernels.ops import default_interpret
    n_tok = int(np.prod(ids.shape))
    op = EmbeddingOp("gather", num_segments=n_tok,
                     num_embeddings=int(table.shape[0]),
                     emb_len=int(table.shape[1]))
    pres = compile_program(single_op_program(op, "lookup"), "O3")
    out = bp.execute(pres.units[0].result,
                     {"table": table, "idxs": ids.reshape(-1)},
                     interpret=default_interpret())
    return out.reshape(*ids.shape, table.shape[1])


def model_embedding_program(*, vocab_size: int, d_model: int, tokens: int,
                            extra_ops: tuple = (),
                            name: str = "model-step") -> EmbeddingProgram:
    """The irregular-lookup program of one model step.

    Token embedding and the label-logit gather of the vocab-parallel cross
    entropy both read the embed table — annotated as a shared table so the
    fusion pass stacks it once; ``extra_ops`` appends model-specific lookups
    (e.g. :func:`repro.models.moe.dispatch_op`).  The result is what
    runtimes hand to :func:`repro.core.pipeline.compile_program`, whose
    cache makes per-step recompiles free.
    """
    ops = (("tok_embed",
            EmbeddingOp("gather", num_segments=tokens,
                        num_embeddings=vocab_size, emb_len=d_model)),
           ("label_gather",
            EmbeddingOp("gather", num_segments=tokens,
                        num_embeddings=vocab_size, emb_len=d_model)))
    return EmbeddingProgram(name, ops + tuple(extra_ops),
                            shared_tables=(("tok_embed", "label_gather"),))


def _masked_lookup(table, ids, mesh, vocab_axis, data_axes, seq_scatter):
    def body(tbl, ids_):
        # tbl is the local vocab shard (V/n, D); ids_ the local data shard
        shard = jax.lax.axis_index(vocab_axis)
        v_local = tbl.shape[0]
        lo = shard * v_local
        local = ids_ - lo
        in_range = (local >= 0) & (local < v_local)
        local = jnp.clip(local, 0, v_local - 1)
        emb = jnp.take(tbl, local, axis=0)          # access: local gather
        emb = jnp.where(in_range[..., None], emb, 0.0)
        if seq_scatter:                              # combine: reduce-scatter
            return jax.lax.psum_scatter(emb, vocab_axis,
                                        scatter_dimension=emb.ndim - 2,
                                        tiled=True)
        return jax.lax.psum(emb, vocab_axis)         # combine: all-reduce

    # batch dim sharded over ALL data axes jointly (one dim, axis tuple)
    dp = tuple(data_axes) if data_axes else None
    ids_spec = P(dp, *(None,) * (ids.ndim - 1))
    out_tail = (vocab_axis, None) if seq_scatter else (None, None)
    out_spec = P(dp, *(None,) * (ids.ndim - 2), *out_tail)
    return shard_map(body, mesh=mesh,
                     in_specs=(P(vocab_axis, None), ids_spec),
                     out_specs=out_spec, check_vma=False)(table, ids)


# ---------------------------------------------------------------------------
# Unembedding + vocab-parallel cross entropy (Megatron-style)
# ---------------------------------------------------------------------------

def logits(x: jax.Array, table: jax.Array) -> jax.Array:
    """x (..., D) @ table.T (D, V) → (..., V); vocab-sharded under GSPMD."""
    return jax.lax.dot_general(
        x, table, (((x.ndim - 1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)


def xent_vocab_parallel(x: jax.Array, table: jax.Array, labels: jax.Array, *,
                        mesh, vocab_axis: str,
                        data_axes: tuple = ()) -> jax.Array:
    """Fused unembed + softmax cross entropy over a vocab-sharded table.

    Never materializes an unsharded (tokens, V) logits tensor: each shard
    computes local logits, the log-sum-exp reduces with ``pmax``/``psum``
    over the vocab axis, and the label logit is fetched from whichever shard
    owns it.  Returns the mean loss (replicated).
    """
    def body(x_, tbl, labels_):
        shard = jax.lax.axis_index(vocab_axis)
        v_local = tbl.shape[0]
        lo = shard * v_local
        lg = jax.lax.dot_general(
            x_, tbl, (((x_.ndim - 1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)          # (..., V/n)
        # the max is a constant stability shift — stop_gradient *before*
        # pmax (which has no JVP rule) so no tangent ever reaches it
        m = jax.lax.pmax(jax.lax.stop_gradient(jnp.max(lg, axis=-1)),
                         vocab_axis)
        se = jax.lax.psum(jnp.sum(jnp.exp(lg - m[..., None]), axis=-1),
                          vocab_axis)
        lse = m + jnp.log(se)
        local_label = labels_ - lo
        in_range = (local_label >= 0) & (local_label < v_local)
        local_label = jnp.clip(local_label, 0, v_local - 1)
        picked = jnp.take_along_axis(lg, local_label[..., None],
                                     axis=-1)[..., 0]
        label_logit = jax.lax.psum(jnp.where(in_range, picked, 0.0),
                                   vocab_axis)
        loss = jnp.mean(lse - label_logit)
        for ax in data_axes:
            loss = jax.lax.pmean(loss, ax)   # mean over all tokens
        return loss

    dp = tuple(data_axes) if data_axes else None
    x_spec = P(dp, *(None,) * (x.ndim - 1))
    lbl_spec = P(dp, *(None,) * (labels.ndim - 1))
    loss = shard_map(
        body, mesh=mesh,
        in_specs=(x_spec, P(vocab_axis, None), lbl_spec),
        out_specs=P(),
        check_vma=False)(x, table, labels)
    return loss
