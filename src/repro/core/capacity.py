"""Capacity-bucket lattice — THE canonical shape-bucketing policy.

Every ragged extent that reaches a kernel (`max_lookups` grid size, the nnz
of the idxs/vals streams, the per-shard exchange buckets) is a *static*
specialization parameter: each distinct value is a distinct jit trace.  The
steady-state paths therefore pad to a small lattice of capacity buckets so a
ragged step sequence reuses one trace per bucket.

This module is the single home of that policy.  It used to be spread over
:mod:`repro.kernels.sls` and re-derived by the executor and the shard
planner; now the kernel layer re-exports it and the compiled
:class:`~repro.core.access_plan.AccessPlan` carries a
:class:`CapacityLattice` instance so host marshaling can never drift from
what the kernels retrace on.
"""
from __future__ import annotations

import dataclasses

import numpy as np


def lookup_capacity(n: int) -> int:
    """Round a ragged extent up to its power-of-two capacity bucket (>= 1).

    Used for the nnz of the idxs/vals operand streams: the bucket only
    controls the retrace count (padding slots are masked by the CSR ``ptrs``
    bounds), so the coarse power-of-two lattice is right.
    """
    n = max(int(n), 1)
    return 1 << (n - 1).bit_length()


def grid_capacity(n: int) -> int:
    """Quarter-octave bucket for the ``max_lookups`` *grid* extent.

    Unlike the operand buffers, every padded ``max_lookups`` slot is a real
    masked grid step, so a 2x overshoot doubles the kernel's inner loop.
    Rounding to the next quarter of a power of two keeps the overshoot
    <= 33% while still giving ragged steps only ~4 buckets per octave."""
    n = max(int(n), 1)
    if n <= 4:
        return n
    q = 1 << ((n - 1).bit_length() - 2)
    return -(-n // q) * q


def exchange_capacity(nnz_per_shard, max_seg_per_shard) -> tuple:
    """Joint ``(nnz_cap, max_lookups)`` bucket of one vocab-sharded exchange
    step (see :mod:`repro.core.access_plan`): every shard's routed bucket is
    padded to the SAME capacities — SPMD needs uniform shapes — so the
    bucket is the max over shards, rounded with the same pow-2 /
    quarter-octave rules the single-device executor retraces on.  A shard
    receiving zero indices still gets the >=1-slot bucket (all-empty CSR is
    a valid kernel input)."""
    nnz = max((int(n) for n in nnz_per_shard), default=0)
    seg = max((int(n) for n in max_seg_per_shard), default=0)
    return lookup_capacity(nnz), grid_capacity(seg)


def collective_exchange_capacity(pair_counts, max_seg_per_shard) -> tuple:
    """Joint ``(pair_cap, max_lookups)`` bucket of one device-collective
    exchange step: every ``(src, dst)`` send bucket of the ``all_to_all``
    must have the SAME static width (the collective splits uniformly), so
    the bucket is the max over all shard pairs, rounded with the same pow-2
    rule as the single-device nnz streams; ``max_lookups`` stays the
    quarter-octave grid bucket over the *receiving* shards' densest
    segment.  An all-empty step still gets the >=1-slot bucket."""
    pair = max((int(n) for n in np.ravel(pair_counts)), default=0)
    seg = max((int(n) for n in max_seg_per_shard), default=0)
    return lookup_capacity(pair), grid_capacity(seg)


@dataclasses.dataclass(frozen=True)
class CapacityLattice:
    """The bucketing policy as a value, carried by every AccessPlan.

    One instance per plan keeps the lattice an explicit part of the compiled
    access artifact (a future backend could subclass with different
    rounding); today there is exactly one policy, shared by all plans."""

    def lookup_capacity(self, n: int) -> int:
        return lookup_capacity(n)

    def grid_capacity(self, n: int) -> int:
        return grid_capacity(n)

    def exchange_capacity(self, nnz_per_shard, max_seg_per_shard) -> tuple:
        return exchange_capacity(nnz_per_shard, max_seg_per_shard)

    def collective_exchange_capacity(self, pair_counts,
                                     max_seg_per_shard) -> tuple:
        return collective_exchange_capacity(pair_counts, max_seg_per_shard)


DEFAULT_LATTICE = CapacityLattice()
