"""Executable semantics for the SLC and DLC IRs.

These interpreters are the semantic oracles of the compiler: every pass and
lowering is property-tested by checking

    interp_scf(scf) == interp_slc(decouple(scf))
                    == interp_slc(optimized)
                    == interp_dlc(lower_to_dlc(optimized))
                    == backend outputs

The DLC interpreter is *queue-faithful*: it first runs the access-unit
(lookup) program to completion, materializing the control/data queues as the
TMU would (paper Fig 10d), and only then runs the execute-unit program,
which may touch memory solely through pops, workspace reads, and stores.
The queues returned alongside the result feed the cost model and the queue
conservation property tests.
"""
from __future__ import annotations

from collections import deque

import numpy as np

from . import scf
from .ops import out_shape
from .slc import (AccStr, AluStr, BufStr, Callback, DotBuf, MemStr, PushBuf,
                  SBin, SlcFor, SlcFunc, StoreBuf, StreamRef, ToVal)

_BINOPS = {
    "+": lambda a, b: a + b,
    "-": lambda a, b: a - b,
    "*": lambda a, b: a * b,
    "/": lambda a, b: a / b,
    "min": np.minimum,
    "max": np.maximum,
}

_ACC = {
    "add": lambda a, b: a + b,
    "max": np.maximum,
    "min": np.minimum,
}

_FNS = {"identity": lambda x: x, "relu": lambda x: np.maximum(x, 0.0),
        "hsum": np.sum}


# ---------------------------------------------------------------------------
# SLC interpreter
# ---------------------------------------------------------------------------

class _SlcState:
    def __init__(self, fn: SlcFunc, inputs: dict):
        self.fn = fn
        self.acc: dict = {}     # AccStr running sums (per program run)
        self.mem = dict(inputs)
        op = fn.op
        init = op.semiring.identity if op.has_compute else 0.0
        self.mem["out"] = np.full(out_shape(op), init, np.dtype(op.dtype))
        self.streams: dict = {}
        self.vars: dict = {}     # execute-unit locals + carries

    def sidx(self, e):
        if isinstance(e, scf.Const):
            return e.value
        if isinstance(e, scf.Param):
            return self.fn.params[e.name]
        if isinstance(e, StreamRef):
            return self.streams[e.name]
        if isinstance(e, SBin):
            return _BINOPS[e.op](self.sidx(e.a), self.sidx(e.b))
        raise TypeError(e)

    def expr(self, e):
        if isinstance(e, ToVal):
            return self.streams[e.stream]
        if isinstance(e, DotBuf):
            a = np.concatenate([np.atleast_1d(x) for x in self.streams[e.buf_a]])
            b = np.concatenate([np.atleast_1d(x) for x in self.streams[e.buf_b]])
            return _FNS[e.fn](np.dot(a, b))
        if isinstance(e, scf.Const):
            return e.value
        if isinstance(e, scf.Param):
            return self.fn.params[e.name]
        if isinstance(e, scf.VarRef):
            return self.vars[e.name]
        if isinstance(e, scf.Load):
            idx = tuple(np.asarray(self.expr(i)).astype(np.int64)
                        if not np.isscalar(self.expr(i)) else int(self.expr(i))
                        for i in e.indices)
            return self.mem[e.memref][idx]
        if isinstance(e, scf.Bin):
            return _BINOPS[e.op](self.expr(e.a), self.expr(e.b))
        if isinstance(e, scf.Apply):
            return _FNS[e.fn](self.expr(e.a))
        raise TypeError(e)

    def run_callback_stmts(self, body):
        for s in body:
            if isinstance(s, (scf.Let, scf.SetVar)):
                self.vars[s.var] = self.expr(s.value)
            elif isinstance(s, scf.Store):
                idx = tuple(_as_index(self.expr(i)) for i in s.indices)
                v = self.expr(s.value)
                if s.accumulate is None:
                    self.mem[s.memref][idx] = v
                else:
                    self.mem[s.memref][idx] = _ACC[s.accumulate](
                        self.mem[s.memref][idx], v)
            elif isinstance(s, scf.For):
                lb = int(self.expr(s.lb))
                ub = int(self.expr(s.ub))
                for i in range(lb, ub):
                    self.vars[s.var] = i
                    self.run_callback_stmts(s.body)
            else:
                raise TypeError(s)


def _as_index(v):
    if np.isscalar(v) or getattr(v, "ndim", 1) == 0:
        return int(v)
    return np.asarray(v).astype(np.int64)


def interp_slc(fn: SlcFunc, inputs: dict) -> np.ndarray:
    st = _SlcState(fn, inputs)
    _run_slc_body(st, fn.body)
    out = st.mem["out"]
    op = fn.op
    if op.has_compute and op.semiring.add != "add" and op.uses_csr:
        lens = np.diff(inputs["ptrs"])
        out[lens == 0] = 0.0
    return out.astype(np.dtype(op.dtype))


def _run_slc_body(st: _SlcState, body):
    for node in body:
        if isinstance(node, SlcFor):
            for var, init in node.carry.items():
                st.vars.setdefault(var, init)
            lb = int(st.sidx(node.lb))
            ub = int(st.sidx(node.ub))
            if node.vlen is None:
                for i in range(lb, ub):
                    st.streams[node.stream] = i
                    _run_slc_body(st, node.body)
            else:
                for base in range(lb, ub, node.vlen):
                    # the mask stream of slcv.for (§7.1) ≙ the clipped range
                    st.streams[node.stream] = np.arange(
                        base, min(ub, base + node.vlen))
                    _run_slc_body(st, node.body)
        elif isinstance(node, MemStr):
            idx = tuple(_as_index(st.sidx(i)) for i in node.indices)
            st.streams[node.stream] = st.mem[node.memref][idx]
        elif isinstance(node, AluStr):
            st.streams[node.stream] = _BINOPS[node.op](
                st.sidx(node.a), st.sidx(node.b))
        elif isinstance(node, AccStr):
            cur = st.acc.get(node.stream, node.init)
            st.streams[node.stream] = cur            # exclusive prefix
            st.acc[node.stream] = cur + int(st.sidx(node.src))
        elif isinstance(node, BufStr):
            st.streams[node.stream] = []
        elif isinstance(node, PushBuf):
            st.streams[node.buf].append(np.atleast_1d(st.streams[node.src]))
        elif isinstance(node, Callback):
            st.run_callback_stmts(node.body)
        elif isinstance(node, StoreBuf):
            _store_buf(st, node)
        else:
            raise TypeError(node)


def _store_buf(st: _SlcState, node: StoreBuf):
    vec = np.concatenate(st.streams[node.buf]) if st.streams[node.buf] \
        else np.zeros((0,), np.dtype(st.fn.op.dtype))
    if node.scale is not None:
        vec = _BINOPS["*" if st.fn.op.semiring.mul == "mul" else "+"](
            st.expr(node.scale), vec)
    row = tuple(_as_index(st.expr(i)) for i in node.row_indices)
    tgt = st.mem[node.memref][row]
    if node.accumulate is None:
        st.mem[node.memref][row] = vec[: tgt.shape[-1]]
    else:
        st.mem[node.memref][row] = _ACC[node.accumulate](tgt, vec[: tgt.shape[-1]])


# ---------------------------------------------------------------------------
# DLC interpreter (queue-faithful)
# ---------------------------------------------------------------------------

def interp_dlc(prog, inputs: dict, return_queues: bool = False):
    """Run a :class:`repro.core.dlc.DlcProgram`.

    Phase 1 executes the lookup (access-unit) program, producing ctrlQ/dataQ.
    Phase 2 executes the compute (execute-unit) program by draining them.
    """
    from . import dlc as D

    op = prog.op
    mem = dict(inputs)
    init = op.semiring.identity if op.has_compute else 0.0
    mem["out"] = np.full(out_shape(op), init, np.dtype(op.dtype))

    ctrlq: deque = deque()
    dataq: deque = deque()
    streams: dict = {}
    acc_state: dict = {}

    def src_val(s):
        kind, v = s
        if kind == "const":
            return v
        if kind == "param":
            return prog.params[v]
        return streams[v]

    # ---- phase 1: access unit ----
    def run_access(body):
        for node in body:
            if isinstance(node, D.DLoop):
                lb = int(src_val(node.lb))
                ub = int(src_val(node.ub))
                if node.vlen is None:
                    for i in range(lb, ub):
                        streams[node.tu] = i
                        run_access(node.body)
                else:
                    for base in range(lb, ub, node.vlen):
                        streams[node.tu] = np.arange(base, min(ub, base + node.vlen))
                        run_access(node.body)
            elif isinstance(node, D.DMem):
                idx = tuple(_as_index(src_val(i)) for i in node.indices)
                streams[node.sid] = mem[node.memref][idx]
            elif isinstance(node, D.DAlu):
                streams[node.sid] = _BINOPS[node.op](src_val(node.a),
                                                     src_val(node.b))
            elif isinstance(node, D.DAcc):
                cur = acc_state.get(node.sid, node.init)
                streams[node.sid] = cur
                acc_state[node.sid] = cur + int(src_val(node.src))
            elif isinstance(node, D.DPushData):
                dataq.append(np.copy(src_val(node.src)))
            elif isinstance(node, D.DPushTok):
                ctrlq.append(node.token)
            elif isinstance(node, D.DStore):
                row = tuple(_as_index(src_val(i)) for i in node.row)
                val = src_val(node.src)
                tgt = mem[node.memref][row]
                if np.ndim(val) and tgt.ndim and val.shape != tgt.shape:
                    # masked tail of a vectorized store stream
                    mem[node.memref][row][: len(val)] = val
                else:
                    mem[node.memref][row] = val
            else:
                raise TypeError(node)

    run_access(prog.lookup)
    ctrlq.append(D.DONE)
    n_data = len(dataq)
    n_tok = len(ctrlq)

    # ---- phase 2: execute unit ----
    local = dict(prog.locals_init)

    def cexpr(e):
        if isinstance(e, scf.Const):
            return e.value
        if isinstance(e, scf.Param):
            return prog.params[e.name]
        if isinstance(e, scf.VarRef):
            return local[e.name]
        if isinstance(e, scf.Load):
            idx = tuple(_as_index(cexpr(i)) for i in e.indices)
            return mem[e.memref][idx]
        if isinstance(e, scf.Bin):
            return _BINOPS[e.op](cexpr(e.a), cexpr(e.b))
        if isinstance(e, scf.Apply):
            return _FNS[e.fn](cexpr(e.a))
        raise TypeError(e)

    def run_cstmts(body):
        for s in body:
            if isinstance(s, D.CPop):
                n = s.count if isinstance(s.count, int) else int(cexpr(s.count))
                if s.also is not None:
                    a_chunks, b_chunks = [], []
                    for _ in range(n):
                        a_chunks.append(np.atleast_1d(dataq.popleft()))
                        b_chunks.append(np.atleast_1d(dataq.popleft()))
                    local[s.var] = np.concatenate(a_chunks)
                    local[s.also] = np.concatenate(b_chunks)
                elif n == 1:
                    local[s.var] = dataq.popleft()
                else:
                    local[s.var] = np.concatenate(
                        [np.atleast_1d(dataq.popleft()) for _ in range(n)])
            elif isinstance(s, D.CDot):
                local[s.var] = _FNS[s.fn](
                    np.dot(local[s.a], local[s.b]))
            elif isinstance(s, D.CStoreRow):
                row = tuple(_as_index(cexpr(r)) for r in s.row)
                vec = np.atleast_1d(local[s.var])
                if s.scale is not None:
                    vec = _BINOPS["*" if op.semiring.mul == "mul" else "+"](
                        vec, cexpr(s.scale))
                tgt = mem[s.memref][row]
                vec = vec[: tgt.shape[-1]] if tgt.ndim else vec
                if s.accumulate is None:
                    if np.ndim(vec) and tgt.ndim and vec.shape != tgt.shape:
                        mem[s.memref][row][: len(vec)] = vec
                    else:
                        mem[s.memref][row] = vec
                else:
                    if np.ndim(vec) and tgt.ndim and vec.shape != tgt.shape:
                        sub = mem[s.memref][row][: len(vec)]
                        mem[s.memref][row][: len(vec)] = _ACC[s.accumulate](sub, vec)
                    else:
                        mem[s.memref][row] = _ACC[s.accumulate](tgt, vec)
            elif isinstance(s, (scf.Let, scf.SetVar)):
                local[s.var] = cexpr(s.value)
            elif isinstance(s, scf.Store):
                idx = tuple(_as_index(cexpr(i)) for i in s.indices)
                v = cexpr(s.value)
                if s.accumulate is None:
                    mem[s.memref][idx] = v
                else:
                    mem[s.memref][idx] = _ACC[s.accumulate](mem[s.memref][idx], v)
            elif isinstance(s, scf.For):
                for i in range(int(cexpr(s.lb)), int(cexpr(s.ub))):
                    local[s.var] = i
                    run_cstmts(s.body)
            else:
                raise TypeError(s)

    cases = {c.token: c for c in prog.cases}
    while True:
        tok = ctrlq.popleft()
        if tok == D.DONE:
            break
        run_cstmts(cases[tok].body)

    out = mem["out"]
    if op.has_compute and op.semiring.add != "add" and op.uses_csr:
        lens = np.diff(inputs["ptrs"])
        out[lens == 0] = 0.0
    out = out.astype(np.dtype(op.dtype))
    if return_queues:
        stats = {"data_pushed": n_data, "tokens": n_tok - 1,
                 "data_left": len(dataq), "ctrl_left": len(ctrlq)}
        return out, stats
    return out
