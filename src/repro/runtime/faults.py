"""Shared fault vocabulary + seeded, site-addressable chaos injection.

A production embedding tier fails *per request*, never per process: the
trainer already had typed failures (``InjectedFailure`` killing the loop at
scheduled steps, ``StragglerTimeout`` from the step watchdog) and PR 7 gives
the serving path the same discipline.  This module is the single home of
that vocabulary — trainer and server raise, catch and classify the SAME
typed errors — plus the :class:`FaultInjector` the chaos tests drive both
runtimes with.

Error taxonomy (all subclass :class:`EmberFault`):

* :class:`MalformedAccessError` — an offset stream failed validation
  against the compiled :class:`~repro.core.access_plan.AccessPlan` (vocab
  bounds, CSR structure, capacity limits).  Defined in
  :mod:`repro.core.access_plan` (the validation site) and re-exported here.
* :class:`InjectedFailure` — a chaos-injected fault (previously defined in
  :mod:`repro.runtime.trainer`; re-exported there for compatibility).
* :class:`StragglerTimeout` — the trainer's per-step watchdog deadline
  (hung collectives on a multi-host mesh).
* :class:`WaveTimeout` — the serving-side analogue: a wave exceeding the
  server's ``wave_deadline_s`` around ``submit_wave``/``StepHandle.result``.
* :class:`RequestError` — a per-request serving failure carrying the
  request's terminal status; never escapes :meth:`DecodeServer.step`.
* :class:`RpcError` — the disaggregated embedding tier's transport fault
  root (framing violations, closed connections); defined in
  :mod:`repro.core.access_plan` (the executor's disagg path classifies
  it) and re-exported here; subclasses
  :class:`RpcTimeout` (a per-call deadline lapsed) and
  :class:`ServiceUnavailable` (every replica dark after bounded retry —
  what the executor's ``degrade_policy`` resolves per step).

Injection sites mirror the executor's DAE phases (and the runtimes above
them)::

    marshal        host index packing (ProgramExecutor._marshal_*/route_*)
    transfer       host->device operand placement (ProgramExecutor._put*)
    dispatch       step/wave launch (ProgramExecutor.submit)
    result         the consume point (StepHandle.result)
    wave           the serving wave body (DecodeServer.step)
    step           the training step (Trainer.run)
    rpc_send       a step/bind request leaving the service client
    rpc_recv       a reply arriving at the service client
    heartbeat      one liveness probe of one replica (ServicePool)
    service_crash  the service process's step loop (the replica self-kills
                   abruptly — the ``kill -9`` shape, os._exit)

The injector is *seeded* (probabilistic specs draw from one
``np.random.default_rng``) and *site-addressable* (each
:class:`FaultSpec` names its site and fires either on exact call ordinals
or with probability ``p``), so a chaos schedule replays bit-identically —
the property the recovery tests assert on.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional, Tuple

import numpy as np

# the access-validation and RPC-transport errors are raised (and, for the
# transport family, classified by the executor's disaggregated submit
# path) in core; re-exported here so runtimes/tests import one fault module
from ..core.access_plan import (EmberFault, MalformedAccessError, RpcError,
                                RpcTimeout, ServiceUnavailable)

__all__ = [
    "EmberFault", "MalformedAccessError", "InjectedFailure",
    "StragglerTimeout", "WaveTimeout", "RequestError", "RpcError",
    "RpcTimeout", "ServiceUnavailable", "FaultSpec", "FaultInjector",
    "SITES", "FAULT_TYPES",
]


class InjectedFailure(EmberFault):
    """A chaos-injected fault (the supervisor treats it like a crash)."""


class StragglerTimeout(EmberFault):
    """A training step exceeded its watchdog deadline."""


class WaveTimeout(EmberFault):
    """A serving wave exceeded ``wave_deadline_s`` (hung wave)."""


class RequestError(EmberFault):
    """Per-request serving failure; carries the terminal status the server
    stamps on the request (``shed`` / ``expired`` / ``failed``)."""

    def __init__(self, status: str, msg: str = ""):
        super().__init__(msg or status)
        self.status = status


#: typed-error wire vocabulary: the service replies ``err`` frames naming
#: one of these classes and the client re-raises the SAME type, so a
#: service-side MalformedAccessError stays a MalformedAccessError at the
#: caller (never a generic transport failure that would trigger failover)
FAULT_TYPES = {
    "EmberFault": EmberFault,
    "MalformedAccessError": MalformedAccessError,
    "InjectedFailure": InjectedFailure,
    "StragglerTimeout": StragglerTimeout,
    "WaveTimeout": WaveTimeout,
    "RpcError": RpcError,
    "RpcTimeout": RpcTimeout,
    "ServiceUnavailable": ServiceUnavailable,
}


SITES: Tuple[str, ...] = ("marshal", "transfer", "dispatch", "result",
                          "wave", "step", "rpc_send", "rpc_recv",
                          "heartbeat", "service_crash")


@dataclasses.dataclass
class FaultSpec:
    """One addressable fault: fire at ``site`` either on exact call
    ordinals (``at`` — 1-based call numbers of that site) or with
    per-call probability ``p``; raise ``error`` (after an optional
    ``delay_s`` sleep that simulates a hung phase) up to ``times`` times.
    ``delay_only=True`` sleeps without raising — the hung-wave shape the
    watchdog must catch."""

    site: str
    at: Tuple[int, ...] = ()          # 1-based call ordinals of the site
    p: float = 0.0                    # used when ``at`` is empty
    error: type = InjectedFailure
    times: int = 1
    delay_s: float = 0.0
    delay_only: bool = False
    fired: int = 0                    # mutable: how often this spec fired

    def __post_init__(self):
        assert self.site in SITES, (self.site, SITES)
        self.at = tuple(int(a) for a in self.at)


class FaultInjector:
    """Seeded, site-addressable chaos injector shared by trainer, executor
    and server.  Runtimes call :meth:`fire` at each instrumented site; the
    injector decides (deterministically per seed) whether that call
    sleeps, raises, or passes through.  ``counts``/``log`` make the
    schedule observable so recovery tests can assert exactly which faults
    fired."""

    def __init__(self, specs=(), seed: int = 0):
        self.specs = [s if isinstance(s, FaultSpec) else FaultSpec(**s)
                      for s in specs]
        self.seed = int(seed)
        self.rng = np.random.default_rng(self.seed)
        self.counts = {s: 0 for s in SITES}
        self.log: list = []           # (site, call ordinal, error name)

    def fire(self, site: str, **ctx) -> None:
        """Invoke the site: count the call, then let each matching spec
        sleep and/or raise.  Unknown context kwargs ride into the raised
        error's message (the typed status the server records)."""
        self.counts[site] += 1
        n = self.counts[site]
        for spec in self.specs:
            if spec.site != site or spec.fired >= spec.times:
                continue
            hit = (n in spec.at) if spec.at else (
                spec.p > 0 and bool(self.rng.random() < spec.p))
            if not hit:
                continue
            spec.fired += 1
            if spec.delay_s > 0:
                time.sleep(spec.delay_s)
            if spec.delay_only:
                self.log.append((site, n, "delay"))
                continue
            self.log.append((site, n, spec.error.__name__))
            detail = " ".join(f"{k}={v}" for k, v in sorted(ctx.items()))
            raise spec.error(
                f"injected {spec.error.__name__} at site={site} call={n}"
                + (f" [{detail}]" if detail else ""))

    def total_fired(self) -> int:
        return sum(s.fired for s in self.specs)

    def stats(self) -> dict:
        return {"seed": self.seed,
                "calls": dict(self.counts),
                "fired": self.total_fired(),
                "log": list(self.log)}


def injector_for_env(env_value: Optional[str], specs=()) -> FaultInjector:
    """Build an injector whose seed comes from an environment string (the
    CI chaos leg pins ``CHAOS_SEED``); ``None``/empty means seed 0."""
    return FaultInjector(specs, seed=int(env_value) if env_value else 0)
