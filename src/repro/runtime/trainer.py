"""Fault-tolerant training runtime.

The loop a 1000-node job actually needs:

* **checkpoint/restart** — resume from the latest committed checkpoint,
  including onto a *different* mesh (elastic rescale; the checkpoint layout
  is offset-based, see `repro.checkpoint`);
* **watchdog** — a step deadline; a step exceeding it raises
  ``StragglerTimeout``, which the supervisor treats like a failure
  (checkpoint-restart from last good step).  On multi-host TPU the deadline
  catches hung collectives (a dead peer never completes its all-reduce);
* **failure injection** — ``failure_schedule`` lets tests kill the loop at
  chosen steps to exercise the restart path deterministically;
* **async checkpointing** — snapshot-to-host is synchronous (cheap), the
  write overlaps the next steps;
* **gradient compression** — optional int8+error-feedback on gradients
  before the optimizer (the cross-pod DCN trade, `repro.optim.compress`).

The supervisor (`run_supervised`) is the single-process stand-in for the
cluster controller: it restarts the train loop after injected failures until
the target step is reached — the same control flow a real launcher runs per
job restart.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import numpy as np

from ..checkpoint import CheckpointManager
from ..data.pipeline import SyntheticTokens
from ..optim import adamw, apply_updates
from ..optim.compress import compress_gradients, error_feedback_init
# the typed fault vocabulary moved to the shared runtime.faults module
# (trainer and server classify the same errors); re-exported here for
# backward compatibility with existing `from repro.runtime.trainer
# import InjectedFailure` callers
from .faults import InjectedFailure, StragglerTimeout  # noqa: F401


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    step_deadline_s: Optional[float] = None     # watchdog
    log_every: int = 10
    grad_compression: bool = False
    lr: float = 3e-4


class Trainer:
    def __init__(self, lm, data: SyntheticTokens, tcfg: TrainerConfig,
                 in_shardings=None, faults=None):
        self.lm = lm
        self.data = data
        self.tcfg = tcfg
        # optional shared chaos injector (runtime.faults.FaultInjector):
        # fires the "step" site each iteration — the seeded superset of the
        # legacy boolean failure_schedule
        self.faults = faults
        self.opt = adamw(lr=tcfg.lr)
        self.ckpt = CheckpointManager(tcfg.ckpt_dir, keep=tcfg.keep)
        self._step_fn = None
        self.in_shardings = in_shardings
        self.emb_compiled = None
        self.emb_executor = None

    def _build_step(self):
        lm, opt, tcfg = self.lm, self.opt, self.tcfg
        # Ember steady-state path: the train step's irregular lookups (token
        # embed + label gather + MoE dispatch) compile once per (batch, seq)
        # signature, and the ProgramExecutor is memoized alongside —
        # restarts get both caches back warm.  The lookups themselves run
        # inside the jitted train step; the executor is the serving-handoff
        # artifact, kept fresh by feeding every optimizer step's params into
        # `update_tables` (below), so serving never re-stacks.  A model
        # sharded over a >1-wide `model` axis hands back a vocab-sharded
        # executor (lm.embedding_executor inherits the ShardCtx mesh).
        if self.emb_compiled is None and hasattr(lm, "embedding_program"):
            dc = self.data.cfg
            if hasattr(lm, "embedding_executor"):
                self.emb_executor = lm.embedding_executor(
                    dc.global_batch, dc.seq_len)
            else:
                from ..core import executor as emb_exec
                self.emb_executor = emb_exec.executor_for(
                    lm.embedding_program(dc.global_batch, dc.seq_len))
            self.emb_compiled = self.emb_executor.compiled

        def train_step(params, opt_state, ef, batch):
            loss, grads = jax.value_and_grad(lm.loss)(params, batch)
            if tcfg.grad_compression:
                grads, ef = compress_gradients(grads, ef)
            updates, opt_state = opt.update(grads, opt_state, params)
            params = apply_updates(params, updates)
            return params, opt_state, ef, loss

        kw = {}
        if self.in_shardings is not None:
            kw["in_shardings"] = self.in_shardings
        self._step_fn = jax.jit(train_step, donate_argnums=(0, 1, 2), **kw)

    def init_state(self, key):
        params = self.lm.init(key)
        opt_state = self.opt.init(params)
        ef = (error_feedback_init(params)
              if self.tcfg.grad_compression else
              jax.tree.map(lambda _: np.zeros((), np.float32), params))
        return {"params": params, "opt": opt_state, "ef": ef}

    def run(self, key, *, failure_schedule: Callable[[int], bool] = None,
            on_step=None) -> dict:
        tcfg = self.tcfg
        start = self.ckpt.latest()
        if start is not None:
            state_like = self.init_state(key)
            tree = {"params": state_like["params"], "opt": state_like["opt"],
                    "ef": state_like["ef"]}
            state, step0 = self.ckpt.restore(tree)
            step0 += 1
        else:
            state = self.init_state(key)
            step0 = 0
        if self._step_fn is None:
            self._build_step()

        losses = []
        for step in range(step0, tcfg.total_steps):
            if failure_schedule is not None and failure_schedule(step):
                raise InjectedFailure(f"injected failure at step {step}")
            if self.faults is not None:
                self.faults.fire("step", step=step)
            t0 = time.time()
            batch = {k: jax.numpy.asarray(v)
                     for k, v in self.data.batch_at(step).items()}
            p, o, ef, loss = self._step_fn(state["params"], state["opt"],
                                           state["ef"], batch)
            loss = float(loss)  # blocks; realistic step boundary
            dt = time.time() - t0
            if tcfg.step_deadline_s and dt > tcfg.step_deadline_s:
                raise StragglerTimeout(
                    f"step {step} took {dt:.1f}s > {tcfg.step_deadline_s}s")
            state = {"params": p, "opt": o, "ef": ef}
            # train-serve handoff: donate the gradient-updated embed table
            # straight into the executor's stacked buffer (alias units just
            # rebind — `table_restacks` stays 0 for the LM program), so a
            # serving consumer of this executor starts on fresh tables with
            # zero host re-stacking.
            if self.emb_executor is not None and \
                    hasattr(self.lm, "embedding_table_inputs"):
                self.emb_executor.update_tables(
                    self.lm.embedding_table_inputs(state["params"]))
            losses.append(loss)
            if on_step:
                on_step(step, loss)
            if (step + 1) % tcfg.ckpt_every == 0 or \
                    step + 1 == tcfg.total_steps:
                self.ckpt.save(step, state)
        self.ckpt.wait()
        out = {"final_step": tcfg.total_steps - 1, "losses": losses,
               "state": state}
        if self.emb_compiled is not None:
            from ..core.executor import executor_cache_stats
            from ..core.pipeline import compile_cache_stats
            out["embedding_compile"] = compile_cache_stats()
            out["embedding_compile"]["executor_cache"] = \
                executor_cache_stats()
            out["embedding_compile"]["executor"] = \
                dict(self.emb_executor.stats)
            out["embedding_compile"]["executor"]["exchange"] = \
                self.emb_executor.exchange
            out["embedding_compile"]["executor"]["replicate_outputs"] = \
                self.emb_executor.replicate_outputs
            out["embedding_compile"]["access_plans"] = \
                self.emb_executor.access_plan_stats()
        return out


def run_supervised(make_trainer: Callable[[], Trainer], key, *,
                   failure_schedule=None, max_restarts: int = 5) -> dict:
    """Cluster-controller stand-in: restart-from-checkpoint on failure."""
    restarts = 0
    fired: set = set()

    def sched(step):
        if failure_schedule and step in failure_schedule and \
                step not in fired:
            fired.add(step)
            return True
        return False

    while True:
        trainer = make_trainer()
        try:
            out = trainer.run(key, failure_schedule=sched)
            out["restarts"] = restarts
            return out
        except (InjectedFailure, StragglerTimeout):
            restarts += 1
            if restarts > max_restarts:
                raise
