"""Disaggregated embedding tier: service process + fault-tolerant client.

ROADMAP item 1 taken to its serving conclusion (FlexEMR's disaggregation
argument): the stacked embedding tables live in their OWN process pool —
separately scalable from the dense tier, restartable without killing the
server — and the :class:`~repro.core.executor.ProgramExecutor` reaches
them over :mod:`repro.runtime.rpc` with its existing submit/result overlap
hiding the extra hop (the request leaves at ``submit``, the reply is
consumed at ``result``).

**Service side** (:class:`EmbeddingService`, ``python -m
repro.runtime.embedding_service``): one process owns the compiled program
+ device-resident stacked tables and serves ``AccessPlan`` step requests —
the per-step offset streams arrive over the wire, the tables never do
(after bind).  Steps replay idempotently: each request carries a monotone
per-client sequence number and the service caches the last reply per
client, so a retried request (reply lost on the wire, client failed over
and back) never double-executes.  A replica that boots next to a complete
*warm artifact* (``program.json`` + a :class:`CheckpointManager` table
checkpoint, written by the pool at bind time) **re-warms from the
artifact** instead of waiting for a bind RPC — the respawn path never
re-ships or re-stacks tables.

**Client side** (:class:`ServicePool`): N replicas serving the same
tables, round-robin dispatch with

* bounded exponential-backoff retry (the ``run_with_spawn_retry`` shape,
  :func:`repro.runtime.rpc.backoff_delays`),
* failover — a transport failure reroutes the step (and every other
  pending step on that connection) to a live peer; the computation is
  deterministic, so a step that executed on the dead replica before the
  reply was lost re-executes identically on the peer,
* a heartbeat monitor with a circuit breaker — ``breaker_misses``
  consecutive missed probes (or ``breaker_failures`` consecutive data
  failures) open the circuit: the replica is marked dark, respawned
  (bounded OSError retry, same backoff shape), and only rejoins rotation
  after a successful probe against its re-warmed process,
* recovery observability — per-revival recovery seconds and the revived
  replica's ``warm_source`` land in :meth:`ServicePool.stats`.

What the pool does NOT decide: what happens to a step when every replica
is dark.  That is the executor's ``degrade_policy`` (hot-slab / stale /
fail — see :class:`~repro.core.executor.ProgramExecutor`); the pool's
contract is to raise a typed :class:`ServiceUnavailable` only after the
bounded retry is exhausted.

Chaos sites (``runtime/faults.py``): ``rpc_send``/``rpc_recv`` fire in
the transport, ``heartbeat`` per liveness probe, ``service_crash`` in the
service's step loop (the replica self-kills with ``os._exit`` — the
``kill -9`` shape the failover path must absorb).
"""
from __future__ import annotations

import dataclasses
import itertools
import json
import os
import signal
import socket
import subprocess
import sys
import tempfile
import threading
import time
from collections import OrderedDict
from pathlib import Path
from typing import Optional

import numpy as np

from ..core.ops import EmbeddingOp, EmbeddingProgram, Semiring
from .faults import (FAULT_TYPES, EmberFault, FaultInjector, FaultSpec,
                     InjectedFailure, RpcError, ServiceUnavailable)
from .rpc import RpcClient, backoff_delays, recv_msg, send_msg

__all__ = ["EmbeddingService", "ServicePool", "StepFuture",
           "program_to_spec", "spec_to_program", "write_warm_artifact",
           "TRANSPORT_FAULTS"]

#: exception classes the retry/failover loop treats as "this replica (or
#: this wire) is gone" — everything else is an application error that
#: must surface typed to the caller, never trigger a reroute
TRANSPORT_FAULTS = (OSError, RpcError, InjectedFailure)

_HOST = "127.0.0.1"


# ---------------------------------------------------------------------------
# Program spec: the JSON identity of an EmbeddingProgram (bind frames and
# the warm artifact both carry it; EmbeddingOp is a flat dataclass)
# ---------------------------------------------------------------------------

def program_to_spec(program: EmbeddingProgram) -> dict:
    return {"name": program.name,
            "ops": [[n, dataclasses.asdict(op)] for n, op in program.ops],
            "shared_tables": [list(g) for g in program.shared_tables]}


def spec_to_program(spec: dict) -> EmbeddingProgram:
    ops = []
    for name, d in spec["ops"]:
        d = dict(d)
        d["semiring"] = Semiring(**d["semiring"])
        ops.append((name, EmbeddingOp(**d)))
    return EmbeddingProgram(spec["name"], tuple(ops),
                            tuple(tuple(g) for g in spec["shared_tables"]))


def _table_key(op: EmbeddingOp) -> str:
    return "x" if op.kind == "fusedmm" else "table"


def _write_program_meta(warm_dir, meta: dict) -> None:
    """Durable atomic publish of ``program.json`` — the ckpt tier's
    fsync-before-rename helper, shared rather than re-implemented: a bare
    ``tmp.write_text(); tmp.rename()`` is atomic against concurrent
    readers but leaves the torn-publish window against power loss that
    PR 8 closed for checkpoints."""
    from ..checkpoint import atomic_write_text
    atomic_write_text(Path(warm_dir) / "program.json", json.dumps(meta))


def _prune_table_steps(tables_dir: Path, keep: int = 2) -> None:
    """Keep-N retention over the warm tables (the CheckpointManager._gc
    shape).  ``keep >= 2`` so the step a just-superseded ``program.json``
    still references survives one more publish cycle."""
    import shutil

    from ..checkpoint import committed_steps
    for s in committed_steps(tables_dir)[:-keep]:
        (tables_dir / f"step_{s:09d}.COMMITTED").unlink(missing_ok=True)
        shutil.rmtree(tables_dir / f"step_{s:09d}", ignore_errors=True)


def write_warm_artifact(warm_dir, bind_meta: dict, tables: dict,
                        version: int) -> None:
    """Publish the re-warm artifact.  Order is the crash-safety contract:
    the table checkpoint commits FIRST (``save_checkpoint``'s
    commit-marker protocol), then ``program.json`` — stamped with the
    committed ``table_step`` — publishes atomically.  A crash between the
    two leaves the *previous* meta referencing its own still-committed
    step (a consistent pair); the reverse order could pair post-update
    meta with pre-update tables, which ``read_warm_artifact`` would have
    no way to detect without the stamp."""
    from ..checkpoint import save_checkpoint
    warm_dir = Path(warm_dir)
    warm_dir.mkdir(parents=True, exist_ok=True)
    save_checkpoint(warm_dir / "tables", version,
                    {op: np.asarray(a) for op, a in tables.items()})
    meta = dict(bind_meta)
    meta["table_step"] = int(version)
    _write_program_meta(warm_dir, meta)
    _prune_table_steps(warm_dir / "tables")


def read_warm_artifact(warm_dir) -> Optional[tuple]:
    """``(bind_meta, tables)`` when a complete *consistent* artifact
    exists, else None.  The meta's ``table_step`` stamp is cross-checked
    against the committed checkpoint steps: a meta referencing a torn or
    pruned step (a crash inside the publish window, or a mismatched pair
    written by pre-stamp code) is rejected rather than silently re-warming
    a replica with tables from a different version than its hot spec."""
    from ..checkpoint import committed_steps, restore_checkpoint
    warm_dir = Path(warm_dir)
    pj = warm_dir / "program.json"
    if not pj.exists():
        return None
    meta = json.loads(pj.read_text())
    steps = committed_steps(warm_dir / "tables")
    step = meta.get("table_step")
    if step is None:
        # legacy (pre-stamp) artifact: best-effort latest committed step
        step = steps[-1] if steps else None
    if step is None or step not in steps:
        return None
    like = {name: np.zeros((), np.float32)
            for name, _ in meta["program"]["ops"]
            if name in meta["table_ops"]}
    tables, _ = restore_checkpoint(warm_dir / "tables", like, step=step)
    return meta, tables


# ---------------------------------------------------------------------------
# Service side
# ---------------------------------------------------------------------------

class EmbeddingService:
    """One replica process: owns the compiled program + stacked tables,
    serves step requests.  Thread-per-connection (the pool uses one data
    and one control connection); all program state mutates under a lock."""

    def __init__(self, warm_dir=None, faults: Optional[FaultInjector] = None):
        self.warm_dir = Path(warm_dir) if warm_dir else None
        self.faults = faults
        self.executor = None
        self.tables: dict = {}           # op name -> {"table"/"x": array}
        self.table_keys: dict = {}
        self.steps = 0
        self.replays = 0
        self.warm_source = "none"        # none | bind | artifact
        self.compile_source = "none"     # none | fresh | artifact
        self._aot_saved = False          # first-step AOT capture done
        self.hot_epoch = 0               # adaptive slab generation bound
        self._replay: dict = {}          # client id -> (seq, meta, arrays)
        self._lock = threading.Lock()
        self._stop = threading.Event()

    # -- binding -----------------------------------------------------------

    def _bind_from(self, meta: dict, tables: dict, source: str) -> None:
        from ..core import artifact as art
        from ..core.executor import ProgramExecutor
        from ..core.pipeline import compile_program, seed_compile_cache
        program = spec_to_program(meta["program"])
        # AOT serving artifact (core/artifact.py) next to the warm
        # artifact: a respawned replica not only re-warms its tables, it
        # skips the PassManager + trace + XLA compile entirely when the
        # fingerprinted artifact a previous life saved still matches
        compiled = None
        payloads = None
        ameta = None
        aot_dir = self.warm_dir / "aot" if self.warm_dir is not None \
            else None
        self.compile_source = "fresh"
        if aot_dir is not None:
            ameta = art.artifact_meta(
                program, opt_level=meta["opt_level"], vlen=meta["vlen"],
                backend=meta["backend"], interpret=meta["interpret"])
            loaded = art.load_artifact(aot_dir, ameta)
            if loaded is not None:
                compiled, payloads = loaded
                self.compile_source = "artifact"
                seed_compile_cache(
                    art.compile_key_of(program, ameta), compiled)
            else:
                art.note_fresh_compile()
        if compiled is None:
            compiled = compile_program(program, meta["opt_level"],
                                       vlen=meta["vlen"])
        self.executor = ProgramExecutor(
            compiled, interpret=meta["interpret"], depth=2,
            backend=meta["backend"], index_policy=meta["index_policy"])
        if aot_dir is not None:
            self.executor.attach_artifact(aot_dir, ameta, payloads,
                                          self.compile_source)
        # a fresh compile re-saves after the first executed step (AOT
        # executables captured); an artifact boot already has them on disk
        self._aot_saved = self.compile_source == "artifact"
        self.table_keys = {name: _table_key(op) for name, op in program.ops}
        self.tables = {op: {self.table_keys[op]: np.asarray(a)}
                       for op, a in tables.items()}
        self.warm_source = source
        # the artifact carries the CURRENT hot spec: a respawned replica
        # re-warms already knowing the post-swap slab generation
        self.hot_epoch = int(meta.get("hot_epoch", 0))

    def try_warm(self) -> bool:
        """Boot-time re-warm: a complete artifact next to this replica
        replaces the bind RPC — the respawn path never re-ships tables."""
        if self.warm_dir is None:
            return False
        art = read_warm_artifact(self.warm_dir)
        if art is None:
            return False
        meta, tables = art
        self._bind_from(meta, tables, source="artifact")
        return True

    # -- request handlers --------------------------------------------------

    def _handle(self, kind: str, meta: dict, arrays: dict) -> tuple:
        if kind == "ping":
            return {"ok": True, "steps": self.steps, "pid": os.getpid(),
                    "bound": self.executor is not None,
                    "replays": self.replays,
                    "warm_source": self.warm_source,
                    "compile_source": self.compile_source,
                    "hot_epoch": self.hot_epoch}, {}
        if kind == "bind":
            self._bind_from(meta, arrays, source="bind")
            return {"ok": True, "warm_source": self.warm_source}, {}
        if kind == "hot":
            # adaptive slab swap: live replicas learn the new spec epoch
            # without a table re-ship (the artifact was rewritten first)
            self.hot_epoch = int(meta.get("hot_epoch", 0))
            return {"ok": True, "hot_epoch": self.hot_epoch}, {}
        if kind == "update":
            if self.executor is None:
                raise RpcError("update before bind")
            self.tables = {op: {self.table_keys[op]: np.asarray(a)}
                           for op, a in arrays.items()}
            return {"ok": True}, {}
        if kind == "step":
            return self._step(meta, arrays)
        if kind == "shutdown":
            self._stop.set()
            return {"ok": True}, {}
        raise RpcError(f"unknown request kind {kind!r}")

    def _step(self, meta: dict, arrays: dict) -> tuple:
        client, seq = meta["client"], int(meta["seq"])
        last = self._replay.get(client)
        if last is not None:
            if seq == last[0]:          # idempotent replay: cached reply,
                self.replays += 1       # the step does NOT re-execute
                return last[1], last[2]
            if seq < last[0]:
                raise RpcError(f"stale step seq {seq} < {last[0]}")
        if self.faults is not None:
            try:
                self.faults.fire("service_crash", step=self.steps)
            except InjectedFailure:
                # abrupt, not graceful: the kill -9 shape — no reply, no
                # connection teardown handshake, no atexit
                os._exit(137)
        if self.executor is None:
            raise RpcError("step before bind (no warm artifact either)")
        inputs: dict = {op: dict(t) for op, t in self.tables.items()}
        for key, arr in arrays.items():
            op, _, stream = key.partition("/")
            inputs.setdefault(op, {})[stream] = arr
        outs = self.executor.step(inputs)
        if not self._aot_saved:
            # first executed step: the AOT executables of the shapes this
            # deployment actually serves exist now — persist them so the
            # next (re)spawn boots by loading, not compiling.  Best-effort:
            # a failed save must never fail the step.
            self._aot_saved = True
            try:
                self.executor.save_artifact()
            except OSError:
                pass
        rmeta = {"ok": True, "seq": seq, "steps": self.steps}
        rarrays = {op: np.asarray(v) for op, v in outs.items()}
        self._replay[client] = (seq, rmeta, rarrays)
        self.steps += 1
        return rmeta, rarrays

    # -- serve loop --------------------------------------------------------

    def _serve_conn(self, conn: socket.socket) -> None:
        try:
            while not self._stop.is_set():
                try:
                    kind, meta, arrays = recv_msg(conn)
                except (RpcError, OSError):
                    return                    # peer gone: this conn is done
                seq = meta.get("seq")
                try:
                    try:
                        with self._lock:
                            rmeta, rarrays = self._handle(kind, meta,
                                                          arrays)
                        send_msg(conn, "ok", rmeta, rarrays)
                    except EmberFault as e:
                        err = {"error": type(e).__name__, "msg": str(e)}
                        if seq is not None:
                            err["seq"] = seq
                        send_msg(conn, "err", err)
                except OSError:
                    return               # client gone mid-reply: done
        finally:
            conn.close()

    def serve(self, portfile=None, port: int = 0) -> None:
        self.try_warm()
        srv = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        srv.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        srv.bind((_HOST, port))
        srv.listen(16)
        if portfile is not None:
            portfile = Path(portfile)
            tmp = portfile.with_suffix(".tmp")
            tmp.write_text(f"{srv.getsockname()[1]} {os.getpid()}")
            tmp.rename(portfile)     # atomic: the pool never reads a torn
        srv.settimeout(0.2)          # port file
        try:
            while not self._stop.is_set():
                try:
                    conn, _ = srv.accept()
                except socket.timeout:
                    continue
                conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
                threading.Thread(target=self._serve_conn, args=(conn,),
                                 daemon=True).start()
        finally:
            srv.close()


def main(argv=None) -> None:
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--portfile", required=True,
                    help="written atomically as '<port> <pid>' once "
                         "listening (the pool's readiness signal)")
    ap.add_argument("--warm-dir", default=None,
                    help="warm-artifact directory (program.json + table "
                         "checkpoint); a complete artifact re-warms this "
                         "replica at boot instead of a bind RPC")
    ap.add_argument("--crash-at", type=int, nargs="*", default=[],
                    help="1-based step ordinals where the service_crash "
                         "site fires (os._exit — the kill -9 shape)")
    ap.add_argument("--chaos-seed", type=int, default=0)
    args = ap.parse_args(argv)
    faults = None
    if args.crash_at:
        faults = FaultInjector(
            [FaultSpec("service_crash", at=tuple(args.crash_at),
                       times=len(args.crash_at))],
            seed=args.chaos_seed)
    EmbeddingService(warm_dir=args.warm_dir, faults=faults).serve(
        portfile=args.portfile)


# ---------------------------------------------------------------------------
# Client side: replica pool with heartbeats, breaker, failover, respawn
# ---------------------------------------------------------------------------

class StepFuture:
    """One in-flight step request.  Holds its own payload so a transport
    failure can resend it verbatim (same seq → idempotent) to a peer."""

    __slots__ = ("pool", "seq", "meta", "arrays", "replica", "value",
                 "error", "done")

    def __init__(self, pool, seq: int, meta: dict, arrays: dict):
        self.pool = pool
        self.seq = seq
        self.meta = meta
        self.arrays = arrays
        self.replica = None
        self.value = None
        self.error: Optional[BaseException] = None
        self.done = False

    def wait(self) -> dict:
        while not self.done:
            self.pool._pump(self.replica)
        if self.error is not None:
            raise self.error
        return self.value


class _Replica:
    def __init__(self, idx: int, portfile: Path):
        self.idx = idx
        self.portfile = portfile
        self.proc: Optional[subprocess.Popen] = None
        self.port: Optional[int] = None
        self.state = "starting"          # starting | live | dead
        self.client: Optional[RpcClient] = None    # data plane
        self.hb: Optional[RpcClient] = None        # control plane
        self.failures = 0                # consecutive data-plane failures
        self.misses = 0                  # consecutive missed heartbeats
        self.spawns = 0
        self.t_dead: Optional[float] = None
        self.pending: OrderedDict = OrderedDict()  # seq -> StepFuture

    def close_clients(self) -> None:
        for c in (self.client, self.hb):
            if c is not None:
                c.close()
        self.client = self.hb = None


_POOL_IDS = itertools.count(1)


class ServicePool:
    """N embedding-service replicas behind one fault-tolerant dispatch.

    The executor talks to exactly three methods — :meth:`bind`,
    :meth:`update_tables`, :meth:`submit_step` — everything else is the
    robustness machinery described in the module docstring.  Single
    serving thread owns the data plane; the optional heartbeat monitor
    owns the control plane and the respawn path (state flips guarded by
    one lock)."""

    def __init__(self, replicas: int = 2, *, warm_dir=None,
                 rpc_timeout_s: float = 30.0, retries: int = 3,
                 backoff_s: float = 0.05, breaker_failures: int = 2,
                 breaker_misses: int = 2, spawn_attempts: int = 3,
                 spawn_timeout_s: float = 120.0,
                 heartbeat_interval_s: Optional[float] = None,
                 auto_respawn: bool = True, faults=None,
                 crash_at: Optional[dict] = None, chaos_seed: int = 0):
        assert replicas >= 1, replicas
        self.pool_id = next(_POOL_IDS)
        self._own_dir = warm_dir is None
        self.warm_dir = Path(warm_dir) if warm_dir else \
            Path(tempfile.mkdtemp(prefix="embsvc_"))
        self.rpc_timeout_s = rpc_timeout_s
        self.retries = max(1, int(retries))
        self.backoff_s = backoff_s
        self.breaker_failures = max(1, int(breaker_failures))
        self.breaker_misses = max(1, int(breaker_misses))
        self.spawn_attempts = max(1, int(spawn_attempts))
        self.spawn_timeout_s = spawn_timeout_s
        self.heartbeat_interval_s = heartbeat_interval_s
        self.auto_respawn = auto_respawn
        self.faults = faults             # chaos injector (client sites)
        self.crash_at = dict(crash_at or {})   # replica idx -> ordinals
        self.chaos_seed = chaos_seed
        self.client_id = f"{os.getpid()}-{self.pool_id}"
        self._seq = itertools.count(1)
        self._rr = 0
        self._lock = threading.RLock()
        self._closing = threading.Event()
        self._monitor_thread: Optional[threading.Thread] = None
        self._bind_call: Optional[tuple] = None    # (meta, arrays)
        self._table_version = 0
        self.replicas = [
            _Replica(i, self.warm_dir / f"replica_{i}.port")
            for i in range(replicas)]
        self.pool_stats = {
            "replicas": replicas, "rpc_steps": 0, "retries": 0,
            "failovers": 0, "respawns": 0, "breaker_open": 0,
            "heartbeats": 0, "hb_misses": 0, "replays": 0,
            "hot_publishes": 0,
            "recoveries_s": [], "warm_sources": [], "compile_sources": []}
        for r in self.replicas:
            self._spawn(r)
        self.wait_ready()
        if heartbeat_interval_s is not None:
            self._monitor_thread = threading.Thread(
                target=self._monitor, daemon=True)
            self._monitor_thread.start()

    # -- lifecycle ---------------------------------------------------------

    def _spawn(self, r: _Replica) -> None:
        """(Re)spawn one replica with bounded OSError retry — the
        ``run_with_spawn_retry`` contract: infra failures retry with
        exponential backoff, nothing else does."""
        r.portfile.unlink(missing_ok=True)
        cmd = [sys.executable, "-m", "repro.runtime.embedding_service",
               "--portfile", str(r.portfile),
               "--warm-dir", str(self.warm_dir)]
        if r.spawns == 0 and r.idx in self.crash_at:
            # chaos schedules apply to the FIRST life of a replica only;
            # its respawn must come back clean (or recovery never ends)
            ords = self.crash_at[r.idx]
            cmd += ["--crash-at", *[str(a) for a in ords],
                    "--chaos-seed", str(self.chaos_seed)]
        env = dict(os.environ)
        src = str(Path(__file__).resolve().parents[2])
        pp = env.get("PYTHONPATH", "")
        if src not in pp.split(os.pathsep):
            env["PYTHONPATH"] = f"{src}{os.pathsep}{pp}" if pp else src
        last: Optional[OSError] = None
        for delay in backoff_delays(self.spawn_attempts, self.backoff_s):
            if delay:
                time.sleep(delay)
            try:
                r.proc = subprocess.Popen(cmd, env=env)
                break
            except OSError as e:
                last = e
        else:
            raise last
        r.spawns += 1
        r.state = "starting"
        r.failures = r.misses = 0

    def _ready_port(self, r: _Replica) -> Optional[int]:
        try:
            txt = r.portfile.read_text().split()
            return int(txt[0])
        except (OSError, ValueError, IndexError):
            return None

    def wait_ready(self, timeout_s: Optional[float] = None) -> None:
        """Block until every starting replica is live (port published +
        ping answered).  A child that dies during startup respawns,
        bounded by ``spawn_attempts`` lives."""
        deadline = time.perf_counter() + (timeout_s or self.spawn_timeout_s)
        while time.perf_counter() < deadline:
            starting = [r for r in self.replicas if r.state == "starting"]
            if not starting:
                return
            for r in starting:
                if r.proc is not None and r.proc.poll() is not None:
                    if r.spawns >= self.spawn_attempts:
                        raise ServiceUnavailable(
                            f"replica {r.idx} died {r.spawns}x at startup "
                            f"(rc={r.proc.returncode})")
                    self._spawn(r)
                    continue
                port = self._ready_port(r)
                if port is not None and self._probe(r, port):
                    continue
            time.sleep(0.02)
        raise ServiceUnavailable(
            f"{sum(r.state != 'live' for r in self.replicas)} replica(s) "
            f"not ready within {timeout_s or self.spawn_timeout_s}s")

    def _probe(self, r: _Replica, port: int) -> bool:
        """Ping a (re)started replica; on success it (re)joins rotation."""
        try:
            hb = RpcClient(_HOST, port, timeout_s=self.rpc_timeout_s)
            meta, _ = hb.call("ping")
        except TRANSPORT_FAULTS:
            return False
        with self._lock:
            r.port = port
            if r.hb is not None:
                r.hb.close()
            r.hb = hb
            was_dead = r.state == "dead"
            r.state = "live"
            r.failures = r.misses = 0
            if was_dead and r.t_dead is not None:
                self.pool_stats["recoveries_s"].append(
                    time.perf_counter() - r.t_dead)
                r.t_dead = None
            self.pool_stats["warm_sources"].append(meta["warm_source"])
            self.pool_stats["compile_sources"].append(
                meta.get("compile_source", "none"))
        # a replica revived from the warm artifact is already bound; one
        # that came back BEFORE any bind happened just waits for it
        return True

    def close(self) -> None:
        self._closing.set()
        if self._monitor_thread is not None:
            self._monitor_thread.join(timeout=5.0)
        for r in self.replicas:
            try:
                if r.hb is not None:
                    r.hb.call("shutdown", deadline_s=1.0)
            except TRANSPORT_FAULTS:
                pass
            r.close_clients()
            if r.proc is not None and r.proc.poll() is None:
                r.proc.terminate()
        for r in self.replicas:
            if r.proc is not None:
                try:
                    r.proc.wait(timeout=5.0)
                except subprocess.TimeoutExpired:
                    r.proc.kill()
                    r.proc.wait()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    def kill_replica(self, idx: int) -> None:
        """SIGKILL one replica — the chaos/bench hook (no cleanup, no
        goodbye: exactly what the failover path must absorb)."""
        r = self.replicas[idx]
        if r.proc is not None and r.proc.poll() is None:
            os.kill(r.proc.pid, signal.SIGKILL)

    # -- heartbeat monitor + circuit breaker -------------------------------

    def _monitor(self) -> None:
        while not self._closing.wait(self.heartbeat_interval_s):
            try:
                self.heartbeat_once()
            except Exception:            # noqa: BLE001 — the monitor must
                pass                     # survive anything transient

    def heartbeat_once(self) -> None:
        """One liveness pass over the pool: probe live replicas, revive
        dark ones.  Callable directly (tests drive it deterministically
        without the thread)."""
        for r in self.replicas:
            if self._closing.is_set():
                return
            if r.state != "live":
                self._try_revive(r)
                continue
            try:
                if self.faults is not None:
                    self.faults.fire("heartbeat", replica=r.idx)
                if r.hb is None:
                    r.hb = RpcClient(_HOST, r.port,
                                     timeout_s=self.rpc_timeout_s)
                r.hb.call("ping")
                r.misses = 0
                self.pool_stats["heartbeats"] += 1
            except TRANSPORT_FAULTS:
                r.misses += 1
                self.pool_stats["hb_misses"] += 1
                if r.hb is not None:
                    r.hb.close()
                    r.hb = None
                if r.misses >= self.breaker_misses:
                    self._open_circuit(r, reason="heartbeat loss")

    def _open_circuit(self, r: _Replica, reason: str) -> None:
        """Mark a replica dark and (optionally) start its respawn.  Data
        plane state (pending futures) is NOT touched here — only the
        serving thread reroutes, when it observes the failure itself."""
        with self._lock:
            if r.state == "dead":
                return
            r.state = "dead"
            r.t_dead = time.perf_counter()
            self.pool_stats["breaker_open"] += 1
        if self.auto_respawn:
            self.respawn(r.idx)

    def respawn(self, idx: int) -> None:
        """Respawn a dark replica's process; it rejoins rotation when a
        later :meth:`heartbeat_once`/:meth:`_try_revive` probe succeeds
        against its re-warmed process."""
        r = self.replicas[idx]
        if r.proc is not None and r.proc.poll() is None:
            r.proc.kill()
            r.proc.wait()
        self._spawn(r)
        r.state = "dead"                 # dark until a probe passes
        self.pool_stats["respawns"] += 1

    def _try_revive(self, r: _Replica) -> None:
        if r.proc is None or r.proc.poll() is not None:
            if self.auto_respawn:
                self.respawn(r)
            return
        port = self._ready_port(r)
        if port is not None:
            self._probe(r, port)

    # -- data plane: bind / update / steps ---------------------------------

    def _bind_meta(self, program, tables, *, opt_level, vlen, backend,
                   index_policy, interpret, hot_spec=None) -> dict:
        return {"program": program_to_spec(program), "opt_level": opt_level,
                "vlen": vlen, "backend": backend,
                "index_policy": index_policy, "interpret": bool(interpret),
                "table_ops": sorted(tables),
                "hot_spec": ({n: sorted(int(i) for i in ids)
                              for n, ids in dict(hot_spec).items()}
                             if hot_spec else None),
                "hot_epoch": 0}

    def bind(self, program, tables: dict, **bind_kw) -> None:
        """Ship program + tables to every live replica — but FIRST publish
        the warm artifact, so any replica that dies from this moment on
        re-warms from checkpoint instead of needing a re-bind."""
        meta = self._bind_meta(program, tables, **bind_kw)
        arrays = {op: np.asarray(a) for op, a in tables.items()}
        self._table_version += 1
        write_warm_artifact(self.warm_dir, meta, arrays,
                            self._table_version)
        self._bind_call = (meta, arrays)
        self._broadcast("bind", meta, arrays)

    def update_tables(self, tables: dict) -> None:
        """Refresh the service-side tables (artifact first, same reason).
        Dark replicas pick the new version up from the artifact when they
        re-warm."""
        if self._bind_call is None:
            raise RpcError("update_tables before bind")
        meta, _ = self._bind_call
        arrays = {op: np.asarray(a) for op, a in tables.items()}
        self._table_version += 1
        write_warm_artifact(self.warm_dir, meta, arrays,
                            self._table_version)
        self._bind_call = (meta, arrays)
        self._broadcast("update", {}, arrays)

    def publish_hot_spec(self, hot_rows: dict) -> None:
        """Propagate an adaptive hot-slab swap: rewrite the warm artifact's
        ``program.json`` with the new spec + bumped epoch (atomic rename;
        the table checkpoint is untouched — a swap re-ranks, it never
        re-ships rows), then best-effort notify live replicas.  An all-dark
        pool is tolerated: the artifact alone guarantees that any replica
        respawned from this moment re-warms with the *current* slab."""
        if self._bind_call is None:
            raise RpcError("publish_hot_spec before bind")
        meta, arrays = self._bind_call
        meta = dict(meta)
        meta["hot_spec"] = {n: sorted(int(i) for i in ids)
                            for n, ids in dict(hot_rows).items()}
        meta["hot_epoch"] = int(meta.get("hot_epoch", 0)) + 1
        warm_dir = Path(self.warm_dir)
        warm_dir.mkdir(parents=True, exist_ok=True)
        # the republished meta must keep referencing the committed table
        # step it was bound with (a swap re-ranks, it never re-ships rows)
        meta["table_step"] = int(self._table_version)
        _write_program_meta(warm_dir, meta)
        self._bind_call = (meta, arrays)
        self.pool_stats["hot_publishes"] += 1
        try:
            self._broadcast("hot", {"hot_epoch": meta["hot_epoch"]}, {})
        except ServiceUnavailable:
            pass    # dark pool: replicas pick the spec up on re-warm

    def _broadcast(self, kind: str, meta: dict, arrays: dict) -> None:
        sent = 0
        for r in self.replicas:
            if r.state != "live":
                continue
            try:
                if r.hb is None:
                    r.hb = RpcClient(_HOST, r.port,
                                     timeout_s=self.rpc_timeout_s)
                if self.faults is not None:
                    self.faults.fire("rpc_send", kind=kind)
                r.hb.call(kind, meta, arrays,
                          deadline_s=max(self.rpc_timeout_s, 60.0))
                sent += 1
            except TRANSPORT_FAULTS:
                # a replica that missed the broadcast re-warms from the
                # artifact after its circuit opens
                self._mark_failure(r)
        if not sent:
            raise ServiceUnavailable(f"no live replica accepted {kind!r}")

    def _next_live(self) -> Optional[_Replica]:
        n = len(self.replicas)
        for k in range(n):
            r = self.replicas[(self._rr + k) % n]
            if r.state == "live":
                self._rr = (self._rr + k + 1) % n
                return r
        return None

    def _ensure_client(self, r: _Replica) -> RpcClient:
        if r.client is None:
            r.client = RpcClient(_HOST, r.port,
                                 timeout_s=self.rpc_timeout_s)
        return r.client

    def _mark_failure(self, r: _Replica) -> None:
        r.failures += 1
        if r.client is not None:
            r.client.close()
            r.client = None
        if r.failures >= self.breaker_failures or (
                r.proc is not None and r.proc.poll() is not None):
            self._open_circuit(r, reason="data-plane failure")

    def submit_step(self, streams: dict) -> StepFuture:
        """Send one step request (monotone seq) to the next live replica;
        returns a :class:`StepFuture` resolved at :meth:`StepFuture.wait`.
        Raises :class:`ServiceUnavailable` only after the bounded
        exponential-backoff retry found no replica to accept the send."""
        seq = next(self._seq)
        fut = StepFuture(self, seq,
                         {"client": self.client_id, "seq": seq}, streams)
        self._send_future(fut)
        self.pool_stats["rpc_steps"] += 1
        return fut

    def _send_future(self, fut: StepFuture) -> None:
        last: Optional[BaseException] = None
        for k, delay in enumerate(
                backoff_delays(self.retries, self.backoff_s)):
            if delay:
                time.sleep(delay)
                self.pool_stats["retries"] += 1
            r = self._next_live()
            if r is None:
                break
            try:
                client = self._ensure_client(r)
                send_msg(client.sock, "step", fut.meta, fut.arrays,
                         faults=self.faults)
                fut.replica = r
                r.pending[fut.seq] = fut
                r.failures = 0
                return
            except TRANSPORT_FAULTS as e:
                last = e
                self._mark_failure(r)
        raise ServiceUnavailable(
            f"no live embedding-service replica accepted step "
            f"{fut.seq} after {self.retries} attempt(s)"
            + (f" (last: {type(last).__name__}: {last})" if last else ""))

    def _pump(self, r: _Replica) -> None:
        """Receive ONE frame on a replica's data connection and resolve
        the matching pending future.  A transport failure here fails the
        replica over: every pending step (payloads retained) resends to a
        live peer — same seq, so a step the dead replica already executed
        replays idempotently if it ever comes back."""
        if r is None:
            raise ServiceUnavailable("step future lost its replica")
        try:
            kind, meta, arrays = recv_msg(
                r.client.sock, deadline_s=self.rpc_timeout_s,
                faults=self.faults)
        except TRANSPORT_FAULTS as e:
            self._failover(r, e)
            return
        fut = r.pending.pop(meta.get("seq"), None)
        if fut is None:
            return                       # stale frame (already rerouted)
        if kind == "err":
            name = meta.get("error", "RpcError")
            cls = FAULT_TYPES.get(name, RpcError)
            try:
                fut.error = cls(meta.get("msg", ""))
            except TypeError:
                fut.error = EmberFault(
                    f"{name}: {meta.get('msg', '')}")
        else:
            fut.value = arrays
            if meta.get("steps", 0) != meta.get("seq"):
                # the service's step counter trailing the seq means some
                # seq was answered from the replay cache somewhere
                self.pool_stats["replays"] = max(
                    self.pool_stats["replays"], 0)
        fut.done = True

    def _failover(self, r: _Replica, cause: BaseException) -> None:
        self._mark_failure(r)
        if r.state == "live":
            # breaker still closed (single transient failure): the wire
            # died but the replica may be fine — reroute pendings anyway,
            # the reconnect happens on the next send
            pass
        pendings = list(r.pending.values())
        r.pending.clear()
        for fut in pendings:
            fut.replica = None
            try:
                self._send_future(fut)
                self.pool_stats["failovers"] += 1
            except ServiceUnavailable as e:
                fut.error = e
                fut.done = True

    # -- observability -----------------------------------------------------

    def stats(self) -> dict:
        s = dict(self.pool_stats)
        s["recoveries_s"] = list(self.pool_stats["recoveries_s"])
        s["warm_sources"] = list(self.pool_stats["warm_sources"])
        s["compile_sources"] = list(self.pool_stats["compile_sources"])
        s["states"] = [r.state for r in self.replicas]
        s["spawns"] = [r.spawns for r in self.replicas]
        return s


if __name__ == "__main__":
    main()
