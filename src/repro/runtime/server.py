"""Production continuous-batching decode server.

The serving loop the Ember steady-state machine is graded under
(``benchmarks/bench_serving.py`` drives it open-loop):

* **Per-slot position counters** — the KV/MLA caches carry a vector
  ``len`` (B,), so every batch slot advances independently: admission,
  prefill and retirement are per-slot operations, never whole-batch
  drains.
* **Prompt-chunked prefill** — an admitted prompt is consumed in
  ``prefill_chunk``-token waves (:meth:`~repro.models.lm.LM.wave_step`, a
  fused ``lax.scan`` of masked decode micro-steps) interleaved with the
  decode waves of the already-running slots.  Because a wave is exactly
  the masked micro-step sequence, chunked prefill is **bit-identical** to
  whole-prompt prefill at any chunk size (tests/test_server.py asserts
  it), and only two traces exist: C=1 (pure decode) and C=prefill_chunk.
* **Prioritized admission + slot recycling** — requests queue on a
  priority heap (lower ``Request.priority`` first, FIFO within a class);
  a slot that hits EOS / max-new / max-len retires *mid-wave*: its cache
  region is zeroed (:meth:`~repro.models.lm.LM.reset_slots`) and the next
  queued request is admitted in the same serving iteration, so a freed
  slot never idles a wave.
* **Cross-program pipelining** (``pipeline=True``) — the wave's access
  streams are mirrored into the model's
  :meth:`~repro.models.lm.LM.embedding_pipeline`
  (:class:`~repro.core.executor.PipelineGroup`): the decode-embed program
  of wave W+1 marshals against the shared staging pool while the MoE
  un-dispatch of wave W executes; ``compile_stats["pipeline_group"]``
  surfaces the per-program in-flight accounting and pool hit/miss
  counters.

Per-request service metrics (submit/admit/first-token/done wall-clock
stamps and per-token times) are recorded on the :class:`Request` itself —
what the open-loop bench aggregates into TTFT / per-token percentiles.

**Fault tolerance** (PR 7): the loop degrades per-request, never
per-process.

* **Input hardening** — prompts validate against the model vocab under
  ``index_policy`` ("strict" fails the request with a typed error,
  "clamp"/"drop" repair it and count), and the same policy flows into the
  pipeline group's executors, whose AccessPlans harden every offset
  stream they marshal.
* **SLO-aware admission** — a request carries a TTFT budget
  (``Request.deadline_s``, or the server-wide ``ttft_slo_s``): submit-time
  shedding predicts queue wait from the calibrated ``capacity_rps`` the
  serving bench measures, admission-time shedding predicts prefill time
  from the measured wave EWMA, and a request whose budget lapsed is
  retired with status ``expired`` — under overload the queue sheds
  instead of growing unboundedly.
* **Wave watchdog + bounded retry** — ``wave_deadline_s`` bounds the
  whole wave (LM step + pipeline feed + handle results); a hung or
  faulted wave resets the pipeline group (abandoning its in-flight
  steps and staging slots) and retries up to ``wave_retries`` times
  before failing ONLY the implicated requests; every other slot and all
  later waves proceed bit-identically to a fault-free run.

Each request ends in exactly one terminal ``status``: ``ok`` | ``shed`` |
``expired`` | ``failed``.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.access_plan import INDEX_POLICIES
from .faults import EmberFault, WaveTimeout

#: terminal request statuses (Request.status ends as exactly one of these)
STATUSES = ("ok", "shed", "expired", "failed")


@dataclasses.dataclass
class Request:
    prompt: np.ndarray              # (L,) int32
    max_new_tokens: int = 16
    priority: int = 0               # lower serves first; FIFO within a class
    deadline_s: Optional[float] = None   # TTFT budget from submit (None: server SLO)
    out: list = dataclasses.field(default_factory=list)
    done: bool = False
    status: str = "queued"          # queued|active -> ok|shed|expired|failed
    error: Optional[str] = None     # typed failure detail (status != ok)
    # service metrics, stamped by the server (perf_counter seconds)
    t_submit: Optional[float] = None
    t_admit: Optional[float] = None
    t_first: Optional[float] = None
    t_done: Optional[float] = None
    token_times: list = dataclasses.field(default_factory=list)
    admitted_wave: Optional[int] = None
    finished_wave: Optional[int] = None


_EMPTY = np.zeros(0, np.int32)


class DecodeServer:
    def __init__(self, lm, params, *, batch_slots: int = 4,
                 max_len: int = 256, eos_id: Optional[int] = None,
                 prefill_chunk: int = 8, pipeline: bool = False,
                 index_policy: str = "strict",
                 capacity_rps=None,
                 capacity_warmup_waves: int = 5,
                 ttft_slo_s: Optional[float] = None,
                 wave_deadline_s: Optional[float] = None,
                 wave_retries: int = 1,
                 faults=None, service: str = "inproc",
                 service_pool=None, degrade_policy: str = "fail",
                 artifact_dir=None):
        assert index_policy in INDEX_POLICIES, index_policy
        self.lm = lm
        # serving artifact (core/artifact.py): boot hydrates the compile
        # cache + AOT executables from here instead of compiling; a fresh
        # compile saves at build and re-saves after the first wave (the
        # captured executables of the shapes actually served)
        self.artifact_dir = artifact_dir
        self._artifact_saved = False
        self.params = params
        self.slots = batch_slots
        self.max_len = max_len
        self.eos = eos_id
        self.prefill_chunk = max(1, int(prefill_chunk))
        # --- fault-tolerance knobs -------------------------------------
        self.index_policy = index_policy
        # calibrated service capacity (requests/s at saturation — what
        # bench_serving.py's closed-loop calibration measures); drives the
        # submit-time predicted-wait shed.  None disables that check.
        # "auto" self-calibrates from the measured wave-time EWMA after
        # ``capacity_warmup_waves`` waves: capacity ≈ slots / (wave_s ×
        # avg waves-per-request) — no closed-loop bench number needed.
        self._capacity_auto = capacity_rps == "auto"
        self.capacity_rps = None if self._capacity_auto else capacity_rps
        self.capacity_warmup_waves = max(1, int(capacity_warmup_waves))
        self._req_wave_spans = 0    # Σ (finished_wave - admitted_wave + 1)
        self._req_span_count = 0
        # server-wide TTFT budget applied to requests without their own
        self.ttft_slo_s = ttft_slo_s
        self.wave_deadline_s = wave_deadline_s
        self.wave_retries = max(0, int(wave_retries))
        self.faults = faults            # chaos injector (site "wave" here)
        # disaggregated embedding tier: every member executor routes its
        # steps to the service pool (cache-keyed on the pool's identity);
        # a ServiceUnavailable surfacing from a wave is an EmberFault, so
        # the wave watchdog's reset+retry already covers replica failover
        assert service in ("inproc", "disagg"), service
        self.service = service
        self.service_pool = service_pool
        self.degrade_policy = degrade_policy
        self._svc_kw = ({"service": service, "service_pool": service_pool,
                         "degrade_policy": degrade_policy}
                        if service == "disagg" else {})
        self._ewma_wave_s: Optional[float] = None   # measured wave time
        # prompt-validation bound: stub LMs expose `vocab`, real ones cfg
        self._vocab = getattr(lm, "vocab", None) or getattr(
            getattr(lm, "cfg", None), "vocab_size", None)
        self.queue: list = []           # (priority, submit seq, Request)
        self._seq = itertools.count()
        self.active: List[Optional[Request]] = [None] * batch_slots
        self._prompt_left: List[np.ndarray] = [_EMPTY] * batch_slots
        self._next_token = np.zeros(batch_slots, np.int32)
        self._pos = np.zeros(batch_slots, np.int64)   # host position mirror
        self.caches = lm.init_caches(batch_slots, max_len)
        # two traces total: C=1 decode waves, C=prefill_chunk prefill waves
        self._wave = jax.jit(lm.wave_step, donate_argnums=(3,))
        self._reset = jax.jit(lm.reset_slots, donate_argnums=(0,))
        self.waves = 0
        self.serve_stats = {"waves": 0, "prefill_waves": 0,
                            "decode_waves": 0, "admitted": 0, "finished": 0,
                            "slot_resets": 0, "queue_peak": 0,
                            "shed": 0, "expired": 0, "failed": 0,
                            "oob_prompt_tokens": 0, "wave_faults": 0,
                            "wave_retries": 0, "watchdog_timeouts": 0,
                            "capacity_rps_live": None}
        # Ember steady-state path: the decode step's irregular lookups
        # compile ONCE per (slots, 1) signature and the ProgramExecutor's
        # marshaling cache (device-resident stacked tables + roff streams)
        # is memoized alongside — every later wave is a double cache hit.
        # A model whose ShardCtx mesh has a >1-wide `model` axis gets the
        # vocab-sharded executor (stacked tables partitioned over the axis).
        self.emb_compiled = None
        self.emb_executor = None
        self.compile_stats: Optional[dict] = None
        if hasattr(lm, "embedding_program"):
            from ..core import executor as emb_exec
            from ..core import pipeline as emberc
            self._emberc = emberc
            self._emb_exec = emb_exec
            self.emb_executor = self._resolve_executor()
            self.emb_compiled = self.emb_executor.compiled
        self.pipeline_group = None
        self._undispatch_name = None
        if pipeline and hasattr(lm, "embedding_pipeline"):
            # the server's index policy flows into every member executor
            # (cache-keyed), so the pipeline's marshaling paths harden the
            # mirrored streams under the same policy as the prompts
            self.pipeline_group = lm.embedding_pipeline(
                batch_slots, 1, index_policy=index_policy, **self._svc_kw)
            if faults is not None:
                # group-level attach: cached member executors stay clean
                self.pipeline_group.faults = faults
            names = self.pipeline_group.names
            self._embed_name = names[0]
            if len(names) > 1:
                self._undispatch_name = names[1]
                op = self.pipeline_group.executor(names[1]) \
                    .compiled.program.op("moe_undispatch")
                self._cap_buf = jnp.zeros((op.num_embeddings, op.emb_len),
                                          lm.cfg.jdtype)
                self._undisp_segments = op.num_segments
                self._undisp_rows = op.num_embeddings
        if self.emb_executor is not None:
            self.compile_stats = self._gather_compile_stats()

    def _resolve_executor(self):
        kw = dict(self._svc_kw)
        if self.artifact_dir is not None:
            kw["artifact_dir"] = self.artifact_dir
        if hasattr(self.lm, "embedding_executor"):
            return self.lm.embedding_executor(self.slots, 1, **kw)
        return self._emb_exec.executor_for(
            self.lm.embedding_program(self.slots, 1), **kw)

    def _gather_compile_stats(self) -> dict:
        s = self._emberc.compile_cache_stats()
        s["executor_cache"] = self._emb_exec.executor_cache_stats()
        s["executor"] = dict(self.emb_executor.stats)
        s["executor"]["shards"] = self.emb_executor.shards
        # sharded serving observability: which exchange moves the offset
        # streams (host scatter vs device all_to_all) and whether pooled
        # outputs are reduce-scattered or replicated — with host_syncs in
        # the stats dict above, the per-step transfer count it saves
        s["executor"]["exchange"] = self.emb_executor.exchange
        s["executor"]["replicate_outputs"] = \
            self.emb_executor.replicate_outputs
        # the compiled access side, observable: hot/cold layout, exchange
        # bytes est. vs. actual, per-pass plan-build time (plan-access)
        s["access_plans"] = self.emb_executor.access_plan_stats()
        if self.artifact_dir is not None:
            # where this boot's compile came from + the process-wide
            # load/reject counters (the version-skew runbook observable)
            from ..core.artifact import artifact_stats
            s["artifact"] = {
                "compile_source": self.emb_executor.compile_source,
                **artifact_stats()}
        if self.pipeline_group is not None:
            s["pipeline_group"] = self.pipeline_group.group_stats()
        return s

    # ------------------------------------------------------------------
    # Admission
    # ------------------------------------------------------------------

    def submit(self, req: Request):
        req.t_submit = time.perf_counter()
        if not self._harden_prompt(req):
            return                       # terminal: failed (typed error)
        if self._shed_at_submit(req):
            return                       # terminal: shed (predicted wait)
        heapq.heappush(self.queue, (req.priority, next(self._seq), req))
        self.serve_stats["queue_peak"] = max(self.serve_stats["queue_peak"],
                                             len(self.queue))

    def _terminate(self, req: Request, status: str,
                   error: Optional[str] = None):
        """Retire a request that never reached a slot (or leaves one):
        stamp its terminal status — the loop itself never dies for it."""
        req.status = status
        req.error = error
        req.done = True
        req.t_done = time.perf_counter()
        self.serve_stats[status if status != "ok" else "finished"] += 1

    def _harden_prompt(self, req: Request) -> bool:
        """Validate the prompt against the model vocab under
        ``index_policy``.  strict → the REQUEST fails (typed, terminal),
        clamp/drop → repair and count.  Returns False when terminal."""
        prompt = np.asarray(req.prompt, np.int32).reshape(-1)
        req.prompt = prompt
        if self._vocab is None:
            return True
        bad = (prompt < 0) | (prompt >= self._vocab)
        nbad = int(bad.sum())
        if nbad == 0:
            return True
        if self.index_policy == "strict":
            self._terminate(
                req, "failed",
                error=f"MalformedAccessError: {nbad} prompt token(s) "
                      f"outside [0, {self._vocab})")
            return False
        self.serve_stats["oob_prompt_tokens"] += nbad
        if self.index_policy == "clamp":
            req.prompt = np.clip(prompt, 0, self._vocab - 1)
            return True
        req.prompt = prompt[~bad]        # drop
        if req.prompt.size == 0:
            self._terminate(req, "failed",
                            error="MalformedAccessError: prompt empty "
                                  "after dropping out-of-bounds tokens")
            return False
        return True

    def _deadline(self, req: Request) -> Optional[float]:
        return req.deadline_s if req.deadline_s is not None \
            else self.ttft_slo_s

    def _shed_at_submit(self, req: Request) -> bool:
        """Predicted-wait shed: with a calibrated service capacity, a
        request that would wait out its whole TTFT budget in the queue is
        shed NOW — the overload answer that keeps the queue bounded."""
        d = self._deadline(req)
        if d is None or not self.capacity_rps:
            return False
        predicted_wait = len(self.queue) / self.capacity_rps
        if predicted_wait > d:
            self._terminate(req, "shed",
                            error=f"predicted queue wait "
                                  f"{predicted_wait:.3f}s > budget {d:.3f}s")
            return True
        return False

    def _predict_ttft_s(self, req: Request) -> float:
        """Service-time part of the TTFT prediction at admission: prefill
        waves needed × the measured wave EWMA (0 until a wave has run —
        the cold server admits optimistically)."""
        if self._ewma_wave_s is None:
            return 0.0
        prefill_waves = max(
            1, -(-int(np.size(req.prompt)) // self.prefill_chunk))
        return prefill_waves * self._ewma_wave_s

    def _admit(self):
        """Fill every free slot from the priority heap — called at the top
        of each serving iteration AND right after mid-wave retirement, so a
        freed slot is refilled in the same iteration.  A popped request
        whose TTFT budget already lapsed (``expired``) or provably cannot
        make it (``shed``) is retired here, terminal, and the next queued
        request considered for the slot."""
        for i in range(self.slots):
            if self.active[i] is not None:
                continue
            while self.queue:
                _, _, req = heapq.heappop(self.queue)
                now = time.perf_counter()
                d = self._deadline(req)
                if d is not None:
                    waited = now - req.t_submit
                    if waited >= d:
                        self._terminate(req, "expired",
                                        error=f"TTFT budget {d:.3f}s "
                                              f"lapsed in queue")
                        continue
                    if waited + self._predict_ttft_s(req) > d:
                        self._terminate(
                            req, "shed",
                            error=f"predicted TTFT exceeds budget "
                                  f"{d:.3f}s at admission")
                        continue
                req.t_admit = now
                req.status = "active"
                req.admitted_wave = self.waves
                self.active[i] = req
                # leave >=1 position of room for generated tokens
                self._prompt_left[i] = np.asarray(
                    req.prompt, np.int32).reshape(-1)[:self.max_len - 1]
                self._pos[i] = 0
                self.serve_stats["admitted"] += 1
                break

    def _finish(self, i: int, req: Request, retired: np.ndarray,
                status: str = "ok", error: Optional[str] = None):
        req.status = status
        if error is not None:
            req.error = error
        req.done = True
        req.t_done = time.perf_counter()
        req.finished_wave = self.waves
        if req.admitted_wave is not None:
            # waves this request occupied a slot — the span the auto
            # capacity estimate divides the wave throughput by
            self._req_wave_spans += max(
                1, req.finished_wave - req.admitted_wave + 1)
            self._req_span_count += 1
        retired[i] = True
        self.serve_stats[status if status != "ok" else "finished"] += 1

    def _recycle(self, retired: np.ndarray):
        """Mid-wave slot recycling: zero the retired slots' cache state and
        admit from the queue into them immediately."""
        if not retired.any():
            return
        self.caches = self._reset(self.caches, jnp.asarray(~retired))
        self.serve_stats["slot_resets"] += int(retired.sum())
        for i in np.where(retired)[0]:
            self.active[i] = None
            self._prompt_left[i] = _EMPTY
            self._pos[i] = 0
        self._admit()

    # ------------------------------------------------------------------
    # Wave loop
    # ------------------------------------------------------------------

    def _feed_pipeline(self, tokens: np.ndarray):
        """Mirror this wave's access streams into the pipeline group: the
        decode-embed lookups of THIS wave marshal while the previous wave's
        un-dispatch gather may still be executing (shared staging pool,
        per-program in-flight accounting)."""
        grp = self.pipeline_group
        toks = np.ascontiguousarray(tokens[:, 0], np.int32)
        emb = self.params["embed"]
        wave = {self._embed_name:
                {"tok_embed": {"table": emb, "idxs": toks},
                 "label_gather": {"table": emb, "idxs": toks}}}
        if self._undispatch_name is not None:
            idxs = (np.arange(self._undisp_segments, dtype=np.int64) *
                    (int(toks[0]) + 1)) % self._undisp_rows
            wave[self._undispatch_name] = \
                {"moe_undispatch": {"table": self._cap_buf,
                                    "idxs": idxs.astype(np.int32)}}
        handles = grp.submit_wave(wave)
        if self.wave_deadline_s is not None:
            # the watchdog needs a bounded observation point: consume this
            # wave's handles now (trades the cross-wave overlap for an
            # enforceable deadline — only paid when a deadline is set)
            for h in handles.values():
                h.result()

    def step(self) -> int:
        """One serving iteration: admit → one wave (chunked prefill and/or
        decode) → retire + recycle + same-iteration admit.  Returns the
        number of active slots afterwards."""
        self._admit()
        if not any(r is not None for r in self.active):
            return 0
        c = self.prefill_chunk \
            if any(p.size for p in self._prompt_left) else 1
        tokens = np.zeros((self.slots, c), np.int32)
        lens = np.zeros(self.slots, np.int32)
        emits = np.zeros(self.slots, bool)   # slot emits a token this wave
        retired = np.zeros(self.slots, bool)
        for i, req in enumerate(self.active):
            if req is None:
                continue
            room = self.max_len - int(self._pos[i])
            left = self._prompt_left[i]
            if left.size:
                n = min(left.size, c, room)
                if n == 0:      # no cache room left mid-prompt: truncated
                    self._finish(i, req, retired)
                    continue
                tokens[i, :n] = left[:n]
                lens[i] = n
                self._prompt_left[i] = left[n:]
                emits[i] = self._prompt_left[i].size == 0
            else:
                if room <= 0:   # cannot place another token
                    self._finish(i, req, retired)
                    continue
                tokens[i, 0] = self._next_token[i]
                lens[i] = 1
                emits[i] = True
        if lens.sum() == 0:
            self._recycle(retired)
            return sum(r is not None for r in self.active)
        # --- the guarded wave body: LM step + pipeline feed, under the
        # watchdog deadline, retried after a typed fault ------------------
        tokens_j, lens_j = jnp.asarray(tokens), jnp.asarray(lens)
        t0 = time.perf_counter()
        lm_done = False     # the LM wave donates its caches: NEVER re-run
        attempt = 0
        while True:
            try:
                if self.faults is not None:
                    self.faults.fire("wave", wave=self.waves)
                if not lm_done:
                    logits, self.caches = self._wave(
                        self.params, tokens_j, lens_j, self.caches)
                    lm_done = True
                if self.pipeline_group is not None:
                    self._feed_pipeline(tokens)
                if self.wave_deadline_s is not None:
                    el = time.perf_counter() - t0
                    if el > self.wave_deadline_s:
                        raise WaveTimeout(
                            f"wave {self.waves} took {el * 1e3:.1f}ms > "
                            f"deadline {self.wave_deadline_s * 1e3:.1f}ms")
                break
            except EmberFault as e:
                # typed faults only: anything else is a bug and propagates
                self.serve_stats["wave_faults"] += 1
                if isinstance(e, WaveTimeout):
                    self.serve_stats["watchdog_timeouts"] += 1
                if self.pipeline_group is not None:
                    self.pipeline_group.reset()
                if attempt >= self.wave_retries:
                    # fail ONLY the implicated requests (the slots served
                    # by this wave); their slots recycle, the loop lives
                    err = f"{type(e).__name__}: {e}"
                    for i, req in enumerate(self.active):
                        if req is None or retired[i]:
                            continue
                        self._finish(i, req, retired, status="failed",
                                     error=err)
                    self._recycle(retired)
                    return sum(r is not None for r in self.active)
                attempt += 1
                self.serve_stats["wave_retries"] += 1
                t0 = time.perf_counter()   # the retry gets a fresh budget
        dt = time.perf_counter() - t0
        self._ewma_wave_s = dt if self._ewma_wave_s is None else \
            0.7 * self._ewma_wave_s + 0.3 * dt
        nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1))
        self._pos += lens
        self.waves += 1
        self.serve_stats["waves"] += 1
        self.serve_stats["prefill_waves" if c > 1 else "decode_waves"] += 1
        if self.artifact_dir is not None and not self._artifact_saved \
                and self.emb_executor is not None:
            # first wave done: re-save so the artifact carries the AOT
            # executables captured while serving it (idempotent publish)
            self._artifact_saved = True
            try:
                self.emb_executor.save_artifact()
            except OSError:
                pass                     # a failed save never fails a wave
        now = time.perf_counter()
        # mid-wave expiry: a slot still waiting on its first token whose
        # TTFT budget lapsed during service retires here (terminal), so an
        # overloaded wave never holds dead slots
        for i, req in enumerate(self.active):
            if req is None or retired[i] or req.t_first is not None:
                continue
            d = self._deadline(req)
            if d is not None and now - req.t_submit > d:
                self._finish(i, req, retired, status="expired",
                             error=f"TTFT budget {d:.3f}s lapsed in service")
        for i, req in enumerate(self.active):
            if req is None or retired[i] or not emits[i]:
                continue
            tok = int(nxt[i])
            req.out.append(tok)
            req.token_times.append(now)
            if req.t_first is None:
                req.t_first = now
            self._next_token[i] = tok
            if (self.eos is not None and tok == self.eos) or \
                    len(req.out) >= req.max_new_tokens or \
                    int(self._pos[i]) >= self.max_len:
                self._finish(i, req, retired)
        self._recycle(retired)
        # after the finish pass, so a drive whose requests all retire on
        # the final wave still arms the estimate before draining
        self._update_capacity()
        return sum(r is not None for r in self.active)

    def _update_capacity(self) -> None:
        """Live capacity estimate under ``capacity_rps="auto"``: each wave
        serves up to ``slots`` requests concurrently, and a finished
        request occupied its slot for its measured wave span, so sustained
        throughput ≈ slots / (wave_s × avg waves-per-request).  Armed only
        after the warmup wave count (cold-compile waves would poison the
        EWMA) and at least one finished request."""
        if not self._capacity_auto or self._ewma_wave_s is None or \
                self.waves < self.capacity_warmup_waves or \
                not self._req_span_count:
            return
        avg_span = self._req_wave_spans / self._req_span_count
        est = self.slots / (self._ewma_wave_s * avg_span)
        self.capacity_rps = est
        self.serve_stats["capacity_rps_live"] = round(est, 2)

    def run_until_drained(self, max_steps: int = 100_000):
        steps = 0
        while (self.queue or
               any(r is not None for r in self.active)) and \
                steps < max_steps:
            self.step()
            steps += 1
        if self.pipeline_group is not None:
            self.pipeline_group.drain()
        if self.emb_executor is not None:
            self.compile_stats = self._gather_compile_stats()
        return steps
