"""Batched decode server.

Continuous-batching-lite: a fixed decode batch of slots; finished sequences
(EOS or length limit) are replaced by queued requests between steps.  The
KV caches are slot-indexed, so admission is a per-slot cache reset + prompt
prefill-by-decode (prompt tokens replayed through ``decode_step`` — one
code path, which is also exactly the ``serve_step`` the dry-run lowers).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Request:
    prompt: np.ndarray              # (L,) int32
    max_new_tokens: int = 16
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class DecodeServer:
    def __init__(self, lm, params, *, batch_slots: int = 4,
                 max_len: int = 256, eos_id: Optional[int] = None):
        self.lm = lm
        self.params = params
        self.slots = batch_slots
        self.max_len = max_len
        self.eos = eos_id
        self.queue: deque = deque()
        self.active: List[Optional[Request]] = [None] * batch_slots
        self._pending_prompt: List[deque] = [deque()
                                             for _ in range(batch_slots)]
        self.caches = lm.init_caches(batch_slots, max_len)
        self._step = jax.jit(lm.decode_step)
        # Ember steady-state path: the decode step's irregular lookups
        # compile ONCE per (slots, 1) signature and the ProgramExecutor's
        # marshaling cache (device-resident stacked tables + roff streams)
        # is memoized alongside — every later wave is a double cache hit.
        # A model whose ShardCtx mesh has a >1-wide `model` axis gets the
        # vocab-sharded executor (stacked tables partitioned over the axis).
        self.emb_compiled = None
        self.emb_executor = None
        self.compile_stats: Optional[dict] = None
        if hasattr(lm, "embedding_program"):
            from ..core import executor as emb_exec
            from ..core import pipeline as emberc
            self._emberc = emberc
            self._emb_exec = emb_exec
            self.emb_executor = self._resolve_executor()
            self.emb_compiled = self.emb_executor.compiled
            self.compile_stats = self._gather_compile_stats()

    def _resolve_executor(self):
        if hasattr(self.lm, "embedding_executor"):
            return self.lm.embedding_executor(self.slots, 1)
        return self._emb_exec.executor_for(
            self.lm.embedding_program(self.slots, 1))

    def _gather_compile_stats(self) -> dict:
        s = self._emberc.compile_cache_stats()
        s["executor_cache"] = self._emb_exec.executor_cache_stats()
        s["executor"] = dict(self.emb_executor.stats)
        s["executor"]["shards"] = self.emb_executor.shards
        # sharded serving observability: which exchange moves the offset
        # streams (host scatter vs device all_to_all) and whether pooled
        # outputs are reduce-scattered or replicated — with host_syncs in
        # the stats dict above, the per-step transfer count it saves
        s["executor"]["exchange"] = self.emb_executor.exchange
        s["executor"]["replicate_outputs"] = \
            self.emb_executor.replicate_outputs
        # the compiled access side, observable: hot/cold layout, exchange
        # bytes est. vs. actual, per-pass plan-build time (plan-access)
        s["access_plans"] = self.emb_executor.access_plan_stats()
        return s

    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        # wave batching: the cache `len` counter is shared across slots, so
        # new requests are admitted only when the whole batch drained (the
        # caches are then re-zeroed).  Per-slot position counters — true
        # continuous batching — are a documented extension point.
        if any(self.active) or not self.queue:
            return
        self.caches = self.lm.init_caches(self.slots, self.max_len)
        if self.emb_executor is not None:
            # per-wave re-resolve is free: identical program signature →
            # executor-cache hit (same warm marshaling cache back)
            self.emb_executor = self._resolve_executor()
            self.emb_compiled = self.emb_executor.compiled
            self.compile_stats = self._gather_compile_stats()
        for i in range(self.slots):
            if self.queue:
                req = self.queue.popleft()
                self.active[i] = req
                self._pending_prompt[i] = deque(req.prompt.tolist())

    def step(self) -> int:
        """One decode step for the whole batch; returns #active."""
        self._admit()
        if not any(self.active):
            return 0
        tokens = np.zeros((self.slots, 1), np.int32)
        for i, req in enumerate(self.active):
            if req is None:
                continue
            if self._pending_prompt[i]:
                tokens[i, 0] = self._pending_prompt[i].popleft()
            elif req.out:
                tokens[i, 0] = req.out[-1]
            else:
                tokens[i, 0] = req.prompt[-1]
        logits, self.caches = self._step(self.params, jnp.asarray(tokens),
                                         self.caches)
        nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1))
        for i, req in enumerate(self.active):
            if req is None:
                continue
            if self._pending_prompt[i]:
                continue  # still prefill-replaying the prompt
            req.out.append(int(nxt[i]))
            if (self.eos is not None and req.out[-1] == self.eos) or \
                    len(req.out) >= req.max_new_tokens:
                req.done = True
                self.active[i] = None
        return sum(r is not None for r in self.active)

    def run_until_drained(self, max_steps: int = 10_000):
        steps = 0
        while (self.queue or any(self.active)) and steps < max_steps:
            self.step()
            steps += 1
        return steps
