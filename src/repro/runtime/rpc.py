"""Length-prefixed typed RPC transport for the disaggregated embedding tier.

The wire format is deliberately dumb — a framing layer, not a protocol
stack — because everything above it (idempotent replay, failover, circuit
breaking) lives in :mod:`repro.runtime.embedding_service` where it can be
chaos-tested against the shared fault vocabulary:

    +--------+----------+-----------+------------------+
    | b"EMB1"| u32 hlen | u64 blen  | header | arrays  |
    +--------+----------+-----------+------------------+

``header`` is ``hlen`` bytes of JSON::

    {"kind": "step", "meta": {...},
     "arrays": [{"key": "...", "shape": [...], "dtype": "...",
                 "nbytes": N}, ...]}

followed by ``blen`` bytes of raw C-order array data, concatenated in
manifest order.  numpy arrays round-trip losslessly (the bit-identity the
disagg bench asserts); every other value rides the JSON ``meta``.

Robustness properties of this layer alone:

* **Per-call deadlines** — every receive tracks a wall-clock deadline
  across partial reads; a lapse raises a typed :class:`RpcTimeout`
  (transport-class: the caller's retry/failover loop may handle it).
* **Typed error transport** — a ``kind="err"`` frame names a class from
  :data:`repro.runtime.faults.FAULT_TYPES` and :func:`raise_typed`
  re-raises the SAME type client-side, so a service-side
  ``MalformedAccessError`` stays an application error (terminal for the
  request) and is never mistaken for a dead replica.
* **Chaos instrumentation** — :func:`send_msg`/:func:`recv_msg` fire the
  ``rpc_send``/``rpc_recv`` injector sites before touching the socket, so
  a seeded schedule can sever any call deterministically.

:func:`backoff_delays` reproduces the exponential shape of
``benchmarks/_mesh.run_with_spawn_retry`` (0, b, 2b, 4b, ...) for the
client's bounded retry and the pool's replica respawn — one backoff
policy across spawn and wire.
"""
from __future__ import annotations

import json
import socket
import struct
import time
from typing import Iterator, Optional, Tuple

import numpy as np

from .faults import FAULT_TYPES, RpcError, RpcTimeout

__all__ = ["send_msg", "recv_msg", "raise_typed", "backoff_delays",
           "Deadline", "RpcClient", "RpcError", "RpcTimeout"]

MAGIC = b"EMB1"
_HDR = struct.Struct(">4sIQ")

#: frame-size ceilings: a corrupt length prefix must fail fast and typed,
#: not attempt a multi-TiB allocation
MAX_HEADER = 64 << 20
MAX_BODY = 16 << 30


def backoff_delays(attempts: int, backoff_s: float) -> Iterator[float]:
    """The ``run_with_spawn_retry`` backoff shape: attempt k sleeps
    ``backoff_s * 2**(k-1)`` first (k=0 sleeps nothing)."""
    for k in range(attempts):
        yield 0.0 if k == 0 else backoff_s * (2 ** (k - 1))


class Deadline:
    """A wall-clock budget shared across the partial reads of one call."""

    def __init__(self, seconds: Optional[float]):
        self.t_end = None if seconds is None else \
            time.perf_counter() + seconds

    def remaining(self) -> Optional[float]:
        if self.t_end is None:
            return None
        left = self.t_end - time.perf_counter()
        if left <= 0:
            raise RpcTimeout("rpc deadline lapsed")
        return left


def _pack(kind: str, meta: Optional[dict], arrays: Optional[dict]
          ) -> Tuple[bytes, list]:
    manifest = []
    bufs = []
    for key, arr in (arrays or {}).items():
        a = np.ascontiguousarray(arr)
        manifest.append({"key": key, "shape": list(a.shape),
                         "dtype": a.dtype.str, "nbytes": a.nbytes})
        bufs.append(a)
    header = json.dumps({"kind": kind, "meta": meta or {},
                         "arrays": manifest}).encode()
    return header, bufs


def send_msg(sock: socket.socket, kind: str, meta: Optional[dict] = None,
             arrays: Optional[dict] = None, *, faults=None) -> None:
    """Frame and send one message.  Any failure surfaces as an ``OSError``
    (the transport class the caller's failover loop catches); the
    ``rpc_send`` chaos site fires first so a schedule can sever the call
    before a byte moves."""
    if faults is not None:
        faults.fire("rpc_send", kind=kind)
    header, bufs = _pack(kind, meta, arrays)
    body_len = sum(b.nbytes for b in bufs)
    sock.sendall(_HDR.pack(MAGIC, len(header), body_len))
    sock.sendall(header)
    for b in bufs:
        sock.sendall(memoryview(b).cast("B"))


def _recv_exact(sock: socket.socket, n: int, deadline: Deadline) -> bytes:
    chunks = []
    got = 0
    while got < n:
        sock.settimeout(deadline.remaining())
        try:
            chunk = sock.recv(min(n - got, 1 << 20))
        except socket.timeout as e:
            raise RpcTimeout("rpc deadline lapsed mid-frame") from e
        if not chunk:
            raise RpcError("connection closed mid-frame")
        chunks.append(chunk)
        got += len(chunk)
    return b"".join(chunks)


def recv_msg(sock: socket.socket, *, deadline_s: Optional[float] = None,
             faults=None) -> Tuple[str, dict, dict]:
    """Receive one framed message → ``(kind, meta, arrays)``.

    Raises :class:`RpcTimeout` when ``deadline_s`` lapses (across partial
    reads, not per chunk) and :class:`RpcError` on framing violations or a
    peer that closed mid-frame.  The ``rpc_recv`` chaos site fires before
    the read, modeling a reply lost on the wire."""
    if faults is not None:
        faults.fire("rpc_recv")
    deadline = Deadline(deadline_s)
    magic, hlen, blen = _HDR.unpack(_recv_exact(sock, _HDR.size, deadline))
    if magic != MAGIC:
        raise RpcError(f"bad frame magic {magic!r}")
    if hlen > MAX_HEADER or blen > MAX_BODY:
        raise RpcError(f"frame sizes out of range (header={hlen} "
                       f"body={blen})")
    try:
        header = json.loads(_recv_exact(sock, hlen, deadline))
        kind = header["kind"]
        meta = header["meta"]
        manifest = header["arrays"]
    except (ValueError, KeyError, TypeError) as e:
        raise RpcError(f"malformed frame header: {e}") from e
    arrays = {}
    for entry in manifest:
        raw = _recv_exact(sock, int(entry["nbytes"]), deadline)
        arrays[entry["key"]] = np.frombuffer(
            raw, dtype=np.dtype(entry["dtype"])
        ).reshape(entry["shape"]).copy()
    return kind, meta, arrays


def raise_typed(meta: dict) -> None:
    """Re-raise a service-side error frame as its original fault type."""
    name = meta.get("error", "EmberFault")
    msg = meta.get("msg", "")
    cls = FAULT_TYPES.get(name, RpcError)
    try:
        raise cls(msg)
    except TypeError:
        # multi-arg constructors (MalformedAccessError) degrade to the
        # base fault with the class name preserved in the message
        raise FAULT_TYPES["EmberFault"](f"{name}: {msg}") from None


class RpcClient:
    """One connection to one replica: framed calls with per-call deadlines.

    ``call`` is the synchronous convenience; ``send``/``recv_reply`` split
    the round trip so the executor's submit/result overlap can hide the
    hop (request leaves at ``submit``, reply is consumed at ``result``)."""

    def __init__(self, host: str, port: int, *,
                 timeout_s: Optional[float] = 5.0, faults=None):
        self.addr = (host, int(port))
        self.timeout_s = timeout_s
        self.faults = faults
        self.sock = socket.create_connection(self.addr, timeout=timeout_s)
        # step frames are small and latency-bound: don't nagle them
        self.sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)

    def send(self, kind: str, meta: Optional[dict] = None,
             arrays: Optional[dict] = None) -> None:
        send_msg(self.sock, kind, meta, arrays, faults=self.faults)

    def recv_reply(self, deadline_s: Optional[float] = None
                   ) -> Tuple[str, dict, dict]:
        kind, meta, arrays = recv_msg(
            self.sock,
            deadline_s=self.timeout_s if deadline_s is None else deadline_s,
            faults=self.faults)
        if kind == "err":
            raise_typed(meta)
        return kind, meta, arrays

    def call(self, kind: str, meta: Optional[dict] = None,
             arrays: Optional[dict] = None,
             deadline_s: Optional[float] = None) -> Tuple[dict, dict]:
        self.send(kind, meta, arrays)
        _, rmeta, rarrays = self.recv_reply(deadline_s)
        return rmeta, rarrays

    def close(self) -> None:
        try:
            self.sock.close()
        except OSError:
            pass
