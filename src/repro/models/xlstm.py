"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, chunk-parallel)
and sLSTM (scalar memory, recurrent).

mLSTM is the gated-linear recurrence

    C_t = f_t · C_{t-1} + i_t · v_t k_tᵀ ;   n_t = f_t n_{t-1} + i_t k_t
    h_t = (C_t q_t) / max(|n_t · q_t|, 1)

and reuses :mod:`repro.models.linear_scan` (the denominator runs through the
same scan with p=1).  Gates are stabilized in log space.  sLSTM keeps
per-head scalar cells with exponential gating and a block-diagonal recurrent
matrix — inherently sequential, expressed as a ``lax.scan`` over time.

Both blocks are self-contained (cfg.d_ff == 0): they own their up/down
projections, as in the paper.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ModelConfig, dense_init
from .linear_scan import gated_linear_scan, gated_linear_step


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def init_mlstm(key, cfg: ModelConfig, dtype):
    d, h = cfg.d_model, cfg.num_heads
    hd = d // h
    ks = jax.random.split(key, 7)
    return {
        "wq": dense_init(ks[0], (d, d), dtype),
        "wk": dense_init(ks[1], (d, d), dtype),
        "wv": dense_init(ks[2], (d, d), dtype),
        "w_if": dense_init(ks[3], (d, 2 * h), jnp.float32, scale=0.02),
        "b_i": jnp.full((h,), -3.0, jnp.float32),   # small initial write
        "b_f": jnp.full((h,), 3.0, jnp.float32),    # long initial memory
        "w_gate": dense_init(ks[4], (d, d), dtype),
        "wo": dense_init(ks[5], (d, d), dtype),
    }


def _mlstm_gates(p, x):
    g = x.astype(jnp.float32) @ p["w_if"]
    h = p["b_i"].shape[0]
    log_i = g[..., :h] + p["b_i"]                     # log input gate
    log_f = jax.nn.log_sigmoid(g[..., h:] + p["b_f"])  # log forget gate
    return log_i, log_f


def mlstm_forward(p, x, cfg: ModelConfig, unroll=False):
    b, s, d = x.shape
    h = cfg.num_heads
    hd = d // h
    q = (x @ p["wq"]).reshape(b, s, h, hd) * hd ** -0.5
    k = (x @ p["wk"]).reshape(b, s, h, hd) * hd ** -0.5
    v = (x @ p["wv"]).reshape(b, s, h, hd)
    log_i, log_f = _mlstm_gates(p, x)
    # input-gate bias starts at -3 so exp(log_i) stays small; the max(|n·q|,1)
    # denominator provides the remaining stabilization (paper App. A)
    scale = jnp.exp(jnp.minimum(log_i, 4.0))
    from .common import pick_chunk
    chunk = pick_chunk(s, min(cfg.ssm_chunk, s))
    num, _ = gated_linear_scan(v, log_f, scale, k, q, chunk, unroll=unroll)
    ones = jnp.ones((b, s, h, 1), x.dtype)
    den, _ = gated_linear_scan(ones, log_f, scale, k, q, chunk,
                               unroll=unroll)
    y = num / jnp.maximum(jnp.abs(den), 1.0)
    y = y.reshape(b, s, d) * jax.nn.silu(x @ p["w_gate"])
    return y @ p["wo"]


def mlstm_decode(p, x, cfg: ModelConfig, cache):
    """cache: {C (b,h,hd,hd), n (b,h,1,hd)}  (state is O(1) in seq len)."""
    b = x.shape[0]
    d = cfg.d_model
    h = cfg.num_heads
    hd = d // h
    xt = x[:, 0]
    q = (xt @ p["wq"]).reshape(b, h, hd) * hd ** -0.5
    k = (xt @ p["wk"]).reshape(b, h, hd) * hd ** -0.5
    v = (xt @ p["wv"]).reshape(b, h, hd)
    log_i, log_f = _mlstm_gates(p, xt)
    scale = jnp.exp(jnp.minimum(log_i, 4.0))
    num, C = gated_linear_step(cache["C"], v, log_f, scale, k, q)
    ones = jnp.ones((b, h, 1), x.dtype)
    den, n = gated_linear_step(cache["n"], ones, log_f, scale, k, q)
    y = num / jnp.maximum(jnp.abs(den), 1.0)
    y = y.reshape(b, d) * jax.nn.silu(xt @ p["w_gate"])
    return (y @ p["wo"])[:, None], {"C": C, "n": n}


def init_mlstm_cache(cfg: ModelConfig, batch, dtype):
    h = cfg.num_heads
    hd = cfg.d_model // h
    return {"C": jnp.zeros((batch, h, hd, hd), dtype),
            "n": jnp.zeros((batch, h, 1, hd), dtype)}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def init_slstm(key, cfg: ModelConfig, dtype):
    d, h = cfg.d_model, cfg.num_heads
    hd = d // h
    ks = jax.random.split(key, 4)
    return {
        "w_in": dense_init(ks[0], (d, 4 * d), dtype),        # z,i,f,o preacts
        "r": dense_init(ks[1], (h, hd, 4 * hd), dtype, scale=0.3 * hd ** -0.5),
        "b": jnp.concatenate([jnp.zeros((2 * d,)),
                              jnp.full((d,), 3.0),
                              jnp.zeros((d,))]).astype(jnp.float32),
        "wo": dense_init(ks[2], (d, d), dtype),
    }


def _slstm_cell(p, cfg, carry, wx_t):
    """One sLSTM step. carry: (h_prev, c, n, m) each (b, d) [m in fp32]."""
    d, nh = cfg.d_model, cfg.num_heads
    hd = d // nh
    h_prev, c, n, m = carry
    b = h_prev.shape[0]
    rh = jnp.einsum("bhd,hde->bhe", h_prev.reshape(b, nh, hd),
                    p["r"]).reshape(b, 4 * d)
    pre = (wx_t + rh).astype(jnp.float32) + p["b"]
    z, i_t, f_t, o = jnp.split(pre, 4, axis=-1)
    log_f = jax.nn.log_sigmoid(f_t)
    m_new = jnp.maximum(log_f + m, i_t)               # stabilizer
    i_s = jnp.exp(i_t - m_new)
    f_s = jnp.exp(log_f + m - m_new)
    c_new = f_s * c + i_s * jnp.tanh(z)
    n_new = f_s * n + i_s
    h_new = jax.nn.sigmoid(o) * c_new / jnp.maximum(n_new, 1.0)
    return (h_new.astype(wx_t.dtype), c_new, n_new, m_new), h_new


def slstm_forward(p, x, cfg: ModelConfig, cost_mode=False):
    b, s, d = x.shape
    wx = x @ p["w_in"]                                 # (b,s,4d)
    if cost_mode:
        return _slstm_flops_equivalent(p, x, wx, cfg)
    carry = (jnp.zeros((b, d), x.dtype),) + tuple(
        jnp.zeros((b, d), jnp.float32) for _ in range(3))
    carry, hs = jax.lax.scan(
        lambda cr, t: _slstm_cell(p, cfg, cr, t), carry,
        jnp.moveaxis(wx, 1, 0))
    y = jnp.moveaxis(hs, 0, 1).astype(x.dtype)
    return y @ p["wo"]


def _slstm_flops_equivalent(p, x, wx, cfg):
    """COST-MODE ONLY: numerically wrong but FLOP-identical stand-in for
    the sequential sLSTM scan (XLA counts scan bodies once; roofline docs).
    The recurrent block-diagonal matmul and gate arithmetic run once per
    timestep, batched over S."""
    b, s, d = x.shape
    nh = cfg.num_heads
    hd = d // nh
    h_fake = x.reshape(b, s, nh, hd)
    rh = jnp.einsum("bshd,hde->bshe", h_fake, p["r"]).reshape(b, s, 4 * d)
    pre = (wx + rh).astype(jnp.float32) + p["b"]
    z, i_t, f_t, o = jnp.split(pre, 4, axis=-1)
    log_f = jax.nn.log_sigmoid(f_t)
    m = jnp.maximum(log_f, i_t)
    c = jnp.exp(log_f + m) + jnp.exp(i_t - m) * jnp.tanh(z)
    n = jnp.exp(log_f) + jnp.exp(i_t - m)
    y = (jax.nn.sigmoid(o) * c / jnp.maximum(n, 1.0)).astype(x.dtype)
    return y @ p["wo"]


def slstm_decode(p, x, cfg: ModelConfig, cache):
    wx = (x[:, 0] @ p["w_in"])
    carry = (cache["h"], cache["c"], cache["n"], cache["m"])
    carry, h_new = _slstm_cell(p, cfg, carry, wx)
    y = (h_new.astype(x.dtype) @ p["wo"])[:, None]
    return y, {"h": carry[0], "c": carry[1], "n": carry[2], "m": carry[3]}


def init_slstm_cache(cfg: ModelConfig, batch, dtype):
    d = cfg.d_model
    return {"h": jnp.zeros((batch, d), dtype),
            "c": jnp.zeros((batch, d), jnp.float32),
            "n": jnp.zeros((batch, d), jnp.float32),
            "m": jnp.zeros((batch, d), jnp.float32)}
