"""Attention layers: blockwise (memory-O(S·chunk)) GQA with full/sliding
window, decode-with-cache, and DeepSeek MLA.

Blockwise attention is the jnp fallback of the Pallas flash kernel
(`repro.kernels.flash_attention`) — the dry-run and CPU tests lower this
path; on a TPU runtime the kernel is selected instead.  The online-softmax
scan over KV chunks keeps live memory at O(S·chunk) per head, which is what
makes the 32k-prefill and 500k shapes compile inside HBM.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .common import ModelConfig, apply_rope, dense_init, rope_freqs

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Blockwise multi-query/grouped attention (training & prefill)
# ---------------------------------------------------------------------------

def dense_attention(q, k, v, *, causal=True, window=None):
    """Plain O(S²)-memory attention. COST-MODE / small-shape path: flop-
    identical to the blockwise path but scan-free, so XLA cost analysis
    counts every block (scan bodies are counted once, see roofline docs)."""
    b, sq, h, d = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    g = h // hkv
    qg = q.reshape(b, sq, hkv, g, d)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                   preferred_element_type=jnp.float32) * d ** -0.5
    qp = jnp.arange(sq)[:, None]
    kp = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= qp >= kp
    if window is not None:
        mask &= qp - kp < window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p_ = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p_.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o.reshape(b, sq, h, dv).astype(q.dtype)


def blockwise_attention(q, k, v, *, causal: bool = True,
                        window: Optional[int] = None, chunk: int = 512,
                        banded: bool = True, dense: bool = False):
    """q (B,Sq,H,D); k,v (B,Sk,Hkv,D); GQA via head grouping. -> (B,Sq,H,D)

    ``banded=True`` with a window slides a static band of KV chunks along
    the diagonal (computes only ceil(window/chunk)+1 chunks per q chunk)
    instead of masking the full row — the O(S·w) sliding-window path.
    ``dense=True`` switches to the scan-free cost-mode path.
    """
    if dense:
        return dense_attention(q, k, v, causal=causal, window=window)
    b, sq, h, d = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    dv = v.shape[-1]                        # MLA: value dim ≠ qk dim
    g = h // hkv
    assert sq % chunk == 0 and sk % chunk == 0, (sq, sk, chunk)
    nq, nk = sq // chunk, sk // chunk
    scale = d ** -0.5

    qc = q.reshape(b, nq, chunk, hkv, g, d)
    kc = k.reshape(b, nk, chunk, hkv, d)
    vc = v.reshape(b, nk, chunk, hkv, dv)

    use_band = banded and window is not None and window < sk
    if use_band:
        band = -(-window // chunk) + 1          # kv chunks per q chunk
        band = min(band, nk)

    def q_step(_, qi):
        qblk = qc[:, qi]                        # (b, C, hkv, g, d)
        q_pos = qi * chunk + jnp.arange(chunk)

        def kv_step(carry, kj):
            m, l, acc = carry
            kblk = jax.lax.dynamic_index_in_dim(kc, kj, 1, keepdims=False)
            vblk = jax.lax.dynamic_index_in_dim(vc, kj, 1, keepdims=False)
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qblk, kblk,
                           preferred_element_type=jnp.float32) * scale
            k_pos = kj * chunk + jnp.arange(chunk)
            mask = jnp.ones((chunk, chunk), bool)
            if causal:
                mask &= q_pos[:, None] >= k_pos[None, :]
            if window is not None:
                mask &= q_pos[:, None] - k_pos[None, :] < window
            s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p.sum(-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p.astype(vblk.dtype), vblk,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, hkv, g, chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, chunk), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, chunk, dv), jnp.float32)
        if use_band:
            start = jnp.maximum(qi - (band - 1), 0)
            kjs = start + jnp.arange(band)
        elif causal:
            # static full scan; masked chunks above the diagonal contribute
            # nothing (hillclimb note: ~2× FLOP waste vs triangular skip)
            kjs = jnp.arange(nk)
        else:
            kjs = jnp.arange(nk)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), kjs)
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, out.astype(q.dtype)        # (b, hkv, g, C, d)

    _, outs = jax.lax.scan(q_step, None, jnp.arange(nq))
    # outs: (nq, b, hkv, g, C, dv) -> (b, S, h, dv)
    out = jnp.moveaxis(outs, 0, 1).transpose(0, 1, 4, 2, 3, 5)
    return out.reshape(b, sq, h, dv)


def decode_attention(q, k_cache, v_cache, cache_len, *,
                     window: Optional[int] = None):
    """q (B,1,H,D); caches (B,Smax,Hkv,D); cache_len (B,) per-slot valid
    lengths incl. the new token (a scalar — legacy whole-batch caches —
    broadcasts to the same math)."""
    b, _, h, d = q.shape
    smax, hkv = k_cache.shape[1], k_cache.shape[2]
    g = h // hkv
    qg = q.reshape(b, 1, hkv, g, d)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k_cache,
                   preferred_element_type=jnp.float32) * d ** -0.5
    cl = jnp.broadcast_to(cache_len, (b,))
    pos = jnp.arange(smax)
    mask = pos[None, :] < cl[:, None]
    if window is not None:
        mask &= pos[None, :] >= cl[:, None] - window
    s = jnp.where(mask[:, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, 1, h, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA attention layer (params, fwd, decode)
# ---------------------------------------------------------------------------

def init_attn(key, cfg: ModelConfig, dtype):
    d, h, hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], (d, h * hd), dtype),
        "wk": dense_init(ks[1], (d, hkv * hd), dtype),
        "wv": dense_init(ks[2], (d, hkv * hd), dtype),
        "wo": dense_init(ks[3], (h * hd, d), dtype),
    }


def attn_forward(p, x, cfg: ModelConfig, *, positions, causal=True,
                 window=None, kv=None, dense=False):
    """x (B,S,D). ``kv`` overrides K/V source (cross-attention)."""
    b, s, _ = x.shape
    h, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    src = kv if kv is not None else x
    sk = src.shape[1]
    q = (x @ p["wq"]).reshape(b, s, h, hd)
    k = (src @ p["wk"]).reshape(b, sk, hkv, hd)
    v = (src @ p["wv"]).reshape(b, sk, hkv, hd)
    if kv is None:  # self-attention: rotary
        cos, sin = rope_freqs(positions, hd, cfg.rope_theta, cfg.rotary_pct)
        q = apply_rope(q, cos, sin, cfg.rotary_pct)
        k = apply_rope(k, cos, sin, cfg.rotary_pct)
    import math
    from .common import pick_chunk
    chunk = pick_chunk(math.gcd(s, sk), min(cfg.attn_chunk, s))
    o = blockwise_attention(q, k, v, causal=causal and kv is None,
                            window=window, chunk=chunk, dense=dense)
    return o.reshape(b, s, h * hd) @ p["wo"]


def slot_update(cache, new, pos):
    """Per-slot cache write: ``cache`` (B,S,...), ``new`` (B,1,...) rows land
    at each slot's own position ``pos`` (B,) — the vmapped analogue of the
    single shared-position ``dynamic_update_slice`` that continuous batching
    needs once every slot carries its own counter."""
    zeros = (0,) * (cache.ndim - 2)
    return jax.vmap(
        lambda c, n, p: jax.lax.dynamic_update_slice(c, n, (p,) + zeros)
    )(cache, new, pos)


def attn_decode(p, x, cfg: ModelConfig, cache, *, window=None):
    """x (B,1,D); cache dict {k,v:(B,Smax,Hkv,hd), len:(B,) per-slot
    position counters} (self-attn).  A scalar ``len`` (legacy whole-batch
    caches) broadcasts through the same per-slot path bit-identically."""
    b = x.shape[0]
    h, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    pos = jnp.broadcast_to(cache["len"], (b,))
    q = (x @ p["wq"]).reshape(b, 1, h, hd)
    k = (x @ p["wk"]).reshape(b, 1, hkv, hd)
    v = (x @ p["wv"]).reshape(b, 1, hkv, hd)
    cos, sin = rope_freqs(pos[:, None].astype(jnp.float32), hd,
                          cfg.rope_theta, cfg.rotary_pct)
    q = apply_rope(q, cos, sin, cfg.rotary_pct)
    k = apply_rope(k, cos, sin, cfg.rotary_pct)
    if "k_scale" in cache:   # int8 quantized cache
        kq, ks = _quant_kv(k)
        vq, vs = _quant_kv(v)
        k_cache = slot_update(cache["k"], kq, pos)
        v_cache = slot_update(cache["v"], vq, pos)
        ks_c = slot_update(cache["k_scale"], ks, pos)
        vs_c = slot_update(cache["v_scale"], vs, pos)
        kd = _dequant_kv(k_cache, ks_c, x.dtype)
        vd = _dequant_kv(v_cache, vs_c, x.dtype)
        o = decode_attention(q, kd, vd, pos + 1, window=window)
        new_cache = {"k": k_cache, "v": v_cache, "k_scale": ks_c,
                     "v_scale": vs_c, "len": cache["len"] + 1}
        return o.reshape(b, 1, h * hd) @ p["wo"], new_cache
    k_cache = slot_update(cache["k"], k, pos)
    v_cache = slot_update(cache["v"], v, pos)
    o = decode_attention(q, k_cache, v_cache, pos + 1, window=window)
    new_cache = {"k": k_cache, "v": v_cache, "len": cache["len"] + 1}
    return o.reshape(b, 1, h * hd) @ p["wo"], new_cache


def init_kv_cache(cfg: ModelConfig, batch, max_len, dtype):
    hkv, hd = cfg.num_kv_heads, cfg.hd
    # ``len`` is per-slot: each batch slot carries its own position counter
    # so the serving loop can admit/retire requests slot-by-slot (true
    # continuous batching) instead of draining whole waves
    if cfg.kv_cache_dtype == "int8":
        # beyond-paper serving optimization: per-(token, head) block-scaled
        # int8 KV — halves-to-quarters the decode memory term (§Perf)
        return {"k": jnp.zeros((batch, max_len, hkv, hd), jnp.int8),
                "v": jnp.zeros((batch, max_len, hkv, hd), jnp.int8),
                "k_scale": jnp.zeros((batch, max_len, hkv), jnp.float32),
                "v_scale": jnp.zeros((batch, max_len, hkv), jnp.float32),
                "len": jnp.zeros((batch,), jnp.int32)}
    return {"k": jnp.zeros((batch, max_len, hkv, hd), dtype),
            "v": jnp.zeros((batch, max_len, hkv, hd), dtype),
            "len": jnp.zeros((batch,), jnp.int32)}


def _quant_kv(x):
    """x (b,1,h,d) -> int8 values + per-(token,head) scale."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def _dequant_kv(q, scale, dtype):
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


# ---------------------------------------------------------------------------
# DeepSeek MLA (multi-head latent attention)
# ---------------------------------------------------------------------------

def init_mla(key, cfg: ModelConfig, dtype):
    d, h, hd, r = cfg.d_model, cfg.num_heads, cfg.hd, cfg.kv_lora_rank
    rd = cfg.rope_head_dim
    ks = jax.random.split(key, 6)
    return {
        "wq": dense_init(ks[0], (d, h * (hd + rd)), dtype),
        "w_dkv": dense_init(ks[1], (d, r), dtype),
        "w_uk": dense_init(ks[2], (r, h * hd), dtype),
        "w_uv": dense_init(ks[3], (r, h * hd), dtype),
        "w_kr": dense_init(ks[4], (d, rd), dtype),
        "wo": dense_init(ks[5], (h * hd, d), dtype),
    }


def mla_forward(p, x, cfg: ModelConfig, *, positions, dense=False):
    b, s, _ = x.shape
    h, hd, rd = cfg.num_heads, cfg.hd, cfg.rope_head_dim
    q = (x @ p["wq"]).reshape(b, s, h, hd + rd)
    qn, qr = q[..., :hd], q[..., hd:]
    c = x @ p["w_dkv"]                                 # (b,s,r) latent KV
    kn = (c @ p["w_uk"]).reshape(b, s, h, hd)
    v = (c @ p["w_uv"]).reshape(b, s, h, hd)
    kr = (x @ p["w_kr"]).reshape(b, s, 1, rd)
    cos, sin = rope_freqs(positions, rd, cfg.rope_theta)
    qr = apply_rope(qr, cos, sin)
    kr = apply_rope(kr, cos, sin)
    qf = jnp.concatenate([qn, qr], axis=-1)
    kf = jnp.concatenate([kn, jnp.broadcast_to(kr, (b, s, h, rd))], axis=-1)
    from .common import pick_chunk
    chunk = pick_chunk(s, min(cfg.attn_chunk, s))
    o = blockwise_attention(qf, kf, v, causal=True, chunk=chunk, dense=dense)
    return o.reshape(b, s, h * hd) @ p["wo"]


def mla_decode(p, x, cfg: ModelConfig, cache):
    """MLA decode caches the *latent* c (B,S,r) + k_rope — the 5-10× KV
    memory reduction that makes deepseek decode_32k fit."""
    b = x.shape[0]
    h, hd, rd, r = cfg.num_heads, cfg.hd, cfg.rope_head_dim, cfg.kv_lora_rank
    pos = jnp.broadcast_to(cache["len"], (b,))
    q = (x @ p["wq"]).reshape(b, 1, h, hd + rd)
    qn, qr = q[..., :hd], q[..., hd:]
    c = x @ p["w_dkv"]
    kr = (x @ p["w_kr"]).reshape(b, 1, 1, rd)
    cos, sin = rope_freqs(pos[:, None].astype(jnp.float32), rd,
                          cfg.rope_theta)
    qr = apply_rope(qr, cos, sin)
    kr = apply_rope(kr, cos, sin)
    c_cache = slot_update(cache["c"], c.reshape(b, 1, r), pos)
    kr_cache = slot_update(cache["kr"], kr.reshape(b, 1, rd), pos)
    # absorbed attention: score = qn·(c W_uk) + qr·kr
    kn = jnp.einsum("bsr,rhd->bshd", c_cache,
                    p["w_uk"].reshape(r, h, hd))
    sc = (jnp.einsum("bqhd,bshd->bhqs", qn, kn) +
          jnp.einsum("bqhd,bsd->bhqs", qr, kr_cache)) * (hd + rd) ** -0.5
    mask = jnp.arange(c_cache.shape[1])[None, :] <= pos[:, None]
    sc = jnp.where(mask[:, None, None, :], sc, NEG_INF)
    pr = jax.nn.softmax(sc.astype(jnp.float32), axis=-1)
    v = jnp.einsum("bsr,rhd->bshd", c_cache, p["w_uv"].reshape(r, h, hd))
    o = jnp.einsum("bhqs,bshd->bqhd", pr.astype(v.dtype), v)
    new_cache = {"c": c_cache, "kr": kr_cache, "len": cache["len"] + 1}
    return o.reshape(b, 1, h * hd) @ p["wo"], new_cache


def init_mla_cache(cfg: ModelConfig, batch, max_len, dtype):
    return {"c": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
            "kr": jnp.zeros((batch, max_len, cfg.rope_head_dim), dtype),
            "len": jnp.zeros((batch,), jnp.int32)}
