"""LM assembly: block registry + scan-over-super-blocks transformer.

The depth dimension is folded into a ``jax.lax.scan`` over *super-blocks*
(one repetition of ``cfg.block_pattern``), so HLO size is independent of
depth — mandatory for compiling 94-layer models on one host and the right
structure at cluster scale.  Heterogeneous stacks (gemma3's 5 local : 1
global, zamba2's 5 mamba : 1 shared-attention) are expressed by the pattern;
depths not divisible by the pattern get an unscanned remainder stack.

Zamba2's *shared* attention block (one set of weights reused at every
occurrence) lives outside the scanned params and enters the scan body by
closure — parameter sharing that scan's per-step slicing cannot express.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ..core import embedding_engine as ee
from . import attention as attn
from . import moe as moe_mod
from . import ssm as ssm_mod
from . import xlstm as xlstm_mod
from .common import ModelConfig, init_mlp, init_rms, gated_mlp, rms_norm

ATTN_KINDS = ("dense", "dense_local", "moe", "shared_attn", "enc_dense",
              "xdec")


# ---------------------------------------------------------------------------
# Block init / apply / decode / cache — registry
# ---------------------------------------------------------------------------

def init_block(kind: str, key, cfg: ModelConfig, dtype):
    ks = jax.random.split(key, 4)
    if kind in ("dense", "dense_local", "enc_dense"):
        return {"norm1": init_rms(ks[0], cfg.d_model, dtype),
                "attn": attn.init_attn(ks[1], cfg, dtype),
                "norm2": init_rms(ks[2], cfg.d_model, dtype),
                "mlp": init_mlp(ks[3], cfg.d_model, cfg.d_ff, dtype)}
    if kind == "moe":
        return {"norm1": init_rms(ks[0], cfg.d_model, dtype),
                "attn": attn.init_attn(ks[1], cfg, dtype),
                "norm2": init_rms(ks[2], cfg.d_model, dtype),
                "moe": moe_mod.init_moe(ks[3], cfg, dtype)}
    if kind == "mla":
        return {"norm1": init_rms(ks[0], cfg.d_model, dtype),
                "attn": attn.init_mla(ks[1], cfg, dtype),
                "norm2": init_rms(ks[2], cfg.d_model, dtype),
                "moe": moe_mod.init_moe(ks[3], cfg, dtype)}
    if kind == "mamba":
        return {"norm1": init_rms(ks[0], cfg.d_model, dtype),
                "mamba": ssm_mod.init_mamba(ks[1], cfg, dtype)}
    if kind == "mlstm":
        return {"norm1": init_rms(ks[0], cfg.d_model, dtype),
                "mlstm": xlstm_mod.init_mlstm(ks[1], cfg, dtype)}
    if kind == "slstm":
        return {"norm1": init_rms(ks[0], cfg.d_model, dtype),
                "slstm": xlstm_mod.init_slstm(ks[1], cfg, dtype)}
    if kind == "shared_attn":
        # per-occurrence params are just the norms; weights come shared
        return {"norm1": init_rms(ks[0], cfg.d_model, dtype),
                "norm2": init_rms(ks[1], cfg.d_model, dtype)}
    if kind == "xdec":
        k5, k6 = jax.random.split(ks[3])
        return {"norm1": init_rms(ks[0], cfg.d_model, dtype),
                "attn": attn.init_attn(ks[1], cfg, dtype),
                "norm_x": init_rms(ks[2], cfg.d_model, dtype),
                "xattn": attn.init_attn(k5, cfg, dtype),
                "norm2": init_rms(k6, cfg.d_model, dtype),
                "mlp": init_mlp(jax.random.fold_in(key, 7), cfg.d_model,
                                cfg.d_ff, dtype)}
    raise ValueError(kind)


def block_apply(kind: str, p, x, cfg: ModelConfig, ctx: dict):
    """Full-sequence forward. Returns (x, aux_loss)."""
    eps = cfg.norm_eps
    aux = jnp.zeros((), jnp.float32)
    if kind in ("dense", "dense_local", "enc_dense", "moe", "mla"):
        window = cfg.sliding_window if kind == "dense_local" else None
        causal = kind != "enc_dense"
        h = rms_norm(x, p["norm1"], eps)
        dense = ctx.get("cost_mode", False)
        if kind == "mla":
            h = attn.mla_forward(p["attn"], h, cfg,
                                 positions=ctx["positions"], dense=dense)
        else:
            h = attn.attn_forward(p["attn"], h, cfg,
                                  positions=ctx["positions"],
                                  causal=causal, window=window, dense=dense)
        x = x + h
        h = rms_norm(x, p["norm2"], eps)
        if kind in ("moe", "mla"):
            h, aux = moe_mod.moe_ffn(p["moe"], h, cfg, mesh=ctx.get("mesh"),
                                     ep_axis=ctx.get("ep_axis"),
                                     data_axes=ctx.get("data_axes", ()))
        else:
            h = gated_mlp(h, p["mlp"], cfg.act)
        return x + h, aux
    if kind == "mamba":
        return x + ssm_mod.mamba_forward(
            p["mamba"], rms_norm(x, p["norm1"], eps), cfg,
            unroll=ctx.get("cost_mode", False)), aux
    if kind == "mlstm":
        return x + xlstm_mod.mlstm_forward(
            p["mlstm"], rms_norm(x, p["norm1"], eps), cfg,
            unroll=ctx.get("cost_mode", False)), aux
    if kind == "slstm":
        return x + xlstm_mod.slstm_forward(
            p["slstm"], rms_norm(x, p["norm1"], eps), cfg,
            cost_mode=ctx.get("cost_mode", False)), aux
    if kind == "shared_attn":
        sp = ctx["shared_params"]
        h = rms_norm(x, p["norm1"], eps)
        h = attn.attn_forward(sp["attn"], h, cfg, positions=ctx["positions"],
                              causal=True,
                              window=ctx.get("shared_window"),
                              dense=ctx.get("cost_mode", False))
        x = x + h
        h = rms_norm(x, p["norm2"], eps)
        return x + gated_mlp(h, sp["mlp"], cfg.act), aux
    if kind == "xdec":
        dense = ctx.get("cost_mode", False)
        h = rms_norm(x, p["norm1"], eps)
        x = x + attn.attn_forward(p["attn"], h, cfg,
                                  positions=ctx["positions"], causal=True,
                                  dense=dense)
        h = rms_norm(x, p["norm_x"], eps)
        x = x + attn.attn_forward(p["xattn"], h, cfg,
                                  positions=ctx["positions"],
                                  causal=False, kv=ctx["enc_out"],
                                  dense=dense)
        h = rms_norm(x, p["norm2"], eps)
        return x + gated_mlp(h, p["mlp"], cfg.act), aux
    raise ValueError(kind)


def init_block_cache(kind: str, cfg: ModelConfig, batch, max_len, dtype):
    if kind in ("dense", "dense_local", "moe", "shared_attn"):
        win = cfg.sliding_window if kind == "dense_local" else None
        alloc = min(max_len, win) if win else max_len
        return attn.init_kv_cache(cfg, batch, alloc if False else max_len,
                                  dtype)
    if kind == "mla":
        return attn.init_mla_cache(cfg, batch, max_len, dtype)
    if kind == "mamba":
        return ssm_mod.init_mamba_cache(cfg, batch, dtype)
    if kind == "mlstm":
        return xlstm_mod.init_mlstm_cache(cfg, batch, dtype)
    if kind == "slstm":
        return xlstm_mod.init_slstm_cache(cfg, batch, dtype)
    if kind == "xdec":
        return {"self": attn.init_kv_cache(cfg, batch, max_len, dtype),
                "enc_out": None}  # filled at prefill
    if kind == "enc_dense":
        return {}
    raise ValueError(kind)


def block_decode(kind: str, p, x, cfg: ModelConfig, cache, ctx: dict):
    eps = cfg.norm_eps
    if kind in ("dense", "dense_local", "moe", "mla", "shared_attn"):
        window = cfg.sliding_window if kind == "dense_local" else None
        h = rms_norm(x, p["norm1"], eps)
        if kind == "mla":
            h, cache = attn.mla_decode(p["attn"], h, cfg, cache)
        elif kind == "shared_attn":
            h, cache = attn.attn_decode(ctx["shared_params"]["attn"], h, cfg,
                                        cache,
                                        window=ctx.get("shared_window"))
        else:
            h, cache = attn.attn_decode(p["attn"], h, cfg, cache,
                                        window=window)
        x = x + h
        h = rms_norm(x, p["norm2"], eps)
        if kind in ("moe", "mla"):
            h, _ = moe_mod.moe_ffn(p["moe"], h, cfg, mesh=ctx.get("mesh"),
                                   ep_axis=ctx.get("ep_axis"),
                                   data_axes=ctx.get("data_axes", ()))
        elif kind == "shared_attn":
            h = gated_mlp(h, ctx["shared_params"]["mlp"], cfg.act)
        else:
            h = gated_mlp(h, p["mlp"], cfg.act)
        return x + h, cache
    if kind == "mamba":
        h, cache = ssm_mod.mamba_decode(p["mamba"],
                                        rms_norm(x, p["norm1"], eps), cfg,
                                        cache)
        return x + h, cache
    if kind == "mlstm":
        h, cache = xlstm_mod.mlstm_decode(p["mlstm"],
                                          rms_norm(x, p["norm1"], eps), cfg,
                                          cache)
        return x + h, cache
    if kind == "slstm":
        h, cache = xlstm_mod.slstm_decode(p["slstm"],
                                          rms_norm(x, p["norm1"], eps), cfg,
                                          cache)
        return x + h, cache
    if kind == "xdec":
        h = rms_norm(x, p["norm1"], eps)
        h, self_c = attn.attn_decode(p["attn"], h, cfg, cache["self"])
        x = x + h
        h = rms_norm(x, p["norm_x"], eps)
        x = x + attn.attn_forward(p["xattn"], h, cfg,
                                  positions=jnp.zeros((1, 1)),
                                  causal=False, kv=ctx["enc_out"])
        h = rms_norm(x, p["norm2"], eps)
        return x + gated_mlp(h, p["mlp"], cfg.act), \
            {"self": self_c, "enc_out": None}
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# The LM
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ShardCtx:
    mesh: object = None
    data_axes: tuple = ("data",)
    model_axis: str = "model"
    use_shard_map_embed: bool = True
    remat: str = "none"              # none | dots | full
    # cost mode: scan-free/unrolled FLOP-faithful lowering for the roofline
    # pass (never executed; see repro.roofline docs)
    cost_mode: bool = False


class LM:
    def __init__(self, cfg: ModelConfig, shard: Optional[ShardCtx] = None):
        self.cfg = cfg
        self.shard = shard or ShardCtx()

    # ---- Ember program compilation ----
    def embedding_program(self, batch: int, seq: int):
        """All irregular lookups of one (batch, seq) step as one
        :class:`~repro.core.ops.EmbeddingProgram` — what the runtimes
        compile (cached) and reuse across steps."""
        cfg = self.cfg
        tokens = batch * seq
        extra = []
        pattern = tuple(cfg.block_pattern) + tuple(cfg.remainder_pattern)
        if cfg.num_experts and any(k in ("moe", "mla") for k in pattern):
            extra.append(("moe_dispatch",
                          moe_mod.dispatch_op(cfg, tokens)))
        return ee.model_embedding_program(
            vocab_size=cfg.padded_vocab, d_model=cfg.d_model, tokens=tokens,
            extra_ops=tuple(extra), name=f"{cfg.name}-step")

    def decode_embed_program(self, batch: int, seq: int = 1):
        """The *embed side* of one decode wave as its own program (token
        embed + label gather over the shared table, no MoE op) — the first
        member of the serving pipeline group.  Splitting the wave's lookups
        into two compiled programs is what lets wave W+1's embed marshal
        overlap wave W's MoE un-dispatch execute."""
        cfg = self.cfg
        return ee.model_embedding_program(
            vocab_size=cfg.padded_vocab, d_model=cfg.d_model,
            tokens=batch * seq, name=f"{cfg.name}-decode-embed")

    def embedding_pipeline(self, batch: int, seq: int = 1,
                           opt_level: str = "O3", depth: int = 2,
                           **kw):
        """The serving :class:`~repro.core.executor.PipelineGroup`: the
        decode-embed program plus (for MoE models) the un-dispatch program,
        joined over one shared staging pool.  Non-MoE models get a
        single-member group (same API, no second program to overlap).

        Defaults to the jax backend: that is the path whose gather
        dispatches ride ``submit_wave``'s coalesced transfer + jitted wave
        executable (differential-tested identical to pallas)."""
        from ..core.executor import executor_for, pipeline_group
        kw.setdefault("backend", "jax")
        cfg = self.cfg
        members = [executor_for(self.decode_embed_program(batch, seq),
                                opt_level, depth=depth, **kw)]
        pattern = tuple(cfg.block_pattern) + tuple(cfg.remainder_pattern)
        if cfg.num_experts and any(k in ("moe", "mla") for k in pattern):
            members.append(executor_for(
                moe_mod.undispatch_program(cfg, batch * seq), opt_level,
                depth=depth, **kw))
        return pipeline_group(members)

    def compile_embeddings(self, batch: int, seq: int,
                           opt_level: str = "O3"):
        """Compile this model's embedding program (compile-cache backed)."""
        from ..core.pipeline import compile_program
        return compile_program(self.embedding_program(batch, seq), opt_level)

    def embedding_executor(self, batch: int, seq: int,
                           opt_level: str = "O3", mesh="auto",
                           hot_rows=None, **kw):
        """The steady-state executor of this model's embedding program:
        compile (cached) + device-resident marshaling cache + double-buffered
        step loop (:mod:`repro.core.executor`).  Memoized per signature, so
        every decode wave / train restart gets the same warm executor.

        ``mesh="auto"`` inherits the model's ``ShardCtx`` mesh: with a
        >1-wide model axis the fused stacked tables come back vocab-sharded
        over it (per-device footprint ÷ shards); pass ``mesh=None`` to force
        the replicated single-device executor.  ``hot_rows`` (e.g. from
        :func:`repro.core.access_plan.hot_rows_from_traces` over decode
        token traces) replicates the classified Zipf head of each vocab on
        every shard so those lookups skip the offset-stream exchange.
        ``exchange=``/``replicate_outputs=`` (forwarded via ``**kw``)
        select the sharded exchange mode — the device-collective
        ``all_to_all`` + reduce-scatter default, or the ``"host"`` scatter
        with fully-replicated outputs."""
        from ..core.executor import executor_for
        if mesh == "auto":
            mesh = self.shard.mesh
        return executor_for(self.embedding_program(batch, seq), opt_level,
                            mesh=mesh, shard_axis=self.shard.model_axis,
                            hot_rows=hot_rows, **kw)

    def embedding_table_inputs(self, params) -> dict:
        """The *param-backed* tables of :meth:`embedding_program`, keyed the
        way :meth:`ProgramExecutor.update_tables` wants them.  Deliberately
        partial: per-step operand tables (the MoE capacity buffer) are step
        data, not params — the executor skips their units."""
        return {"tok_embed": {"table": params["embed"]},
                "label_gather": {"table": params["embed"]}}

    # ---- init ----
    def init(self, key) -> dict:
        cfg = self.cfg
        dtype = cfg.jdtype
        keys = jax.random.split(key, 8)
        params = {
            "embed": (jax.random.normal(keys[0],
                                        (cfg.padded_vocab, cfg.d_model),
                                        jnp.float32) * 0.02).astype(dtype),
            "final_norm": jnp.ones((cfg.d_model,), dtype),
        }
        pattern = cfg.block_pattern

        def init_super(k):
            kk = jax.random.split(k, len(pattern))
            return tuple(init_block(kind, kk[i], cfg, dtype)
                         for i, kind in enumerate(pattern))

        supers = [init_super(jax.random.fold_in(keys[1], i))
                  for i in range(cfg.n_super)]
        params["scan"] = jax.tree.map(lambda *xs: jnp.stack(xs), *supers)
        params["rest"] = tuple(
            init_block(kind, jax.random.fold_in(keys[2], i), cfg, dtype)
            for i, kind in enumerate(cfg.remainder_pattern))
        if "shared_attn" in pattern or "shared_attn" in cfg.remainder_pattern:
            params["shared"] = {
                "attn": attn.init_attn(keys[3], cfg, dtype),
                "mlp": init_mlp(keys[4], cfg.d_model, cfg.d_ff, dtype),
            }
        if cfg.enc_layers:
            enc = [init_block("enc_dense", jax.random.fold_in(keys[5], i),
                              cfg, dtype) for i in range(cfg.enc_layers)]
            params["enc_scan"] = jax.tree.map(lambda *xs: jnp.stack(xs), *enc)
            params["enc_norm"] = jnp.ones((cfg.d_model,), dtype)
        if cfg.modality != "text":
            params["frontend_proj"] = jnp.eye(cfg.d_model, dtype=dtype)
        return params

    # ---- shared machinery ----
    def _batch_axes(self, batch_size: int) -> tuple:
        """Data axes the batch dim can actually shard over (empty when the
        global batch is too small — e.g. long_500k's batch of 1)."""
        sh = self.shard
        if sh.mesh is None:
            return ()
        import numpy as _np
        dsize = int(_np.prod([sh.mesh.shape[a] for a in sh.data_axes]))
        return tuple(sh.data_axes) \
            if batch_size % dsize == 0 and batch_size >= dsize else ()

    def _ctx(self, params, positions, batch_size=None) -> dict:
        sh = self.shard
        return {
            "positions": positions,
            "mesh": sh.mesh,
            "ep_axis": sh.model_axis if sh.mesh is not None else None,
            "data_axes": (self._batch_axes(batch_size)
                          if batch_size is not None else
                          (sh.data_axes if sh.mesh is not None else ())),
            "cost_mode": sh.cost_mode,
            "shared_params": params.get("shared"),
            "shared_window": (self.cfg.sliding_window
                              if self.cfg.family == "hybrid" and
                              not self.cfg.long_context_ok else None),
        }

    def _maybe_remat(self, f):
        r = self.shard.remat
        if r == "none":
            return f
        policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                  if r == "dots" else None)
        return jax.checkpoint(f, policy=policy)

    def _stack(self, params, x, ctx):
        cfg = self.cfg
        pattern = cfg.block_pattern

        def super_step(carry, layer_params):
            h, aux = carry
            for i, kind in enumerate(pattern):
                h, a = block_apply(kind, layer_params[i], h, cfg, ctx)
                aux = aux + a
            return (h, aux), None

        step = self._maybe_remat(
            lambda c, lp: super_step(c, lp))
        (x, aux), _ = jax.lax.scan(
            step, (x, jnp.zeros((), jnp.float32)), params["scan"],
            unroll=cfg.n_super if self.shard.cost_mode else 1)
        for i, kind in enumerate(cfg.remainder_pattern):
            x, a = block_apply(kind, params["rest"][i], x, cfg, ctx)
            aux = aux + a
        return x, aux

    def _encode(self, params, enc_embeds, ctx):
        x = enc_embeds @ params["frontend_proj"]

        def step(h, lp):
            h, _ = block_apply("enc_dense", lp, h, self.cfg, ctx)
            return h, None

        x, _ = jax.lax.scan(self._maybe_remat(step), x, params["enc_scan"],
                            unroll=(self.cfg.enc_layers
                                    if self.shard.cost_mode else 1))
        return rms_norm(x, params["enc_norm"], self.cfg.norm_eps)

    # ---- forward / loss ----
    def forward(self, params, batch: dict):
        """batch: {tokens (B,S)} [+ frontend_embeds (B,Sf,D)] [+ enc_embeds].
        Returns hidden states (B,S,D) after final norm."""
        cfg = self.cfg
        sh = self.shard
        tokens = batch["tokens"]
        b, s = tokens.shape
        ba = self._batch_axes(b)
        if sh.mesh is not None and sh.use_shard_map_embed:
            x = ee.lookup(params["embed"], tokens, mesh=sh.mesh,
                          vocab_axis=sh.model_axis,
                          strategy=cfg.embed_strategy,
                          data_axes=ba)
        else:
            x = ee.lookup(params["embed"], tokens, strategy="take")
        if cfg.modality == "vision-stub" and "frontend_embeds" in batch:
            fe = batch["frontend_embeds"] @ params["frontend_proj"]
            x = jnp.concatenate([fe, x[:, fe.shape[1]:]], axis=1)
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.float32)[None],
                                     (b, s))
        ctx = self._ctx(params, positions, batch_size=b)
        if cfg.enc_layers:
            ctx["enc_out"] = self._encode(params, batch["enc_embeds"], ctx)
        x, aux = self._stack(params, x, ctx)
        return rms_norm(x, params["final_norm"], cfg.norm_eps), aux

    def loss(self, params, batch: dict):
        cfg = self.cfg
        sh = self.shard
        x, aux = self.forward(params, batch)
        labels = batch["labels"]
        if sh.mesh is not None:
            ce = ee.xent_vocab_parallel(x, params["embed"], labels,
                                        mesh=sh.mesh,
                                        vocab_axis=sh.model_axis,
                                        data_axes=self._batch_axes(
                                            labels.shape[0]))
        else:
            lg = ee.logits(x, params["embed"])
            ce = jnp.mean(jax.nn.logsumexp(lg, -1) -
                          jnp.take_along_axis(lg, labels[..., None],
                                              -1)[..., 0])
        return ce + 0.01 * aux

    # ---- serving ----
    def init_caches(self, batch, max_len, dtype=None):
        cfg = self.cfg
        dtype = dtype or cfg.jdtype
        pattern = cfg.block_pattern

        def one_super():
            return tuple(init_block_cache(kind, cfg, batch, max_len, dtype)
                         for kind in pattern)

        caches = {
            "scan": jax.tree.map(lambda *xs: jnp.stack(xs),
                                 *[one_super() for _ in range(cfg.n_super)])
            if cfg.n_super else (),
            "rest": tuple(init_block_cache(k, cfg, batch, max_len, dtype)
                          for k in cfg.remainder_pattern),
        }
        return caches

    def prefill(self, params, batch: dict, caches):
        """Run the full-seq forward and (for simplicity of the runtime) fill
        caches by replaying tokens through decode in the serving loop; the
        dry-run lowers `serve_step` = one decode step, which is the shape
        that matters.  Here: returns last-position hidden state."""
        x, _ = self.forward(params, batch)
        return x[:, -1:]

    def decode_step(self, params, tokens_new, caches, batch_ctx=None,
                    active=None):
        """tokens_new (B,1) -> (logits (B,1,V-sharded…), caches).

        ``active`` (B,) bool masks the continuous-batching batch: inactive
        slots feed a zero token and keep their caches (incl. the per-slot
        ``len`` counter) bit-identical — the property that makes
        prompt-chunked prefill equal whole-prompt prefill regardless of how
        a wave's slots are staggered."""
        cfg = self.cfg
        sh = self.shard
        if active is not None:
            # zero the fed token so inactive slots contribute a deterministic
            # input to batch-coupled ops (MoE capacity contention)
            tokens_new = jnp.where(active[:, None], tokens_new, 0)
        if sh.mesh is not None and sh.use_shard_map_embed:
            x = ee.lookup(params["embed"], tokens_new, mesh=sh.mesh,
                          vocab_axis=sh.model_axis,
                          strategy=cfg.embed_strategy,
                          data_axes=self._batch_axes(tokens_new.shape[0]))
        else:
            x = ee.lookup(params["embed"], tokens_new, strategy="take")
        ctx = self._ctx(params, None, batch_size=tokens_new.shape[0])
        if cfg.enc_layers:
            ctx["enc_out"] = batch_ctx["enc_out"]
        pattern = cfg.block_pattern

        def keep_old(old, new):
            if active is None:
                return new
            return jax.tree.map(
                lambda o, n: jnp.where(
                    active.reshape((active.shape[0],) + (1,) * (n.ndim - 1)),
                    n, o), old, new)

        def super_step(h, xs):
            layer_params, layer_cache = xs
            new_caches = []
            for i, kind in enumerate(pattern):
                h, nc = block_decode(kind, layer_params[i], h, cfg,
                                     layer_cache[i], ctx)
                new_caches.append(keep_old(layer_cache[i], nc))
            return h, tuple(new_caches)

        if cfg.n_super:
            x, new_scan = jax.lax.scan(
                super_step, x, (params["scan"], caches["scan"]),
                unroll=cfg.n_super if self.shard.cost_mode else 1)
        else:
            new_scan = ()
        new_rest = []
        for i, kind in enumerate(cfg.remainder_pattern):
            x, nc = block_decode(kind, params["rest"][i], x, cfg,
                                 caches["rest"][i], ctx)
            new_rest.append(keep_old(caches["rest"][i], nc))
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = ee.logits(x, params["embed"])[..., :cfg.vocab_size]
        return logits, {"scan": new_scan, "rest": tuple(new_rest)}

    def wave_step(self, params, tokens, lens, caches, batch_ctx=None):
        """One serving *wave*: a fused ``lax.scan`` of ``tokens.shape[1]``
        masked decode micro-steps.  ``tokens`` (B,C) ragged-right with
        per-slot valid counts ``lens`` (B,); slot b consumes
        ``tokens[b, :lens[b]]`` and idles (caches untouched) afterwards.

        Because each micro-step is exactly :meth:`decode_step` with the
        ``active = t < lens`` mask, splitting a prompt across waves of any
        chunk size replays the *same* micro-step sequence as one big wave —
        prompt-chunked prefill is bit-identical to whole-prompt prefill.

        Returns ``(logits (B,1,V) at each slot's last valid token, caches)``.
        """
        b, c = tokens.shape
        lens = lens.astype(jnp.int32)

        def micro(carry, xs):
            caches, logits_last = carry
            tok, t = xs
            active = t < lens
            logits, caches = self.decode_step(params, tok[:, None], caches,
                                              batch_ctx=batch_ctx,
                                              active=active)
            logits_last = jnp.where(active[:, None, None], logits,
                                    logits_last)
            return (caches, logits_last), None

        init = (caches,
                jnp.zeros((b, 1, self.cfg.vocab_size), jnp.float32))
        (caches, logits_last), _ = jax.lax.scan(
            micro, init, (tokens.T, jnp.arange(c, dtype=jnp.int32)))
        return logits_last, caches

    def reset_slots(self, caches, keep):
        """Zero the cache state of retired slots (``keep`` (B,) bool) so a
        recycled slot starts from position 0 with no stale KV.  Scan-stacked
        leaves carry batch at axis 1 (leading axis is n_super), ``rest``
        leaves at axis 0."""
        def mask_at(axis):
            def f(leaf):
                shape = [1] * leaf.ndim
                shape[axis] = keep.shape[0]
                return jnp.where(keep.reshape(shape), leaf,
                                 jnp.zeros_like(leaf))
            return f
        return {"scan": jax.tree.map(mask_at(1), caches["scan"]),
                "rest": jax.tree.map(mask_at(0), caches["rest"])}
