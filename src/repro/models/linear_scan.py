"""Shared chunked gated-linear-recurrence core.

One algorithm serves both Mamba2's SSD and xLSTM's mLSTM (and any
linear-attention variant): the recurrence

    S_t = exp(a_t) · S_{t-1} + scale_t · x_t ⊗ B_t          (state (h,p,n))
    y_t = (C_t · S_t)                                        (readout)

is evaluated chunk-parallel: O(L²) attention-like contraction within each
chunk, a ``lax.scan`` carrying S across chunks.  All O(L²) intermediates are
chunk-local (never (S/L, L, L) global), so the 500k-token shapes fit.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gated_linear_scan(x, a, scale, B, C, chunk: int, state0=None,
                      unroll: bool = False):
    """x (b,s,h,p); a,scale (b,s,h); B,C (b,s,h,n). Returns (y, S_final)."""
    b, s, h, p = x.shape
    n = B.shape[-1]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    xc = x.reshape(b, nc, chunk, h, p)
    ac = a.reshape(b, nc, chunk, h)
    sc = scale.reshape(b, nc, chunk, h)
    Bc = B.reshape(b, nc, chunk, h, n)
    Cc = C.reshape(b, nc, chunk, h, n)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))

    def step(S_prev, inp):
        xc_, ac_, sc_, Bc_, Cc_ = inp
        acs = jnp.cumsum(ac_, axis=1)                       # (b,L,h)
        decay = jnp.exp(acs[:, :, None, :] - acs[:, None, :, :])
        decay = jnp.where(tri[None, :, :, None], decay, 0.0)
        cb = jnp.einsum("bihn,bjhn->bijh", Cc_, Bc_)
        w = cb * decay * sc_[:, None, :, :]
        y_intra = jnp.einsum("bijh,bjhp->bihp", w.astype(x.dtype), xc_)
        y_inter = jnp.einsum("blhn,bhpn,blh->blhp", Cc_, S_prev,
                             jnp.exp(acs).astype(x.dtype))
        tail = jnp.exp(acs[:, -1:, :] - acs) * sc_          # (b,L,h)
        S_new = jnp.einsum("blh,blhp,blhn->bhpn", tail.astype(x.dtype),
                           xc_, Bc_)
        cd = jnp.exp(acs[:, -1, :])
        S_next = (S_prev * cd[:, :, None, None].astype(x.dtype) +
                  S_new).astype(x.dtype)   # keep the carry dtype stable
        return S_next, (y_intra + y_inter).astype(x.dtype)

    S0 = state0 if state0 is not None else jnp.zeros((b, h, p, n), x.dtype)
    inputs = tuple(jnp.moveaxis(t, 1, 0) for t in (xc, ac, sc, Bc, Cc))
    S_final, ys = jax.lax.scan(step, S0, inputs,
                               unroll=nc if unroll else 1)
    return jnp.moveaxis(ys, 0, 1).reshape(b, s, h, p), S_final


def gated_linear_step(S_prev, x, a, scale, B, C):
    """Single-token recurrence (decode). x (b,h,p); a,scale (b,h); B,C (b,h,n)."""
    decay = jnp.exp(a)[:, :, None, None].astype(x.dtype)
    S = S_prev * decay + jnp.einsum("bh,bhp,bhn->bhpn",
                                    scale.astype(x.dtype), x, B)
    y = jnp.einsum("bhn,bhpn->bhp", C, S)
    return y, S
