"""Model substrate: configuration + shared layer primitives.

Every assigned architecture is an instance of :class:`ModelConfig`: a stack
of *super-blocks* (``block_pattern``) repeated ``num_layers //
len(pattern)`` times via ``jax.lax.scan`` (keeping HLO size O(1) in depth —
required for 94-layer dry-runs and the right structure at cluster scale),
plus an unscanned remainder when the depth is not a multiple of the
pattern.

Block kinds:

=============  ============================================================
``dense``      GQA attention (+RoPE/partial-RoPE) + gated MLP
``dense_local``same, sliding-window attention
``moe``        GQA attention + mixture-of-experts FFN (EP dispatch)
``mla``        DeepSeek MLA attention (compressed KV) + MoE FFN
``mlstm``      xLSTM mLSTM block (matrix memory, chunked linear attention)
``slstm``      xLSTM sLSTM block (scalar memory, recurrent scan)
``mamba``      Mamba2 SSD block (chunked state-space scan)
``shared_attn``Zamba-style global-attention block inserted in an SSM stack
``enc_dense``  bidirectional attention + MLP (whisper encoder)
``xdec``       causal self-attn + cross-attn + MLP (whisper decoder)
=============  ============================================================
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense|moe|ssm|hybrid|vlm|audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    block_pattern: Tuple[str, ...] = ("dense",)
    head_dim: Optional[int] = None
    # attention
    rope_theta: float = 10000.0
    rotary_pct: float = 1.0          # chatglm partial rotary
    sliding_window: int = 4096
    attn_chunk: int = 512            # kv/q chunk for blockwise attention
    # moe
    num_experts: int = 0
    experts_per_tok: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    # mla
    kv_lora_rank: int = 0
    rope_head_dim: int = 64
    # ssm / xlstm
    ssm_state: int = 64
    ssm_chunk: int = 256
    # encoder-decoder (whisper)
    enc_layers: int = 0
    enc_seq: int = 1500
    # frontends
    modality: str = "text"           # text | audio-stub | vision-stub
    act: str = "silu"                # mlp activation
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    # serving
    kv_cache_dtype: str = "model"    # model dtype | "int8" (quantized cache)
    # embedding engine strategy (Ember integration)
    embed_strategy: str = "masked_psum"
    # applicability notes (DESIGN.md §Arch-applicability)
    long_context_ok: bool = False    # sub-quadratic → long_500k runs

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    @property
    def padded_vocab(self) -> int:
        """Embedding-table rows: vocab padded to a multiple of 256 so the
        vocab dim shards evenly over any mesh model axis ≤256 (standard
        table padding; ids never address the pad rows, decode slices the
        logits back to the logical vocab)."""
        return -(-self.vocab_size // 256) * 256

    @property
    def n_super(self) -> int:
        return self.num_layers // len(self.block_pattern)

    @property
    def remainder_pattern(self) -> Tuple[str, ...]:
        r = self.num_layers % len(self.block_pattern)
        return self.block_pattern[:r]


# ---------------------------------------------------------------------------
# Primitives
# ---------------------------------------------------------------------------

def rms_norm(x, gamma, eps=1e-5):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)).astype(x.dtype) \
        * gamma


def init_rms(key, d, dtype):
    del key
    return jnp.ones((d,), dtype)


def dense_init(key, shape, dtype, scale=None):
    fan_in = shape[0]
    s = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * s).astype(dtype)


def rope_freqs(positions, head_dim, theta, rotary_pct=1.0):
    """positions (..., S) -> (cos, sin) of shape (..., S, rot/2)."""
    rot = int(head_dim * rotary_pct) // 2 * 2
    inv = 1.0 / (theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))
    ang = positions[..., None].astype(jnp.float32) * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin, rotary_pct=1.0):
    """x (..., S, H, D); cos/sin (..., S, rot/2)."""
    d = x.shape[-1]
    rot = int(d * rotary_pct) // 2 * 2
    xr, xp = x[..., :rot], x[..., rot:]
    x1, x2 = xr[..., ::2], xr[..., 1::2]
    c = cos[..., None, :]
    s = sin[..., None, :]
    o1 = x1 * c - x2 * s
    o2 = x2 * c + x1 * s
    out = jnp.stack([o1, o2], axis=-1).reshape(xr.shape).astype(x.dtype)
    return jnp.concatenate([out, xp], axis=-1) if rot < d else out


_ACTS = {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}


def pick_chunk(s: int, preferred: int) -> int:
    """Largest chunk ≤ preferred that divides s (gcd fallback)."""
    import math
    return preferred if s % preferred == 0 else math.gcd(s, preferred)


def gated_mlp(x, p, act="silu"):
    h = _ACTS[act](x @ p["wi_gate"]) * (x @ p["wi_up"])
    return h @ p["wo"]


def init_mlp(key, d_model, d_ff, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi_gate": dense_init(k1, (d_model, d_ff), dtype),
        "wi_up": dense_init(k2, (d_model, d_ff), dtype),
        "wo": dense_init(k3, (d_ff, d_model), dtype),
    }
