from .common import ModelConfig
from .lm import LM, ShardCtx

__all__ = ["ModelConfig", "LM", "ShardCtx"]
