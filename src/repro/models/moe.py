"""Mixture-of-Experts layer with expert-parallel (EP) dispatch.

MoE dispatch *is* an embedding operation in the paper's taxonomy: tokens are
gathered into per-expert capacity buffers by irregular indices (an SLS-class
scatter/gather, DESIGN.md §4), so the dispatch path is built on the same
sort-and-slot structure emberc generates for SLS — realized here at cluster
scale with a shard_map: local sort-based slotting (access), all-to-all over
the expert/model axis (the queue), expert FFN (execute), reverse all-to-all
and weighted combine.

Capacity-based dropping keeps every shape static (required for pjit); the
aux load-balance loss keeps the router from collapsing.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..core.jax_compat import shard_map
from ..core.ops import EmbeddingOp
from .common import ModelConfig, dense_init, _ACTS


def dispatch_op(cfg: ModelConfig, tokens: int) -> EmbeddingOp:
    """The EP dispatch as a characterized embedding operation.

    Un-dispatch (``out_buf[slot]`` below) is a plain irregular gather over
    the (E·C, D) capacity buffer — the op the Ember program compiler
    co-schedules with the step's other lookups (paper Table 1 taxonomy).
    """
    e, k = cfg.num_experts, max(cfg.experts_per_tok, 1)
    capacity = int(tokens * k / e * cfg.capacity_factor) + 1
    return EmbeddingOp("gather", num_segments=tokens * k,
                       num_embeddings=e * capacity, emb_len=cfg.d_model)


def undispatch_program(cfg: ModelConfig, tokens: int, name=None):
    """The MoE un-dispatch as a standalone one-op
    :class:`~repro.core.ops.EmbeddingProgram` — the second member of the
    serving :func:`~repro.core.executor.pipeline_group`: wave W's expert
    outputs gather back to token order while wave W+1's decode embed
    marshals against the shared staging pool."""
    from ..core.ops import EmbeddingProgram
    return EmbeddingProgram(name or f"{cfg.name}-moe-undispatch",
                            (("moe_undispatch", dispatch_op(cfg, tokens)),))


def init_moe(key, cfg: ModelConfig, dtype):
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.num_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, e), jnp.float32),
        "wi_gate": dense_init(ks[1], (e, d, f), dtype),
        "wi_up": dense_init(ks[2], (e, d, f), dtype),
        "wo": dense_init(ks[3], (e, f, d), dtype),
    }
    if cfg.num_shared_experts:
        fs = f * cfg.num_shared_experts
        k1, k2, k3 = jax.random.split(ks[4], 3)
        p["shared"] = {
            "wi_gate": dense_init(k1, (d, fs), dtype),
            "wi_up": dense_init(k2, (d, fs), dtype),
            "wo": dense_init(k3, (fs, d), dtype),
        }
    return p


def _slot_assignments(expert_ids, num_experts, capacity):
    """Sort-based capacity slotting (the SLS 'segment traversal' on device).

    expert_ids (N,) -> (slot (N,), keep (N,)) where slot ∈ [0, E*C).
    """
    n = expert_ids.shape[0]
    order = jnp.argsort(expert_ids)              # stable
    sorted_e = expert_ids[order]
    starts = jnp.searchsorted(sorted_e, jnp.arange(num_experts))
    pos_in_expert = jnp.arange(n) - starts[sorted_e]
    keep_sorted = pos_in_expert < capacity
    slot_sorted = sorted_e * capacity + jnp.minimum(pos_in_expert,
                                                    capacity - 1)
    # un-sort back to assignment order
    inv = jnp.argsort(order)
    return slot_sorted[inv], keep_sorted[inv]


def moe_ffn_local(p, x2d, cfg: ModelConfig, ep_axis=None):
    """x2d (T, D) -> (T, D). When ``ep_axis`` is given we are inside a
    shard_map and experts are sharded over it (EP all-to-all dispatch)."""
    t, d = x2d.shape
    e, k = cfg.num_experts, cfg.experts_per_tok
    act = _ACTS[cfg.act]

    logits = (x2d.astype(jnp.float32) @ p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    topw, tope = jax.lax.top_k(probs, k)                   # (T,k)
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)

    # aux load-balance loss (replicated; mean of frac_e * prob_e * E)
    frac = jnp.mean(jax.nn.one_hot(tope, e, dtype=jnp.float32), axis=(0, 1))
    aux = e * jnp.sum(frac * jnp.mean(probs, axis=0))

    flat_e = tope.reshape(-1)                              # (T*k,)
    capacity = int(t * k / e * cfg.capacity_factor) + 1
    slot, keep = _slot_assignments(flat_e, e, capacity)

    src = jnp.repeat(x2d, k, axis=0)                       # (T*k, D)
    buf = jnp.zeros((e * capacity, d), x2d.dtype)
    buf = buf.at[jnp.where(keep, slot, e * capacity)].set(src,
                                                          mode="drop")

    if ep_axis is not None:
        n = jax.lax.axis_size(ep_axis)
        e_loc = e // n
        # tiled all-to-all: (E=n·E_loc, C, D) -> (E_loc, n·C, D)
        buf = buf.reshape(e, capacity, d)
        buf = jax.lax.all_to_all(buf, ep_axis, split_axis=0, concat_axis=1,
                                 tiled=True)
        wg, wu, wo = p["wi_gate"], p["wi_up"], p["wo"]     # local (E_loc,…)
    else:
        e_loc = e
        buf = buf.reshape(e, capacity, d)
        wg, wu, wo = p["wi_gate"], p["wi_up"], p["wo"]

    h = act(jnp.einsum("ecd,edf->ecf", buf, wg)) * \
        jnp.einsum("ecd,edf->ecf", buf, wu)
    out_buf = jnp.einsum("ecf,efd->ecd", h, wo)

    if ep_axis is not None:
        # reverse tiled all-to-all: (E_loc, n·C, D) -> (E, C, D)
        out_buf = jax.lax.all_to_all(out_buf, ep_axis, split_axis=1,
                                     concat_axis=0, tiled=True)
    out_buf = out_buf.reshape(e * capacity, d)

    gathered = jnp.where(keep[:, None], out_buf[slot], 0.0)  # (T*k, D)
    out = jnp.sum(gathered.reshape(t, k, d) *
                  topw[..., None].astype(x2d.dtype), axis=1)

    if "shared" in p:
        sp = p["shared"]
        out = out + (act(x2d @ sp["wi_gate"]) * (x2d @ sp["wi_up"])) @ sp["wo"]
    return out, aux


def _replicated_token_ep(p, x2d, cfg: ModelConfig, ep_axis):
    """Decode-path EP: tokens too few to split over the EP axis — every rank
    routes the (replicated) tokens, processes only its local experts, and the
    outputs combine with one psum.  No all-to-all; collective bytes are
    O(tokens·D), ideal for serve steps."""
    t, d = x2d.shape
    e, k = cfg.num_experts, cfg.experts_per_tok
    act = _ACTS[cfg.act]
    n = jax.lax.axis_size(ep_axis)
    rank = jax.lax.axis_index(ep_axis)
    e_loc = e // n

    logits = x2d.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    topw, tope = jax.lax.top_k(probs, k)
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)
    frac = jnp.mean(jax.nn.one_hot(tope, e, dtype=jnp.float32), axis=(0, 1))
    aux = e * jnp.sum(frac * jnp.mean(probs, axis=0))

    flat_e = tope.reshape(-1)
    capacity = int(t * k / e * cfg.capacity_factor) + 1
    slot, keep = _slot_assignments(flat_e, e, capacity)
    src = jnp.repeat(x2d, k, axis=0)
    buf = jnp.zeros((e * capacity, d), x2d.dtype)
    buf = buf.at[jnp.where(keep, slot, e * capacity)].set(src, mode="drop")

    my = jax.lax.dynamic_slice_in_dim(buf, rank * e_loc * capacity,
                                      e_loc * capacity).reshape(
                                          e_loc, capacity, d)
    h = act(jnp.einsum("ecd,edf->ecf", my, p["wi_gate"])) * \
        jnp.einsum("ecd,edf->ecf", my, p["wi_up"])
    out_loc = jnp.einsum("ecf,efd->ecd", h, p["wo"]).reshape(-1, d)
    out_buf = jnp.zeros((e * capacity, d), x2d.dtype)
    out_buf = jax.lax.dynamic_update_slice_in_dim(
        out_buf, out_loc, rank * e_loc * capacity, axis=0)
    out_buf = jax.lax.psum(out_buf, ep_axis)

    gathered = jnp.where(keep[:, None], out_buf[slot], 0.0)
    out = jnp.sum(gathered.reshape(t, k, d) *
                  topw[..., None].astype(x2d.dtype), axis=1)
    if "shared" in p:
        sp = p["shared"]
        out = out + (act(x2d @ sp["wi_gate"]) * (x2d @ sp["wi_up"])) @ sp["wo"]
    return out, aux


def moe_ffn(p, x, cfg: ModelConfig, mesh=None, ep_axis="model",
            data_axes=("data",)):
    """x (B,S,D) -> (B,S,D). With a mesh: shard_map EP dispatch."""
    b, s, d = x.shape
    if mesh is None or ep_axis is None:
        out, aux = moe_ffn_local(p, x.reshape(-1, d), cfg)
        return out.reshape(b, s, d), aux

    n_ep = mesh.shape[ep_axis]
    seq_split = s % n_ep == 0 and s >= n_ep   # decode (s==1): can't split

    def body(p_, x_):
        t = x_.shape[0] * x_.shape[1]
        if seq_split:
            out, aux = moe_ffn_local(p_, x_.reshape(t, d), cfg,
                                     ep_axis=ep_axis)
        else:
            out, aux = _replicated_token_ep(p_, x_.reshape(t, d), cfg,
                                            ep_axis)
        aux = jax.lax.pmean(aux, ep_axis)
        for ax in data_axes:
            aux = jax.lax.pmean(aux, ax)
        return out.reshape(x_.shape), aux

    dp = tuple(data_axes) if data_axes else None
    p_specs = jax.tree.map(lambda _: P("model", None, None), p)
    p_specs["router"] = P(None, None)
    if "shared" in p:
        p_specs["shared"] = jax.tree.map(lambda _: P(None, None), p["shared"])
    # tokens split over data axes on batch and (train/prefill) over the EP
    # axis on sequence
    x_spec = P(dp, ep_axis, None) if seq_split else P(dp, None, None)
    out, aux = shard_map(
        body, mesh=mesh, in_specs=(p_specs, x_spec),
        out_specs=(x_spec, P()), check_vma=False)(p, x)
    return out, aux
