"""Mamba2 (SSD) block — chunked state-space scan.

The SSD chunked-parallel algorithm: within a chunk the recurrence is
materialized as a (lower-triangular) attention-like contraction; across
chunks a short ``lax.scan`` carries the (H, P, N) state.  Chunking keeps the
sequential scan length at S/chunk (e.g. 2048 steps for the 500k shape) and
the HLO size O(1), while the per-step state is O(1) in sequence length —
this is why the ``long_500k`` cell runs for SSM/hybrid archs and is skipped
for full attention (DESIGN.md §4).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ModelConfig, dense_init


def init_mamba(key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    n = cfg.ssm_state
    d_inner = 2 * d
    h = cfg.num_heads
    p_head = d_inner // h
    ks = jax.random.split(key, 6)
    return {
        # in_proj: x, z(gate), B, C, dt
        "w_in": dense_init(ks[0], (d, d_inner * 2 + 2 * n + h), dtype),
        "conv_w": dense_init(ks[1], (4, d_inner), dtype, scale=0.5),
        "A_log": jnp.zeros((h,), jnp.float32) + jnp.log(jnp.arange(1, h + 1)),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "w_out": dense_init(ks[2], (d_inner, d), dtype),
        "norm_z": jnp.ones((d_inner,), dtype),
    }


def _ssd_chunked(x, dt, A, B, C, chunk, unroll=False):
    """Chunked SSD scan via the shared gated-linear core.

    x (b,s,h,p); dt (b,s,h); A (h,) <0; B,C (b,s,n) (single group).
    """
    from .linear_scan import gated_linear_scan
    b, s, h, p = x.shape
    a = dt * A[None, None, :]
    Bh = jnp.broadcast_to(B[:, :, None, :], (b, s, h, B.shape[-1]))
    Ch = jnp.broadcast_to(C[:, :, None, :], (b, s, h, C.shape[-1]))
    y, _ = gated_linear_scan(x, a, dt, Bh, Ch, chunk, unroll=unroll)
    return y


def mamba_forward(p, x, cfg: ModelConfig, unroll=False):
    b, s, d = x.shape
    h = cfg.num_heads
    d_inner = 2 * d
    ph = d_inner // h
    n = cfg.ssm_state
    proj = x @ p["w_in"]
    xz, rest = proj[..., :2 * d_inner], proj[..., 2 * d_inner:]
    xi, z = xz[..., :d_inner], xz[..., d_inner:]
    Bm, Cm, dt = rest[..., :n], rest[..., n:2 * n], rest[..., 2 * n:]
    # causal depthwise conv (kernel 4)
    xpad = jnp.pad(xi, ((0, 0), (3, 0), (0, 0)))
    xconv = sum(xpad[:, i:i + s] * p["conv_w"][i][None, None, :]
                for i in range(4))
    xconv = jax.nn.silu(xconv)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    xh = xconv.reshape(b, s, h, ph)
    from .common import pick_chunk
    chunk = pick_chunk(s, min(cfg.ssm_chunk, s))
    y = _ssd_chunked(xh, dt, A, Bm.astype(jnp.float32),
                     Cm.astype(jnp.float32), chunk, unroll=unroll)
    y = y + xh * p["D"][None, None, :, None].astype(x.dtype)
    y = y.reshape(b, s, d_inner) * jax.nn.silu(z)
    return y @ p["w_out"]


def mamba_decode(p, x, cfg: ModelConfig, cache):
    """Single-step recurrence. cache: {state (b,h,p,n), conv (b,3,d_inner)}."""
    b, _, d = x.shape
    h = cfg.num_heads
    d_inner = 2 * d
    ph = d_inner // h
    n = cfg.ssm_state
    proj = (x[:, 0] @ p["w_in"])
    xi, z = proj[..., :d_inner], proj[..., d_inner:2 * d_inner]
    rest = proj[..., 2 * d_inner:]
    Bm, Cm, dt = rest[..., :n], rest[..., n:2 * n], rest[..., 2 * n:]
    conv_in = jnp.concatenate([cache["conv"], xi[:, None]], axis=1)  # (b,4,di)
    xconv = jnp.einsum("bkd,kd->bd", conv_in, p["conv_w"])
    xconv = jax.nn.silu(xconv)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])      # (b,h)
    A = -jnp.exp(p["A_log"])
    decay = jnp.exp(dt * A[None, :])                                 # (b,h)
    xh = xconv.reshape(b, h, ph)
    S = cache["state"] * decay[:, :, None, None].astype(x.dtype) + \
        jnp.einsum("bh,bhp,bn->bhpn", dt.astype(x.dtype), xh, Bm)
    y = jnp.einsum("bn,bhpn->bhp", Cm, S) + \
        xh * p["D"][None, :, None].astype(x.dtype)
    y = y.reshape(b, d_inner) * jax.nn.silu(z)
    out = (y @ p["w_out"])[:, None]
    new_cache = {"state": S, "conv": conv_in[:, 1:]}
    return out, new_cache


def init_mamba_cache(cfg: ModelConfig, batch, dtype):
    d_inner = 2 * cfg.d_model
    ph = d_inner // cfg.num_heads
    return {"state": jnp.zeros((batch, cfg.num_heads, ph, cfg.ssm_state),
                               dtype),
            "conv": jnp.zeros((batch, 3, d_inner), dtype)}
