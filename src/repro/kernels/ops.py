"""Public jit'd wrappers for the Pallas kernel layer.

`interpret` defaults to True on CPU hosts (this container) and False when a
real TPU backend is present — the kernels are *targets* for TPU v5e and
*validated* under the Pallas interpreter.
"""
from __future__ import annotations

import jax

from .sls import (sls_pallas, max_lookups_of, lookup_capacity, grid_capacity,
                  exchange_capacity)
from .gather import block_gather_pallas
from .fusedmm import fusedmm_pallas
from .flash_attention import flash_attention
from . import ref


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def sls(table, ptrs, idxs, weights=None, *, num_segments, max_lookups,
        add_op="add", mul_op="mul", col_tile=128, interpret=None,
        seg_base=None):
    return sls_pallas(table, ptrs, idxs, weights,
                      num_segments=num_segments, max_lookups=max_lookups,
                      add_op=add_op, mul_op=mul_op, col_tile=col_tile,
                      seg_base=seg_base,
                      interpret=default_interpret() if interpret is None
                      else interpret)


def block_gather(table, idxs, *, block_rows=1, interpret=None):
    return block_gather_pallas(
        table, idxs, block_rows=block_rows,
        interpret=default_interpret() if interpret is None else interpret)


def fusedmm(x, ptrs, idxs, *, num_segments, max_lookups, fn="identity",
            interpret=None):
    return fusedmm_pallas(
        x, ptrs, idxs, num_segments=num_segments, max_lookups=max_lookups,
        fn=fn,
        interpret=default_interpret() if interpret is None else interpret)


def attention(q, k, v, *, causal=True, block_q=128, block_k=128,
              interpret=None):
    return flash_attention(
        q, k, v, causal=causal, block_q=block_q, block_k=block_k,
        interpret=default_interpret() if interpret is None else interpret)


__all__ = ["sls", "block_gather", "fusedmm", "attention", "ref",
           "max_lookups_of", "lookup_capacity", "grid_capacity",
           "exchange_capacity", "default_interpret"]
