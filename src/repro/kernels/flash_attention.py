"""Blockwise (flash) attention Pallas kernel.

Not an Ember contribution per se, but the LM substrate's perf-critical
compute layer: long-context prefill needs O(S·B) memory attention.  The
kernel is the standard online-softmax tiling adapted to TPU: (Bq, D) query
tiles resident in VMEM, KV streamed block-by-block (the same
access-runs-ahead structure as the DAE kernels), running max/denominator in
VMEM scratch, MXU for both matmuls.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q, k, v, o, m_scr, l_scr, acc_scr, *, scale, causal,
                  bq, bk, seq_k):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * bq
    k_start = ki * bk
    needed = (not causal) or (k_start <= q_start + bq - 1)

    @pl.when(needed)
    def _block():
        s = jax.lax.dot_general(
            q[0], k[0], (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (bq, bk)
        if causal:
            rows = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            cols = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(rows >= cols, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=-1, keepdims=True)
        m_scr[...] = m_new
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p.astype(v.dtype), v[0], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(ki == nk - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o[0] = (acc_scr[...] / denom).astype(o.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_k: int = 128, interpret: bool = False):
    """q, k, v: (BH, S, D) — batch×heads flattened.  Returns (BH, S, D)."""
    bh, seq_q, d = q.shape
    seq_k = k.shape[1]
    bq = min(block_q, seq_q)
    bk = min(block_k, seq_k)
    assert seq_q % bq == 0 and seq_k % bk == 0, "pad sequence to block size"
    scale = d ** -0.5
    grid = (bh, seq_q // bq, seq_k // bk)

    kernel = functools.partial(_flash_kernel, scale=scale, causal=causal,
                               bq=bq, bk=bk, seq_k=seq_k)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, seq_q, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
