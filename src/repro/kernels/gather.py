"""Block-sparse attention gather kernel (SpAttn, paper §2.2.2 / §7.4).

The emb-opt3 form of this operation has *zero* queue traffic: Ember's
store-stream optimization lets the access unit copy blocks straight from the
table to the output.  The TPU analogue is a pure DMA-copy kernel: the scalar
core (index map over scalar-prefetched ``idxs``) drives table-block DMAs
into VMEM, and the body is a straight VMEM→VMEM copy — the VPU never touches
the data, mirroring "bypass the core" (DESIGN.md §2).

The paper's L2-residency hint (reused blocks served from L2, Fig 18) maps to
the revisit behavior of the block pipeline: consecutive grid steps hitting
the same table block skip the re-fetch (Pallas keeps the block in VMEM), so
sorted/clustered indices get the same traffic filtering — the cost model's
``resident_blocks`` discount.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gather_kernel(idxs, table_block, out):
    # store-stream: pure copy, no compute
    out[0] = table_block[...]


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def block_gather_pallas(table, idxs, *, block_rows: int = 1,
                        interpret: bool = False):
    """out[g, r, :] = table[idxs[g] * block_rows + r, :]

    table (N*block_rows, E); idxs (G,) int32 — scalar-prefetched.
    """
    n_rows, emb_len = table.shape
    num_blocks = idxs.shape[0]
    padded = _round_up(emb_len, 128)
    if padded != emb_len:
        table = jnp.pad(table, ((0, 0), (0, padded - emb_len)))

    grid = (num_blocks,)

    def table_map(g, idxs_ref):
        return idxs_ref[g], 0

    def out_map(g, idxs_ref):
        return g, 0, 0

    out = pl.pallas_call(
        _gather_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[pl.BlockSpec((block_rows, padded),
                                   table_map)],
            out_specs=pl.BlockSpec((1, block_rows, padded), out_map),
        ),
        out_shape=jax.ShapeDtypeStruct((num_blocks, block_rows, padded),
                                       table.dtype),
        interpret=interpret,
    )(idxs, table)
    return out[..., :emb_len]


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m
