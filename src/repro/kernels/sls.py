"""DAE-style SLS / EmbeddingBag Pallas TPU kernel.

TPU-native realization of the Ember-compiled DLC program (DESIGN.md §2):

* **access unit** ≙ the scalar core executing ``PrefetchScalarGridSpec``
  index maps: the CSR ``ptrs``/``idxs`` arrays are scalar-prefetched, and the
  per-grid-step index map computes *which table row to DMA next* — running
  ahead of compute exactly like the TMU traversal engine;
* **queues** ≙ Pallas's double-buffered block pipeline: while the VPU
  reduces lookup ``j``, the DMA for lookup ``j+1`` is in flight;
* **execute unit** ≙ the kernel body (vector ⊕/⊗ on 8×128 vregs).

The kernel is *segment-major*: grid = (num_segments, max_lookups); segments
are padded to ``max_lookups`` and the tail is masked with ``@pl.when`` (the
SLCV mask stream of §7.1).  The compiler's KernelPlan chooses the column
tile (``vlen`` → queue alignment pads the row to a multiple of 128 lanes),
whether whole rows are marshaled per DMA (bufferization) and the pipeline
depth.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_INIT = {"add": 0.0, "max": -jnp.inf, "min": jnp.inf}
_COMBINE = {"add": jnp.add, "max": jnp.maximum, "min": jnp.minimum}


def _sls_kernel(ptrs, idxs, seg_base, table_row, weights, out, *, add_op,
                mul_op, weighted):
    """One grid step = one (segment b, column tile c, lookup slot j)."""
    b = pl.program_id(0)
    j = pl.program_id(2)   # innermost: the out block (b, c) is revisited
                           # consecutively across j, enabling VMEM-resident
                           # accumulation (the DAE execute-unit loop)
    beg = ptrs[b]
    end = ptrs[b + 1]
    n = end - beg

    @pl.when(j == 0)
    def _init():
        out[...] = jnp.full_like(out, _INIT[add_op])

    @pl.when(j < n)
    def _accumulate():
        row = table_row[...]
        if weighted:
            w = weights[0, beg + j].astype(row.dtype)
            row = row * w if mul_op == "mul" else row + w
        out[...] = _COMBINE[add_op](out[...], row)

    # SLS convention: empty segments produce 0 even for max/min semirings
    @pl.when((j == pl.num_programs(2) - 1) & (n == 0))
    def _empty():
        out[...] = jnp.zeros_like(out)


@functools.partial(
    jax.jit,
    static_argnames=("num_segments", "max_lookups", "add_op", "mul_op",
                     "col_tile", "interpret"))
def sls_pallas(table, ptrs, idxs, weights=None, *, num_segments: int,
               max_lookups: int, add_op: str = "add", mul_op: str = "mul",
               col_tile: int = 128, interpret: bool = False, seg_base=None):
    """Compiler entry point (see `repro.core.backend_pallas.KernelPlan`).

    table     (N, E)   embedding table (HBM resident)
    ptrs      (B+1,)   CSR segment offsets  — scalar-prefetched
    idxs      (nnz,)   row indices          — scalar-prefetched
    weights   (nnz,)   optional per-lookup scale (GNN edge values)
    seg_base  (B,)     optional per-segment table-row base — the fused
                       multi-table program's table-offset stream, applied in
                       the scalar-prefetched index map (access-unit ALU)
    """
    n_rows, emb_len = table.shape
    # queue alignment (§7.3): pad the row to a lane-aligned tile so every
    # marshaled vector is VMEM-tile aligned
    col_tile = min(col_tile, _round_up(emb_len, 128))
    padded = _round_up(emb_len, col_tile)
    if padded != emb_len:
        table = jnp.pad(table, ((0, 0), (0, padded - emb_len)))
    col_blocks = padded // col_tile

    weighted = weights is not None
    if not weighted:
        weights = jnp.zeros((1,), table.dtype)
    weights2d = weights[None, :]  # SMEM scalars must be ≥1-d arrays
    if idxs.shape[0] == 0:        # degenerate all-empty batch
        idxs = jnp.zeros((1,), jnp.int32)
    if seg_base is None:          # single-table: zero base, broadcast-safe
        seg_base = jnp.zeros((1,), jnp.int32)

    grid = (num_segments, col_blocks, max_lookups)

    def table_map(b, c, j, ptrs_ref, idxs_ref, base_ref):
        beg = ptrs_ref[b]
        n = ptrs_ref[b + 1] - beg
        # masked tail: clamp to a safe row; @pl.when skips the accumulate
        p = beg + jnp.minimum(j, jnp.maximum(n - 1, 0))
        row = idxs_ref[jnp.minimum(p, idxs_ref.shape[0] - 1)]
        # fused multi-table rebase onto the stacked table (§ program fusion)
        row = row + base_ref[jnp.minimum(b, base_ref.shape[0] - 1)]
        return row, c

    def out_map(b, c, j, ptrs_ref, idxs_ref, base_ref):
        return b, c

    kernel = functools.partial(_sls_kernel, add_op=add_op, mul_op=mul_op,
                               weighted=weighted)

    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, col_tile), table_map),   # one row tile/DMA
                pl.BlockSpec(memory_space=pltpu.SMEM),    # weights (scalar)
            ],
            out_specs=pl.BlockSpec((1, col_tile), out_map),
        ),
        out_shape=jax.ShapeDtypeStruct((num_segments, padded), table.dtype),
        interpret=interpret,
    )(ptrs, idxs, jnp.asarray(seg_base, jnp.int32), table, weights2d)
    return out[:, :emb_len]


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def max_lookups_of(ptrs: np.ndarray) -> int:
    return int(np.diff(ptrs).max(initial=0)) or 1


# The shape-bucketing policy (pow-2 nnz, quarter-octave max_lookups, joint
# exchange buckets) lives in ONE canonical module — repro.core.capacity —
# carried by every compiled AccessPlan; re-exported here so kernel callers
# keep their historical import path.
from repro.core.capacity import (lookup_capacity, grid_capacity,  # noqa: E402
                                 exchange_capacity)
