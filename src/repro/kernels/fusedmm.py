"""FusedMM (SDDMM+SpMM) Pallas kernel — message-passing models (§2.2.3).

The bufferized DLC program for MP keeps *two* buffer streams (x[i,:] and
x[j,:]), computes the SDDMM dot on the execute unit, and reuses the buffered
x[j,:] for the SpMM accumulate — the workspace loop's second memory pass
disappears.  Here both rows arrive as VMEM blocks (the two "buffers"); the
body does the dot (VPU reduce) and scaled accumulate without re-touching
HBM, which is exactly the paper's hand-optimized MP structure.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _fusedmm_kernel(ptrs, idxs, xi, xj, out, *, fn):
    j = pl.program_id(1)
    b = pl.program_id(0)
    n = ptrs[b + 1] - ptrs[b]

    @pl.when(j == 0)
    def _init():
        out[...] = jnp.zeros_like(out)

    @pl.when(j < n)
    def _edge():
        a = xi[...]
        c = xj[...]
        s = jnp.sum(a * c)              # SDDMM (buffered dot)
        if fn == "relu":
            s = jnp.maximum(s, 0.0)
        out[...] += s * c               # SpMM from the same buffer


@functools.partial(jax.jit, static_argnames=("num_segments", "max_lookups",
                                             "fn", "interpret"))
def fusedmm_pallas(x, ptrs, idxs, *, num_segments: int, max_lookups: int,
                   fn: str = "identity", interpret: bool = False):
    n_rows, emb_len = x.shape
    padded = _round_up(emb_len, 128)
    if padded != emb_len:
        x = jnp.pad(x, ((0, 0), (0, padded - emb_len)))
    if idxs.shape[0] == 0:
        idxs = jnp.zeros((1,), jnp.int32)

    grid = (num_segments, max_lookups)

    def xi_map(b, j, ptrs_ref, idxs_ref):
        return b, 0

    def xj_map(b, j, ptrs_ref, idxs_ref):
        beg = ptrs_ref[b]
        n = ptrs_ref[b + 1] - beg
        p = beg + jnp.minimum(j, jnp.maximum(n - 1, 0))
        return idxs_ref[jnp.minimum(p, idxs_ref.shape[0] - 1)], 0

    def out_map(b, j, ptrs_ref, idxs_ref):
        return b, 0

    out = pl.pallas_call(
        functools.partial(_fusedmm_kernel, fn=fn),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[pl.BlockSpec((1, padded), xi_map),
                      pl.BlockSpec((1, padded), xj_map)],
            out_specs=pl.BlockSpec((1, padded), out_map),
        ),
        out_shape=jax.ShapeDtypeStruct((num_segments, padded), x.dtype),
        interpret=interpret,
    )(ptrs, idxs, x, x)
    return out[:, :emb_len]


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m
