"""Pure-jnp oracles for every Pallas kernel (and the `traditional core`
baseline the paper compares DAE against).

All functions are jit-compatible and shape-static.  CSR inputs are given in
*segment-id* form (``seg_ids`` sorted ascending, one per lookup) because XLA
needs static shapes; :func:`csr_to_lookups` converts from the paper's
``ptrs`` form.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

_SEG_REDUCERS = {
    "add": jax.ops.segment_sum,
    "max": jax.ops.segment_max,
    "min": jax.ops.segment_min,
}


def csr_to_lookups(ptrs: np.ndarray) -> np.ndarray:
    """ptrs (B+1,) -> seg_ids (nnz,) — host-side preprocessing."""
    lens = np.diff(ptrs)
    return np.repeat(np.arange(len(lens)), lens).astype(np.int32)


@functools.partial(jax.jit, static_argnames=("num_segments", "add_op", "mul_op"))
def sls(table, idxs, seg_ids, weights=None, *, num_segments: int,
        add_op: str = "add", mul_op: str = "mul"):
    """Sparse-lengths-sum / EmbeddingBag: out[b] = ⊕_{p: seg[p]=b} w_p ⊗ T[i_p].

    Covers the paper's SLS (dlrm), SpMM (gnn, weighted), and KG (semiring,
    single-lookup segments) operations.
    """
    rows = jnp.take(table, idxs, axis=0)
    if weights is not None:
        w = weights[:, None].astype(rows.dtype)
        rows = rows * w if mul_op == "mul" else rows + w
    out = _SEG_REDUCERS[add_op](rows, seg_ids, num_segments=num_segments)
    if add_op != "add":
        # empty segments: identity -> 0.0 (SLS convention)
        counts = jax.ops.segment_sum(jnp.ones_like(seg_ids), seg_ids,
                                     num_segments=num_segments)
        out = jnp.where(counts[:, None] > 0, out, 0.0)
    return out.astype(table.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows",))
def block_gather(table, idxs, *, block_rows: int = 1):
    """BigBird-style block-sparse gather: out[g, r] = T[idxs[g]*R + r]."""
    rows = idxs[:, None] * block_rows + jnp.arange(block_rows)[None, :]
    return jnp.take(table, rows.reshape(-1), axis=0).reshape(
        idxs.shape[0], block_rows, table.shape[-1])


@functools.partial(jax.jit, static_argnames=("num_segments", "fn"))
def fusedmm(x, idxs, seg_ids, *, num_segments: int, fn: str = "identity"):
    """FusedMM (message passing): SDDMM + SpMM in one pass.

    out[i] = Σ_{p: seg[p]=i} f(<x[i], x[j_p]>) · x[j_p]
    """
    xi = jnp.take(x, seg_ids, axis=0)
    xj = jnp.take(x, idxs, axis=0)
    s = jnp.sum(xi * xj, axis=-1)
    if fn == "relu":
        s = jnp.maximum(s, 0.0)
    contrib = s[:, None] * xj
    return jax.ops.segment_sum(contrib, seg_ids,
                               num_segments=num_segments).astype(x.dtype)


def attention_reference(q, k, v, *, causal: bool = True, scale=None):
    """O(S²)-memory attention oracle for the flash kernel (small shapes)."""
    s = scale if scale is not None else q.shape[-1] ** -0.5
    logits = jnp.einsum("...qhd,...khd->...hqk", q, k).astype(jnp.float32) * s
    if causal:
        qlen, klen = logits.shape[-2], logits.shape[-1]
        mask = jnp.tril(jnp.ones((qlen, klen), bool), k=klen - qlen)
        logits = jnp.where(mask, logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("...hqk,...khd->...qhd", p, v).astype(q.dtype)
