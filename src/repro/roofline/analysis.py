"""Roofline term derivation from compiled dry-run artifacts (deliverable g).

    compute term    = HLO_FLOPs   / (chips × peak_FLOP/s)
    memory term     = HLO_bytes   / (chips × HBM_bw)
    collective term = coll_bytes  / (chips × link_bw)

``cost_analysis`` provides FLOPs and bytes; collective bytes are parsed from
the HLO text by summing operand sizes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute.
"""
from __future__ import annotations

import dataclasses
import re

# TPU v5e-class hardware constants (per chip), per the assignment.
@dataclasses.dataclass(frozen=True)
class HW:
    peak_flops: float = 197e12        # bf16 FLOP/s
    hbm_bw: float = 819e9             # B/s
    ici_bw: float = 50e9              # B/s per link


_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes_from_hlo(hlo_text: str) -> float:
    """Sum of result sizes of every collective op in the (stable)HLO text.

    Works on both pre-SPMD lowered stablehlo (jax lowered.as_text()) and
    post-partitioning HLO (compiled.as_text()).  Counts each op's *result*
    shape — for all-reduce that equals the payload; for all-gather the
    gathered result; a consistent, comparable proxy for link traffic.
    """
    total = 0
    pending = False
    for line in hlo_text.splitlines():
        s = line.strip()
        # HLO form: `%x = bf16[256,1024] all-reduce(...)`
        m = re.match(r"%?[\w.\-]+\s*=\s*(\([^)]*\)|[\w\[\],]+)\s+"
                     r"([\w\-]+)", s)
        if m and any(m.group(2).startswith(c) for c in _COLLECTIVES):
            total += _shape_bytes(m.group(1))
            continue
        # stablehlo form: `stablehlo.all_reduce` — region ops may carry the
        # result type on a later `}) : (...) -> tensor<...>` line
        m2 = re.search(r"stablehlo\.(all_gather|all_reduce|reduce_scatter|"
                       r"all_to_all|collective_permute)", s)
        if m2:
            tm = re.findall(r"->\s*tensor<([^>]+)>", s) or \
                re.findall(r"tensor<([^>]+)>", s)
            if tm:
                total += _tensor_bytes(tm[-1])
            else:
                pending = True
            continue
        if pending and "-> tensor<" in s:
            tm = re.findall(r"->\s*tensor<([^>]+)>", s)
            if tm:
                total += _tensor_bytes(tm[-1])
            pending = False
    return float(total)


def _tensor_bytes(t: str) -> int:
    parts = t.split("x")
    dt = parts[-1].strip()
    bytes_per = {"f32": 4, "bf16": 2, "f16": 2, "i32": 4, "ui32": 4,
                 "i8": 1, "i64": 8, "f64": 8, "i1": 1}.get(dt, 4)
    n = 1
    for p in parts[:-1]:
        try:
            n *= int(p)
        except ValueError:
            return 0
    return n * bytes_per


def roofline_terms(*, flops: float, bytes_accessed: float,
                   collective_bytes: float, n_chips: int,
                   hw: HW = HW()) -> dict:
    compute_s = flops / (n_chips * hw.peak_flops)
    memory_s = bytes_accessed / (n_chips * hw.hbm_bw)
    coll_s = collective_bytes / (n_chips * hw.ici_bw)
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": coll_s}
    dom = max(terms, key=terms.get)
    bound = max(compute_s, memory_s, coll_s)
    return {**terms, "bottleneck": dom.replace("_s", ""),
            "step_lower_bound_s": bound,
            "roofline_fraction_compute": compute_s / bound if bound else 0.0}


def analytic_flops(cfg, seq: int, batch: int, kind: str) -> float:
    """MODEL_FLOPS + attention/linear-scan terms — the fallback compute
    estimate for cells whose unrolled cost compile did not finish."""
    base = model_flops(cfg, seq, batch, kind)
    if kind == "decode":
        return base
    mult = 3.0 if kind == "train" else 1.0   # fwd+bwd vs fwd
    b, s = batch, seq
    attn = 0.0
    for k in (cfg.block_pattern * cfg.n_super) + cfg.remainder_pattern:
        if k in ("dense", "moe", "mla", "shared_attn", "enc_dense", "xdec"):
            attn += 4.0 * b * s * s * cfg.num_heads * cfg.hd
            if k == "xdec":
                attn += 4.0 * b * s * s * cfg.num_heads * cfg.hd
        elif k == "dense_local":
            w = min(cfg.sliding_window, s)
            attn += 4.0 * b * s * w * cfg.num_heads * cfg.hd
        elif k in ("mamba", "mlstm"):
            L = cfg.ssm_chunk
            p_h = (2 * cfg.d_model // cfg.num_heads if k == "mamba"
                   else cfg.d_model // cfg.num_heads)
            attn += b * s * cfg.num_heads * (2 * L * p_h +
                                             4 * p_h * cfg.ssm_state)
    if cfg.enc_layers:
        attn += cfg.enc_layers * 4.0 * b * s * s * cfg.num_heads * cfg.hd
    return base + mult * attn


def model_flops(cfg, seq: int, batch: int, kind: str) -> float:
    """MODEL_FLOPS = 6·N_active·D tokens (train) or 2·N_active·D (fwd)."""
    n_active = active_params(cfg)
    tokens = seq * batch if kind != "decode" else batch
    mult = 6.0 if kind == "train" else 2.0
    return mult * n_active * tokens


def active_params(cfg) -> float:
    """Active (per-token) parameter count of a ModelConfig."""
    d, v = cfg.d_model, cfg.vocab_size
    total = v * d  # embedding (tied unembedding counted once for lookups)
    per_layer = {}
    hd = cfg.hd
    for kind in (cfg.block_pattern * cfg.n_super) + cfg.remainder_pattern:
        attn = d * cfg.num_heads * hd + 2 * d * cfg.num_kv_heads * hd \
            + cfg.num_heads * hd * d
        mlp = 3 * d * cfg.d_ff
        if kind in ("dense", "dense_local", "enc_dense"):
            n = attn + mlp
        elif kind == "moe":
            n = attn + 3 * d * cfg.moe_d_ff * cfg.experts_per_tok \
                + 3 * d * cfg.moe_d_ff * cfg.num_shared_experts
        elif kind == "mla":
            r, rd = cfg.kv_lora_rank, cfg.rope_head_dim
            n = (d * cfg.num_heads * (hd + rd) + d * r +
                 r * 2 * cfg.num_heads * hd + d * rd +
                 cfg.num_heads * hd * d)
            n += 3 * d * cfg.moe_d_ff * (cfg.experts_per_tok +
                                         cfg.num_shared_experts)
        elif kind == "mamba":
            di = 2 * d
            n = d * (2 * di + 2 * cfg.ssm_state + cfg.num_heads) + di * d
        elif kind == "mlstm":
            n = 5 * d * d
        elif kind == "slstm":
            n = 4 * d * d + d * d + cfg.num_heads * (d // cfg.num_heads) ** 2 * 4
        elif kind == "shared_attn":
            n = attn + mlp  # shared weights but active per occurrence
        elif kind == "xdec":
            n = 2 * attn + mlp
        else:
            n = 0
        per_layer[kind] = n
        total += n
    if cfg.enc_layers:
        attn = 4 * d * cfg.num_heads * hd
        total += cfg.enc_layers * (attn + 3 * d * cfg.d_ff)
    return float(total)


def analytic_bytes_per_device(cfg, seq: int, batch: int, kind: str,
                              n_data: int = 16, n_model: int = 16) -> float:
    """Production-path HBM traffic estimate per device per step.

    The cost-mode HLO memory number materializes dense-attention S² logits
    that the production blockwise path keeps on-chip; this analytic estimate
    is the companion column for attention-heavy cells (methodology note in
    EXPERIMENTS.md)."""
    P_loc = active_params(cfg) / n_model
    tok_loc = seq * batch / n_data if kind != "decode" else batch / n_data
    d = cfg.d_model
    if kind == "train":
        param_io = P_loc * 2 * 4            # read fwd+bwd, grad w, update rw
        opt_io = P_loc * 4 * 4              # two fp32 moments, read+write
        act_io = 14 * tok_loc * d * 2 * (cfg.num_layers + cfg.enc_layers)
        return param_io + opt_io + act_io
    if kind == "prefill":
        return P_loc * 2 + 8 * tok_loc * d * 2 * cfg.num_layers
    # decode: params once + KV/state cache traffic
    cache = 0.0
    for k in (cfg.block_pattern * cfg.n_super) + cfg.remainder_pattern:
        if k in ("dense", "moe", "shared_attn", "xdec", "enc_dense"):
            cache += 2 * seq * cfg.num_kv_heads * cfg.hd * 2
        elif k == "dense_local":
            cache += 2 * min(seq, cfg.sliding_window) *                 cfg.num_kv_heads * cfg.hd * 2
        elif k == "mla":
            cache += seq * (cfg.kv_lora_rank + cfg.rope_head_dim) * 2
        elif k == "mamba":
            cache += cfg.num_heads * (2 * d // cfg.num_heads) *                 cfg.ssm_state * 2 * 2
        elif k == "mlstm":
            cache += cfg.num_heads * (d // cfg.num_heads) ** 2 * 2 * 2
        elif k == "slstm":
            cache += 4 * d * 4
    cache_loc = cache * batch / max(n_data, 1) / n_model * n_model  # heads/model
    cache_loc = cache * batch / (n_data * n_model)
    return P_loc * 2 + cache_loc
