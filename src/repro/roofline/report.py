"""Assemble the §Roofline table from experiments/dryrun/*.json.

    PYTHONPATH=src python -m repro.roofline.report [--markdown]
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

from ..configs import get_config
from ..launch.steps import SHAPES
from .analysis import (HW, analytic_bytes_per_device, analytic_flops,
                       model_flops, roofline_terms)

DRYRUN = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

ADVICE = {
    "compute": "raise MXU utilization: larger fused matmul tiles / drop "
               "the causal-masking FLOP waste in attention",
    "memory": "cut HBM traffic: fuse producer→consumer chains, keep "
              "attention blocks VMEM-resident (flash kernel), bf16 "
              "activations end-to-end",
    "collective": "overlap or shrink collectives: reduce-scatter instead "
                  "of all-reduce, seq-parallel embed, int8 grad compression",
}


def load_cells(mesh="single"):
    cells = []
    for f in sorted(DRYRUN.glob(f"*__{mesh}.json")):
        cells.append(json.loads(f.read_text()))
    return cells


def build_rows(mesh="single"):
    rows = []
    for rec in load_cells(mesh):
        arch, shape = rec["arch"], rec["shape"]
        row = {"arch": arch, "shape": shape, "status": rec["status"]}
        if rec["status"] == "skipped":
            row["note"] = rec["reason"][:60]
            rows.append(row)
            continue
        if rec["status"] != "ok":
            row["note"] = rec.get("error", "")[:60]
            rows.append(row)
            continue
        seq, batch, kind = SHAPES[shape]
        cfg = get_config(arch)
        mf = model_flops(cfg, seq, batch, kind)
        if "roofline" not in rec:
            # analytic fallback: the unrolled cost compile has not landed
            # for this cell — estimate terms from analytic FLOPs + the
            # scanned compile's (loop-body-once) traffic, clearly marked
            n = 256
            af = analytic_flops(cfg, seq, batch, kind)
            fscan = rec.get("flops_scanned", 0.0) * n
            scale = af / fscan if fscan else 1.0
            rec = dict(rec)
            rec["flops"] = af
            rec["cost_compiled"] = False
            rec["roofline"] = roofline_terms(
                flops=af / n,
                bytes_accessed=rec.get("bytes_scanned", 0.0) * max(scale, 1),
                collective_bytes=rec.get("collective_bytes", 0.0),
                n_chips=1)
        r = rec["roofline"]
        hlo_total = rec.get("flops", 0.0)
        ab = analytic_bytes_per_device(cfg, seq, batch, kind)
        mem_an = ab / HW().hbm_bw
        # verdict uses the analytic production-path memory: the HLO memory
        # number is an upper bound inflated by cost-mode dense attention
        # (and trip-scaling for fallback cells) — both are reported
        terms = {"compute": r["compute_s"], "memory": mem_an,
                 "collective": r["collective_s"]}
        dom = max(terms, key=terms.get)
        bound = max(terms.values())
        r = dict(r, bottleneck=dom, step_lower_bound_s=bound,
                 roofline_fraction_compute=(r["compute_s"] / bound
                                            if bound else 0.0))
        row.update({
            "memory_s_analytic": mem_an,
            "compute_s": r["compute_s"], "memory_s": r["memory_s"],
            "collective_s": r["collective_s"],
            "bottleneck": r["bottleneck"],
            "bound_s": r["step_lower_bound_s"],
            "roofline_frac": r["roofline_fraction_compute"],
            "model_flops": mf,
            "hlo_flops": hlo_total,
            "useful_ratio": mf / hlo_total if hlo_total else float("nan"),
            "cost_compiled": rec.get("cost_compiled", False),
            "advice": ADVICE[r["bottleneck"]],
        })
        rows.append(row)
    return rows


def markdown(rows) -> str:
    out = ["| arch | shape | compute_s | memory_s (HLO) | memory_s (analytic) "
           "| collective_s | bottleneck | roofline-frac | MODEL/HLO | note |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if "bottleneck" not in r:
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | "
                       f"{r['status']} | — | — | {r.get('note','')} |")
            continue
        flag = "" if r["cost_compiled"] else " (est)"
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.2e} | "
            f"{r['memory_s']:.2e} | {r.get('memory_s_analytic', 0):.2e} | "
            f"{r['collective_s']:.2e} | "
            f"**{r['bottleneck']}** | {r['roofline_frac']:.2f} | "
            f"{r['useful_ratio']:.2f}{flag} | {r['advice'][:44]}… |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single")
    args = ap.parse_args()
    rows = build_rows(args.mesh)
    print(markdown(rows))
    ok = [r for r in rows if "bottleneck" in r]
    if ok:
        from collections import Counter
        c = Counter(r["bottleneck"] for r in ok)
        print(f"\nbottleneck distribution: {dict(c)}")
        worst = sorted(ok, key=lambda r: r["roofline_frac"])[:3]
        print("lowest roofline fractions:",
              [(r["arch"], r["shape"], round(r["roofline_frac"], 3))
               for r in worst])
        coll = sorted(ok, key=lambda r: -(r["collective_s"] /
                                          max(r["bound_s"], 1e-12)))[:3]
        print("most collective-bound:",
              [(r["arch"], r["shape"]) for r in coll])


if __name__ == "__main__":
    main()
