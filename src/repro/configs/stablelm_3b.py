"""stablelm-3b [dense] [hf:stabilityai/stablelm-2-1_6b; unverified].

32L d_model=2560 32H (GQA kv=32 = MHA) d_ff=6912 vocab=50304.
Partial rotary (stablelm uses rotary_pct=0.25)."""
from repro.models import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="stablelm-3b", family="dense",
        num_layers=32, d_model=2560, num_heads=32, num_kv_heads=32,
        d_ff=6912, vocab_size=50304,
        block_pattern=("dense",), rotary_pct=0.25,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="stablelm-reduced", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=256, block_pattern=("dense",),
        rotary_pct=0.25, attn_chunk=8, dtype="float32",
    )
