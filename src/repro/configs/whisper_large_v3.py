"""whisper-large-v3 [audio] — enc-dec, conv frontend STUB
[arXiv:2212.04356; unverified].

32L (decoder) + 32L encoder, d_model=1280 20H (MHA) d_ff=5120 vocab=51866.
`input_specs` provides precomputed frame embeddings (conv frontend stubbed);
shapes apply to the decoder backbone, the encoder sees the same frame count.
Full attention both sides → `long_500k` skipped."""
from repro.models import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-large-v3", family="audio",
        num_layers=32, d_model=1280, num_heads=20, num_kv_heads=20,
        d_ff=5120, vocab_size=51866,
        block_pattern=("xdec",), enc_layers=32, enc_seq=1500,
        modality="audio-stub", act="gelu",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="whisper-reduced", family="audio",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=256, block_pattern=("xdec",),
        enc_layers=2, enc_seq=16, modality="audio-stub", act="gelu",
        attn_chunk=8, dtype="float32",
    )
