"""deepseek-v2-lite-16b [moe] — MLA kv_lora=512, shared+routed experts top-6
[arXiv:2405.04434; hf].

27L d_model=2048 16H (kv via MLA latent) expert d_ff=1408 vocab=102400,
64 routed experts top-6 + 2 shared.  The MLA latent cache is the
decode-memory win (§DESIGN arch table)."""
from repro.models import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-lite-16b", family="moe",
        num_layers=27, d_model=2048, num_heads=16, num_kv_heads=16,
        d_ff=0, vocab_size=102400, head_dim=128,
        block_pattern=("mla",),
        num_experts=64, experts_per_tok=6, num_shared_experts=2,
        moe_d_ff=1408, kv_lora_rank=512, rope_head_dim=64,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="deepseek-reduced", family="moe",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=0, vocab_size=256, block_pattern=("mla",),
        num_experts=8, experts_per_tok=2, num_shared_experts=1,
        moe_d_ff=32, kv_lora_rank=16, rope_head_dim=8,
        attn_chunk=8, dtype="float32",
    )
