"""qwen3-moe-235b-a22b [moe] — 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B; hf].

94L d_model=4096 64H (GQA kv=4) expert d_ff=1536 vocab=151936.
Experts shard over the model axis (EP): the dispatch all-to-all is the
SLS-class embedding op at scale — a prime hillclimb candidate."""
from repro.models import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-235b-a22b", family="moe",
        num_layers=94, d_model=4096, num_heads=64, num_kv_heads=4,
        d_ff=0, vocab_size=151936, head_dim=128,
        block_pattern=("moe",),
        num_experts=128, experts_per_tok=8, moe_d_ff=1536,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-reduced", family="moe",
        num_layers=2, d_model=64, num_heads=8, num_kv_heads=2,
        d_ff=0, vocab_size=256, block_pattern=("moe",),
        num_experts=8, experts_per_tok=2, moe_d_ff=32,
        attn_chunk=8, dtype="float32",
    )
