"""chatglm3-6b [dense] — 2d RoPE (partial rotary), extreme GQA kv=2
[arXiv:2406.12793; hf].

28L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=65024."""
from repro.models import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="chatglm3-6b", family="dense",
        num_layers=28, d_model=4096, num_heads=32, num_kv_heads=2,
        d_ff=13696, vocab_size=65024,
        block_pattern=("dense",), rotary_pct=0.5,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="chatglm3-reduced", family="dense",
        num_layers=2, d_model=64, num_heads=8, num_kv_heads=2,
        d_ff=128, vocab_size=256, block_pattern=("dense",),
        rotary_pct=0.5, attn_chunk=8, dtype="float32",
    )
