"""zamba2-7b [hybrid] — Mamba2 backbone + SHARED attention block
[arXiv:2411.15242; unverified].

81L d_model=3584 32H d_ff=14336 vocab=32000 ssm_state=64.
Pattern: 5 mamba : 1 shared-attn (one attention weight set reused at every
occurrence — held outside the scanned params).  SSM state is O(1) →
`long_500k` runs; at 500k the shared attention gets a sliding window
(DESIGN.md §4, documented adaptation)."""
from repro.models import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-7b", family="hybrid",
        num_layers=81, d_model=3584, num_heads=32, num_kv_heads=32,
        d_ff=14336, vocab_size=32000, ssm_state=64,
        block_pattern=("mamba", "mamba", "mamba", "mamba", "mamba",
                       "shared_attn"),
        ssm_chunk=256, sliding_window=4096, long_context_ok=True,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="zamba2-reduced", family="hybrid",
        num_layers=8, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=256, ssm_state=8,
        block_pattern=("mamba", "mamba", "shared_attn"),
        ssm_chunk=8, sliding_window=8, attn_chunk=8, dtype="float32",
        long_context_ok=True,
    )
