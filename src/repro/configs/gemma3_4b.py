"""gemma3-4b [dense] — 5:1 local:global attention, 128k context, 262k vocab
[hf:google/gemma-3-1b-pt; unverified].

34L d_model=2560 8H (GQA kv=4) d_ff=10240 vocab=262144.
The 262k vocab-sharded table is the flagship Ember embedding case.
`long_500k` skipped: the global layers are full attention."""
from repro.models import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-4b", family="dense",
        num_layers=34, d_model=2560, num_heads=8, num_kv_heads=4,
        d_ff=10240, vocab_size=262144, head_dim=256,
        block_pattern=("dense_local",) * 5 + ("dense",),
        sliding_window=1024, rope_theta=1_000_000.0,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="gemma3-reduced", family="dense",
        num_layers=8, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=512,
        block_pattern=("dense_local",) * 5 + ("dense",),
        sliding_window=8, attn_chunk=8, dtype="float32",
    )
