"""h2o-danube-1.8b [dense] — llama+mistral mix, sliding-window attention
[arXiv:2401.16818; hf].

24L d_model=2560 32H (GQA kv=8) d_ff=6912 vocab=32000."""
from repro.models import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="h2o-danube-1.8b", family="dense",
        num_layers=24, d_model=2560, num_heads=32, num_kv_heads=8,
        d_ff=6912, vocab_size=32000,
        block_pattern=("dense_local",), sliding_window=4096,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="danube-reduced", family="dense",
        num_layers=2, d_model=64, num_heads=8, num_kv_heads=2,
        d_ff=128, vocab_size=256,
        block_pattern=("dense_local",), sliding_window=8, attn_chunk=8,
        dtype="float32",
    )
