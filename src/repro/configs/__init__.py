"""Architecture registry: one module per assigned architecture.

``get_config(name)`` returns the full (paper-exact) ModelConfig;
``get_reduced(name)`` a same-family small config for CPU smoke tests.
"""
from __future__ import annotations

import importlib

ARCHS = [
    "xlstm-1.3b", "stablelm-3b", "gemma3-4b", "h2o-danube-1.8b",
    "chatglm3-6b", "llava-next-34b", "qwen3-moe-235b-a22b",
    "deepseek-v2-lite-16b", "whisper-large-v3", "zamba2-7b",
]

def _mod(name: str):
    key = name.replace("-", "_").replace(".", "_")
    return importlib.import_module(f"repro.configs.{key}")


def get_config(name: str):
    return _mod(name).config()


def get_reduced(name: str):
    return _mod(name).reduced()


def list_archs():
    return list(ARCHS)
