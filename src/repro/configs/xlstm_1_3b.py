"""xlstm-1.3b [ssm] — sLSTM + mLSTM blocks [arXiv:2405.04517; unverified].

48L d_model=2048 4H d_ff=0 (blocks are self-contained) vocab=50304.
Pattern: 3 mLSTM : 1 sLSTM (the paper's mostly-mLSTM mix).  O(1) recurrent
state → `long_500k` runs for this arch.
"""
from repro.models import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-1.3b", family="ssm",
        num_layers=48, d_model=2048, num_heads=4, num_kv_heads=4,
        d_ff=0, vocab_size=50304,
        block_pattern=("mlstm", "mlstm", "mlstm", "slstm"),
        ssm_chunk=256, long_context_ok=True,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="xlstm-reduced", family="ssm",
        num_layers=4, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=0, vocab_size=256,
        block_pattern=("mlstm", "mlstm", "mlstm", "slstm"),
        ssm_chunk=8, dtype="float32", long_context_ok=True,
    )
