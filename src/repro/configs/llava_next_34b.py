"""llava-next-34b [vlm] — anyres patch tiling (stubbed vision frontend)
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified].

60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000.
`input_specs` provides precomputed patch embeddings (the anyres tile gather
is the block-gather embedding op in benchmarks)."""
from repro.models import ModelConfig

VISION_TOKENS = 576  # one 24×24 anyres base tile


def config() -> ModelConfig:
    return ModelConfig(
        name="llava-next-34b", family="vlm",
        num_layers=60, d_model=7168, num_heads=56, num_kv_heads=8,
        d_ff=20480, vocab_size=64000,
        block_pattern=("dense",), modality="vision-stub",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="llava-reduced", family="vlm",
        num_layers=2, d_model=64, num_heads=8, num_kv_heads=2,
        d_ff=128, vocab_size=256, block_pattern=("dense",),
        modality="vision-stub", attn_chunk=8, dtype="float32",
    )
