from .ckpt import (CheckpointManager, atomic_write_text, committed_steps,
                   latest_step, publish_dir, restore_checkpoint,
                   save_checkpoint)

__all__ = ["CheckpointManager", "save_checkpoint", "restore_checkpoint",
           "latest_step", "committed_steps", "atomic_write_text",
           "publish_dir"]
