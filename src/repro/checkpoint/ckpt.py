"""Sharded, atomic, async-capable checkpointing with elastic re-shard.

Layout (tensorstore-free; works on any shared filesystem):

    <dir>/step_000123/
        manifest.json            # step, tree structure, leaf shapes/dtypes
        shard_00000.npz          # this host's addressable shards
    <dir>/step_000123.COMMITTED  # atomic commit marker (rename-based)

Every host writes the *addressable* shards of every leaf with their global
offsets recorded in the manifest; restore rebuilds global arrays with
``jax.make_array_from_callback`` against the *current* mesh/sharding — a
checkpoint written on a 512-chip mesh restores onto 256 chips (elastic
rescale) because assembly is offset-based, not device-based.

``CheckpointManager`` adds keep-N retention and a background-thread async
save (compute/IO overlap: the arrays are snapshotted to host memory
synchronously — cheap — and written in the background).
"""
from __future__ import annotations

import json
import shutil
import threading
import time
from pathlib import Path

import jax
import numpy as np
from jax.tree_util import tree_flatten_with_path, tree_unflatten


def _path_key(path) -> str:
    return jax.tree_util.keystr(path)


def save_checkpoint(ckpt_dir, step: int, tree, *, host_index: int = 0):
    ckpt_dir = Path(ckpt_dir)
    step_dir = ckpt_dir / f"step_{step:09d}"
    tmp_dir = ckpt_dir / f".tmp_step_{step:09d}_{host_index}"
    tmp_dir.mkdir(parents=True, exist_ok=True)

    leaves, treedef = tree_flatten_with_path(tree)
    manifest = {"step": step, "leaves": []}
    arrays = {}
    for i, (path, leaf) in enumerate(leaves):
        key = f"leaf_{i:05d}"
        entry = {"key": key, "path": _path_key(path),
                 "shape": list(np.shape(leaf)),
                 "dtype": str(np.asarray(jax.device_get(leaf) if not
                              isinstance(leaf, jax.Array) else 0).dtype)
                 if False else None,
                 "shards": []}
        if isinstance(leaf, jax.Array) and hasattr(leaf, "addressable_shards"):
            entry["dtype"] = str(leaf.dtype)
            for j, shard in enumerate(leaf.addressable_shards):
                if shard.replica_id != 0:
                    continue  # one copy per replicated shard
                name = f"{key}_s{host_index}_{j}"
                arrays[name] = np.asarray(shard.data)
                entry["shards"].append(
                    {"name": name,
                     "index": [[s.start or 0, s.stop] for s in
                               _norm_index(shard.index, leaf.shape)]})
        else:
            arr = np.asarray(leaf)
            entry["dtype"] = str(arr.dtype)
            name = f"{key}_full"
            arrays[name] = arr
            entry["shards"].append(
                {"name": name, "index": [[0, s] for s in arr.shape]})
        manifest["leaves"].append(entry)

    np.savez(tmp_dir / f"shard_{host_index:05d}.npz", **arrays)
    (tmp_dir / "manifest.json").write_text(json.dumps(manifest))
    # atomic publish: rename tmp → final, then commit marker
    if step_dir.exists():
        shutil.rmtree(step_dir)
    tmp_dir.rename(step_dir)
    (ckpt_dir / f"step_{step:09d}.COMMITTED").write_text(str(time.time()))
    return step_dir


def _norm_index(index, shape):
    out = []
    for sl, dim in zip(index, shape):
        start = sl.start or 0
        stop = sl.stop if sl.stop is not None else dim
        out.append(slice(start, stop))
    return out


def latest_step(ckpt_dir) -> int | None:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = [int(p.stem.split("_")[1])
             for p in ckpt_dir.glob("step_*.COMMITTED")]
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir, tree_like, *, step: int = None,
                       shardings=None):
    """Restore onto the current mesh. ``tree_like`` provides structure and
    (if shardings is None) target shardings from its leaves."""
    ckpt_dir = Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {ckpt_dir}")
    step_dir = ckpt_dir / f"step_{step:09d}"
    manifest = json.loads((step_dir / "manifest.json").read_text())
    data: dict = {}
    for f in step_dir.glob("shard_*.npz"):
        with np.load(f) as z:
            data.update({k: z[k] for k in z.files})

    leaves_like, treedef = tree_flatten_with_path(tree_like)
    shard_leaves = (jax.tree_util.tree_leaves(shardings)
                    if shardings is not None else [None] * len(leaves_like))
    by_path = {e["path"]: e for e in manifest["leaves"]}

    out_leaves = []
    for (path, like), shd in zip(leaves_like, shard_leaves):
        entry = by_path[_path_key(path)]
        shape = tuple(entry["shape"])
        dtype = np.dtype(entry["dtype"])
        full = np.zeros(shape, dtype)
        for s in entry["shards"]:
            idx = tuple(slice(a, b) for a, b in s["index"])
            full[idx] = data[s["name"]]
        if shd is not None:
            arr = jax.make_array_from_callback(
                shape, shd, lambda idx, _f=full: _f[idx])
        elif isinstance(like, jax.Array) and hasattr(like, "sharding"):
            arr = jax.make_array_from_callback(
                shape, like.sharding, lambda idx, _f=full: _f[idx])
        else:
            arr = full
        out_leaves.append(arr)
    return tree_unflatten(treedef, out_leaves), step


class CheckpointManager:
    def __init__(self, ckpt_dir, keep: int = 3, async_save: bool = True):
        self.dir = Path(ckpt_dir)
        self.keep = keep
        self.async_save = async_save
        self._thread: threading.Thread | None = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save(self, step: int, tree):
        self.wait()
        # snapshot to host memory synchronously (cheap), write in background
        host_tree = jax.tree.map(
            lambda x: x if isinstance(x, jax.Array) else np.asarray(x), tree)

        def _do():
            save_checkpoint(self.dir, step, host_tree)
            self._gc()

        if self.async_save:
            self._thread = threading.Thread(target=_do, daemon=True)
            self._thread.start()
        else:
            _do()

    def restore(self, tree_like, shardings=None, step=None):
        return restore_checkpoint(self.dir, tree_like, step=step,
                                  shardings=shardings)

    def latest(self):
        return latest_step(self.dir)

    def _gc(self):
        steps = sorted(int(p.stem.split("_")[1])
                       for p in self.dir.glob("step_*.COMMITTED"))
        for s in steps[:-self.keep]:
            (self.dir / f"step_{s:09d}.COMMITTED").unlink(missing_ok=True)
            shutil.rmtree(self.dir / f"step_{s:09d}", ignore_errors=True)
