"""Sharded, atomic, async-capable checkpointing with elastic re-shard.

Layout (tensorstore-free; works on any shared filesystem):

    <dir>/step_000123/
        manifest.json            # step, tree structure, leaf shapes/dtypes
        shard_00000.npz          # this host's addressable shards
    <dir>/step_000123.COMMITTED  # atomic commit marker (rename-based)

Every host writes the *addressable* shards of every leaf with their global
offsets recorded in the manifest; restore rebuilds global arrays with
``jax.make_array_from_callback`` against the *current* mesh/sharding — a
checkpoint written on a 512-chip mesh restores onto 256 chips (elastic
rescale) because assembly is offset-based, not device-based.

``CheckpointManager`` adds keep-N retention and a background-thread async
save (compute/IO overlap: the arrays are snapshotted to host memory
synchronously — cheap — and written in the background).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path

import jax
import numpy as np
from jax.tree_util import tree_flatten_with_path, tree_unflatten


def _path_key(path) -> str:
    return jax.tree_util.keystr(path)


def save_checkpoint(ckpt_dir, step: int, tree, *, host_index: int = 0):
    ckpt_dir = Path(ckpt_dir)
    step_dir = ckpt_dir / f"step_{step:09d}"
    tmp_dir = ckpt_dir / f".tmp_step_{step:09d}_{host_index}"
    tmp_dir.mkdir(parents=True, exist_ok=True)

    leaves, treedef = tree_flatten_with_path(tree)
    manifest = {"step": step, "leaves": []}
    arrays = {}
    for i, (path, leaf) in enumerate(leaves):
        key = f"leaf_{i:05d}"
        entry = {"key": key, "path": _path_key(path),
                 "shape": list(np.shape(leaf)),
                 "dtype": str(np.asarray(jax.device_get(leaf) if not
                              isinstance(leaf, jax.Array) else 0).dtype)
                 if False else None,
                 "shards": []}
        if hasattr(leaf, "addressable_shards"):  # jax.Array or _HostSnapshot
            entry["dtype"] = str(leaf.dtype)
            for j, shard in enumerate(leaf.addressable_shards):
                if shard.replica_id != 0:
                    continue  # one copy per replicated shard
                name = f"{key}_s{host_index}_{j}"
                arrays[name] = np.asarray(shard.data)
                entry["shards"].append(
                    {"name": name,
                     "index": [[s.start or 0, s.stop] for s in
                               _norm_index(shard.index, leaf.shape)]})
        else:
            arr = np.asarray(leaf)
            entry["dtype"] = str(arr.dtype)
            name = f"{key}_full"
            arrays[name] = arr
            entry["shards"].append(
                {"name": name, "index": [[0, s] for s in arr.shape]})
        manifest["leaves"].append(entry)

    with open(tmp_dir / f"shard_{host_index:05d}.npz", "wb") as f:
        np.savez(f, **arrays)
        f.flush()
        os.fsync(f.fileno())
    with open(tmp_dir / "manifest.json", "w") as f:
        f.write(json.dumps(manifest))
        f.flush()
        os.fsync(f.fileno())
    marker = ckpt_dir / f"step_{step:09d}.COMMITTED"
    publish_dir(ckpt_dir, tmp_dir, step_dir, marker)
    return step_dir


def publish_dir(parent: Path, tmp_dir: Path, final_dir: Path,
                marker: Path) -> None:
    """The commit-marker publish protocol (shared by checkpoints and the
    serving artifact).  Order matters for crash safety: a re-publish of an
    already-committed directory must retire the OLD marker before the old
    directory goes away — otherwise a crash between rmtree and rename
    leaves a committed marker pointing at nothing (the torn-save window;
    latest_step/restore_checkpoint and artifact loads skip such states)."""
    marker.unlink(missing_ok=True)
    if final_dir.exists():
        shutil.rmtree(final_dir)
    tmp_dir.rename(final_dir)
    _fsync_dir(parent)                    # make the rename durable
    marker.write_text(str(time.time()))
    _fsync_dir(parent)                    # ... and the commit marker


def atomic_write_text(path, text: str) -> None:
    """Durable atomic single-file publish: write a tmp sibling, fsync it,
    rename over the target, fsync the directory.  A bare
    ``tmp.write_text(); tmp.rename()`` is atomic against *readers* but not
    against power loss — the rename can land while the tmp's data blocks
    are still unflushed, leaving an empty/garbage file under the final
    name after a crash."""
    path = Path(path)
    tmp = path.with_name("." + path.name + ".tmp")
    with open(tmp, "w") as f:
        f.write(text)
        f.flush()
        os.fsync(f.fileno())
    tmp.rename(path)
    _fsync_dir(path.parent)


def _fsync_dir(path: Path) -> None:
    """Best-effort directory fsync (rename/unlink durability on POSIX)."""
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


def _is_torn(ckpt_dir: Path, step: int) -> bool:
    """A committed marker whose step directory (or manifest) is missing —
    the pre-fix torn-save shape, or a crash mid-publish."""
    return not (ckpt_dir / f"step_{step:09d}" / "manifest.json").exists()


def committed_steps(ckpt_dir) -> list:
    """All *intact* committed steps, ascending (torn steps excluded)."""
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return []
    steps = sorted(int(p.stem.split("_")[1])
                   for p in ckpt_dir.glob("step_*.COMMITTED"))
    return [s for s in steps if not _is_torn(ckpt_dir, s)]


def _norm_index(index, shape):
    out = []
    for sl, dim in zip(index, shape):
        start = sl.start or 0
        stop = sl.stop if sl.stop is not None else dim
        out.append(slice(start, stop))
    return out


def latest_step(ckpt_dir) -> int | None:
    """The newest committed step whose directory is intact.  A torn step
    (marker without dir/manifest — a crash inside the publish window) is
    skipped, falling back to the previous committed step."""
    steps = committed_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore_checkpoint(ckpt_dir, tree_like, *, step: int = None,
                       shardings=None):
    """Restore onto the current mesh. ``tree_like`` provides structure and
    (if shardings is None) target shardings from its leaves."""
    ckpt_dir = Path(ckpt_dir)
    if step is None:
        # latest_step already skips torn steps (marker without an intact
        # directory), so this falls back to the newest restorable one
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {ckpt_dir}")
    step_dir = ckpt_dir / f"step_{step:09d}"
    if not (step_dir / "manifest.json").exists():
        raise FileNotFoundError(
            f"checkpoint step {step} in {ckpt_dir} is torn "
            f"(committed marker without manifest)")
    manifest = json.loads((step_dir / "manifest.json").read_text())
    data: dict = {}
    for f in step_dir.glob("shard_*.npz"):
        with np.load(f) as z:
            data.update({k: z[k] for k in z.files})

    leaves_like, treedef = tree_flatten_with_path(tree_like)
    shard_leaves = (jax.tree_util.tree_leaves(shardings)
                    if shardings is not None else [None] * len(leaves_like))
    by_path = {e["path"]: e for e in manifest["leaves"]}

    out_leaves = []
    for (path, like), shd in zip(leaves_like, shard_leaves):
        entry = by_path[_path_key(path)]
        shape = tuple(entry["shape"])
        dtype = np.dtype(entry["dtype"])
        full = np.zeros(shape, dtype)
        for s in entry["shards"]:
            idx = tuple(slice(a, b) for a, b in s["index"])
            full[idx] = data[s["name"]]
        if shd is not None:
            arr = jax.make_array_from_callback(
                shape, shd, lambda idx, _f=full: _f[idx])
        elif isinstance(like, jax.Array) and hasattr(like, "sharding"):
            arr = jax.make_array_from_callback(
                shape, like.sharding, lambda idx, _f=full: _f[idx])
        else:
            arr = full
        out_leaves.append(arr)
    return tree_unflatten(treedef, out_leaves), step


class _HostShard:
    __slots__ = ("replica_id", "data", "index")

    def __init__(self, replica_id, data, index):
        self.replica_id = replica_id
        self.data = data
        self.index = index


class _HostSnapshot:
    """Host-memory copy of a ``jax.Array``'s addressable shards, taken
    synchronously at :meth:`CheckpointManager.save` time.  The background
    write thread must never touch the live device arrays: a donating
    train step deletes those buffers as soon as the next step runs, and a
    save racing that donation dies with "Array has been deleted"."""
    __slots__ = ("dtype", "shape", "addressable_shards")

    def __init__(self, x):
        self.dtype = x.dtype
        self.shape = x.shape
        self.addressable_shards = [
            _HostShard(s.replica_id, np.asarray(s.data), s.index)
            for s in x.addressable_shards]


def _host_snapshot(x):
    if isinstance(x, jax.Array) and hasattr(x, "addressable_shards"):
        return _HostSnapshot(x)
    return np.asarray(x)


class CheckpointManager:
    def __init__(self, ckpt_dir, keep: int = 3, async_save: bool = True):
        self.dir = Path(ckpt_dir)
        self.keep = keep
        self.async_save = async_save
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None

    def wait(self):
        """Join an in-flight background save.  An exception the save thread
        hit (a failed artifact write must never pass as durable) is
        captured and re-raised HERE — and from the next :meth:`save`."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def save(self, step: int, tree):
        self.wait()                     # re-raises a failed previous save
        # snapshot to host memory synchronously (cheap), write in background
        # — shard structure preserved, but NO live device references cross
        # into the thread (donation in the next step would delete them)
        host_tree = jax.tree.map(_host_snapshot, tree)

        def _do():
            try:
                save_checkpoint(self.dir, step, host_tree)
                self._gc()
            except BaseException as e:   # noqa: BLE001 — daemon thread:
                if not self.async_save:  # anything unre-raised is lost
                    raise
                self._error = e

        if self.async_save:
            self._thread = threading.Thread(target=_do, daemon=True)
            self._thread.start()
        else:
            _do()

    def restore(self, tree_like, shardings=None, step=None):
        return restore_checkpoint(self.dir, tree_like, step=step,
                                  shardings=shardings)

    def latest(self):
        return latest_step(self.dir)

    def _gc(self):
        steps = sorted(int(p.stem.split("_")[1])
                       for p in self.dir.glob("step_*.COMMITTED"))
        for s in steps[:-self.keep]:
            (self.dir / f"step_{s:09d}.COMMITTED").unlink(missing_ok=True)
            shutil.rmtree(self.dir / f"step_{s:09d}", ignore_errors=True)
