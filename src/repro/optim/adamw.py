"""AdamW with optional ZeRO-1 sharding of optimizer state.

Self-contained (no optax in this container).  State dtype is fp32
regardless of param dtype (bf16-safe master moments); ``zero_axes`` shards
the moments over the data axes (ZeRO-1) — with GSPMD that is expressed by
sharding constraints on the state pytree, applied in
``repro.launch.sharding.opt_state_specs``.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: dict
    nu: dict


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: object = 3e-4            # float or callable(step) -> float
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0

    def init(self, params):
        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return AdamWState(jnp.zeros((), jnp.int32), zeros,
                          jax.tree.map(jnp.copy, zeros))

    def update(self, grads, state: AdamWState, params):
        step = state.step + 1
        if self.grad_clip:
            grads = clip_by_global_norm(grads, self.grad_clip)
        lr = self.lr(step) if callable(self.lr) else self.lr
        b1, b2 = self.b1, self.b2
        mu = jax.tree.map(
            lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
            state.mu, grads)
        nu = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2) *
            jnp.square(g.astype(jnp.float32)), state.nu, grads)
        mu_hat_scale = 1.0 / (1 - b1 ** step.astype(jnp.float32))
        nu_hat_scale = 1.0 / (1 - b2 ** step.astype(jnp.float32))

        def upd(m, v, p):
            u = (m * mu_hat_scale) / (jnp.sqrt(v * nu_hat_scale) + self.eps)
            u = u + self.weight_decay * p.astype(jnp.float32)
            return (-lr * u).astype(p.dtype)

        updates = jax.tree.map(upd, mu, nu, params)
        return updates, AdamWState(step, mu, nu)


def adamw(**kw) -> AdamW:
    return AdamW(**kw)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: p + u, params, updates)


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads)
