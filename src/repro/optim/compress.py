"""Gradient compression with error feedback (distributed-optimization trick).

Int8 block-quantized gradients for the *cross-pod* all-reduce: the ``pod``
axis crosses DCN (slow links), so compressing the gradient exchanged over it
4× is the classic bandwidth trade.  Error feedback accumulates the
quantization residual locally and re-injects it next step, preserving
convergence (EF-SGD/EF21 style).

Usage inside a train step::

    grads, ef = compress_gradients(grads, ef)   # quantize + residual update

Under GSPMD the quantize/dequantize ops surround the gradient all-reduce;
XLA fuses the cast into the collective's producer/consumer.  (A custom
reduce over int8 would need a collective-permute ladder; we keep the
standard psum on the dequantized values and claim only the DCN-egress
savings, which is what matters at the pod boundary.)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

BLOCK = 256


def _quantize(g32):
    flat = g32.reshape(-1)
    pad = (-flat.shape[0]) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale, pad


def _dequantize(q, scale, pad, shape):
    deq = (q.astype(jnp.float32) * scale).reshape(-1)
    if pad:
        deq = deq[:-pad]
    return deq.reshape(shape)


def error_feedback_init(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_gradients(grads, ef_state):
    """Quantize each gradient to int8 (block-scaled) with error feedback.

    Returns (dequantized grads — what actually enters the optimizer and the
    collective — and the new residual state)."""
    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, scale, pad = _quantize(g32)
        deq = _dequantize(q, scale, pad, g32.shape)
        return deq.astype(g.dtype), g32 - deq

    leaves_g, treedef = jax.tree.flatten(grads)
    leaves_e = jax.tree.leaves(ef_state)
    pairs = [one(g, e) for g, e in zip(leaves_g, leaves_e)]
    grads_c = jax.tree.unflatten(treedef, [p[0] for p in pairs])
    ef_new = jax.tree.unflatten(treedef, [p[1] for p in pairs])
    return grads_c, ef_new
