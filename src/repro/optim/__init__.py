from .adamw import adamw, apply_updates, clip_by_global_norm
from .schedule import cosine_schedule
from .compress import compress_gradients, error_feedback_init

__all__ = ["adamw", "apply_updates", "clip_by_global_norm",
           "cosine_schedule", "compress_gradients", "error_feedback_init"]
