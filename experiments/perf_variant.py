"""§Perf lower-only variant comparator.

Full cost-mode COMPILES take ~15 min/cell on this 1-core host, so hillclimb
iterations are compared on the cost-mode LOWERING (seconds–minutes):

* ``flops``: trip-correct global FLOPs (scan-free/unrolled program);
* ``shard_map collective bytes``: the embed-psum / vocab-parallel-CE /
  MoE-all-to-all traffic is explicit pre-SPMD (these are exactly the
  collectives the hillclimb levers touch); GSPMD-inserted gradient
  all-reduces are invariant across these variants (same params).

The anchored baseline for each cell is its full compiled record from
``experiments/dryrun``.

    PYTHONPATH=src python experiments/perf_variant.py qwen3-moe-235b-a22b \
        train_4k v_cap105 capacity_factor=1.05
"""
import json
import sys
import time

# device-count flag must precede any jax import
from repro.launch.dryrun import OUT_DIR  # noqa: F401  (sets XLA_FLAGS)
import jax

from repro.configs import get_config
from repro.launch.mesh import make_production_mesh, mesh_context
from repro.launch.steps import build_bundle
from repro.roofline.analysis import collective_bytes_from_hlo


def parse_val(v):
    for cast in (int, float):
        try:
            return cast(v)
        except ValueError:
            pass
    return v


def main():
    arch, shape, variant = sys.argv[1:4]
    overrides = {k: parse_val(v) for k, v in
                 (kv.split("=", 1) for kv in sys.argv[4:])}
    import dataclasses
    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    mesh = make_production_mesh()
    t0 = time.time()
    with mesh_context(mesh):
        b = build_bundle(cfg, mesh, shape, remat="none", cost_mode=True)
        lo = jax.jit(b.fn, in_shardings=b.in_shardings).lower(*b.args)
        ca = lo.cost_analysis() or {}
        txt = lo.as_text()
    rec = {
        "arch": arch, "shape": shape, "variant": variant,
        "overrides": overrides,
        "flops_global": float(ca.get("flops", 0.0)),
        "shardmap_collective_bytes": collective_bytes_from_hlo(txt),
        "lower_s": round(time.time() - t0, 1),
    }
    out = OUT_DIR / f"perf__{arch}__{shape}__{variant}.json"
    out.write_text(json.dumps(rec, indent=2))
    print(json.dumps(rec))


if __name__ == "__main__":
    main()
