"""§Perf hillclimb driver: run one (arch × shape) cell with config overrides.

    PYTHONPATH=src python experiments/hillclimb.py gemma3-4b train_4k \
        v1_seq_scatter embed_strategy=masked_psum_scatter

Writes experiments/dryrun/<arch>__<shape>__single__<variant>.json.
"""
import sys

from repro.launch.dryrun import run_cell  # sets XLA device-count flag first


def parse_val(v: str):
    for cast in (int, float):
        try:
            return cast(v)
        except ValueError:
            pass
    if v in ("true", "false"):
        return v == "true"
    return v


def main():
    arch, shape, variant = sys.argv[1:4]
    overrides = {}
    for kv in sys.argv[4:]:
        k, v = kv.split("=", 1)
        overrides[k] = parse_val(v)
    rec = run_cell(arch, shape, "single", skip_existing=False,
                   variant=variant, overrides=overrides)
    r = rec.get("roofline", {})
    print(f"{arch} {shape} {variant}: status={rec['status']} "
          f"compute={r.get('compute_s', 0):.3e}s "
          f"memory={r.get('memory_s', 0):.3e}s "
          f"collective={r.get('collective_s', 0):.3e}s "
          f"bottleneck={r.get('bottleneck')} "
          f"err={rec.get('error', '')[:100]}")


if __name__ == "__main__":
    main()
