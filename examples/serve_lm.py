"""Serving driver: batched greedy decoding with the wave-batching server.

    PYTHONPATH=src python examples/serve_lm.py --arch zamba2-7b --requests 6
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_reduced
from repro.models import LM
from repro.runtime.server import DecodeServer, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="h2o-danube-1.8b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--new-tokens", type=int, default=8)
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    srv = DecodeServer(lm, params, batch_slots=args.slots, max_len=64)

    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size, 4).astype(
        np.int32), max_new_tokens=args.new_tokens)
        for _ in range(args.requests)]
    for r in reqs:
        srv.submit(r)
    t0 = time.time()
    steps = srv.run_until_drained()
    dt = time.time() - t0
    toks = sum(len(r.out) for r in reqs)
    print(f"arch={cfg.name}: {len(reqs)} requests, {toks} tokens, "
          f"{steps} decode steps, {toks/dt:.1f} tok/s (CPU)")
    for i, r in enumerate(reqs):
        print(f"  req{i}: prompt={r.prompt.tolist()} -> {r.out}")


if __name__ == "__main__":
    main()
