"""Embedding operations inside a model: MoE dispatch as an SLS-class op and
the vocab-sharded embedding engine, on whatever devices this host has.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/moe_embedding_ops.py
"""
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import embedding_engine as ee
from repro.models import moe as moe_mod
from repro.configs import get_reduced


def main():
    n = len(jax.devices())
    model_par = min(4, n)
    from repro.launch.mesh import axis_types_kw, mesh_context
    mesh = jax.make_mesh((n // model_par, model_par), ("data", "model"),
                         **axis_types_kw(2))
    print(f"devices={n}, mesh=({n // model_par}×{model_par})")

    # 1) vocab-sharded embedding lookup + vocab-parallel xent
    V, D, B, S = 128, 32, 4, 16
    rng = np.random.default_rng(0)
    table = jnp.asarray(rng.standard_normal((V, D)), jnp.float32)
    ids = jnp.asarray(rng.integers(0, V, (B, S)), jnp.int32)
    with mesh_context(mesh):
        tbl = jax.device_put(table, NamedSharding(mesh, P("model", None)))
        emb = ee.lookup(tbl, ids, mesh=mesh, vocab_axis="model",
                        strategy="masked_psum", data_axes=("data",))
        err = float(jnp.abs(emb - jnp.take(table, ids, axis=0)).max())
        print(f"sharded embedding lookup: err={err:.2e} ✓")

        # 2) MoE dispatch = the SLS-class embedding op, with EP all-to-all.
        # capacity_factor=8 → no token drops, so the EP layout must agree
        # bit-for-bit with the single-device reference (at production
        # capacity 1.25 the two layouts drop *different* tokens — expected).
        import dataclasses
        cfg = dataclasses.replace(get_reduced("qwen3-moe-235b-a22b"),
                                  capacity_factor=8.0)
        p = moe_mod.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (B, 16, cfg.d_model))
        ref, _ = moe_mod.moe_ffn(p, x, cfg, mesh=None)
        out, aux = moe_mod.moe_ffn(
            p, jax.device_put(x, NamedSharding(mesh, P("data", None, None))),
            cfg, mesh=mesh)
        print(f"EP MoE dispatch (all-to-all over {model_par} expert shards): "
              f"err={float(jnp.abs(out - ref).max()):.2e} "
              f"aux={float(aux):.3f} ✓")


if __name__ == "__main__":
    main()
