"""End-to-end driver: train an assigned-architecture LM with the full
substrate — synthetic pipeline, AdamW, checkpointing, fault-tolerant
supervisor with injected failures, optional gradient compression.

Default preset is CPU-friendly; ``--preset 100m`` trains a ~100M-param
stablelm-family model for a few hundred steps (use on a real accelerator).

    PYTHONPATH=src python examples/train_lm.py --arch stablelm-3b --steps 40
    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300
"""
import argparse
import dataclasses
import tempfile

import jax

from repro.configs import get_reduced
from repro.data.pipeline import DataConfig, SyntheticTokens
from repro.models import LM, ModelConfig
from repro.runtime.trainer import Trainer, TrainerConfig, run_supervised


def preset_100m() -> ModelConfig:
    return ModelConfig(name="stablelm-100m", family="dense", num_layers=12,
                       d_model=768, num_heads=12, num_kv_heads=12,
                       d_ff=2048, vocab_size=32000,
                       block_pattern=("dense",), dtype="float32")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-3b")
    ap.add_argument("--preset", default=None, choices=[None, "100m"])
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--compress", action="store_true")
    ap.add_argument("--inject-failures", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = preset_100m() if args.preset == "100m" else get_reduced(args.arch)
    lm = LM(cfg)
    n_params = sum(x.size for x in jax.tree.leaves(
        jax.eval_shape(lm.init, jax.random.PRNGKey(0))))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M")

    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="repro_ckpt_")
    data = SyntheticTokens(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        global_batch=args.batch, modality=cfg.modality,
        d_model=cfg.d_model, enc_seq=args.seq))
    tcfg = TrainerConfig(total_steps=args.steps, ckpt_every=max(
        args.steps // 4, 1), ckpt_dir=ckpt_dir,
        grad_compression=args.compress, log_every=5)

    def make_trainer():
        return Trainer(LM(cfg), data, tcfg)

    schedule = {args.steps // 3, 2 * args.steps // 3} \
        if args.inject_failures else None
    out = run_supervised(make_trainer, jax.random.PRNGKey(0),
                         failure_schedule=schedule)
    losses = out["losses"]
    print(f"finished step {out['final_step']} restarts={out['restarts']} "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")


if __name__ == "__main__":
    main()
