"""Quickstart: compile an EmbeddingBag through the full Ember pipeline.

Shows the paper's progressive lowering end-to-end: SCF → SLC (decoupled)
→ optimized SLCV → DLC (queue code) → the TPU KernelPlan, with the queue
traffic shrinking at every opt level (Fig 14), and validates every stage
against the numpy reference.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core.backend_pallas import execute as run_pallas, make_plan
from repro.core.dlc import pretty as dlc_pretty
from repro.core.ops import EmbeddingOp, make_inputs, reference
from repro.core.pipeline import compile_op, run_interpreted
from repro.core.slc import pretty as slc_pretty


def main():
    # an nn.EmbeddingBag / SLS: 8 segments, table of 64×96, weighted sum
    op = EmbeddingOp(kind="sls", num_segments=8, num_embeddings=64,
                     emb_len=96, avg_lookups=6, weighted=True)
    inputs = make_inputs(op, seed=0)
    want = reference(op, inputs)

    print("=" * 72)
    print("UNOPTIMIZED DECOUPLED CODE (emb-opt0) — SLC IR")
    print("=" * 72)
    res0 = compile_op(op, "O0")
    print(slc_pretty(res0.slc))

    print()
    print("=" * 72)
    print("FULLY OPTIMIZED (emb-opt3: vectorized+bufferized+aligned) — SLC")
    print("=" * 72)
    res3 = compile_op(op, "O3", vlen=16)
    print(slc_pretty(res3.slc))

    print()
    print("=" * 72)
    print("DLC (access-unit dataflow + execute-unit queue code), emb-opt3")
    print("=" * 72)
    print(dlc_pretty(res3.dlc))

    print()
    print("queue traffic per opt level (Fig 14):")
    for lvl in ("O0", "O1", "O2", "O3"):
        res = compile_op(op, lvl, vlen=16)
        out, stats = run_interpreted(res, inputs, "dlc", return_queues=True)
        np.testing.assert_allclose(out, want, rtol=1e-4, atol=1e-4)
        print(f"  {lvl}: data items={stats['data_pushed']:5d} "
              f"tokens={stats['tokens']:4d}   (semantics verified ✓)")

    plan = make_plan(res3)
    print(f"\nTPU KernelPlan: {plan}")
    out = run_pallas(res3, inputs, interpret=True)
    np.testing.assert_allclose(np.asarray(out), want, rtol=1e-4, atol=1e-4)
    print("Pallas DAE kernel output matches the reference ✓")


if __name__ == "__main__":
    main()
