#!/usr/bin/env bash
# Tier-1 verification entrypoint (the ROADMAP command, with PYTHONPATH set).
#
#   scripts/tier1.sh            # exactly the ROADMAP tier-1 run
#   scripts/tier1.sh --fast     # + no cacheprovider (clean CI workspaces)
#   scripts/tier1.sh [pytest args...]   # extra args forwarded to pytest
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

EXTRA=()
if [[ "${1:-}" == "--fast" ]]; then
  EXTRA+=(-p no:cacheprovider)
  shift
fi
exec python -m pytest -x -q "${EXTRA[@]}" "$@"
