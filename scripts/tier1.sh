#!/usr/bin/env bash
# Tier-1 verification entrypoint (the ROADMAP command, with PYTHONPATH set).
#
#   scripts/tier1.sh            # exactly the ROADMAP tier-1 run (full
#                               # differential sweep: >=200 generated cases)
#   scripts/tier1.sh --fast     # + no cacheprovider (clean CI workspaces)
#                               # + differential smoke subset (pytest --fast)
#                               # + steady-state executor bench smoke run
#   scripts/tier1.sh [pytest args...]   # extra args forwarded to pytest
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

FAST=0
EXTRA=()
if [[ "${1:-}" == "--fast" ]]; then
  FAST=1
  # --fast (tests/conftest.py) gates the generated differential cases to a
  # smoke subset, the same way this script gates the benches below
  EXTRA+=(-p no:cacheprovider --fast)
  shift
fi
python -m pytest -x -q "${EXTRA[@]}" "$@"

if [[ "$FAST" == 1 ]]; then
  # steady-state throughput smoke: asserts the partitioner's VMEM audit,
  # the overlap>=cached ordering, and refreshes BENCH_steady_state.json
  # (small sizes; seconds, not minutes)
  python benchmarks/bench_steady_state.py --fast
  # vocab-sharded smoke (the bench respawns itself in a subprocess with a
  # forced 2-device CPU mesh — no env leak into this shell): asserts
  # sharded numerics == replicated for BOTH exchange modes, fewer host
  # syncs + reduce-scattered output bytes on the collective path, and the
  # per-device footprint halving, refreshes BENCH_sharded.json
  python benchmarks/bench_sharded.py --fast --exchange=both
  # locality-aware hot/cold sharding smoke (same respawn pattern): asserts
  # outputs identical to the interleaved PR-3 path AND >= 2x less routed
  # exchange volume on the Zipf stream; the non-stationary leg rotates the
  # Zipf head every N steps and asserts the adaptive re-classifier holds
  # routed exchange <= 2x the stationary optimum (static degrades >= 4x)
  # with outputs bit-identical to a cold-built oracle across every slab
  # swap, incl. collective+host exchange, the spill router and the disagg
  # republish path; refreshes BENCH_locality.json
  python benchmarks/bench_locality.py --fast
  # open-loop serving smoke: continuous-batching server under Poisson load
  # at 2 QPS points + a 16x overload point (asserts the SLO admission
  # sheds instead of queueing unboundedly) + the cross-program pipeline
  # ablation (asserts pipeline_group beats the sequential two-program
  # baseline), refreshes BENCH_serving.json
  python benchmarks/bench_serving.py --fast
  # disaggregated embedding tier smoke: asserts disagg outputs are
  # bit-identical to in-process, measures the steady-state RPC overhead
  # ratio, and runs the kill-a-replica-mid-load leg (failover + respawn +
  # checkpoint re-warm; failed_requests==0 required), refreshes
  # BENCH_disagg.json
  python benchmarks/bench_disagg.py --fast
  # cold-start smoke: boots the same program three ways in subprocesses
  # (cold compile / in-process warm caches / AOT serving artifact) and
  # asserts the artifact boot loads instead of compiling
  # (compile_source=artifact, zero AOT compiles), is bit-identical to the
  # fresh compile, and >= 3x faster TTFT; refreshes BENCH_coldstart.json
  python benchmarks/bench_coldstart.py --fast
  # chaos leg: the seeded fault-injection suite replayed under a pinned
  # seed — per-site executor recovery, wave watchdog + bounded retry,
  # hardening policies, and the rpc/service sites of the disaggregated
  # tier (rpc_send/rpc_recv severing + service_crash respawn).  The full
  # pytest above already ran it once with the default seed; this replay
  # pins the probabilistic schedules.
  CHAOS_SEED=7 python -m pytest -x -q -p no:cacheprovider --fast \
    tests/test_faults.py tests/test_disagg.py
fi
