#!/usr/bin/env python
"""Fail CI when a freshly-measured BENCH_*.json regresses a tracked
metric vs. the committed baseline by more than 10%.

The tier1 workflow refreshes the ``BENCH_*.json`` records in the workspace
(``scripts/tier1.sh --fast``); this script diffs the tracked metrics
against the versions committed at HEAD (``git show``).  Each metric is
direction-aware: exchange-bytes and serving-latency metrics are
lower-is-better (a >10% increase fails), serving-throughput metrics are
higher-is-better (a >10% drop fails).  Rate metrics tagged ``abs``
compare absolutely (baseline + 0.10), since a relative band around a 0.0
baseline is degenerate.  A metric missing on either side is reported and
skipped (new benches and schema growth are not regressions), as is a
record whose benchmark ``config`` differs from the baseline's (numbers
are only comparable within one workload — the mismatch is a warning and
exit 0, never a failure).

The workflow passes the PR's merge base (``origin/<base branch>``) or, on
push, ``HEAD^`` as the baseline ref — never the commit under test, which
could carry its own regressed records.  An unresolvable ref degrades to
all-skip (first push of a branch), not a failure.  A *malformed* record —
a fresh or committed ``BENCH_*.json`` that is not valid JSON — is a hard
error with a clear one-line message (exit 2, no traceback): silent skips
would let a corrupted baseline disable the gate.

    python scripts/check_bench_regression.py [--baseline-ref HEAD]

Exit codes: 0 ok (possibly with warnings), 1 regression(s), 2 malformed
records.
"""
from __future__ import annotations

import argparse
import json
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

#: (file, dotted metric path, direction[, mode[, tolerance]]).  "lower" =
#: lower is better, a +10% increase fails; "higher" = higher is better, a
#: -10% drop fails.  mode "abs" (rates in [0, 1]) replaces the relative
#: band with an absolute one: fresh may not exceed baseline + tolerance.
#: A per-metric tolerance (5th element) overrides the global 10% band for
#: metrics that need a wider one (noisy wall-clock ratios).
#: The exchange metrics are deterministic byte counts; the serving
#: metrics are wall-clock service numbers (the 10% band absorbs machine
#: noise at the smoke sizes tier1.sh --fast runs them at).
METRICS = (
    ("BENCH_sharded.json", "exchange_measured.index_bytes_per_step",
     "lower"),
    ("BENCH_sharded.json", "exchange_measured.row_bytes_per_step",
     "lower"),
    ("BENCH_sharded.json",
     "exchange_ablation.collective.index_bytes_per_step", "lower"),
    ("BENCH_sharded.json",
     "exchange_ablation.collective.row_bytes_per_step", "lower"),
    ("BENCH_locality.json", "exchange_index_bytes_per_step.hot_cold",
     "lower"),
    # adaptive hot slab (PR 9): under the drifting-head workload the
    # re-classifier must keep routed exchange near the stationary optimum
    # (bytes may not grow >10%) and the windowed hot hit-rate it recovers
    # after the final head rotation may not drop >10%
    ("BENCH_locality.json",
     "non_stationary.adaptive_routed_bytes_per_step", "lower"),
    ("BENCH_locality.json", "non_stationary.post_drift_hot_hit_rate",
     "higher"),
    # serving loop (PR 6): p99 service latency must not inflate, and
    # neither open-loop throughput nor the cross-program pipeline's
    # tokens/sec may fall behind the committed baseline
    ("BENCH_serving.json", "open_loop.saturating.ttft_ms.p99", "lower"),
    ("BENCH_serving.json", "open_loop.saturating.token_latency_ms.p99",
     "lower"),
    ("BENCH_serving.json", "open_loop.saturating.tokens_per_sec",
     "higher"),
    ("BENCH_serving.json", "pipeline.pipelined_tokens_per_sec", "higher"),
    # fault tolerance (PR 7): the saturating point must not start shedding
    # where the baseline didn't — a shed-rate jump >0.10 absolute means
    # the server got slower and the SLO admission is covering for it
    ("BENCH_serving.json", "open_loop.saturating.shed_rate", "lower",
     "abs"),
    # disaggregated tier (PR 8): killing a replica mid-load must fail
    # ZERO requests (baseline 0; abs mode means any failure trips), and
    # the steady-state RPC overhead ratio must stay bounded — gated with
    # a loose per-metric tolerance (5th element) since it is a wall-clock
    # ratio of two small numbers
    ("BENCH_disagg.json", "disagg.failed_requests", "lower", "abs"),
    ("BENCH_disagg.json", "steady_state.overhead_ratio", "lower", "rel",
     0.5),
    # AOT serving artifact (PR 10): the artifact-loaded boot's TTFT may
    # not grow >10%, and its outputs must stay bit-identical to a fresh
    # compile — gated with zero relative tolerance (baseline 1; "abs"
    # would only bound above, so a 1 -> 0 flip must trip the rel band)
    ("BENCH_coldstart.json", "artifact_boot.ttft_s", "lower"),
    ("BENCH_coldstart.json", "artifact_boot.bit_identical", "higher",
     "rel", 0.0),
)

TOLERANCE = 0.10


def dig(record: dict, path: str):
    cur = record
    for part in path.split("."):
        if not isinstance(cur, dict) or part not in cur:
            return None
        cur = cur[part]
    return cur


def baseline_json(ref: str, name: str):
    """Returns ``(record, reason)``: record is the parsed baseline or
    None, reason one of "ok" | "no-ref" (unresolvable baseline ref — skip
    everything) | "missing" (file absent at the ref — a new bench) |
    "malformed" (present but not JSON — a hard error)."""
    p = subprocess.run(["git", "show", f"{ref}:{name}"],
                       capture_output=True, text=True, cwd=REPO)
    if p.returncode != 0:
        err = p.stderr.lower()
        if "invalid object name" in err or "unknown revision" in err or \
                "bad revision" in err:
            return None, "no-ref"
        return None, "missing"
    try:
        return json.loads(p.stdout), "ok"
    except json.JSONDecodeError as e:
        print(f"ERROR {name}@{ref}: baseline record is not valid JSON "
              f"({e})", file=sys.stderr)
        return None, "malformed"


def fresh_json(path: Path):
    """Parse a workspace record; a malformed file is a clear one-line
    error (never a traceback)."""
    try:
        return json.loads(path.read_text()), "ok"
    except json.JSONDecodeError as e:
        print(f"ERROR {path.name}: fresh record is not valid JSON ({e})",
              file=sys.stderr)
        return None, "malformed"
    except OSError as e:
        print(f"ERROR {path.name}: cannot read fresh record ({e})",
              file=sys.stderr)
        return None, "malformed"


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline-ref", default="HEAD",
                    help="git ref holding the committed baseline records")
    args = ap.parse_args()

    failures = []
    malformed = []
    config_mismatches = []
    records: dict = {}    # file name -> (fresh_rec, base_rec, comparable)
    for metric in METRICS:
        name, path, direction = metric[0], metric[1], metric[2]
        mode = metric[3] if len(metric) > 3 else "rel"
        tol = metric[4] if len(metric) > 4 else TOLERANCE
        if name not in records:
            records[name] = _load_pair(name, malformed, config_mismatches,
                                       args.baseline_ref)
        fresh_rec, base_rec, comparable = records[name]
        if not comparable:
            continue
        fresh = dig(fresh_rec, path)
        base = dig(base_rec, path) if base_rec else None
        if fresh is None or base is None:
            print(f"SKIP {name}:{path} (metric absent: "
                  f"fresh={fresh} baseline={base})")
            continue
        if mode == "abs":
            limit = base + tol
            bad = fresh > limit
        elif direction == "lower":
            limit = base * (1 + tol)
            bad = fresh > limit
        else:
            limit = base * (1 - tol)
            bad = fresh < limit
        status = "FAIL" if bad else "ok"
        print(f"{status:4} {name}:{path} [{direction}"
              f"{',abs' if mode == 'abs' else ''}]  baseline={base}  "
              f"fresh={fresh}  limit={limit:.4g}")
        if bad:
            failures.append((name, path, base, fresh))
    if config_mismatches:
        print(f"\nWARNING: {len(config_mismatches)} record(s) skipped on "
              f"config mismatch (baselines measured under a different "
              f"workload): {', '.join(sorted(set(config_mismatches)))}")
    if malformed:
        print(f"\n{len(malformed)} malformed benchmark record(s): "
              f"{', '.join(sorted(set(malformed)))} — regenerate with "
              f"scripts/tier1.sh --fast", file=sys.stderr)
        return 2
    if failures:
        print(f"\n{len(failures)} benchmark regression(s) > "
              f"{TOLERANCE:.0%} vs {args.baseline_ref}", file=sys.stderr)
        return 1
    return 0


def _load_pair(name: str, malformed: list, config_mismatches: list,
               ref: str):
    """Load fresh + baseline records for one file; returns
    ``(fresh, base, comparable)``, recording malformed records and
    config mismatches for the summary."""
    fresh_path = REPO / name
    if not fresh_path.exists():
        print(f"SKIP {name} (no fresh record)")
        return None, None, False
    fresh_rec, fstate = fresh_json(fresh_path)
    if fstate == "malformed":
        malformed.append(name)
        return None, None, False
    base_rec, bstate = baseline_json(ref, name)
    if bstate == "malformed":
        malformed.append(f"{name}@{ref}")
        return fresh_rec, None, False
    if bstate == "no-ref":
        print(f"SKIP {name} (baseline ref {ref!r} not resolvable — "
              f"first push?)")
        return fresh_rec, None, False
    if bstate == "missing":
        print(f"SKIP {name} (no baseline at {ref} — new bench)")
        return fresh_rec, None, False
    # metrics are only comparable between runs of the same workload:
    # a baseline committed from a full-size run must not silently
    # gate (or trip on) a --fast measurement
    fresh_cfg = (fresh_rec or {}).get("config")
    base_cfg = (base_rec or {}).get("config")
    if fresh_cfg != base_cfg:
        print(f"SKIP {name} (configs differ: fresh={fresh_cfg} "
              f"baseline={base_cfg})")
        config_mismatches.append(name)
        return fresh_rec, base_rec, False
    return fresh_rec, base_rec, True


if __name__ == "__main__":
    sys.exit(main())
