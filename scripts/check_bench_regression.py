#!/usr/bin/env python
"""Fail CI when a freshly-measured BENCH_*.json regresses its exchange
bytes vs. the committed baseline by more than 10%.

The tier1 workflow refreshes the ``BENCH_*.json`` records in the workspace
(``scripts/tier1.sh --fast``); this script diffs the *byte-counted*
exchange metrics — deterministic layout/routing products, unlike the
noisy µs timings — against the versions committed at HEAD (``git show``).
A metric missing on either side is reported and skipped (new benches and
schema growth are not regressions), as is a record whose benchmark
``config`` differs from the baseline's (byte counts are only comparable
within one workload); a >10% increase in any tracked metric exits
non-zero.

The workflow passes the PR's merge base (``origin/<base branch>``) or, on
push, ``HEAD^`` as the baseline ref — never the commit under test, which
could carry its own regressed records.  An unresolvable ref degrades to
all-skip (first push of a branch), not a failure.

    python scripts/check_bench_regression.py [--baseline-ref HEAD]
"""
from __future__ import annotations

import argparse
import json
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

#: (file, dotted metric path) — every tracked metric counts exchanged
#: bytes per step; lower is better, +10% fails.
METRICS = (
    ("BENCH_sharded.json", "exchange_measured.index_bytes_per_step"),
    ("BENCH_sharded.json", "exchange_measured.row_bytes_per_step"),
    ("BENCH_sharded.json",
     "exchange_ablation.collective.index_bytes_per_step"),
    ("BENCH_sharded.json",
     "exchange_ablation.collective.row_bytes_per_step"),
    ("BENCH_locality.json", "exchange_index_bytes_per_step.hot_cold"),
)

TOLERANCE = 0.10


def dig(record: dict, path: str):
    cur = record
    for part in path.split("."):
        if not isinstance(cur, dict) or part not in cur:
            return None
        cur = cur[part]
    return cur


def baseline_json(ref: str, name: str):
    try:
        out = subprocess.run(["git", "show", f"{ref}:{name}"],
                             capture_output=True, text=True, cwd=REPO,
                             check=True).stdout
        return json.loads(out)
    except (subprocess.CalledProcessError, json.JSONDecodeError):
        return None


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline-ref", default="HEAD",
                    help="git ref holding the committed baseline records")
    args = ap.parse_args()

    failures = []
    config_ok: dict = {}
    for name, path in METRICS:
        fresh_path = REPO / name
        if not fresh_path.exists():
            print(f"SKIP {name}:{path} (no fresh record)")
            continue
        fresh_rec = json.loads(fresh_path.read_text())
        base_rec = baseline_json(args.baseline_ref, name)
        # byte counts are only comparable between runs of the same
        # workload: a baseline committed from a full-size run must not
        # silently gate (or trip on) a --fast measurement
        if name not in config_ok:
            fresh_cfg = (fresh_rec or {}).get("config")
            base_cfg = (base_rec or {}).get("config")
            config_ok[name] = fresh_cfg == base_cfg
            if not config_ok[name]:
                print(f"SKIP {name} (configs differ: fresh={fresh_cfg} "
                      f"baseline={base_cfg})")
        if not config_ok[name]:
            continue
        fresh = dig(fresh_rec, path)
        base = dig(base_rec, path) if base_rec else None
        if fresh is None or base is None:
            print(f"SKIP {name}:{path} (metric absent: "
                  f"fresh={fresh} baseline={base})")
            continue
        limit = base * (1 + TOLERANCE)
        status = "FAIL" if fresh > limit else "ok"
        print(f"{status:4} {name}:{path}  baseline={base}  fresh={fresh}  "
              f"limit={limit:.0f}")
        if fresh > limit:
            failures.append((name, path, base, fresh))
    if failures:
        print(f"\n{len(failures)} exchange-bytes regression(s) > "
              f"{TOLERANCE:.0%} vs {args.baseline_ref}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
