#!/usr/bin/env python
"""Fail CI when a freshly-measured BENCH_*.json regresses a tracked
metric vs. the committed baseline by more than 10%.

The tier1 workflow refreshes the ``BENCH_*.json`` records in the workspace
(``scripts/tier1.sh --fast``); this script diffs the tracked metrics
against the versions committed at HEAD (``git show``).  Each metric is
direction-aware: exchange-bytes and serving-latency metrics are
lower-is-better (a >10% increase fails), serving-throughput metrics are
higher-is-better (a >10% drop fails).  A metric missing on either side is
reported and skipped (new benches and schema growth are not regressions),
as is a record whose benchmark ``config`` differs from the baseline's
(numbers are only comparable within one workload).

The workflow passes the PR's merge base (``origin/<base branch>``) or, on
push, ``HEAD^`` as the baseline ref — never the commit under test, which
could carry its own regressed records.  An unresolvable ref degrades to
all-skip (first push of a branch), not a failure.

    python scripts/check_bench_regression.py [--baseline-ref HEAD]
"""
from __future__ import annotations

import argparse
import json
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

#: (file, dotted metric path, direction).  "lower" = lower is better, a
#: +10% increase fails; "higher" = higher is better, a -10% drop fails.
#: The exchange metrics are deterministic byte counts; the serving
#: metrics are wall-clock service numbers (the 10% band absorbs machine
#: noise at the smoke sizes tier1.sh --fast runs them at).
METRICS = (
    ("BENCH_sharded.json", "exchange_measured.index_bytes_per_step",
     "lower"),
    ("BENCH_sharded.json", "exchange_measured.row_bytes_per_step",
     "lower"),
    ("BENCH_sharded.json",
     "exchange_ablation.collective.index_bytes_per_step", "lower"),
    ("BENCH_sharded.json",
     "exchange_ablation.collective.row_bytes_per_step", "lower"),
    ("BENCH_locality.json", "exchange_index_bytes_per_step.hot_cold",
     "lower"),
    # serving loop (PR 6): p99 service latency must not inflate, and
    # neither open-loop throughput nor the cross-program pipeline's
    # tokens/sec may fall behind the committed baseline
    ("BENCH_serving.json", "open_loop.saturating.ttft_ms.p99", "lower"),
    ("BENCH_serving.json", "open_loop.saturating.token_latency_ms.p99",
     "lower"),
    ("BENCH_serving.json", "open_loop.saturating.tokens_per_sec",
     "higher"),
    ("BENCH_serving.json", "pipeline.pipelined_tokens_per_sec", "higher"),
)

TOLERANCE = 0.10


def dig(record: dict, path: str):
    cur = record
    for part in path.split("."):
        if not isinstance(cur, dict) or part not in cur:
            return None
        cur = cur[part]
    return cur


def baseline_json(ref: str, name: str):
    try:
        out = subprocess.run(["git", "show", f"{ref}:{name}"],
                             capture_output=True, text=True, cwd=REPO,
                             check=True).stdout
        return json.loads(out)
    except (subprocess.CalledProcessError, json.JSONDecodeError):
        return None


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline-ref", default="HEAD",
                    help="git ref holding the committed baseline records")
    args = ap.parse_args()

    failures = []
    config_ok: dict = {}
    for name, path, direction in METRICS:
        fresh_path = REPO / name
        if not fresh_path.exists():
            print(f"SKIP {name}:{path} (no fresh record)")
            continue
        fresh_rec = json.loads(fresh_path.read_text())
        base_rec = baseline_json(args.baseline_ref, name)
        # metrics are only comparable between runs of the same workload:
        # a baseline committed from a full-size run must not silently
        # gate (or trip on) a --fast measurement
        if name not in config_ok:
            fresh_cfg = (fresh_rec or {}).get("config")
            base_cfg = (base_rec or {}).get("config")
            config_ok[name] = fresh_cfg == base_cfg
            if not config_ok[name]:
                print(f"SKIP {name} (configs differ: fresh={fresh_cfg} "
                      f"baseline={base_cfg})")
        if not config_ok[name]:
            continue
        fresh = dig(fresh_rec, path)
        base = dig(base_rec, path) if base_rec else None
        if fresh is None or base is None:
            print(f"SKIP {name}:{path} (metric absent: "
                  f"fresh={fresh} baseline={base})")
            continue
        if direction == "lower":
            limit = base * (1 + TOLERANCE)
            bad = fresh > limit
        else:
            limit = base * (1 - TOLERANCE)
            bad = fresh < limit
        status = "FAIL" if bad else "ok"
        print(f"{status:4} {name}:{path} [{direction}]  baseline={base}  "
              f"fresh={fresh}  limit={limit:.1f}")
        if bad:
            failures.append((name, path, base, fresh))
    if failures:
        print(f"\n{len(failures)} benchmark regression(s) > "
              f"{TOLERANCE:.0%} vs {args.baseline_ref}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
