"""Steady-state program-step throughput: the PR-2 executor ablation.

One multi-table LM/MoE-shaped embedding program (token embed + label gather
sharing the embed table + MoE un-dispatch gather + a DLRM-style bank of SLS
tables), executed for K identical-shape steps (the fixed-batch serving
pattern) four ways:

    per_op              unfused, one kernel dispatch per op, host marshal
                        per step (the pre-fusion baseline)
    fused_percall       PR 1: fused program, but fuse_inputs() re-stacks the
                        tables and re-merges the CSR streams on the host
                        EVERY step
    executor_cached     PR 2 ProgramExecutor.step(): device-resident stacked
                        tables + bucketed scratch (zero host re-stacking),
                        synchronous consume
    executor_overlap    PR 2 submit/result pipeline (depth 2): step N+1's
                        access stream marshals while step N executes

Emits CSV through the harness ``report`` hook and writes
``BENCH_steady_state.json`` with per-variant us/step, speedups, and the
fusion partitioner's resource audit (no fused group may exceed the
estimated-VMEM budget).
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np

from repro.core import backend_jax, cost_model
from repro.core.executor import ProgramExecutor
from repro.core.ops import (EmbeddingOp, EmbeddingProgram,
                            make_program_inputs)
from repro.core.passes import fuse_inputs, split_outputs
from repro.core.pipeline import compile_program
from repro.core import embedding_engine as ee

DEFAULT_OUT = Path(__file__).resolve().parent.parent / \
    "BENCH_steady_state.json"


def _program(fast: bool) -> EmbeddingProgram:
    # serving shape: huge tables, small per-step batches.  The grid stays
    # small (interpret-mode pallas unrolls it at trace time); the table
    # rows are what the per-call path re-stacks every step.
    if fast:
        vocab, d, tokens, n_tbl, segs, rows, avg = 512, 64, 16, 2, 16, 2000, 4
    else:
        vocab, d, tokens, n_tbl, segs, rows, avg = \
            8192, 64, 32, 4, 32, 50000, 4
    sls_bank = tuple(
        (f"dlrm{i}", EmbeddingOp("sls", segs, rows, d, avg_lookups=avg))
        for i in range(n_tbl))
    moe = (("moe_undispatch", EmbeddingOp("gather", tokens, tokens * 2, d)),)
    return ee.model_embedding_program(vocab_size=vocab, d_model=d,
                                      tokens=tokens,
                                      extra_ops=moe + sls_bank,
                                      name="steady-state-lm")


def _steps(prog: EmbeddingProgram, n: int) -> list:
    """n identical-shape steps with fresh index values (fixed-batch decode:
    the shapes are steady, the lookups are not).  Tables are converted to
    device arrays ONCE, shared by every step — exactly where a model's
    params live; what the per-call fused path then pays is the host
    round trip of re-stacking them."""
    import jax.numpy as jnp
    base = make_program_inputs(prog, seed=0)
    for name in base:
        for k in ("table", "x"):
            if k in base[name]:
                base[name][k] = jnp.asarray(base[name][k])
    rng = np.random.default_rng(1)
    steps = []
    for _ in range(n):
        ins = {name: dict(per_op) for name, per_op in base.items()}
        for name in ins:
            if "idxs" in ins[name]:
                idxs = ins[name]["idxs"].copy()
                rng.shuffle(idxs)
                ins[name]["idxs"] = idxs
        steps.append(ins)
    return steps


def _time_variants(variants: dict, steps, repeats: int = 3) -> dict:
    """Interleaved best-of-N per-step times.

    All variants warm first, then the repeats alternate across variants and
    each takes its minimum: one-off noise (GC, lazy jit admin) is absorbed
    by the extra rounds, and slow machine-load drift hits every variant
    equally instead of whichever happened to run last — the two effects
    that used to make same-cost variants rank-unstable at small step
    counts."""
    for fn in variants.values():
        fn(steps[:1])              # warm the jit caches out of the timing
    best = {k: float("inf") for k in variants}
    for _ in range(repeats):
        for k, fn in variants.items():
            t0 = time.perf_counter()
            fn(steps)
            best[k] = min(best[k],
                          (time.perf_counter() - t0) * 1e6 / len(steps))
    return best


def run_variants(fast: bool, n_steps: int) -> dict:
    import jax
    prog = _program(fast)
    steps = _steps(prog, n_steps)

    pres = compile_program(prog, "O3", use_cache=False)

    # all variants run the same execute unit (the backend_jax XLA path — the
    # production path on non-TPU hosts) so the ablation isolates exactly
    # what this PR changes: marshal strategy and cross-step overlap.
    def per_op(batch):
        for ins in batch:
            outs = {n: backend_jax.execute(op, ins[n]) for n, op in prog.ops}
            jax.block_until_ready(outs)

    def fused_percall(batch):
        for ins in batch:          # PR 1: host re-stack + re-merge per step
            outs = {}
            for unit in pres.units:
                if unit.group is None:
                    outs[unit.names[0]] = backend_jax.execute(
                        unit.result.op, ins[unit.names[0]])
                else:
                    fused = backend_jax.execute(
                        unit.group.op, fuse_inputs(unit.group, ins))
                    outs.update(split_outputs(unit.group, fused))
            jax.block_until_ready(outs)

    ex_sync = ProgramExecutor(pres, backend="jax")

    def executor_cached(batch):
        for ins in batch:
            ex_sync.step(ins)

    ex_async = ProgramExecutor(pres, depth=2, backend="jax")

    def executor_overlap(batch):
        ex_async.run_steps(batch)

    variants = {"per_op": per_op, "fused_percall": fused_percall,
                "executor_cached": executor_cached,
                "executor_overlap": executor_overlap}
    out = _time_variants(variants, steps)

    # the overlap pipeline must never lose to the synchronous consume: its
    # only extra work is slot bookkeeping, amortized by the depth+1 scratch
    # rotation — anything past noise (5%) is a regression.
    assert out["executor_overlap"] <= out["executor_cached"] * 1.05, \
        (f"cross-step overlap regressed: overlap "
         f"{out['executor_overlap']:.1f}us vs cached "
         f"{out['executor_cached']:.1f}us")

    # partitioner audit: every fused group's estimated working set fits
    budget = cost_model.FusionBudget()
    audit = []
    for u in pres.fused_units:
        res = cost_model.fused_plan_resources(u.group.member_ops,
                                              vlen=pres.vlen)
        bal = res["queue_balance"]
        audit.append({"members": list(u.names),
                      "vmem_bytes": int(res["vmem_bytes"]),
                      # inf = a store-stream plan (no execute-unit work);
                      # JSON has no Infinity, so report null
                      "queue_balance": round(bal, 2)
                      if np.isfinite(bal) else None})
        assert res["vmem_bytes"] <= budget.vmem_bytes, \
            f"fused group {u.names} exceeds the VMEM budget"
    return {
        "config": {"fast": fast, "steps": n_steps, "backend": "jax",
                   "ops": len(prog.ops), "units": len(pres.units),
                   "fused_units": len(pres.fused_units)},
        "us_per_step": {k: round(v, 1) for k, v in out.items()},
        "overlap_vs_cached": round(out["executor_cached"] /
                                   out["executor_overlap"], 3),
        "speedup_vs_fused_percall": {
            k: round(out["fused_percall"] / v, 2) for k, v in out.items()},
        "speedup_vs_per_op": {
            k: round(out["per_op"] / v, 2) for k, v in out.items()},
        "executor_stats": dict(ex_async.stats),
        "partitioner": {"budget_vmem_bytes": budget.vmem_bytes,
                        "groups": audit},
    }


def run(report, fast: bool = True, n_steps: int = 3,
        out_path: Path = DEFAULT_OUT) -> dict:
    rec = run_variants(fast, n_steps)
    for k, v in rec["us_per_step"].items():
        report(f"steady_state/{k}_us", v,
               rec["speedup_vs_fused_percall"][k])
    out_path.write_text(json.dumps(rec, indent=2))
    report("steady_state/json", 0, str(out_path))
    return rec


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fast", action="store_true",
                    help="smoke sizes (tier1.sh --fast)")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--out", type=Path, default=DEFAULT_OUT)
    args = ap.parse_args()
    n = args.steps or (3 if args.fast else 8)

    def report(name, us, derived):
        print(f"{name},{us:.1f},{derived}", flush=True)

    rec = run(report, fast=args.fast, n_steps=n, out_path=args.out)
    slow = rec["us_per_step"]["fused_percall"]
    best = min(rec["us_per_step"]["executor_cached"],
               rec["us_per_step"]["executor_overlap"])
    print(f"steady-state executor speedup over per-call fused path: "
          f"{slow / best:.2f}x")


if __name__ == "__main__":
    main()
