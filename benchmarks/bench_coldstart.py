"""Cold-start TTFT: boot by loading, not compiling (PR-10 tentpole).

Three boots of the same embedding program, each measured as *time to
first token* (executor construction through the first step's outputs
materialized on the host):

* **cold** — a fresh process pointed at an empty ``--artifact-dir``:
  pays PassManager + trace + XLA compile, then publishes the serving
  artifact (``core/artifact.py``) the way a first production boot would.
* **warm cache** — a second boot *in the same process*: the executor/
  compile LRU caches and jit traces are already hot.  The in-process
  ceiling the artifact is trying to approach from a cold process.
* **artifact** — a fresh process pointed at the artifact the cold boot
  published: the compile payload hydrates the compile cache and the
  serialized XLA executables deserialize instead of tracing.  Required:
  ``compile_source == "artifact"``, zero AOT compiles, outputs
  bit-identical to the cold boot, and TTFT >= 3x faster than cold.

Each boot runs in its own subprocess (re-exec of this file with
``--child``) so jit/compile caches can't leak between legs.  Writes
``BENCH_coldstart.json``; registered in ``benchmarks/run.py`` as
``coldstart``.  Gated in CI: ``artifact_boot.ttft_s`` direction-aware,
``artifact_boot.bit_identical`` absolutely.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import tempfile
import time
from pathlib import Path

import numpy as np

DEFAULT_OUT = Path(__file__).resolve().parent.parent / \
    "BENCH_coldstart.json"

BACKEND = "pallas"
MIN_SPEEDUP = 3.0


def _program():
    from repro.core.ops import EmbeddingOp, EmbeddingProgram
    # several distinct kernel specializations over small tables: TTFT is
    # then dominated by trace + XLA compile (what the artifact removes),
    # not by kernel execution, which these shapes keep in the ms range
    sls0 = EmbeddingOp("sls", num_segments=8, num_embeddings=256,
                       emb_len=32, avg_lookups=8, weighted=True)
    sls1 = EmbeddingOp("sls", num_segments=8, num_embeddings=128,
                       emb_len=32, avg_lookups=4)
    g0 = EmbeddingOp("gather", num_segments=4, num_embeddings=128,
                     emb_len=32, block_rows=2)
    g1 = EmbeddingOp("gather", num_segments=4, num_embeddings=64,
                     emb_len=32, block_rows=4)
    return EmbeddingProgram("bench_coldstart",
                            (("sls0", sls0), ("sls1", sls1),
                             ("g0", g0), ("g1", g1)))


def _boot_and_step(artifact_dir):
    """One boot: executor_for (artifact-hydrated when possible) + first
    step, outputs forced to host — the TTFT the serving tier pays."""
    from repro.core.executor import executor_for
    from repro.core.ops import make_program_inputs
    prog = _program()
    ins = make_program_inputs(prog, seed=0)
    t0 = time.perf_counter()
    ex = executor_for(prog, backend=BACKEND, artifact_dir=artifact_dir)
    outs = {k: np.asarray(v) for k, v in ex.step(ins).items()}
    ttft = time.perf_counter() - t0
    return ex, ins, outs, ttft


def _child(mode: str, artifact_dir: str, out_json: str) -> None:
    import jax
    from repro.core.artifact import artifact_stats
    from repro.core.executor import clear_executor_cache
    from repro.core.pipeline import clear_compile_cache

    # init the PJRT backend before the clock starts: a serving process
    # brings the runtime up at exec, long before it loads a model — the
    # artifact optimizes program compilation, not generic jax startup
    jax.numpy.zeros((1,), jax.numpy.float32).block_until_ready()

    ex, ins, outs, ttft = _boot_and_step(artifact_dir)
    rec = {"mode": mode, "ttft_s": ttft,
           "compile_source": ex.compile_source,
           "aot": dict(ex.aot.stats),
           "artifact_stats": artifact_stats()}
    if mode == "build":
        # re-save (idempotent publish) so the artifact carries the AOT
        # executables of the shapes the first step actually served
        ex.save_artifact()
        # warm-cache leg: the same boot repeated in-process — LRU caches
        # and jit traces hot, the ceiling the artifact boot approaches
        clear_executor_cache()   # marshal caches re-key; compile cache +
        clear_compile_cache()    # jit traces are what stay genuinely warm
        _, _, outs2, warm = _boot_and_step(None)
        assert all(np.array_equal(outs[k], outs2[k]) for k in outs)
        rec["warm_cache_ttft_s"] = warm
    np.savez(Path(out_json).with_suffix(".npz"), **outs)
    Path(out_json).write_text(json.dumps(rec))


def _spawn_child(mode: str, artifact_dir: Path, tag: Path) -> dict:
    out_json = tag.with_suffix(".json")
    env = dict(os.environ)
    src = str(Path(__file__).resolve().parent.parent / "src")
    pp = env.get("PYTHONPATH", "")
    if src not in pp.split(os.pathsep):
        env["PYTHONPATH"] = f"{src}{os.pathsep}{pp}" if pp else src
    subprocess.run(
        [sys.executable, str(Path(__file__).resolve()), "--child", mode,
         "--dir", str(artifact_dir), "--child-out", str(out_json)],
        env=env, check=True)
    return json.loads(out_json.read_text())


def run_coldstart(fast: bool) -> dict:
    repeats = 1 if fast else 3
    colds, warms, arts = [], [], []
    with tempfile.TemporaryDirectory(prefix="coldstart_") as td:
        td = Path(td)
        for i in range(repeats):
            adir = td / f"artifact_{i}"
            build = _spawn_child("build", adir, td / f"build_{i}")
            load = _spawn_child("load", adir, td / f"load_{i}")
            assert load["compile_source"] == "artifact", load
            assert load["aot"]["compiles"] == 0, \
                f"artifact boot re-traced: {load['aot']}"
            assert load["aot"]["loads"] >= 1, load["aot"]
            colds.append(build["ttft_s"])
            warms.append(build["warm_cache_ttft_s"])
            arts.append(load["ttft_s"])
            with np.load(td / f"build_{i}.npz") as a, \
                    np.load(td / f"load_{i}.npz") as b:
                assert sorted(a.files) == sorted(b.files)
                bit_identical = all(np.array_equal(a[k], b[k])
                                    for k in a.files)
            assert bit_identical, \
                "artifact-loaded outputs diverged from fresh compile"
        cold = float(np.median(colds))
        warm = float(np.median(warms))
        art = float(np.median(arts))
        speedup = cold / art
        assert speedup >= MIN_SPEEDUP, \
            f"artifact boot only {speedup:.2f}x faster than cold " \
            f"(need >= {MIN_SPEEDUP}x)"
        return {"config": {"fast": fast, "backend": BACKEND,
                           "program": "bench_coldstart", "ops": 4,
                           "repeats": repeats,
                           "min_speedup": MIN_SPEEDUP},
                "cold_boot": {"ttft_s": round(cold, 4)},
                "warm_cache_boot": {"ttft_s": round(warm, 4)},
                "artifact_boot": {
                    "ttft_s": round(art, 4),
                    "bit_identical": int(bit_identical),
                    "compile_source": "artifact",
                    "aot_loaded": int(load["aot"]["loads"]),
                    "aot_compiles": int(load["aot"]["compiles"]),
                    "speedup_vs_cold": round(speedup, 2)}}


def run(report, fast: bool = True, out_path: Path = DEFAULT_OUT) -> dict:
    rec = run_coldstart(fast)
    report("coldstart/cold_boot_s", rec["cold_boot"]["ttft_s"] * 1e6,
           "fresh process, empty artifact dir")
    report("coldstart/warm_cache_s",
           rec["warm_cache_boot"]["ttft_s"] * 1e6, "in-process re-boot")
    ab = rec["artifact_boot"]
    report("coldstart/artifact_boot_s", ab["ttft_s"] * 1e6,
           f"speedup={ab['speedup_vs_cold']}x "
           f"bit_identical={ab['bit_identical']} "
           f"aot_loaded={ab['aot_loaded']}")
    out_path.write_text(json.dumps(rec, indent=2))
    report("coldstart/json", 0, str(out_path))
    return rec


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fast", action="store_true",
                    help="smoke sizes (tier1.sh --fast)")
    ap.add_argument("--out", type=Path, default=DEFAULT_OUT)
    ap.add_argument("--child", default=None, metavar="MODE",
                    help=argparse.SUPPRESS)   # internal re-exec hook
    ap.add_argument("--dir", default=None, help=argparse.SUPPRESS)
    ap.add_argument("--child-out", default=None, help=argparse.SUPPRESS)
    args = ap.parse_args()
    if args.child is not None:
        _child(args.child, args.dir, args.child_out)
        return

    def report(name, us, derived):
        print(f"{name},{us:.1f},{derived}", flush=True)

    rec = run(report, fast=args.fast, out_path=args.out)
    print(f"coldstart: cold {rec['cold_boot']['ttft_s']:.3f}s -> "
          f"artifact {rec['artifact_boot']['ttft_s']:.3f}s "
          f"({rec['artifact_boot']['speedup_vs_cold']}x, "
          f"bit_identical={rec['artifact_boot']['bit_identical']})")


if __name__ == "__main__":
    main()
