"""Fig 18 reproduction: block-sparse attention APKE (accesses per kilo
element) under the model-specific optimizations (§7.4).

The paper shows that serving highly-reused blocks from L2 filters 50–74% of
LLC accesses, improving with block size.  The TPU analogue keeps revisited
blocks VMEM-resident (DESIGN.md §2): consecutive grid steps that hit the
same table block skip the re-fetch.  We measure exactly that filtering on
BigBird-style traces: fraction of block fetches eliminated by
residency, per block size — same trend, same mechanism."""
from __future__ import annotations

import numpy as np

from repro.core.ops import EmbeddingOp, make_inputs
from repro.core.pipeline import compile_op, run_interpreted


def _bigbird_trace(num_queries, num_blocks, window=3, n_random=2, seed=0):
    """Per query: a local window of blocks + global block 0 + random blocks
    (BigBird's local+global+random pattern) — flattened access trace."""
    rng = np.random.default_rng(seed)
    trace = []
    for q in range(num_queries):
        base = (q * num_blocks) // num_queries
        for w in range(-(window // 2), window // 2 + 1):
            trace.append((base + w) % num_blocks)
        trace.append(0)
        trace.extend(rng.integers(0, num_blocks, n_random).tolist())
    return np.array(trace, np.int64)


def run(report):
    num_blocks = 256
    for block_rows in (1, 2, 4, 8):
        trace = _bigbird_trace(512, num_blocks, seed=block_rows)
        total = len(trace)
        # VMEM residency filter: a fetch is skipped if the same block was
        # touched in the previous step (pipeline revisit), or lives in the
        # small resident set (8 hot blocks — global + local window)
        resident: list = []
        fetches = 0
        for b in trace:
            if b in resident:
                resident.remove(b)
                resident.append(b)  # LRU refresh
                continue
            fetches += 1
            resident.append(b)
            if len(resident) > 8:
                resident.pop(0)
        filtered = 1 - fetches / total
        elems = total * block_rows * 64
        apke_base = total / (elems / 1000)
        apke_opt = fetches / (elems / 1000)
        report(f"blocksparse/bs{block_rows}/apke_unopt", 0,
               round(apke_base, 2))
        report(f"blocksparse/bs{block_rows}/apke_resident", 0,
               round(apke_opt, 2))
        report(f"blocksparse/bs{block_rows}/filtered_pct", 0,
               round(100 * filtered, 1))

    # the store-stream path itself: emb-opt3 gather is fully offloaded
    op = EmbeddingOp("gather", num_segments=64, num_embeddings=num_blocks,
                     emb_len=64, block_rows=4)
    ins = make_inputs(op, seed=1)
    _, s0 = run_interpreted(compile_op(op, "O2"), ins, "dlc",
                            return_queues=True)
    _, s3 = run_interpreted(compile_op(op, "O3"), ins, "dlc",
                            return_queues=True)
    report("blocksparse/store_stream_queue_items_O2", 0, s0["data_pushed"])
    report("blocksparse/store_stream_queue_items_O3", 0, s3["data_pushed"])
