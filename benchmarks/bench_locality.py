"""Locality-aware hot/cold vocab sharding on a Zipf workload: the PR-4
access-plan ablation.

A DLRM-style bank of SLS tables serves a *stationary* Zipf(1.05) lookup
stream (the paper's high-locality class; DRM-style skew) through the
steady-state executor three ways on a 2-device mesh:

    replicated      no mesh — every device holds the full stacked tables
    interleaved     PR-3 vocab sharding: every row ceil-split over the
                    shards, EVERY lookup routed to its owning shard
    hot_cold        PR-4 AccessPlan sharding: the Zipf head of each vocab
                    (classified from a calibration trace by
                    ``data/locality.py`` reuse scores, sized to
                    ``FusionBudget.hot_slab_bytes``) is replicated on every
                    shard — those lookups stay local — while the tail stays
                    interleave-sharded

All three must produce identical outputs (atol 1e-5).  The point of the
benchmark: the routed exchange volume (indices out) of ``hot_cold`` must be
>= 2x smaller than ``interleaved`` on the skewed stream, for a hot slab
costing a small fraction of the table bytes.  Records per-variant us/step,
measured + estimated exchange bytes, and the hot-slab audit into
``BENCH_locality.json``.

On a single-device host ``main()`` re-execs itself in a subprocess with a
forced 2-device CPU platform (the env mutation never touches this
process — see ``benchmarks/_mesh.respawn_with_devices``, shared with
``bench_sharded`` and the 2-device tests).  Under ``benchmarks/run.py``
a 1-device host skips with a report line.  The executors run the default
(collective) exchange; the recorded ``exchange_index_bytes`` are the
*wire* volume of the all_to_all send lattice — hot lookups sit on its
diagonal, which is exactly why the hot/cold reduction shows up there.

The *non-stationary* leg (the adaptive-locality ablation) rotates the
Zipf head to a disjoint row set every ``rotate_every`` steps and runs the
same hot/cold layout two ways: a static slab classified once from the
phase-0 calibration trace, and an adaptive executor
(``AdaptiveHotConfig``) whose sliding-window re-classifier swaps the slab
in place when the windowed hot hit-rate collapses.  Asserted: the static
slab's routed exchange degrades >= 4x off its stationary optimum while
the adaptive slab stays within 2x of it; every step stays allclose to the
replicated oracle; the first step after each swap is bit-identical to a
cold-built executor holding the same hot set AND allclose to the DLC
interpreter oracle.  The leg also covers ``exchange="host"``, a
spill-routing probe on a source-skewed stream, and a 1-replica
disaggregated pool whose warm artifact is republished on swap.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

DEFAULT_OUT = Path(__file__).resolve().parent.parent / "BENCH_locality.json"

ZIPF_ALPHA = 1.05
HOT_ROW_FRACTION = 8       # hot slab budget = rows/8 per table


def _respawn(devices: int) -> int:
    try:
        from ._mesh import respawn_with_devices
    except ImportError:
        from _mesh import respawn_with_devices
    return respawn_with_devices(devices)


def _zipf_sampler(rows: int, seed: int):
    """A stationary Zipf(1.05) row distribution: ONE permutation maps ranks
    to rows for the whole workload (steps and calibration draw from the
    same skewed head — the serving reality hot/cold sharding exploits)."""
    import numpy as np
    rng = np.random.default_rng(seed)
    perm = rng.permutation(rows)
    p = np.arange(1, rows + 1, dtype=np.float64) ** (-ZIPF_ALPHA)
    p /= p.sum()

    def draw(step_rng, n):
        return perm[step_rng.choice(rows, size=n, p=p)].astype(np.int32)

    return draw


def _drifting_sampler(rows: int, seed: int, rotate_every: int):
    """A Zipf(1.05) distribution whose head *rotates*: each phase of
    ``rotate_every`` steps maps ranks to rows through the base permutation
    cyclically shifted by ``3/8`` of the vocab — successive heads (the top
    ``rows/8``) land on pairwise-disjoint row sets, so a slab classified in
    one phase is stone cold in the next (the drift the adaptive
    re-classifier must absorb)."""
    import numpy as np
    rng = np.random.default_rng(seed)
    perm = rng.permutation(rows)
    p = np.arange(1, rows + 1, dtype=np.float64) ** (-ZIPF_ALPHA)
    p /= p.sum()

    def draw(step_rng, n, step):
        phase = step // rotate_every
        shifted = np.roll(perm, -(phase * (rows * 3 // 8)) % rows)
        return shifted[step_rng.choice(rows, size=n, p=p)].astype(np.int32)

    return draw


def build_drifting_workload(fast: bool, n_steps: int, rotate_every: int,
                            seed: int = 0):
    """(program, drifting steps, phase-0 calibration traces, skewed steps).

    Same table bank as :func:`build_workload` but a denser stream (more
    lookups per segment: the windowed re-classifier ranks the head from a
    few steps of counts, so each window must actually sample it) drawn
    from :func:`_drifting_sampler`.  The skewed steps put ~all lookups in
    the first half of the *tables* — a lookup's source shard is its fused
    segment slice, so that is the source imbalance that trips the spill
    router's lattice-diagonal overload check."""
    import numpy as np

    from repro.core.ops import EmbeddingOp, EmbeddingProgram

    if fast:
        n_tbl, segs, rows, d, avg = 2, 16, 2048, 64, 32
    else:
        n_tbl, segs, rows, d, avg = 4, 32, 8192, 64, 32
    prog = EmbeddingProgram("drift", tuple(
        (f"tbl{i}", EmbeddingOp("sls", segs, rows, d, avg_lookups=avg))
        for i in range(n_tbl)))

    rng = np.random.default_rng(seed)
    samplers = {name: _drifting_sampler(op.num_embeddings, seed + 17 * i,
                                        rotate_every)
                for i, (name, op) in enumerate(prog.ops)}
    tables = {name: rng.standard_normal(
        (op.num_embeddings, op.emb_len)).astype(np.float32)
        for name, op in prog.ops}

    def make_step(t, skew=False):
        ins = {}
        for i, (name, op) in enumerate(prog.ops):
            if skew:
                heavy = i < len(prog.ops) // 2
                lens = np.full(op.num_segments,
                               op.avg_lookups * 3 if heavy else 1, np.int64)
            else:
                lens = rng.poisson(op.avg_lookups, size=op.num_segments)
            ptrs = np.zeros(op.num_segments + 1, np.int64)
            np.cumsum(lens, out=ptrs[1:])
            ins[name] = {"table": tables[name], "ptrs": ptrs,
                         "idxs": samplers[name](rng, int(ptrs[-1]), t)}
        return ins

    steps = [make_step(t) for t in range(n_steps)]
    skewed = [make_step(0, skew=True) for _ in range(8)]
    cal_rng = np.random.default_rng(seed + 999)   # held-out, phase 0
    traces = {name: samplers[name](cal_rng, 20_000, 0)
              for name, _ in prog.ops}
    return prog, steps, traces, skewed


def build_workload(fast: bool, n_steps: int, seed: int = 0):
    """(program, steps, calibration traces): shared tables once, fresh
    Zipf index streams per step, and a held-out calibration trace per op."""
    import numpy as np

    from repro.core.ops import EmbeddingOp, EmbeddingProgram

    if fast:
        n_tbl, segs, rows, d, avg = 2, 16, 2048, 64, 8
    else:
        n_tbl, segs, rows, d, avg = 4, 32, 8192, 64, 8
    prog = EmbeddingProgram("locality", tuple(
        (f"tbl{i}", EmbeddingOp("sls", segs, rows, d, avg_lookups=avg))
        for i in range(n_tbl)))

    rng = np.random.default_rng(seed)
    samplers = {name: _zipf_sampler(op.num_embeddings, seed + 17 * i)
                for i, (name, op) in enumerate(prog.ops)}
    tables = {name: rng.standard_normal(
        (op.num_embeddings, op.emb_len)).astype(np.float32)
        for name, op in prog.ops}

    steps = []
    for _ in range(n_steps):
        ins = {}
        for name, op in prog.ops:
            lens = rng.poisson(op.avg_lookups, size=op.num_segments)
            ptrs = np.zeros(op.num_segments + 1, np.int64)
            np.cumsum(lens, out=ptrs[1:])
            ins[name] = {"table": tables[name], "ptrs": ptrs,
                         "idxs": samplers[name](rng, int(ptrs[-1]))}
        steps.append(ins)

    cal_rng = np.random.default_rng(seed + 999)   # held-out calibration
    traces = {name: samplers[name](cal_rng, 20_000) for name, _ in prog.ops}
    return prog, steps, traces


def run_variants(fast: bool, n_steps: int) -> dict:
    import jax
    import numpy as np

    from repro.core import access_plan as ap
    from repro.core import cost_model
    from repro.core.executor import ProgramExecutor
    from repro.core.pipeline import compile_program
    from repro.launch.mesh import axis_types_kw

    try:
        from . import bench_steady_state as bss
    except ImportError:
        import bench_steady_state as bss

    shards = min(2, len(jax.devices()))
    assert shards >= 2, "bench_locality needs >= 2 devices (see main())"
    mesh = jax.make_mesh((1, shards), ("data", "model"),
                         **axis_types_kw(2))

    prog, steps, traces = build_workload(fast, n_steps)
    op0 = prog.ops[0][1]
    hot_slab_bytes = (op0.num_embeddings // HOT_ROW_FRACTION) * \
        op0.emb_len * 4
    budget_hot = cost_model.FusionBudget(shards=shards,
                                         hot_slab_bytes=hot_slab_bytes)
    hot = ap.hot_rows_from_traces(prog, traces, budget_hot)
    assert hot, "the Zipf stream must classify a hot head"

    # same execute unit everywhere (backend_jax XLA path): the ablation
    # isolates the access-plan layout + exchange, not the kernel
    repl = ProgramExecutor(compile_program(prog, "O3", use_cache=False),
                           backend="jax")
    inter = ProgramExecutor(
        compile_program(prog, "O3", use_cache=False,
                        budget=cost_model.FusionBudget(shards=shards)),
        backend="jax", mesh=mesh)
    hotx = ProgramExecutor(
        compile_program(prog, "O3", use_cache=False, budget=budget_hot,
                        hot_rows=hot),
        backend="jax", mesh=mesh, hot_rows=hot)

    # numeric identity on every step: replication must be invisible
    for k, ins in enumerate(steps):
        want = repl.step(ins)
        got_i, got_h = inter.step(ins), hotx.step(ins)
        for n in want:
            np.testing.assert_allclose(
                np.asarray(got_i[n]), np.asarray(want[n]),
                rtol=1e-5, atol=1e-5, err_msg=f"interleaved {n} step {k}")
            np.testing.assert_allclose(
                np.asarray(got_h[n]), np.asarray(want[n]),
                rtol=1e-5, atol=1e-5, err_msg=f"hot_cold {n} step {k}")

    # routed exchange volume (indices out), measured per step
    steps_run = inter.stats["steps"]
    idx_inter = inter.stats["exchange_index_bytes"] // steps_run
    idx_hot = hotx.stats["exchange_index_bytes"] // steps_run
    reduction = idx_inter / max(idx_hot, 1)
    assert reduction >= 2.0, \
        (f"hot/cold sharding must cut routed exchange bytes >= 2x on "
         f"Zipf({ZIPF_ALPHA}): interleaved {idx_inter} vs hot {idx_hot} "
         f"B/step ({reduction:.2f}x)")

    aps = hotx.access_plan_stats()
    hot_frac = aps["hot_traffic_fraction"]
    audit = []
    for u in hotx._units:
        if u.group is None:
            continue
        # the executors run the collective exchange with reduce-scattered
        # outputs (the >=2-shard default), so estimate that link model —
        # keeps exchange_bytes_est comparable to the measured counters
        res = cost_model.fused_plan_resources(
            u.group.member_ops, vlen=hotx.compiled.vlen, shards=shards,
            hot_rows_total=u.plan.hot_rows_total,
            hot_traffic_fraction=hot_frac,
            replicate_outputs=False, collective=True)
        audit.append({
            "members": list(u.unit.names),
            "hot_rows": u.plan.hot_rows_total,
            "hot_slab_bytes": int(res["hot_slab_bytes"]),
            "table_bytes_per_shard": int(res["table_bytes_per_shard"]),
            "exchange_bytes_est": int(res["exchange_bytes"]),
            "exchange_savings_bytes_est": int(
                res["exchange_savings_bytes"]),
        })

    out = bss._time_variants({
        "replicated": lambda b: [repl.step(i) for i in b],
        "interleaved": lambda b: [inter.step(i) for i in b],
        "hot_cold": lambda b: [hotx.step(i) for i in b],
    }, steps, repeats=5)

    return {
        "config": {"fast": fast, "steps": n_steps, "backend": "jax",
                   "shards": shards, "zipf_alpha": ZIPF_ALPHA,
                   "ops": len(prog.ops),
                   "hot_slab_budget_bytes": hot_slab_bytes},
        "us_per_step": {k: round(v, 1) for k, v in out.items()},
        "exchange_index_bytes_per_step": {
            "interleaved": int(idx_inter),
            "hot_cold": int(idx_hot),
            "reduction": round(reduction, 2),
        },
        "hot_traffic_fraction": hot_frac,
        "access_plans": aps,
        "hot_slab_audit": audit,
    }


ROTATE_EVERY = 24          # drift phase length (steps) of the adaptive leg
DRIFT_PHASES = 3


def _adaptive_cfg(**over):
    from repro.data.locality import AdaptiveHotConfig
    kw = dict(window_steps=6, num_windows=3, drift_threshold=0.7,
              min_swap_interval=8, spill_fraction=0.0, refine_passes=1)
    kw.update(over)
    return AdaptiveHotConfig(**kw)


def run_non_stationary(fast: bool) -> dict:
    """The adaptive-locality ablation under a rotating Zipf head (see the
    module docstring).  Returns the ``non_stationary`` record."""
    import jax
    import numpy as np

    from repro.core import access_plan as ap
    from repro.core import cost_model
    from repro.core.executor import ProgramExecutor
    from repro.core.pipeline import compile_program, run_program_interpreted
    from repro.launch.mesh import axis_types_kw

    shards = min(2, len(jax.devices()))
    assert shards >= 2, "bench_locality needs >= 2 devices (see main())"
    mesh = jax.make_mesh((1, shards), ("data", "model"),
                         **axis_types_kw(2))
    n_steps = ROTATE_EVERY * DRIFT_PHASES
    prog, steps, traces, skewed = build_drifting_workload(
        fast, n_steps, ROTATE_EVERY)
    op0 = prog.ops[0][1]
    hot_slab_bytes = (op0.num_embeddings // HOT_ROW_FRACTION) * \
        op0.emb_len * 4
    budget_hot = cost_model.FusionBudget(shards=shards,
                                         hot_slab_bytes=hot_slab_bytes)
    hot = ap.hot_rows_from_traces(prog, traces, budget_hot)
    assert hot, "phase 0 must classify a hot head"

    acfg = _adaptive_cfg()
    repl = ProgramExecutor(compile_program(prog, "O3", use_cache=False),
                           backend="jax")
    chot = compile_program(prog, "O3", use_cache=False, budget=budget_hot,
                           hot_rows=hot)
    static = ProgramExecutor(chot, backend="jax", mesh=mesh, hot_rows=hot)
    adapt = ProgramExecutor(chot, backend="jax", mesh=mesh, hot_rows=hot,
                            adaptive=acfg)
    hostx = ProgramExecutor(chot, backend="jax", mesh=mesh, hot_rows=hot,
                            exchange="host", adaptive=acfg)

    opt_static = opt_adapt = None
    prev_epoch, oracle_checks, pending_oracle = 0, 0, False
    for t, ins in enumerate(steps):
        want = {n: np.asarray(v) for n, v in repl.step(ins).items()}
        got_s, got_a = static.step(ins), adapt.step(ins)
        got_h = hostx.step(ins)
        for n in want:
            for tag, got in (("static", got_s), ("adaptive", got_a),
                             ("adaptive_host", got_h)):
                np.testing.assert_allclose(
                    np.asarray(got[n]), want[n], rtol=1e-5, atol=1e-5,
                    err_msg=f"{tag} {n} step {t}")
        if pending_oracle and oracle_checks < 4:
            # first step on the swapped slab: the no-recompile swap path
            # must land exactly where a cold build with the same hot set
            # lands (bit-identical), and match the DLC interpreter oracle
            cold = ProgramExecutor(chot, backend="jax", mesh=mesh,
                                   hot_rows=dict(adapt.hot_rows))
            cold_out = cold.step(ins)
            interp = run_program_interpreted(repl.compiled, ins)
            for n in want:
                np.testing.assert_array_equal(
                    np.asarray(got_a[n]), np.asarray(cold_out[n]),
                    err_msg=f"swap != cold path: {n} step {t}")
                np.testing.assert_allclose(
                    np.asarray(got_a[n]), np.asarray(interp[n]),
                    rtol=1e-5, atol=1e-5,
                    err_msg=f"swap vs interpreter oracle: {n} step {t}")
            oracle_checks += 1
        pending_oracle = adapt.slab_epoch > prev_epoch
        prev_epoch = adapt.slab_epoch
        if t == ROTATE_EVERY - 1:
            # end of the stationary phase: this is the layout's optimum
            opt_static = static.stats["exchange_index_bytes"]
            opt_adapt = adapt.stats["exchange_index_bytes"]

    post = n_steps - ROTATE_EVERY
    opt_per_step = opt_static / ROTATE_EVERY
    static_post = (static.stats["exchange_index_bytes"] - opt_static) / post
    adapt_post = (adapt.stats["exchange_index_bytes"] - opt_adapt) / post
    static_deg = static_post / max(opt_per_step, 1)
    adapt_ratio = adapt_post / max(opt_per_step, 1)
    post_hot_frac = adapt.window_stats()["hot_traffic_fraction"]
    assert adapt.stats["hot_swaps"] >= 2, adapt.stats["hot_swaps"]
    assert hostx.stats["hot_swaps"] >= 2, hostx.stats["hot_swaps"]
    assert oracle_checks >= 1
    assert static_deg >= 4.0, \
        (f"static slab must degrade >= 4x under head rotation, got "
         f"{static_deg:.2f}x ({static_post:.0f} vs {opt_per_step:.0f} "
         f"B/step)")
    assert adapt_ratio <= 2.0, \
        (f"adaptive slab must stay within 2x of the stationary optimum, "
         f"got {adapt_ratio:.2f}x ({adapt_post:.0f} vs {opt_per_step:.0f} "
         f"B/step)")

    # spill probe: a source-skewed stationary stream overloads shard 0's
    # lattice diagonal; the router spills a bounded fraction of its hot
    # lookups to the lighter peer, outputs unchanged
    spillx = ProgramExecutor(
        chot, backend="jax", mesh=mesh, hot_rows=hot,
        adaptive=_adaptive_cfg(drift_threshold=0.05, min_swap_interval=10**6,
                               spill_fraction=0.25, spill_overload=1.2,
                               refine_passes=0))
    for t, ins in enumerate(skewed):
        want = repl.step(ins)
        got = spillx.step(ins)
        for n in want:
            np.testing.assert_allclose(
                np.asarray(got[n]), np.asarray(want[n]),
                rtol=1e-5, atol=1e-5, err_msg=f"spill {n} step {t}")
    assert spillx.stats["spilled_lookups"] > 0, \
        "the skewed stream must trip the spill router"
    assert spillx.stats["hot_swaps"] == 0

    disagg = _run_disagg_drift(prog, steps[:ROTATE_EVERY + 16], hot)

    return {
        "rotate_every": ROTATE_EVERY,
        "phases": DRIFT_PHASES,
        "steps": n_steps,
        "adaptive_config": {
            "window_steps": acfg.window_steps,
            "num_windows": acfg.num_windows,
            "drift_threshold": acfg.drift_threshold,
            "min_swap_interval": acfg.min_swap_interval,
            "refine_passes": acfg.refine_passes,
        },
        "stationary_optimum_bytes_per_step": int(opt_per_step),
        "static_routed_bytes_per_step": int(static_post),
        "adaptive_routed_bytes_per_step": int(adapt_post),
        "static_degradation": round(static_deg, 2),
        "adaptive_ratio": round(adapt_ratio, 2),
        "hot_swaps": adapt.stats["hot_swaps"],
        "host_hot_swaps": hostx.stats["hot_swaps"],
        "swap_oracle_checks": oracle_checks,
        "post_drift_hot_hit_rate": post_hot_frac,
        "spill_probe": {
            "steps": len(skewed),
            "spilled_lookups": spillx.stats["spilled_lookups"],
        },
        "disagg": disagg,
    }


def _run_disagg_drift(prog, steps, hot) -> dict:
    """Drifting stream against a 1-replica disaggregated pool: the client
    detects the drift from its own index streams, swaps its local slab,
    and propagates it by republishing the warm artifact + a 'hot'
    broadcast — observable as the replica's ping-reported hot_epoch.
    Outputs stay bit-identical to the in-process executor."""
    import numpy as np

    from repro.core.executor import ProgramExecutor
    from repro.core.pipeline import compile_program
    from repro.runtime.embedding_service import ServicePool

    ref = ProgramExecutor(compile_program(prog, "O3", use_cache=False),
                          backend="jax")
    with ServicePool(1, rpc_timeout_s=30.0, backoff_s=0.01) as pool:
        dx = ProgramExecutor(
            compile_program(prog, "O3", use_cache=False), backend="jax",
            service="disagg", service_pool=pool, hot_rows=hot,
            adaptive=_adaptive_cfg())
        for t, ins in enumerate(steps):
            want = ref.step(ins)
            got = dx.step(ins)
            for n in want:
                np.testing.assert_array_equal(
                    np.asarray(got[n]), np.asarray(want[n]),
                    err_msg=f"disagg {n} step {t}")
        assert dx.stats["hot_swaps"] >= 1, dx.stats["hot_swaps"]
        assert pool.pool_stats["hot_publishes"] >= 1
        ping = pool.replicas[0].hb.call("ping")[0]
        assert ping["hot_epoch"] == pool.pool_stats["hot_publishes"], ping
        return {
            "steps": len(steps),
            "hot_swaps": dx.stats["hot_swaps"],
            "hot_publishes": pool.pool_stats["hot_publishes"],
            "replica_hot_epoch": ping["hot_epoch"],
        }


def run(report, fast: bool = True, n_steps: int = 3,
        out_path: Path = DEFAULT_OUT) -> dict:
    import jax
    if len(jax.devices()) < 2:
        report("locality/skipped", 0, "needs >= 2 devices")
        return {}
    rec = run_variants(fast, n_steps)
    for k, v in rec["us_per_step"].items():
        report(f"locality/{k}_us", v, rec["config"]["shards"])
    report("locality/exchange_reduction", 0,
           rec["exchange_index_bytes_per_step"]["reduction"])
    report("locality/hot_traffic_fraction", 0,
           rec["hot_traffic_fraction"])
    ns = run_non_stationary(fast)
    rec["non_stationary"] = ns
    report("locality/nonstat_static_degradation", 0,
           ns["static_degradation"])
    report("locality/nonstat_adaptive_ratio", 0, ns["adaptive_ratio"])
    report("locality/nonstat_hot_swaps", 0, ns["hot_swaps"])
    report("locality/nonstat_post_drift_hot_fraction", 0,
           ns["post_drift_hot_hit_rate"])
    out_path.write_text(json.dumps(rec, indent=2))
    report("locality/json", 0, str(out_path))
    return rec


def main() -> None:
    ap_ = argparse.ArgumentParser(description=__doc__)
    ap_.add_argument("--fast", action="store_true",
                     help="smoke sizes (tier1.sh --fast)")
    ap_.add_argument("--steps", type=int, default=None)
    ap_.add_argument("--devices", type=int, default=2,
                     help="forced CPU device count (default 2); applied in "
                          "a respawned child process, never this one")
    ap_.add_argument("--out", type=Path, default=DEFAULT_OUT)
    ap_.add_argument("--no-respawn", action="store_true",
                     help="internal: already running with the forced "
                          "device environment")
    args = ap_.parse_args()
    if not args.no_respawn and "jax" not in sys.modules:
        sys.exit(_respawn(args.devices))
    n = args.steps or (3 if args.fast else 8)

    def report(name, us, derived):
        print(f"{name},{us:.1f},{derived}", flush=True)

    rec = run(report, fast=args.fast, n_steps=n, out_path=args.out)
    if rec:
        ex = rec["exchange_index_bytes_per_step"]
        print(f"hot/cold sharding: routed exchange "
              f"{ex['interleaved']} -> {ex['hot_cold']} B/step "
              f"({ex['reduction']:.2f}x less) with "
              f"{rec['hot_traffic_fraction']:.0%} of lookups served from "
              f"the replicated hot slab")
        ns = rec["non_stationary"]
        print(f"head rotation: static slab degrades "
              f"{ns['static_degradation']:.2f}x off its stationary "
              f"optimum; adaptive holds {ns['adaptive_ratio']:.2f}x with "
              f"{ns['hot_swaps']} live swaps and post-drift hot hit-rate "
              f"{ns['post_drift_hot_hit_rate']:.0%}")


if __name__ == "__main__":
    main()
