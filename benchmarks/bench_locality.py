"""Locality-aware hot/cold vocab sharding on a Zipf workload: the PR-4
access-plan ablation.

A DLRM-style bank of SLS tables serves a *stationary* Zipf(1.05) lookup
stream (the paper's high-locality class; DRM-style skew) through the
steady-state executor three ways on a 2-device mesh:

    replicated      no mesh — every device holds the full stacked tables
    interleaved     PR-3 vocab sharding: every row ceil-split over the
                    shards, EVERY lookup routed to its owning shard
    hot_cold        PR-4 AccessPlan sharding: the Zipf head of each vocab
                    (classified from a calibration trace by
                    ``data/locality.py`` reuse scores, sized to
                    ``FusionBudget.hot_slab_bytes``) is replicated on every
                    shard — those lookups stay local — while the tail stays
                    interleave-sharded

All three must produce identical outputs (atol 1e-5).  The point of the
benchmark: the routed exchange volume (indices out) of ``hot_cold`` must be
>= 2x smaller than ``interleaved`` on the skewed stream, for a hot slab
costing a small fraction of the table bytes.  Records per-variant us/step,
measured + estimated exchange bytes, and the hot-slab audit into
``BENCH_locality.json``.

On a single-device host ``main()`` re-execs itself in a subprocess with a
forced 2-device CPU platform (the env mutation never touches this
process — see ``benchmarks/_mesh.respawn_with_devices``, shared with
``bench_sharded`` and the 2-device tests).  Under ``benchmarks/run.py``
a 1-device host skips with a report line.  The executors run the default
(collective) exchange; the recorded ``exchange_index_bytes`` are the
*wire* volume of the all_to_all send lattice — hot lookups sit on its
diagonal, which is exactly why the hot/cold reduction shows up there.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

DEFAULT_OUT = Path(__file__).resolve().parent.parent / "BENCH_locality.json"

ZIPF_ALPHA = 1.05
HOT_ROW_FRACTION = 8       # hot slab budget = rows/8 per table


def _respawn(devices: int) -> int:
    try:
        from ._mesh import respawn_with_devices
    except ImportError:
        from _mesh import respawn_with_devices
    return respawn_with_devices(devices)


def _zipf_sampler(rows: int, seed: int):
    """A stationary Zipf(1.05) row distribution: ONE permutation maps ranks
    to rows for the whole workload (steps and calibration draw from the
    same skewed head — the serving reality hot/cold sharding exploits)."""
    import numpy as np
    rng = np.random.default_rng(seed)
    perm = rng.permutation(rows)
    p = np.arange(1, rows + 1, dtype=np.float64) ** (-ZIPF_ALPHA)
    p /= p.sum()

    def draw(step_rng, n):
        return perm[step_rng.choice(rows, size=n, p=p)].astype(np.int32)

    return draw


def build_workload(fast: bool, n_steps: int, seed: int = 0):
    """(program, steps, calibration traces): shared tables once, fresh
    Zipf index streams per step, and a held-out calibration trace per op."""
    import numpy as np

    from repro.core.ops import EmbeddingOp, EmbeddingProgram

    if fast:
        n_tbl, segs, rows, d, avg = 2, 16, 2048, 64, 8
    else:
        n_tbl, segs, rows, d, avg = 4, 32, 8192, 64, 8
    prog = EmbeddingProgram("locality", tuple(
        (f"tbl{i}", EmbeddingOp("sls", segs, rows, d, avg_lookups=avg))
        for i in range(n_tbl)))

    rng = np.random.default_rng(seed)
    samplers = {name: _zipf_sampler(op.num_embeddings, seed + 17 * i)
                for i, (name, op) in enumerate(prog.ops)}
    tables = {name: rng.standard_normal(
        (op.num_embeddings, op.emb_len)).astype(np.float32)
        for name, op in prog.ops}

    steps = []
    for _ in range(n_steps):
        ins = {}
        for name, op in prog.ops:
            lens = rng.poisson(op.avg_lookups, size=op.num_segments)
            ptrs = np.zeros(op.num_segments + 1, np.int64)
            np.cumsum(lens, out=ptrs[1:])
            ins[name] = {"table": tables[name], "ptrs": ptrs,
                         "idxs": samplers[name](rng, int(ptrs[-1]))}
        steps.append(ins)

    cal_rng = np.random.default_rng(seed + 999)   # held-out calibration
    traces = {name: samplers[name](cal_rng, 20_000) for name, _ in prog.ops}
    return prog, steps, traces


def run_variants(fast: bool, n_steps: int) -> dict:
    import jax
    import numpy as np

    from repro.core import access_plan as ap
    from repro.core import cost_model
    from repro.core.executor import ProgramExecutor
    from repro.core.pipeline import compile_program
    from repro.launch.mesh import axis_types_kw

    try:
        from . import bench_steady_state as bss
    except ImportError:
        import bench_steady_state as bss

    shards = min(2, len(jax.devices()))
    assert shards >= 2, "bench_locality needs >= 2 devices (see main())"
    mesh = jax.make_mesh((1, shards), ("data", "model"),
                         **axis_types_kw(2))

    prog, steps, traces = build_workload(fast, n_steps)
    op0 = prog.ops[0][1]
    hot_slab_bytes = (op0.num_embeddings // HOT_ROW_FRACTION) * \
        op0.emb_len * 4
    budget_hot = cost_model.FusionBudget(shards=shards,
                                         hot_slab_bytes=hot_slab_bytes)
    hot = ap.hot_rows_from_traces(prog, traces, budget_hot)
    assert hot, "the Zipf stream must classify a hot head"

    # same execute unit everywhere (backend_jax XLA path): the ablation
    # isolates the access-plan layout + exchange, not the kernel
    repl = ProgramExecutor(compile_program(prog, "O3", use_cache=False),
                           backend="jax")
    inter = ProgramExecutor(
        compile_program(prog, "O3", use_cache=False,
                        budget=cost_model.FusionBudget(shards=shards)),
        backend="jax", mesh=mesh)
    hotx = ProgramExecutor(
        compile_program(prog, "O3", use_cache=False, budget=budget_hot,
                        hot_rows=hot),
        backend="jax", mesh=mesh, hot_rows=hot)

    # numeric identity on every step: replication must be invisible
    for k, ins in enumerate(steps):
        want = repl.step(ins)
        got_i, got_h = inter.step(ins), hotx.step(ins)
        for n in want:
            np.testing.assert_allclose(
                np.asarray(got_i[n]), np.asarray(want[n]),
                rtol=1e-5, atol=1e-5, err_msg=f"interleaved {n} step {k}")
            np.testing.assert_allclose(
                np.asarray(got_h[n]), np.asarray(want[n]),
                rtol=1e-5, atol=1e-5, err_msg=f"hot_cold {n} step {k}")

    # routed exchange volume (indices out), measured per step
    steps_run = inter.stats["steps"]
    idx_inter = inter.stats["exchange_index_bytes"] // steps_run
    idx_hot = hotx.stats["exchange_index_bytes"] // steps_run
    reduction = idx_inter / max(idx_hot, 1)
    assert reduction >= 2.0, \
        (f"hot/cold sharding must cut routed exchange bytes >= 2x on "
         f"Zipf({ZIPF_ALPHA}): interleaved {idx_inter} vs hot {idx_hot} "
         f"B/step ({reduction:.2f}x)")

    aps = hotx.access_plan_stats()
    hot_frac = aps["hot_traffic_fraction"]
    audit = []
    for u in hotx._units:
        if u.group is None:
            continue
        # the executors run the collective exchange with reduce-scattered
        # outputs (the >=2-shard default), so estimate that link model —
        # keeps exchange_bytes_est comparable to the measured counters
        res = cost_model.fused_plan_resources(
            u.group.member_ops, vlen=hotx.compiled.vlen, shards=shards,
            hot_rows_total=u.plan.hot_rows_total,
            hot_traffic_fraction=hot_frac,
            replicate_outputs=False, collective=True)
        audit.append({
            "members": list(u.unit.names),
            "hot_rows": u.plan.hot_rows_total,
            "hot_slab_bytes": int(res["hot_slab_bytes"]),
            "table_bytes_per_shard": int(res["table_bytes_per_shard"]),
            "exchange_bytes_est": int(res["exchange_bytes"]),
            "exchange_savings_bytes_est": int(
                res["exchange_savings_bytes"]),
        })

    out = bss._time_variants({
        "replicated": lambda b: [repl.step(i) for i in b],
        "interleaved": lambda b: [inter.step(i) for i in b],
        "hot_cold": lambda b: [hotx.step(i) for i in b],
    }, steps, repeats=5)

    return {
        "config": {"fast": fast, "steps": n_steps, "backend": "jax",
                   "shards": shards, "zipf_alpha": ZIPF_ALPHA,
                   "ops": len(prog.ops),
                   "hot_slab_budget_bytes": hot_slab_bytes},
        "us_per_step": {k: round(v, 1) for k, v in out.items()},
        "exchange_index_bytes_per_step": {
            "interleaved": int(idx_inter),
            "hot_cold": int(idx_hot),
            "reduction": round(reduction, 2),
        },
        "hot_traffic_fraction": hot_frac,
        "access_plans": aps,
        "hot_slab_audit": audit,
    }


def run(report, fast: bool = True, n_steps: int = 3,
        out_path: Path = DEFAULT_OUT) -> dict:
    import jax
    if len(jax.devices()) < 2:
        report("locality/skipped", 0, "needs >= 2 devices")
        return {}
    rec = run_variants(fast, n_steps)
    for k, v in rec["us_per_step"].items():
        report(f"locality/{k}_us", v, rec["config"]["shards"])
    report("locality/exchange_reduction", 0,
           rec["exchange_index_bytes_per_step"]["reduction"])
    report("locality/hot_traffic_fraction", 0,
           rec["hot_traffic_fraction"])
    out_path.write_text(json.dumps(rec, indent=2))
    report("locality/json", 0, str(out_path))
    return rec


def main() -> None:
    ap_ = argparse.ArgumentParser(description=__doc__)
    ap_.add_argument("--fast", action="store_true",
                     help="smoke sizes (tier1.sh --fast)")
    ap_.add_argument("--steps", type=int, default=None)
    ap_.add_argument("--devices", type=int, default=2,
                     help="forced CPU device count (default 2); applied in "
                          "a respawned child process, never this one")
    ap_.add_argument("--out", type=Path, default=DEFAULT_OUT)
    ap_.add_argument("--no-respawn", action="store_true",
                     help="internal: already running with the forced "
                          "device environment")
    args = ap_.parse_args()
    if not args.no_respawn and "jax" not in sys.modules:
        sys.exit(_respawn(args.devices))
    n = args.steps or (3 if args.fast else 8)

    def report(name, us, derived):
        print(f"{name},{us:.1f},{derived}", flush=True)

    rec = run(report, fast=args.fast, n_steps=n, out_path=args.out)
    if rec:
        ex = rec["exchange_index_bytes_per_step"]
        print(f"hot/cold sharding: routed exchange "
              f"{ex['interleaved']} -> {ex['hot_cold']} B/step "
              f"({ex['reduction']:.2f}x less) with "
              f"{rec['hot_traffic_fraction']:.0%} of lookups served from "
              f"the replicated hot slab")


if __name__ == "__main__":
    main()
