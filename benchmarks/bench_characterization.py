"""Table 1 / Table 2 reproduction: characterization of embedding operations.

For each model class: loop structure, compute-per-lookup ratio, memory
footprint, and the reuse-distance CDF of representative inputs (synthetic
L0/L1/L2 traces following the paper's methodology — the Criteo/OGB datasets
are not redistributable offline; the CDF *shapes* match the published
curves: L2 ≫ L1 ≫ L0 ≈ flat)."""
from __future__ import annotations

import time

import numpy as np

from repro.core.ops import EmbeddingOp
from repro.data.locality import make_trace, reuse_cdf

MODELS = {
    "dlrm_sls": EmbeddingOp("sls", num_segments=64, num_embeddings=16384,
                            emb_len=64, avg_lookups=64),
    "kg": EmbeddingOp("kg", num_segments=4096, num_embeddings=100_000,
                      emb_len=512),
    "spattn": EmbeddingOp("gather", num_segments=512, num_embeddings=4096,
                          emb_len=64, block_rows=4),
    "gnn_spmm": EmbeddingOp("spmm", num_segments=2048,
                            num_embeddings=100_000, emb_len=128,
                            avg_lookups=26),
    "mp_fusedmm": EmbeddingOp("fusedmm", num_segments=2048,
                              num_embeddings=2048, emb_len=128,
                              avg_lookups=5),
}


def run(report):
    t0 = time.time()
    for name, op in MODELS.items():
        report(f"characterize/{name}/compute_per_lookup", 0,
               op.compute_per_lookup)
        report(f"characterize/{name}/footprint_MB", 0,
               round(op.footprint_bytes() / 1e6, 1))
    # reuse-distance CDFs at a 1K-vector cache (the paper's "CDF(1K) ≈ hit
    # probability of a 1MB cache with 256-f32 vectors" example)
    for loc in ("L0", "L1", "L2"):
        trace = make_trace(16384, 30_000, locality=loc, seed=1)
        xs, cdf = reuse_cdf(trace, xs=np.array([16, 128, 1024, 8192]))
        report(f"characterize/cdf_{loc}/at_1k",
               (time.time() - t0) * 1e6 / 3, round(float(cdf[2]), 3))
    # invariant from the paper: higher locality ⇒ higher CDF at every size
    t_lo = make_trace(16384, 30_000, "L0", seed=2)
    t_hi = make_trace(16384, 30_000, "L2", seed=2)
    _, c_lo = reuse_cdf(t_lo, xs=np.array([1024]))
    _, c_hi = reuse_cdf(t_hi, xs=np.array([1024]))
    report("characterize/cdf_ordering_ok", 0, int(c_hi[0] > c_lo[0]))
