"""Fig 19 reproduction: Ember-generated code vs hand-optimized DAE code.

``ref-dae`` is a hand-written DLC program per model class with the minimal
possible queue traffic (what an expert writes against the TMU directly).
Parity is measured on the two quantities that determine DAE throughput
(§8.1): data items and control tokens marshaled per operation — plus the
modeled throughput ratio.  The paper reports geomean 99%; Ember's general
optimizations reach the same queue structure, so the generated/hand ratio
here is ≥ 0.99 by construction *except* where hand code can exploit
CPU-specific token tricks the paper also excludes (§8.3).
"""
from __future__ import annotations

import numpy as np

from repro.core import cost_model as cm
from repro.core.ops import EmbeddingOp, make_inputs, reference
from repro.core.pipeline import compile_op, run_interpreted

CLASSES = {
    "sls": EmbeddingOp("sls", 16, 512, 64, avg_lookups=8),
    "kg": EmbeddingOp("kg", 64, 512, 64),
    "spmm": EmbeddingOp("spmm", 16, 512, 64, avg_lookups=8),
    "fusedmm": EmbeddingOp("fusedmm", 16, 64, 64, avg_lookups=4),
    "spattn": EmbeddingOp("gather", 32, 128, 64, block_rows=4),
}


def hand_optimal_traffic(op: EmbeddingOp, n_lookups: int, vlen: int) -> dict:
    """Queue traffic of expert-written TMU code (minimum achievable):
    bufferized whole-row marshaling, aligned output addressing, store
    streams for compute-free ops."""
    chunks = -(-op.emb_len // vlen)
    if not op.has_compute:
        return {"data": 0, "tokens": 0}  # store streams
    if op.kind == "fusedmm":
        # two buffers (x_i, x_j) per edge; one token per edge
        return {"data": n_lookups * 2 * chunks, "tokens": n_lookups}
    data = n_lookups * chunks
    if op.weighted or op.kind in ("kg", "spmm"):
        # per-lookup rescale values cannot be elided even by hand (§7.3:
        # they are padded/marshaled alongside the vectors)
        data += n_lookups
    return {"data": data, "tokens": n_lookups}


def run(report):
    ratios = []
    for name, op in CLASSES.items():
        ins = make_inputs(op, seed=3)
        res = compile_op(op, "O3", vlen=cm.VLEN)
        out, stats = run_interpreted(res, ins, "dlc", return_queues=True)
        np.testing.assert_allclose(np.asarray(out), reference(op, ins),
                                   rtol=1e-3, atol=1e-4)
        n_lookups = (len(ins["idxs"]) if "idxs" in ins
                     else op.num_segments)
        hand = hand_optimal_traffic(op, n_lookups, cm.VLEN)
        gen_cost = stats["data_pushed"] + 0.5 * stats["tokens"]
        hand_cost = hand["data"] + 0.5 * hand["tokens"]
        ratio = 1.0 if gen_cost == hand_cost == 0 else \
            min(1.0, hand_cost / max(gen_cost, 1e-9))
        ratios.append(max(ratio, 1e-3))
        report(f"vs_handopt/{name}/generated_items", 0, stats["data_pushed"])
        report(f"vs_handopt/{name}/hand_items", 0, hand["data"])
        report(f"vs_handopt/{name}/parity", 0, round(ratio, 3))
    geo = float(np.exp(np.mean(np.log(ratios))))
    report("vs_handopt/geomean_parity", 0, round(geo, 3))
    report("vs_handopt/geomean_paper", 0, 0.99)
    report("vs_handopt/ge_0_95", 0, int(geo >= 0.95))
