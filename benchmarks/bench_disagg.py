"""Disaggregated embedding tier graded as a service (PR-8 tentpole).

Three legs:

* **Bit identity** — the same program stepped through an in-process
  executor and through the disaggregated service path
  (``service="disagg"``: streams over the RPC tier, tables resident in
  the replica processes) must produce byte-identical outputs.  Asserted
  here, recorded for the gate.

* **Steady state** — median us/step of both paths on the same inputs.
  ``overhead_ratio`` (disagg/inproc) is what the submit/result overlap is
  supposed to bound: the request leaves at submit, the reply is consumed
  at result, so the extra hop hides behind the work between them.  Gated
  in CI with a loose per-metric tolerance (wall-clock ratio of two small
  numbers is noisy).

* **Kill a replica mid-load** — a continuous-batching ``DecodeServer``
  (pipeline=True) serving open-loop Poisson arrivals from a 2-replica
  pool with the heartbeat monitor armed; one replica gets SIGKILL mid
  load.  Required: every request reaches a terminal status and
  ``failed_requests == 0`` (in-wave failover + the wave watchdog's
  reset+retry absorb the crash), the pool recovers the replica via
  respawn + checkpoint re-warm, and ``recovery_s`` is recorded from the
  pool's breaker-open→probe-pass timestamps.  ``failed_requests`` is
  gated absolutely: the baseline is 0, any failure trips CI.

Writes ``BENCH_disagg.json``; registered in ``benchmarks/run.py`` as
``disagg``.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np

DEFAULT_OUT = Path(__file__).resolve().parent.parent / "BENCH_disagg.json"

ARCH = "zamba2-7b"              # single embed program: cheapest real wave

POOL_KW = dict(rpc_timeout_s=30.0, backoff_s=0.01)


def _program():
    from repro.core.ops import EmbeddingOp, EmbeddingProgram
    sls = EmbeddingOp("sls", num_segments=32, num_embeddings=2048,
                      emb_len=64, avg_lookups=16, weighted=True)
    gather = EmbeddingOp("gather", num_segments=16, num_embeddings=512,
                         emb_len=64, block_rows=2)
    return EmbeddingProgram("bench_disagg", (("sls0", sls), ("g0", gather)))


def _median_us_per_step(ex, ins, steps: int, repeats: int) -> float:
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        ex.run_steps([ins] * steps)
        ts.append((time.perf_counter() - t0) / steps * 1e6)
    return float(np.median(ts))


def _identity_and_steady(pool, fast: bool) -> tuple:
    from repro.core.executor import executor_for
    from repro.core.ops import make_program_inputs
    prog = _program()
    ins = make_program_inputs(prog, seed=0)
    steps, repeats = (8, 3) if fast else (32, 5)

    inproc = executor_for(prog, backend="jax")
    disagg = executor_for(prog, backend="jax", service="disagg",
                          service_pool=pool)
    ref = inproc.run_steps([ins] * 3)
    out = disagg.run_steps([ins] * 3)
    identical = all(
        np.array_equal(np.asarray(r[k]), np.asarray(o[k]))
        for r, o in zip(ref, out) for k in r)
    assert identical, "disagg outputs diverged from in-process"

    # both paths warmed above; measure steady state
    us_in = _median_us_per_step(inproc, ins, steps, repeats)
    us_di = _median_us_per_step(disagg, ins, steps, repeats)
    return ({"identical": bool(identical), "steps_compared": 3},
            {"inproc_us_per_step": round(us_in, 1),
             "disagg_us_per_step": round(us_di, 1),
             "overhead_ratio": round(us_di / us_in, 3),
             "rpc_steps": disagg.stats["rpc_steps"]})


def _kill_leg(fast: bool) -> dict:
    import jax
    from repro.configs import get_reduced
    from repro.models import LM
    from repro.runtime.embedding_service import ServicePool
    from repro.runtime.server import DecodeServer, Request

    cfg = get_reduced(ARCH)
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    n_req, max_new, slots = (10, 4, 2) if fast else (24, 8, 4)
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size, 6).astype(
        np.int32), max_new_tokens=max_new) for _ in range(n_req)]

    with ServicePool(2, heartbeat_interval_s=0.05, **POOL_KW) as pool:
        srv = DecodeServer(lm, params, batch_slots=slots, max_len=32,
                           pipeline=True, service="disagg",
                           service_pool=pool)
        # warm the wave traces + service bind before the clock starts
        warm = Request(prompt=np.zeros(4, np.int32), max_new_tokens=2)
        srv.submit(warm)
        srv.run_until_drained()

        # open loop: Poisson arrivals, one replica SIGKILLed mid-load
        arrivals = np.cumsum(rng.exponential(0.01, size=n_req))
        t0 = time.perf_counter()
        kill_at = arrivals[n_req // 3]
        killed = False
        i = 0
        while i < n_req or any(not r.done for r in reqs):
            now = time.perf_counter() - t0
            while i < n_req and arrivals[i] <= now:
                srv.submit(reqs[i])
                i += 1
            if not killed and now >= kill_at:
                victim = next(j for j, r in enumerate(pool.replicas)
                              if r.state == "live")
                pool.kill_replica(victim)
                killed = True
            srv.step()
        assert killed, "load drained before the kill point"

        # the monitor thread drives respawn + artifact re-warm; wait for
        # the pool to be whole again so recovery_s lands in the record
        t_rec = time.perf_counter()
        while any(r.state != "live" for r in pool.replicas):
            time.sleep(0.05)
            assert time.perf_counter() - t_rec < 180, \
                "replica never recovered"
        stats = pool.stats()

    statuses = {s: sum(1 for r in reqs if r.status == s)
                for s in ("ok", "shed", "expired", "failed")}
    non_terminal = sum(1 for r in reqs if not r.done)
    assert non_terminal == 0, \
        f"{non_terminal} requests left without a terminal status"
    return {"requests": n_req,
            "statuses": statuses,
            "failed_requests": statuses["failed"],
            "non_terminal": non_terminal,
            "wave_faults": srv.serve_stats["wave_faults"],
            "wave_retries": srv.serve_stats["wave_retries"],
            "recovery_s": round(stats["recoveries_s"][-1], 3)
            if stats["recoveries_s"] else None,
            "rewarm_source": stats["warm_sources"][-1],
            "compile_source": stats["compile_sources"][-1],
            "pool": {k: stats[k] for k in
                     ("failovers", "retries", "respawns", "breaker_open",
                      "heartbeats", "hb_misses")}}


def run_disagg(fast: bool) -> dict:
    from repro.runtime.embedding_service import ServicePool
    with ServicePool(2, **POOL_KW) as pool:
        identity, steady = _identity_and_steady(pool, fast)
    kill = _kill_leg(fast)
    assert kill["failed_requests"] == 0, \
        f"replica kill failed {kill['failed_requests']} requests"
    assert kill["rewarm_source"] == "artifact", \
        "respawned replica did not re-warm from the checkpoint artifact"
    assert kill["compile_source"] == "artifact", \
        "respawned replica recompiled instead of loading the AOT artifact"
    return {"config": {"fast": fast, "arch": ARCH, "replicas": 2,
                       "rpc_timeout_s": POOL_KW["rpc_timeout_s"]},
            "bit_identity": identity,
            "steady_state": steady,
            "disagg": kill}


def run(report, fast: bool = True, out_path: Path = DEFAULT_OUT) -> dict:
    rec = run_disagg(fast)
    report("disagg/bit_identity", 0, rec["bit_identity"]["identical"])
    ss = rec["steady_state"]
    report("disagg/steady_state_us", ss["disagg_us_per_step"],
           f"inproc={ss['inproc_us_per_step']} "
           f"ratio={ss['overhead_ratio']}")
    k = rec["disagg"]
    report("disagg/kill_recovery_s", 0,
           f"recovery={k['recovery_s']}s failed={k['failed_requests']} "
           f"rewarm={k['rewarm_source']} compile={k['compile_source']}")
    report("disagg/kill_statuses", 0, k["statuses"])
    out_path.write_text(json.dumps(rec, indent=2))
    report("disagg/json", 0, str(out_path))
    return rec


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fast", action="store_true",
                    help="smoke sizes (tier1.sh --fast)")
    ap.add_argument("--out", type=Path, default=DEFAULT_OUT)
    args = ap.parse_args()

    def report(name, us, derived):
        print(f"{name},{us:.1f},{derived}", flush=True)

    rec = run(report, fast=args.fast, out_path=args.out)
    print(f"disagg overhead {rec['steady_state']['overhead_ratio']}x; "
          f"kill leg: {rec['disagg']['failed_requests']} failed, "
          f"recovered in {rec['disagg']['recovery_s']}s "
          f"({rec['disagg']['rewarm_source']})")


if __name__ == "__main__":
    main()
