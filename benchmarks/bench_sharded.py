"""Replicated vs vocab-sharded fused programs: the PR-3 sharding ablation.

The serving-shape LM/MoE embedding program of ``bench_steady_state`` runs
through the steady-state executor two ways on a multi-device mesh:

    replicated      ProgramExecutor without a mesh — every device would hold
                    the full fused stacked tables (PR-2 behavior)
    vocab_sharded   stacked tables partitioned over the mesh's ``model``
                    axis; the host routes each step's CSR streams to their
                    owning shards (indices out) and the batched kernel runs
                    under shard_map with pooled partial rows combined back

Records µs/step for both (cached + overlapped), the per-device
stacked-table footprint (the point of sharding: ÷ shard count), the
partitioner's per-shard VMEM audit, and the measured exchange volume into
``BENCH_sharded.json``.  Asserts the sharded outputs match the replicated
executor (atol 1e-5), the footprint actually halves on 2 shards, and the
overlap-vs-cached ordering holds on the sharded path too.

On a single-device host, ``main()`` re-execs itself in a *subprocess* whose
environment forces a 2-device CPU mesh
(``--xla_force_host_platform_device_count``) — the mutation never touches
this process's ``os.environ``, so importing jax later in the same process
(e.g. a harness running several benchmarks) keeps seeing the real device
count.  Under ``benchmarks/run.py`` (jax already imported) a 1-device host
skips with a report line.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
from pathlib import Path

DEFAULT_OUT = Path(__file__).resolve().parent.parent / "BENCH_sharded.json"


def respawn_with_devices(n: int) -> int:
    """Run this script again in a child process with an n-device CPU
    platform forced via its (copied) environment; returns the exit code.
    The forced ``XLA_FLAGS`` / device count never leak into the calling
    process's environment or its later jax import."""
    env = dict(os.environ)
    flags = env.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        env["XLA_FLAGS"] = \
            f"--xla_force_host_platform_device_count={n} {flags}".strip()
    return subprocess.run(
        [sys.executable, sys.argv[0], *sys.argv[1:], "--no-respawn"],
        env=env).returncode


def run_variants(fast: bool, n_steps: int) -> dict:
    import jax
    import numpy as np

    from repro.core import cost_model
    from repro.core.executor import ProgramExecutor
    from repro.core.pipeline import compile_program
    from repro.launch.mesh import axis_types_kw

    try:
        from . import bench_steady_state as bss
    except ImportError:                      # run as a plain script
        import bench_steady_state as bss

    shards = min(2, len(jax.devices()))
    assert shards >= 2, "bench_sharded needs >= 2 devices (see main())"
    mesh = jax.make_mesh((1, shards), ("data", "model"),
                         **axis_types_kw(2))

    prog = bss._program(fast)
    steps = bss._steps(prog, n_steps)

    # same execute unit everywhere (backend_jax XLA path): the ablation
    # isolates the sharded layout + exchange, not the kernel
    repl = ProgramExecutor(compile_program(prog, "O3", use_cache=False),
                           backend="jax")
    budget = cost_model.FusionBudget(shards=shards)
    shrd = ProgramExecutor(
        compile_program(prog, "O3", use_cache=False, budget=budget),
        backend="jax", mesh=mesh)
    shrd_async = ProgramExecutor(
        compile_program(prog, "O3", use_cache=False, budget=budget),
        backend="jax", mesh=mesh, depth=2)

    # numeric identity: vocab-sharded pooling must reproduce the
    # single-device executor exactly (modulo f32 reassociation)
    want = repl.step(steps[0])
    got = shrd.step(steps[0])
    for n in want:
        np.testing.assert_allclose(np.asarray(got[n]), np.asarray(want[n]),
                                   rtol=1e-5, atol=1e-5,
                                   err_msg=f"sharded {n} diverged")

    # interleaved best-of-N (see bench_steady_state._time_variants): slow
    # machine-load drift hits all variants equally, so the overlap/cached
    # comparison is stable enough to assert on.  The 2-fake-device CPU
    # collectives are much noisier than single-device dispatch, so the
    # sharded ablation takes extra rounds for the minima to converge.
    out = bss._time_variants({
        "replicated_cached": lambda b: [repl.step(i) for i in b],
        "sharded_cached": lambda b: [shrd.step(i) for i in b],
        "sharded_overlap": lambda b: shrd_async.run_steps(b),
    }, steps, repeats=5)
    # overlap must not regress on the sharded path either.  On the forced
    # CPU mesh two in-flight cross-device collectives contend for the same
    # host threads, so overlap ≈ cached within collective jitter is the
    # steady state here (the genuine overlap win — 1.8× — is measured on
    # the single-device path by bench_steady_state, which asserts the tight
    # 5% bound); anything past jitter is a pipeline regression.
    assert out["sharded_overlap"] <= out["sharded_cached"] * 1.15, \
        (f"sharded overlap regressed: {out['sharded_overlap']:.1f}us vs "
         f"cached {out['sharded_cached']:.1f}us")

    # footprints: what ONE device holds of the fused stacked tables
    def fused_units(ex):
        return [u for u in ex._units if u.group is not None]

    repl_dev = sum(int(u.table.nbytes) for u in fused_units(repl))
    shrd_dev = sum(int(u.table.addressable_shards[0].data.nbytes)
                   for u in fused_units(shrd))
    assert shrd_dev <= repl_dev // shards + 4096, \
        (f"sharding did not divide the footprint: {shrd_dev} vs "
         f"{repl_dev} / {shards}")

    # partitioner audit, per shard count — the per-shard VMEM budget view
    audit = []
    for u in fused_units(shrd):
        res = cost_model.fused_plan_resources(u.group.member_ops,
                                              vlen=shrd.compiled.vlen,
                                              shards=shards)
        assert res["vmem_bytes"] <= budget.vmem_bytes, \
            f"fused group {u.unit.names} exceeds the per-shard VMEM budget"
        audit.append({
            "members": list(u.unit.names),
            "vmem_bytes_per_shard": int(res["vmem_bytes"]),
            "table_bytes": int(res["table_bytes"]),
            "table_bytes_per_shard": int(res["table_bytes_per_shard"]),
            "exchange_bytes_per_step": int(res["exchange_bytes"]),
        })

    steps_run = shrd.stats["steps"]       # counters below are shrd's only
    return {
        "config": {"fast": fast, "steps": n_steps, "backend": "jax",
                   "shards": shards, "ops": len(prog.ops),
                   "fused_units": len(fused_units(shrd))},
        "us_per_step": {k: round(v, 1) for k, v in out.items()},
        "sharded_vs_replicated": round(
            out["replicated_cached"] / out["sharded_cached"], 3),
        "overlap_vs_cached": round(
            out["sharded_cached"] / out["sharded_overlap"], 3),
        "per_device_table_bytes": {"replicated": repl_dev,
                                   "vocab_sharded": shrd_dev,
                                   "ratio": round(shrd_dev / repl_dev, 3)},
        "exchange_measured": {
            "index_bytes_per_step":
                shrd.stats["exchange_index_bytes"] // max(steps_run, 1),
            "row_bytes_per_step":
                shrd.stats["exchange_row_bytes"] // max(steps_run, 1),
        },
        "executor_stats": dict(shrd_async.stats),
        "partitioner": {"budget_vmem_bytes": budget.vmem_bytes,
                        "shards": shards, "groups": audit},
    }


def run(report, fast: bool = True, n_steps: int = 3,
        out_path: Path = DEFAULT_OUT) -> dict:
    import jax
    if len(jax.devices()) < 2:
        report("sharded/skipped", 0, "needs >= 2 devices")
        return {}
    rec = run_variants(fast, n_steps)
    for k, v in rec["us_per_step"].items():
        report(f"sharded/{k}_us", v, rec["config"]["shards"])
    report("sharded/per_device_table_ratio", 0,
           rec["per_device_table_bytes"]["ratio"])
    out_path.write_text(json.dumps(rec, indent=2))
    report("sharded/json", 0, str(out_path))
    return rec


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fast", action="store_true",
                    help="smoke sizes (tier1.sh --fast)")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--devices", type=int, default=2,
                    help="forced CPU device count (default 2); applied in "
                         "a respawned child process, never this one")
    ap.add_argument("--out", type=Path, default=DEFAULT_OUT)
    ap.add_argument("--no-respawn", action="store_true",
                    help="internal: already running with the forced "
                         "device environment")
    args = ap.parse_args()
    if not args.no_respawn and "jax" not in sys.modules:
        sys.exit(respawn_with_devices(args.devices))
    n = args.steps or (3 if args.fast else 8)

    def report(name, us, derived):
        print(f"{name},{us:.1f},{derived}", flush=True)

    rec = run(report, fast=args.fast, n_steps=n, out_path=args.out)
    if rec:
        pd = rec["per_device_table_bytes"]
        print(f"vocab sharding: per-device stacked tables "
              f"{pd['replicated']} -> {pd['vocab_sharded']} bytes "
              f"({pd['ratio']:.2f}x) on {rec['config']['shards']} shards")


if __name__ == "__main__":
    main()
