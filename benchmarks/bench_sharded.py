"""Replicated vs vocab-sharded fused programs: the sharding + exchange
ablation.

The serving-shape LM/MoE embedding program of ``bench_steady_state`` runs
through the steady-state executor three ways on a multi-device mesh:

    replicated       ProgramExecutor without a mesh — every device would
                     hold the full fused stacked tables (PR-2 behavior)
    sharded_host     stacked tables partitioned over the mesh's ``model``
                     axis; the host routes each step's CSR streams to their
                     owning shards (indices out as a per-owner sharded
                     device_put) and partial pools psum back (PR-3/4)
    sharded_collective  the same layout, but the index exchange runs as a
                     ``jax.lax.all_to_all`` inside the shard_map body (ONE
                     resident send buffer per step) and the pooled outputs
                     are reduce-scattered — each shard keeps its own
                     segment slice (``--exchange`` ablation, PR-5)

Records µs/step (cached + collective-overlapped), the per-device
stacked-table footprint (the point of sharding: ÷ shard count), the
partitioner's per-shard VMEM audit, the measured exchange volume, and the
per-mode host-sync counts into ``BENCH_sharded.json``.  Asserts all
sharded outputs match the replicated executor (atol 1e-5), the footprint
actually halves on 2 shards, the collective path issues FEWER host syncs
per step than the host exchange, its reduce-scattered output bytes are
≤ replicated/shards + padding, and the overlap-vs-cached ordering holds.

On a single-device host, ``main()`` re-execs itself in a *subprocess*
whose environment forces a 2-device CPU mesh (``benchmarks/_mesh.py`` —
the mutation never touches this process's ``os.environ``, so importing
jax later in the same process keeps seeing the real device count).  Under
``benchmarks/run.py`` (jax already imported) a 1-device host skips with a
report line.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

try:
    from ._mesh import respawn_with_devices
except ImportError:                      # run as a plain script
    from _mesh import respawn_with_devices

DEFAULT_OUT = Path(__file__).resolve().parent.parent / "BENCH_sharded.json"


def run_variants(fast: bool, n_steps: int, exchange: str = "both") -> dict:
    import jax
    import numpy as np

    from repro.core import cost_model
    from repro.core.executor import ProgramExecutor
    from repro.core.pipeline import compile_program
    from repro.launch.mesh import axis_types_kw

    try:
        from . import bench_steady_state as bss
    except ImportError:                      # run as a plain script
        import bench_steady_state as bss

    shards = min(2, len(jax.devices()))
    assert shards >= 2, "bench_sharded needs >= 2 devices (see main())"
    mesh = jax.make_mesh((1, shards), ("data", "model"),
                         **axis_types_kw(2))

    prog = bss._program(fast)
    steps = bss._steps(prog, n_steps)

    # same execute unit everywhere (backend_jax XLA path): the ablation
    # isolates the sharded layout + exchange, not the kernel
    repl = ProgramExecutor(compile_program(prog, "O3", use_cache=False),
                           backend="jax")
    budget = cost_model.FusionBudget(shards=shards)
    pres = compile_program(prog, "O3", use_cache=False, budget=budget)
    shrd_host = ProgramExecutor(pres, backend="jax", mesh=mesh,
                                exchange="host")
    shrd_coll = ProgramExecutor(pres, backend="jax", mesh=mesh,
                                exchange="collective")
    # the overlap pipeline only runs (and is only worth compiling) when
    # the collective variants are timed
    shrd_async = ProgramExecutor(
        compile_program(prog, "O3", use_cache=False, budget=budget),
        backend="jax", mesh=mesh, exchange="collective", depth=2) \
        if exchange in ("collective", "both") else None

    # numeric identity: both exchange modes must reproduce the
    # single-device executor exactly (modulo f32 reassociation) — the
    # --exchange=collective acceptance gate
    want = repl.step(steps[0])
    got_h = shrd_host.step(steps[0])
    got_c = shrd_coll.step(steps[0])
    for n in want:
        np.testing.assert_allclose(np.asarray(got_h[n]),
                                   np.asarray(want[n]),
                                   rtol=1e-5, atol=1e-5,
                                   err_msg=f"sharded-host {n} diverged")
        np.testing.assert_allclose(np.asarray(got_c[n]),
                                   np.asarray(want[n]),
                                   rtol=1e-5, atol=1e-5,
                                   err_msg=f"collective {n} diverged")

    def fused_units(ex):
        return [u for u in ex._units if u.group is not None]

    # collective wins, measured on the SAME step: fewer host syncs (one
    # resident send buffer per CSR unit instead of ptrs+idxs+vals
    # scatters) and reduce-scattered output bytes ≤ replicated/S + padding
    syncs_host = shrd_host.stats["host_syncs"]
    syncs_coll = shrd_coll.stats["host_syncs"]
    assert syncs_coll < syncs_host, \
        (f"collective exchange must issue fewer host syncs per step: "
         f"{syncs_coll} vs {syncs_host}")
    rs_pad = sum(
        (u.plan.padded_segments - u.plan.num_segments)
        * (u.plan.op.block_rows if u.plan.op.kind == "gather" else 1)
        * u.plan.op.emb_len * 4 * (shards - 1) // shards
        for u in fused_units(shrd_coll))
    assert shrd_coll.stats["exchange_row_bytes"] <= \
        shrd_host.stats["exchange_row_bytes"] // shards + rs_pad, \
        (f"reduce-scattered output bytes exceed replicated/S + padding: "
         f"{shrd_coll.stats['exchange_row_bytes']} vs "
         f"{shrd_host.stats['exchange_row_bytes']} / {shards} + {rs_pad}")

    # interleaved best-of-N (see bench_steady_state._time_variants): slow
    # machine-load drift hits all variants equally, so the overlap/cached
    # comparison is stable enough to assert on.  The 2-fake-device CPU
    # collectives are much noisier than single-device dispatch, so the
    # sharded ablation takes extra rounds for the minima to converge (the
    # in-body all_to_all adds its own jitter on the fake mesh: 8 rounds).
    variants = {"replicated_cached": lambda b: [repl.step(i) for i in b]}
    if exchange in ("host", "both"):
        variants["sharded_host"] = lambda b: [shrd_host.step(i) for i in b]
    if exchange in ("collective", "both"):
        variants["sharded_collective"] = \
            lambda b: [shrd_coll.step(i) for i in b]
        variants["sharded_overlap"] = lambda b: shrd_async.run_steps(b)
    out = bss._time_variants(variants, steps, repeats=8)
    # overlap must not regress on the sharded path either.  On the forced
    # CPU mesh two in-flight cross-device collectives contend for the same
    # host threads, so overlap ≈ cached within collective jitter is the
    # steady state here (the genuine overlap win — 1.8× — is measured on
    # the single-device path by bench_steady_state, which asserts the tight
    # 5% bound); anything past jitter is a pipeline regression.
    if "sharded_overlap" in out:
        assert out["sharded_overlap"] <= out["sharded_collective"] * 1.15, \
            (f"sharded overlap regressed: {out['sharded_overlap']:.1f}us "
             f"vs cached {out['sharded_collective']:.1f}us")

    # footprints: what ONE device holds of the fused stacked tables
    repl_dev = sum(int(u.table.nbytes) for u in fused_units(repl))
    shrd_dev = sum(int(u.table.addressable_shards[0].data.nbytes)
                   for u in fused_units(shrd_coll))
    assert shrd_dev <= repl_dev // shards + 4096, \
        (f"sharding did not divide the footprint: {shrd_dev} vs "
         f"{repl_dev} / {shards}")

    # partitioner audit, per shard count — the per-shard VMEM budget view
    audit = []
    for u in fused_units(shrd_coll):
        res = cost_model.fused_plan_resources(
            u.group.member_ops, vlen=shrd_coll.compiled.vlen,
            shards=shards, replicate_outputs=False)
        assert res["vmem_bytes"] <= budget.vmem_bytes, \
            f"fused group {u.unit.names} exceeds the per-shard VMEM budget"
        audit.append({
            "members": list(u.unit.names),
            "vmem_bytes_per_shard": int(res["vmem_bytes"]),
            "table_bytes": int(res["table_bytes"]),
            "table_bytes_per_shard": int(res["table_bytes_per_shard"]),
            "exchange_bytes_per_step": int(res["exchange_bytes"]),
        })

    def exchange_record(ex):
        n = max(ex.stats["steps"], 1)
        return {
            "steps": ex.stats["steps"],
            "host_syncs_per_step": round(ex.stats["host_syncs"] / n, 2),
            "index_bytes_per_step": ex.stats["exchange_index_bytes"] // n,
            "row_bytes_per_step": ex.stats["exchange_row_bytes"] // n,
            "replicate_outputs": ex.replicate_outputs,
        }

    steps_run = shrd_coll.stats["steps"]  # counters below are collective's
    return {
        "config": {"fast": fast, "steps": n_steps, "backend": "jax",
                   "shards": shards, "ops": len(prog.ops),
                   "exchange": exchange,
                   "fused_units": len(fused_units(shrd_coll))},
        "us_per_step": {k: round(v, 1) for k, v in out.items()},
        "sharded_vs_replicated": round(
            out["replicated_cached"] /
            out.get("sharded_collective", out.get("sharded_host")), 3),
        "overlap_vs_cached": round(
            out["sharded_collective"] / out["sharded_overlap"], 3)
        if "sharded_overlap" in out else None,
        "per_device_table_bytes": {"replicated": repl_dev,
                                   "vocab_sharded": shrd_dev,
                                   "ratio": round(shrd_dev / repl_dev, 3)},
        "exchange_measured": {
            "index_bytes_per_step":
                shrd_coll.stats["exchange_index_bytes"]
                // max(steps_run, 1),
            "row_bytes_per_step":
                shrd_coll.stats["exchange_row_bytes"] // max(steps_run, 1),
        },
        "exchange_ablation": {"host": exchange_record(shrd_host),
                              "collective": exchange_record(shrd_coll)},
        "executor_stats": dict(shrd_async.stats)
        if shrd_async is not None else None,
        "access_plans": shrd_coll.access_plan_stats(),
        "partitioner": {"budget_vmem_bytes": budget.vmem_bytes,
                        "shards": shards, "groups": audit},
    }


def run(report, fast: bool = True, n_steps: int = 3,
        out_path: Path = DEFAULT_OUT, exchange: str = "both") -> dict:
    import jax
    if len(jax.devices()) < 2:
        report("sharded/skipped", 0, "needs >= 2 devices")
        return {}
    rec = run_variants(fast, n_steps, exchange)
    for k, v in rec["us_per_step"].items():
        report(f"sharded/{k}_us", v, rec["config"]["shards"])
    report("sharded/per_device_table_ratio", 0,
           rec["per_device_table_bytes"]["ratio"])
    report("sharded/host_syncs_per_step", 0, "host %.1f collective %.1f" % (
        rec["exchange_ablation"]["host"]["host_syncs_per_step"],
        rec["exchange_ablation"]["collective"]["host_syncs_per_step"]))
    out_path.write_text(json.dumps(rec, indent=2))
    report("sharded/json", 0, str(out_path))
    return rec


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fast", action="store_true",
                    help="smoke sizes (tier1.sh --fast)")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--exchange", choices=("host", "collective", "both"),
                    default="both",
                    help="which sharded exchange mode(s) to time; the "
                         "host/collective cross-checks (numeric identity, "
                         "host-sync and output-byte comparisons) always "
                         "run both once")
    ap.add_argument("--devices", type=int, default=2,
                    help="forced CPU device count (default 2); applied in "
                         "a respawned child process, never this one")
    ap.add_argument("--out", type=Path, default=DEFAULT_OUT)
    ap.add_argument("--no-respawn", action="store_true",
                    help="internal: already running with the forced "
                         "device environment")
    args = ap.parse_args()
    if not args.no_respawn and "jax" not in sys.modules:
        sys.exit(respawn_with_devices(args.devices))
    n = args.steps or (3 if args.fast else 8)

    def report(name, us, derived):
        print(f"{name},{us:.1f},{derived}", flush=True)

    rec = run(report, fast=args.fast, n_steps=n, out_path=args.out,
              exchange=args.exchange)
    if rec:
        pd = rec["per_device_table_bytes"]
        ab = rec["exchange_ablation"]
        print(f"vocab sharding: per-device stacked tables "
              f"{pd['replicated']} -> {pd['vocab_sharded']} bytes "
              f"({pd['ratio']:.2f}x) on {rec['config']['shards']} shards")
        print(f"collective exchange: host syncs/step "
              f"{ab['host']['host_syncs_per_step']} -> "
              f"{ab['collective']['host_syncs_per_step']}, pooled-row "
              f"bytes/step {ab['host']['row_bytes_per_step']} -> "
              f"{ab['collective']['row_bytes_per_step']} (reduce-scatter)")


if __name__ == "__main__":
    main()
