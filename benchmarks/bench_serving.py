"""Open-loop serving benchmark: the continuous-batching DecodeServer graded
as a *service* (PR-6 tentpole).

Two measurements:

* **Open-loop sweep** — Poisson arrivals at ≥2 target QPS points (derived
  from a closed-loop capacity calibration, so the sweep is
  machine-portable), Zipf-distributed prompt token ids, mixed prompt
  lengths.  Reports p50/p99 time-to-first-token, p50/p99 inter-token
  latency, and generated tokens/sec at each point.  The server runs with
  ``pipeline=True``: every wave's access streams feed the
  :class:`~repro.core.executor.PipelineGroup` whose per-program in-flight
  and pool hit/miss counters land in the record.  The measured points run
  with SLO admission armed at a generous budget (so ``shed_rate`` is 0.0
  unless the server regresses — gated absolutely in CI), and a 16x
  **overload** point with a tight budget asserts the server sheds the
  excess instead of queueing it unboundedly while every request still
  reaches a terminal status.

* **Cross-program pipeline ablation** — at saturating load (back-to-back
  waves), the wave's two compiled programs (decode embed + MoE un-dispatch)
  run (a) sequentially through two standalone executors (synchronous
  step/step — the two-program baseline) and (b) through ``pipeline_group``
  (wave W+1's embed marshals against the shared pool while wave W's
  un-dispatch executes).  The pipelined path is REQUIRED to beat the
  sequential baseline on tokens/sec — asserted here, gated in CI via
  ``scripts/check_bench_regression.py``.

Writes ``BENCH_serving.json``; registered in ``benchmarks/run.py`` as
``serving``.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np

DEFAULT_OUT = Path(__file__).resolve().parent.parent / "BENCH_serving.json"

ARCH = "qwen3-moe-235b-a22b"     # MoE: the wave has both pipeline programs


def _percentiles(xs, scale=1e3) -> dict:
    if not len(xs):
        return {"p50": 0.0, "p99": 0.0}
    return {"p50": round(float(np.percentile(xs, 50)) * scale, 3),
            "p99": round(float(np.percentile(xs, 99)) * scale, 3)}


def _workload(cfg, n: int, seed: int, *, max_new: int, len_lo: int,
              len_hi: int, deadline_s=None):
    """n requests with Zipf-distributed token ids and mixed prompt/output
    lengths (deterministic per seed so every run serves the same work)."""
    from repro.runtime.server import Request
    rng = np.random.default_rng(seed)
    reqs = []
    for _ in range(n):
        length = int(rng.integers(len_lo, len_hi + 1))
        prompt = ((rng.zipf(1.3, size=length) - 1)
                  % cfg.vocab_size).astype(np.int32)
        reqs.append(Request(prompt=prompt,
                            max_new_tokens=int(rng.integers(
                                max(1, max_new // 2), max_new + 1)),
                            deadline_s=deadline_s))
    return reqs


def _serve_metrics(reqs, makespan: float) -> dict:
    ttft = [r.t_first - r.t_submit for r in reqs if r.t_first is not None]
    gaps = np.concatenate([np.diff(r.token_times) for r in reqs
                           if len(r.token_times) > 1] or [np.zeros(0)])
    toks = sum(len(r.out) for r in reqs)
    statuses = {s: sum(r.status == s for r in reqs)
                for s in ("ok", "shed", "expired", "failed")}
    # SLO losses (shed + expired) over all offered requests: the gated
    # overload signal — a healthy server at the measured points sheds 0
    shed_rate = (statuses["shed"] + statuses["expired"]) / max(1, len(reqs))
    return {"completed": sum(r.done for r in reqs),
            "served_ok": statuses["ok"],
            "statuses": statuses,
            "shed_rate": round(shed_rate, 4),
            "generated_tokens": toks,
            "tokens_per_sec": round(toks / makespan, 1),
            "ttft_ms": _percentiles(ttft),
            "token_latency_ms": _percentiles(gaps)}


def _closed_loop(make_server, reqs):
    """Everything submitted up front: the server's capacity (calibrates the
    open-loop QPS points to this machine)."""
    srv = make_server()
    t0 = time.perf_counter()
    for r in reqs:
        srv.submit(r)
    srv.run_until_drained()
    dt = time.perf_counter() - t0
    return {"requests_per_sec": round(len(reqs) / dt, 2),
            **_serve_metrics(reqs, dt)}, srv


def _open_loop(make_server, reqs, qps: float, seed: int):
    """Poisson arrivals at target ``qps``; the server never sees a request
    before its arrival time (idle gaps are slept, not skipped)."""
    srv = make_server()
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / qps, size=len(reqs)))
    t0 = time.perf_counter()
    i = 0
    while True:
        now = time.perf_counter() - t0
        while i < len(reqs) and arrivals[i] <= now:
            srv.submit(reqs[i])
            i += 1
        active = srv.step()
        if active == 0 and not srv.queue:
            if i >= len(reqs):
                break
            time.sleep(max(0.0, arrivals[i] - (time.perf_counter() - t0)))
    srv.run_until_drained()             # settle the pipeline group + stats
    dt = time.perf_counter() - t0
    offered = len(reqs) / float(arrivals[-1])
    return {"target_qps": round(qps, 2), "offered_qps": round(offered, 2),
            **_serve_metrics(reqs, dt)}, srv


def _pipeline_ablation(lm, wave_batch: int, n_waves: int, fast: bool):
    """Sequential two-program baseline vs pipeline_group at saturating load
    (back-to-back waves, interleaved best-of-N timing)."""
    import jax.numpy as jnp
    from repro.core.executor import ProgramExecutor, pipeline_group
    from repro.core.pipeline import compile_program
    from repro.models import moe as moe_mod
    try:
        from . import bench_steady_state as bss
    except ImportError:                 # run as a script, not a package
        import bench_steady_state as bss
    _time_variants = bss._time_variants

    cfg = lm.cfg
    prog_a = lm.decode_embed_program(wave_batch)
    prog_b = moe_mod.undispatch_program(cfg, wave_batch)
    pres_a = compile_program(prog_a, "O3")
    pres_b = compile_program(prog_b, "O3")
    undisp = prog_b.op("moe_undispatch")
    emb_tbl = jnp.zeros((cfg.padded_vocab, cfg.d_model), jnp.float32)
    cap_tbl = jnp.zeros((undisp.num_embeddings, undisp.emb_len), jnp.float32)
    rng = np.random.default_rng(7)
    waves = []
    for _ in range(n_waves):
        toks = ((rng.zipf(1.3, size=wave_batch) - 1)
                % cfg.padded_vocab).astype(np.int32)
        slots = rng.integers(0, undisp.num_embeddings,
                             undisp.num_segments).astype(np.int32)
        waves.append((
            {"tok_embed": {"table": emb_tbl, "idxs": toks},
             "label_gather": {"table": emb_tbl, "idxs": toks}},
            {"moe_undispatch": {"table": cap_tbl, "idxs": slots}}))

    ex_a_seq = ProgramExecutor(pres_a, backend="jax", depth=2)
    ex_b_seq = ProgramExecutor(pres_b, backend="jax", depth=2)

    def sequential(batch):
        for ins_a, ins_b in batch:
            ex_a_seq.step(ins_a)
            ex_b_seq.step(ins_b)

    grp = pipeline_group([ProgramExecutor(pres_a, backend="jax", depth=2),
                          ProgramExecutor(pres_b, backend="jax", depth=2)])
    name_a, name_b = grp.names

    def pipelined(batch):
        for ins_a, ins_b in batch:
            grp.submit_wave({name_a: ins_a, name_b: ins_b})
        grp.drain()

    out = _time_variants({"sequential": sequential,
                          "pipelined": pipelined}, waves)
    # the acceptance bar: cross-program pipelining must beat the
    # sequential two-program baseline on tokens/sec at saturating load
    # (fast smoke sizes get 5% noise grace, like bench_steady_state)
    grace = 1.05 if fast else 1.0
    assert out["pipelined"] <= out["sequential"] * grace, \
        (f"pipeline_group lost to the sequential baseline: "
         f"{out['pipelined']:.1f}us vs {out['sequential']:.1f}us per wave")
    tps = {k: round(wave_batch / v * 1e6, 1) for k, v in out.items()}
    return {"wave_batch": wave_batch, "waves": n_waves,
            "us_per_wave": {k: round(v, 1) for k, v in out.items()},
            "sequential_tokens_per_sec": tps["sequential"],
            "pipelined_tokens_per_sec": tps["pipelined"],
            "speedup": round(out["sequential"] / out["pipelined"], 3),
            "group_stats": grp.group_stats()}


def run_serving(fast: bool) -> dict:
    import jax
    from repro.configs import get_reduced
    from repro.models import LM
    from repro.runtime.server import DecodeServer

    cfg = get_reduced(ARCH)
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    if fast:
        slots, n_req, max_new, len_hi, max_len, chunk = 4, 12, 5, 8, 32, 4
        wave_batch, n_waves = 64, 10
    else:
        slots, n_req, max_new, len_hi, max_len, chunk = 8, 40, 10, 16, 64, 4
        wave_batch, n_waves = 512, 20

    def make_server(capacity=None, slo=None):
        return DecodeServer(lm, params, batch_slots=slots, max_len=max_len,
                            prefill_chunk=chunk, pipeline=True,
                            capacity_rps=capacity, ttft_slo_s=slo)

    def fresh_reqs(seed):
        return _workload(cfg, n_req, seed, max_new=max_new, len_lo=2,
                         len_hi=len_hi)

    # warm both wave traces (C=prefill_chunk and C=1) and the executor
    # marshaling caches so the calibration measures steady state, not compile
    _closed_loop(make_server, _workload(cfg, 3, 9, max_new=max_new,
                                        len_lo=2, len_hi=len_hi))
    calib, _ = _closed_loop(make_server, fresh_reqs(0))
    capacity = max(calib["requests_per_sec"], 1e-3)
    # SLO machinery armed at the measured points with a generous budget
    # (2x the closed-loop time of the whole batch, so arrival + queueing
    # jitter never approaches it): a healthy server records shed_rate 0.0
    # here, and only a real slowdown makes the admission control start
    # covering for it — which the abs gate on saturating.shed_rate trips
    slo = 2.0 * n_req / capacity
    open_loop, last_srv = {}, None
    for point, mult in (("low", 0.5), ("saturating", 4.0)):
        open_loop[point], last_srv = _open_loop(
            lambda: make_server(capacity, slo), fresh_reqs(1),
            capacity * mult, seed=42)
        open_loop[point]["ttft_slo_s"] = round(slo, 4)
    assert open_loop["saturating"]["completed"] == n_req

    # overload: 16x capacity under a tight budget — the server must shed
    # or expire the excess instead of queueing it unboundedly, and every
    # request still reaches a terminal status; requests that DID get a
    # first token got it inside the budget (the mid-wave expiry check
    # runs before tokens are emitted, on the same timestamp)
    tight = 0.5 * n_req / capacity
    over_reqs = fresh_reqs(2)
    open_loop["overload"], _ = _open_loop(
        lambda: make_server(capacity, tight), over_reqs,
        capacity * 16.0, seed=43)
    ov = open_loop["overload"]
    ov["ttft_slo_s"] = round(tight, 4)
    assert all(r.done for r in over_reqs), \
        "overload left requests without a terminal status"
    losses = ov["statuses"]["shed"] + ov["statuses"]["expired"]
    assert losses > 0, \
        f"16x overload shed nothing (statuses={ov['statuses']})"
    assert ov["ttft_ms"]["p99"] <= (tight * 1.05 + 0.05) * 1e3, \
        (f"overload TTFT p99 {ov['ttft_ms']['p99']}ms exceeds the "
         f"{tight * 1e3:.0f}ms budget — admitted work queued past its SLO")

    pipe = _pipeline_ablation(lm, wave_batch, n_waves, fast)
    return {
        "config": {"fast": fast, "arch": ARCH, "slots": slots,
                   "requests": n_req, "max_new": max_new,
                   "prefill_chunk": chunk, "max_len": max_len,
                   "wave_batch": wave_batch},
        "calibration": {"capacity_rps": capacity,
                        "closed_loop_tokens_per_sec":
                            calib["tokens_per_sec"]},
        "open_loop": open_loop,
        "pipeline": pipe,
        "server_stats": dict(last_srv.serve_stats),
        "server_pipeline_group":
            last_srv.compile_stats.get("pipeline_group", {}),
    }


def run(report, fast: bool = True, out_path: Path = DEFAULT_OUT) -> dict:
    rec = run_serving(fast)
    for point, m in rec["open_loop"].items():
        report(f"serving/{point}_ttft_p99_ms", m["ttft_ms"]["p99"] * 1e3,
               f"qps={m['target_qps']}")
        report(f"serving/{point}_token_p99_ms",
               m["token_latency_ms"]["p99"] * 1e3,
               f"tok/s={m['tokens_per_sec']}")
        report(f"serving/{point}_shed_rate", 0,
               f"shed_rate={m['shed_rate']} statuses={m['statuses']}")
    pipe = rec["pipeline"]
    report("serving/pipeline_speedup", pipe["us_per_wave"]["pipelined"],
           pipe["speedup"])
    # the pipeline-group's own accounting: per-program in-flight peaks and
    # the shared staging pool's hit/miss/grown counters
    gs = pipe["group_stats"]
    for prog, n in gs["max_in_flight"].items():
        report(f"serving/group_max_inflight/{prog}", 0, n)
    pool = gs["pool"]
    report("serving/group_pool", 0,
           f"hits={pool['hits']} misses={pool['misses']} "
           f"grown={pool['grown']} forced_drains={pool['forced_drains']}")
    out_path.write_text(json.dumps(rec, indent=2))
    report("serving/json", 0, str(out_path))
    return rec


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fast", action="store_true",
                    help="smoke sizes (tier1.sh --fast)")
    ap.add_argument("--out", type=Path, default=DEFAULT_OUT)
    args = ap.parse_args()

    def report(name, us, derived):
        print(f"{name},{us:.1f},{derived}", flush=True)

    rec = run(report, fast=args.fast, out_path=args.out)
    sat = rec["open_loop"]["saturating"]
    print(f"saturating: {sat['tokens_per_sec']} tok/s, "
          f"TTFT p99 {sat['ttft_ms']['p99']}ms; pipeline speedup "
          f"{rec['pipeline']['speedup']}x over sequential")


if __name__ == "__main__":
    main()
