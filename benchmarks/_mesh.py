"""Forced multi-device CPU mesh plumbing shared by the sharded benches and
the 2-device tests.

Three callers used to hand-roll the same two tricks (``bench_sharded``,
``bench_locality``, ``tests/test_sharded_executor.py``):

* **respawn, don't mutate** — forcing
  ``--xla_force_host_platform_device_count`` only works before jax is
  imported, and writing it into ``os.environ`` leaks into every later jax
  import of the calling process (a harness running several benchmarks
  would silently see fake devices).  :func:`respawn_with_devices` re-execs
  the current script in a child whose *copied* environment carries the
  flag; :func:`forced_device_env` is the reusable environment builder.
* **skip, don't fail** — a host whose environment cannot honor the forced
  count (flag already pinned, non-CPU platform) should report and skip.
  Children verify with :func:`require_devices` and print
  ``MESH_SKIP <have> <want>`` so the parent can tell "environment can't"
  from "code broke" (``tests/conftest.py`` turns it into a pytest skip).
"""
from __future__ import annotations

import os
import subprocess
import sys

MESH_SKIP = "MESH_SKIP"


def forced_device_env(n: int, base: dict = None) -> dict:
    """A copy of ``base`` (default ``os.environ``) whose ``XLA_FLAGS``
    forces an ``n``-device CPU platform — for a *child* process only; the
    caller's environment is never touched."""
    env = dict(os.environ if base is None else base)
    flags = env.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        env["XLA_FLAGS"] = \
            f"--xla_force_host_platform_device_count={n} {flags}".strip()
    return env


def respawn_with_devices(n: int) -> int:
    """Run this script again in a child process with an n-device CPU
    platform forced via its (copied) environment; returns the exit code.
    The forced ``XLA_FLAGS`` / device count never leak into the calling
    process's environment or its later jax import."""
    return subprocess.run(
        [sys.executable, sys.argv[0], *sys.argv[1:], "--no-respawn"],
        env=forced_device_env(n)).returncode


def require_devices(n: int) -> bool:
    """In a (re)spawned child: do we actually see ``n`` devices?  Prints
    the ``MESH_SKIP`` sentinel when the forced count was not honored so
    the parent can skip instead of fail."""
    import jax
    have = len(jax.devices())
    if have < n:
        print(f"{MESH_SKIP} {have} {n}", flush=True)
        return False
    return True
