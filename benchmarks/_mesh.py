"""Forced multi-device CPU mesh plumbing shared by the sharded benches and
the 2-device tests.

Three callers used to hand-roll the same two tricks (``bench_sharded``,
``bench_locality``, ``tests/test_sharded_executor.py``):

* **respawn, don't mutate** — forcing
  ``--xla_force_host_platform_device_count`` only works before jax is
  imported, and writing it into ``os.environ`` leaks into every later jax
  import of the calling process (a harness running several benchmarks
  would silently see fake devices).  :func:`respawn_with_devices` re-execs
  the current script in a child whose *copied* environment carries the
  flag; :func:`forced_device_env` is the reusable environment builder.
* **skip, don't fail** — a host whose environment cannot honor the forced
  count (flag already pinned, non-CPU platform) should report and skip.
  Children verify with :func:`require_devices` and print
  ``MESH_SKIP <have> <want>`` so the parent can tell "environment can't"
  from "code broke" (``tests/conftest.py`` turns it into a pytest skip).
* **retry transient spawns** — a loaded CI host can transiently fail the
  fork/exec itself (``OSError``: EAGAIN, resource limits) or OOM-kill the
  child before it runs a line.  :func:`run_with_spawn_retry` retries
  exactly those infra failures with exponential backoff; an ordinary
  nonzero exit (a real test failure) is NEVER retried — it must surface
  on the first run.
"""
from __future__ import annotations

import os
import subprocess
import sys
import time

MESH_SKIP = "MESH_SKIP"


def run_with_spawn_retry(cmd, *, attempts: int = 3, backoff_s: float = 0.5,
                         sleep=time.sleep, **kw):
    """``subprocess.run`` with bounded retry on *spawn/infra* failures
    only: an ``OSError`` raised by the spawn itself, or a child killed by
    a signal (negative returncode — the OOM-killer / a stray SIGKILL,
    not a test outcome).  Ordinary nonzero exits return immediately.
    Returns the last ``CompletedProcess`` (or re-raises the last
    ``OSError`` when every attempt failed to spawn)."""
    last_exc = None
    result = None
    for k in range(attempts):
        if k:
            sleep(backoff_s * (2 ** (k - 1)))
        try:
            result = subprocess.run(cmd, **kw)
        except OSError as e:
            last_exc = e
            continue
        if result.returncode >= 0:
            return result
    if result is not None:
        return result
    raise last_exc


def forced_device_env(n: int, base: dict = None) -> dict:
    """A copy of ``base`` (default ``os.environ``) whose ``XLA_FLAGS``
    forces an ``n``-device CPU platform — for a *child* process only; the
    caller's environment is never touched."""
    env = dict(os.environ if base is None else base)
    flags = env.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        env["XLA_FLAGS"] = \
            f"--xla_force_host_platform_device_count={n} {flags}".strip()
    return env


def respawn_with_devices(n: int) -> int:
    """Run this script again in a child process with an n-device CPU
    platform forced via its (copied) environment; returns the exit code.
    The forced ``XLA_FLAGS`` / device count never leak into the calling
    process's environment or its later jax import.  Transient spawn
    failures (fork/exec errors, a signal-killed child) retry with backoff
    — see :func:`run_with_spawn_retry`."""
    return run_with_spawn_retry(
        [sys.executable, sys.argv[0], *sys.argv[1:], "--no-respawn"],
        env=forced_device_env(n)).returncode


def require_devices(n: int) -> bool:
    """In a (re)spawned child: do we actually see ``n`` devices?  Prints
    the ``MESH_SKIP`` sentinel when the forced count was not honored so
    the parent can skip instead of fail."""
    import jax
    have = len(jax.devices())
    if have < n:
        print(f"{MESH_SKIP} {have} {n}", flush=True)
        return False
    return True
