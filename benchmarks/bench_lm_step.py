"""Framework-level step benchmarks: wall time of reduced-config train and
decode steps per architecture (CPU host — relative numbers only; TPU
roofline projections live in EXPERIMENTS.md §Roofline)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs import get_reduced, list_archs
from repro.models import LM
from repro.optim import adamw, apply_updates


def run(report):
    for arch in list_archs():
        cfg = get_reduced(arch)
        lm = LM(cfg)
        params = lm.init(jax.random.PRNGKey(0))
        opt = adamw(lr=1e-3)
        opt_state = opt.init(params)
        batch = {"tokens": jnp.zeros((2, 16), jnp.int32),
                 "labels": jnp.zeros((2, 16), jnp.int32)}
        if cfg.modality == "audio-stub":
            batch["enc_embeds"] = jnp.zeros((2, 16, cfg.d_model))
        if cfg.modality == "vision-stub":
            batch["frontend_embeds"] = jnp.zeros((2, 8, cfg.d_model))

        @jax.jit
        def step(p, o, b):
            loss, g = jax.value_and_grad(lm.loss)(p, b)
            u, o = opt.update(g, o, p)
            return apply_updates(p, u), o, loss

        p1, o1, _ = step(params, opt_state, batch)   # compile
        jax.block_until_ready(p1)
        t0 = time.time()
        n = 3
        for _ in range(n):
            p1, o1, loss = step(p1, o1, batch)
        jax.block_until_ready(loss)
        report(f"lm_step/{arch}/train_us", (time.time() - t0) * 1e6 / n,
               round(float(loss), 3))
