"""Fig 16 / Fig 17 reproduction: the general-optimization ablation.

Two independent measurements per (model × opt level):

1. *Real compiler output*: emberc-generated DLC executed on the
   queue-faithful interpreter — marshaled data items and control tokens
   (the quantities Fig 14 illustrates and Fig 17's axes are built from).
2. *Modeled performance*: the calibrated machine-balance model, checked
   against the paper's published speedups (RM1/RM2/RM3 emb-opt3/emb-opt0 =
   6.6× / 12.1× / 21×; vectorization ≈ 5.13×).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import cost_model as cm
from repro.core.ops import EmbeddingOp, make_inputs
from repro.core.pipeline import compile_op, run_interpreted

# Table 3 DLRM configs (lookups scaled down 8× for interpreter speed; the
# queue-traffic *ratios* are size-independent)
RMS = {
    "RM1": EmbeddingOp("sls", num_segments=16, num_embeddings=2048,
                       emb_len=32, avg_lookups=8),
    "RM2": EmbeddingOp("sls", num_segments=8, num_embeddings=2048,
                       emb_len=64, avg_lookups=16),
    "RM3": EmbeddingOp("sls", num_segments=4, num_embeddings=2048,
                       emb_len=128, avg_lookups=32),
}

PAPER_O3 = {"RM1": 6.6, "RM2": 12.1, "RM3": 21.0}
LOCALITY_HIT = {"L0": 0.30, "L1": 0.65, "L2": 0.90}


def run(report):
    for name, op in RMS.items():
        ins = make_inputs(op, seed=0)
        traffic = {}
        for lvl in ("O0", "O1", "O2", "O3"):
            t0 = time.time()
            res = compile_op(op, lvl, vlen=cm.VLEN)
            _, stats = run_interpreted(res, ins, "dlc", return_queues=True)
            traffic[lvl] = stats
            report(f"ablation/{name}/{lvl}/data_items",
                   (time.time() - t0) * 1e6, stats["data_pushed"])
            report(f"ablation/{name}/{lvl}/tokens", 0, stats["tokens"])
        # modeled speedups vs paper (L1 locality — the headline setting)
        for lvl_i, lvl in enumerate(("O1", "O2", "O3"), start=1):
            for loc, hit in LOCALITY_HIT.items():
                s = cm.speedup_over_opt0(op_full(name), lvl_i, hit_rate=hit)
                report(f"ablation/{name}/{lvl}/{loc}/model_speedup", 0,
                       round(s, 2))
        s3 = cm.speedup_over_opt0(op_full(name), 3, hit_rate=0.9)
        report(f"ablation/{name}/O3/paper_speedup", 0, PAPER_O3[name])
        report(f"ablation/{name}/O3/within_25pct", 0,
               int(abs(s3 - PAPER_O3[name]) / PAPER_O3[name] < 0.25))

    # Fig 17: the access/compute throughput plane (normalized to emb-opt0)
    for name in RMS:
        for lvl_i, lvl in enumerate(("O0", "O1", "O2", "O3")):
            a, c = cm.queue_plane_point(op_full(name), lvl_i, hit_rate=0.65)
            report(f"plane/{name}/{lvl}/access_x", 0, round(a, 2))
            report(f"plane/{name}/{lvl}/compute_y", 0, round(c, 2))

    # MP models (Fig 16 right): optimization impact ∝ compute-per-lookup
    mp = EmbeddingOp("fusedmm", num_segments=8, num_embeddings=64,
                     emb_len=128, avg_lookups=4)
    ins = make_inputs(mp, seed=1)
    for lvl in ("O0", "O3"):
        _, stats = run_interpreted(compile_op(mp, lvl, vlen=cm.VLEN), ins,
                                   "dlc", return_queues=True)
        report(f"ablation/MP/{lvl}/data_items", 0, stats["data_pushed"])
    s = cm.speedup_over_opt0(
        EmbeddingOp("fusedmm", 2048, 2048, 128, avg_lookups=5), 3,
        hit_rate=0.65)
    report("ablation/MP/O3/model_speedup", 0, round(s, 2))


def op_full(name):
    """Full-size Table 3 configs for the analytic model."""
    e = {"RM1": 32, "RM2": 64, "RM3": 128}[name]
    lk = {"RM1": 64, "RM2": 128, "RM3": 256}[name]
    seg = {"RM1": 64, "RM2": 32, "RM3": 16}[name]
    return EmbeddingOp("sls", num_segments=seg, num_embeddings=16384,
                       emb_len=e, avg_lookups=lk)
