"""Fig 16 / Fig 17 reproduction: the general-optimization ablation.

Two independent measurements per (model × opt level):

1. *Real compiler output*: emberc-generated DLC executed on the
   queue-faithful interpreter — marshaled data items and control tokens
   (the quantities Fig 14 illustrates and Fig 17's axes are built from).
2. *Modeled performance*: the calibrated machine-balance model, checked
   against the paper's published speedups (RM1/RM2/RM3 emb-opt3/emb-opt0 =
   6.6× / 12.1× / 21×; vectorization ≈ 5.13×).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import cost_model as cm
from repro.core.ops import (EmbeddingOp, EmbeddingProgram, make_inputs,
                            make_program_inputs)
from repro.core.pipeline import (compile_cache_stats, compile_op,
                                 compile_program, run_interpreted,
                                 run_program_interpreted)

# Table 3 DLRM configs (lookups scaled down 8× for interpreter speed; the
# queue-traffic *ratios* are size-independent)
RMS = {
    "RM1": EmbeddingOp("sls", num_segments=16, num_embeddings=2048,
                       emb_len=32, avg_lookups=8),
    "RM2": EmbeddingOp("sls", num_segments=8, num_embeddings=2048,
                       emb_len=64, avg_lookups=16),
    "RM3": EmbeddingOp("sls", num_segments=4, num_embeddings=2048,
                       emb_len=128, avg_lookups=32),
}

PAPER_O3 = {"RM1": 6.6, "RM2": 12.1, "RM3": 21.0}
LOCALITY_HIT = {"L0": 0.30, "L1": 0.65, "L2": 0.90}


def run(report):
    for name, op in RMS.items():
        ins = make_inputs(op, seed=0)
        traffic = {}
        for lvl in ("O0", "O1", "O2", "O3"):
            t0 = time.time()
            res = compile_op(op, lvl, vlen=cm.VLEN)
            _, stats = run_interpreted(res, ins, "dlc", return_queues=True)
            traffic[lvl] = stats
            report(f"ablation/{name}/{lvl}/data_items",
                   (time.time() - t0) * 1e6, stats["data_pushed"])
            report(f"ablation/{name}/{lvl}/tokens", 0, stats["tokens"])
        # modeled speedups vs paper (L1 locality — the headline setting)
        for lvl_i, lvl in enumerate(("O1", "O2", "O3"), start=1):
            for loc, hit in LOCALITY_HIT.items():
                s = cm.speedup_over_opt0(op_full(name), lvl_i, hit_rate=hit)
                report(f"ablation/{name}/{lvl}/{loc}/model_speedup", 0,
                       round(s, 2))
        s3 = cm.speedup_over_opt0(op_full(name), 3, hit_rate=0.9)
        report(f"ablation/{name}/O3/paper_speedup", 0, PAPER_O3[name])
        report(f"ablation/{name}/O3/within_25pct", 0,
               int(abs(s3 - PAPER_O3[name]) / PAPER_O3[name] < 0.25))

    # Fig 17: the access/compute throughput plane (normalized to emb-opt0)
    for name in RMS:
        for lvl_i, lvl in enumerate(("O0", "O1", "O2", "O3")):
            a, c = cm.queue_plane_point(op_full(name), lvl_i, hit_rate=0.65)
            report(f"plane/{name}/{lvl}/access_x", 0, round(a, 2))
            report(f"plane/{name}/{lvl}/compute_y", 0, round(c, 2))

    # MP models (Fig 16 right): optimization impact ∝ compute-per-lookup
    mp = EmbeddingOp("fusedmm", num_segments=8, num_embeddings=64,
                     emb_len=128, avg_lookups=4)
    ins = make_inputs(mp, seed=1)
    for lvl in ("O0", "O3"):
        _, stats = run_interpreted(compile_op(mp, lvl, vlen=cm.VLEN), ins,
                                   "dlc", return_queues=True)
        report(f"ablation/MP/{lvl}/data_items", 0, stats["data_pushed"])
    s = cm.speedup_over_opt0(
        EmbeddingOp("fusedmm", 2048, 2048, 128, avg_lookups=5), 3,
        hit_rate=0.65)
    report("ablation/MP/O3/model_speedup", 0, round(s, 2))

    run_multitable(report)


def run_multitable(report):
    """Program-level fusion ablation: a 4-table DLRM-shaped step (Table 1's
    multi-table shape) compiled fused vs. per-op at O3 — compile+run wall
    time, queue traffic, dispatch count, and the compile-cache hit rate a
    steady-state runtime sees."""
    tables = tuple(
        (f"t{i}", EmbeddingOp("sls", num_segments=8, num_embeddings=512,
                              emb_len=32, avg_lookups=8))
        for i in range(4))
    prog = EmbeddingProgram("dlrm-4table", tables)
    ins = make_program_inputs(prog, seed=0)

    # delta accounting — never reset the process-global cache counters
    # (benchmarks/run.py reports them across the whole run)
    stats0 = compile_cache_stats()
    t0 = time.time()
    pres = compile_program(prog, "O3", vlen=cm.VLEN)
    compile_s = time.time() - t0
    t0 = time.time()
    _, fstats = run_program_interpreted(pres, ins, "dlc", return_queues=True)
    run_s = time.time() - t0
    report("ablation/multitable/fused/compile", compile_s * 1e6,
           len(pres.units))
    report("ablation/multitable/fused/run", run_s * 1e6,
           fstats["data_pushed"])

    t0 = time.time()
    pres_n = compile_program(prog, "O3", vlen=cm.VLEN, fuse=False,
                             use_cache=False)
    compile_n = time.time() - t0
    t0 = time.time()
    _, nstats = run_program_interpreted(pres_n, ins, "dlc",
                                        return_queues=True)
    run_n = time.time() - t0
    report("ablation/multitable/per_op/compile", compile_n * 1e6,
           len(pres_n.units))
    report("ablation/multitable/per_op/run", run_n * 1e6,
           nstats["data_pushed"])
    report("ablation/multitable/fused/dispatch_ratio", 0,
           round(len(pres_n.units) / len(pres.units), 2))

    # steady state: every later step re-compiles the same signature
    for _ in range(9):
        compile_program(prog, "O3", vlen=cm.VLEN)
    stats1 = compile_cache_stats()
    hits = stats1["hits"] - stats0["hits"]
    misses = stats1["misses"] - stats0["misses"]
    report("ablation/multitable/compile_cache/hit_rate", 0,
           round(hits / max(hits + misses, 1), 3))


def op_full(name):
    """Full-size Table 3 configs for the analytic model."""
    e = {"RM1": 32, "RM2": 64, "RM3": 128}[name]
    lk = {"RM1": 64, "RM2": 128, "RM3": 256}[name]
    seg = {"RM1": 64, "RM2": 32, "RM3": 16}[name]
    return EmbeddingOp("sls", num_segments=seg, num_embeddings=16384,
                       emb_len=e, avg_lookups=lk)
