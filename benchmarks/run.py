"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Select subsets with
``python -m benchmarks.run [characterization|dae_potential|ablation|
blocksparse|vs_handopt|lm_step|steady_state|sharded|locality|serving|
disagg|coldstart]``.

``--json PATH`` additionally writes every reported row (plus the cache
stats) as machine-readable JSON — what CI consumes; ``-`` writes JSON to
stdout instead of the CSV.
"""
from __future__ import annotations

import argparse
import json
import sys

BENCHES = ["characterization", "dae_potential", "ablation", "blocksparse",
           "vs_handopt", "lm_step", "steady_state", "sharded", "locality",
           "serving", "disagg", "coldstart"]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("benches", nargs="*", metavar="bench",
                    help=f"subset of benchmarks (default: all of "
                         f"{', '.join(BENCHES)})")
    ap.add_argument("--json", dest="json_out", default=None,
                    help="write rows as JSON to this path ('-' = stdout, "
                         "suppressing the CSV)")
    args = ap.parse_args()
    selected = args.benches or BENCHES
    unknown = [b for b in selected if b not in BENCHES]
    if unknown:
        ap.error(f"unknown benchmark(s) {unknown}; choose from {BENCHES}")
    json_to_stdout = args.json_out == "-"
    rows: list = []

    def report(name, us, derived):
        rows.append({"name": name, "us_per_call": round(float(us), 1),
                     "derived": derived})
        if not json_to_stdout:
            print(f"{name},{us:.1f},{derived}", flush=True)

    if not json_to_stdout:
        print("name,us_per_call,derived")

    for b in selected:
        mod = __import__(f"benchmarks.bench_{b}", fromlist=["run"])
        mod.run(report)

    # global compile-cache effectiveness across everything the run compiled
    from repro.core.executor import executor_cache_stats
    from repro.core.pipeline import compile_cache_stats
    stats = compile_cache_stats()
    report("compile_cache/hits", 0, stats["hits"])
    report("compile_cache/misses", 0, stats["misses"])
    report("compile_cache/hit_rate", 0, round(stats["hit_rate"], 3))
    # entries broken down by vocab-shard count: a shard-count change that
    # silently forks cache keys (the sharded cache-key regression) is
    # visible as unexpected multi-shard histograms here
    report("compile_cache/entries_by_shards", 0,
           stats["entries_by_shards"])
    report("executor_cache/entries_by_shards", 0,
           executor_cache_stats()["entries_by_shards"])

    if args.json_out:
        payload = json.dumps({"rows": rows}, indent=2, default=str)
        if json_to_stdout:
            print(payload)
        else:
            with open(args.json_out, "w") as f:
                f.write(payload)
            print(f"# wrote {len(rows)} rows to {args.json_out}",
                  file=sys.stderr)


if __name__ == "__main__":
    main()
