"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Select subsets with
``python -m benchmarks.run [characterization|dae_potential|ablation|
blocksparse|vs_handopt|lm_step]``.
"""
from __future__ import annotations

import sys

BENCHES = ["characterization", "dae_potential", "ablation", "blocksparse",
           "vs_handopt", "lm_step"]


def main() -> None:
    selected = sys.argv[1:] or BENCHES
    print("name,us_per_call,derived")

    def report(name, us, derived):
        print(f"{name},{us:.1f},{derived}", flush=True)

    for b in selected:
        mod = __import__(f"benchmarks.bench_{b}", fromlist=["run"])
        mod.run(report)


if __name__ == "__main__":
    main()
