"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Select subsets with
``python -m benchmarks.run [characterization|dae_potential|ablation|
blocksparse|vs_handopt|lm_step]``.
"""
from __future__ import annotations

import sys

BENCHES = ["characterization", "dae_potential", "ablation", "blocksparse",
           "vs_handopt", "lm_step", "steady_state", "sharded"]


def main() -> None:
    selected = sys.argv[1:] or BENCHES
    print("name,us_per_call,derived")

    def report(name, us, derived):
        print(f"{name},{us:.1f},{derived}", flush=True)

    for b in selected:
        mod = __import__(f"benchmarks.bench_{b}", fromlist=["run"])
        mod.run(report)

    # global compile-cache effectiveness across everything the run compiled
    from repro.core.executor import executor_cache_stats
    from repro.core.pipeline import compile_cache_stats
    stats = compile_cache_stats()
    report("compile_cache/hits", 0, stats["hits"])
    report("compile_cache/misses", 0, stats["misses"])
    report("compile_cache/hit_rate", 0, round(stats["hit_rate"], 3))
    # entries broken down by vocab-shard count: a shard-count change that
    # silently forks cache keys (the sharded cache-key regression) is
    # visible as unexpected multi-shard histograms here
    report("compile_cache/entries_by_shards", 0,
           stats["entries_by_shards"])
    report("executor_cache/entries_by_shards", 0,
           executor_cache_stats()["entries_by_shards"])


if __name__ == "__main__":
    main()
