"""Figs 3/4/6/7 reproduction: the potential of DAE architectures, from the
calibrated machine-balance model (gem5/McPAT are not available offline; the
model reproduces the paper's published ratios — see core/cost_model.py)."""
from __future__ import annotations

from repro.core import cost_model as cm
from repro.core.ops import EmbeddingOp

PAPER = {
    "tmu_requests_ratio": 5.7,     # Fig 6a (we model the 8-10× slot ratio)
    "dae_geomean_speedup": 5.8,    # Fig 7
    "spattn_max_speedup": 17.0,    # Fig 7 (fully offloaded gather)
}


def run(report):
    # Fig 6: requests/s of the TMU vs a traditional core
    ratio = (cm.requests_per_second(decoupled=True) /
             cm.requests_per_second(decoupled=False))
    report("dae_potential/tmu_req_ratio", 0, round(ratio, 2))
    report("dae_potential/tmu_req_ratio_paper", 0,
           PAPER["tmu_requests_ratio"])

    # Fig 7: DAE speedup over a traditional core per model class
    classes = {
        "sls_rm2": EmbeddingOp("sls", 64, 16384, 64, avg_lookups=128),
        "kg": EmbeddingOp("kg", 4096, 100_000, 512),
        "gnn_spmm": EmbeddingOp("spmm", 2048, 100_000, 128, avg_lookups=26),
        "mp_fusedmm": EmbeddingOp("fusedmm", 2048, 2048, 128, avg_lookups=5),
        "spattn": EmbeddingOp("gather", 512, 4096, 64, block_rows=4),
    }
    sp = {}
    for name, op in classes.items():
        s = cm.dae_speedup_over_core(op, hit_rate=0.65)
        sp[name] = s
        report(f"dae_potential/speedup_{name}", 0, round(s, 2))
    geo = 1.0
    for v in sp.values():
        geo *= v
    geo **= 1.0 / len(sp)
    report("dae_potential/geomean", 0, round(geo, 2))
    report("dae_potential/geomean_paper", 0, PAPER["dae_geomean_speedup"])
    # geomean must land within 2× of the paper's 5.8× (model fidelity gate)
    report("dae_potential/geomean_within_2x_paper", 0,
           int(0.5 < geo / PAPER["dae_geomean_speedup"] < 2.0))
