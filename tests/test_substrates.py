"""Substrate tests: data pipeline determinism + locality tooling, optimizer,
gradient compression, checkpoint manager."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.data.locality import hit_rate, make_trace, reuse_cdf, \
    reuse_distances
from repro.data.pipeline import DataConfig, SyntheticTokens
from repro.optim import (adamw, apply_updates, clip_by_global_norm,
                         compress_gradients, cosine_schedule,
                         error_feedback_init)


# ---- data ----

def test_pipeline_deterministic_and_restartable():
    cfg = DataConfig(vocab_size=100, seq_len=8, global_batch=4)
    a = SyntheticTokens(cfg).batch_at(17)
    b = SyntheticTokens(cfg).batch_at(17)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = SyntheticTokens(cfg).batch_at(18)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_pipeline_host_sharding_disjoint():
    cfg = DataConfig(vocab_size=1000, seq_len=8, global_batch=8)
    h0 = SyntheticTokens(cfg, host_index=0, host_count=2).batch_at(3)
    h1 = SyntheticTokens(cfg, host_index=1, host_count=2).batch_at(3)
    assert h0["tokens"].shape == (4, 8)
    assert not np.array_equal(h0["tokens"], h1["tokens"])


def test_reuse_distance_exact():
    # trace: a b a c b a -> distances: a:-1 b:-1 a:1 c:-1 b:2 a:2
    d = reuse_distances(np.array([0, 1, 0, 2, 1, 0]))
    np.testing.assert_array_equal(d, [-1, -1, 1, -1, 2, 2])


def test_locality_ordering():
    lo = make_trace(4096, 20000, "L0", seed=0)
    hi = make_trace(4096, 20000, "L2", seed=0)
    assert hit_rate(hi, 256) > hit_rate(lo, 256) + 0.1
    xs, cdf = reuse_cdf(hi, xs=np.array([1, 100, 100000]))
    assert (np.diff(cdf) >= 0).all()


# ---- optimizer ----

def test_adamw_optimizes_quadratic():
    opt = adamw(lr=0.1, weight_decay=0.0)
    params = {"w": jnp.array([3.0, -2.0])}
    state = opt.init(params)
    for _ in range(200):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        u, state = opt.update(g, state, params)
        params = apply_updates(params, u)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_clip_by_global_norm():
    g = {"a": jnp.full((10,), 10.0)}
    c = clip_by_global_norm(g, 1.0)
    n = float(jnp.linalg.norm(c["a"]))
    assert abs(n - 1.0) < 1e-4


def test_cosine_schedule_shape():
    lr = cosine_schedule(1.0, warmup_steps=10, total_steps=100)
    assert float(lr(0)) == 0.0
    assert abs(float(lr(10)) - 1.0) < 1e-5
    assert float(lr(100)) < float(lr(50)) < float(lr(10))


def test_compression_error_feedback_unbiased_over_time():
    """Residual re-injection: the *cumulative* compressed signal tracks the
    cumulative true gradient (EF property)."""
    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.standard_normal((4, 300)), jnp.float32)
    ef = error_feedback_init({"w": g_true})["w"]
    total_c = jnp.zeros_like(g_true)
    for step in range(20):
        gc, ef = compress_gradients({"w": g_true}, {"w": ef})
        gc, ef = gc["w"], ef["w"]
        total_c = total_c + gc
    drift = float(jnp.abs(total_c - 20 * g_true).max())
    scale = float(jnp.abs(g_true).max())
    assert drift < 0.2 * scale, drift


def test_compression_quantization_error_bounded():
    rng = np.random.default_rng(1)
    g = jnp.asarray(rng.standard_normal((1000,)) * 5, jnp.float32)
    ef = error_feedback_init({"w": g})
    gc, _ = compress_gradients({"w": g}, ef)
    err = float(jnp.abs(gc["w"] - g).max())
    assert err <= float(jnp.abs(g).max()) / 127 + 1e-6


# ---- checkpoint ----

def test_checkpoint_roundtrip_and_keep(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2, async_save=False)
    tree = {"a": jnp.arange(6.0).reshape(2, 3),
            "nested": {"b": jnp.ones((4,), jnp.int32)},
            "scalar": jnp.array(3)}
    for step in (1, 2, 3, 4):
        mgr.save(step, tree)
    assert mgr.latest() == 4
    out, step = mgr.restore(tree)
    assert step == 4
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tree["a"]))
    np.testing.assert_array_equal(np.asarray(out["nested"]["b"]),
                                  np.asarray(tree["nested"]["b"]))
    # retention: only the last 2 steps survive
    import pathlib
    kept = sorted(p.name for p in pathlib.Path(tmp_path).glob("step_*")
                  if p.is_dir())
    assert len(kept) == 2


def test_checkpoint_async_save(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2, async_save=True)
    mgr.save(5, {"x": jnp.zeros((8, 8))})
    mgr.wait()
    assert mgr.latest() == 5
