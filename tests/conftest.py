"""Shared test plumbing: the ``--fast`` smoke switch and the forced
2-device subprocess runner.

``--fast`` (wired through ``scripts/tier1.sh --fast``) shrinks the
generated-case counts of the differential harness to a smoke subset, the
same way tier1.sh gates the benchmark smokes; the full ``pytest`` run (the
ROADMAP tier-1 command) keeps the ≥200-case sweep.

``run_on_mesh`` is the single home of the respawn/env-forcing logic that
used to be duplicated across ``tests/test_sharded_executor.py``,
``benchmarks/bench_sharded.py`` and ``benchmarks/bench_locality.py`` (the
benches share :mod:`benchmarks._mesh`): it executes a code snippet in a
subprocess whose copied environment forces an N-device CPU platform, skips
(not fails) when the forced count cannot be honored, and never mutates the
calling process's environment.
"""
from __future__ import annotations

import os
import sys
import textwrap
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent

# make benchmarks._mesh (and the benchmarks package generally) importable
# from tests without installing the repo
sys.path.insert(0, str(REPO))

from benchmarks._mesh import (MESH_SKIP, forced_device_env,  # noqa: E402
                              run_with_spawn_retry)


def pytest_addoption(parser):
    parser.addoption(
        "--fast", action="store_true", default=False,
        help="smoke subset of the generated differential cases "
             "(tier1.sh --fast); the full run sweeps >=200 cases")


def pytest_configure(config):
    # hypothesis example counts follow the same --fast gate (loaded before
    # collection, so @settings decorators inherit the profile's
    # max_examples); tests that pin max_examples explicitly are unaffected
    try:
        from hypothesis import settings
    except ImportError:
        return
    settings.register_profile("diff-full", max_examples=20)
    settings.register_profile("diff-fast", max_examples=5)
    settings.load_profile(
        "diff-fast" if config.getoption("--fast") else "diff-full")


@pytest.fixture(scope="session")
def fast_mode(request) -> bool:
    return bool(request.config.getoption("--fast"))


# one implementation of the skip protocol: the child calls
# benchmarks._mesh.require_devices, which prints the MESH_SKIP sentinel
# this fixture matches on (the repo root is on the child's PYTHONPATH)
_PREAMBLE = """
from benchmarks._mesh import require_devices
if not require_devices({devices}):
    raise SystemExit(0)
"""


@pytest.fixture
def run_on_mesh():
    """Run ``code`` in a subprocess with a forced ``devices``-wide CPU
    platform.  The child first verifies the forced count took effect and
    prints the ``MESH_SKIP`` sentinel otherwise, which this fixture turns
    into ``pytest.skip`` — an environment that can't honor the mesh is not
    a failure.  Returns the completed process (stdout checked by caller)."""

    def run(code: str, devices: int = 2, timeout: int = 900,
            sentinel: str = None):
        body = _PREAMBLE.format(devices=devices) + textwrap.dedent(code)
        env = forced_device_env(devices)
        env["PYTHONPATH"] = "src" + os.pathsep + str(REPO) + (
            os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
        # bounded spawn retry: a loaded CI host transiently failing the
        # fork/exec (or OOM-killing the child before it runs) should not
        # flake the 2-device job; real test failures never retry
        r = run_with_spawn_retry([sys.executable, "-c", body],
                                 capture_output=True, text=True, env=env,
                                 cwd=str(REPO), timeout=timeout)
        if MESH_SKIP in r.stdout:
            pytest.skip(f"forced {devices}-device CPU mesh not honored: "
                        f"{r.stdout.strip().splitlines()[-1]}")
        if sentinel is not None:
            assert sentinel in r.stdout, \
                (r.stdout[-2000:] + "\n" + r.stderr[-4000:])
        return r

    return run
