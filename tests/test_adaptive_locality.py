"""Adaptive hot-slab locality: windowed re-classification units, the live
slab-swap path (no-recompile respecialization, epoch-checked marshaling,
churn/leak plateau) on a forced 2-device mesh, drift propagation through
the disaggregated artifact-republish path, and the DecodeServer
``capacity_rps="auto"`` self-calibration.

The swap machinery's core invariant under test: a swap changes slab
*membership*, never slab *shape* — per-slot hot counts, capacities, local
rows, memoized shard_fns and scratch buckets all stay constant, so ten
swaps cost ten table restacks and zero retraces.
"""
import numpy as np
import pytest

from repro.core.executor import (ProgramExecutor, clear_executor_cache,
                                 executor_for)
from repro.core.ops import EmbeddingOp, EmbeddingProgram
from repro.core.pipeline import compile_program
from repro.core.shard_plan import compute_spill
from repro.data.locality import (AdaptiveHotConfig, WindowedCounts,
                                 classify_hot_from_counts)

# ---------------------------------------------------------------------------
# Windowed counters + re-ranking (pure units)
# ---------------------------------------------------------------------------


def test_windowed_counts_age_out():
    wc = WindowedCounts(8, window_steps=4, num_windows=2)
    for _ in range(2):
        wc.add([1, 1, 2])
    assert not wc.full
    assert wc.totals()[1] == 4 and wc.totals()[2] == 2
    wc.add([3])
    assert wc.totals()[1] == 4 and wc.totals()[3] == 1
    # the 4th step completes the window; the ring rotates into (and
    # clears) the stripe holding rows 1/2 — they age out entirely
    wc.add([3])
    assert wc.full
    t = wc.totals()
    assert t[1] == 0 and t[2] == 0 and t[3] == 2
    wc.reset()
    assert wc.totals().sum() == 0 and not wc.full and wc.steps == 0


def test_windowed_counts_ignores_out_of_range():
    wc = WindowedCounts(4, window_steps=2, num_windows=2)
    wc.add([-1, 0, 3, 4, 99])
    assert wc.totals().tolist() == [1, 0, 0, 1]


def test_adaptive_config_validation():
    with pytest.raises(ValueError):
        AdaptiveHotConfig(window_steps=2, num_windows=4)
    with pytest.raises(ValueError):
        AdaptiveHotConfig(drift_threshold=0.0)
    with pytest.raises(ValueError):
        AdaptiveHotConfig(spill_fraction=1.5)
    with pytest.raises(ValueError):
        AdaptiveHotConfig(refine_passes=-1)
    assert hash(AdaptiveHotConfig()) == hash(AdaptiveHotConfig())


def test_classify_hot_from_counts_ranks_and_pads():
    counts = np.zeros(10, np.int64)
    counts[[7, 2, 5]] = [9, 9, 3]
    # ties break by row id; result sorted ascending
    assert classify_hot_from_counts(counts, 2).tolist() == [2, 7]
    # prev_hot pads the set to EXACTLY its size (shape stability): row 5
    # ranks on counts, then previously-hot 1/4 fill by their counts
    prev = np.array([1, 4, 9])
    got = classify_hot_from_counts(counts, 3, prev_hot=prev)
    assert len(got) == 3 and {2, 7}.issubset(set(got.tolist()))
    # more live candidates than prev size: truncates, never grows
    got = classify_hot_from_counts(counts, 3, prev_hot=np.array([0]))
    assert len(got) == 1


def test_compute_spill_overload_detection():
    balanced = np.array([[50, 5], [4, 52]])
    assert compute_spill(balanced, 0.25, 1.5) == {}
    skewed = np.array([[90, 2], [3, 10]])
    assert compute_spill(skewed, 0.25, 1.5) == {0: (1, 0.25)}
    # least-loaded peer by routed column load, 3-way
    tri = np.zeros((3, 3), np.int64)
    tri[0, 0], tri[1, 1], tri[2, 2] = 90, 10, 10
    tri[0, 1] = 30                       # shard 1 is busier than shard 2
    assert compute_spill(tri, 0.5, 1.5) == {0: (2, 0.5)}
    assert compute_spill(skewed, 0.0, 1.5) == {}      # spill disabled
    assert compute_spill(np.array([[9]]), 0.25, 1.5) == {}
    assert compute_spill(np.zeros((2, 2), np.int64), 0.25, 1.5) == {}


# ---------------------------------------------------------------------------
# Executor surface (single device)
# ---------------------------------------------------------------------------


def _prog():
    return EmbeddingProgram("adapt1", (
        ("t", EmbeddingOp("sls", 4, 64, 8, avg_lookups=4)),))


def test_executor_for_keys_on_adaptive_config():
    clear_executor_cache()
    prog = _prog()
    a = executor_for(prog, backend="jax")
    b = executor_for(prog, backend="jax", adaptive=AdaptiveHotConfig())
    c = executor_for(prog, backend="jax",
                     adaptive=AdaptiveHotConfig(window_steps=8))
    assert a is not b and b is not c
    assert executor_for(prog, backend="jax",
                        adaptive=AdaptiveHotConfig()) is b


def test_adaptive_rejects_wrong_type():
    with pytest.raises(TypeError):
        ProgramExecutor(compile_program(_prog(), "O1", use_cache=False),
                        backend="jax", adaptive=object())


def test_single_shard_swap_is_a_noop():
    ex = ProgramExecutor(compile_program(_prog(), "O1", use_cache=False),
                         backend="jax", adaptive=AdaptiveHotConfig())
    assert ex.swap_hot_slab({"t": (1, 2, 3)}) is False
    assert ex.slab_epoch == 0 and ex.stats["hot_swaps"] == 0
    ws = ex.window_stats()
    assert ws["adaptive"] and ws["slab_epoch"] == 0
    assert ws["hot_lookups"] == 0 and ws["steps_in_window"] == 0


# ---------------------------------------------------------------------------
# Live swap on a 2-device mesh: drift trigger, bit-identity, churn plateau
# ---------------------------------------------------------------------------


def test_adaptive_swap_two_devices(run_on_mesh):
    code = """
        import jax
        import numpy as np
        from repro.core import access_plan as ap
        from repro.core import cost_model
        from repro.core.executor import ProgramExecutor
        from repro.core.ops import EmbeddingOp, EmbeddingProgram
        from repro.core.pipeline import compile_program
        from repro.data.locality import AdaptiveHotConfig
        from repro.launch.mesh import axis_types_kw

        mesh = jax.make_mesh((1, 2), ("data", "model"), **axis_types_kw(2))
        rows, segs = 256, 8
        prog = EmbeddingProgram("drift", (
            ("a", EmbeddingOp("sls", segs, rows, 8, avg_lookups=6)),
            ("b", EmbeddingOp("sls", segs, rows, 8, avg_lookups=6)),
        ))
        rng = np.random.default_rng(0)
        tables = {n: rng.standard_normal((rows, 8)).astype(np.float32)
                  for n, _ in prog.ops}

        def step_ins(lo, hi):
            ins = {}
            for n, op in prog.ops:
                lens = np.full(segs, op.avg_lookups, np.int64)
                ptrs = np.zeros(segs + 1, np.int64)
                np.cumsum(lens, out=ptrs[1:])
                ins[n] = {"table": tables[n], "ptrs": ptrs,
                          "idxs": rng.integers(lo, hi, int(ptrs[-1])
                                               ).astype(np.int32)}
            return ins

        hot = {n: tuple(range(32)) for n, _ in prog.ops}
        cfg = AdaptiveHotConfig(window_steps=4, num_windows=2,
                                drift_threshold=0.6, min_swap_interval=4,
                                spill_fraction=0.0, refine_passes=0)
        chot = compile_program(prog, "O3", use_cache=False, hot_rows=hot,
                               budget=cost_model.FusionBudget(shards=2))
        ref = ProgramExecutor(compile_program(prog, "O3", use_cache=False),
                              backend="jax")
        ex = ProgramExecutor(chot, backend="jax", mesh=mesh, hot_rows=hot,
                             adaptive=cfg)

        def check(ins):
            want, got = ref.step(ins), ex.step(ins)
            for n in want:
                np.testing.assert_allclose(
                    np.asarray(got[n]), np.asarray(want[n]),
                    rtol=1e-5, atol=1e-5, err_msg=n)

        for _ in range(6):                     # reference window: all hot
            check(step_ins(0, 32))
        ws = ex.window_stats()
        assert ws["window_full"] and ws["hot_traffic_fraction"] == 1.0
        assert ws["reference_hot_fraction"] == 1.0
        for _ in range(8):                     # drift: disjoint head
            check(step_ins(64, 96))
        assert ex.stats["hot_swaps"] >= 1, ex.stats
        assert ex.slab_epoch >= 1
        swapped = {n: set(v) for n, v in ex.hot_rows.items()}
        for n in swapped:                      # re-ranked onto the new head
            assert len(swapped[n]) == 32
            assert swapped[n] & set(range(64, 96))
        # windowed counters age out (satellite: drift visible within one
        # window) while cumulative stats stay blended
        for _ in range(6):
            check(step_ins(64, 96))
        ws = ex.window_stats()
        assert ws["hot_traffic_fraction"] > 0.5
        cum = ex.stats["hot_lookups"] / (
            ex.stats["hot_lookups"] + ex.stats["cold_lookups"])
        assert cum < ws["hot_traffic_fraction"]   # history stays blended

        # first post-swap outputs == a cold-built executor with the same
        # hot set, bit for bit (the swap path IS the cold path)
        cold = ProgramExecutor(chot, backend="jax", mesh=mesh,
                               hot_rows=dict(ex.hot_rows))
        ins = step_ins(0, rows)
        got, want = ex.step(ins), cold.step(ins)
        for n in want:
            np.testing.assert_array_equal(np.asarray(got[n]),
                                          np.asarray(want[n]), err_msg=n)

        # ------- churn: >= 10 direct swaps must plateau every cache ------
        hot_a = {n: tuple(range(32)) for n, _ in prog.ops}
        hot_b = {n: tuple(range(100, 132)) for n, _ in prog.ops}
        ex.step(step_ins(0, rows))
        fns0 = len(ex._shard_fns)
        pool0 = ex.pool.stats["entries"]
        restacks0 = ex.stats["table_restacks"]
        for i in range(10):
            assert ex.swap_hot_slab(hot_a if i % 2 else hot_b)
            check(step_ins(0, rows))
        assert ex.stats["hot_swaps"] >= 11
        assert len(ex._shard_fns) == fns0          # zero retraces
        assert ex.pool.stats["entries"] == pool0   # no leaked staging
        assert ex.stats["table_restacks"] >= restacks0 + 10
        for u in ex._units:
            if u.group is not None:
                assert u.plan.epoch == ex.slab_epoch

        # geometry-changing candidate: rejected atomically, never applied
        before = ex.slab_epoch
        assert ex.swap_hot_slab({n: (0, 1) for n, _ in prog.ops}) is False
        assert ex.stats["hot_swaps_rejected"] >= 1
        assert ex.slab_epoch == before
        check(step_ins(0, rows))

        # epoch-checked marshaling: a stale plan fails loud, not stale
        u = next(u for u in ex._units if u.group is not None)
        u.plan.epoch -= 1
        try:
            ex.step(step_ins(0, rows))
            raise AssertionError("stale plan must raise")
        except RuntimeError as e:
            assert "stale access plan" in str(e)
        u.plan.epoch += 1
        check(step_ins(0, rows))
        print("ADAPTIVE_MESH_OK")
    """
    run_on_mesh(code, devices=2, sentinel="ADAPTIVE_MESH_OK")


# ---------------------------------------------------------------------------
# Disagg: swap republishes the artifact; a killed replica re-warms with it
# ---------------------------------------------------------------------------


def test_disagg_swap_republish_and_rewarm():
    import time

    from repro.runtime.embedding_service import ServicePool

    prog = _prog()
    rng = np.random.default_rng(1)
    table = rng.standard_normal((64, 8)).astype(np.float32)

    def step_ins(lo, hi):
        lens = np.full(4, 4, np.int64)
        ptrs = np.zeros(5, np.int64)
        np.cumsum(lens, out=ptrs[1:])
        return {"t": {"table": table, "ptrs": ptrs,
                      "idxs": rng.integers(lo, hi, 16).astype(np.int32)}}

    ref = ProgramExecutor(compile_program(prog, "O3", use_cache=False),
                          backend="jax")
    cfg = AdaptiveHotConfig(window_steps=4, num_windows=2,
                            drift_threshold=0.6, min_swap_interval=4,
                            refine_passes=0)
    with ServicePool(1, rpc_timeout_s=30.0, backoff_s=0.01) as pool:
        ex = ProgramExecutor(
            compile_program(prog, "O3", use_cache=False), backend="jax",
            service="disagg", service_pool=pool,
            hot_rows={"t": tuple(range(16))}, adaptive=cfg)

        def check(ins):
            want, got = ref.step(ins), ex.step(ins)
            np.testing.assert_array_equal(np.asarray(got["t"]),
                                          np.asarray(want["t"]))

        for _ in range(6):                 # reference window: all hot
            check(step_ins(0, 16))
        for _ in range(8):                 # drift to a disjoint head
            check(step_ins(32, 48))
        assert ex.stats["hot_swaps"] >= 1
        published = pool.pool_stats["hot_publishes"]
        assert published >= 1
        assert set(np.asarray(ex._svc_hot["t"])) & set(range(32, 48))

        # kill the only replica right after the swap's republish; the
        # revived replica must re-warm from the rewritten artifact --
        # carrying the POST-swap slab spec, never the bind-time one
        pool.kill_replica(0)
        r = pool.replicas[0]
        spawns0 = r.spawns
        t0 = time.perf_counter()
        # kill_replica leaves state "live" until heartbeats notice the dead
        # socket, so drive them until the replica has actually respawned
        # AND come back live
        while r.spawns == spawns0 or r.state != "live":
            pool.heartbeat_once()
            time.sleep(0.05)
            assert time.perf_counter() - t0 < 120, "revive timed out"
        s = pool.stats()
        assert s["warm_sources"][-1] == "artifact"
        ping = pool.replicas[0].hb.call("ping")[0]
        assert ping["hot_epoch"] == published
        check(step_ins(0, 64))             # and it still serves, identical


# ---------------------------------------------------------------------------
# DecodeServer capacity_rps="auto" self-calibration
# ---------------------------------------------------------------------------


def test_capacity_rps_auto_calibrates():
    from test_server import EchoLM, _req

    from repro.runtime.server import DecodeServer

    srv = DecodeServer(EchoLM(), {}, batch_slots=2, max_len=32,
                       capacity_rps="auto", capacity_warmup_waves=2)
    assert srv.capacity_rps is None        # unarmed until warmup waves
    reqs = [_req([i + 1], max_new_tokens=6) for i in range(4)]
    for r in reqs:
        srv.submit(r)
    srv.run_until_drained()
    assert all(r.done for r in reqs)
    assert srv.capacity_rps is not None and srv.capacity_rps > 0
    live = srv.serve_stats["capacity_rps_live"]
    assert live is not None and live == round(srv.capacity_rps, 2)


def test_capacity_rps_fixed_stays_fixed():
    from test_server import EchoLM, _req

    from repro.runtime.server import DecodeServer

    srv = DecodeServer(EchoLM(), {}, batch_slots=1, max_len=32,
                       capacity_rps=5.0)
    r = _req([1], max_new_tokens=3)
    srv.submit(r)
    srv.run_until_drained()
    assert srv.capacity_rps == 5.0
    assert srv.serve_stats["capacity_rps_live"] is None
