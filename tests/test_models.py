"""Per-architecture smoke tests (deliverable f): every assigned arch, as a
reduced same-family config, runs one forward + one train step on CPU with
shape and finiteness assertions — plus decode-parity tests for the
recurrent families (chunked/parallel training path ≡ sequential decode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, get_reduced, list_archs
from repro.models import LM
from repro.optim import adamw, apply_updates

ARCHS = list_archs()


def _batch(cfg, b=2, s=16, seed=0):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    out = {"tokens": jax.random.randint(k1, (b, s), 0, cfg.vocab_size),
           "labels": jax.random.randint(k2, (b, s), 0, cfg.vocab_size)}
    if cfg.modality == "audio-stub":
        out["enc_embeds"] = jax.random.normal(k3, (b, s, cfg.d_model))
    if cfg.modality == "vision-stub":
        out["frontend_embeds"] = jax.random.normal(k3, (b, 8, cfg.d_model))
    return out


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_instantiates(arch):
    """The paper-exact config is structurally sound (abstract init only)."""
    cfg = get_config(arch)
    lm = LM(cfg)
    shapes = jax.eval_shape(lm.init, jax.random.PRNGKey(0))
    n_params = sum(np.prod(l.shape) for l in jax.tree.leaves(shapes))
    assert n_params > 1e8, (arch, n_params)  # all assigned archs are ≥1B-ish
    assert cfg.num_layers == cfg.n_super * len(cfg.block_pattern) + \
        len(cfg.remainder_pattern)


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_reduced(arch)
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    x, aux = lm.forward(params, batch)
    assert x.shape == (2, 16, cfg.d_model)
    assert bool(jnp.isfinite(x).all()), arch

    opt = adamw(lr=1e-3)
    opt_state = opt.init(params)

    @jax.jit
    def step(p, o, b):
        loss, g = jax.value_and_grad(lm.loss)(p, b)
        u, o = opt.update(g, o, p)
        return apply_updates(p, u), o, loss

    p1, o1, loss = step(params, opt_state, batch)
    assert np.isfinite(float(loss)), arch
    assert all(bool(jnp.isfinite(l).all()) for l in jax.tree.leaves(p1))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_step(arch):
    cfg = get_reduced(arch)
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    caches = lm.init_caches(2, 32)
    tok = jnp.zeros((2, 1), jnp.int32)
    ctx = None
    if cfg.enc_layers:
        ctx = {"enc_out": jax.random.normal(jax.random.PRNGKey(1),
                                            (2, 16, cfg.d_model))}
    logits, caches2 = lm.decode_step(params, tok, caches, batch_ctx=ctx)
    assert logits.shape == (2, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), arch
    # cache structure preserved
    assert jax.tree.structure(caches) == jax.tree.structure(caches2)


@pytest.mark.parametrize("arch", ["xlstm-1.3b", "zamba2-7b"])
def test_recurrent_forward_matches_decode(arch):
    """Chunk-parallel training path ≡ sequential decode (the invariant that
    makes long_500k serving trustworthy for the sub-quadratic archs)."""
    cfg = get_reduced(arch)
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    s = 12
    batch = _batch(cfg, b=2, s=s, seed=3)
    hs, _ = lm.forward(params, batch)

    caches = lm.init_caches(2, s + 4)
    outs = []
    from repro.core.embedding_engine import logits as unembed
    for t in range(s):
        lg, caches = lm.decode_step(params, batch["tokens"][:, t:t + 1],
                                    caches)
        outs.append(lg)
    lg_fwd = unembed(hs, params["embed"])
    lg_dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(lg_dec, np.float32),
                               np.asarray(lg_fwd, np.float32),
                               rtol=2e-3, atol=2e-3)


def test_cost_mode_flop_parity_shapes():
    """Cost-mode (dense/unrolled) lowering produces the same output shapes
    as the production path (it is a lowering-only artifact)."""
    from repro.models import ShardCtx
    cfg = get_reduced("stablelm-3b")
    lm_prod = LM(cfg)
    lm_cost = LM(cfg, ShardCtx(cost_mode=True))
    params = jax.eval_shape(lm_prod.init, jax.random.PRNGKey(0))
    batch = {"tokens": jax.ShapeDtypeStruct((2, 16), jnp.int32),
             "labels": jax.ShapeDtypeStruct((2, 16), jnp.int32)}
    a = jax.eval_shape(lm_prod.loss, params, batch)
    b = jax.eval_shape(lm_cost.loss, params, batch)
    assert a.shape == b.shape == ()


def test_moe_capacity_drops_are_bounded():
    from repro.models import moe as moe_mod
    cfg = get_reduced("qwen3-moe-235b-a22b")
    p = moe_mod.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (64, cfg.d_model))
    out, aux = moe_mod.moe_ffn_local(p, x, cfg)
    assert out.shape == x.shape
    assert float(aux) > 0.5  # aux ≈ 1 for near-uniform routing


def test_int8_kv_cache_decode_parity():
    """Beyond-paper serving optimization: int8 block-scaled KV cache.
    Greedy decode must agree with the bf16 cache (and the cache must be
    ≥3× smaller)."""
    import dataclasses
    cfg = get_reduced("stablelm-3b")
    cfg8 = dataclasses.replace(cfg, kv_cache_dtype="int8")
    lm, lm8 = LM(cfg), LM(cfg8)
    params = lm.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 10), 0,
                              cfg.vocab_size)
    c, c8 = lm.init_caches(2, 16), lm8.init_caches(2, 16)
    outs, outs8 = [], []
    for t in range(10):
        lg, c = lm.decode_step(params, toks[:, t:t + 1], c)
        lg8, c8 = lm8.decode_step(params, toks[:, t:t + 1], c8)
        outs.append(lg)
        outs8.append(lg8)
    a = jnp.concatenate(outs, 1)
    b = jnp.concatenate(outs8, 1)
    agree = float((jnp.argmax(a, -1) == jnp.argmax(b, -1)).mean())
    assert agree > 0.95, agree
    nb = sum(x.nbytes for x in jax.tree.leaves(c))
    nb8 = sum(x.nbytes for x in jax.tree.leaves(c8))
    assert nb8 * 3 < nb, (nb, nb8)
