"""Differential test harness — the oracle of record for the executor.

Randomly generated :class:`EmbeddingProgram`s (mixed sls/kg/gather,
weighted/unweighted, shared tables, mixed semirings) and random ragged CSR
steps (zero-length segments, empty steps, pow-2-boundary nnz) run through
the steady-state :class:`ProgramExecutor` and must reproduce the
``core/interp.py`` DLC oracle (``run_program_interpreted`` — the
queue-faithful interpreter of the SAME compiled artifact) across the full
configuration cross-product:

    opt_level × backend(jax|pallas) × mesh(1|2) × hot_rows(off|on)
              × exchange(host|collective) × replicate_outputs

The deterministic corpus below needs nothing beyond numpy (the full
``pytest`` run sweeps ≥200 generated program/step cases; ``--fast`` — the
``tier1.sh --fast`` smoke — keeps a small subset, the same way tier1.sh
gates the benches).  When ``hypothesis`` is installed (requirements-dev,
CI) an additional property test explores the same generator space with
shrinking.  The 2-device mesh leg runs the corpus in a forced-2-device
subprocess via the ``run_on_mesh`` conftest fixture.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.core.executor import ProgramExecutor
from repro.core.ops import EmbeddingOp, EmbeddingProgram, Semiring
from repro.core.pipeline import compile_program, run_program_interpreted

VLEN = 4
ATOL = RTOL = 1e-5

# full-run corpus size: 28 seeds × (2 opt levels × 2 backends × 2 steps)
# = 224 differential cases on the single-device leg alone (the 2-device
# leg and the hypothesis sweep add more); --fast keeps 4 seeds.
SEEDS_FULL = 28
SEEDS_FAST = 4

_SEMIRINGS = (Semiring(), Semiring(), Semiring(),        # mostly (add, mul)
              Semiring("max"), Semiring("min"),
              Semiring("max", "add"))


# ---------------------------------------------------------------------------
# Generators (shared by the corpus tests, the hypothesis strategy, and the
# 2-device subprocess — keep them importable without pytest fixtures)
# ---------------------------------------------------------------------------

def gen_program(pick_int, pick_bool) -> EmbeddingProgram:
    """Build a random program from two primitive choice functions
    (``pick_int(lo, hi)`` inclusive, ``pick_bool()``) so the same generator
    space serves seeded-rng corpora and hypothesis draws."""
    n_ops = pick_int(1, 4)
    emb_base = (4, 8)[pick_int(0, 1)]
    ops = []
    for i in range(n_ops):
        kind = ("sls", "sls", "kg", "gather")[pick_int(0, 3)]
        # an off-width op becomes an unfusable singleton now and then
        emb = emb_base if pick_int(0, 4) else (4 if emb_base == 8 else 8)
        sr = _SEMIRINGS[pick_int(0, len(_SEMIRINGS) - 1)]
        if kind == "gather":
            op = EmbeddingOp("gather", pick_int(1, 5), pick_int(1, 16),
                             emb, block_rows=pick_int(1, 2))
        elif kind == "kg":
            op = EmbeddingOp("kg", pick_int(1, 6), pick_int(1, 20), emb,
                             semiring=sr)
        else:
            op = EmbeddingOp("sls", pick_int(1, 6), pick_int(1, 20), emb,
                             avg_lookups=pick_int(0, 4),
                             weighted=pick_bool(), semiring=sr)
        ops.append((f"op{i}", op))
    # shared tables: any same-shape pair of same-kind ops may share
    shared = []
    if len(ops) >= 2 and pick_bool():
        for i in range(len(ops)):
            for j in range(i + 1, len(ops)):
                a, b = ops[i][1], ops[j][1]
                if (a.kind == b.kind and
                        a.num_embeddings == b.num_embeddings and
                        a.emb_len == b.emb_len and
                        a.block_rows == b.block_rows):
                    shared.append((ops[i][0], ops[j][0]))
                    break
            if shared:
                break
    return EmbeddingProgram("diff", tuple(ops),
                            shared_tables=tuple(shared))


def random_program(rng) -> EmbeddingProgram:
    return gen_program(lambda lo, hi: int(rng.integers(lo, hi + 1)),
                       lambda: bool(rng.integers(0, 2)))


def random_tables(rng, prog: EmbeddingProgram) -> dict:
    """One table array per op (shared-table groups alias ONE array —
    steady-state params the executor binds once)."""
    tables: dict = {}
    by_slot: dict = {}
    for name, op in prog.ops:
        slot = prog.table_slot(name)
        if slot not in by_slot:
            rows = op.num_embeddings * (op.block_rows
                                        if op.kind == "gather" else 1)
            by_slot[slot] = rng.standard_normal(
                (rows, op.emb_len)).astype(np.float32)
        tables[name] = by_slot[slot]
    return tables


def random_step(rng, prog: EmbeddingProgram, tables: dict) -> dict:
    """One ragged step: Poisson segment lengths with a fat tail of
    zero-length segments, ~1-in-8 fully-empty CSR streams, and uniform
    indices (the mesh leg layers hot/cold on top)."""
    step: dict = {}
    for name, op in prog.ops:
        ins: dict = {"table": tables[name]}
        if op.kind == "gather":
            ins["idxs"] = rng.integers(
                0, op.num_embeddings, op.num_segments).astype(np.int32)
        elif op.kind == "kg":
            ins["idxs"] = rng.integers(
                0, op.num_embeddings, op.num_segments).astype(np.int32)
            ins["vals"] = rng.standard_normal(
                op.num_segments).astype(np.float32)
        else:
            lens = rng.poisson(max(op.avg_lookups, 1), op.num_segments)
            lens[rng.random(op.num_segments) < 0.25] = 0
            if rng.random() < 0.125:
                lens[:] = 0                      # empty step
            ptrs = np.zeros(op.num_segments + 1, np.int64)
            np.cumsum(lens, out=ptrs[1:])
            nnz = int(ptrs[-1])
            ins["ptrs"] = ptrs
            ins["idxs"] = rng.integers(
                0, op.num_embeddings, nnz).astype(np.int32)
            if op.weighted:
                ins["vals"] = rng.standard_normal(nnz).astype(np.float32)
        step[name] = ins
    return step


def random_hot_rows(rng, prog: EmbeddingProgram) -> dict:
    """A random hot classification: up to half of each vocab's rows."""
    hot: dict = {}
    for name, op in prog.ops:
        k = int(rng.integers(0, max(op.num_embeddings // 2, 1) + 1))
        if k:
            hot[name] = tuple(int(i) for i in rng.choice(
                op.num_embeddings, size=k, replace=False))
    return hot


def check_case(pres, ex: ProgramExecutor, steps: list, oracles: list,
               tag: str) -> int:
    """Run ``steps`` through ``ex`` and compare each against its DLC-interp
    oracle; returns the number of (program, step) cases checked."""
    for k, (ins, want) in enumerate(zip(steps, oracles)):
        got = ex.step(ins)
        for n in want:
            np.testing.assert_allclose(
                np.asarray(got[n]), want[n], rtol=RTOL, atol=ATOL,
                err_msg=f"{tag} step {k} op {n}")
    return len(steps)


def run_differential_seed(seed: int, opt_levels=None) -> int:
    """One corpus seed on the single-device leg: compile per opt level,
    oracle once per (opt level, step), executor per backend."""
    rng = np.random.default_rng(seed)
    prog = random_program(rng)
    tables = random_tables(rng, prog)
    steps = [random_step(rng, prog, tables) for _ in range(2)]
    opts = opt_levels or (("O1", "O3") if seed % 2 == 0 else ("O2", "O3"))
    cases = 0
    for opt in opts:
        pres = compile_program(prog, opt, vlen=VLEN, use_cache=False)
        oracles = [run_program_interpreted(pres, s) for s in steps]
        for backend in ("jax", "pallas"):
            ex = ProgramExecutor(pres, backend=backend)
            cases += check_case(pres, ex, steps, oracles,
                                f"seed {seed} {opt} {backend}")
    return cases


# ---------------------------------------------------------------------------
# Single-device corpus (no hypothesis required)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(SEEDS_FULL))
def test_differential_corpus_single_device(seed, fast_mode):
    if fast_mode and seed >= SEEDS_FAST:
        pytest.skip("--fast smoke subset (full run sweeps all seeds)")
    assert run_differential_seed(seed) == 8   # 2 opts × 2 backends × 2 steps


# ---------------------------------------------------------------------------
# Hypothesis sweep of the same generator space (CI installs hypothesis;
# the container suite skips, exactly like tests/test_ir_property.py)
# ---------------------------------------------------------------------------

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    @st.composite
    def _programs(draw):
        prog = gen_program(lambda lo, hi: draw(st.integers(lo, hi)),
                           lambda: draw(st.booleans()))
        return prog, draw(st.integers(0, 2 ** 31 - 1))

    # max_examples comes from the profile conftest loads (20 full / 5 fast)
    @settings(deadline=None,
              suppress_health_check=[HealthCheck.too_slow,
                                     HealthCheck.data_too_large])
    @given(case=_programs())
    def test_differential_hypothesis(case):
        prog, seed = case
        rng = np.random.default_rng(seed)
        tables = random_tables(rng, prog)
        steps = [random_step(rng, prog, tables)]
        pres = compile_program(prog, "O3", vlen=VLEN, use_cache=False)
        oracles = [run_program_interpreted(pres, s) for s in steps]
        for backend in ("jax", "pallas"):
            ex = ProgramExecutor(pres, backend=backend)
            check_case(pres, ex, steps, oracles, f"hyp {backend}")

except ImportError:      # pragma: no cover - exercised in the container
    @pytest.mark.skip(reason="property sweep needs hypothesis "
                             "(pip install -r requirements-dev.txt)")
    def test_differential_hypothesis():
        pass


# ---------------------------------------------------------------------------
# 2-device mesh leg: the corpus across hot_rows × exchange ×
# replicate_outputs, in a forced-2-device subprocess
# ---------------------------------------------------------------------------

def test_differential_two_device_mesh(run_on_mesh, fast_mode):
    seeds = 2 if fast_mode else 6
    code = f"""
        import sys
        sys.path.insert(0, "tests")
        import numpy as np
        import jax
        import test_differential as td
        from repro.core.executor import ProgramExecutor
        from repro.core.pipeline import (compile_program,
                                         run_program_interpreted)
        from repro.launch.mesh import axis_types_kw

        mesh = jax.make_mesh((1, 2), ("data", "model"), **axis_types_kw(2))
        cases = 0
        for seed in range({seeds}):
            rng = np.random.default_rng(10_000 + seed)
            prog = td.random_program(rng)
            tables = td.random_tables(rng, prog)
            steps = [td.random_step(rng, prog, tables) for _ in range(2)]
            hot = td.random_hot_rows(rng, prog)
            pres = compile_program(prog, "O3", vlen=td.VLEN,
                                   use_cache=False)
            oracles = [run_program_interpreted(pres, s) for s in steps]
            for backend in ("jax", "pallas"):
                for exchange, repl in (("host", True),
                                       ("collective", False),
                                       ("collective", True)):
                    for hr in (None, hot):
                        ex = ProgramExecutor(
                            pres, backend=backend, mesh=mesh,
                            exchange=exchange, replicate_outputs=repl,
                            hot_rows=hr)
                        cases += td.check_case(
                            pres, ex, steps, oracles,
                            f"seed {{seed}} {{backend}} {{exchange}} "
                            f"repl={{repl}} hot={{hr is not None}}")
        print("DIFF_MESH_OK", cases)
    """
    r = run_on_mesh(code, devices=2, timeout=1800, sentinel="DIFF_MESH_OK")
    cases = int(r.stdout.split("DIFF_MESH_OK")[-1].split()[0])
    assert cases == seeds * 2 * 3 * 2 * 2   # backends×exchange/repl×hot×steps
