"""Differential test harness — the oracle of record for the executor.

Randomly generated :class:`EmbeddingProgram`s (mixed sls/kg/gather,
weighted/unweighted, shared tables, mixed semirings) and random ragged CSR
steps (zero-length segments, empty steps, pow-2-boundary nnz) run through
the steady-state :class:`ProgramExecutor` and must reproduce the
``core/interp.py`` DLC oracle (``run_program_interpreted`` — the
queue-faithful interpreter of the SAME compiled artifact) across the full
configuration cross-product:

    opt_level × backend(jax|pallas) × mesh(1|2) × hot_rows(off|on)
              × exchange(host|collective) × replicate_outputs

The deterministic corpus below needs nothing beyond numpy (the full
``pytest`` run sweeps ≥200 generated program/step cases; ``--fast`` — the
``tier1.sh --fast`` smoke — keeps a small subset, the same way tier1.sh
gates the benches).  When ``hypothesis`` is installed (requirements-dev,
CI) an additional property test explores the same generator space with
shrinking.  The 2-device mesh leg runs the corpus in a forced-2-device
subprocess via the ``run_on_mesh`` conftest fixture.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.core.executor import ProgramExecutor
from repro.core.ops import EmbeddingOp, EmbeddingProgram, Semiring
from repro.core.pipeline import compile_program, run_program_interpreted

VLEN = 4
ATOL = RTOL = 1e-5

# full-run corpus size: 28 seeds × (2 opt levels × 2 backends × 2 steps)
# = 224 differential cases on the single-device leg alone (the 2-device
# leg and the hypothesis sweep add more); --fast keeps 4 seeds.
SEEDS_FULL = 28
SEEDS_FAST = 4

_SEMIRINGS = (Semiring(), Semiring(), Semiring(),        # mostly (add, mul)
              Semiring("max"), Semiring("min"),
              Semiring("max", "add"))


# ---------------------------------------------------------------------------
# Generators (shared by the corpus tests, the hypothesis strategy, and the
# 2-device subprocess — keep them importable without pytest fixtures)
# ---------------------------------------------------------------------------

def gen_program(pick_int, pick_bool) -> EmbeddingProgram:
    """Build a random program from two primitive choice functions
    (``pick_int(lo, hi)`` inclusive, ``pick_bool()``) so the same generator
    space serves seeded-rng corpora and hypothesis draws."""
    n_ops = pick_int(1, 4)
    emb_base = (4, 8)[pick_int(0, 1)]
    ops = []
    for i in range(n_ops):
        kind = ("sls", "sls", "kg", "gather")[pick_int(0, 3)]
        # an off-width op becomes an unfusable singleton now and then
        emb = emb_base if pick_int(0, 4) else (4 if emb_base == 8 else 8)
        sr = _SEMIRINGS[pick_int(0, len(_SEMIRINGS) - 1)]
        if kind == "gather":
            op = EmbeddingOp("gather", pick_int(1, 5), pick_int(1, 16),
                             emb, block_rows=pick_int(1, 2))
        elif kind == "kg":
            op = EmbeddingOp("kg", pick_int(1, 6), pick_int(1, 20), emb,
                             semiring=sr)
        else:
            op = EmbeddingOp("sls", pick_int(1, 6), pick_int(1, 20), emb,
                             avg_lookups=pick_int(0, 4),
                             weighted=pick_bool(), semiring=sr)
        ops.append((f"op{i}", op))
    # shared tables: any same-shape pair of same-kind ops may share
    shared = []
    if len(ops) >= 2 and pick_bool():
        for i in range(len(ops)):
            for j in range(i + 1, len(ops)):
                a, b = ops[i][1], ops[j][1]
                if (a.kind == b.kind and
                        a.num_embeddings == b.num_embeddings and
                        a.emb_len == b.emb_len and
                        a.block_rows == b.block_rows):
                    shared.append((ops[i][0], ops[j][0]))
                    break
            if shared:
                break
    return EmbeddingProgram("diff", tuple(ops),
                            shared_tables=tuple(shared))


def random_program(rng) -> EmbeddingProgram:
    return gen_program(lambda lo, hi: int(rng.integers(lo, hi + 1)),
                       lambda: bool(rng.integers(0, 2)))


def random_tables(rng, prog: EmbeddingProgram) -> dict:
    """One table array per op (shared-table groups alias ONE array —
    steady-state params the executor binds once)."""
    tables: dict = {}
    by_slot: dict = {}
    for name, op in prog.ops:
        slot = prog.table_slot(name)
        if slot not in by_slot:
            rows = op.num_embeddings * (op.block_rows
                                        if op.kind == "gather" else 1)
            by_slot[slot] = rng.standard_normal(
                (rows, op.emb_len)).astype(np.float32)
        tables[name] = by_slot[slot]
    return tables


def random_step(rng, prog: EmbeddingProgram, tables: dict) -> dict:
    """One ragged step: Poisson segment lengths with a fat tail of
    zero-length segments, ~1-in-8 fully-empty CSR streams, and uniform
    indices (the mesh leg layers hot/cold on top)."""
    step: dict = {}
    for name, op in prog.ops:
        ins: dict = {"table": tables[name]}
        if op.kind == "gather":
            ins["idxs"] = rng.integers(
                0, op.num_embeddings, op.num_segments).astype(np.int32)
        elif op.kind == "kg":
            ins["idxs"] = rng.integers(
                0, op.num_embeddings, op.num_segments).astype(np.int32)
            ins["vals"] = rng.standard_normal(
                op.num_segments).astype(np.float32)
        else:
            lens = rng.poisson(max(op.avg_lookups, 1), op.num_segments)
            lens[rng.random(op.num_segments) < 0.25] = 0
            if rng.random() < 0.125:
                lens[:] = 0                      # empty step
            ptrs = np.zeros(op.num_segments + 1, np.int64)
            np.cumsum(lens, out=ptrs[1:])
            nnz = int(ptrs[-1])
            ins["ptrs"] = ptrs
            ins["idxs"] = rng.integers(
                0, op.num_embeddings, nnz).astype(np.int32)
            if op.weighted:
                ins["vals"] = rng.standard_normal(nnz).astype(np.float32)
        step[name] = ins
    return step


def random_hot_rows(rng, prog: EmbeddingProgram) -> dict:
    """A random hot classification: up to half of each vocab's rows."""
    hot: dict = {}
    for name, op in prog.ops:
        k = int(rng.integers(0, max(op.num_embeddings // 2, 1) + 1))
        if k:
            hot[name] = tuple(int(i) for i in rng.choice(
                op.num_embeddings, size=k, replace=False))
    return hot


def check_case(pres, ex: ProgramExecutor, steps: list, oracles: list,
               tag: str) -> int:
    """Run ``steps`` through ``ex`` and compare each against its DLC-interp
    oracle; returns the number of (program, step) cases checked."""
    for k, (ins, want) in enumerate(zip(steps, oracles)):
        got = ex.step(ins)
        for n in want:
            np.testing.assert_allclose(
                np.asarray(got[n]), want[n], rtol=RTOL, atol=ATOL,
                err_msg=f"{tag} step {k} op {n}")
    return len(steps)


def run_differential_seed(seed: int, opt_levels=None) -> int:
    """One corpus seed on the single-device leg: compile per opt level,
    oracle once per (opt level, step), executor per backend."""
    rng = np.random.default_rng(seed)
    prog = random_program(rng)
    tables = random_tables(rng, prog)
    steps = [random_step(rng, prog, tables) for _ in range(2)]
    opts = opt_levels or (("O1", "O3") if seed % 2 == 0 else ("O2", "O3"))
    cases = 0
    for opt in opts:
        pres = compile_program(prog, opt, vlen=VLEN, use_cache=False)
        oracles = [run_program_interpreted(pres, s) for s in steps]
        for backend in ("jax", "pallas"):
            ex = ProgramExecutor(pres, backend=backend)
            cases += check_case(pres, ex, steps, oracles,
                                f"seed {seed} {opt} {backend}")
    return cases


# ---------------------------------------------------------------------------
# Single-device corpus (no hypothesis required)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(SEEDS_FULL))
def test_differential_corpus_single_device(seed, fast_mode):
    if fast_mode and seed >= SEEDS_FAST:
        pytest.skip("--fast smoke subset (full run sweeps all seeds)")
    assert run_differential_seed(seed) == 8   # 2 opts × 2 backends × 2 steps


# ---------------------------------------------------------------------------
# Hypothesis sweep of the same generator space (CI installs hypothesis;
# the container suite skips, exactly like tests/test_ir_property.py)
# ---------------------------------------------------------------------------

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    @st.composite
    def _programs(draw):
        prog = gen_program(lambda lo, hi: draw(st.integers(lo, hi)),
                           lambda: draw(st.booleans()))
        return prog, draw(st.integers(0, 2 ** 31 - 1))

    # max_examples comes from the profile conftest loads (20 full / 5 fast)
    @settings(deadline=None,
              suppress_health_check=[HealthCheck.too_slow,
                                     HealthCheck.data_too_large])
    @given(case=_programs())
    def test_differential_hypothesis(case):
        prog, seed = case
        rng = np.random.default_rng(seed)
        tables = random_tables(rng, prog)
        steps = [random_step(rng, prog, tables)]
        pres = compile_program(prog, "O3", vlen=VLEN, use_cache=False)
        oracles = [run_program_interpreted(pres, s) for s in steps]
        for backend in ("jax", "pallas"):
            ex = ProgramExecutor(pres, backend=backend)
            check_case(pres, ex, steps, oracles, f"hyp {backend}")

except ImportError:      # pragma: no cover - exercised in the container
    @pytest.mark.skip(reason="property sweep needs hypothesis "
                             "(pip install -r requirements-dev.txt)")
    def test_differential_hypothesis():
        pass


# ---------------------------------------------------------------------------
# 2-device mesh leg: the corpus across hot_rows × exchange ×
# replicate_outputs, in a forced-2-device subprocess
# ---------------------------------------------------------------------------

# ---------------------------------------------------------------------------
# Malformed-stream leg (PR 7): corrupted index streams through the hardened
# executor vs independent repair oracles
# ---------------------------------------------------------------------------

def corrupt_step(rng, prog: EmbeddingProgram, step: dict):
    """Copy ``step`` with ~1/3 of each op's indices pushed out of bounds
    (negative and >= vocab).  Returns ``(bad_step, n_gather_kg, n_csr)`` —
    the per-kind OOB counts the hardened executor must report."""
    bad = {n: dict(ins) for n, ins in step.items()}
    n_gk = n_csr = 0
    for name, op in prog.ops:
        idxs = np.asarray(bad[name]["idxs"])
        if idxs.size == 0:
            continue
        k = max(1, idxs.size // 3)
        pos = rng.choice(idxs.size, size=k, replace=False)
        rows = op.num_embeddings
        oob = np.where(rng.integers(0, 2, k) == 0,
                       -1 - rng.integers(0, 3, k),
                       rows + rng.integers(0, 5, k))
        out = idxs.copy()
        out[pos] = oob
        bad[name]["idxs"] = out.astype(np.int32)
        if op.kind in ("gather", "kg"):
            n_gk += k
        else:
            n_csr += k
    return bad, n_gk, n_csr


def clamp_reference(prog: EmbeddingProgram, step: dict) -> dict:
    """The clamp oracle input: every index clipped into its vocab."""
    ref = {}
    for name, op in prog.ops:
        ins = dict(step[name])
        ins["idxs"] = np.clip(np.asarray(ins["idxs"]), 0,
                              op.num_embeddings - 1).astype(np.int32)
        ref[name] = ins
    return ref


def drop_reference(prog: EmbeddingProgram, step: dict) -> dict:
    """The drop oracle input: CSR ops excise their OOB entries (ptrs
    rebuilt); gather/kg keep one lookup per segment, so drop degrades to
    clamp there — the same contract the executor documents."""
    ref = {}
    for name, op in prog.ops:
        ins = dict(step[name])
        idxs = np.asarray(ins["idxs"])
        rows = op.num_embeddings
        oob = (idxs < 0) | (idxs >= rows)
        if op.kind in ("gather", "kg"):
            ins["idxs"] = np.clip(idxs, 0, rows - 1).astype(np.int32)
        elif oob.any():
            ptrs = np.asarray(ins["ptrs"], np.int64)
            seg = np.repeat(np.arange(op.num_segments), np.diff(ptrs))
            keep = ~oob
            kept = np.bincount(seg[keep], minlength=op.num_segments)
            new_ptrs = np.zeros(op.num_segments + 1, np.int64)
            np.cumsum(kept, out=new_ptrs[1:])
            ins["ptrs"] = new_ptrs
            ins["idxs"] = idxs[keep].astype(np.int32)
            if "vals" in ins:
                ins["vals"] = np.asarray(ins["vals"])[keep]
        ref[name] = ins
    return ref


@pytest.mark.parametrize("seed", range(6))
def test_differential_malformed_streams(seed, fast_mode):
    """strict raises typed, clamp/drop match their repair oracles with
    exact per-policy counters, and a post-fault reset serves clean steps
    bit-identically — on both backends."""
    from repro.core.access_plan import MalformedAccessError
    if fast_mode and seed >= 2:
        pytest.skip("--fast smoke subset (full run sweeps all seeds)")
    rng = np.random.default_rng(5_000 + seed)
    prog = random_program(rng)
    tables = random_tables(rng, prog)
    n_gk = n_csr = 0
    for _ in range(8):           # all-empty steps have nothing to corrupt
        clean = random_step(rng, prog, tables)
        bad, n_gk, n_csr = corrupt_step(rng, prog, clean)
        if n_gk + n_csr:
            break
    assert n_gk + n_csr > 0
    pres = compile_program(prog, "O3", vlen=VLEN, use_cache=False)
    clean_oracle = run_program_interpreted(pres, clean)
    clamp_oracle = run_program_interpreted(pres, clamp_reference(prog, bad))
    drop_oracle = run_program_interpreted(pres, drop_reference(prog, bad))
    for backend in ("jax", "pallas"):
        tag = f"seed {seed} {backend}"
        # strict: typed error, and the executor recovers after reset
        ex = ProgramExecutor(pres, backend=backend)
        with pytest.raises(MalformedAccessError, match="outside"):
            ex.step(bad)
        ex.reset()
        got = ex.step(clean)
        for n in clean_oracle:
            np.testing.assert_allclose(
                np.asarray(got[n]), np.asarray(clean_oracle[n]),
                rtol=RTOL, atol=ATOL, err_msg=f"{tag} post-strict {n}")
        # clamp: repaired output == oracle on clipped inputs, all counted
        exc = ProgramExecutor(pres, backend=backend, index_policy="clamp")
        got = exc.step(bad)
        for n in clamp_oracle:
            np.testing.assert_allclose(
                np.asarray(got[n]), np.asarray(clamp_oracle[n]),
                rtol=RTOL, atol=ATOL, err_msg=f"{tag} clamp {n}")
        assert exc.stats["oob_lookups"] == n_gk + n_csr
        assert exc.stats["dropped_lookups"] == 0
        # drop: CSR entries excised (counted dropped), gather/kg clamped
        exd = ProgramExecutor(pres, backend=backend, index_policy="drop")
        got = exd.step(bad)
        for n in drop_oracle:
            np.testing.assert_allclose(
                np.asarray(got[n]), np.asarray(drop_oracle[n]),
                rtol=RTOL, atol=ATOL, err_msg=f"{tag} drop {n}")
        assert exd.stats["oob_lookups"] == n_gk
        assert exd.stats["dropped_lookups"] == n_csr


def test_hardening_clean_inputs_bit_identical():
    """The acceptance bar: hardened policies are zero-cost on clean
    streams — outputs bit-identical (not merely close) across policies."""
    rng = np.random.default_rng(77)
    prog = random_program(rng)
    tables = random_tables(rng, prog)
    steps = [random_step(rng, prog, tables) for _ in range(2)]
    pres = compile_program(prog, "O3", vlen=VLEN, use_cache=False)
    outs = {}
    for policy in ("strict", "clamp", "drop"):
        ex = ProgramExecutor(pres, backend="jax", index_policy=policy)
        outs[policy] = [ex.step(s) for s in steps]
        assert ex.stats["oob_lookups"] == 0
        assert ex.stats["dropped_lookups"] == 0
    for k in range(len(steps)):
        for n in outs["strict"][k]:
            for policy in ("clamp", "drop"):
                np.testing.assert_array_equal(
                    np.asarray(outs["strict"][k][n]),
                    np.asarray(outs[policy][k][n]),
                    err_msg=f"step {k} op {n} policy {policy}")


def test_structural_damage_raises_under_every_policy():
    """Non-monotone ptrs are structural (unrepairable) — typed error even
    under clamp/drop."""
    from repro.core.access_plan import MalformedAccessError
    prog = EmbeddingProgram("bad", (
        ("s", EmbeddingOp("sls", 3, 8, 8, avg_lookups=2)),))
    pres = compile_program(prog, "O3", vlen=VLEN, use_cache=False)
    table = np.zeros((8, 8), np.float32)
    ins = {"s": {"table": table,
                 "ptrs": np.array([0, 3, 1, 4], np.int64),
                 "idxs": np.zeros(4, np.int32)}}
    for policy in ("strict", "clamp", "drop"):
        ex = ProgramExecutor(pres, backend="jax", index_policy=policy)
        with pytest.raises(MalformedAccessError, match="non-decreasing"):
            ex.step(ins)


def test_differential_two_device_mesh(run_on_mesh, fast_mode):
    seeds = 2 if fast_mode else 6
    code = f"""
        import sys
        sys.path.insert(0, "tests")
        import numpy as np
        import jax
        import test_differential as td
        from repro.core.executor import ProgramExecutor
        from repro.core.pipeline import (compile_program,
                                         run_program_interpreted)
        from repro.launch.mesh import axis_types_kw

        mesh = jax.make_mesh((1, 2), ("data", "model"), **axis_types_kw(2))
        cases = 0
        for seed in range({seeds}):
            rng = np.random.default_rng(10_000 + seed)
            prog = td.random_program(rng)
            tables = td.random_tables(rng, prog)
            steps = [td.random_step(rng, prog, tables) for _ in range(2)]
            hot = td.random_hot_rows(rng, prog)
            pres = compile_program(prog, "O3", vlen=td.VLEN,
                                   use_cache=False)
            oracles = [run_program_interpreted(pres, s) for s in steps]
            for backend in ("jax", "pallas"):
                for exchange, repl in (("host", True),
                                       ("collective", False),
                                       ("collective", True)):
                    for hr in (None, hot):
                        ex = ProgramExecutor(
                            pres, backend=backend, mesh=mesh,
                            exchange=exchange, replicate_outputs=repl,
                            hot_rows=hr)
                        cases += td.check_case(
                            pres, ex, steps, oracles,
                            f"seed {{seed}} {{backend}} {{exchange}} "
                            f"repl={{repl}} hot={{hr is not None}}")
        print("DIFF_MESH_OK", cases)
    """
    r = run_on_mesh(code, devices=2, timeout=1800, sentinel="DIFF_MESH_OK")
    cases = int(r.stdout.split("DIFF_MESH_OK")[-1].split()[0])
    assert cases == seeds * 2 * 3 * 2 * 2   # backends×exchange/repl×hot×steps
