"""End-to-end system behaviour: training convergence, checkpoint/restart,
failure injection + supervised restart, straggler watchdog, decode server."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_reduced
from repro.data.pipeline import DataConfig, SyntheticTokens
from repro.models import LM
from repro.runtime.server import DecodeServer, Request
from repro.runtime.trainer import (InjectedFailure, StragglerTimeout,
                                   Trainer, TrainerConfig, run_supervised)


def _mk(tmp_path, arch="stablelm-3b", steps=24, **kw):
    cfg = get_reduced(arch)
    lm = LM(cfg)
    data = SyntheticTokens(DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                                      global_batch=8))
    tcfg = TrainerConfig(total_steps=steps, ckpt_every=8,
                         ckpt_dir=str(tmp_path / "ckpt"), **kw)
    return Trainer(lm, data, tcfg)


def test_training_loss_decreases(tmp_path):
    out = _mk(tmp_path, steps=30).run(jax.random.PRNGKey(0))
    losses = out["losses"]
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.05, losses


def test_checkpoint_restart_continuity(tmp_path):
    t1 = _mk(tmp_path, steps=16)
    out1 = t1.run(jax.random.PRNGKey(0))
    assert out1["final_step"] == 15
    # a fresh trainer resumes from the committed step and finishes further
    t2 = _mk(tmp_path, steps=24)
    out2 = t2.run(jax.random.PRNGKey(0))
    assert out2["final_step"] == 23
    # resumed run only executed the remaining steps
    assert len(out2["losses"]) == 24 - 16


def test_supervised_restart_after_injected_failures(tmp_path):
    out = run_supervised(lambda: _mk(tmp_path, steps=30),
                         jax.random.PRNGKey(0),
                         failure_schedule={10, 20})
    assert out["restarts"] == 2
    assert out["final_step"] == 29


def test_straggler_watchdog(tmp_path):
    t = _mk(tmp_path, steps=5, step_deadline_s=1e-9)
    with pytest.raises(StragglerTimeout):
        t.run(jax.random.PRNGKey(0))


def test_grad_compression_training(tmp_path):
    out = _mk(tmp_path, steps=30, grad_compression=True).run(
        jax.random.PRNGKey(0))
    losses = out["losses"]
    assert np.isfinite(losses).all()
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.03, losses


def test_decode_server_drains(tmp_path):
    cfg = get_reduced("h2o-danube-1.8b")
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    srv = DecodeServer(lm, params, batch_slots=2, max_len=64)
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size, 4).astype(np.int32),
                    max_new_tokens=5) for _ in range(5)]
    for r in reqs:
        srv.submit(r)
    srv.run_until_drained()
    assert all(r.done for r in reqs)
    assert all(len(r.out) == 5 for r in reqs)
    # the compiled access side is observable through compile_stats
    aps = srv.compile_stats["access_plans"]
    assert aps["units"] >= 1 and aps["shards"] == srv.emb_executor.shards
    assert aps["plan_build_s"] >= 0
    for k in ("hot_rows", "hot_slab_bytes", "exchange_index_bytes",
              "exchange_index_bytes_est", "exchange_savings_bytes"):
        assert k in aps


def test_elastic_checkpoint_reshard(tmp_path):
    """Save on one sharding layout, restore onto another (subprocess with 8
    fake devices exercises the offset-based assembly)."""
    import subprocess
    import sys
    import textwrap
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, numpy as np
        import jax.numpy as jnp
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.checkpoint import save_checkpoint, restore_checkpoint
        from repro.launch.mesh import axis_types_kw
        mesh8 = jax.make_mesh((8,), ("model",), **axis_types_kw(1))
        x = jnp.arange(64.0).reshape(16, 4)
        xs = jax.device_put(x, NamedSharding(mesh8, P("model", None)))
        save_checkpoint(r"{tmp_path}", 7, {{"w": xs}})
        # restore onto a DIFFERENT mesh (2-way) — elastic rescale
        mesh2 = jax.make_mesh((2, 4), ("a", "b"), **axis_types_kw(2))
        tgt = NamedSharding(mesh2, P("b", None))
        out, step = restore_checkpoint(r"{tmp_path}", {{"w": x}},
                                       shardings={{"w": tgt}})
        assert step == 7
        np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(x))
        print("ELASTIC_OK")
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True,
                       env={**__import__("os").environ,
                            "PYTHONPATH": "src"}, cwd="/root/repo")
    assert "ELASTIC_OK" in r.stdout, r.stderr[-2000:]
