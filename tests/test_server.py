"""Continuous-batching serving loop: slot lifecycle, prioritized
admission, mid-wave EOS recycling (the PR-6 regression), chunked-prefill
bit-identity, and staggered-admission slot isolation.

The lifecycle tests drive the server with ``EchoLM`` — a minimal
deterministic stub (next token = last fed token + 1 mod vocab) whose cache
is just the per-slot position counter — so wave/slot bookkeeping is
observable without model noise.  The numerical tests use the reduced real
LMs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import LM
from repro.runtime.server import DecodeServer, Request


class EchoLM:
    """argmax(logits) == last fed token + 1 (mod vocab); the cache is the
    per-slot position counter, matching the LM cache tree layout."""
    vocab = 64

    def init_caches(self, batch, max_len):
        return {"scan": (),
                "rest": ({"len": jnp.zeros((batch,), jnp.int32)},)}

    def wave_step(self, params, tokens, lens, caches, batch_ctx=None):
        b, c = tokens.shape
        idx = jnp.clip(lens - 1, 0, c - 1)
        last = jnp.take_along_axis(tokens, idx[:, None], axis=1)[:, 0]
        logits = jax.nn.one_hot((last + 1) % self.vocab, self.vocab)[:, None]
        new = {"scan": (),
               "rest": ({"len": caches["rest"][0]["len"] + lens},)}
        return logits, new

    def reset_slots(self, caches, keep):
        return {"scan": (),
                "rest": ({"len": jnp.where(
                    keep, caches["rest"][0]["len"], 0)},)}


def _req(prompt, **kw):
    return Request(prompt=np.asarray(prompt, np.int32), **kw)


# ---------------------------------------------------------------------------
# Slot lifecycle (EchoLM)
# ---------------------------------------------------------------------------

def test_eos_frees_slot_and_admits_same_iteration():
    """The PR-6 regression: a slot hitting EOS mid-wave must retire
    immediately and the next queued request must be admitted in the SAME
    serving iteration — not after the whole batch drains."""
    srv = DecodeServer(EchoLM(), {}, batch_slots=1, max_len=32,
                       eos_id=5, prefill_chunk=4)
    r1 = _req([4], max_new_tokens=10)     # first generated token is 5 = EOS
    r2 = _req([10], max_new_tokens=3)
    srv.submit(r1)
    srv.submit(r2)
    srv.run_until_drained()
    assert r1.done and r1.out == [5]
    assert r2.done and r2.out == [11, 12, 13]
    # same-iteration recycling: r2 entered the wave counter r1 retired on
    assert r2.admitted_wave == r1.finished_wave
    assert srv.serve_stats["slot_resets"] == 2
    assert srv.serve_stats["admitted"] == 2


def test_priority_queue_ordering():
    """Lower priority value serves first; FIFO within a class (on one slot
    the admission order is fully observable)."""
    srv = DecodeServer(EchoLM(), {}, batch_slots=1, max_len=32,
                       prefill_chunk=2)
    reqs = [_req([i + 1], max_new_tokens=2, priority=p)
            for i, p in enumerate([2, 0, 1, 0])]
    for r in reqs:
        srv.submit(r)
    srv.run_until_drained()
    order = sorted(range(4), key=lambda i: reqs[i].admitted_wave)
    assert order == [1, 3, 2, 0]          # priorities 0, 0 (FIFO), 1, 2
    assert all(r.done for r in reqs)


def test_zero_active_slot_wave_is_a_noop():
    srv = DecodeServer(EchoLM(), {}, batch_slots=2, max_len=16)
    assert srv.step() == 0
    assert srv.run_until_drained() == 0
    assert srv.serve_stats["waves"] == 0


def test_slot_recycling_under_full_queue():
    """More requests than slots with ragged lengths: every slot is recycled
    multiple times, all requests complete, and per-request output follows
    the echo chain from its own prompt (no stale-cache leakage)."""
    srv = DecodeServer(EchoLM(), {}, batch_slots=2, max_len=32,
                       prefill_chunk=4)
    rng = np.random.default_rng(0)
    reqs = []
    for k in range(9):
        n = int(rng.integers(1, 6))
        start = int(rng.integers(0, 40))
        reqs.append(_req([start], max_new_tokens=n))
    for r in reqs:
        srv.submit(r)
    srv.run_until_drained()
    for r in reqs:
        assert r.done
        start = int(r.prompt[0])
        want = [(start + 1 + j) % EchoLM.vocab
                for j in range(r.max_new_tokens)]
        assert r.out == want, (start, r.out, want)
    assert srv.serve_stats["admitted"] == 9
    assert srv.serve_stats["slot_resets"] == 9
    # the 2 slots turned over while others were mid-flight: some admission
    # happened at a wave where the other slot was already past prefill
    waves = sorted(r.admitted_wave for r in reqs)
    assert waves[2] > 0                   # third admission waited for a slot


def test_max_len_slot_retires_and_recycles():
    """A slot that exhausts cache room retires (finished, possibly short)
    and its successor still serves correctly."""
    srv = DecodeServer(EchoLM(), {}, batch_slots=1, max_len=8,
                       prefill_chunk=4)
    r1 = _req([3, 4, 5, 6], max_new_tokens=50)   # wants more than room
    r2 = _req([20], max_new_tokens=2)
    srv.submit(r1)
    srv.submit(r2)
    srv.run_until_drained(max_steps=200)
    # room after the prompt, +1: the first token spends no cache position
    # (it reads the prompt's last logits)
    assert r1.done and len(r1.out) == 8 - 4 + 1
    assert r2.done and r2.out == [21, 22]


def test_request_service_metrics_are_stamped():
    srv = DecodeServer(EchoLM(), {}, batch_slots=2, max_len=16)
    r = _req([7, 8], max_new_tokens=3)
    srv.submit(r)
    srv.run_until_drained()
    assert r.t_submit is not None and r.t_admit >= r.t_submit
    assert r.t_first >= r.t_admit and r.t_done >= r.t_first
    assert len(r.token_times) == 3
    assert r.finished_wave >= r.admitted_wave


# ---------------------------------------------------------------------------
# SLO edge cases (PR 7)
# ---------------------------------------------------------------------------

def test_zero_admissible_requests_with_nonempty_queue():
    """Every queued request's budget already lapsed: step() retires them
    all at admission (status expired), runs NO wave, and returns 0 — a
    queue of dead requests never spins the loop."""
    srv = DecodeServer(EchoLM(), {}, batch_slots=2, max_len=16)
    reqs = [_req([3], max_new_tokens=2, deadline_s=0.0) for _ in range(3)]
    for r in reqs:
        srv.submit(r)
    assert len(srv.queue) == 3
    assert srv.step() == 0
    assert srv.serve_stats["waves"] == 0
    assert srv.serve_stats["expired"] == 3
    assert not srv.queue
    for r in reqs:
        assert r.done and r.status == "expired"
        assert "lapsed in queue" in r.error


def test_all_slots_expire_in_one_wave_then_server_recovers():
    """Budgets that pass admission but lapse during the (artificially
    slowed) first wave: every active slot retires expired mid-wave, and a
    later request is still served normally."""
    from repro.runtime.faults import FaultInjector, FaultSpec
    srv = DecodeServer(
        EchoLM(), {}, batch_slots=2, max_len=16,
        faults=FaultInjector([FaultSpec("wave", at=(1,), delay_s=0.4,
                                        delay_only=True)]))
    # budget wide enough to always survive admission on a loaded box, but
    # narrower than the injected wave stall so it lapses *in service*
    reqs = [_req([3], max_new_tokens=2, deadline_s=0.1),
            _req([7], max_new_tokens=2, deadline_s=0.1)]
    for r in reqs:
        srv.submit(r)
    srv.step()
    for r in reqs:
        assert r.done and r.status == "expired"
        assert "lapsed in service" in r.error
        assert r.t_first is None and not r.out
    assert srv.serve_stats["expired"] == 2
    late = _req([10], max_new_tokens=2)        # no deadline: must serve
    srv.submit(late)
    srv.run_until_drained()
    assert late.status == "ok" and late.out == [11, 12]


def test_deadline_past_at_admission_pops_next_request():
    """One slot, two requests: the first expires at admission (not at
    submit — no capacity calibration), and the SAME admission pass admits
    the second into the slot."""
    import time
    srv = DecodeServer(EchoLM(), {}, batch_slots=1, max_len=16)
    dead = _req([3], max_new_tokens=2, deadline_s=0.01)
    live = _req([7], max_new_tokens=2)
    srv.submit(dead)
    srv.submit(live)
    time.sleep(0.02)                           # dead's budget lapses queued
    srv.run_until_drained()
    assert dead.status == "expired" and not dead.out
    assert live.status == "ok" and live.out == [8, 9]
    assert srv.serve_stats["admitted"] == 1
    # the wave count never stalled on the dead request
    assert dead.admitted_wave is None


def test_per_request_deadline_overrides_server_slo():
    """Request.deadline_s wins over ttft_slo_s: a generous per-request
    budget keeps a request alive that the server-wide SLO would expire."""
    import time
    srv = DecodeServer(EchoLM(), {}, batch_slots=1, max_len=16,
                       ttft_slo_s=0.01)
    r = _req([3], max_new_tokens=2, deadline_s=30.0)
    srv.submit(r)
    time.sleep(0.02)
    srv.run_until_drained()
    assert r.status == "ok" and r.out == [4, 5]


# ---------------------------------------------------------------------------
# Chunked prefill bit-identity (real LM)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["stablelm-3b", "qwen3-moe-235b-a22b"])
def test_chunked_prefill_bit_identical(arch):
    """Splitting a ragged prompt batch into waves of ANY chunk size replays
    the same masked micro-step sequence: logits at each slot's last prompt
    token and every cache leaf are bit-identical to the whole-prompt wave
    (the MoE arch exercises capacity contention across slots too)."""
    cfg = get_reduced(arch)
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    b, L = 2, 9
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, L), 0,
                              cfg.vocab_size)
    lens = jnp.array([9, 6], jnp.int32)
    wave = jax.jit(lm.wave_step)
    lg_whole, cache_whole = wave(params, toks, lens,
                                 lm.init_caches(b, 16))
    for chunk in (1, 4):
        caches = lm.init_caches(b, 16)
        lg_by_slot = [None] * b
        off = 0
        while off < L:
            n = min(chunk, L - off)
            cl = jnp.clip(lens - off, 0, n)
            part = jnp.pad(toks[:, off:off + n], ((0, 0), (0, chunk - n)))
            lg, caches = wave(params, part, cl, caches)
            for i in range(b):
                if int(cl[i]) > 0 and off + int(cl[i]) == int(lens[i]):
                    lg_by_slot[i] = lg[i]
            off += chunk
        for i in range(b):
            np.testing.assert_array_equal(
                np.asarray(lg_by_slot[i]), np.asarray(lg_whole[i]),
                err_msg=f"{arch} chunk={chunk} slot={i}")
        for lw, lc in zip(jax.tree.leaves(cache_whole),
                          jax.tree.leaves(caches)):
            np.testing.assert_array_equal(np.asarray(lw), np.asarray(lc))


def test_wave_step_matches_decode_step_replay():
    """wave_step IS the fused masked decode loop: replaying the same
    tokens through per-step decode_step calls (the legacy serving path)
    produces bit-identical logits and caches."""
    cfg = get_reduced("stablelm-3b")
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    b, L = 2, 6
    toks = jax.random.randint(jax.random.PRNGKey(2), (b, L), 0,
                              cfg.vocab_size)
    lens = jnp.array([6, 4], jnp.int32)
    lg_wave, cache_wave = jax.jit(lm.wave_step)(
        params, toks, lens, lm.init_caches(b, 16))
    caches = lm.init_caches(b, 16)
    step = jax.jit(lm.decode_step)
    lg_by_slot = [None] * b
    for t in range(L):
        lg, caches = step(params, toks[:, t:t + 1], caches, None,
                          jnp.asarray(t < np.asarray(lens)))
        for i in range(b):
            if t == int(lens[i]) - 1:
                lg_by_slot[i] = lg[i]
    for i in range(b):
        np.testing.assert_array_equal(np.asarray(lg_by_slot[i]),
                                      np.asarray(lg_wave[i]))
    for lw, lc in zip(jax.tree.leaves(cache_wave),
                      jax.tree.leaves(caches)):
        np.testing.assert_array_equal(np.asarray(lw), np.asarray(lc))


# ---------------------------------------------------------------------------
# Staggered admission / slot isolation (real LM, through the server)
# ---------------------------------------------------------------------------

def test_staggered_admission_matches_solo_decode():
    """Requests recycled through a shared 2-slot server (admitted at
    different waves, into previously-used slots) must produce exactly the
    greedy continuation they get when served alone — slot recycling leaks
    no stale cache state (dense arch: slots are independent)."""
    cfg = get_reduced("h2o-danube-1.8b")
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab_size, int(n)).astype(np.int32)
               for n in (5, 3, 7, 2, 4)]
    shared = [Request(prompt=p.copy(), max_new_tokens=4) for p in prompts]
    srv = DecodeServer(lm, params, batch_slots=2, max_len=32,
                       prefill_chunk=3)
    for r in shared:
        srv.submit(r)
    srv.run_until_drained()
    assert all(r.done for r in shared)
    # staggering actually happened: admissions span multiple waves
    assert len({r.admitted_wave for r in shared}) > 1
    for p, r in zip(prompts, shared):
        solo_req = Request(prompt=p.copy(), max_new_tokens=4)
        solo = DecodeServer(lm, params, batch_slots=1, max_len=32,
                            prefill_chunk=8)
        solo.submit(solo_req)
        solo.run_until_drained()
        assert solo_req.out == r.out, (p, solo_req.out, r.out)


def test_server_output_invariant_to_prefill_chunk():
    """End-to-end: the same workload through prefill_chunk=1 vs 4 servers
    yields identical greedy outputs (chunking is a scheduling choice, not a
    numerics choice)."""
    cfg = get_reduced("stablelm-3b")
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab_size, int(n)).astype(np.int32)
               for n in (4, 6, 2)]
    outs = []
    for chunk in (1, 4):
        reqs = [Request(prompt=p.copy(), max_new_tokens=3) for p in prompts]
        srv = DecodeServer(lm, params, batch_slots=2, max_len=32,
                           prefill_chunk=chunk)
        for r in reqs:
            srv.submit(r)
        srv.run_until_drained()
        outs.append([r.out for r in reqs])
    assert outs[0] == outs[1]
