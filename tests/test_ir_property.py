"""Hypothesis property tests over the compiler's core invariants.

For random embedding-op instances (kind, sizes, semiring, locality,
vectorization width): the whole IR pipeline preserves semantics at every
stage and opt level, queues always conserve, and alignment padding is
value-preserving.
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis "
    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.ops import EmbeddingOp, Semiring, make_inputs, reference
from repro.core.pipeline import compile_op, run_interpreted

kinds = st.sampled_from(["sls", "kg", "gather", "spmm", "fusedmm"])


@st.composite
def ops(draw):
    kind = draw(kinds)
    sr = Semiring()  # semiring variation tested separately below
    fmt = draw(st.sampled_from(["offsets", "lengths"])) \
        if kind in ("sls", "spmm") else "offsets"
    return EmbeddingOp(
        kind=kind,
        num_segments=draw(st.integers(1, 8)),
        num_embeddings=draw(st.integers(1, 20)),
        emb_len=draw(st.integers(1, 20)),
        avg_lookups=draw(st.integers(0, 5)),
        block_rows=draw(st.integers(1, 3)) if kind == "gather" else 1,
        weighted=draw(st.booleans()) if kind in ("sls",) else False,
        index_format=fmt,
        semiring=sr)


@settings(max_examples=40, deadline=None)
@given(op=ops(), lvl=st.sampled_from(["O0", "O1", "O2", "O3"]),
       vlen=st.sampled_from([1, 3, 4, 8]), seed=st.integers(0, 3))
def test_pipeline_preserves_semantics(op, lvl, vlen, seed):
    if lvl == "O0":
        vlen = 1
    ins = make_inputs(op, seed=seed)
    ref = reference(op, ins)
    res = compile_op(op, lvl, vlen=max(vlen, 1))
    for stage in ("slc", "dlc"):
        got = run_interpreted(res, ins, stage)
        np.testing.assert_allclose(got, ref, rtol=1e-3, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(op=ops(), seed=st.integers(0, 3))
def test_queues_conserve_and_shrink(op, seed):
    ins = make_inputs(op, seed=seed)
    pushed = []
    for lvl in ("O0", "O1", "O2", "O3"):
        _, stats = run_interpreted(compile_op(op, lvl, vlen=4), ins, "dlc",
                                   return_queues=True)
        assert stats["data_left"] == 0 and stats["ctrl_left"] == 0
        pushed.append(stats["data_pushed"])
    assert pushed[0] >= pushed[1] >= pushed[2] >= pushed[3]


@settings(max_examples=20, deadline=None)
@given(add=st.sampled_from(["add", "max", "min"]),
       mul=st.sampled_from(["mul", "add"]),
       kind=st.sampled_from(["sls", "kg"]),
       lvl=st.sampled_from(["O0", "O2", "O3"]),
       seed=st.integers(0, 2))
def test_semiring_generality(add, mul, kind, lvl, seed):
    op = EmbeddingOp(kind=kind, num_segments=5, num_embeddings=7, emb_len=6,
                     avg_lookups=2, weighted=(kind == "sls"),
                     semiring=Semiring(add, mul))
    ins = make_inputs(op, seed=seed)
    got = run_interpreted(compile_op(op, lvl, vlen=4), ins, "dlc")
    np.testing.assert_allclose(got, reference(op, ins), rtol=1e-3, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(emb_len=st.integers(1, 40), vlen=st.sampled_from([4, 8, 16]),
       seed=st.integers(0, 2))
def test_alignment_padding_value_preserving(emb_len, vlen, seed):
    """Queue alignment pads rows to vlen multiples; results identical."""
    op = EmbeddingOp(kind="sls", num_segments=4, num_embeddings=9,
                     emb_len=emb_len, avg_lookups=3)
    ins = make_inputs(op, seed=seed)
    res = compile_op(op, "O3", vlen=vlen)
    padded = res.opt.get("padded_emb")
    assert padded is not None and padded % vlen == 0 and padded >= emb_len
    got = run_interpreted(res, ins, "dlc")
    np.testing.assert_allclose(got, reference(op, ins), rtol=1e-3, atol=1e-4)
