"""Compiler IR correctness: SCF ≡ SLC ≡ DLC(queued) across kinds × opt
levels, queue-traffic structure (Fig 14), and verifier behaviour."""
import numpy as np
import pytest

from repro.core.ops import EmbeddingOp, Semiring, make_inputs, reference
from repro.core.pipeline import OPT_LEVELS, compile_op, run_interpreted
from repro.core.scf import build_scf, interp_scf
from repro.core import slc as slc_ir
from repro.core.decouple import decouple

KINDS = ["sls", "kg", "gather", "spmm", "fusedmm"]


def _op(kind, seed=0, emb_len=10, weighted=False):
    return EmbeddingOp(kind=kind, num_segments=6, num_embeddings=13,
                       emb_len=emb_len, avg_lookups=3,
                       block_rows=2 if kind == "gather" else 1,
                       weighted=weighted)


@pytest.mark.parametrize("kind", KINDS)
def test_scf_matches_reference(kind):
    op = _op(kind)
    ins = make_inputs(op, seed=1)
    np.testing.assert_allclose(interp_scf(build_scf(op), ins),
                               reference(op, ins), rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("kind", KINDS)
@pytest.mark.parametrize("lvl", OPT_LEVELS)
@pytest.mark.parametrize("stage", ["slc", "dlc"])
def test_pipeline_semantics(kind, lvl, stage):
    op = _op(kind, weighted=(kind == "sls"))
    ins = make_inputs(op, seed=2)
    res = compile_op(op, lvl, vlen=4)
    got = run_interpreted(res, ins, stage)
    np.testing.assert_allclose(got, reference(op, ins), rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("add,mul", [("max", "mul"), ("min", "mul"),
                                     ("max", "add")])
@pytest.mark.parametrize("lvl", OPT_LEVELS)
def test_semirings(add, mul, lvl):
    op = EmbeddingOp(kind="kg", num_segments=5, num_embeddings=9, emb_len=6,
                     semiring=Semiring(add, mul))
    ins = make_inputs(op, seed=3)
    got = run_interpreted(compile_op(op, lvl, vlen=4), ins, "dlc")
    np.testing.assert_allclose(got, reference(op, ins), rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("kind", KINDS)
def test_queue_conservation(kind):
    """Every pushed datum/token is popped exactly once (DAE invariant)."""
    op = _op(kind)
    ins = make_inputs(op, seed=4)
    for lvl in OPT_LEVELS:
        _, stats = run_interpreted(compile_op(op, lvl, vlen=4), ins, "dlc",
                                   return_queues=True)
        assert stats["data_left"] == 0, (kind, lvl, stats)
        assert stats["ctrl_left"] == 0, (kind, lvl, stats)


@pytest.mark.parametrize("kind", KINDS)
def test_queue_traffic_decreases_with_opt(kind):
    """Fig 14: each optimization strictly reduces marshaled data."""
    op = _op(kind, emb_len=16)
    ins = make_inputs(op, seed=5)
    data = []
    for lvl in OPT_LEVELS:
        _, stats = run_interpreted(compile_op(op, lvl, vlen=4), ins, "dlc",
                                   return_queues=True)
        data.append(stats["data_pushed"])
    assert data[0] >= data[1] >= data[2] >= data[3], (kind, data)
    assert data[0] > data[3] or data[0] == 0


def test_gather_opt3_fully_offloaded():
    """SpAttn emb-opt3 = store streams: zero queue traffic (the 17× case)."""
    op = _op("gather")
    ins = make_inputs(op, seed=6)
    _, stats = run_interpreted(compile_op(op, "O3", vlen=4), ins, "dlc",
                               return_queues=True)
    assert stats["data_pushed"] == 0
    assert stats["tokens"] == 0


def test_decoupling_selects_workspace_loops():
    """fusedmm's second e-loop re-reads x[j,:] → must stay on the execute
    unit (paper §6.2), i.e. inside a callback, not as an SLC loop."""
    fn = decouple(build_scf(_op("fusedmm")))
    loops = slc_ir.loops(fn.body)
    # i, p, e (SDDMM) offloaded; e2 (workspace) must NOT be
    assert len(loops) == 3
    text = slc_ir.pretty(fn)
    assert "for(e2=" in text  # workspace loop rendered inside a callback


def test_verifier_rejects_writable_memstr():
    from repro.core import scf
    op = _op("sls")
    fn = decouple(build_scf(op))
    fn.body.insert(0, slc_ir.MemStr("bad", "out", (scf.Const(0),
                                                   scf.Const(0))))
    with pytest.raises(slc_ir.SlcVerifyError):
        slc_ir.verify(fn)


def test_verifier_rejects_undefined_stream():
    op = _op("sls")
    fn = decouple(build_scf(op))
    fn.body.append(slc_ir.Callback([__import__(
        "repro.core.scf", fromlist=["Let"]).Let(
            "x", slc_ir.ToVal("nonexistent_stream"))]))
    with pytest.raises(slc_ir.SlcVerifyError):
        slc_ir.verify(fn)


def test_vectorize_rejected_below_o1_reduction():
    """hsum rewrite: fusedmm SDDMM accumulator vectorizes via horizontal
    sum; result must stay exact."""
    op = _op("fusedmm", emb_len=9)
    ins = make_inputs(op, seed=7)
    res = compile_op(op, "O1", vlen=4)
    got = run_interpreted(res, ins, "slc")
    np.testing.assert_allclose(got, reference(op, ins), rtol=1e-4, atol=1e-5)


def test_opt_metadata_recorded():
    op = _op("gather")
    res = compile_op(op, "O3", vlen=4)
    assert res.opt["vectorized"] and res.opt["bufferized"]
    assert res.opt["store_streams"]
    res0 = compile_op(op, "O0")
    assert not res0.opt["vectorized"]


@pytest.mark.parametrize("kind", ["sls", "spmm"])
@pytest.mark.parametrize("lvl", OPT_LEVELS)
def test_accumulation_streams_lengths_format(kind, lvl):
    """Paper §7.4: segment boundaries tracked by ACCUMULATING lengths
    (acc_str) instead of loading offsets — the scalar accumulator becomes an
    access-unit stream, keeping the inner loop decoupled."""
    op = EmbeddingOp(kind=kind, num_segments=6, num_embeddings=13,
                     emb_len=10, avg_lookups=3, weighted=(kind == "sls"),
                     index_format="lengths")
    ins = make_inputs(op, seed=2)
    assert "lens" in ins and "ptrs" not in ins
    res = compile_op(op, lvl, vlen=4)
    for stage in ("slc", "dlc"):
        got = run_interpreted(res, ins, stage)
        np.testing.assert_allclose(got, reference(op, ins), rtol=1e-4,
                                   atol=1e-5)
    text = slc_ir.pretty(res.slc)
    assert "acc_str" in text  # the §7.4 stream is actually generated
