"""Chaos suite: the seeded site-addressable FaultInjector, executor fault
recovery per DAE site, the serving wave watchdog + bounded retry, prompt
hardening policies, SLO shedding, and the spawn-retry helper.

The recovery tests all assert the same property the ISSUE names: after a
typed fault + ``reset()``, the next steps produce outputs **bit-identical**
to a fault-free run — recovery never corrupts the marshaling caches, the
staging pool, or a neighbouring slot.  ``CHAOS_SEED`` (the CI chaos leg
pins it) seeds the probabilistic specs through ``injector_for_env``.
"""
import os
import subprocess

import numpy as np
import pytest

from repro.core.executor import ProgramExecutor
from repro.core.ops import EmbeddingOp, EmbeddingProgram, make_program_inputs
from repro.core.pipeline import compile_program, run_program_interpreted
from repro.runtime.faults import (EmberFault, FaultInjector, FaultSpec,
                                  InjectedFailure, MalformedAccessError,
                                  SITES, StragglerTimeout, WaveTimeout,
                                  injector_for_env)
from repro.runtime.server import DecodeServer, Request

from test_server import EchoLM, _req


def _prog():
    return EmbeddingProgram("chaos", (
        ("s", EmbeddingOp("sls", 5, 9, 8, avg_lookups=3)),
        ("g", EmbeddingOp("gather", 6, 20, 8)),
    ))


# ---------------------------------------------------------------------------
# FaultInjector semantics
# ---------------------------------------------------------------------------

def test_spec_fires_at_exact_ordinals_and_respects_times():
    inj = FaultInjector([FaultSpec("dispatch", at=(2, 3), times=1)])
    inj.fire("dispatch")                       # call 1: pass
    with pytest.raises(InjectedFailure, match="site=dispatch call=2"):
        inj.fire("dispatch")
    inj.fire("dispatch")                       # call 3: times budget spent
    assert inj.total_fired() == 1
    assert inj.counts["dispatch"] == 3
    assert inj.log == [("dispatch", 2, "InjectedFailure")]


def test_sites_are_independent_counters():
    inj = FaultInjector([FaultSpec("result", at=(1,))])
    inj.fire("marshal")
    inj.fire("transfer")                       # other sites never match
    with pytest.raises(InjectedFailure):
        inj.fire("result")


def test_probabilistic_schedule_replays_per_seed():
    def schedule(seed):
        inj = FaultInjector([FaultSpec("wave", p=0.5, times=100)],
                            seed=seed)
        fired = []
        for k in range(40):
            try:
                inj.fire("wave")
                fired.append(False)
            except InjectedFailure:
                fired.append(True)
        return fired

    assert schedule(7) == schedule(7)          # bit-identical replay
    assert any(schedule(7))                    # and it actually fires


def test_delay_only_sleeps_without_raising():
    inj = FaultInjector([FaultSpec("wave", at=(1,), delay_s=0.01,
                                   delay_only=True)])
    inj.fire("wave")                           # no raise
    assert inj.log == [("wave", 1, "delay")]
    assert inj.total_fired() == 1


def test_custom_error_type_and_context():
    inj = FaultInjector([FaultSpec("step", at=(1,),
                                   error=StragglerTimeout)])
    with pytest.raises(StragglerTimeout, match=r"\[step=4\]"):
        inj.fire("step", step=4)


def test_injector_for_env_seeds_from_chaos_seed():
    assert injector_for_env("7").seed == 7
    assert injector_for_env(None).seed == 0
    assert injector_for_env("").seed == 0
    # the CI chaos leg: whatever CHAOS_SEED is pinned to must replay
    env = os.environ.get("CHAOS_SEED")
    a = injector_for_env(env, [FaultSpec("wave", p=0.3, times=5)])
    b = injector_for_env(env, [FaultSpec("wave", p=0.3, times=5)])
    for _ in range(20):
        ra = rb = None
        try:
            a.fire("wave")
        except InjectedFailure as e:
            ra = str(e)
        try:
            b.fire("wave")
        except InjectedFailure as e:
            rb = str(e)
        assert ra == rb


def test_unknown_site_rejected():
    with pytest.raises(AssertionError):
        FaultSpec("gpu-on-fire")
    assert set(SITES) == {"marshal", "transfer", "dispatch", "result",
                          "wave", "step", "rpc_send", "rpc_recv",
                          "heartbeat", "service_crash"}


# ---------------------------------------------------------------------------
# Executor recovery per DAE site: fault -> reset -> bit-identical steps
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("site", ["marshal", "transfer", "dispatch",
                                  "result"])
def test_executor_site_fault_then_reset_recovers(site):
    pres = compile_program(_prog(), "O3", vlen=4, use_cache=False)
    # default (pallas) backend: the only one where every DAE phase runs —
    # jax-backend singletons marshal host views without scratch or puts
    ex = ProgramExecutor(pres,
                         faults=FaultInjector([FaultSpec(site, at=(1,))]))
    ins = make_program_inputs(_prog(), seed=0)
    with pytest.raises(InjectedFailure, match=f"site={site}"):
        ex.step(ins)
    ex.reset()
    assert ex.stats["resets"] == 1
    # the pool must not leak busy slots from the abandoned step
    assert all(o is None for e in ex.pool._entries.values()
               for o in e["owners"])
    for seed in (1, 2):
        ins = make_program_inputs(_prog(), seed=seed)
        got = ex.step(ins)
        want = run_program_interpreted(pres, ins)
        for n in want:
            np.testing.assert_array_equal(np.asarray(got[n]),
                                          np.asarray(want[n]),
                                          err_msg=f"{n} after {site} fault")


def test_executor_fault_types_are_ember_faults():
    assert issubclass(InjectedFailure, EmberFault)
    assert issubclass(MalformedAccessError, EmberFault)
    assert issubclass(WaveTimeout, EmberFault)
    assert issubclass(StragglerTimeout, EmberFault)


# ---------------------------------------------------------------------------
# Serving wave watchdog + bounded retry (EchoLM: outputs fully predictable)
# ---------------------------------------------------------------------------

def _echo_run(**kw):
    srv = DecodeServer(EchoLM(), {}, batch_slots=2, max_len=32,
                       prefill_chunk=4, **kw)
    reqs = [_req([10], max_new_tokens=3), _req([20], max_new_tokens=3),
            _req([30], max_new_tokens=2)]
    for r in reqs:
        srv.submit(r)
    srv.run_until_drained(max_steps=100)
    return srv, reqs


def test_wave_fault_retries_once_and_matches_fault_free():
    _, clean = _echo_run()
    srv, reqs = _echo_run(
        faults=FaultInjector([FaultSpec("wave", at=(2,), times=1)]),
        wave_retries=1)
    assert srv.serve_stats["wave_faults"] == 1
    assert srv.serve_stats["wave_retries"] == 1
    assert srv.serve_stats["failed"] == 0
    for r, c in zip(reqs, clean):
        assert r.done and r.status == "ok"
        assert r.out == c.out


def test_wave_fault_beyond_retries_fails_only_implicated():
    _, clean = _echo_run()
    srv, reqs = _echo_run(
        faults=FaultInjector([FaultSpec("wave", at=(2, 3), times=2)]),
        wave_retries=1)
    assert srv.serve_stats["wave_faults"] == 2
    failed = [r for r in reqs if r.status == "failed"]
    assert failed and len(failed) < len(reqs)
    for r in failed:
        assert r.done and "InjectedFailure" in r.error
    # the survivors still produce the exact fault-free echo chain
    for r, c in zip(reqs, clean):
        if r.status == "ok":
            assert r.out == c.out
    assert srv.serve_stats["failed"] == len(failed)


def test_hung_wave_watchdog_times_out_and_recovers():
    # wide margins (1s hang vs 0.25s deadline, ms-scale real waves) and
    # retries=2 so a loaded CI box tripping a *genuine* slow wave on top
    # of the injected hang still recovers
    _, clean = _echo_run()
    srv, reqs = _echo_run(
        faults=FaultInjector([FaultSpec("wave", at=(2,), delay_s=1.0,
                                        delay_only=True)]),
        wave_deadline_s=0.25, wave_retries=2)
    assert srv.serve_stats["watchdog_timeouts"] >= 1
    assert srv.serve_stats["wave_retries"] >= 1
    for r, c in zip(reqs, clean):
        assert r.done and r.status == "ok"
        assert r.out == c.out


def test_hung_wave_without_retries_fails_typed():
    srv, reqs = _echo_run(
        faults=FaultInjector([FaultSpec("wave", at=(1,), delay_s=0.2,
                                        delay_only=True)]),
        wave_deadline_s=0.05, wave_retries=0)
    failed = [r for r in reqs if r.status == "failed"]
    assert failed
    assert all("WaveTimeout" in r.error for r in failed)


# ---------------------------------------------------------------------------
# Prompt hardening + SLO shedding (EchoLM)
# ---------------------------------------------------------------------------

def test_prompt_hardening_strict_fails_typed():
    srv = DecodeServer(EchoLM(), {}, batch_slots=1, max_len=16)
    bad = _req([70, 3], max_new_tokens=2)      # vocab is 64
    srv.submit(bad)
    assert bad.done and bad.status == "failed"
    assert "MalformedAccessError" in bad.error
    assert not srv.queue                       # never admitted
    ok = _req([3], max_new_tokens=2)
    srv.submit(ok)
    srv.run_until_drained()
    assert ok.status == "ok" and ok.out == [4, 5]


@pytest.mark.parametrize("policy", ["clamp", "drop"])
def test_prompt_hardening_degrades_and_counts(policy):
    srv = DecodeServer(EchoLM(), {}, batch_slots=1, max_len=16,
                       index_policy=policy)
    r = _req([70, 3], max_new_tokens=2)
    srv.submit(r)
    srv.run_until_drained()
    assert r.status == "ok"
    # clamp: [63, 3]; drop: [3] — either way the echo runs from 3
    assert r.out == [4, 5]
    assert srv.serve_stats["oob_prompt_tokens"] == 1


def test_prompt_drop_to_empty_fails():
    srv = DecodeServer(EchoLM(), {}, batch_slots=1, max_len=16,
                       index_policy="drop")
    r = _req([70, 99], max_new_tokens=2)
    srv.submit(r)
    assert r.done and r.status == "failed"
    assert "empty" in r.error


def test_submit_shed_on_predicted_queue_wait():
    srv = DecodeServer(EchoLM(), {}, batch_slots=1, max_len=16,
                       capacity_rps=1.0, ttft_slo_s=0.5)
    r1, r2 = _req([3], max_new_tokens=2), _req([4], max_new_tokens=2)
    srv.submit(r1)                             # queue empty: admitted
    srv.submit(r2)                             # predicted wait 1.0s > 0.5s
    assert r2.done and r2.status == "shed"
    assert "predicted queue wait" in r2.error
    assert srv.serve_stats["shed"] == 1
    srv.run_until_drained()
    assert r1.status == "ok" and r1.out == [4, 5]


def test_every_request_reaches_exactly_one_terminal_status():
    srv, reqs = _echo_run(
        faults=FaultInjector([FaultSpec("wave", at=(1, 2), times=2)]),
        wave_retries=0)
    for r in reqs:
        assert r.done
        assert r.status in ("ok", "shed", "expired", "failed")
        assert r.t_done is not None


# ---------------------------------------------------------------------------
# Pipeline-group chaos through the real server (group-level sites)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("site,kw", [
    ("transfer", {}),
    ("dispatch", {}),
    # "result" only fires when the watchdog consumes the wave handles
    ("result", {"wave_deadline_s": 30.0}),
])
def test_pipeline_site_fault_recovers_bit_identical(site, kw):
    import jax
    from repro.configs import get_reduced
    from repro.models import LM
    cfg = get_reduced("qwen3-moe-235b-a22b")
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab_size, 4).astype(np.int32)
               for _ in range(3)]

    def run(faults=None):
        srv = DecodeServer(lm, params, batch_slots=2, max_len=32,
                           prefill_chunk=4, pipeline=True, faults=faults,
                           wave_retries=1, **kw)
        reqs = [Request(prompt=p.copy(), max_new_tokens=3) for p in prompts]
        for r in reqs:
            srv.submit(r)
        srv.run_until_drained(max_steps=100)
        return srv, reqs

    _, clean = run()
    srv, reqs = run(FaultInjector([FaultSpec(site, at=(2,), times=1)]))
    assert srv.serve_stats["wave_faults"] == 1
    assert srv.serve_stats["wave_retries"] == 1
    assert srv.pipeline_group.stats["resets"] >= 1
    for r, c in zip(reqs, clean):
        assert r.done and r.status == "ok"
        assert r.out == c.out, (site, r.out, c.out)


# ---------------------------------------------------------------------------
# Trainer: shared vocabulary + the "step" site
# ---------------------------------------------------------------------------

def test_trainer_reexports_shared_fault_types():
    from repro.runtime import faults as fl
    from repro.runtime import trainer as tr
    assert tr.InjectedFailure is fl.InjectedFailure
    assert tr.StragglerTimeout is fl.StragglerTimeout


def test_trainer_step_site_fires(tmp_path):
    import jax
    from repro.configs import get_reduced
    from repro.data.pipeline import DataConfig, SyntheticTokens
    from repro.models import LM
    from repro.runtime.trainer import Trainer, TrainerConfig
    cfg = get_reduced("stablelm-3b")
    lm = LM(cfg)
    data = SyntheticTokens(DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                                      global_batch=8))
    tcfg = TrainerConfig(total_steps=6, ckpt_every=100,
                         ckpt_dir=str(tmp_path / "ckpt"))
    inj = FaultInjector([FaultSpec("step", at=(3,))])
    with pytest.raises(InjectedFailure, match="site=step call=3"):
        Trainer(lm, data, tcfg, faults=inj).run(jax.random.PRNGKey(0))
    assert inj.counts["step"] == 3


# ---------------------------------------------------------------------------
# Spawn retry: infra failures retry, test failures never do
# ---------------------------------------------------------------------------

class _FakeRun:
    """Scripted subprocess.run: pops the next outcome per call (an int
    returncode or an OSError instance to raise)."""

    def __init__(self, outcomes):
        self.outcomes = list(outcomes)
        self.calls = 0

    def __call__(self, cmd, **kw):
        self.calls += 1
        out = self.outcomes.pop(0)
        if isinstance(out, OSError):
            raise out
        return subprocess.CompletedProcess(cmd, out)


def _retry(outcomes, attempts=3):
    from benchmarks._mesh import run_with_spawn_retry
    import benchmarks._mesh as mesh
    fake = _FakeRun(outcomes)
    sleeps = []
    orig = mesh.subprocess.run
    mesh.subprocess.run = fake
    try:
        r = run_with_spawn_retry(["x"], attempts=attempts,
                                 backoff_s=0.5, sleep=sleeps.append)
    finally:
        mesh.subprocess.run = orig
    return r, fake, sleeps


def test_spawn_retry_oserror_then_success():
    r, fake, sleeps = _retry([OSError("EAGAIN"), 0])
    assert r.returncode == 0 and fake.calls == 2
    assert sleeps == [0.5]                     # exponential from backoff_s


def test_spawn_retry_signal_killed_child_retries():
    r, fake, sleeps = _retry([-9, -9, 0])
    assert r.returncode == 0 and fake.calls == 3
    assert sleeps == [0.5, 1.0]


def test_spawn_retry_ordinary_failure_never_retries():
    r, fake, sleeps = _retry([1, 0])
    assert r.returncode == 1 and fake.calls == 1
    assert sleeps == []


def test_spawn_retry_exhausted_signal_kills_returns_last():
    r, fake, _ = _retry([-9, -9, -9])
    assert r.returncode == -9 and fake.calls == 3


def test_spawn_retry_exhausted_oserrors_reraises():
    with pytest.raises(OSError, match="ENOMEM"):
        _retry([OSError("ENOMEM"), OSError("ENOMEM"), OSError("ENOMEM")])
